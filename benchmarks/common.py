"""Shared benchmark harness: cached index builds, ground truth, timing."""
from __future__ import annotations

import json
import os
import pickle
import time

import numpy as np

from repro.core import (FavorIndex, HnswParams, compile_filter, paper_filters,
                        paper_schema)
from repro.core import filters as F
from repro.core import refimpl
from repro.data import synthetic

CACHE = os.environ.get("BENCH_CACHE", os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), ".bench_cache"))

# default benchmark scale (paper uses 1M x 128d on a 64-thread server; this
# container is 1 CPU core -- trends, not absolute QPS, are the deliverable)
N = int(os.environ.get("BENCH_N", 20000))
DIM = int(os.environ.get("BENCH_DIM", 32))
NQ = int(os.environ.get("BENCH_Q", 128))
SEED = 7


def _cache_path(name: str) -> str:
    os.makedirs(CACHE, exist_ok=True)
    return os.path.join(CACHE, name)


def get_dataset(n: int = N, dim: int = DIM, seed: int = SEED):
    vecs, attrs, schema = synthetic.make_paper_dataset(n, dim, seed=seed)
    queries = synthetic.make_queries(NQ, dim, dataset_seed=seed)
    return vecs, attrs, schema, queries


def get_index(n: int = N, dim: int = DIM, seed: int = SEED,
              M: int = 12, efc: int = 60) -> FavorIndex:
    key = f"favor_{n}_{dim}_{seed}_{M}_{efc}.pkl"
    path = _cache_path(key)
    vecs, attrs, schema, _ = get_dataset(n, dim, seed)
    if os.path.exists(path):
        with open(path, "rb") as f:
            idx = pickle.load(f)
        return FavorIndex(idx, attrs)
    t0 = time.perf_counter()
    fi = FavorIndex.build(vecs, attrs, HnswParams(M=M, efc=efc, seed=seed))
    fi.index.build_seconds = getattr(fi, "build_seconds", time.perf_counter() - t0)
    with open(path, "wb") as f:
        pickle.dump(fi.index, f)
    return fi


def update_bench_json(section: str, payload: dict,
                      name: str = "BENCH_serve.json",
                      outdir: str = "bench_out") -> str:
    """Merge one benchmark's summary into the stable cross-PR serving JSON.

    Multiple benchmarks (bench_cache, bench_serve_backends, bench_qps_recall
    ``run_scorers``) contribute sections to the same file; read-modify-write
    keeps them from clobbering each other.  Sections this run did not
    produce are preserved verbatim -- even when the file also carries legacy
    pre-section keys (the old wholesale reset on a legacy marker is how the
    file once shed its ``graph_scorers`` section); only non-dict flat values
    and the legacy ``bench`` blob are dropped.
    """
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, name)
    data: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except ValueError:
            data = {}
    if not isinstance(data, dict):
        data = {}
    data = {k: v for k, v in data.items()
            if k != "bench" and isinstance(v, dict)}
    data[section] = payload
    txt = json.dumps(data, indent=2, sort_keys=True)
    with open(path, "w") as f:
        f.write(txt)
    # mirror the canonical serving summary at the repo root so every PR
    # diff carries the current numbers next to the code that moved them
    if name == "BENCH_serve.json":
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        with open(os.path.join(root, name), "w") as f:
            f.write(txt)
    return path


def ground_truth(vecs, mask, queries, k: int = 10):
    out = []
    for q in queries:
        ids, _ = refimpl.bruteforce_filtered(vecs, mask, q, k)
        out.append(ids)
    return out


def mean_recall(ids_batch, truth, k: int = 10) -> float:
    return float(np.mean([refimpl.recall_at_k(np.asarray(i), t, k)
                          for i, t in zip(ids_batch, truth)]))


def timed_search(fi: FavorIndex, queries, flt, *, k=10, ef=64, repeats=3, **kw):
    """Returns (result, best qps) -- warm (post-compile) timing."""
    from repro.core import SearchOptions
    opts = SearchOptions(k=k, ef=ef, **kw)
    res = fi.query(queries, flt, opts)  # warm-up/compile
    best = 0.0
    for _ in range(repeats):
        res = fi.query(queries, flt, opts)
        best = max(best, res.qps)
    return res, best


class Csv:
    def __init__(self, name: str, header: list[str], outdir: str = "bench_out"):
        os.makedirs(outdir, exist_ok=True)
        self.path = os.path.join(outdir, name)
        self.rows = [header]

    def add(self, *row):
        self.rows.append([f"{x:.6g}" if isinstance(x, float) else str(x)
                          for x in row])

    def write(self, echo: bool = True):
        txt = "\n".join(",".join(r) for r in self.rows)
        with open(self.path, "w") as f:
            f.write(txt + "\n")
        if echo:
            print(txt)
        return self.path
