"""Zipf-skewed repeat-filter serving benchmark for the cache subsystem.

Production hybrid-query traffic is heavily skewed: the same filters (tenant
ids, facets, date windows) and repeat query vectors recur constantly.  This
benchmark draws requests from a Zipf distribution over a pool of distinct
(query, filter) pairs, then drives the same request sequence through an
uncached ``LocalBackend`` engine and a ``CachingBackend`` wrap, sweeping the
Zipf exponent (skew -> hit rate).

Reported per sweep point: QPS (both engines), speedup, p99 latency, per-layer
hit rates, Recall@10 of both engines against exact ground truth, and the
fraction of requests where cached ids differ from uncached (must be 0: every
layer is exact at the default CacheSpec).

A second sweep measures the **semantic-threshold trade**: the same
repeat-heavy stream with near-duplicate (jittered) query vectors driven at
``semantic_threshold`` in {0, 0.05, 0.1, 0.2}, reporting the semantic hit
rate, QPS and recall@10 delta vs the lossless threshold-0 run per point --
the ROADMAP follow-up that finally *measures* what threshold > 0 costs.

Emits ``bench_out/cache.csv``, ``bench_out/cache_thresholds.csv`` and the
``cache`` section of the stable cross-PR summary
``bench_out/BENCH_serve.json``.

CLI: ``python -m benchmarks.bench_cache [--quick] [--smoke]`` (--smoke is the
CI mode: tiny corpus, one sweep point, asserts the acceptance invariants).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.cache import CachingBackend
from repro.core import CacheSpec, LocalBackend, SearchOptions, refimpl
from repro.core import filters as F
from repro.serving import ServeEngine

from . import common

SKEWS = (0.0, 1.0, 1.4, 2.0)  # Zipf exponents: uniform -> heavily skewed
THRESHOLDS = (0.0, 0.05, 0.1, 0.2)  # semantic L2 match radii


def _filter_pool(schema, n_filters: int, rng) -> list:
    """Distinct filters mixing selectivity bands so both routes are hot:
    ~10% equality/range (graph route) and ~1% conjunctions (brute route)."""
    pool = []
    for i in range(n_filters):
        v = int(rng.integers(0, 10))
        lo = float(rng.uniform(0.0, 85.0))
        if i % 3 == 0:
            pool.append(F.Equality("i0", v))
        elif i % 3 == 1:
            pool.append(F.And(F.Equality("i0", v),
                              F.Range("f0", lo, lo + 10.0)))
        else:
            pool.append(F.Range("f0", lo, lo + 10.0))
    return pool


def _zipf_requests(n_pairs: int, n_requests: int, skew: float, rng):
    """Request stream of pair indices: P(rank r) ~ 1/(r+1)^skew."""
    ranks = np.arange(1, n_pairs + 1, dtype=np.float64)
    p = ranks ** -skew if skew > 0 else np.ones(n_pairs)
    p /= p.sum()
    perm = rng.permutation(n_pairs)       # decorrelate rank from pool index
    return perm[rng.choice(n_pairs, size=n_requests, p=p)]


def _drive(backend, requests, opts, max_batch: int):
    eng = ServeEngine(backend, opts, max_batch=max_batch, max_wait_ms=1e6)
    t0 = time.perf_counter()
    for q, flt in requests:
        eng.submit(q, flt)
    out = eng.drain()  # throughput bench: no straggler-deadline waits
    wall = time.perf_counter() - t0
    out.sort(key=lambda r: r.rid)         # rid order == request order
    pct = eng.latency_percentiles()
    return eng, out, len(out) / max(wall, 1e-12), pct.get("p99", 0.0)


def _recall(responses, pair_ids, truth, k: int) -> float:
    per = [refimpl.recall_at_k(np.asarray(r.ids), truth[pid], k)
           for r, pid in zip(responses, pair_ids) if pid in truth]
    return float(np.mean(per)) if per else 0.0


def _threshold_sweep(fi, vecs, attrs, schema, qpool, fpool, opts,
                     n_requests: int, max_batch: int, k: int,
                     gt_cap: int, rng) -> tuple[list[dict], float]:
    """Recall-vs-threshold sweep for the semantic layer; returns the per-
    threshold rows plus the uncached recall baseline the deltas are
    measured against.

    The stream repeats (query, filter) pairs Zipf-style, but half the
    repeats carry a *jittered* copy of the pool query (sigma tuned so the
    L2 distance between two jitters of the same base lands around 0.1 for
    any dim): threshold 0 serves only exact repeats (lossless by
    construction), larger thresholds also serve the near-duplicates and pay
    whatever recall that costs -- which is exactly what each sweep point
    measures, as recall@10 against per-request exact ground truth.
    """
    dim = vecs.shape[1]
    sigma = 0.07 / np.sqrt(2.0 * dim)  # pairwise jitter distance ~ 0.07
    pairs = [(qi, fj) for qi in range(len(qpool)) for fj in range(len(fpool))]
    pair_ids = _zipf_requests(len(pairs), n_requests, 1.2,
                              np.random.default_rng(common.SEED + 23))
    jitter = rng.integers(0, 2, size=n_requests).astype(bool)
    reqs = []
    for r, pid in enumerate(pair_ids):
        qi, fj = pairs[pid]
        q = np.asarray(qpool[qi], np.float32)
        if jitter[r]:
            q = (q + rng.normal(scale=sigma, size=dim)).astype(np.float32)
        reqs.append((q, fpool[fj]))

    masks = {fj: np.asarray(F.eval_program(F.compile_filter(f, schema),
                                           attrs.ints, attrs.floats))
             for fj, f in enumerate(fpool)}
    gt_rows = range(min(gt_cap, n_requests))
    truth = {r: refimpl.bruteforce_filtered(
        vecs, masks[pairs[pair_ids[r]][1]], reqs[r][0], k)[0]
        for r in gt_rows}

    def _recall(responses) -> float:
        return float(np.mean([refimpl.recall_at_k(np.asarray(
            responses[r].ids), truth[r], k) for r in gt_rows]))

    base = LocalBackend(fi)
    _drive(base, reqs, opts, max_batch)                   # warm/compile
    _, out_u, _, _ = _drive(base, reqs, opts, max_batch)
    uncached_recall = _recall(out_u)  # the true lossless baseline

    rows = []
    for t in THRESHOLDS:
        spec = CacheSpec(semantic_threshold=t)
        _drive(CachingBackend(base, spec), reqs, opts, max_batch)  # warm
        eng, out, qps, p99 = _drive(CachingBackend(base, spec), reqs, opts,
                                    max_batch)
        st = eng.stats["cache"]
        rows.append({
            "threshold": t,
            "hit_rate_semantic": st["semantic"]["hit_rate"],
            "qps": qps, "p99_ms": p99,
            "recall": _recall(out),
            "recall_delta": _recall(out) - uncached_recall,
        })
    return rows, uncached_recall


def run(quick: bool = False, smoke: bool = False) -> str:
    n = 2000 if smoke else (6000 if quick else common.N)
    dim = 16 if smoke else common.DIM
    n_requests = 128 if smoke else (512 if quick else 1024)
    n_queries = 32 if smoke else 64
    n_filters = 8 if smoke else 32
    max_batch = 64
    gt_cap = 32 if smoke else 128         # ground-truth pairs per sweep point
    skews = (1.4,) if smoke else SKEWS
    k = 10

    vecs, attrs, schema, queries = common.get_dataset(n, dim)
    fi = common.get_index(n, dim)
    rng = np.random.default_rng(common.SEED + 5)
    qpool = np.asarray(queries)[:n_queries]
    if len(qpool) < n_queries:
        qpool = rng.normal(size=(n_queries, dim)).astype(np.float32)
    fpool = _filter_pool(schema, n_filters, rng)
    pairs = [(qi, fj) for qi in range(len(qpool)) for fj in range(n_filters)]
    opts = SearchOptions(k=k, ef=64)

    # exact ground truth for the first gt_cap pool pairs (Zipf ranks are
    # decorrelated from pool order, so this is an unbiased sample)
    masks = {fj: np.asarray(F.eval_program(F.compile_filter(f, schema),
                                           attrs.ints, attrs.floats))
             for fj, f in enumerate(fpool)}
    truth = {}
    for pid in range(min(gt_cap, len(pairs))):
        qi, fj = pairs[pid]
        ids, _ = refimpl.bruteforce_filtered(vecs, masks[fj], qpool[qi], k)
        truth[pid] = ids

    csv = common.Csv("cache.csv",
                     ["skew", "hit_rate_semantic", "hit_rate_selectivity",
                      "hit_rate_candidates", "qps_uncached", "qps_cached",
                      "speedup", "p99_uncached_ms", "p99_cached_ms",
                      "recall_uncached", "recall_cached", "mismatch_frac"])
    points = []
    base = LocalBackend(fi)

    for skew in skews:
        pair_ids = _zipf_requests(len(pairs), n_requests, skew,
                                  np.random.default_rng(common.SEED + 11))
        reqs = [(qpool[pairs[p][0]], fpool[pairs[p][1]]) for p in pair_ids]

        # warm passes compile every (route, sub-batch) executable each
        # engine will hit: the cached warm-up runs the SAME stream from the
        # same cold cache state, so its hit/miss pattern -- and therefore
        # its miss-sub-batch shapes -- replay identically in the measured
        # run (caches are deterministic); a fresh wrapper then measures
        # with clean counters and a cold cache
        _drive(base, reqs, opts, max_batch)
        _drive(CachingBackend(base, CacheSpec()), reqs, opts, max_batch)

        _, out_u, qps_u, p99_u = _drive(base, reqs, opts, max_batch)
        cb = CachingBackend(base, CacheSpec())
        eng_c, out_c, qps_c, p99_c = _drive(cb, reqs, opts, max_batch)

        st = eng_c.stats["cache"]
        mismatch = float(np.mean([not np.array_equal(a.ids, b.ids)
                                  for a, b in zip(out_u, out_c)]))
        rec_u = _recall(out_u, pair_ids, truth, k)
        rec_c = _recall(out_c, pair_ids, truth, k)
        row = {
            "skew": skew,
            "hit_rate_semantic": st["semantic"]["hit_rate"],
            "hit_rate_selectivity": st["selectivity"]["hit_rate"],
            "hit_rate_candidates": st["candidates"]["hit_rate"],
            "qps_uncached": qps_u, "qps_cached": qps_c,
            "speedup": qps_c / max(qps_u, 1e-12),
            "p99_uncached_ms": p99_u, "p99_cached_ms": p99_c,
            "recall_uncached": rec_u, "recall_cached": rec_c,
            "mismatch_frac": mismatch,
        }
        points.append(row)
        csv.add(*[row[h] for h in csv.rows[0]])
    csv.write()

    # -- semantic threshold sweep (recall-vs-QPS trade per threshold) --------
    trows, t_base_recall = _threshold_sweep(fi, vecs, attrs, schema, qpool,
                                            fpool, opts, n_requests,
                                            max_batch, k, gt_cap, rng)
    tcsv = common.Csv("cache_thresholds.csv",
                      ["threshold", "hit_rate_semantic", "qps", "p99_ms",
                       "recall", "recall_delta"])
    for row in trows:
        tcsv.add(*[row[h] for h in tcsv.rows[0]])
    tcsv.write()

    summary = {
        "config": {"n": n, "dim": dim, "requests": n_requests,
                   "query_pool": len(qpool), "filter_pool": n_filters,
                   "k": k, "max_batch": max_batch},
        "points": points,
        "headline": max(points, key=lambda r: r["speedup"]),
        "threshold_sweep": trows,
        "threshold_uncached_recall": t_base_recall,
    }
    path = common.update_bench_json("cache", summary)

    head = summary["headline"]
    if smoke:
        assert head["mismatch_frac"] == 0.0, \
            f"cached results diverged: {head['mismatch_frac']}"
        assert head["recall_cached"] >= head["recall_uncached"] - 1e-9
        assert trows[0]["threshold"] == 0.0
        # threshold 0 serves exact repeats only -> recall must equal the
        # UNCACHED baseline on the same stream (lossless), not merely
        # itself: deltas are measured against that independent run
        assert abs(trows[0]["recall"] - t_base_recall) < 1e-9, \
            (trows[0]["recall"], t_base_recall)
        # larger radii must not serve fewer semantic hits on this stream
        assert trows[-1]["hit_rate_semantic"] >= \
            trows[0]["hit_rate_semantic"] - 1e-9
    tmax = trows[-1]
    return (f"speedup={head['speedup']:.2f}x@skew={head['skew']} "
            f"sem_hit={head['hit_rate_semantic']:.2f} | thr{tmax['threshold']}"
            f": hit={tmax['hit_rate_semantic']:.2f} "
            f"dRecall={tmax['recall_delta']:+.3f} {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny corpus, one point, assert invariants")
    args = ap.parse_args()
    print(run(quick=args.quick, smoke=args.smoke))


if __name__ == "__main__":
    main()
