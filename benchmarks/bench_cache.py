"""Zipf-skewed repeat-filter serving benchmark for the cache subsystem.

Production hybrid-query traffic is heavily skewed: the same filters (tenant
ids, facets, date windows) and repeat query vectors recur constantly.  This
benchmark draws requests from a Zipf distribution over a pool of distinct
(query, filter) pairs, then drives the same request sequence through an
uncached ``LocalBackend`` engine and a ``CachingBackend`` wrap, sweeping the
Zipf exponent (skew -> hit rate).

Reported per sweep point: QPS (both engines), speedup, p99 latency, per-layer
hit rates, Recall@10 of both engines against exact ground truth, and the
fraction of requests where cached ids differ from uncached (must be 0: every
layer is exact at the default CacheSpec).  Emits ``bench_out/cache.csv`` plus
the stable cross-PR serving summary ``bench_out/BENCH_serve.json``.

CLI: ``python -m benchmarks.bench_cache [--quick] [--smoke]`` (--smoke is the
CI mode: tiny corpus, one sweep point, asserts the acceptance invariants).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.cache import CachingBackend
from repro.core import CacheSpec, LocalBackend, SearchOptions, refimpl
from repro.core import filters as F
from repro.serving import ServeEngine

from . import common

SKEWS = (0.0, 1.0, 1.4, 2.0)  # Zipf exponents: uniform -> heavily skewed


def _filter_pool(schema, n_filters: int, rng) -> list:
    """Distinct filters mixing selectivity bands so both routes are hot:
    ~10% equality/range (graph route) and ~1% conjunctions (brute route)."""
    pool = []
    for i in range(n_filters):
        v = int(rng.integers(0, 10))
        lo = float(rng.uniform(0.0, 85.0))
        if i % 3 == 0:
            pool.append(F.Equality("i0", v))
        elif i % 3 == 1:
            pool.append(F.And(F.Equality("i0", v),
                              F.Range("f0", lo, lo + 10.0)))
        else:
            pool.append(F.Range("f0", lo, lo + 10.0))
    return pool


def _zipf_requests(n_pairs: int, n_requests: int, skew: float, rng):
    """Request stream of pair indices: P(rank r) ~ 1/(r+1)^skew."""
    ranks = np.arange(1, n_pairs + 1, dtype=np.float64)
    p = ranks ** -skew if skew > 0 else np.ones(n_pairs)
    p /= p.sum()
    perm = rng.permutation(n_pairs)       # decorrelate rank from pool index
    return perm[rng.choice(n_pairs, size=n_requests, p=p)]


def _drive(backend, requests, opts, max_batch: int):
    eng = ServeEngine(backend, opts, max_batch=max_batch, max_wait_ms=1e6)
    t0 = time.perf_counter()
    for q, flt in requests:
        eng.submit(q, flt)
    out = eng.run()
    wall = time.perf_counter() - t0
    out.sort(key=lambda r: r.rid)         # rid order == request order
    pct = eng.latency_percentiles()
    return eng, out, len(out) / max(wall, 1e-12), pct.get("p99", 0.0)


def _recall(responses, pair_ids, truth, k: int) -> float:
    per = [refimpl.recall_at_k(np.asarray(r.ids), truth[pid], k)
           for r, pid in zip(responses, pair_ids) if pid in truth]
    return float(np.mean(per)) if per else 0.0


def run(quick: bool = False, smoke: bool = False) -> str:
    n = 2000 if smoke else (6000 if quick else common.N)
    dim = 16 if smoke else common.DIM
    n_requests = 128 if smoke else (512 if quick else 1024)
    n_queries = 32 if smoke else 64
    n_filters = 8 if smoke else 32
    max_batch = 64
    gt_cap = 32 if smoke else 128         # ground-truth pairs per sweep point
    skews = (1.4,) if smoke else SKEWS
    k = 10

    vecs, attrs, schema, queries = common.get_dataset(n, dim)
    fi = common.get_index(n, dim)
    rng = np.random.default_rng(common.SEED + 5)
    qpool = np.asarray(queries)[:n_queries]
    if len(qpool) < n_queries:
        qpool = rng.normal(size=(n_queries, dim)).astype(np.float32)
    fpool = _filter_pool(schema, n_filters, rng)
    pairs = [(qi, fj) for qi in range(len(qpool)) for fj in range(n_filters)]
    opts = SearchOptions(k=k, ef=64)

    # exact ground truth for the first gt_cap pool pairs (Zipf ranks are
    # decorrelated from pool order, so this is an unbiased sample)
    masks = {fj: np.asarray(F.eval_program(F.compile_filter(f, schema),
                                           attrs.ints, attrs.floats))
             for fj, f in enumerate(fpool)}
    truth = {}
    for pid in range(min(gt_cap, len(pairs))):
        qi, fj = pairs[pid]
        ids, _ = refimpl.bruteforce_filtered(vecs, masks[fj], qpool[qi], k)
        truth[pid] = ids

    csv = common.Csv("cache.csv",
                     ["skew", "hit_rate_semantic", "hit_rate_selectivity",
                      "hit_rate_candidates", "qps_uncached", "qps_cached",
                      "speedup", "p99_uncached_ms", "p99_cached_ms",
                      "recall_uncached", "recall_cached", "mismatch_frac"])
    points = []
    base = LocalBackend(fi)

    for skew in skews:
        pair_ids = _zipf_requests(len(pairs), n_requests, skew,
                                  np.random.default_rng(common.SEED + 11))
        reqs = [(qpool[pairs[p][0]], fpool[pairs[p][1]]) for p in pair_ids]

        # warm passes compile every (route, sub-batch) executable each
        # engine will hit: the cached warm-up runs the SAME stream from the
        # same cold cache state, so its hit/miss pattern -- and therefore
        # its miss-sub-batch shapes -- replay identically in the measured
        # run (caches are deterministic); a fresh wrapper then measures
        # with clean counters and a cold cache
        _drive(base, reqs, opts, max_batch)
        _drive(CachingBackend(base, CacheSpec()), reqs, opts, max_batch)

        _, out_u, qps_u, p99_u = _drive(base, reqs, opts, max_batch)
        cb = CachingBackend(base, CacheSpec())
        eng_c, out_c, qps_c, p99_c = _drive(cb, reqs, opts, max_batch)

        st = eng_c.stats["cache"]
        mismatch = float(np.mean([not np.array_equal(a.ids, b.ids)
                                  for a, b in zip(out_u, out_c)]))
        rec_u = _recall(out_u, pair_ids, truth, k)
        rec_c = _recall(out_c, pair_ids, truth, k)
        row = {
            "skew": skew,
            "hit_rate_semantic": st["semantic"]["hit_rate"],
            "hit_rate_selectivity": st["selectivity"]["hit_rate"],
            "hit_rate_candidates": st["candidates"]["hit_rate"],
            "qps_uncached": qps_u, "qps_cached": qps_c,
            "speedup": qps_c / max(qps_u, 1e-12),
            "p99_uncached_ms": p99_u, "p99_cached_ms": p99_c,
            "recall_uncached": rec_u, "recall_cached": rec_c,
            "mismatch_frac": mismatch,
        }
        points.append(row)
        csv.add(*[row[h] for h in csv.rows[0]])
    csv.write()

    summary = {
        "bench": "serve_cache",
        "config": {"n": n, "dim": dim, "requests": n_requests,
                   "query_pool": len(qpool), "filter_pool": n_filters,
                   "k": k, "max_batch": max_batch},
        "points": points,
        "headline": max(points, key=lambda r: r["speedup"]),
    }
    os.makedirs("bench_out", exist_ok=True)
    path = os.path.join("bench_out", "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True)

    head = summary["headline"]
    if smoke:
        assert head["mismatch_frac"] == 0.0, \
            f"cached results diverged: {head['mismatch_frac']}"
        assert head["recall_cached"] >= head["recall_uncached"] - 1e-9
    return (f"speedup={head['speedup']:.2f}x@skew={head['skew']} "
            f"sem_hit={head['hit_rate_semantic']:.2f} {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny corpus, one point, assert invariants")
    args = ap.parse_args()
    print(run(quick=args.quick, smoke=args.smoke))


if __name__ == "__main__":
    main()
