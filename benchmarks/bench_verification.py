"""Paper section 6.5 verification tests + Fig. 6 recall levels + Tabs. 4/5/6.

 * Fig. 6   -- QPS at different recall levels (k in {1, 10, 50}).
 * Tab. 4/5 -- construction time + storage vs a plain-HNSW (RSF) build.
 * Fig. 12  -- TD proportion on search paths vs QPS correlation.
 * Fig. 13  -- unfiltered (p=100%) search path length: FAVOR == vanilla HNSW.
 * Tab. 6   -- linear model: R^2 of d_m ~ m over sampled anchors (> 0.8).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import HnswParams, TrueFilter, build_hnsw, compile_filter
from repro.core import filters as F
from . import common as C


def run_recall_levels(quick: bool = False):
    fi = C.get_index()
    vecs, attrs, schema, queries = C.get_dataset()
    flt = F.Equality("b0", True)
    prog = compile_filter(flt, schema)
    mask = F.eval_program(prog, attrs.ints, attrs.floats)
    csv = C.Csv("recall_levels.csv", ["k", "ef", "qps", "recall_at_k"])
    for k in ([1, 10, 50] if not quick else [10]):
        truth = C.ground_truth(vecs, mask, queries, k)
        for ef in [max(16, 2 * k), max(48, 4 * k), max(96, 8 * k)]:
            res, qps = C.timed_search(fi, queries, flt, k=k, ef=ef)
            csv.add(k, ef, qps, C.mean_recall(res.ids, truth, k))
    csv.write()
    return csv.path


def run_construction(quick: bool = False):
    ns = [5000, C.N] if not quick else [5000]
    csv = C.Csv("construction.csv",
                ["n", "method", "build_s", "index_bytes", "delta_d"])
    for n in ns:
        vecs, attrs, schema, _ = C.get_dataset(n=n)
        t0 = time.perf_counter()
        idx = build_hnsw(vecs, HnswParams(M=12, efc=60, seed=1))
        t_favor = time.perf_counter() - t0
        # RSF/vanilla HNSW == same build minus the Delta_d recording; measure
        # by rebuilding with alpha tracking disabled (alpha=efc -> no span)
        t0 = time.perf_counter()
        idx2 = build_hnsw(vecs, HnswParams(M=12, efc=60, seed=1, alpha=60))
        t_plain = time.perf_counter() - t0
        csv.add(n, "favor", t_favor, idx.storage_bytes() + attrs.ints.nbytes +
                attrs.floats.nbytes, idx.delta_d)
        csv.add(n, "hnsw_rsf", t_plain, idx2.storage_bytes(), 0.0)
    csv.write()
    return csv.path


def run_search_path(quick: bool = False):
    fi = C.get_index()
    vecs, attrs, schema, queries = C.get_dataset()
    csv = C.Csv("search_path.csv",
                ["scenario", "method", "qps", "path_td_frac", "mean_hops"])
    # Fig. 12: TD proportion vs QPS across selectivities
    for p_name, flt in [("p50", F.Equality("b0", True)),
                        ("p10", F.Equality("i0", 3)),
                        ("p30", F.Inclusion("i0", [1, 4, 7]))]:
        res, qps = C.timed_search(fi, queries, flt, k=10, ef=96, force="graph")
        frac = float(res.path_td.sum() / max(1, res.hops.sum()))
        csv.add(p_name, "favor", qps, frac, float(res.hops.mean()))
    # Fig. 13: unfiltered p=100% -- FAVOR path length ~= vanilla HNSW
    res_t, qps_t = C.timed_search(fi, queries, TrueFilter(), k=10, ef=96,
                                  force="graph")
    csv.add("p100", "favor", qps_t, 1.0, float(res_t.hops.mean()))
    res_0, qps_0 = C.timed_search(fi, queries, TrueFilter(), k=10, ef=96,
                                  force="graph", pbar_min=0.0)
    csv.add("p100", "hnsw_equiv", qps_0, 1.0, float(res_0.hops.mean()))
    csv.write()
    ratio = res_t.hops.mean() / max(1.0, res_0.hops.mean())
    print(f"# p=100%: FAVOR path length / vanilla = {ratio:.3f} (paper: ~1.0)")
    return csv.path


def run_linear_model(quick: bool = False):
    vecs, attrs, schema, _ = C.get_dataset()
    rng = np.random.default_rng(0)
    anchors = rng.choice(len(vecs), 64 if not quick else 16, replace=False)
    m_max = 1000
    r2s = []
    for a in anchors:
        d = np.linalg.norm(vecs - vecs[a], axis=1)
        dm = np.sort(d)[1:m_max + 1]
        m = np.arange(1, len(dm) + 1)
        coef = np.polyfit(m, dm, 1)
        pred = np.polyval(coef, m)
        ss_res = np.sum((dm - pred) ** 2)
        ss_tot = np.sum((dm - dm.mean()) ** 2)
        r2s.append(1.0 - ss_res / ss_tot)
    csv = C.Csv("linear_model.csv", ["mean_r2", "std_r2", "n_anchors"])
    csv.add(float(np.mean(r2s)), float(np.std(r2s)), len(anchors))
    csv.write()
    print(f"# paper Tab. 6 claim: R^2 > 0.8 -- measured {np.mean(r2s):.3f}")
    return csv.path


if __name__ == "__main__":
    run_recall_levels()
    run_construction()
    run_search_path()
    run_linear_model()
