"""Paper Figs. 10 + 11 ablations.

Fig. 10 (exclusion distance): D=0 vs D(Eq. 14) vs D_max -- QPS at matched ef
plus recall and search-path TD fraction.  Claim mirrored: Eq. 14 beats both.

Fig. 11 (termination threshold): pbar in {0, 0.25, 0.5, 0.75} -- recall/QPS
tradeoff; claim mirrored: pbar = 0.5 keeps recall high without the slowdown
of larger guards.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import SearchConfig, compile_filter, favor_graph_search, stack_programs
from repro.core import exclusion
from repro.core import filters as F
from . import common as C


def _forced_D_search(fi, queries, prog, D_vec, k, ef, pbar=0.5, repeats=3):
    import time
    progs = {kk: jnp.asarray(v) for kk, v in stack_programs(
        [prog] * len(queries)).items()}
    cfg = SearchConfig(k=k, ef=ef, pbar_min=pbar)
    qj = jnp.asarray(queries)
    out = favor_graph_search(fi.g, qj, progs, jnp.asarray(D_vec), cfg)
    best = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = favor_graph_search(fi.g, qj, progs, jnp.asarray(D_vec), cfg)
        out["ids"].block_until_ready()
        best = max(best, len(queries) / (time.perf_counter() - t0))
    return out, best


def run_exclusion(quick: bool = False):
    fi = C.get_index()
    vecs, attrs, schema, queries = C.get_dataset()
    flt = F.Equality("i0", 4)  # Equality_int, p ~= 10% (paper's Fig. 10 setup)
    prog = compile_filter(flt, schema)
    mask = F.eval_program(prog, attrs.ints, attrs.floats)
    p = float(mask.mean())
    k, ef = 10, 96
    truth = C.ground_truth(vecs, mask, queries, k)

    d_eq14 = float(exclusion.exclusion_distance(p, ef, fi.delta_d))
    d_max = float(np.mean([exclusion.d_max(q, vecs, mask) for q in queries[:16]]))
    csv = C.Csv("ablation_exclusion.csv",
                ["strategy", "D", "qps", "recall_at_10", "path_td_frac",
                 "mean_hops"])
    for name, d in [("D0", 0.0), ("D_eq14", d_eq14), ("D_max", d_max)]:
        out, qps = _forced_D_search(fi, queries, prog,
                                    np.full(len(queries), d, np.float32), k, ef)
        rec = C.mean_recall(np.asarray(out["ids"]), truth, k)
        hops = np.asarray(out["hops"])
        frac = float(np.asarray(out["path_td"]).sum() / max(1, hops.sum()))
        csv.add(name, d, qps, rec, frac, float(hops.mean()))
    csv.write()
    return csv.path


def run_termination(quick: bool = False):
    fi = C.get_index()
    vecs, attrs, schema, queries = C.get_dataset()
    flt = F.Equality("b0", True)  # Equality_bool (paper's Fig. 11 setup)
    prog = compile_filter(flt, schema)
    mask = F.eval_program(prog, attrs.ints, attrs.floats)
    p = float(mask.mean())
    k, ef = 10, 48
    truth = C.ground_truth(vecs, mask, queries, k)
    d = float(exclusion.exclusion_distance(p, ef, fi.delta_d))
    csv = C.Csv("ablation_termination.csv",
                ["pbar_min", "qps", "recall_at_10", "mean_hops"])
    for pbar in [0.0, 0.25, 0.5, 0.75]:
        out, qps = _forced_D_search(fi, queries, prog,
                                    np.full(len(queries), d, np.float32),
                                    k, ef, pbar=pbar)
        rec = C.mean_recall(np.asarray(out["ids"]), truth, k)
        csv.add(pbar, qps, rec, float(np.asarray(out["hops"]).mean()))
    csv.write()
    return csv.path


if __name__ == "__main__":
    run_exclusion()
    run_termination()
