"""Benchmark harness entry point: one module per paper table/figure.

``python -m benchmarks.run [--quick] [--only name]``

Emits per-benchmark CSVs to bench_out/ and a ``name,us_per_call,derived``
summary to stdout (derived = the benchmark's headline metric/CSV path).
"""
from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (bench_ablation, bench_cache, bench_qps_recall, bench_quant,
                   bench_selectivity, bench_serve_backends,
                   bench_verification)

    benches = [
        ("qps_recall_figs4_5_8_9", bench_qps_recall.run),
        # graph-route scorer layer: f32 vs PQ-ADC traversal (core.scoring)
        ("graph_scorers", bench_qps_recall.run_scorers),
        ("quant_pq_adc", bench_quant.run),
        ("serve_backends", bench_serve_backends.run),
        # also emits the stable cross-PR serving summary BENCH_serve.json
        ("serve_cache_zipf", bench_cache.run),
        ("selectivity_fig7", bench_selectivity.run),
        ("exclusion_ablation_fig10", bench_ablation.run_exclusion),
        ("termination_fig11", bench_ablation.run_termination),
        ("recall_levels_fig6", bench_verification.run_recall_levels),
        ("construction_tabs4_5", bench_verification.run_construction),
        ("search_path_figs12_13", bench_verification.run_search_path),
        ("linear_model_tab6", bench_verification.run_linear_model),
    ]
    print("name,us_per_call,derived")
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            out = fn(quick=args.quick)
            dt = (time.perf_counter() - t0) * 1e6
            print(f"{name},{dt:.0f},{out}")
        except Exception as e:
            traceback.print_exc()
            print(f"{name},-1,FAILED:{type(e).__name__}")


if __name__ == "__main__":
    main()
