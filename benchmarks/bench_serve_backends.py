"""ServeEngine throughput/latency over the pluggable execution backends.

One unmodified ServeEngine drives four configurations -- LocalBackend and
ShardedBackend, each in float32 and PQ-compressed brute-scan mode -- over the
same mixed-selectivity workload (reduced favor-anns config).  Reports QPS,
p50/p99 latency and the bytes-per-vector accounting that verifies the brute
route actually streams codes (not float32) when a QuantSpec is set:
scan_bytes = N * bytes_per_vector is the per-query bandwidth bound.

The model axis spans every visible device (1 on the CI CPU; S-way sharded
under ``XLA_FLAGS=--xla_force_host_platform_device_count=S``).

    PYTHONPATH=src python -m benchmarks.run --only serve_backends [--quick]
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.favor_anns import FavorServeConfig
from repro.core import FavorIndex, HnswParams, LocalBackend, ShardedBackend
from repro.core import filters as F
from repro.core.distributed import largest_divisor
from repro.data import synthetic
from repro.serving import ServeEngine

from .common import DIM, N, NQ, SEED, Csv


def _workload(schema, dim, n_requests, seed=0):
    rng = np.random.default_rng(seed)
    flts = list(F.paper_filters(schema).values()) + [
        F.And(F.Equality("i0", int(v)), F.Range("f0", lo, lo + 8.0))
        for v, lo in zip(rng.integers(0, 10, 4), rng.uniform(0, 90, 4))
    ]
    qs = synthetic.make_queries(n_requests, dim, dataset_seed=SEED,
                                seed=seed + 101)
    return [(qs[i], flts[int(rng.integers(0, len(flts)))])
            for i in range(n_requests)]


def _drive(backend, opts, requests, max_batch=128):
    eng = ServeEngine(backend, opts, max_batch=max_batch)
    for q, flt in requests:
        eng.submit(q, flt)
    eng.run()          # warm-up: compiles every (route, bucket) executable
    eng.reset_stats()
    for q, flt in requests:
        eng.submit(q, flt)
    t0 = time.perf_counter()
    out = eng.run()
    wall = time.perf_counter() - t0
    pct = eng.latency_percentiles()
    return (len(out) / max(wall, 1e-12), pct.get("p50", 0.0),
            pct.get("p99", 0.0), eng.stats)


def run(quick: bool = False) -> str:
    n, dim = (4096, DIM) if quick else (max(4096, N // 2), DIM)
    n_requests = 64 if quick else min(256, NQ * 2)
    vecs, attrs, schema = synthetic.make_paper_dataset(n, dim, seed=SEED)
    requests = _workload(schema, dim, n_requests, seed=3)

    qcfg = FavorServeConfig(pq_m=max(4, dim // 4), rerank=8)
    spec = qcfg.build_spec(hnsw=HnswParams(M=12, efc=60, seed=SEED))
    opts_f32 = qcfg.search_options(k=10, ef=64, use_pq=False)
    opts_pq = qcfg.search_options(k=10, ef=64, use_pq=True)

    local = LocalBackend(FavorIndex.build(vecs, attrs, spec=spec))
    n_model = largest_divisor(n, len(jax.devices()))
    mesh = jax.make_mesh((1, n_model), ("data", "model"))
    shard = ShardedBackend.build(vecs, attrs, mesh, spec,
                                 codebook=local.index.codebook, seed=SEED)

    bpv_f32 = local.index.bytes_per_vector()
    bpv_pq = local.index.bytes_per_vector(quantized=True)
    grid = [("local", local, opts_f32, bpv_f32),
            ("local", local, opts_pq, bpv_pq),
            ("sharded", shard, opts_f32, bpv_f32),
            ("sharded", shard, opts_pq, bpv_pq)]

    csv = Csv("serve_backends.csv",
              ["backend", "shards", "use_pq", "qps", "p50_ms", "p99_ms",
               "graph", "brute", "bytes_per_vector", "scan_bytes"])
    summary = []
    for name, backend, opts, bpv in grid:
        qps, p50, p99, stats = _drive(backend, opts, requests)
        shards = n_model if name == "sharded" else 1
        csv.add(name, shards, int(opts.use_pq), qps, p50, p99,
                stats["graph"], stats["brute"], float(bpv), float(bpv * n))
        summary.append(f"{name}{'_pq' if opts.use_pq else '_f32'}={qps:.0f}")
    path = csv.write()
    return (f"shards={n_model} compression={bpv_f32 / bpv_pq:.1f}x "
            + " ".join(summary) + f" csv={path}")


if __name__ == "__main__":
    print(run(quick=True))
