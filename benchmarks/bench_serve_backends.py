"""ServeEngine throughput/latency over the pluggable execution backends.

One unmodified ServeEngine drives four configurations -- LocalBackend and
ShardedBackend, each in float32 and PQ-compressed brute-scan mode -- over the
same mixed-selectivity workload (reduced favor-anns config).  Reports QPS,
p50/p99 latency and the bytes-per-vector accounting that verifies the brute
route actually streams codes (not float32) when a QuantSpec is set:
scan_bytes = N * bytes_per_vector is the per-query bandwidth bound.

It then runs the **shape-stable serving sweep**: the same mixed-selectivity
stream submitted in random-size bursts (so the selector's gi/bi sub-batches
take data-dependent sizes every batch) against a cold unpadded engine vs a
``SearchOptions(batch=BatchSpec(...))`` engine that ``warmup()``s its bucket
ladder first.  Reported per arm: p99 (cold traffic -- the unpadded arm pays
its compiles inline, which is exactly the production spike), compiled-shape
counts from the engine registry, pad overhead, and a result-parity check.
The sweep lands in the ``batching`` section of bench_out/BENCH_serve.json.

Finally the **live-index churn scenario**: interleaved upsert/delete/search
traffic holding the unmerged delta at 0% / 1% / 10% of the base row count,
so the steady-state mutation overhead (delta scan + top-k compose +
tombstone masking) is tracked across PRs, plus a device-parallel bulk-build
vs numpy-loop build comparison (wall time and recall@10, asserted within
1pt in smoke mode).  Lands in the ``mutation`` section of BENCH_serve.json.

The **concurrency scenario**: two-tenant Poisson traffic through the
pipelined front-end (``parallel_steps`` 1 vs 2, bit-identity across arms,
QPS ratio -- the >=1.25x bar applies on multi-core hosts; a 1-core
container timeshares host and device work, so the arms tie there) plus
the live-index merge arms (steady-state vs background-merge vs
inline-merge per-step p99, run as two identical lifecycle cycles so the
measured cycle is compile-free).  Lands in the ``concurrency`` section.

The **observability scenario** closes the file: the same mixed-selectivity
stream with the obs layer off vs on at default sampling (best-of-repeats
QPS, row-identical parity) plus a max-rate probe arm populating the
estimator-accuracy and route-confusion metrics.  In smoke mode the <5%
overhead bar is asserted; the numbers land in the ``obs`` section of
BENCH_serve.json.

The model axis spans every visible device (1 on the CI CPU; S-way sharded
under ``XLA_FLAGS=--xla_force_host_platform_device_count=S``).

    PYTHONPATH=src python -m benchmarks.run --only serve_backends [--quick]
    PYTHONPATH=src python -m benchmarks.bench_serve_backends --smoke   # CI:
        asserts compiled shapes <= bucket ladder, padded/unpadded parity,
        and use_pallas working under ShardedBackend
"""
from __future__ import annotations

import argparse
import asyncio
import os
import time

import jax
import numpy as np

from repro.configs.favor_anns import FavorServeConfig
from repro.core import (BatchSpec, FavorIndex, HnswParams, LocalBackend,
                        ObsSpec, ShardedBackend, router)
from repro.core import filters as F
from repro.core.distributed import largest_divisor
from repro.data import synthetic
from repro.index.bulk import build_hnsw_bulk
from repro.serving import (FrontEnd, FrontEndSpec, MergeController,
                           Overloaded, ServeEngine, TenantSpec)

from .common import DIM, N, NQ, SEED, Csv, update_bench_json


def _workload(schema, dim, n_requests, seed=0):
    rng = np.random.default_rng(seed)
    flts = list(F.paper_filters(schema).values()) + [
        F.And(F.Equality("i0", int(v)), F.Range("f0", lo, lo + 8.0))
        for v, lo in zip(rng.integers(0, 10, 4), rng.uniform(0, 90, 4))
    ]
    qs = synthetic.make_queries(n_requests, dim, dataset_seed=SEED,
                                seed=seed + 101)
    return [(qs[i], flts[int(rng.integers(0, len(flts)))])
            for i in range(n_requests)]


def _drive(backend, opts, requests, max_batch=128):
    eng = ServeEngine(backend, opts, max_batch=max_batch)
    for q, flt in requests:
        eng.submit(q, flt)
    eng.run()          # warm-up: compiles every (route, bucket) executable
    eng.reset_stats()
    for q, flt in requests:
        eng.submit(q, flt)
    t0 = time.perf_counter()
    out = eng.run()
    wall = time.perf_counter() - t0
    pct = eng.latency_percentiles()
    return (len(out) / max(wall, 1e-12), pct.get("p50", 0.0),
            pct.get("p99", 0.0), eng.stats)


def _burst_drive(backend, opts, requests, *, max_batch: int,
                 burst_seed: int = 123):
    """Drive ``requests`` in random-size bursts so every batch has a fresh
    data-dependent (graph, brute) split -- the shape-churn workload.  Cold
    by construction: the engine is built here, so any compile the stream
    triggers lands inside the measured latencies (a bucketed engine
    pre-warms its ladder; an unpadded one cannot -- its shape set is
    unbounded).  The padded arm still pays one-time eager-op glue compiles
    (sub-batch gathers/concats at raw sizes) in its first batches; the
    *executable* set -- the expensive traces -- is bounded by the ladder,
    which is what the registry counts and the smoke guard asserts."""
    eng = ServeEngine(backend, opts, max_batch=max_batch)
    if opts.batch is not None:
        eng.warmup()
        eng.reset_stats()
    rng = np.random.default_rng(burst_seed)
    out = []
    i = 0
    t0 = time.perf_counter()
    while i < len(requests):
        burst = int(rng.integers(1, max_batch + 1))
        for q, flt in requests[i:i + burst]:
            eng.submit(q, flt)
        out.extend(eng.step(force=True))
        i += burst
    wall = time.perf_counter() - t0
    out.sort(key=lambda r: r.rid)
    pct = eng.latency_percentiles()
    return eng, out, {
        "qps": len(out) / max(wall, 1e-12),
        "p50_ms": pct.get("p50", 0.0), "p99_ms": pct.get("p99", 0.0),
        "compiled_shapes": eng.stats["batching"]["compiled_shapes"],
        "sizes": eng.stats["batching"]["sizes"],
        "pad_overhead": eng.stats["batching"]["pad_overhead"],
    }


def _p99_sweep(grid, requests, spec: BatchSpec, max_batch: int):
    """(name, backend, opts) grid -> per-backend padded/unpadded points
    plus a row-level parity check between the two arms."""
    points = []
    for name, backend, opts in grid:
        _, out_u, m_u = _burst_drive(backend, opts, requests,
                                     max_batch=max_batch)
        _, out_p, m_p = _burst_drive(backend, opts.with_(batch=spec),
                                     requests, max_batch=max_batch)
        mismatch = float(np.mean([not np.array_equal(a.ids, b.ids)
                                  for a, b in zip(out_u, out_p)]))
        points.append({
            "backend": name, "unpadded": m_u, "padded": m_p,
            "mismatch_frac": mismatch,
            "p99_ratio": m_p["p99_ms"] / max(m_u["p99_ms"], 1e-12),
        })
    return points


def _graph_recall(backend, queries, want_ids, opts, k=10) -> float:
    r = router.execute(backend, queries, F.TrueFilter(),
                       opts.with_(force="graph"))
    return float(np.mean([len(set(r.ids[i]) & set(want_ids[i])) / k
                          for i in range(len(queries))]))


def _churn_point(make_backend, opts, requests, attrs, *, frac: float,
                 batch: int = 16, seed: int = 7) -> dict:
    """Serve ``requests`` while holding the live delta at ``frac`` of the
    base row count: each served batch is preceded by a small upsert burst
    with matching retirements of the oldest streamed ids, so the measured
    QPS includes the steady-state mutation overhead (delta scan + compose
    + tombstone masking), not a one-off ingest spike."""
    eng = ServeEngine(make_backend(), opts, max_batch=batch)
    # warm-up over the full stream: compiles every (route, split-size)
    # executable the timed loop will hit, so the 0%-delta point measures
    # serving, not first-point compiles
    i = 0
    while i < len(requests):
        for q, flt in requests[i:i + batch]:
            eng.submit(q, flt)
        eng.step(force=True)
        i += batch
    eng.reset_stats()
    rng = np.random.default_rng(seed)
    dim = requests[0][0].shape[0]
    n_base = eng.stats["mutations"]["base_rows"]
    target = int(round(frac * n_base))
    pool: list[int] = []

    def mutate(count: int) -> None:
        if count <= 0:
            return
        rows = rng.integers(0, attrs.ints.shape[0], count)
        ids = eng.upsert(rng.normal(size=(count, dim)).astype(np.float32),
                         attrs.ints[rows], attrs.floats[rows])
        pool.extend(int(i) for i in ids)
        while len(pool) > target:
            eng.delete([pool.pop(0)])

    mutate(target)              # reach the steady-state delta fraction
    t0 = time.perf_counter()
    i = 0
    while i < len(requests):
        mutate(max(1, target // 8) if target else 0)
        for q, flt in requests[i:i + batch]:
            eng.submit(q, flt)
        eng.step(force=True)
        i += batch
    wall = time.perf_counter() - t0
    st = eng.stats["mutations"]
    return {"delta_frac": frac, "target_delta_rows": target,
            "qps": len(requests) / max(wall, 1e-12),
            "delta_rows": st["delta_rows"], "upserts": st["upserts"],
            "deletes": st["deletes"]}


def _frontend_coalesce(backend, opts, schema, dim, *, smoke: bool) -> dict:
    """Poisson arrivals through the async front-end.  With coalesce_ms=0
    every dispatch carries whatever trickled in during the previous engine
    step (~1 row at low rates) and pads it up to the smallest bucket; a
    hold window of a few mean inter-arrivals fills the bucket with real
    rows first.  Both arms are checked bit-identical against the
    synchronous ``router.execute`` one-shot path."""
    n_req = 32 if smoke else 96
    reqs = _workload(schema, dim, n_req, seed=29)
    gaps = np.random.default_rng(31).exponential(0.008, n_req)
    ref = router.execute(backend, np.stack([q for q, _ in reqs]),
                         [f for _, f in reqs], opts)

    async def drive(coalesce_ms: float):
        eng = ServeEngine(backend, opts, max_batch=16 if smoke else 32)
        eng.warmup()
        fe = FrontEnd(eng, FrontEndSpec(coalesce_ms=coalesce_ms,
                                        coalesce_target=16))
        t0 = time.perf_counter()
        tasks = []
        for i, (q, flt) in enumerate(reqs):
            tasks.append(asyncio.create_task(fe.submit(q, flt)))
            await asyncio.sleep(gaps[i])
        outs = await asyncio.gather(*tasks)
        wall = time.perf_counter() - t0
        st = fe.stats
        await fe.close()
        return outs, st, wall

    arms = {}
    for label, cms in (("uncoalesced", 0.0), ("coalesced", 40.0)):
        outs, st, wall = asyncio.run(drive(cms))
        t = st["tenants"]["default"]
        arms[label] = {
            "coalesce_ms": cms,
            "qps": len(outs) / max(wall, 1e-12),
            "p50_ms": t["p50_ms"], "p99_ms": t["p99_ms"],
            "dispatches": st["coalesce"]["dispatches"],
            "mean_batch": st["coalesce"]["mean_batch"],
            "pad_overhead": st["engine"]["batching"]["pad_overhead"],
            "mismatch_frac": float(np.mean(
                [not np.array_equal(r.ids, ref.ids[i])
                 for i, r in enumerate(outs)])),
        }
    return arms


def _frontend_qos(backend, opts, schema, dim, *, smoke: bool) -> dict:
    """One hot tenant fires its whole burst at t=0 while three cold
    tenants trickle steady traffic.  admission_on = token bucket + bounded
    queue + weighted fair dequeue; admission_off = unbounded global FIFO,
    so the burst head-of-line-blocks every cold request behind it."""
    n_cold, cold_each = 3, (8 if smoke else 16)
    hot_n = 64 if smoke else 160
    hot_reqs = _workload(schema, dim, hot_n, seed=37)
    cold_reqs = _workload(schema, dim, n_cold * cold_each, seed=41)

    def _spec(admission: bool) -> FrontEndSpec:
        tenants = {"hot": TenantSpec(rate_qps=50.0, burst=8, queue_cap=16)}
        for c in range(n_cold):
            tenants[f"cold{c}"] = TenantSpec(weight=2.0)
        return FrontEndSpec(coalesce_ms=2.0, coalesce_target=16,
                            admission=admission, fair=admission,
                            tenants=tenants)

    async def drive(admission: bool):
        eng = ServeEngine(backend, opts, max_batch=16 if smoke else 32)
        eng.warmup()
        fe = FrontEnd(eng, _spec(admission))

        async def one(q, flt, tenant):
            try:
                return await fe.submit(q, flt, tenant=tenant)
            except Overloaded:
                return None        # sheds are attributed in fe.stats

        async def cold(name, reqs):
            for q, flt in reqs:
                await one(q, flt, name)
                await asyncio.sleep(0.004)

        burst = [asyncio.create_task(one(q, f, "hot")) for q, f in hot_reqs]
        colds = [asyncio.create_task(
            cold(f"cold{c}", cold_reqs[c * cold_each:(c + 1) * cold_each]))
            for c in range(n_cold)]
        await asyncio.gather(*burst, *colds)
        st = fe.stats
        await fe.close()
        return st

    out = {}
    for label, admission in (("admission_on", True),
                             ("admission_off", False)):
        asyncio.run(drive(admission))   # warm pass: compiles land here,
        st = asyncio.run(drive(admission))  # not in the measured arm
        hot = st["tenants"]["hot"]
        colds = [st["tenants"][f"cold{c}"] for c in range(n_cold)]
        out[label] = {
            "hot": {"served": hot["served"], "shed": hot["shed_total"],
                    "shed_reasons": {k: v for k, v in hot["shed"].items()
                                     if v},
                    "p99_ms": hot["p99_ms"]},
            "cold_served": sum(c["served"] for c in colds),
            "cold_shed": sum(c["shed_total"] for c in colds),
            "cold_p99_ms": max(c["p99_ms"] for c in colds),
        }
    return out


def _assert_frontend_smoke(fr: dict) -> None:
    """CI acceptance for the async front-end: coalescing is lossless and
    cuts pad waste; admission sheds the hot tenant only and bounds cold
    tail latency."""
    un, co = fr["coalesce"]["uncoalesced"], fr["coalesce"]["coalesced"]
    assert un["mismatch_frac"] == 0.0 and co["mismatch_frac"] == 0.0, fr
    assert co["pad_overhead"] < un["pad_overhead"], (un, co)
    assert co["mean_batch"] >= un["mean_batch"], (un, co)
    on, off = fr["qos"]["admission_on"], fr["qos"]["admission_off"]
    assert on["hot"]["shed"] > 0, on
    assert on["cold_shed"] == 0 and off["cold_shed"] == 0, (on, off)
    assert off["hot"]["shed"] == 0, off
    assert on["cold_p99_ms"] <= off["cold_p99_ms"], (on, off)


def _concurrency(make_backend, opts, schema, dim, attrs, *,
                 smoke: bool) -> dict:
    """Pipelined step dispatch + background incremental merge under load.

    **Pipeline arm** -- two tenants submit Poisson traffic through the
    async front-end with a short coalesce hold.  ``parallel_steps=1``
    resolves every step before the next dispatch, so hold window, host
    phase and device wait all serialize; ``parallel_steps=2`` keeps one
    step's device phase in flight while the scheduler holds/builds the
    next batch.  Best-of-repeats QPS per arm, plus a per-request
    bit-identity check across arms (batch composition differs between
    them -- bucket padding makes results batch-invariant).

    **Merge arm** -- per-step latency on a live index holding a ~10%
    unmerged delta, in three phases on comparable engines: steady (delta
    live, no compaction running), background (a small-wave
    ``MergeController`` folds the delta off-thread while steps keep
    serving; only the epoch-guarded commit swap runs under the engine
    lock), and foreground (the same delta compacted inline by the step
    that crosses ``merge_delta_frac`` -- the whole build lands in that
    request's latency, the contrast case).  Every build/serve executable
    is compiled in a rehearsal pass (upserts matched by deletes keep the
    row count constant, so post-merge shapes repeat exactly).
    """
    n_req = 64 if smoke else 160
    reqs = _workload(schema, dim, n_req, seed=53)
    gaps = np.random.default_rng(59).exponential(0.002, n_req)
    arrive = np.cumsum(gaps)
    pipe_backend = make_backend()

    async def drive(slots: int):
        eng = ServeEngine(pipe_backend, opts, max_batch=8)
        eng.warmup()
        fe = FrontEnd(eng, FrontEndSpec(parallel_steps=slots,
                                        coalesce_ms=2.0, coalesce_target=8))
        tasks = []
        t0 = time.perf_counter()
        for i, (q, flt) in enumerate(reqs):
            lag = arrive[i] - (time.perf_counter() - t0)
            if lag > 0:
                await asyncio.sleep(lag)
            tasks.append(asyncio.create_task(
                fe.submit(q, flt, tenant=("a", "b")[i % 2])))
        outs = await asyncio.gather(*tasks)
        wall = time.perf_counter() - t0
        st = fe.stats
        await fe.close()
        return outs, st, wall

    def best_of(slots: int, repeats: int = 3):
        outs, st, qps = None, None, 0.0
        for _ in range(repeats):
            o, s, w = asyncio.run(drive(slots))
            if len(o) / w > qps:
                outs, st, qps = o, s, len(o) / w
        return outs, st, qps

    outs_s, st_s, qps_s = best_of(1)
    outs_p, st_p, qps_p = best_of(2)
    pipe = {
        "requests": n_req,
        # wall-clock overlap needs host and device work on separate cores:
        # on a 1-core container they timeshare and the arms tie, so the
        # >=1.25x smoke bar only applies at cores >= 2 (the CI runner)
        "cores": os.cpu_count() or 1,
        "serialized": {"qps": qps_s,
                       "dispatches": st_s["coalesce"]["dispatches"],
                       "mean_batch": st_s["coalesce"]["mean_batch"]},
        "pipelined": {"qps": qps_p, "slots": st_p["coalesce"]["slots"],
                      "dispatches": st_p["coalesce"]["dispatches"],
                      "mean_batch": st_p["coalesce"]["mean_batch"]},
        "qps_ratio": qps_p / max(qps_s, 1e-12),
        "mismatch_frac": float(np.mean(
            [not np.array_equal(a.ids, b.ids)
             for a, b in zip(outs_s, outs_p)])),
    }

    # -- merge arm ----------------------------------------------------------
    m_reqs = _workload(schema, dim, 48 if smoke else 96, seed=61)
    rng = np.random.default_rng(67)
    n_attr = attrs.ints.shape[0]

    def churn(eng, count):
        """Upsert ``count`` rows and retire ``count`` old ids, so the merged
        index keeps the base row count (and every executable shape)."""
        rows = rng.integers(0, n_attr, count)
        vecs = rng.normal(size=(count, dim)).astype(np.float32)
        ids = eng.upsert(vecs, attrs.ints[rows], attrs.floats[rows])
        base_n = int(ids[0])              # first delta id == base row count
        eng.delete(list(range(max(base_n - count, 0), base_n)))

    def step_once(eng, k):
        """One single-request step with a think gap wider than one build
        burst: the edge-paced controller launches its next wave the moment
        a step finishes, so the burst completes inside this gap."""
        time.sleep(0.03)
        q, flt = m_reqs[k % len(m_reqs)]
        eng.submit(q, flt)
        active = eng._m_merge_active.value() > 0.0
        t0 = time.perf_counter()
        eng.step(force=True)
        return (time.perf_counter() - t0) * 1e3, (
            active or eng._m_merge_active.value() > 0.0)

    p99 = lambda xs: float(np.percentile(np.asarray(xs), 99))  # noqa: E731

    def background_cycle():
        """One full lifecycle on a fresh backend: churn up a ~10% delta,
        steady-state serve, then serve on while the controller folds the
        delta off-thread.  Run twice -- a merge grows the row count, so a
        warmed engine can never replay its own merge shape-for-shape, but
        a second identical cycle on a fresh backend hits every executable
        the first cycle compiled (same base, same delta count)."""
        eng = ServeEngine(make_backend(), opts, max_batch=8,
                          merge_background=True)
        eng._merge_ctl.stop()
        # small waves + fast poll so the build spans many steps; a generous
        # max_yield_s lets the edge-triggered pacing wait out a full step
        # and launch each burst at the start of the inter-step gap
        ctl = MergeController(eng, wave=2, poll_s=0.002, max_yield_s=0.2)
        eng._merge_ctl = ctl
        eng.warmup()
        n_base = eng.stats["mutations"]["base_rows"]
        delta = max(16, int(round(0.10 * n_base)))
        churn(eng, delta)
        for k in range(len(m_reqs)):      # warm the delta-live serve path
            step_once(eng, k)
        eng.reset_stats()
        lat_s = [step_once(eng, k)[0] for k in range(len(m_reqs))]
        eng.merge_delta_frac = 0.05       # next poll/poke starts the build
        lat_b, act_b = [], []
        k = 0
        # merge-count checked right after every step: a post-commit serve
        # (new row count -> fresh executables) never enters the sample
        while ctl.merges < 1 and k < 2000:
            ms, active = step_once(eng, k)
            lat_b.append(ms)
            act_b.append(active)
            k += 1
        during = [ms for ms, a in zip(lat_b, act_b) if a] or lat_b
        out = {
            "delta_rows": delta,
            "steady": {"p99_ms": p99(lat_s), "steps": len(lat_s)},
            "background": {
                "p99_ms": p99(lat_b), "steps": len(lat_b),
                "during_merge_steps": int(np.sum(act_b)),
                "during_p99_ms": p99(during),
                "merges": ctl.merges, "stale_commits": ctl.stale,
                "merge_s": eng._m_merge_s.sum(),
                "commit_stall_s": eng._m_merge_stall.sum(),
                "delta_rows_after": eng.stats["mutations"]["delta_rows"],
            },
        }
        eng.close()
        return out

    background_cycle()                    # dress rehearsal: compiles land here
    merge = background_cycle()            # measured: every executable warm

    # foreground contrast: the same delta compacted inline by the step
    # that crosses the threshold -- the whole build (and its compiles)
    # lands in that request's latency, which is exactly the point
    eng_f = ServeEngine(make_backend(), opts, max_batch=8)
    eng_f.warmup()
    churn(eng_f, merge["delta_rows"])
    for k in range(len(m_reqs)):
        step_once(eng_f, k)
    eng_f.reset_stats()
    eng_f.merge_delta_frac = 0.05
    lat_f = [step_once(eng_f, k)[0] for k in range(len(m_reqs))]
    st_f = eng_f.stats["mutations"]
    merge["foreground"] = {
        "p99_ms": p99(lat_f), "max_ms": float(np.max(lat_f)),
        "steps": len(lat_f), "merges": st_f["merges"],
    }
    merge["p99_vs_steady"] = (merge["background"]["during_p99_ms"]
                              / max(merge["steady"]["p99_ms"], 1e-12))
    return {"pipeline": pipe, "merge": merge}


def _assert_concurrency_smoke(co: dict) -> None:
    """CI acceptance for pipelined serving: overlapped dispatch buys real
    wall-clock (>=1.25x serialized) without changing a single result bit,
    and a background merge never stalls serving past 2x the steady-state
    p99 (the foreground arm shows what inline compaction costs instead)."""
    pipe, mg = co["pipeline"], co["merge"]
    assert pipe["mismatch_frac"] == 0.0, pipe
    if pipe["cores"] >= 2:
        assert pipe["qps_ratio"] >= 1.25, pipe
    else:
        # single-core container: host phase, device compute and the
        # scheduler all timeshare one core, so overlap cannot buy
        # wall-clock and thread contention adds real (noisy) overhead.
        # Only guard against pathological collapse here -- the >=1.25x
        # bar runs on the multi-core CI runner
        assert pipe["qps_ratio"] >= 0.3, pipe
    bg = mg["background"]
    assert bg["merges"] >= 1 and bg["delta_rows_after"] == 0, bg
    assert bg["during_merge_steps"] >= 1, bg
    assert bg["during_p99_ms"] <= 2.0 * mg["steady"]["p99_ms"], mg
    assert mg["foreground"]["merges"] >= 1, mg


def _obs_overhead(backend, opts, requests, *, repeats: int) -> dict:
    """Observability cost + probe accuracy on the mixed-selectivity stream.

    Three arms over the same warmed engine shape: obs OFF
    (``ObsSpec(enabled=False)``), obs ON at default sampling (every batch
    traced -- the worst steady-state case), and a diagnostics arm with the
    estimator-accuracy probe on every batch plus sampled route shadows.
    Overhead is best-of-``repeats`` QPS off vs on (best-of bounds scheduler
    noise, which at these walltimes dwarfs the obs cost itself); the off/on
    arms are also checked row-identical, the observe-never-steer contract.
    """
    def drive(obs_spec, n_rep):
        best, outs, eng = 0.0, None, None
        for _ in range(n_rep):
            eng = ServeEngine(backend, opts, max_batch=32, obs=obs_spec)
            for q, flt in requests:
                eng.submit(q, flt)
            eng.drain()                 # warm-up pass
            eng.reset_stats()
            for q, flt in requests:
                eng.submit(q, flt)
            t0 = time.perf_counter()
            out = eng.drain()
            wall = time.perf_counter() - t0
            best = max(best, len(out) / max(wall, 1e-12))
            outs = out
        return best, outs, eng

    qps_off, out_off, _ = drive(ObsSpec(enabled=False), repeats)
    qps_on, out_on, eng_on = drive(ObsSpec(), repeats)
    mismatch = float(np.mean([not np.array_equal(a.ids, b.ids)
                              for a, b in zip(out_off, out_on)]))
    # diagnostics arm: accuracy, not speed -- one pass, max probe rate
    _, _, eng_p = drive(ObsSpec(probe_sample=1.0, shadow_sample=0.5,
                                slow_ms=0.0), 1)
    snap = eng_p.obs.snapshot()
    err = snap["histograms"]["favor_estimator_abs_error"]["series"].get(
        "", {"sum": 0.0, "count": 0})
    probes = snap["counters"]["favor_estimator_probes_total"]["series"]
    flips = snap["counters"]["favor_estimator_route_flips_total"]["series"]
    shadow = snap["counters"]["favor_route_shadow_total"]["series"]
    regret = snap["counters"]["favor_route_regret_seconds_total"][
        "series"].get("", 0.0)
    return {
        "qps_off": qps_off, "qps_on": qps_on,
        "overhead_frac": (qps_off - qps_on) / max(qps_off, 1e-12),
        "mismatch_frac": mismatch,
        "traces": eng_on.stats["obs"]["traces"],
        "slow_queries": eng_on.stats["obs"]["slow_queries"],
        "probes": {
            "count": int(sum(probes.values())),
            "mean_abs_error": err["sum"] / max(err["count"], 1),
            "route_flips": int(sum(flips.values())),
            "by_route": {k: int(v) for k, v in probes.items()},
        },
        "shadow": {
            "count": int(sum(shadow.values())),
            "confusion": {k: int(v) for k, v in shadow.items()},
            "regret_s": float(regret),
        },
    }


def _assert_obs_smoke(ob: dict) -> None:
    """CI acceptance for the observability layer: bit-identical results,
    <5% QPS overhead at default sampling, and populated estimator-error +
    route-confusion metrics on the mixed-selectivity stream."""
    assert ob["mismatch_frac"] == 0.0, ob
    assert ob["overhead_frac"] < 0.05, ob
    assert ob["traces"] > 0, ob
    p, s = ob["probes"], ob["shadow"]
    assert p["count"] > 0 and 0.0 <= p["mean_abs_error"] <= 1.0, p
    assert s["count"] > 0 and s["confusion"], s


def _assert_smoke(points, shard, requests, spec: BatchSpec, opts):
    """CI acceptance: bounded compiled shapes, exact parity, and the Pallas
    brute scan working inside the sharded shard_map path."""
    ladder = set(spec.buckets())
    for pt in points:
        assert pt["mismatch_frac"] == 0.0, \
            f"{pt['backend']}: padded results diverged ({pt['mismatch_frac']})"
        sizes = pt["padded"]["sizes"]
        for kind, seen in sizes.items():
            extra = set(seen) - ladder
            assert not extra, \
                f"{pt['backend']}/{kind}: shapes {extra} escaped the ladder"
            assert len(seen) <= len(ladder), (kind, seen)
        # the unpadded arm compiles one executable per distinct split size;
        # the padded arm is bounded by the ladder
        assert pt["padded"]["compiled_shapes"] <= 3 * len(ladder), pt
    qs = np.stack([q for q, _ in requests[:8]])
    flts = [flt for _, flt in requests[:8]]
    brute = opts.with_(force="brute")
    rn = router.execute(shard, qs, flts, brute)
    rp = router.execute(shard, qs, flts, brute.with_(use_pallas=True))
    for i in range(len(qs)):  # sets: kernel may swap exact-tie ids
        assert set(rn.ids[i]) == set(rp.ids[i]), i
    rpb = router.execute(shard, qs, flts,
                         brute.with_(use_pallas=True, batch=spec))
    assert np.array_equal(rp.ids, rpb.ids)


def run(quick: bool = False, smoke: bool = False) -> str:
    if smoke:
        quick = True
    n, dim = (2048, 16) if smoke else ((4096, DIM) if quick
                                       else (max(4096, N // 2), DIM))
    n_requests = 48 if smoke else (64 if quick else min(256, NQ * 2))
    vecs, attrs, schema = synthetic.make_paper_dataset(n, dim, seed=SEED)
    requests = _workload(schema, dim, n_requests, seed=3)

    qcfg = FavorServeConfig(pq_m=max(4, dim // 4), rerank=8)
    spec = qcfg.build_spec(hnsw=HnswParams(M=12, efc=60, seed=SEED))
    opts_f32 = qcfg.search_options(k=10, ef=64, use_pq=False)
    opts_pq = qcfg.search_options(k=10, ef=64, use_pq=True)

    local = LocalBackend(FavorIndex.build(vecs, attrs, spec=spec))
    n_model = largest_divisor(n, len(jax.devices()))
    mesh = jax.make_mesh((1, n_model), ("data", "model"))
    shard = ShardedBackend.build(vecs, attrs, mesh, spec,
                                 codebook=local.index.codebook, seed=SEED)

    # -- shape-stable serving sweep FIRST: the unpadded arm must be cold
    # (driving the grid beforehand would pre-compile many of the very
    # (route, size) executables whose inline compiles it measures) --------
    spec = BatchSpec(min_bucket=8, max_bucket=16 if smoke else 64)
    sweep_batch = 16 if smoke else 64
    sweep_reqs = _workload(schema, dim, n_requests, seed=17)
    points = _p99_sweep([("local", local, opts_f32),
                         ("sharded", shard, opts_f32)],
                        sweep_reqs, spec, sweep_batch)

    bpv_f32 = local.index.bytes_per_vector()
    bpv_pq = local.index.bytes_per_vector(quantized=True)
    grid = [("local", local, opts_f32, bpv_f32),
            ("local", local, opts_pq, bpv_pq),
            ("sharded", shard, opts_f32, bpv_f32),
            ("sharded", shard, opts_pq, bpv_pq)]

    csv = Csv("serve_backends.csv",
              ["backend", "shards", "use_pq", "qps", "p50_ms", "p99_ms",
               "graph", "brute", "bytes_per_vector", "scan_bytes"])
    summary = []
    for name, backend, opts, bpv in grid:
        qps, p50, p99, stats = _drive(backend, opts, requests)
        shards = n_model if name == "sharded" else 1
        csv.add(name, shards, int(opts.use_pq), qps, p50, p99,
                stats["graph"], stats["brute"], float(bpv), float(bpv * n))
        summary.append(f"{name}{'_pq' if opts.use_pq else '_f32'}={qps:.0f}")
    path = csv.write()

    pcsv = Csv("serve_batching.csv",
               ["backend", "padded", "qps", "p50_ms", "p99_ms",
                "compiled_shapes", "pad_overhead", "mismatch_frac"])
    for pt in points:
        for arm in ("unpadded", "padded"):
            m = pt[arm]
            pcsv.add(pt["backend"], int(arm == "padded"), m["qps"],
                     m["p50_ms"], m["p99_ms"], m["compiled_shapes"],
                     m["pad_overhead"], pt["mismatch_frac"])
    pcsv.write()
    jpath = update_bench_json("batching", {
        "config": {"n": n, "dim": dim, "requests": n_requests,
                   "max_batch": sweep_batch, "buckets": list(spec.buckets()),
                   "shards": n_model},
        "points": points,
    })
    if smoke:
        _assert_smoke(points, shard, sweep_reqs, spec, opts_f32)

    # -- live-index churn + bulk-vs-loop build comparison ---------------------
    params = HnswParams(M=12, efc=60, seed=SEED)
    t0 = time.perf_counter()
    bulk_idx = build_hnsw_bulk(vecs, params, wave=256)
    bulk_s = time.perf_counter() - t0
    rq = synthetic.make_queries(32, dim, dataset_seed=SEED, seed=909)
    d2 = (np.sum(rq ** 2, 1)[:, None] + np.sum(vecs ** 2, 1)[None, :]
          - 2.0 * rq @ vecs.T)
    want = np.argsort(d2, axis=1, kind="stable")[:, :10]
    rec_seq = _graph_recall(local, rq, want, opts_f32)
    rec_bulk = _graph_recall(LocalBackend(FavorIndex(bulk_idx, attrs)),
                             rq, want, opts_f32)
    churn = [_churn_point(lambda: LocalBackend(FavorIndex(bulk_idx, attrs)),
                          opts_f32, requests, attrs, frac=frac)
             for frac in (0.0, 0.01, 0.10)]
    jpath = update_bench_json("mutation", {
        "config": {"n": n, "dim": dim, "requests": n_requests},
        "churn": churn,
        "bulk_build": {"recall_seq": rec_seq, "recall_bulk": rec_bulk,
                       "build_s_seq": local.index.build_seconds,
                       "build_s_bulk": bulk_s},
    })
    if smoke:
        # acceptance: device-parallel bulk build within 1pt of the loop
        assert abs(rec_seq - rec_bulk) <= 0.01, (rec_seq, rec_bulk)
        for pt in churn:
            assert pt["qps"] > 0.0, pt
            assert pt["delta_rows"] == pt["target_delta_rows"], pt
            if pt["delta_frac"]:
                assert pt["upserts"] > pt["target_delta_rows"], pt
                assert pt["deletes"] > 0, pt

    # -- async front-end: coalescing + multi-tenant QoS -----------------------
    fe_opts = opts_f32.with_(batch=spec)
    fr = {"coalesce": _frontend_coalesce(local, fe_opts, schema, dim,
                                         smoke=smoke),
          "qos": _frontend_qos(local, fe_opts, schema, dim, smoke=smoke)}
    jpath = update_bench_json("frontend", {
        "config": {"n": n, "dim": dim, "buckets": list(spec.buckets())},
        **fr,
    })
    if smoke:
        _assert_frontend_smoke(fr)

    # -- pipelined dispatch + background merge --------------------------------
    co = _concurrency(lambda: LocalBackend(FavorIndex(bulk_idx, attrs)),
                      fe_opts, schema, dim, attrs, smoke=smoke)
    jpath = update_bench_json("concurrency", {
        "config": {"n": n, "dim": dim, "buckets": list(spec.buckets())},
        **co,
    })
    if smoke:
        _assert_concurrency_smoke(co)

    # -- observability: overhead + estimator/route-confusion probes -----------
    ob = _obs_overhead(local, opts_f32, requests,
                       repeats=3 if quick else 5)
    jpath = update_bench_json("obs", {
        "config": {"n": n, "dim": dim, "requests": n_requests,
                   "max_batch": 32},
        **ob,
    })
    if smoke:
        _assert_obs_smoke(ob)

    sp = points[-1]  # sharded point
    fr_co = fr["coalesce"]
    fr_on, fr_off = fr["qos"]["admission_on"], fr["qos"]["admission_off"]
    hot_total = fr_on["hot"]["shed"] + fr_on["hot"]["served"]
    return (f"shards={n_model} compression={bpv_f32 / bpv_pq:.1f}x "
            + " ".join(summary)
            + f" | batching: shapes {sp['unpadded']['compiled_shapes']}->"
              f"{sp['padded']['compiled_shapes']} "
              f"p99 {sp['unpadded']['p99_ms']:.1f}->"
              f"{sp['padded']['p99_ms']:.1f}ms "
              f"pad={sp['padded']['pad_overhead']:.2f}"
            + " | mutation: qps "
            + "/".join(f"{pt['qps']:.0f}@{pt['delta_frac']:.0%}"
                       for pt in churn)
            + f" bulk_recall={rec_bulk:.3f} (seq {rec_seq:.3f}, "
              f"{local.index.build_seconds:.1f}s->{bulk_s:.1f}s)"
            + " | frontend: pad "
              f"{fr_co['uncoalesced']['pad_overhead']:.2f}->"
              f"{fr_co['coalesced']['pad_overhead']:.2f} "
              f"hot shed {fr_on['hot']['shed']}/{hot_total} "
              f"cold p99 {fr_on['cold_p99_ms']:.0f}ms"
              f" (fifo {fr_off['cold_p99_ms']:.0f}ms)"
            + f" | conc: pipeline {co['pipeline']['qps_ratio']:.2f}x "
              f"merge p99 {co['merge']['steady']['p99_ms']:.1f}->"
              f"{co['merge']['background']['p99_ms']:.1f}ms bg "
              f"({co['merge']['foreground']['p99_ms']:.0f}ms inline) "
              f"stall {co['merge']['background']['commit_stall_s'] * 1e3:.1f}ms"
            + f" | obs: overhead {ob['overhead_frac']:+.1%} "
              f"err {ob['probes']['mean_abs_error']:.3f} "
              f"flips {ob['probes']['route_flips']}/{ob['probes']['count']} "
              f"regret {ob['shadow']['regret_s'] * 1e3:.1f}ms"
            + f" json={jpath}")


def main() -> None:
    ap = argparse.ArgumentParser()
    # direct module invocation has always been the quick run; the
    # full-size corpus stays reachable via --full or benchmarks.run
    ap.add_argument("--full", action="store_true",
                    help="full-size corpus (default: quick)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny corpus, assert the compile-regression"
                         " guard, padded parity, sharded use_pallas and the"
                         " <5%% obs overhead bar")
    args = ap.parse_args()
    print(run(quick=not args.full, smoke=args.smoke))


if __name__ == "__main__":
    main()
