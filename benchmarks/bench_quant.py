"""Compressed vs float32 brute scan: QPS / Recall@10 / bytes-per-vector.

Sweeps the paper's six filter scenarios (selectivity 0.8%..50%) through the
float32 PreFBF scan and the PQ ADC scan (+ exact re-rank), reporting the
memory-format trade-off the quant subsystem buys: the compressed scan
streams codebook.bytes_per_vector() bytes per row instead of 4*d.

    PYTHONPATH=src python -m benchmarks.run --only quant [--quick]
"""
from __future__ import annotations

import numpy as np

from repro.core import FavorIndex
from repro.core import filters as F
from repro.core import refimpl

from .common import (Csv, get_dataset, get_index, ground_truth, mean_recall,
                     timed_search)


def run(quick: bool = False) -> str:
    vecs, attrs, schema, queries = get_dataset()
    if quick:
        queries = queries[:32]
    base = get_index()
    # the production memory format comes from the favor-anns config, with M
    # rescaled to the (smaller) bench dim; rerank=8 holds Recall@10 within
    # ~0.5pt of float32 even at 50% selectivity while the re-rank touches
    # only 80 full-precision rows per query
    from dataclasses import replace

    from repro.configs.favor_anns import FavorServeConfig
    qcfg = FavorServeConfig(pq_m=max(4, vecs.shape[1] // 4), rerank=8)
    spec = qcfg.build_spec()
    spec = replace(spec, quant=replace(spec.quant,
                                       train_iters=10 if quick else 20))
    fi = FavorIndex(base.index, attrs, spec)
    bpv_f32 = fi.bytes_per_vector()
    bpv_pq = fi.bytes_per_vector(quantized=True)

    from repro.core.filters import paper_filters
    flts = paper_filters(schema)
    csv = Csv("quant.csv", ["filter", "selectivity", "qps_f32", "qps_pq",
                            "recall_f32", "recall_pq", "bytes_f32",
                            "bytes_pq", "compression"])
    worst_gap = 0.0
    for name, flt in flts.items():
        mask = F.eval_program(F.compile_filter(flt, schema), attrs.ints,
                              attrs.floats)
        sel = float(mask.mean())
        truth = ground_truth(vecs, mask, queries)
        r32, qps32 = timed_search(fi, queries, flt, force="brute")
        rpq, qpspq = timed_search(fi, queries, flt, force="brute", use_pq=True)
        rec32 = mean_recall(r32.ids, truth)
        recpq = mean_recall(rpq.ids, truth)
        worst_gap = max(worst_gap, rec32 - recpq)
        csv.add(name, sel, qps32, qpspq, rec32, recpq,
                float(bpv_f32), float(bpv_pq), bpv_f32 / bpv_pq)
    path = csv.write()
    return (f"compression={bpv_f32 / bpv_pq:.1f}x "
            f"worst_recall_gap={worst_gap:.4f} csv={path}")


if __name__ == "__main__":
    print(run(quick=True))
