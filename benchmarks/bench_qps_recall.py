"""Paper Figs. 4/5/8/9: QPS-recall tradeoff per filtering scenario.

Methods: FAVOR (full selector pipeline), FAVOR-graph (exclusion-distance
search forced), RSF (result-set-filtering baseline, same batching), PreFBF
(brute force).  ef sweeps the tradeoff curve.  Paper claim mirrored: FAVOR
gives >= 1.3x the best filter-agnostic baseline's QPS at Recall@10 ~ 95%.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import SearchConfig, compile_filter, paper_filters, stack_programs
from repro.core import filters as F
from repro.core import rsf_graph_search
from . import common as C


def rsf_qps(fi, queries, flt, k, ef, repeats=3):
    progs = {kk: jnp.asarray(v) for kk, v in stack_programs(
        [compile_filter(flt, fi.schema)] * len(queries)).items()}
    cfg = SearchConfig(k=k, ef=ef)
    qj = jnp.asarray(queries)
    out = rsf_graph_search(fi.g, qj, progs, cfg)  # compile
    import time
    best = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = rsf_graph_search(fi.g, qj, progs, cfg)
        out["ids"].block_until_ready()
        best = max(best, len(queries) / (time.perf_counter() - t0))
    return np.asarray(out["ids"]), best


def run(quick: bool = False):
    fi = C.get_index()
    vecs, attrs, schema, queries = C.get_dataset()
    scenarios = paper_filters(schema)
    efs = [24, 48, 96, 192] if not quick else [48, 96]
    k = 10
    csv = C.Csv("qps_recall.csv",
                ["scenario", "method", "ef", "qps", "recall_at_10"])
    summary = {}
    for name, flt in scenarios.items():
        prog = compile_filter(flt, schema)
        mask = F.eval_program(prog, attrs.ints, attrs.floats)
        truth = C.ground_truth(vecs, mask, queries, k)
        best_at_95 = {}
        for ef in efs:
            res, qps = C.timed_search(fi, queries, flt, k=k, ef=ef)
            rec = C.mean_recall(res.ids, truth, k)
            csv.add(name, "favor", ef, qps, rec)
            best_at_95.setdefault("favor", []).append((rec, qps))

            res_g, qps_g = C.timed_search(fi, queries, flt, k=k, ef=ef,
                                          force="graph")
            rec_g = C.mean_recall(res_g.ids, truth, k)
            csv.add(name, "favor_graph", ef, qps_g, rec_g)

            ids_r, qps_r = rsf_qps(fi, queries, flt, k, ef)
            rec_r = C.mean_recall(ids_r, truth, k)
            csv.add(name, "rsf", ef, qps_r, rec_r)
            best_at_95.setdefault("rsf", []).append((rec_r, qps_r))
        res_b, qps_b = C.timed_search(fi, queries, flt, k=k, ef=efs[-1],
                                      force="brute")
        csv.add(name, "prefbf", 0, qps_b, C.mean_recall(res_b.ids, truth, k))

        def at95(pairs):
            ok = [q for r, q in pairs if r >= 0.95]
            return max(ok) if ok else 0.0
        summary[name] = (at95(best_at_95["favor"]), at95(best_at_95["rsf"]))
    csv.write()
    print("\n# FAVOR vs RSF QPS at Recall@10>=95% (paper: 1.3-5x):")
    for name, (f, r) in summary.items():
        ratio = f / r if r else float("inf")
        print(f"#   {name:15s} favor={f:8.1f} rsf={r:8.1f} ratio={ratio:.2f}x")
    return csv.path


if __name__ == "__main__":
    run()
