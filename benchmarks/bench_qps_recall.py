"""Paper Figs. 4/5/8/9: QPS-recall tradeoff per filtering scenario.

Methods: FAVOR (full selector pipeline), FAVOR-graph (exclusion-distance
search forced), RSF (result-set-filtering baseline, same batching), PreFBF
(brute force).  ef sweeps the tradeoff curve.  Paper claim mirrored: FAVOR
gives >= 1.3x the best filter-agnostic baseline's QPS at Recall@10 ~ 95%.

``run_scorers`` (CLI: ``python -m benchmarks.bench_qps_recall --smoke``)
sweeps the graph route's pluggable scorer layer (core.scoring): the same
traversal with f32 vs PQ-ADC vs SQ neighbor scoring under one shared wave
budget, reporting QPS, recall@10, traversal waves and the bytes-gathered-
per-hop reduction.  The summary lands in the ``graph_scorers`` section of
bench_out/BENCH_serve.json; --smoke runs a bandwidth-bound d=128 corpus and
asserts the acceptance bar: PQ graph-route QPS >= f32's on the scenario
aggregate at <=1pt recall gap, >= 8x fewer bytes/hop, and a bounded
compile count (the lane-compaction ladder must not multiply executables).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import (BuildSpec, ExactScorer, FavorIndex, HnswParams,
                        PqAdcScorer, QuantSpec, SearchConfig, SearchOptions,
                        compile_filter, paper_filters, stack_programs)
from repro.core import filters as F
from repro.core import refimpl, rsf_graph_search
from repro.data import synthetic
from . import common as C


def rsf_qps(fi, queries, flt, k, ef, repeats=3):
    progs = {kk: jnp.asarray(v) for kk, v in stack_programs(
        [compile_filter(flt, fi.schema)] * len(queries)).items()}
    cfg = SearchConfig(k=k, ef=ef)
    qj = jnp.asarray(queries)
    out = rsf_graph_search(fi.g, qj, progs, cfg)  # compile
    import time
    best = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = rsf_graph_search(fi.g, qj, progs, cfg)
        out["ids"].block_until_ready()
        best = max(best, len(queries) / (time.perf_counter() - t0))
    return np.asarray(out["ids"]), best


def run(quick: bool = False):
    fi = C.get_index()
    vecs, attrs, schema, queries = C.get_dataset()
    scenarios = paper_filters(schema)
    efs = [24, 48, 96, 192] if not quick else [48, 96]
    k = 10
    csv = C.Csv("qps_recall.csv",
                ["scenario", "method", "ef", "qps", "recall_at_10"])
    summary = {}
    for name, flt in scenarios.items():
        prog = compile_filter(flt, schema)
        mask = F.eval_program(prog, attrs.ints, attrs.floats)
        truth = C.ground_truth(vecs, mask, queries, k)
        best_at_95 = {}
        for ef in efs:
            res, qps = C.timed_search(fi, queries, flt, k=k, ef=ef)
            rec = C.mean_recall(res.ids, truth, k)
            csv.add(name, "favor", ef, qps, rec)
            best_at_95.setdefault("favor", []).append((rec, qps))

            res_g, qps_g = C.timed_search(fi, queries, flt, k=k, ef=ef,
                                          force="graph")
            rec_g = C.mean_recall(res_g.ids, truth, k)
            csv.add(name, "favor_graph", ef, qps_g, rec_g)

            ids_r, qps_r = rsf_qps(fi, queries, flt, k, ef)
            rec_r = C.mean_recall(ids_r, truth, k)
            csv.add(name, "rsf", ef, qps_r, rec_r)
            best_at_95.setdefault("rsf", []).append((rec_r, qps_r))
        res_b, qps_b = C.timed_search(fi, queries, flt, k=k, ef=efs[-1],
                                      force="brute")
        csv.add(name, "prefbf", 0, qps_b, C.mean_recall(res_b.ids, truth, k))

        def at95(pairs):
            ok = [q for r, q in pairs if r >= 0.95]
            return max(ok) if ok else 0.0
        summary[name] = (at95(best_at_95["favor"]), at95(best_at_95["rsf"]))
    csv.write()
    print("\n# FAVOR vs RSF QPS at Recall@10>=95% (paper: 1.3-5x):")
    for name, (f, r) in summary.items():
        ratio = f / r if r else float("inf")
        print(f"#   {name:15s} favor={f:8.1f} rsf={r:8.1f} ratio={ratio:.2f}x")
    return csv.path


# Uniform traversal wave budget for the scorer sweep (SearchOptions.
# max_steps, applied to EVERY scorer): quantized distances are noisy, which
# delays Algorithm 3's termination test for a handful of straggler lanes --
# ~1.7x the f32 wave count with identical mean hops and identical recall.
# The budget trims exactly that tail (f32 finishes under it untouched at
# the smoke ef), making the wall-clock comparison about per-wave cost,
# which is the quantity compression actually changes.
STEP_BUDGET = 136

# The smoke corpus is deliberately bandwidth-bound: at d=128 one f32
# neighbor gather streams 512B/row vs 8B of PQ codes, so the scorer choice
# dominates per-wave cost.  (C.DIM=32 keeps the rest of the suite cheap,
# but there f32 scoring is too light for compression to pay.)
SMOKE_DIM = 128


def run_scorers(quick: bool = False, smoke: bool = False) -> str:
    """Graph-route scorer sweep: f32 vs PQ-ADC vs SQ traversal, same
    exclusion machinery, identical batching, one shared wave budget.  The
    headline is the paper-motivated trade: per-hop neighbor gathers shrink
    from 4*d to M (or d) bytes while the exact re-rank keeps recall@10
    within 1pt -- and on the bandwidth-bound smoke corpus the PQ route must
    also WIN on wall-clock (QPS >= f32 at <=1pt recall gap).

    Timing interleaves the scorers round-robin (best-of-N per config)
    instead of timing each config in a block, so slow drift on a shared
    box hits every scorer equally.  A compile-count guard asserts the
    lane-compaction ladder stays inside one executable per (scorer,
    program-shape) pair.
    """
    from repro.core import favor_graph_search

    n = 4096 if smoke else (8192 if quick else C.N)
    dim = SMOKE_DIM if smoke else C.DIM
    nq = 48 if smoke else C.NQ
    efs = [96] if smoke else ([48, 96] if quick else [48, 96, 192])
    rounds = 8 if smoke else 3
    k = 10
    vecs, attrs, schema = synthetic.make_paper_dataset(n, dim, seed=C.SEED)
    queries = synthetic.make_queries(nq, dim, dataset_seed=C.SEED)
    fi = FavorIndex.build(
        vecs, attrs, HnswParams(M=12, efc=60, seed=C.SEED),
        BuildSpec(quant=QuantSpec(m=8, nbits=8, train_iters=10)))
    # SQ rides the same graph: re-wrap the built index with an sq codebook
    # (train_sq is a min/max pass -- no second HNSW build)
    fi_sq = FavorIndex(fi.index, attrs,
                       BuildSpec(quant=QuantSpec(kind="sq")))
    bytes_f32 = ExactScorer().bytes_per_row(fi.g)
    bytes_pq = PqAdcScorer().bytes_per_row(fi.g)
    bytes_sq = fi_sq.g["codes"].shape[1]
    ratio = bytes_f32 / bytes_pq

    configs = [("f32", fi, None), ("pq", fi, "pq"), ("sq", fi_sq, "sq")]
    scenarios = ["equality_bool", "range_50", "logic"]
    csv = C.Csv("graph_scorers.csv",
                ["scenario", "scorer", "ef", "qps", "recall_at_10",
                 "bytes_per_row", "waves"])
    summary = {"n": n, "dim": dim, "step_budget": STEP_BUDGET,
               "bytes_per_row_f32": bytes_f32, "bytes_per_row_pq": bytes_pq,
               "bytes_per_row_sq": int(bytes_sq),
               "bytes_per_hop_ratio": ratio, "scenarios": {}}
    cache0 = favor_graph_search._cache_size()
    for name in scenarios:
        flt = paper_filters(schema)[name]
        mask = F.eval_program(compile_filter(flt, schema), attrs.ints,
                              attrs.floats)
        truth = [refimpl.bruteforce_filtered(vecs, mask, q, k)[0]
                 for q in queries]
        row = {}
        for ef in efs:
            # re-rank deep (top 8k of ef TD candidates): the exact pass
            # reads ~ef f32 rows per query, noise next to the per-hop scan
            # it replaces, and it is what holds the <=1pt bar
            opts = {s: SearchOptions(k=k, ef=ef, force="graph",
                                     graph_quant=gq, max_steps=STEP_BUDGET,
                                     graph_rerank=8 if gq else None)
                    for s, _, gq in configs}
            state = {}
            for s, f, _ in configs:       # warm-up/compile + recall/waves
                res = f.query(queries, flt, opts[s])
                rec = float(np.mean([refimpl.recall_at_k(res.ids[i],
                                                         truth[i], k)
                                     for i in range(nq)]))
                waves = int(np.max(res.waves)) if res.waves is not None else 0
                state[s] = {"recall_at_10": rec, "qps": 0.0, "waves": waves}
            for _ in range(rounds):       # interleaved best-of-N
                for s, f, _ in configs:
                    res = f.query(queries, flt, opts[s])
                    state[s]["qps"] = max(state[s]["qps"], res.qps)
            per_row = {"f32": bytes_f32, "pq": bytes_pq, "sq": bytes_sq}
            for s, _, _ in configs:
                csv.add(name, s, ef, state[s]["qps"],
                        state[s]["recall_at_10"], per_row[s],
                        state[s]["waves"])
            row = state                   # summary keeps the largest ef
        summary["scenarios"][name] = row
    compiles = favor_graph_search._cache_size() - cache0
    compile_budget = len(configs) * len(scenarios) * len(efs)
    summary["graph_compiles"] = compiles
    csv.write()
    path = C.update_bench_json("graph_scorers", summary)
    print(f"# bytes gathered per hop: f32={bytes_f32}B pq={bytes_pq}B "
          f"sq={bytes_sq}B ({ratio:.0f}x less for pq)")
    print(f"# graph executables compiled: {compiles} "
          f"(budget {compile_budget})")
    if smoke:
        assert ratio >= 8, f"bytes-per-hop reduction {ratio:.1f}x < 8x"
        # the compaction ladder must stay inside ONE executable per
        # (scorer cfg, program shape); a blowup here means stage widths
        # leaked into separate jit entries
        assert compiles <= compile_budget, (
            f"{compiles} graph executables for {compile_budget} "
            f"(scorer, scenario, ef) combos -- lane compaction is "
            f"multiplying compiles")
        agg = {s: 0.0 for s, _, _ in configs}
        for name, row in summary["scenarios"].items():
            gap = row["f32"]["recall_at_10"] - row["pq"]["recall_at_10"]
            assert gap <= 0.01, (
                f"{name}: PQ graph recall {row['pq']['recall_at_10']:.3f} "
                f"more than 1pt under f32 {row['f32']['recall_at_10']:.3f}")
            for s in agg:
                agg[s] += nq / row[s]["qps"]    # batch seconds, summed
        # the wall-clock bar: compressed traversal must beat f32 on the
        # aggregate across scenarios (per-scenario splits are within the
        # single-core container's timing noise; the aggregate is not)
        assert agg["pq"] <= agg["f32"], (
            f"PQ graph route slower than f32 on aggregate: "
            f"{agg['pq']*1e3:.1f}ms vs {agg['f32']*1e3:.1f}ms")
        print(f"# SMOKE OK: PQ wall-clock {agg['f32']/agg['pq']:.2f}x f32 "
              f"at <=1pt recall gap, bytes/hop {ratio:.0f}x smaller, "
              f"{compiles} compiles <= {compile_budget}")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: small corpus + scorer acceptance asserts")
    ap.add_argument("--full", action="store_true",
                    help="also run the full QPS-recall scenario sweep")
    args = ap.parse_args()
    if args.full:
        print(run(quick=args.quick))
    print(run_scorers(quick=args.quick, smoke=args.smoke))


if __name__ == "__main__":
    main()
