"""Paper Figs. 4/5/8/9: QPS-recall tradeoff per filtering scenario.

Methods: FAVOR (full selector pipeline), FAVOR-graph (exclusion-distance
search forced), RSF (result-set-filtering baseline, same batching), PreFBF
(brute force).  ef sweeps the tradeoff curve.  Paper claim mirrored: FAVOR
gives >= 1.3x the best filter-agnostic baseline's QPS at Recall@10 ~ 95%.

``run_scorers`` (CLI: ``python -m benchmarks.bench_qps_recall --smoke``)
sweeps the graph route's pluggable scorer layer (core.scoring): the same
traversal with f32 vs PQ-ADC neighbor scoring, reporting QPS, recall@10 and
the bytes-gathered-per-hop reduction.  The summary lands in the
``graph_scorers`` section of bench_out/BENCH_serve.json; --smoke asserts
the acceptance bar (PQ recall within 1pt of f32, >= 8x fewer bytes/hop).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import (BuildSpec, ExactScorer, FavorIndex, HnswParams,
                        PqAdcScorer, QuantSpec, SearchConfig,
                        compile_filter, paper_filters, stack_programs)
from repro.core import filters as F
from repro.core import refimpl, rsf_graph_search
from repro.data import synthetic
from . import common as C


def rsf_qps(fi, queries, flt, k, ef, repeats=3):
    progs = {kk: jnp.asarray(v) for kk, v in stack_programs(
        [compile_filter(flt, fi.schema)] * len(queries)).items()}
    cfg = SearchConfig(k=k, ef=ef)
    qj = jnp.asarray(queries)
    out = rsf_graph_search(fi.g, qj, progs, cfg)  # compile
    import time
    best = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = rsf_graph_search(fi.g, qj, progs, cfg)
        out["ids"].block_until_ready()
        best = max(best, len(queries) / (time.perf_counter() - t0))
    return np.asarray(out["ids"]), best


def run(quick: bool = False):
    fi = C.get_index()
    vecs, attrs, schema, queries = C.get_dataset()
    scenarios = paper_filters(schema)
    efs = [24, 48, 96, 192] if not quick else [48, 96]
    k = 10
    csv = C.Csv("qps_recall.csv",
                ["scenario", "method", "ef", "qps", "recall_at_10"])
    summary = {}
    for name, flt in scenarios.items():
        prog = compile_filter(flt, schema)
        mask = F.eval_program(prog, attrs.ints, attrs.floats)
        truth = C.ground_truth(vecs, mask, queries, k)
        best_at_95 = {}
        for ef in efs:
            res, qps = C.timed_search(fi, queries, flt, k=k, ef=ef)
            rec = C.mean_recall(res.ids, truth, k)
            csv.add(name, "favor", ef, qps, rec)
            best_at_95.setdefault("favor", []).append((rec, qps))

            res_g, qps_g = C.timed_search(fi, queries, flt, k=k, ef=ef,
                                          force="graph")
            rec_g = C.mean_recall(res_g.ids, truth, k)
            csv.add(name, "favor_graph", ef, qps_g, rec_g)

            ids_r, qps_r = rsf_qps(fi, queries, flt, k, ef)
            rec_r = C.mean_recall(ids_r, truth, k)
            csv.add(name, "rsf", ef, qps_r, rec_r)
            best_at_95.setdefault("rsf", []).append((rec_r, qps_r))
        res_b, qps_b = C.timed_search(fi, queries, flt, k=k, ef=efs[-1],
                                      force="brute")
        csv.add(name, "prefbf", 0, qps_b, C.mean_recall(res_b.ids, truth, k))

        def at95(pairs):
            ok = [q for r, q in pairs if r >= 0.95]
            return max(ok) if ok else 0.0
        summary[name] = (at95(best_at_95["favor"]), at95(best_at_95["rsf"]))
    csv.write()
    print("\n# FAVOR vs RSF QPS at Recall@10>=95% (paper: 1.3-5x):")
    for name, (f, r) in summary.items():
        ratio = f / r if r else float("inf")
        print(f"#   {name:15s} favor={f:8.1f} rsf={r:8.1f} ratio={ratio:.2f}x")
    return csv.path


def run_scorers(quick: bool = False, smoke: bool = False) -> str:
    """Graph-route scorer sweep: f32 vs PQ-ADC traversal, same exclusion
    machinery, identical batching.  The headline is the paper-motivated
    trade: per-hop neighbor gathers shrink from 4*d to M bytes while the
    exact re-rank keeps recall@10 within 1pt."""
    n = 4096 if smoke else (8192 if quick else C.N)
    nq = 48 if smoke else C.NQ
    efs = [96] if smoke else ([48, 96] if quick else [48, 96, 192])
    k = 10
    vecs, attrs, schema = synthetic.make_paper_dataset(n, C.DIM, seed=C.SEED)
    queries = synthetic.make_queries(nq, C.DIM, dataset_seed=C.SEED)
    fi = FavorIndex.build(
        vecs, attrs, HnswParams(M=12, efc=60, seed=C.SEED),
        BuildSpec(quant=QuantSpec(m=8, nbits=8, train_iters=10)))
    bytes_f32 = ExactScorer().bytes_per_row(fi.g)
    bytes_pq = PqAdcScorer().bytes_per_row(fi.g)
    ratio = bytes_f32 / bytes_pq

    scenarios = ["equality_bool", "range_50", "logic"]
    csv = C.Csv("graph_scorers.csv",
                ["scenario", "scorer", "ef", "qps", "recall_at_10",
                 "bytes_per_row"])
    summary = {"n": n, "dim": C.DIM, "bytes_per_row_f32": bytes_f32,
               "bytes_per_row_pq": bytes_pq, "bytes_per_hop_ratio": ratio,
               "scenarios": {}}
    for name in scenarios:
        flt = paper_filters(schema)[name]
        mask = F.eval_program(compile_filter(flt, schema), attrs.ints,
                              attrs.floats)
        truth = [refimpl.bruteforce_filtered(vecs, mask, q, k)[0]
                 for q in queries]
        row = {}
        for scorer, gq in (("f32", None), ("pq", PqAdcScorer().kind)):
            best = (0.0, 0.0)           # (recall, qps) at the largest ef
            for ef in efs:
                # re-rank deep (top 8k of ef TD candidates): the exact pass
                # reads ~ef f32 rows per query, noise next to the per-hop
                # scan it replaces, and it is what holds the <=1pt bar
                res, qps = C.timed_search(fi, queries, flt, k=k, ef=ef,
                                          force="graph", graph_quant=gq,
                                          graph_rerank=8 if gq else None)
                rec = float(np.mean([refimpl.recall_at_k(res.ids[i],
                                                         truth[i], k)
                                     for i in range(nq)]))
                csv.add(name, scorer, ef, qps,
                        rec, bytes_pq if gq else bytes_f32)
                best = (rec, qps)
            row[scorer] = {"recall_at_10": best[0], "qps": best[1]}
        summary["scenarios"][name] = row
    csv.write()
    path = C.update_bench_json("graph_scorers", summary)
    print(f"# bytes gathered per hop: f32={bytes_f32}B "
          f"pq={bytes_pq}B ({ratio:.0f}x less)")
    if smoke:
        assert ratio >= 8, f"bytes-per-hop reduction {ratio:.1f}x < 8x"
        for name, row in summary["scenarios"].items():
            gap = row["f32"]["recall_at_10"] - row["pq"]["recall_at_10"]
            assert gap <= 0.01, (
                f"{name}: PQ graph recall {row['pq']['recall_at_10']:.3f} "
                f"more than 1pt under f32 {row['f32']['recall_at_10']:.3f}")
        print("# SMOKE OK: PQ graph recall within 1pt of f32, "
              f"bytes/hop {ratio:.0f}x smaller")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: small corpus + scorer acceptance asserts")
    ap.add_argument("--full", action="store_true",
                    help="also run the full QPS-recall scenario sweep")
    args = ap.parse_args()
    if args.full:
        print(run(quick=args.quick))
    print(run_scorers(quick=args.quick, smoke=args.smoke))


if __name__ == "__main__":
    main()
