"""Paper Fig. 7: QPS across varying selectivity at fixed recall target.

Range filters of decreasing width drive p from ~30% down to ~0.2%; we report
graph-route QPS, brute-route QPS and the selector's routed QPS, validating:
  * the route curves cross inside 1% < p < 3% (paper section 6.2.3),
  * the selector tracks the upper envelope (stable under low selectivity).
"""
from __future__ import annotations

import numpy as np

from repro.core import compile_filter
from repro.core import filters as F
from . import common as C


SELECTIVITIES = [0.002, 0.005, 0.01, 0.02, 0.03, 0.05, 0.1, 0.3]


def run(quick: bool = False):
    fi = C.get_index()
    vecs, attrs, schema, queries = C.get_dataset()
    sels = SELECTIVITIES if not quick else [0.005, 0.02, 0.1]
    k, ef = 10, 96
    csv = C.Csv("selectivity.csv",
                ["p_target", "p_true", "method", "qps", "recall_at_10"])
    cross = []
    for p in sels:
        flt = F.Range("f0", 50.0 - 50.0 * p, 50.0 + 50.0 * p)  # width 100p
        prog = compile_filter(flt, schema)
        mask = F.eval_program(prog, attrs.ints, attrs.floats)
        p_true = float(mask.mean())
        truth = C.ground_truth(vecs, mask, queries, k)
        rows = {}
        for method, force in [("graph", "graph"), ("brute", "brute"),
                              ("favor", None)]:
            res, qps = C.timed_search(fi, queries, flt, k=k, ef=ef, force=force)
            rec = C.mean_recall(res.ids, truth, k)
            csv.add(p, p_true, method, qps, rec)
            rows[method] = qps
        cross.append((p_true, rows["graph"], rows["brute"], rows["favor"]))
    csv.write()
    print("\n# selector crossover check (brute faster below ~1%, graph above):")
    for p, g, b, f in cross:
        pick = "brute" if b > g else "graph"
        print(f"#   p={p:7.4f} graph={g:8.1f} brute={b:8.1f} "
              f"favor={f:8.1f} faster={pick}")
    return csv.path


if __name__ == "__main__":
    run()
