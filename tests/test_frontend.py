"""Async multi-tenant front-end semantics (repro.serving.frontend).

Acceptance bars from the PR-7 issue:
  * coalescing preserves bit-identical results vs. one-shot batches
  * shed requests never reach the backend
  * per-tenant cache isolation (A's semantic/candidate hits never serve B)
  * clean cancellation of in-flight futures on shutdown
plus the satellite contracts: the engine bucket ladder unified on
BatchSpec, deadline-aware ``run()`` and the ``drain()`` helper.

No pytest-asyncio: every async scenario runs through ``asyncio.run`` so the
dev extras stay unchanged.
"""
import asyncio
import time

import numpy as np
import pytest

from repro.cache import CachingBackend
from repro.core import (BatchSpec, CacheSpec, FrontEndSpec, LocalBackend,
                        SearchOptions, TenantSpec, router)
from repro.core import filters as F
from repro.serving import FrontEnd, Overloaded, ServeEngine
from repro.serving.engine import _bucket
from repro.serving.frontend import TokenBucket, WeightedFairScheduler
from repro.serving.frontend.admission import TenantState

OPTS = SearchOptions(k=5, ef=48, batch=BatchSpec(min_bucket=4, max_bucket=16))


def _queries(n, d, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _flt(schema):
    return F.paper_filters(schema)["equality_bool"]


# ---------------------------------------------------------------------------
# Spec validation
# ---------------------------------------------------------------------------
def test_tenant_spec_validation():
    TenantSpec(weight=2.0, rate_qps=100.0, burst=4, queue_cap=8,
               deadline_ms=50.0)
    with pytest.raises(ValueError, match="weight"):
        TenantSpec(weight=0.0)
    with pytest.raises(ValueError, match="rate_qps"):
        TenantSpec(rate_qps=-1.0)
    with pytest.raises(ValueError, match="burst"):
        TenantSpec(burst=0)
    with pytest.raises(ValueError, match="queue_cap"):
        TenantSpec(queue_cap=0)
    with pytest.raises(ValueError, match="deadline_ms"):
        TenantSpec(deadline_ms=0.0)


def test_frontend_spec_validation_and_tenant_lookup():
    spec = FrontEndSpec(coalesce_ms=5.0,
                        tenants={"b": TenantSpec(weight=2.0),
                                 "a": TenantSpec(weight=3.0)})
    # dict canonicalizes to a sorted tuple (frozen, deterministic)
    assert spec.tenants[0][0] == "a"
    assert spec.tenant("b").weight == 2.0
    assert spec.tenant("nope") == spec.default_tenant
    with pytest.raises(ValueError, match="coalesce_ms"):
        FrontEndSpec(coalesce_ms=-1.0)
    with pytest.raises(ValueError, match="coalesce_target"):
        FrontEndSpec(coalesce_target=0)
    with pytest.raises(TypeError, match="tenants"):
        FrontEndSpec(tenants={"a": 1.0})
    with pytest.raises(TypeError, match="default_tenant"):
        FrontEndSpec(default_tenant="gold")


# ---------------------------------------------------------------------------
# Admission primitives (no engine, fake clocks)
# ---------------------------------------------------------------------------
def test_token_bucket_rate_and_burst():
    t = [0.0]
    b = TokenBucket(10.0, 2, clock=lambda: t[0])
    assert b.try_take() and b.try_take()       # burst of 2
    assert not b.try_take()                    # empty
    assert b.retry_after_s() == pytest.approx(0.1)
    t[0] += 0.1                                # one token refilled
    assert b.try_take() and not b.try_take()
    t[0] += 10.0                               # refill clamps at burst
    assert b.tokens <= 2.0
    assert b.try_take() and b.try_take() and not b.try_take()
    with pytest.raises(ValueError, match="rate_qps"):
        TokenBucket(0.0, 2)


def test_weighted_fair_dequeue_shares_and_no_starvation():
    sched = WeightedFairScheduler()
    heavy = TenantState("heavy", TenantSpec(weight=3.0), 1, None)
    light = TenantState("light", TenantSpec(weight=1.0), 2, None)
    for st in (heavy, light):
        for i in range(40):
            sched.on_enqueue(st)
            st.queue.append(i)
    order = []
    for _ in range(40):
        st = sched.pick([heavy, light])
        st.queue.popleft()
        sched.on_dequeue(st)
        order.append(st.name)
    # ~3:1 split over the first 40 slots, and the light tenant is never
    # starved out of a window
    assert 25 <= order.count("heavy") <= 35
    assert order.count("light") >= 5
    assert "light" in order[:8]


# ---------------------------------------------------------------------------
# Engine satellites: unified ladder, deadline-aware run(), drain()
# ---------------------------------------------------------------------------
def test_bucket_unified_with_batchspec_ladder():
    # the legacy helper and BatchSpec agree on every size: one ladder
    for n in (1, 7, 8, 9, 100, 512, 513, 2000):
        assert _bucket(n) == BatchSpec().bucket_for(n)
    spec = BatchSpec(min_bucket=4, max_bucket=8)
    assert _bucket(3, spec) == 4 and _bucket(9, spec) == 16


def test_engine_pad_spec_follows_opts(small_index):
    eng = ServeEngine(LocalBackend(small_index), OPTS)
    assert eng.pad_spec is OPTS.batch
    eng2 = ServeEngine(LocalBackend(small_index), SearchOptions(k=5, ef=48))
    assert eng2.pad_spec == BatchSpec()       # default ladder == old hardcode


def test_run_waits_out_straggler_deadline(small_index, small_dataset):
    _, _, schema = small_dataset
    eng = ServeEngine(LocalBackend(small_index), OPTS, max_batch=8,
                      max_wait_ms=120.0)
    q = _queries(1, 16, seed=3)[0]
    eng.submit(q, _flt(schema))
    eng.drain()                               # absorb compile time first
    eng.submit(q, _flt(schema))
    t0 = time.perf_counter()
    out = eng.run()
    waited = time.perf_counter() - t0
    assert len(out) == 1 and not eng.queue
    assert waited >= 0.1                      # honored the coalescing window


def test_drain_forces_immediately(small_index, small_dataset):
    _, _, schema = small_dataset
    eng = ServeEngine(LocalBackend(small_index), OPTS, max_batch=8,
                      max_wait_ms=1e6)
    q = _queries(1, 16, seed=4)[0]
    eng.submit(q, _flt(schema))
    out = eng.drain()                         # would hang under run()
    assert len(out) == 1 and not eng.queue


# ---------------------------------------------------------------------------
# Front-end: coalescing parity + pad reduction
# ---------------------------------------------------------------------------
def test_coalescing_bit_identical_to_one_shot_batch(small_index,
                                                    small_dataset):
    _, _, schema = small_dataset
    backend = LocalBackend(small_index)
    flts = list(F.paper_filters(schema).values())[:4]
    qs = _queries(8, 16, seed=11)
    reqs = [(qs[i], flts[i % len(flts)]) for i in range(8)]

    ref = router.execute(backend, qs, [f for _, f in reqs], OPTS)

    async def main():
        eng = ServeEngine(backend, OPTS, max_batch=16)
        fe = FrontEnd(eng, FrontEndSpec(coalesce_ms=500.0, coalesce_target=8))
        outs = await asyncio.gather(*[fe.submit(q, f) for q, f in reqs])
        st = fe.stats
        await fe.close()
        return outs, st

    outs, st = asyncio.run(main())
    # one coalesced dispatch, results bit-identical to the one-shot batch
    assert st["coalesce"]["dispatches"] == 1
    assert st["coalesce"]["mean_batch"] == 8.0
    for i, r in enumerate(outs):
        assert np.array_equal(r.ids, ref.ids[i])
        assert np.array_equal(r.dists, ref.dists[i])
        assert r.route == ("brute" if ref.routed_brute[i] else "graph")


def test_coalescing_cuts_pad_overhead(small_index, small_dataset):
    """The acceptance direction: at one-at-a-time arrival, an uncoalesced
    front-end pads every single-row dispatch to the smallest bucket while
    a coalesced one fills the bucket first."""
    _, _, schema = small_dataset
    flt = _flt(schema)
    qs = _queries(4, 16, seed=12)

    async def drive(spec):
        eng = ServeEngine(LocalBackend(small_index), OPTS, max_batch=16)
        eng.warmup()
        fe = FrontEnd(eng, spec)
        if spec.coalesce_ms:
            await asyncio.gather(*[fe.submit(q, flt) for q in qs])
        else:
            for q in qs:                     # arrivals one dispatch apart
                await fe.submit(q, flt)
        pad = fe.stats["engine"]["batching"]["pad_overhead"]
        await fe.close()
        return pad, fe

    pad_un, _ = asyncio.run(drive(FrontEndSpec(coalesce_ms=0.0)))
    pad_co, _ = asyncio.run(drive(FrontEndSpec(coalesce_ms=500.0,
                                               coalesce_target=4)))
    assert pad_un >= 0.7                      # 1 real row per 4-row bucket
    assert pad_co < pad_un


# ---------------------------------------------------------------------------
# Admission control: shed at the door, never the backend
# ---------------------------------------------------------------------------
def test_shed_requests_never_reach_backend(small_index, small_dataset):
    _, _, schema = small_dataset
    flt = _flt(schema)
    qs = _queries(4, 16, seed=13)

    async def main():
        eng = ServeEngine(LocalBackend(small_index), OPTS, max_batch=16)
        spec = FrontEndSpec(coalesce_ms=1e4, coalesce_target=64,
                            tenants={"t": TenantSpec(queue_cap=1)})
        fe = FrontEnd(eng, spec)
        t1 = asyncio.create_task(fe.submit(qs[0], flt, tenant="t"))
        await asyncio.sleep(0.02)             # t1 is queued (held window)
        shed = []
        for i in (1, 2):
            with pytest.raises(Overloaded) as e:
                await fe.submit(qs[i], flt, tenant="t")
            shed.append(e.value.reason)
        await fe.close(drain=True)            # serves only the queued one
        return await t1, shed, fe.stats

    r1, shed, st = asyncio.run(main())
    assert shed == ["queue_full", "queue_full"]
    t = st["tenants"]["t"]
    assert t["served"] == 1 and t["shed"]["queue_full"] == 2
    assert t["shed_total"] == 2
    # the backend saw exactly the served request, nothing shed
    assert st["engine"]["graph"] + st["engine"]["brute"] == 1
    assert r1.ids.shape == (5,)


def test_rate_limit_shed_with_retry_after(small_index, small_dataset):
    _, _, schema = small_dataset
    flt = _flt(schema)
    q = _queries(1, 16, seed=14)[0]

    async def main():
        eng = ServeEngine(LocalBackend(small_index), OPTS, max_batch=16)
        spec = FrontEndSpec(
            tenants={"t": TenantSpec(rate_qps=0.001, burst=1)})
        fe = FrontEnd(eng, spec)
        r = await fe.submit(q, flt, tenant="t")
        with pytest.raises(Overloaded) as e:
            await fe.submit(q, flt, tenant="t")
        await fe.close()
        return r, e.value

    r, err = asyncio.run(main())
    assert err.reason == "rate_limit" and err.tenant == "t"
    assert err.retry_after_ms is not None and err.retry_after_ms > 0
    assert r.ids.shape == (5,)


def test_admission_off_is_unbounded_fifo(small_index, small_dataset):
    _, _, schema = small_dataset
    flt = _flt(schema)
    qs = _queries(4, 16, seed=15)

    async def main():
        eng = ServeEngine(LocalBackend(small_index), OPTS, max_batch=16)
        spec = FrontEndSpec(admission=False, fair=False, coalesce_ms=200.0,
                            coalesce_target=4,
                            tenants={"t": TenantSpec(queue_cap=1,
                                                     rate_qps=0.001)})
        fe = FrontEnd(eng, spec)
        outs = await asyncio.gather(*[fe.submit(q, flt, tenant="t")
                                      for q in qs])
        st = fe.stats
        await fe.close()
        return outs, st

    outs, st = asyncio.run(main())
    assert len(outs) == 4
    assert st["tenants"]["t"]["shed_total"] == 0


def test_deadline_shed(small_index, small_dataset):
    _, _, schema = small_dataset
    flt = _flt(schema)
    q = _queries(1, 16, seed=16)[0]

    async def main():
        eng = ServeEngine(LocalBackend(small_index), OPTS, max_batch=16)
        fe = FrontEnd(eng, FrontEndSpec(coalesce_ms=1e4, coalesce_target=64))
        task = asyncio.create_task(fe.submit(q, flt, deadline_ms=5.0))
        await asyncio.sleep(0.05)             # deadline lapses while held
        with pytest.raises(Overloaded) as e:
            await task
        st = fe.stats
        await fe.close()
        return e.value, st

    err, st = asyncio.run(main())
    assert err.reason == "deadline"
    assert st["tenants"]["default"]["shed"]["deadline"] == 1
    assert st["engine"]["graph"] + st["engine"]["brute"] == 0


# ---------------------------------------------------------------------------
# Tenant-scoped caches: isolation
# ---------------------------------------------------------------------------
def test_semantic_cache_isolated_per_tenant(small_index, small_dataset):
    _, _, schema = small_dataset
    flt = _flt(schema)
    q = _queries(1, 16, seed=17)[0]

    async def main():
        cb = CachingBackend(LocalBackend(small_index), CacheSpec())
        eng = ServeEngine(cb, OPTS, max_batch=16)
        fe = FrontEnd(eng, FrontEndSpec())
        ra1 = await fe.submit(q, flt, tenant="A")
        ra2 = await fe.submit(q, flt, tenant="A")   # exact repeat: A hits
        rb1 = await fe.submit(q, flt, tenant="B")   # B must NOT see A's entry
        st = fe.stats
        await fe.close()
        return (ra1, ra2, rb1), st

    (ra1, ra2, rb1), st = asyncio.run(main())
    a, b = st["tenants"]["A"], st["tenants"]["B"]
    assert a["semantic"]["hits"] == 1 and a["semantic"]["misses"] == 1
    assert b["semantic"]["hits"] == 0 and b["semantic"]["misses"] == 1
    assert a["scope"] != b["scope"] != 0
    # isolation never changes results: all three are the same exact answer
    assert np.array_equal(ra1.ids, ra2.ids)
    assert np.array_equal(ra1.ids, rb1.ids)


def test_candidate_cache_isolated_per_tenant(small_index, small_dataset):
    _, _, schema = small_dataset
    # a filter the selector sends brute; p_max=1.0 admits it regardless
    flt = F.And(F.Equality("i0", 3), F.Range("f0", 10.0, 12.0))
    qs = _queries(3, 16, seed=18)

    async def main():
        cb = CachingBackend(LocalBackend(small_index),
                            CacheSpec(candidate_p_max=1.0, semantic=False))
        eng = ServeEngine(cb, OPTS.with_(force="brute"), max_batch=16)
        fe = FrontEnd(eng, FrontEndSpec())
        for i in range(3):                    # miss, miss(admit), hit for A
            await fe.submit(qs[i], flt, tenant="A")
        await fe.submit(qs[0], flt, tenant="B")   # B: isolated -> miss
        st = fe.stats
        await fe.close()
        return st

    st = asyncio.run(main())
    a, b = st["tenants"]["A"], st["tenants"]["B"]
    assert a["candidates"]["hits"] == 1 and a["candidates"]["misses"] == 2
    assert b["candidates"]["hits"] == 0 and b["candidates"]["misses"] == 1


def test_unscoped_engine_traffic_stays_scope_zero(small_index,
                                                  small_dataset):
    """Direct ServeEngine.submit (no front-end) records under scope 0 --
    the tenant scopes never leak into unscoped traffic."""
    _, _, schema = small_dataset
    flt = _flt(schema)
    q = _queries(1, 16, seed=19)[0]
    cb = CachingBackend(LocalBackend(small_index), CacheSpec())
    eng = ServeEngine(cb, OPTS, max_batch=16)
    eng.submit(q, flt)
    eng.drain()
    eng.submit(q, flt)
    out = eng.drain()
    assert len(out) == 1
    sem = cb.cache_stats()["semantic"]["by_scope"]
    assert set(sem) == {0} and sem[0]["hits"] == 1


# ---------------------------------------------------------------------------
# Shutdown semantics
# ---------------------------------------------------------------------------
def test_close_cancels_in_flight_futures(small_index, small_dataset):
    _, _, schema = small_dataset
    flt = _flt(schema)
    qs = _queries(3, 16, seed=20)

    async def main():
        eng = ServeEngine(LocalBackend(small_index), OPTS, max_batch=16)
        fe = FrontEnd(eng, FrontEndSpec(coalesce_ms=1e4, coalesce_target=64))
        tasks = [asyncio.create_task(fe.submit(q, flt)) for q in qs]
        await asyncio.sleep(0.02)             # all three queued, held
        await fe.close(drain=False)
        cancelled = 0
        for t in tasks:
            try:
                await t
            except asyncio.CancelledError:
                cancelled += 1
        # closed front-end rejects new work with a structured response
        with pytest.raises(Overloaded, match="closed"):
            await fe.submit(qs[0], flt)
        return cancelled, fe.stats

    cancelled, st = asyncio.run(main())
    assert cancelled == 3
    assert st["engine"]["graph"] + st["engine"]["brute"] == 0


def test_close_drain_serves_queued(small_index, small_dataset):
    _, _, schema = small_dataset
    flt = _flt(schema)
    qs = _queries(3, 16, seed=21)

    async def main():
        eng = ServeEngine(LocalBackend(small_index), OPTS, max_batch=16)
        fe = FrontEnd(eng, FrontEndSpec(coalesce_ms=1e4, coalesce_target=64))
        tasks = [asyncio.create_task(fe.submit(q, flt)) for q in qs]
        await asyncio.sleep(0.02)
        await fe.close(drain=True)
        return await asyncio.gather(*tasks)

    outs = asyncio.run(main())
    assert len(outs) == 3 and all(r.ids.shape == (5,) for r in outs)


# ---------------------------------------------------------------------------
# Multiple logical front-ends over one backend
# ---------------------------------------------------------------------------
def test_two_frontends_share_one_backend(small_index, small_dataset):
    _, _, schema = small_dataset
    flt = _flt(schema)
    q = _queries(1, 16, seed=22)[0]

    async def main():
        cb = CachingBackend(LocalBackend(small_index), CacheSpec())
        fe1 = FrontEnd(ServeEngine(cb, OPTS, max_batch=16), FrontEndSpec())
        fe2 = FrontEnd(ServeEngine(cb, OPTS, max_batch=16), FrontEndSpec())
        await fe1.submit(q, flt, tenant="shared")
        r2 = await fe2.submit(q, flt, tenant="shared")
        st1, st2 = fe1.stats, fe2.stats
        await fe1.close()
        await fe2.close()
        return r2, st1, st2

    r2, st1, st2 = asyncio.run(main())
    # the tenant name interns to ONE scope on the shared backend, so the
    # second front-end's identical request is a semantic hit
    assert st1["tenants"]["shared"]["scope"] == \
        st2["tenants"]["shared"]["scope"]
    assert st2["tenants"]["shared"]["semantic"]["hits"] == 1
    assert r2.ids.shape == (5,)
