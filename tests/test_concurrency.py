"""Concurrent serving: pipelined step dispatch, background incremental
merge, and the snapshot discipline that keeps them bit-exact.

The contract under test (PR acceptance):

  * every response produced while search/upsert/delete/background-merge
    threads interleave is bit-identical to a single-threaded replay of the
    same component-epoch state -- torn reads never surface as "almost
    right" results;
  * a background merge never blocks ``step()`` for more than one build
    wave: steps keep completing while the merge builds, and the only
    lock-held slice (the commit swap) is a small fraction of the merge;
  * the pipelined front-end (``FrontEndSpec.parallel_steps > 1``) returns
    results bit-identical to the serialized baseline, resolving futures in
    dispatch order, and ``close()`` joins in-flight device work instead of
    racing it.

Everything here runs single-process with real threads (the engine lock,
executor slots and merge worker are the production code paths).
"""
import asyncio
import threading
import time

import numpy as np
import pytest

from repro.core import (FavorIndex, HnswParams, LocalBackend, SearchOptions,
                        paper_schema, random_attributes, router)
from repro.core import filters as F
from repro.core.options import FrontEndSpec
from repro.serving import FrontEnd, ServeEngine
from repro.serving.merge import MergeController

OPTS = SearchOptions(k=8, ef=64)
PARAMS = HnswParams(M=8, efc=48, seed=3)


@pytest.fixture(scope="module")
def ds():
    rng = np.random.default_rng(33)
    n, d = 768, 16
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    schema = paper_schema()
    attrs = random_attributes(schema, n, seed=17)
    return vecs, attrs, schema


def _fresh(ds, **engine_kw):
    vecs, attrs, _ = ds
    be = LocalBackend(FavorIndex.build(vecs, attrs, PARAMS))
    return ServeEngine(be, OPTS, **engine_kw)


def _queries(ds, n=6, seed=91):
    vecs, attrs, schema = ds
    rng = np.random.default_rng(seed)
    qs = rng.normal(size=(n, vecs.shape[1])).astype(np.float32)
    flts = [F.Equality("i0", 3) if i % 2 else F.TrueFilter()
            for i in range(n)]
    return qs, flts


def _serve_one(eng, q, flt):
    """One single-query step, atomically: submit + host dispatch under the
    engine lock (so a concurrent thread can't batch-steal the row), device
    sync outside it -- the same discipline FrontEnd._serve uses."""
    with eng._lock:
        rid = eng.submit(q, flt)
        step = eng.begin_batch(force=True)
    (r,) = [r for r in eng.finish_batch(step) if r.rid == rid]
    return r


def _delta_rows(ds, count, seed=55):
    vecs, attrs, schema = ds
    rng = np.random.default_rng(seed)
    col = schema.int_index("i0")
    row = int(np.nonzero(attrs.ints[:, col] == 3)[0][0])
    return (rng.normal(size=(count, vecs.shape[1])).astype(np.float32),
            np.tile(attrs.ints[row], (count, 1)),
            np.tile(attrs.floats[row], (count, 1)))


# ---------------------------------------------------------------------------
# router defer mode: the host/device split is pure plumbing
# ---------------------------------------------------------------------------
def test_deferred_execute_bit_identical_and_idempotent(ds):
    eng = _fresh(ds)
    qs, flts = _queries(ds, n=4)
    sync = router.execute(eng.backend, qs, flts, OPTS)
    pend = router.execute(eng.backend, qs, flts, OPTS, defer=True)
    assert isinstance(pend, router.PendingExecution)
    res = pend.finish()
    assert pend.finish() is res                  # idempotent
    np.testing.assert_array_equal(res.ids, sync.ids)
    np.testing.assert_array_equal(res.dists, sync.dists)
    np.testing.assert_array_equal(res.routed_brute, sync.routed_brute)


# ---------------------------------------------------------------------------
# threaded stress: search + upsert + delete + background merge
# ---------------------------------------------------------------------------
def test_threaded_stress_bit_identical_to_epoch_replay(ds):
    """Concurrent responses must each bit-match the single-threaded replay
    of one epoch-consistent snapshot (S0 pre-upsert, S1 post-upsert, S2
    post-delete, S3 post-merge) -- never a torn in-between."""
    vecs, _, _ = ds
    qs, flts = _queries(ds)
    uv, ui, uf = _delta_rows(ds, 24)

    # single-threaded replay on an identical build: capture per-state
    # ground truth.  Ops and build are seed-deterministic, so the
    # concurrent engine walks through exactly these four states.
    rep = _fresh(ds)
    expected = {}

    def snap(name):
        expected[name] = [_serve_one(rep, qs[i], flts[i])
                          for i in range(len(qs))]

    snap("S0")
    rep_ids = rep.upsert(uv, ui, uf)
    snap("S1")
    rep.delete([int(rep_ids[0]), int(rep_ids[1]), 5])
    snap("S2")
    rep.merge()
    snap("S3")

    eng = _fresh(ds, merge_background=True)     # worker idles: no frac set
    stop = threading.Event()
    errors = []
    checked = np.zeros(4, np.int64)             # responses matched per state

    def matches(i, r, name):
        e = expected[name][i]
        return (np.array_equal(r.ids, e.ids)
                and np.array_equal(r.dists, e.dists))

    def searcher(seed):
        rng = np.random.default_rng(seed)
        try:
            while not stop.is_set():
                i = int(rng.integers(len(qs)))
                r = _serve_one(eng, qs[i], flts[i])
                for s, name in enumerate(("S0", "S1", "S2", "S3")):
                    if matches(i, r, name):
                        checked[s] += 1
                        break
                else:
                    errors.append(
                        f"query {i}: ids {r.ids.tolist()} match no "
                        f"epoch-consistent state")
                    stop.set()
        except Exception as e:                  # pragma: no cover
            errors.append(repr(e))
            stop.set()

    threads = [threading.Thread(target=searcher, args=(100 + t,))
               for t in range(2)]
    for t in threads:
        t.start()
    try:
        time.sleep(0.15)
        ids = eng.upsert(uv, ui, uf)
        np.testing.assert_array_equal(ids, rep_ids)   # positional parity
        time.sleep(0.15)
        assert eng.delete([int(ids[0]), int(ids[1]), 5]) == 3
        time.sleep(0.15)
        # background-style merge while searchers keep serving: the real
        # prepare (no lock) / epoch-guarded commit (engine lock) path
        out = eng._merge_ctl.merge_once()
        assert out is not None and out["merged_slots"] == 24
        time.sleep(0.15)
    finally:
        stop.set()
        for t in threads:
            t.join()
        eng.close()
    assert not errors, errors[:3]
    # the run actually crossed the states (not all S0) and finished merged
    assert checked.sum() > 0 and checked[3] > 0, checked.tolist()
    for i in range(len(qs)):
        r = _serve_one(eng, qs[i], flts[i])
        assert matches(i, r, "S3"), f"post-merge query {i} diverged"
    st = eng.stats["mutations"]
    assert st["delta_rows"] == 0 and st["base_rows"] == vecs.shape[0] + 24


# ---------------------------------------------------------------------------
# merge-never-stalls: steps keep completing while the merge builds
# ---------------------------------------------------------------------------
def test_background_merge_never_blocks_steps(ds):
    eng = _fresh(ds, merge_background=True, merge_delta_frac=0.01)
    # small waves -> many pacing points: the build phase spans many device
    # dispatches while serving threads keep stepping through the gaps
    eng._merge_ctl.stop()
    ctl = eng._merge_ctl = MergeController(eng, wave=16, poll_s=0.005)
    qs, flts = _queries(ds, n=4)
    uv, ui, uf = _delta_rows(ds, 96)

    _serve_one(eng, qs[0], flts[0])             # warm the serve path
    eng.upsert(uv, ui, uf)                      # 96/768 = 12.5% > 1%
    t_start = time.perf_counter()
    during, latencies = 0, []
    # first step's finish pokes the controller; keep stepping until the
    # merge commits (watchdog-bounded by the suite timeout)
    while ctl.merges == 0 and time.perf_counter() - t_start < 120.0:
        active = eng._m_merge_active.value() > 0
        t0 = time.perf_counter()
        _serve_one(eng, qs[during % len(qs)], flts[during % len(qs)])
        lat = time.perf_counter() - t0
        if active and eng._m_merge_active.value() > 0:
            during += 1
            latencies.append(lat)
    eng.close()
    assert ctl.merges == 1, "background merge never committed"
    merge_s = eng._m_merge_s.sum()
    stall_s = eng._m_merge_stall.sum()
    assert eng._m_merge_s.count() == 1 and merge_s > 0.0
    # the build overlapped serving: whole steps completed strictly inside
    # the merge window, each far shorter than the merge itself
    assert during >= 1, "no step completed while the merge was building"
    assert max(latencies) < merge_s, (latencies, merge_s)
    # the lock-held slice (commit swap) is a fraction of the merge, not
    # the merge: "one wave" of stall, not seconds of rebuild
    assert stall_s < merge_s
    st = eng.stats["mutations"]
    assert st["auto_merges"] == 1 and st["delta_rows"] == 0


def test_merge_commit_epoch_guard_rejects_stale_prepare(ds):
    be = _fresh(ds).backend
    uv, ui, uf = _delta_rows(ds, 12)
    be.upsert(uv, ui, uf)
    prep = be.merge_prepare()
    assert prep is not None
    be.merge()                   # foreground merge moves the graph epoch
    assert be.merge_commit(prep) is None        # stale build thrown away
    assert be.live_stats()["delta_rows"] == 0


# ---------------------------------------------------------------------------
# pipelined front-end: bit-identity, ordering, close/drain
# ---------------------------------------------------------------------------
def _drive_frontend(ds, spec, n=24):
    eng = _fresh(ds)
    fe = FrontEnd(eng, spec)
    qs, flts = _queries(ds, n=n, seed=7)

    async def main():
        futs = [asyncio.ensure_future(
                    fe.submit(qs[i], flts[i], tenant=f"t{i % 2}"))
                for i in range(n)]
        outs = await asyncio.gather(*futs)
        await fe.close()
        return outs, fe.stats

    outs, st = asyncio.run(main())
    return outs, st


def test_pipelined_frontend_bit_identical_to_serialized(ds):
    base, _ = _drive_frontend(ds, FrontEndSpec())
    piped, st = _drive_frontend(ds, FrontEndSpec(parallel_steps=3))
    assert st["coalesce"]["slots"] == 3
    assert st["coalesce"]["inflight"] == 0      # close joined the pipeline
    assert len(piped) == len(base)
    for b, p in zip(base, piped):
        np.testing.assert_array_equal(p.ids, b.ids)
        np.testing.assert_array_equal(p.dists, b.dists)
        assert p.route == b.route


def test_close_drain_joins_inflight_steps(ds):
    eng = _fresh(ds)
    fe = FrontEnd(eng, FrontEndSpec(parallel_steps=2))
    qs, flts = _queries(ds, n=8, seed=3)

    async def main():
        futs = [asyncio.ensure_future(fe.submit(qs[i], flts[i]))
                for i in range(len(qs))]
        await asyncio.sleep(0)                  # scheduler starts dispatching
        await fe.close(drain=True)
        outs = await asyncio.gather(*futs)
        with pytest.raises(Exception) as ei:
            await fe.submit(qs[0], flts[0])
        return outs, ei.value

    outs, err = asyncio.run(main())
    # every already-submitted request resolved with a real result -- close
    # waited out the in-flight executor steps instead of racing them
    assert len(outs) == len(qs)
    assert all(r.ids.shape == (OPTS.k,) for r in outs)
    assert getattr(err, "reason", None) == "closed"
    assert eng._m_inflight.value() == 0


def test_close_nodrain_cancels_only_queued(ds):
    eng = _fresh(ds)
    # a long coalesce hold keeps submissions queued (never dispatched), so
    # drain=False must cancel them all cleanly
    fe = FrontEnd(eng, FrontEndSpec(parallel_steps=2, coalesce_ms=5000.0,
                                    coalesce_target=64))
    qs, flts = _queries(ds, n=3, seed=5)

    async def main():
        futs = [asyncio.ensure_future(fe.submit(qs[i], flts[i]))
                for i in range(len(qs))]
        await asyncio.sleep(0.05)               # inside the hold window
        await fe.close(drain=False)
        return await asyncio.gather(*futs, return_exceptions=True)

    outs = asyncio.run(main())
    assert all(isinstance(o, asyncio.CancelledError) for o in outs)
    assert eng._m_inflight.value() == 0
