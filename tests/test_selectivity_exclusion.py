"""Selectivity estimator (Eq. 1) + exclusion distance (Eq. 5/13/14)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e '.[dev]')")
from hypothesis import given, settings, strategies as st

from repro.core import exclusion
from repro.core import filters as F
from repro.core import selectivity as S

SCHEMA = F.paper_schema()


def test_estimator_close_to_exact():
    attrs = F.random_attributes(SCHEMA, 20000, seed=0)
    prog = F.compile_filter(F.Equality("i0", 4), SCHEMA)
    exact = S.exact_selectivity(prog, attrs)
    idx = S.sample_indices(attrs.n, rate=0.05, seed=1)
    est = float(S.estimate_selectivity(prog, attrs.ints[idx], attrs.floats[idx]))
    assert abs(est - exact) < 0.03


def test_relative_error_formula():
    # Eq. 1 at the paper's example: million scale, p ~ 1%, 1% sampling
    err = S.relative_error(n=10000, p=0.01, total=1_000_000)
    assert 0.02 < err < 0.12  # ~3% (paper says ~1% order of magnitude)
    assert S.relative_error(10000, 0.5, 1_000_000) < err  # decreasing in p
    assert S.relative_error(20000, 0.01, 1_000_000) < err  # decreasing in n


def test_batched_estimate_matches_single():
    attrs = F.random_attributes(SCHEMA, 5000, seed=2)
    filters = [F.Equality("b0", True), F.Range("f0", 0.0, 30.0)]
    progs = [F.compile_filter(f, SCHEMA) for f in filters]
    batch = F.stack_programs(progs)
    idx = S.sample_indices(attrs.n, rate=0.1, seed=3)
    est_b = S.estimate_selectivity_batched(batch, attrs.ints[idx], attrs.floats[idx])
    for i, p in enumerate(progs):
        est_1 = S.estimate_selectivity(p, attrs.ints[idx], attrs.floats[idx])
        assert abs(float(est_b[i]) - float(est_1)) < 1e-6


# -- exclusion distance -------------------------------------------------------
def test_delta_d_from_curve_linear():
    # perfectly linear curve -> slope recovered exactly
    curve = 0.5 + 0.02 * np.arange(100)
    assert abs(exclusion.delta_d_from_curve(curve, 10, 100) - 0.02) < 1e-9


@settings(max_examples=100, deadline=None)
@given(st.floats(0.011, 0.99), st.integers(30, 400))
def test_property_eq14_inside_eq13_band(p, ef):
    """The recommended D (Eq. 14, un-normalized) must sit inside the
    admissible band of Ineq. 13 for k < ef/2 (section 5.4 requires ef>2k)."""
    k = max(1, ef // 4)
    dd = 0.05
    lo, hi = exclusion.exclusion_bounds(p, ef, k, dd)
    d = exclusion.exclusion_distance(p, ef, dd, normalize=False)
    assert lo < d < hi


def test_monotone_in_p():
    dd = 0.02
    ds = [exclusion.exclusion_distance(p, 100, dd) for p in (0.05, 0.1, 0.3, 0.9)]
    assert all(a > b for a, b in zip(ds, ds[1:]))  # p up -> D down
    # limits: p -> 1 gives D -> 0
    assert exclusion.exclusion_distance(1.0, 100, dd) == pytest.approx(0.0)


def test_clamp_keeps_finite():
    assert np.isfinite(exclusion.exclusion_distance(0.0, 100, 0.02))


def test_d_max_ablation():
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(500, 8)).astype(np.float32)
    mask = rng.random(500) < 0.5
    q = rng.normal(size=(8,)).astype(np.float32)
    dmax = exclusion.d_max(q, vecs, mask)
    d = np.linalg.norm(vecs - q, axis=1)
    assert dmax >= d[mask].max() - d[~mask].min() - 1e-6


def test_d_strategy_regression():
    """Fidelity iterations 0-1 (EXPERIMENTS.md section Perf): the default
    strategy is "lo" -- the lower edge of Ineq. 13 (minimal sufficient
    exclusion).  Pin the default + the band ordering lo < mid and the
    magnitude failure modes of the two Eq. 14 readings."""
    k, ef, p, dd = 10, 48, 0.05, 0.02
    d_lo = exclusion.exclusion_distance(p, ef, dd, k=k)
    d_mid = exclusion.exclusion_distance(p, ef, dd, k=k, strategy="mid")
    d_nrm = exclusion.exclusion_distance(p, ef, dd, k=k, strategy="mid_norm")
    lo, hi = exclusion.exclusion_bounds(p, ef, k, dd)
    assert d_lo == pytest.approx(lo)
    assert lo < d_mid < hi          # paper midpoint stays inside the band
    assert d_nrm == pytest.approx(d_mid / ef)
    # "lo" clears the S-radius requirement (Fig. 3c) by construction
    assert d_lo >= (1 - p) * (k / p - 1) * dd - 1e-12
    # backwards-compat mapping
    assert exclusion.exclusion_distance(p, ef, dd, normalize=False) == d_mid
