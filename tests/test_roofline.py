"""Roofline machinery: HLO collective parser + three-term model."""
import numpy as np

from repro.roofline import analysis as RA
from repro.roofline import hw


def test_parse_single_and_tuple_collectives():
    text = """
  %all-reduce.8 = (f32[4096,39,10]{2,1,0}, f32[4096,39,1]{2,1,0}) all-reduce(%a, %b), replica_groups=[16,16]<=[256], use_global_device_ids=true
  %all-reduce.1 = f32[16,4096,2304]{2,1,0} all-reduce(%c), channel_id=1, replica_groups=[16,16]<=[256]
  %ag = bf16[26,2304,4,256]{3,2,1,0} all-gather(%d), replica_groups=[8,32]<=[256], dimensions={1}
  %rs = f32[64,128]{1,0} reduce-scatter(%e), replica_groups=[16,16]<=[256]
  %a2a = f32[64,128]{1,0} all-to-all(%f), replica_groups=[16,16]<=[256]
  %cp = f32[64,128]{1,0} collective-permute(%g), source_target_pairs={{0,1}}
  %ard = f32[8]{0} all-reduce-done(%x)
  %ars = f32[8]{0} all-reduce-start(%y), replica_groups={{0,1},{2,3}}
"""
    st = RA.parse_collectives(text, 256)
    assert st.counts == {"all-reduce": 3, "all-gather": 1, "reduce-scatter": 1,
                         "all-to-all": 1, "collective-permute": 1}
    exp = (2 * (15 / 16) * (4096 * 39 * 10 * 4 + 4096 * 39 * 1 * 4)   # tuple AR
           + 2 * (15 / 16) * (16 * 4096 * 2304 * 4)                   # AR
           + (31 / 32) * (26 * 2304 * 4 * 256 * 2)                    # AG
           + 15 * (64 * 128 * 4)                                      # RS
           + (15 / 16) * (64 * 128 * 4)                               # A2A
           + 64 * 128 * 4                                             # CP
           + 2 * (1 / 2) * 32)                                        # AR-start
    np.testing.assert_allclose(st.link_bytes, exp, rtol=1e-9)


def test_parse_ignores_non_collectives():
    text = """
  %dot.1 = f32[128,128]{1,0} dot(%a, %b), lhs_contracting_dims={1}
  %fusion.2 = f32[64]{0} fusion(%all), calls=%computation_with_all_gather_name
"""
    st = RA.parse_collectives(text, 16)
    assert st.counts == {}


def test_roofline_terms_and_bottleneck():
    r = RA.Roofline(flops=hw.PEAK_FLOPS_BF16, hbm_bytes=hw.HBM_BW / 2,
                    coll_link_bytes=hw.ICI_LINK_BW / 4, n_devices=256,
                    collectives={}, model_flops=hw.PEAK_FLOPS_BF16 * 128)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 0.5) < 1e-9
    assert abs(r.t_collective - 0.25) < 1e-9
    assert r.bottleneck == "compute"
    assert abs(r.useful_flops_frac - 0.5) < 1e-9
    assert abs(r.roofline_frac - 0.5) < 1e-9


def test_group_size_formats():
    assert RA._group_size("[16,16]<=[256]", 999) == 16
    assert RA._group_size("{{0,1,2,3}}", 999) == 4
    assert RA._group_size(None, 77) == 77
