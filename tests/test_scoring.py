"""Scorer layer: protocol properties, exclusion composition, the unified
traversal's scorer parity (ADC graph within 1pt of f32; bit-identical with
lossless codes), the rsf lane-mask alignment and the graph_arrays memo."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (BuildSpec, ExactScorer, FavorIndex, HnswParams,
                        LocalBackend, PqAdcScorer, QuantSpec, Scorer,
                        SearchConfig, SearchOptions, SqScorer, compile_filter,
                        exclusion_compose, graph_arrays, paper_filters,
                        paper_schema, random_attributes, router,
                        rsf_graph_search, scorer_for, stack_programs)
from repro.core import filters as F
from repro.core import refimpl
from repro.serving import ServeEngine

SCHEMA = paper_schema()
SCORERS = [ExactScorer(), PqAdcScorer(), SqScorer()]


def _quant_g(n=512, d=16, seed=0):
    """A graph-arrays dict carrying every scorer's arrays (pq + sq keys can
    coexist: each scorer reads only its own)."""
    from repro import quant
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    attrs = random_attributes(SCHEMA, n, seed=seed + 1)
    fi = FavorIndex.build(vecs, attrs, HnswParams(M=6, efc=32, seed=seed))
    g = dict(fi.g)
    pq = quant.train_pq(vecs, m=8, nbits=6, iters=5, seed=seed)
    sq = quant.train_sq(vecs)
    g["codes"] = jnp.asarray(quant.encode(pq, vecs))
    g["centroids"] = jnp.asarray(pq.centroids)
    g["sq_codes"] = jnp.asarray(quant.encode(sq, vecs))
    g["sq_lo"] = jnp.asarray(sq.lo)
    g["sq_scale"] = jnp.asarray(sq.scale)
    return g, vecs, rng


def _g_for(g, scorer):
    """Swap in the right 'codes' array for the scorer under test."""
    if scorer.kind == "sq":
        g = dict(g)
        g["codes"] = g["sq_codes"]
    return g


def _progs(b, flt=None):
    flt = flt or F.TrueFilter()
    return {k: jnp.asarray(v) for k, v in
            stack_programs([compile_filter(flt, SCHEMA)] * b).items()}


# ---------------------------------------------------------------------------
# Protocol + selection
# ---------------------------------------------------------------------------
def test_scorer_protocol_and_selection():
    for s in SCORERS:
        assert isinstance(s, Scorer)
    assert isinstance(scorer_for(SearchConfig()), ExactScorer)
    assert scorer_for(SearchConfig()).exact
    s = scorer_for(SearchConfig(graph_quant="pq", use_pallas=True))
    assert isinstance(s, PqAdcScorer) and s.use_pallas and not s.exact
    assert isinstance(scorer_for(SearchConfig(graph_quant="sq")), SqScorer)
    # scorers are frozen + hashable: legal jit-static parameters
    assert len({ExactScorer(), ExactScorer(use_pallas=True),
                PqAdcScorer(), SqScorer()}) == 4


def test_bytes_per_row_accounting():
    g, vecs, _ = _quant_g()
    d = vecs.shape[1]
    assert ExactScorer().bytes_per_row(g) == 4 * d
    assert PqAdcScorer().bytes_per_row(g) == 8          # m codes
    assert SqScorer().bytes_per_row(_g_for(g, SqScorer())) == d
    # the graph route's per-hop gather shrinks >= 8x under PQ
    assert ExactScorer().bytes_per_row(g) // PqAdcScorer().bytes_per_row(g) >= 8


@pytest.mark.parametrize("scorer", SCORERS, ids=lambda s: s.kind)
def test_score_block_matches_true_distance(scorer):
    """Every scorer approximates (or equals) the true distance; exact is
    exact."""
    g, vecs, rng = _quant_g()
    gs = _g_for(g, scorer)
    qs = jnp.asarray(rng.normal(size=(4, vecs.shape[1])).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, vecs.shape[0], size=(4, 16), dtype=np.int32))
    state = scorer.prepare(gs, qs, _progs(4))
    d = np.asarray(scorer.score_block(gs, state, ids))
    true = np.linalg.norm(np.asarray(qs)[:, None, :] - vecs[np.asarray(ids)],
                          axis=-1)
    if scorer.exact:
        np.testing.assert_allclose(d, true, rtol=1e-4, atol=1e-4)
    else:
        # approximate, but correlated: relative error bounded on average
        assert np.mean(np.abs(d - true) / (true + 1e-6)) < 0.25


# ---------------------------------------------------------------------------
# hypothesis properties (CI; the container skips without hypothesis)
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _G_CACHE = {}

    def _cached_g():
        if "g" not in _G_CACHE:
            _G_CACHE["g"] = _quant_g(n=256, d=8, seed=5)
        return _G_CACHE["g"]

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2 ** 16),
           kind=st.sampled_from(["exact", "pq", "sq"]))
    def test_score_block_permutation_equivariant(seed, kind):
        """Permuting the id block permutes the scores identically: scoring
        is elementwise over ids, for every scorer."""
        g, vecs, _ = _cached_g()
        scorer = {"exact": ExactScorer(), "pq": PqAdcScorer(),
                  "sq": SqScorer()}[kind]
        gs = _g_for(g, scorer)
        rng = np.random.default_rng(seed)
        b, m = 3, 12
        qs = jnp.asarray(rng.normal(size=(b, 8)).astype(np.float32))
        ids = rng.integers(0, vecs.shape[0], size=(b, m), dtype=np.int32)
        perm = rng.permutation(m)
        state = scorer.prepare(gs, qs, _progs(b))
        d = np.asarray(scorer.score_block(gs, state, jnp.asarray(ids)))
        dp = np.asarray(scorer.score_block(gs, state, jnp.asarray(ids[:, perm])))
        np.testing.assert_array_equal(d[:, perm], dp)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2 ** 16))
    def test_exclusion_compose_preserves_class_order(seed):
        """Eq. 2 adds a per-class constant: within the TD rows (and within
        the non-TD rows) the distance order is untouched, whatever the
        scorer produced."""
        rng = np.random.default_rng(seed)
        m = 32
        d = rng.uniform(0.0, 10.0, size=(1, m)).astype(np.float32)
        td = rng.integers(0, 2, size=(1, m)).astype(bool)
        D = np.float32(rng.uniform(0.0, 20.0))
        dbar = np.asarray(exclusion_compose(jnp.asarray(d), jnp.asarray(td),
                                            jnp.asarray(D)))
        for cls in (td, ~td):
            idx = np.nonzero(cls[0])[0]
            if len(idx) < 2:
                continue
            order_d = idx[np.argsort(d[0, idx], kind="stable")]
            order_b = idx[np.argsort(dbar[0, idx], kind="stable")]
            np.testing.assert_array_equal(order_d, order_b)
        # and every non-TD row is shifted by exactly D
        np.testing.assert_allclose(dbar[~td], d[~td] + D, rtol=1e-6)
        np.testing.assert_array_equal(dbar[td], d[td])


# ---------------------------------------------------------------------------
# Traversal parity across scorers
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def pq_corpus():
    rng = np.random.default_rng(17)
    n, d = 3000, 16
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    attrs = random_attributes(SCHEMA, n, seed=18)
    fi = FavorIndex.build(vecs, attrs, HnswParams(M=8, efc=48, seed=4),
                          BuildSpec(quant=QuantSpec(m=8, nbits=8,
                                                    train_iters=10)))
    queries = rng.normal(size=(24, d)).astype(np.float32)
    return fi, vecs, attrs, queries


@pytest.mark.parametrize("scenario", ["equality_bool", "range_50", "logic"])
def test_pq_graph_recall_within_1pt_of_f32(pq_corpus, scenario):
    """Acceptance bar: the ADC-scored graph route (with exact re-rank) stays
    within 1 recall point of the f32 route."""
    fi, vecs, attrs, queries = pq_corpus
    flt = paper_filters(SCHEMA)[scenario]
    mask = F.eval_program(compile_filter(flt, SCHEMA), attrs.ints, attrs.floats)
    truth = [refimpl.bruteforce_filtered(vecs, mask, q, 10)[0]
             for q in queries]
    be = LocalBackend(fi)
    rec = {}
    for gq in (None, "pq"):
        res = router.execute(be, queries, flt,
                             SearchOptions(k=10, ef=96, force="graph",
                                           graph_quant=gq))
        rec[gq] = np.mean([refimpl.recall_at_k(res.ids[i], truth[i], 10)
                           for i in range(len(queries))])
    assert rec["pq"] >= rec[None] - 0.01, rec


def test_sq_lossless_codes_bit_identical(pq_corpus):
    """With codes that decode exactly (corpus on the int8 grid), the SQ
    traversal sees the true geometry and the exact re-rank returns the f32
    route's answer bit for bit."""
    rng = np.random.default_rng(23)
    n, d = 1500, 12
    vecs = rng.integers(0, 256, size=(n, d)).astype(np.float32)
    vecs[0], vecs[1] = 0.0, 255.0       # pin the grid: lo=0, scale=1
    attrs = random_attributes(SCHEMA, n, seed=24)
    fi = FavorIndex.build(vecs, attrs, HnswParams(M=8, efc=40, seed=5),
                          BuildSpec(quant=QuantSpec(kind="sq")))
    assert float(np.max(np.abs(
        fi.codebook.scale - 1.0))) == 0.0, "codes not lossless"
    queries = rng.normal(size=(8, d)).astype(np.float32) * 64 + 128
    flt = paper_filters(SCHEMA)["equality_bool"]
    be = LocalBackend(fi)
    r_f32 = router.execute(be, queries, flt,
                           SearchOptions(k=10, ef=64, force="graph"))
    r_sq = router.execute(be, queries, flt,
                          SearchOptions(k=10, ef=64, force="graph",
                                        graph_quant="sq"))
    np.testing.assert_array_equal(r_f32.ids, r_sq.ids)
    np.testing.assert_array_equal(r_f32.dists, r_sq.dists)


def test_pq_bf16_lut_tolerance():
    """bf16 LUT storage halves the per-query table and only perturbs
    distances by the table's rounding error -- not the PQ quantization
    error, which is an order of magnitude larger."""
    g, vecs, rng = _quant_g()
    qs = jnp.asarray(rng.normal(size=(4, vecs.shape[1])).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, vecs.shape[0], size=(4, 16),
                                   dtype=np.int32))
    lo = PqAdcScorer(lut_bf16=True)
    hi = PqAdcScorer(lut_bf16=False)
    st_lo = lo.prepare(g, qs, _progs(4))
    st_hi = hi.prepare(g, qs, _progs(4))
    assert st_lo["luts"].dtype == jnp.bfloat16
    assert st_hi["luts"].dtype == jnp.float32
    assert lo.lut_bytes(g, 4) * 2 == hi.lut_bytes(g, 4)
    d_lo = np.asarray(lo.score_block(g, st_lo, ids))
    d_hi = np.asarray(hi.score_block(g, st_hi, ids))
    np.testing.assert_allclose(d_lo, d_hi, rtol=2e-2)
    assert np.mean(np.abs(d_lo - d_hi) / (d_hi + 1e-6)) < 5e-3


def test_sq_score_block_bit_stable_across_batch_width():
    """Lane compaction re-invokes the scorer at every stage width, so a
    lane's distances must not depend on how many other lanes ride along --
    the folded-affine SQ path keeps its contractions batch-independent."""
    g, vecs, rng = _quant_g()
    sc = SqScorer()
    gs = _g_for(g, sc)
    qs = jnp.asarray(rng.normal(size=(8, vecs.shape[1])).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, vecs.shape[0], size=(8, 16),
                                   dtype=np.int32))
    full_state = sc.prepare(gs, qs, _progs(8))
    full = np.asarray(sc.score_block(gs, full_state, ids))
    for width in (1, 2, 4):
        for off in range(0, 8, width):
            sl = slice(off, off + width)
            st = sc.prepare(gs, qs[sl], _progs(width))
            part = np.asarray(sc.score_block(gs, st, ids[sl]))
            np.testing.assert_array_equal(part, full[sl])


def test_sq_graph_route_matches_singles_under_compaction(pq_corpus):
    """Regression for the compaction ladder slicing scorer state: SqScorer's
    query-independent w2 weights are declared shared_state and must survive
    lane packing -- a batched run equals 24 independent single-query runs
    bit for bit."""
    fi, vecs, attrs, queries = pq_corpus
    fi_sq = FavorIndex(fi.index, attrs, BuildSpec(quant=QuantSpec(kind="sq")))
    be = LocalBackend(fi_sq)
    flt = paper_filters(SCHEMA)["equality_bool"]
    opts = SearchOptions(k=10, ef=48, force="graph", graph_quant="sq")
    batched = router.execute(be, queries[:6], flt, opts)
    for i in range(6):
        single = router.execute(be, queries[i:i + 1], flt, opts)
        np.testing.assert_array_equal(single.ids[0], batched.ids[i])
        np.testing.assert_array_equal(single.dists[0], batched.dists[i])


def test_max_steps_budget(pq_corpus):
    """SearchOptions.max_steps bounds total traversal waves across the
    compaction ladder; capped lanes still return a valid result pool."""
    fi, vecs, attrs, queries = pq_corpus
    be = LocalBackend(fi)
    flt = paper_filters(SCHEMA)["equality_bool"]
    free = router.execute(be, queries, flt,
                          SearchOptions(k=10, ef=96, force="graph",
                                        graph_quant="pq"))
    cap = int(np.max(free.waves)) // 2
    capped = router.execute(be, queries, flt,
                            SearchOptions(k=10, ef=96, force="graph",
                                          graph_quant="pq", max_steps=cap))
    assert int(np.max(capped.waves)) <= cap
    assert (capped.ids >= 0).any(axis=1).all()   # every lane returned hits
    assert np.isfinite(capped.dists[capped.ids >= 0]).all()
    with pytest.raises(ValueError):
        SearchOptions(max_steps=-1)


def test_graph_quant_padded_parity(pq_corpus):
    """Bucket padding stays bit-identical under the quantized scorer."""
    from repro.core import BatchSpec
    fi, vecs, attrs, queries = pq_corpus
    flt = paper_filters(SCHEMA)["equality_bool"]
    be = LocalBackend(fi)
    opts = SearchOptions(k=10, ef=64, force="graph", graph_quant="pq")
    ra = router.execute(be, queries[:5], flt, opts)
    rb = router.execute(be, queries[:5], flt,
                        opts.with_(batch=BatchSpec(min_bucket=4,
                                                   max_bucket=32)))
    np.testing.assert_array_equal(ra.ids, rb.ids)
    np.testing.assert_array_equal(ra.dists, rb.dists)
    np.testing.assert_array_equal(ra.hops, rb.hops)


def test_rsf_valid_mask_and_path_td(pq_corpus):
    """Satellite: rsf_graph_search carries the same lane-mask contract and
    diagnostics as favor_graph_search (one traversal body)."""
    fi, vecs, attrs, queries = pq_corpus
    flt = paper_filters(SCHEMA)["equality_bool"]
    progs = {k: jnp.asarray(v) for k, v in stack_programs(
        [compile_filter(flt, SCHEMA)] * 8).items()}
    cfg = SearchConfig(k=10, ef=48)
    full = rsf_graph_search(fi.g, jnp.asarray(queries[:8]), progs, cfg)
    assert "path_td" in full and "hops" in full
    valid = np.array([True] * 5 + [False] * 3)
    masked = rsf_graph_search(fi.g, jnp.asarray(queries[:8]), progs, cfg,
                              valid=jnp.asarray(valid))
    np.testing.assert_array_equal(np.asarray(masked["ids"])[:5],
                                  np.asarray(full["ids"])[:5])
    np.testing.assert_array_equal(np.asarray(masked["dists"])[:5],
                                  np.asarray(full["dists"])[:5])
    assert (np.asarray(masked["ids"])[5:] == -1).all()
    assert np.isinf(np.asarray(masked["dists"])[5:]).all()
    assert (np.asarray(masked["hops"])[5:] == 0).all()
    assert (np.asarray(masked["path_td"])[5:] == 0).all()


def test_engine_warmup_and_stats_with_graph_quant(pq_corpus):
    from repro.core import BatchSpec
    fi, vecs, attrs, queries = pq_corpus
    eng = ServeEngine(LocalBackend(fi),
                      SearchOptions(k=10, ef=48, graph_quant="pq",
                                    batch=BatchSpec(min_bucket=4,
                                                    max_bucket=8)))
    eng.warmup()
    assert eng.stats["scorers"]["graph"] == "pq"
    assert eng.stats["scorers"]["brute"] == "exact"
    flt = paper_filters(SCHEMA)["equality_bool"]
    for q in queries[:5]:
        eng.submit(q, flt)
    out = eng.run()
    assert len(out) == 5


def test_graph_route_pallas_scorers_match_jnp():
    """use_pallas wires the graph route through the kernels (gather_distance
    for exact, the pq_adc block-gather for PQ): same answers as the jnp
    scorers.  Tiny corpus -- interpret-mode kernels run per hop."""
    rng = np.random.default_rng(9)
    n, d = 400, 16
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    attrs = random_attributes(SCHEMA, n, seed=2)
    fi = FavorIndex.build(vecs, attrs, HnswParams(M=6, efc=32, seed=1),
                          BuildSpec(quant=QuantSpec(m=4, nbits=6,
                                                    train_iters=5)))
    qs = rng.normal(size=(2, d)).astype(np.float32)
    flt = paper_filters(SCHEMA)["equality_bool"]
    be = LocalBackend(fi)
    for gq in (None, "pq"):
        base = SearchOptions(k=5, ef=24, force="graph", graph_quant=gq)
        rj = router.execute(be, qs, flt, base)
        rp = router.execute(be, qs, flt, base.with_(use_pallas=True))
        np.testing.assert_array_equal(rj.ids, rp.ids), gq
        np.testing.assert_allclose(rj.dists, rp.dists, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# graph_arrays memoization
# ---------------------------------------------------------------------------
def test_graph_arrays_memoized(small_index, small_dataset):
    _, attrs, _ = small_dataset
    g1 = graph_arrays(small_index.index, attrs)
    g2 = graph_arrays(small_index.index, attrs)
    assert g1 is g2                      # same (index, attrs, version) -> hit
    assert g1["vectors"] is g2["vectors"]
    g3 = graph_arrays(small_index.index, attrs, version=1)
    assert g3 is not g1                  # version bump -> fresh upload
    # FavorIndex holds a *copy*: adding quantized-scorer keys there must
    # never leak into the shared cache entry
    fi2 = FavorIndex(small_index.index, attrs,
                     BuildSpec(quant=QuantSpec(m=4, nbits=4, train_iters=4)))
    assert "codes" in fi2.g
    assert "codes" not in graph_arrays(small_index.index, attrs)
    assert fi2.g["vectors"] is g1["vectors"]  # arrays still shared


def test_bump_version_reuploads_attrs(small_dataset):
    """An in-place attribute edit + bump_version() must reach the device
    copies (the memo is keyed on the epoch), and the scorer arrays ride
    along."""
    vecs, attrs0, schema = small_dataset
    attrs = F.AttributeTable(schema, attrs0.ints.copy(), attrs0.floats.copy())
    fi = FavorIndex.build(vecs[:600], F.AttributeTable(
        schema, attrs.ints[:600], attrs.floats[:600]),
        HnswParams(M=6, efc=32, seed=8),
        BuildSpec(quant=QuantSpec(m=4, nbits=4, train_iters=4)))
    fi.attrs.ints[:] = (fi.attrs.ints + 1) % 2
    # (whether the pre-bump device copy aliases the host buffer is an XLA
    # CPU implementation detail -- the contract is only post-bump freshness)
    fi.bump_version()
    np.testing.assert_array_equal(np.asarray(fi.g["attrs_int"]),
                                  fi.attrs.ints)
    assert "codes" in fi.g and "centroids" in fi.g  # scorer arrays restored
