"""HNSW construction invariants + oracle search quality."""
import collections

import numpy as np
import pytest

from repro.core import filters as F
from repro.core import refimpl
from repro.core.hnsw import HnswIndex, HnswParams, build_hnsw


@pytest.fixture(scope="module")
def built(small_dataset_mod):
    vecs, _, _ = small_dataset_mod
    return build_hnsw(vecs, HnswParams(M=8, efc=48, seed=3)), vecs


@pytest.fixture(scope="module")
def small_dataset_mod():
    rng = np.random.default_rng(7)
    vecs = rng.normal(size=(1500, 16)).astype(np.float32)
    return vecs, None, None


def test_degree_bounds(built):
    idx, _ = built
    for level, arr in enumerate(idx.levels):
        m = idx.params.M0 if level == 0 else idx.params.M
        assert arr.shape[1] == m
        assert ((arr >= -1) & (arr < idx.n)).all()


def test_no_self_loops(built):
    idx, _ = built
    for arr in idx.levels:
        rows = np.arange(idx.n)[:, None]
        assert not np.any(arr == rows)


def test_base_layer_connected(built):
    idx, _ = built
    # BFS from entry point over level-0 edges reaches (almost) everything
    adj = idx.levels[0]
    seen = np.zeros(idx.n, bool)
    frontier = [idx.entry_point]
    seen[idx.entry_point] = True
    while frontier:
        nxt = adj[frontier].ravel()
        nxt = nxt[nxt >= 0]
        nxt = nxt[~seen[nxt]]
        seen[np.unique(nxt)] = True
        frontier = np.unique(nxt).tolist()
    assert seen.mean() > 0.99


def test_level_distribution(built):
    idx, _ = built
    counts = collections.Counter(idx.node_level.tolist())
    assert counts[0] > 0.8 * idx.n  # exponential decay
    assert idx.max_level == max(counts)


def test_delta_d_positive_and_sane(built):
    idx, vecs = built
    assert idx.delta_d > 0
    # compare against a direct estimate of the m-th NN slope on a sample
    rng = np.random.default_rng(0)
    sample = rng.choice(idx.n, 50, replace=False)
    slopes = []
    for s in sample:
        d = np.linalg.norm(vecs - vecs[s], axis=1)
        d = np.sort(d)[1:101]
        slopes.append((d[-1] - d[9]) / (len(d) - 10))
    direct = np.mean(slopes)
    assert 0.3 * direct < idx.delta_d < 3.0 * direct


def test_unfiltered_recall(built):
    idx, vecs = built
    rng = np.random.default_rng(1)
    qs = rng.normal(size=(20, vecs.shape[1])).astype(np.float32)
    mask = np.ones(idx.n, bool)
    recs = []
    for q in qs:
        truth, _ = refimpl.bruteforce_filtered(vecs, mask, q, 10)
        ids, _, _ = refimpl.favor_search(idx, q, mask, 10, 64, 0.0, pbar_min=0.0)
        recs.append(refimpl.recall_at_k(ids, truth, 10))
    assert np.mean(recs) >= 0.93


def test_save_load_roundtrip(built, tmp_path):
    idx, _ = built
    p = str(tmp_path / "idx.npz")
    idx.save(p)
    idx2 = HnswIndex.load(p)
    assert idx2.n == idx.n and idx2.max_level == idx.max_level
    assert idx2.entry_point == idx.entry_point
    assert abs(idx2.delta_d - idx.delta_d) < 1e-9
    for a, b in zip(idx.levels, idx2.levels):
        np.testing.assert_array_equal(a, b)
