"""Unified observability layer (repro.obs).

Acceptance bars from the PR-8 issue:
  * every stats surface served from ONE metrics registry, exported both as
    a JSON snapshot and prometheus text exposition (golden-tested)
  * route traces cover every ``router.execute`` stage, nest correctly under
    coalesced front-end batches, and feed the slow-query ring
  * estimator-accuracy probes measure |p_hat - p_true| against the real
    corpus; route-confusion shadows populate (chosen, faster) counters
  * ``ObsSpec(enabled=False)`` (and obs=None) is bit-identical to enabled
  * ``reset_stats()`` cascades through the registry: engine counters,
    frontend tenant/coalesce ledgers, cache layer counters, trace rings
plus the satellite contracts: injectable monotonic clock (deterministic
histograms/spans under a fake ``time_fn``) and histogram ``le`` edges.

No pytest-asyncio: async scenarios run through ``asyncio.run``.
"""
import asyncio
import json

import numpy as np
import pytest

from repro.cache import CachingBackend
from repro.core import (BatchSpec, CacheSpec, FrontEndSpec, LocalBackend,
                        ObsSpec, SearchOptions, router)
from repro.core import filters as F
from repro.obs import MetricsRegistry, Obs, RequestTrace
from repro.obs.probes import innermost, true_fraction
from repro.obs.trace import sample_period
from repro.serving import FrontEnd, ServeEngine

OPTS = SearchOptions(k=5, ef=48, batch=BatchSpec(min_bucket=4, max_bucket=16))


def _queries(n, d, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _flt(schema):
    return F.paper_filters(schema)["equality_bool"]


class FakeClock:
    """Monotonic fake: every call advances by ``tick`` seconds."""

    def __init__(self, tick=0.001):
        self.t = 0.0
        self.tick = tick

    def __call__(self):
        self.t += self.tick
        return self.t


# ---------------------------------------------------------------------------
# Registry primitives
# ---------------------------------------------------------------------------
def test_counter_labels_and_monotonicity():
    reg = MetricsRegistry()
    c = reg.counter("favor_x_total", "x", labels=("route",))
    c.inc(route="graph")
    c.inc(2.5, route="graph")
    c.inc(route="brute")
    assert c.value(route="graph") == 3.5
    assert c.value(route="brute") == 1.0
    assert c.value(route="never") == 0.0
    assert c.total() == 4.5
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1.0, route="graph")
    with pytest.raises(ValueError, match="labels"):
        c.inc(tenant="a")  # wrong label name
    with pytest.raises(ValueError, match="labels"):
        c.inc()            # missing label


def test_registry_registration_idempotent_and_conflicting():
    reg = MetricsRegistry()
    a = reg.counter("favor_y_total", "y")
    assert reg.counter("favor_y_total") is a
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("favor_y_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("favor_y_total", labels=("route",))
    with pytest.raises(ValueError, match="bad metric name"):
        reg.counter("favor-y", "dashes are not prometheus names")


def test_histogram_bucket_edges_are_inclusive_upper_bounds():
    reg = MetricsRegistry()
    h = reg.histogram("favor_h", "h", buckets=(0.1, 1.0))
    # prometheus ``le`` semantics: a sample equal to the bound lands IN it
    for v in (0.05, 0.1, 0.5, 1.0, 2.0):
        h.observe(v)
    snap = reg.snapshot()["histograms"]["favor_h"]["series"][""]
    assert snap["buckets"] == [["0.1", 2], ["1", 4], ["+Inf", 5]]
    assert snap["count"] == 5
    assert snap["sum"] == pytest.approx(3.65)
    # observe_many bins identically (numpy searchsorted path)
    h2 = reg.histogram("favor_h2", "h", buckets=(0.1, 1.0))
    h2.observe_many([0.05, 0.1, 0.5, 1.0, 2.0])
    assert (reg.snapshot()["histograms"]["favor_h2"]["series"][""]
            == snap)
    with pytest.raises(ValueError, match="strictly"):
        reg.histogram("favor_h3", "h", buckets=(1.0, 1.0))


def test_histogram_percentile_interpolation():
    reg = MetricsRegistry()
    h = reg.histogram("favor_p", "p", buckets=(1.0, 2.0, 4.0))
    assert h.percentile(50) is None
    h.observe_many([0.5] * 50 + [1.5] * 50)
    assert h.percentile(25) == pytest.approx(0.5)
    assert h.percentile(100) == pytest.approx(2.0)
    assert 1.0 < h.percentile(75) <= 2.0


def test_prometheus_exposition_golden():
    reg = MetricsRegistry()
    c = reg.counter("favor_requests_total", "Requests served, by route",
                    labels=("route",))
    c.inc(3, route="graph")
    c.inc(route="brute")
    reg.gauge("favor_delta_rows", "Live delta rows").set(12)
    h = reg.histogram("favor_latency_seconds", "Latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 2.0):
        h.observe(v)
    reg.register_view("cache", lambda: {"semantic": {"hits": 2, "misses": 1,
                                                     "note": "non-numeric"}})
    assert reg.prometheus_text() == """\
# HELP favor_requests_total Requests served, by route
# TYPE favor_requests_total counter
favor_requests_total{route="brute"} 1
favor_requests_total{route="graph"} 3
# HELP favor_delta_rows Live delta rows
# TYPE favor_delta_rows gauge
favor_delta_rows 12
# HELP favor_latency_seconds Latency
# TYPE favor_latency_seconds histogram
favor_latency_seconds_bucket{le="0.1"} 1
favor_latency_seconds_bucket{le="1"} 2
favor_latency_seconds_bucket{le="+Inf"} 3
favor_latency_seconds_sum 2.55
favor_latency_seconds_count 3
# HELP favor_view Flattened numeric leaves of registered stats views
# TYPE favor_view gauge
favor_view{view="cache",path="semantic.hits"} 2
favor_view{view="cache",path="semantic.misses"} 1
"""


def test_snapshot_is_json_able_and_reset_zeroes():
    reg = MetricsRegistry()
    reg.counter("favor_a_total", "a").inc(7)
    reg.histogram("favor_b", "b", buckets=(1.0,)).observe(0.5)
    reg.register_view("v", lambda: {"x": 1})
    snap = json.loads(json.dumps(reg.snapshot()))
    assert snap["counters"]["favor_a_total"]["series"][""] == 7
    assert snap["views"]["v"] == {"x": 1}
    hooked = []
    reg.on_reset(lambda: hooked.append(True))
    reg.reset()
    assert hooked == [True]
    snap = reg.snapshot()
    assert snap["counters"]["favor_a_total"]["series"][""] == 0
    assert snap["histograms"]["favor_b"]["series"][""]["count"] == 0


def test_sample_period():
    assert sample_period(0.0) == 0
    assert sample_period(1.0) == 1
    assert sample_period(0.5) == 2
    assert sample_period(0.1) == 10
    assert sample_period(1e-9) >= 1


# ---------------------------------------------------------------------------
# Spans + fake clock determinism
# ---------------------------------------------------------------------------
def test_span_nesting_and_fake_clock_determinism():
    clock = FakeClock(tick=1.0)
    tr = RequestTrace(1, batch=4, time_fn=clock)   # t0 = 1
    with tr.span("outer", rows=4):                 # t0 = 2
        with tr.span("inner"):                     # t0 = 3, t1 = 4
            pass
    # outer t1 = 5
    tr.finish()                                    # t1 = 6
    assert [s.name for s in tr.spans] == ["outer"]
    outer = tr.spans[0]
    assert [c.name for c in outer.children] == ["inner"]
    assert outer.attrs == {"rows": 4}
    assert outer.duration_s == pytest.approx(3.0)
    assert outer.children[0].duration_s == pytest.approx(1.0)
    assert tr.duration_s == pytest.approx(5.0)
    assert tr.stage_ms() == {"outer": pytest.approx(3000.0)}
    d = tr.to_dict()
    assert d["spans"][0]["children"][0]["name"] == "inner"


def test_obsspec_validation():
    ObsSpec()  # defaults valid
    with pytest.raises(ValueError, match="trace_sample"):
        ObsSpec(trace_sample=1.5)
    with pytest.raises(ValueError, match="probe_sample"):
        ObsSpec(probe_sample=-0.1)
    with pytest.raises(ValueError, match="trace_cap"):
        ObsSpec(trace_cap=0)
    with pytest.raises(ValueError, match="slow_ms"):
        ObsSpec(slow_ms=-1.0)
    with pytest.raises(ValueError, match="latency_buckets"):
        ObsSpec(latency_buckets=(0.1, 0.1))
    assert ObsSpec(slow_ms=None).slow_ms is None
    assert ObsSpec().with_(probe_sample=0.5).probe_sample == 0.5
    with pytest.raises(TypeError):
        Obs("not a spec")


# ---------------------------------------------------------------------------
# Engine integration: one registry serves every stats surface
# ---------------------------------------------------------------------------
def _drive(eng, schema, n=12, seed=0, d=16):
    qs = _queries(n, d, seed)
    flt = _flt(schema)
    for i in range(n):
        eng.submit(qs[i], flt)
    out = eng.drain()
    assert len(out) == n
    return out


def test_engine_stats_served_from_registry(small_index, small_dataset):
    _, _, schema = small_dataset
    eng = ServeEngine(LocalBackend(small_index), OPTS, max_batch=8)
    _drive(eng, schema)
    st = eng.stats
    assert st["graph"] + st["brute"] == 12
    assert st["batches"] == 2
    assert st["obs"]["traces"] == 2           # trace_sample defaults to 1.0
    # the same numbers through both machine exports
    snap = eng.obs.snapshot()
    served = snap["counters"]["favor_requests_total"]["series"]
    assert sum(served.values()) == 12
    assert snap["histograms"]["favor_request_latency_seconds"][
        "series"][""]["count"] == 12
    assert snap["histograms"]["favor_p_hat"]["series"][""]["count"] == 12
    assert snap["views"]["batching"]["pad_rows"] >= 0
    text = eng.obs.prometheus_text()
    assert "# TYPE favor_requests_total counter" in text
    assert "favor_batches_total 2" in text
    assert 'favor_view{view="scorers",' in text


def test_trace_spans_cover_every_router_stage(small_index, small_dataset):
    _, _, schema = small_dataset
    # cache-capable backend: the lookup/record stages are real, not skipped
    cb = CachingBackend(LocalBackend(small_index), CacheSpec())
    eng = ServeEngine(cb, OPTS, max_batch=8)
    _drive(eng, schema, n=8)
    tr = eng.obs.tracer.traces[-1]
    names = [s.name for s in tr.spans]
    for stage in ("compile", "cache_lookup", "estimate", "route",
                  "cache_record"):
        assert stage in names, names
    assert ("graph" in names) or ("brute" in names)
    # route sub-batch spans nest their pad + search steps
    route_sp = next(s for s in tr.spans if s.name in ("graph", "brute"))
    kids = [c.name for c in route_sp.children]
    assert kids == ["pad", "search"], kids
    assert route_sp.attrs["rows"] >= 1
    assert route_sp.attrs["bucket"] in OPTS.batch.buckets()
    assert 0.0 <= route_sp.attrs["pad_frac"] <= 1.0
    # every top-level stage fed the shared stage histogram
    hist = eng.obs.registry.snapshot()["histograms"]["favor_stage_seconds"]
    stages = {k for k in hist["series"]}
    assert 'stage="estimate"' in stages and 'stage="route"' in stages


def test_slow_query_log_and_sampling(small_index, small_dataset):
    _, _, schema = small_dataset
    # slow_ms=0: every traced batch is "slow"; trace_sample=0.5 -> 1-in-2
    eng = ServeEngine(LocalBackend(small_index), OPTS, max_batch=4,
                      obs=ObsSpec(trace_sample=0.5, slow_ms=0.0))
    _drive(eng, schema, n=16)   # 4 batches -> batches 1 and 3 traced
    assert eng.stats["batches"] == 4
    assert eng.stats["obs"]["traces"] == 2
    slow = list(eng.obs.tracer.slow_log)
    assert len(slow) == 8       # per-request entries for the traced batches
    sq = slow[0]
    assert sq.route in ("graph", "brute")
    assert sq.ef == OPTS.ef
    assert 0.0 <= sq.p_hat <= 1.0
    assert sq.signature            # canonical filter signature, non-empty
    assert set(sq.stages_ms) >= {"compile", "estimate", "route"}
    assert sq.total_ms >= 0.0
    d = sq.to_dict()
    assert d["signature"] == sq.signature
    # slow_ms=None disables the ring entirely
    eng2 = ServeEngine(LocalBackend(small_index), OPTS, max_batch=4,
                       obs=ObsSpec(slow_ms=None))
    _drive(eng2, schema, n=8)
    assert len(eng2.obs.tracer.slow_log) == 0


def test_obs_disabled_is_bit_identical_and_inert(small_index, small_dataset):
    _, _, schema = small_dataset
    qs = _queries(10, 16, seed=5)
    flt = _flt(schema)
    backend = LocalBackend(small_index)
    # router level: obs wired vs. not
    obs = Obs(ObsSpec(trace_sample=1.0))
    r_obs = router.execute(backend, qs, flt, OPTS, obs=obs)
    r_off = router.execute(backend, qs, flt, OPTS, obs=None)
    assert np.array_equal(r_obs.ids, r_off.ids)
    assert np.array_equal(r_obs.dists, r_off.dists)
    # engine level: ObsSpec(enabled=False) builds no tracer/probes and
    # serves identical responses
    eng_on = ServeEngine(LocalBackend(small_index), OPTS, max_batch=8)
    eng_off = ServeEngine(LocalBackend(small_index), OPTS, max_batch=8,
                          obs=ObsSpec(enabled=False))
    assert eng_off.obs.tracer is None and not eng_off.obs.wants_probe
    out_on = _drive(eng_on, schema, n=12, seed=9)
    out_off = _drive(eng_off, schema, n=12, seed=9)
    for a, b in zip(out_on, out_off):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.dists, b.dists)
        assert a.route == b.route
    assert eng_off.stats["obs"] == {"enabled": False, "trace_sample": 1.0}
    # counters still work with obs disabled (registry stays live)
    assert eng_off.stats["graph"] + eng_off.stats["brute"] == 12


def test_time_fn_injection_is_deterministic(small_index, small_dataset):
    _, _, schema = small_dataset

    def latencies(seed):
        eng = ServeEngine(LocalBackend(small_index), OPTS, max_batch=4,
                          time_fn=FakeClock(tick=0.001),
                          obs=ObsSpec(slow_ms=None))
        _drive(eng, schema, n=8, seed=seed)
        stages = {s.name: s.duration_s for s in eng.obs.tracer.traces[0].spans}
        return list(eng.latencies), stages

    lat1, st1 = latencies(3)
    lat2, st2 = latencies(3)
    # two runs under the fake clock agree exactly, tick for tick
    assert lat1 == lat2
    assert st1 == st2
    assert all(lat > 0 for lat in lat1)


# ---------------------------------------------------------------------------
# Probes: estimator accuracy + route confusion
# ---------------------------------------------------------------------------
def test_true_fraction_matches_direct_mask(small_index, small_dataset):
    _, attrs, schema = small_dataset
    backend = LocalBackend(small_index)
    assert innermost(backend) is backend
    flt = _flt(schema)
    prog = F.compile_filter(flt, schema)
    want = float(np.asarray(
        F.eval_program(prog, attrs.ints, attrs.floats)).mean())
    assert true_fraction(backend, flt) == pytest.approx(want)
    assert true_fraction(backend, F.TrueFilter()) == pytest.approx(1.0)


def test_estimator_probe_against_known_distribution(small_index,
                                                    small_dataset):
    _, _, schema = small_dataset
    backend = LocalBackend(small_index)
    eng = ServeEngine(backend, OPTS, max_batch=8,
                      obs=ObsSpec(probe_sample=1.0, slow_ms=None))
    out = _drive(eng, schema, n=16)      # 2 batches -> 2 probes
    snap = eng.obs.snapshot()
    probes = snap["counters"]["favor_estimator_probes_total"]["series"]
    assert sum(probes.values()) == 2
    err = snap["histograms"]["favor_estimator_abs_error"]["series"][""]
    assert err["count"] == 2
    # single filter everywhere: each probe's error is |p_hat - p_true|
    p_true = true_fraction(backend, _flt(schema))
    p_hat = out[0].p_hat
    assert err["sum"] == pytest.approx(2 * abs(p_hat - p_true))
    # equality_bool sits far above lambda on both estimate and truth, and
    # the graph route itself implies p_hat >= lambda: no route flips
    lam = float(backend.sel_cfg.lam)
    assert p_true >= lam and p_hat >= lam
    flips = snap["counters"]["favor_estimator_route_flips_total"]["series"]
    assert sum(flips.values()) == 0


def test_route_confusion_shadow_populates(small_index, small_dataset):
    _, _, schema = small_dataset
    eng = ServeEngine(LocalBackend(small_index), OPTS, max_batch=8,
                      obs=ObsSpec(shadow_sample=1.0, slow_ms=None))
    out = _drive(eng, schema, n=16)
    shadow = eng.obs.snapshot()["counters"]["favor_route_shadow_total"]
    assert sum(shadow["series"].values()) == 2    # 1 shadow per batch
    chosen_routes = {r.route for r in out}
    for key in shadow["series"]:
        assert any(f'chosen="{r}"' in key for r in chosen_routes), key


# ---------------------------------------------------------------------------
# Front-end: coalesced-batch traces + the full reset cascade
# ---------------------------------------------------------------------------
def test_frontend_coalesced_batch_traces(small_index, small_dataset):
    _, _, schema = small_dataset

    async def main():
        cb = CachingBackend(LocalBackend(small_index), CacheSpec())
        eng = ServeEngine(cb, OPTS, max_batch=16)
        fe = FrontEnd(eng, FrontEndSpec(coalesce_ms=25.0, coalesce_target=8))
        qs = _queries(8, 16, seed=21)
        outs = await asyncio.gather(
            *[fe.submit(qs[i], _flt(schema)) for i in range(8)])
        st = fe.stats
        traces = list(eng.obs.tracer.traces)
        await fe.close()
        return outs, st, traces

    outs, st, traces = asyncio.run(main())
    assert len(outs) == 8
    # the hold window coalesced concurrent submits into fewer dispatches;
    # each dispatched batch carries one span tree covering the pipeline
    assert st["coalesce"]["dispatches"] == len(traces) > 0
    total = 0
    for tr in traces:
        names = [s.name for s in tr.spans]
        assert names[0] == "compile" and names[-1] == "cache_record", names
        for sp in tr.spans:     # spans nest: children close inside parents
            for c in sp.children:
                assert sp.t0 <= c.t0 and c.t1 <= sp.t1
        total += tr.batch
    assert total == 8


def test_reset_cascade_zeroes_every_surface(small_index, small_dataset):
    _, _, schema = small_dataset

    async def main():
        cb = CachingBackend(LocalBackend(small_index), CacheSpec())
        eng = ServeEngine(cb, OPTS, max_batch=8)
        fe = FrontEnd(eng, FrontEndSpec(coalesce_ms=5.0, coalesce_target=8))
        qs = _queries(8, 16, seed=23)
        flt = _flt(schema)

        async def burst():
            return await asyncio.gather(
                *[fe.submit(qs[i], flt) for i in range(8)])

        await burst()
        await burst()            # repeat traffic: populates cache hits
        before = fe.stats
        fe.reset_stats()         # one call cascades through the registry
        after = fe.stats
        await burst()            # cached ENTRIES survived the counter reset
        served_after = fe.stats
        await fe.close()
        return before, after, served_after

    before, after, warm = asyncio.run(main())
    # ...counters were non-zero before the reset
    assert before["tenants"]["default"]["served"] == 16
    assert before["coalesce"]["dispatches"] > 0
    eng_b = before["engine"]
    assert eng_b["graph"] + eng_b["brute"] == 16 and eng_b["batches"] > 0
    assert eng_b["cache"]["semantic"]["hits"] > 0
    assert eng_b["obs"]["traces"] > 0
    # ...and all zero after
    assert after["tenants"]["default"]["served"] == 0
    assert "p99_ms" not in after["tenants"]["default"]  # window cleared
    assert after["coalesce"]["dispatches"] == 0
    eng_a = after["engine"]
    assert eng_a["graph"] == eng_a["brute"] == eng_a["batches"] == 0
    assert eng_a["obs"]["traces"] == 0
    for layer in ("selectivity", "candidates", "semantic"):
        st = eng_a["cache"][layer]
        assert st["hits"] == st["misses"] == 0
    assert eng_a["batching"]["pad_rows"] == 0
    # entries survived: the post-reset burst is served from the warm cache
    assert warm["engine"]["cache"]["semantic"]["hits"] > 0
    assert warm["tenants"]["default"]["served"] == 8


def test_frontend_ledgers_in_exposition(small_index, small_dataset):
    _, _, schema = small_dataset

    async def main():
        cb = CachingBackend(LocalBackend(small_index), CacheSpec())
        eng = ServeEngine(cb, OPTS, max_batch=8)
        fe = FrontEnd(eng, FrontEndSpec(coalesce_ms=2.0))
        qs = _queries(4, 16, seed=27)
        await asyncio.gather(
            *[fe.submit(qs[i], _flt(schema)) for i in range(4)])
        text = eng.obs.prometheus_text()
        snap = eng.obs.snapshot()
        await fe.close()
        return text, snap

    text, snap = asyncio.run(main())
    assert ('favor_view{view="frontend",path="tenants.default.served"} 4'
            in text)
    assert 'favor_view{view="cache",path="semantic.' in text
    assert snap["views"]["frontend"]["tenants"]["default"]["served"] == 4
