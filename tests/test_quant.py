"""Quantization subsystem: codebooks, ADC scans, Pallas kernel, e2e recall."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (FavorIndex, compile_filter, paper_filters,
                        paper_schema, random_attributes, stack_programs)
from repro.core import filters as F
from repro.core import refimpl
from repro.kernels.pq_adc import ops as pq_ops
from repro.kernels.pq_adc import ref as pq_ref
from repro.quant import (build_luts, decode, encode, load_codebook,
                         pq_prefbf_topk, save_codebook, train_pq, train_sq)

SCHEMA = paper_schema()


def _data(n, d, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(np.float32), rng


# ---------------------------------------------------------------------------
# codebooks
# ---------------------------------------------------------------------------
def test_pq_train_encode_decode_roundtrip():
    x, _ = _data(1500, 16, seed=1)
    cb = train_pq(x, m=8, nbits=6, iters=15, seed=0)
    assert cb.centroids.shape == (8, 64, 2)
    codes = encode(cb, x)
    assert codes.shape == (1500, 8) and codes.dtype == np.uint8
    recon = decode(cb, codes)
    assert recon.shape == x.shape
    mse = float(np.mean((recon - x) ** 2))
    assert mse < 0.5 * float(np.var(x)), "codebooks did not learn the data"


def test_pq_nondividing_dim():
    x, _ = _data(800, 10, seed=2)  # 10 dims over m=4 -> dsub=3, 2 pad dims
    cb = train_pq(x, m=4, nbits=5, iters=10, seed=0)
    assert cb.dsub == 3 and cb.padded_dim == 12 and cb.dim == 10
    recon = decode(cb, encode(cb, x))
    assert recon.shape == x.shape


def test_sq_roundtrip_error_bound():
    x, _ = _data(500, 12, seed=3)
    cb = train_sq(x)
    codes = encode(cb, x)
    assert codes.dtype == np.uint8 and codes.shape == x.shape
    recon = decode(cb, codes)
    # affine int8: per-dim error is at most half a quantization step
    assert np.all(np.abs(recon - x) <= 0.5 * cb.scale[None, :] + 1e-6)


def test_codebook_save_load(tmp_path):
    x, _ = _data(600, 8, seed=4)
    for cb in (train_pq(x, m=4, nbits=4, iters=5), train_sq(x)):
        p = str(tmp_path / "cb.npz")
        save_codebook(p, cb)
        cb2 = load_codebook(p)
        assert type(cb2) is type(cb) and cb2.dim == cb.dim
        np.testing.assert_array_equal(encode(cb, x), encode(cb2, x))


# ---------------------------------------------------------------------------
# ADC vs exact distances
# ---------------------------------------------------------------------------
def test_adc_distance_error_bound():
    x, rng = _data(2000, 16, seed=5)
    cb = train_pq(x, m=8, nbits=6, iters=15, seed=0)
    codes = jnp.asarray(encode(cb, x))
    qs = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    luts = build_luts(jnp.asarray(cb.centroids), qs)
    idx = codes.astype(jnp.int32)[None, :, :, None]
    adc = jnp.sum(jnp.take_along_axis(luts[:, None], idx, axis=3)[..., 0], -1)
    exact = np.linalg.norm(np.asarray(qs)[:, None, :] - x[None], axis=-1)
    err = np.abs(np.sqrt(np.asarray(adc)) - exact)
    assert float(np.mean(err)) / float(np.mean(exact)) < 0.1, \
        "ADC distances drifted too far from exact"


# ---------------------------------------------------------------------------
# Pallas kernel vs ref oracle (interpret mode on CPU)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,b,m,nbits,r,bq,bn", [
    (700, 6, 8, 6, 20, 4, 128),    # non-multiple row count (padding path)
    (1024, 8, 4, 8, 10, 8, 256),
    (512, 4, 16, 4, 40, 4, 512),   # one n-tile, large R
])
def test_pq_adc_kernel_matches_ref(n, b, m, nbits, r, bq, bn):
    rng = np.random.default_rng(n + m)
    k = 1 << nbits
    codes = jnp.asarray(rng.integers(0, k, size=(n, m)).astype(np.uint8))
    luts = jnp.asarray(rng.uniform(0, 4.0, size=(b, m, k)).astype(np.float32))
    norms = jnp.asarray(rng.uniform(1.0, 2.0, size=(n,)).astype(np.float32))
    attrs = random_attributes(SCHEMA, n, seed=n)
    ints, floats = jnp.asarray(attrs.ints), jnp.asarray(attrs.floats)
    pool = [F.Equality("b0", True), F.Inclusion("i0", [1, 5, 9]),
            F.Range("f0", 10.0, 60.0), F.TrueFilter()]
    progs = {kk: jnp.asarray(v) for kk, v in stack_programs(
        [compile_filter(pool[i % len(pool)], SCHEMA) for i in range(b)]).items()}

    ids, dd = pq_ops.pq_adc_topr(codes, norms, ints, floats, luts, progs,
                                 r=r, block_q=bq, block_n=bn)
    rd, ri = pq_ref.pq_adc_topr_ref(luts, codes, norms, ints, floats, progs,
                                    r=r)
    dd_c = np.where(np.isinf(np.asarray(dd)), pq_ref.BIG, np.asarray(dd))
    np.testing.assert_allclose(dd_c, np.asarray(rd), rtol=1e-5, atol=1e-5)
    same = np.asarray(ids) == np.asarray(ri)
    assert same.mean() > 0.99  # ids agree where ADC values are unique


def test_pq_adc_kernel_matches_jnp_scan():
    """Pallas route of pq_prefbf_topk vs the jnp lax.scan route."""
    x, rng = _data(1200, 16, seed=7)
    cb = train_pq(x, m=8, nbits=6, iters=10, seed=0)
    from repro.core import prefbf
    attrs = random_attributes(SCHEMA, 1200, seed=8)
    pv, pn, pi, pf = prefbf.pad_db(x, np.einsum("nd,nd->n", x, x),
                                   attrs.ints, attrs.floats, 256)
    codes = jnp.asarray(encode(cb, pv))
    qs = jnp.asarray(rng.normal(size=(6, 16)).astype(np.float32))
    progs = {kk: jnp.asarray(v) for kk, v in stack_programs(
        [compile_filter(F.Range("f0", 20.0, 80.0), SCHEMA)] * 6).items()}
    args = (codes, jnp.asarray(pn), jnp.asarray(pi), jnp.asarray(pf), qs,
            progs, jnp.asarray(cb.centroids), jnp.asarray(pv))
    ji, jd = pq_prefbf_topk(*args, k=10, rerank=2, chunk=256)
    ki, kd = pq_prefbf_topk(*args, k=10, rerank=2, chunk=256, use_pallas=True)
    np.testing.assert_allclose(np.asarray(jd), np.asarray(kd),
                               rtol=1e-5, atol=1e-5)
    assert (np.asarray(ji) == np.asarray(ki)).mean() > 0.99


# ---------------------------------------------------------------------------
# end-to-end recall through FavorIndex
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def quant_index(small_index, small_dataset):
    vecs, attrs, _ = small_dataset
    return FavorIndex(small_index.index, attrs, quantize="pq", pq_m=8,
                      pq_nbits=6, pq_train_iters=15, rerank=4)


def test_use_pq_requires_quantized_index(small_index, small_dataset):
    vecs, _, schema = small_dataset
    qs = np.zeros((2, vecs.shape[1]), np.float32)
    with pytest.raises(ValueError, match="quantize"):
        small_index.search(qs, F.TrueFilter(), k=5, use_pq=True)


def test_e2e_pq_recall_within_2pts(quant_index, small_dataset):
    vecs, attrs, schema = small_dataset
    rng = np.random.default_rng(21)
    qs = rng.normal(size=(16, vecs.shape[1])).astype(np.float32)
    for name, flt in paper_filters(schema).items():
        mask = F.eval_program(compile_filter(flt, schema), attrs.ints,
                              attrs.floats)
        truth = [refimpl.bruteforce_filtered(vecs, mask, q, 10)[0] for q in qs]
        r_f32 = quant_index.search(qs, flt, k=10, force="brute")
        r_pq = quant_index.search(qs, flt, k=10, force="brute", use_pq=True)
        rec_f32 = np.mean([refimpl.recall_at_k(i[i >= 0], t, 10)
                           for i, t in zip(r_f32.ids, truth)])
        rec_pq = np.mean([refimpl.recall_at_k(i[i >= 0], t, 10)
                          for i, t in zip(r_pq.ids, truth)])
        assert rec_pq >= rec_f32 - 0.02, \
            f"{name}: pq recall {rec_pq:.3f} < f32 {rec_f32:.3f} - 0.02"


def test_e2e_pq_routed_search(quant_index, small_dataset):
    """Default (selector-routed) search works with use_pq: graph queries are
    untouched, brute queries go through the compressed scan."""
    vecs, _, schema = small_dataset
    rng = np.random.default_rng(22)
    qs = rng.normal(size=(8, vecs.shape[1])).astype(np.float32)
    flt = paper_filters(schema)["range_50"]
    res = quant_index.search(qs, flt, k=10, use_pq=True)
    assert np.all(np.sort(res.dists, axis=1) == res.dists)
    assert res.ids.shape == (8, 10)


def test_sq_fallback_e2e(small_index, small_dataset):
    vecs, attrs, schema = small_dataset
    fi = FavorIndex(small_index.index, attrs, quantize="sq", rerank=4)
    assert fi.bytes_per_vector(quantized=True) == vecs.shape[1]
    rng = np.random.default_rng(23)
    qs = rng.normal(size=(6, vecs.shape[1])).astype(np.float32)
    flt = paper_filters(schema)["equality_bool"]
    r_f32 = small_index.search(qs, flt, k=10, force="brute")
    r_sq = fi.search(qs, flt, k=10, force="brute", use_pq=True)
    # int8 scalar quantization + 4x re-rank recovers the exact top-10 here
    assert (r_sq.ids == r_f32.ids).mean() > 0.95


def test_index_save_load_roundtrip_with_codebook(quant_index, small_dataset,
                                                 tmp_path):
    vecs, _, schema = small_dataset
    path = str(tmp_path / "idx")
    quant_index.save(path)
    fi2 = FavorIndex.load(path)
    assert fi2.quantize == "pq"
    np.testing.assert_array_equal(np.asarray(fi2._codes),
                                  np.asarray(quant_index._codes))
    rng = np.random.default_rng(24)
    qs = rng.normal(size=(4, vecs.shape[1])).astype(np.float32)
    flt = paper_filters(schema)["inclusion"]
    r1 = quant_index.search(qs, flt, k=10, force="brute", use_pq=True)
    r2 = fi2.search(qs, flt, k=10, force="brute", use_pq=True)
    np.testing.assert_array_equal(r1.ids, r2.ids)
