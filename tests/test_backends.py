"""Unified search API: SearchOptions validation, legacy shims, router
routing parity between LocalBackend and ShardedBackend, ServeEngine over
both backends (the sharded 2-device run lives in a subprocess)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.core import (BuildSpec, FavorIndex, HnswParams, LocalBackend,
                        QuantSpec, SearchOptions, ShardedBackend,
                        paper_filters, router)
from repro.core import filters as F
from repro.serving import ServeEngine


# ---------------------------------------------------------------------------
# options validation
# ---------------------------------------------------------------------------
def test_search_options_validation():
    with pytest.raises(ValueError, match="force"):
        SearchOptions(force="brutal")          # typo must not auto-route
    with pytest.raises(ValueError, match="k must"):
        SearchOptions(k=0)
    with pytest.raises(ValueError, match="rerank"):
        SearchOptions(rerank=-1)
    assert SearchOptions(rerank=0).rerank == 0  # explicit 0 is preserved
    cfg = SearchOptions(k=5, ef=48, gamma=1.5).search_config()
    assert cfg.k == 5 and cfg.ef == 48 and cfg.gamma == 1.5


def test_quant_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        QuantSpec(kind="opq")
    with pytest.raises(ValueError, match="nbits"):
        QuantSpec(nbits=9)
    with pytest.raises(ValueError, match="prefbf_chunk"):
        BuildSpec(prefbf_chunk=0)


def test_plan_routes_force_and_threshold():
    p = np.array([0.001, 0.5])
    plan = router.plan_routes(p, lam=0.01)
    assert plan.brute.tolist() == [True, False]
    assert router.plan_routes(p, 0.01, "brute").brute.all()
    assert not router.plan_routes(p, 0.01, "graph").brute.any()
    with pytest.raises(ValueError, match="force"):
        router.plan_routes(p, 0.01, "bruteforce")


def test_filter_count_mismatch_is_value_error(small_index, small_dataset):
    vecs, _, schema = small_dataset
    qs = np.zeros((4, vecs.shape[1]), np.float32)
    flt = paper_filters(schema)["equality_bool"]
    with pytest.raises(ValueError, match="one filter per query"):
        small_index.query(qs, [flt] * 3, SearchOptions(k=5, ef=48))


# ---------------------------------------------------------------------------
# legacy shims
# ---------------------------------------------------------------------------
def test_legacy_search_kwargs_warn_and_match(small_index, small_dataset):
    vecs, _, schema = small_dataset
    rng = np.random.default_rng(31)
    qs = rng.normal(size=(6, vecs.shape[1])).astype(np.float32)
    flt = paper_filters(schema)["equality_bool"]
    with pytest.deprecated_call():
        legacy = small_index.search(qs, flt, k=5, ef=48)
    typed = small_index.query(qs, flt, SearchOptions(k=5, ef=48))
    np.testing.assert_array_equal(legacy.ids, typed.ids)
    np.testing.assert_array_equal(legacy.routed_brute, typed.routed_brute)


def test_legacy_build_kwargs_warn(small_index, small_dataset):
    vecs, attrs, _ = small_dataset
    with pytest.deprecated_call():
        fi = FavorIndex(small_index.index, attrs, quantize="sq", rerank=2)
    assert fi.quantize == "sq" and fi.rerank == 2
    assert fi.spec.quant == QuantSpec(kind="sq", rerank=2)
    # pre-1.1 third positional was sel_cfg
    from repro.core.selector import SelectorConfig
    with pytest.deprecated_call():
        fi = FavorIndex(small_index.index, attrs, SelectorConfig(lam=0.02))
    assert fi.sel_cfg.lam == 0.02
    with pytest.raises(TypeError, match="BuildSpec"):
        FavorIndex(small_index.index, attrs, {"quant": None})


def test_legacy_engine_kwargs_warn(small_index):
    with pytest.deprecated_call():
        eng = ServeEngine(small_index, k=5, ef=48, max_batch=8)
    assert eng.opts == SearchOptions(k=5, ef=48)
    with pytest.raises(ValueError, match="not both"):
        ServeEngine(small_index, SearchOptions(), k=5)
    # pre-1.1 second positional was k
    with pytest.deprecated_call():
        eng = ServeEngine(small_index, 5)
    assert eng.opts.k == 5
    with pytest.raises(TypeError, match="SearchOptions"):
        ServeEngine(small_index, {"k": 5})


def test_loaded_codebook_round_trips_quant_spec(small_index, small_dataset,
                                                tmp_path):
    """fi.spec must describe the codebook actually attached (not defaults),
    so it can rebuild an equivalent backend elsewhere."""
    vecs, attrs, _ = small_dataset
    fi = FavorIndex(small_index.index, attrs,
                    BuildSpec(quant=QuantSpec(m=4, nbits=5, train_iters=5,
                                              rerank=2)))
    fi.save(str(tmp_path / "idx"))
    fi2 = FavorIndex.load(str(tmp_path / "idx"))
    assert fi2.spec.quant.kind == "pq"
    assert fi2.spec.quant.m == 4 and fi2.spec.quant.nbits == 5


def test_sharded_sample_bounds(small_dataset):
    """build_sharded honors SelectorConfig-style min/max sample bounds."""
    from repro.core import distributed as dist
    vecs, attrs, _ = small_dataset
    hi = dist.build_sharded(vecs, attrs, 2, HnswParams(M=8, efc=32),
                            min_sample=256)
    assert hi.sample_rows * 2 >= 256
    lo = dist.build_sharded(vecs, attrs, 2, HnswParams(M=8, efc=32),
                            sample_rate=0.5, max_sample=128)
    assert lo.sample_rows * 2 <= 128


def test_explicit_rerank_zero_honored(small_index, small_dataset):
    """Regression for the falsy-kwarg bug: rerank=0 must NOT fall back to
    the index default (4).  rerank=0 and rerank=1 both exact-re-rank exactly
    the top-k ADC candidates, so their results must coincide."""
    vecs, attrs, schema = small_dataset
    fi = FavorIndex(small_index.index, attrs,
                    BuildSpec(quant=QuantSpec(m=8, nbits=4, train_iters=8,
                                              rerank=4)))
    rng = np.random.default_rng(33)
    qs = rng.normal(size=(5, vecs.shape[1])).astype(np.float32)
    flt = paper_filters(schema)["range_50"]
    base = SearchOptions(k=10, force="brute", use_pq=True)
    r0 = fi.query(qs, flt, base.with_(rerank=0))
    r1 = fi.query(qs, flt, base.with_(rerank=1))
    np.testing.assert_array_equal(r0.ids, r1.ids)


# ---------------------------------------------------------------------------
# backend parity on a single device (mesh 1x1)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def backends_1dev(small_index, small_dataset):
    vecs, attrs, _ = small_dataset
    spec = BuildSpec(hnsw=HnswParams(M=8, efc=48, seed=3),
                     quant=QuantSpec(m=8, nbits=5, train_iters=10, rerank=4))
    local = LocalBackend(FavorIndex(small_index.index, attrs,
                                    BuildSpec(quant=spec.quant)))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shard = ShardedBackend.build(vecs, attrs, mesh, spec,
                                 codebook=local.index.codebook)
    return local, shard


def test_backend_route_parity_1dev(backends_1dev, small_dataset):
    vecs, attrs, schema = small_dataset
    local, shard = backends_1dev
    rng = np.random.default_rng(40)
    qs = rng.normal(size=(6, vecs.shape[1])).astype(np.float32)
    opts = SearchOptions(k=10, ef=64)
    for name, flt in paper_filters(schema).items():
        rl = router.execute(local, qs, flt, opts)
        rs = router.execute(shard, qs, flt, opts)
        # same selector, psum-combined estimate -> same routing decisions
        sel = float(F.eval_program(F.compile_filter(flt, schema), attrs.ints,
                                   attrs.floats).mean())
        if not 0.005 <= sel <= 0.02:  # skip the lambda boundary band
            np.testing.assert_array_equal(rl.routed_brute, rs.routed_brute,
                                          err_msg=name)
        # two independent 256-row samples: allow 3 sigma of estimator noise
        tol = 3.0 * np.sqrt(2.0 * sel * (1.0 - sel) / 256) + 0.01
        assert abs(rl.p_hat.mean() - rs.p_hat.mean()) < tol, name


def test_backend_brute_parity_1dev(backends_1dev, small_dataset):
    """Exact float32 brute scans must agree on global row ids; the sharded
    PQ brute (ADC scan + per-shard exact re-rank) must track the local PQ
    result within a small recall tolerance."""
    vecs, attrs, schema = small_dataset
    local, shard = backends_1dev
    rng = np.random.default_rng(41)
    qs = rng.normal(size=(5, vecs.shape[1])).astype(np.float32)
    flt = paper_filters(schema)["equality_int"]
    f32 = SearchOptions(k=10, ef=64, force="brute")
    rl = router.execute(local, qs, flt, f32)
    rs = router.execute(shard, qs, flt, f32)
    np.testing.assert_array_equal(rl.ids, rs.ids)

    pq = f32.with_(use_pq=True)
    rlq = router.execute(local, qs, flt, pq)
    rsq = router.execute(shard, qs, flt, pq)
    # same codebook, same rows -> overwhelmingly the same re-ranked ids
    agree = float((rlq.ids == rsq.ids).mean())
    assert agree > 0.9, agree
    assert shard.bytes_per_vector(quantized=True) == \
        local.index.bytes_per_vector(quantized=True)


def test_serve_engine_over_sharded_backend_1dev(backends_1dev, small_dataset):
    """The acceptance bar: ServeEngine runs unmodified over ShardedBackend."""
    vecs, _, schema = small_dataset
    _, shard = backends_1dev
    eng = ServeEngine(shard, SearchOptions(k=5, ef=48, use_pq=True),
                      max_batch=8)
    rng = np.random.default_rng(42)
    flts = list(paper_filters(schema).values())
    rids = [eng.submit(rng.normal(size=(vecs.shape[1],)).astype(np.float32),
                       flts[i % len(flts)]) for i in range(20)]
    out = eng.run()
    assert sorted(r.rid for r in out) == sorted(rids)
    assert eng.stats["graph"] + eng.stats["brute"] == 20


def test_sharded_use_pq_without_codebook_raises(small_dataset):
    vecs, attrs, _ = small_dataset
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shard = ShardedBackend.build(vecs, attrs, mesh,
                                 BuildSpec(hnsw=HnswParams(M=8, efc=32)))
    with pytest.raises(ValueError, match="quantize"):
        router.execute(shard, np.zeros((2, vecs.shape[1]), np.float32),
                       F.TrueFilter(), SearchOptions(k=5, use_pq=True))


# ---------------------------------------------------------------------------
# 2-shard parity (subprocess: needs its own device count)
# ---------------------------------------------------------------------------
SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    from repro.core import (BuildSpec, FavorIndex, HnswParams, LocalBackend,
                            QuantSpec, SearchOptions, ShardedBackend,
                            paper_filters, refimpl, router)
    from repro.core import filters as F
    from repro.serving import ServeEngine

    assert len(jax.devices()) == 2
    rng = np.random.default_rng(0)
    N, d = 2048, 16
    vecs = rng.normal(size=(N, d)).astype(np.float32)
    schema = F.paper_schema()
    attrs = F.random_attributes(schema, N, seed=1)
    spec = BuildSpec(hnsw=HnswParams(M=8, efc=40, seed=0),
                     quant=QuantSpec(m=8, nbits=6, train_iters=10, rerank=4))
    local = LocalBackend(FavorIndex.build(vecs, attrs, spec=spec))
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    shard = ShardedBackend.build(vecs, attrs, mesh, spec,
                                 codebook=local.index.codebook)
    assert shard.sharded.n_shards == 2
    assert shard.sharded.arrays["codes"].shape == (N, 8)

    Q = 8
    qs = rng.normal(size=(Q, d)).astype(np.float32)
    opts = SearchOptions(k=10, ef=64)
    for name, flt in paper_filters(schema).items():
        mask = F.eval_program(F.compile_filter(flt, schema), attrs.ints,
                              attrs.floats)
        sel = float(mask.mean())
        rl = router.execute(local, qs, flt, opts)
        rs = router.execute(shard, qs, flt, opts)
        if not 0.005 <= sel <= 0.02:
            assert (rl.routed_brute == rs.routed_brute).all(), name
        truth = [refimpl.bruteforce_filtered(vecs, mask, q, 10)[0] for q in qs]
        rec_l = np.mean([refimpl.recall_at_k(rl.ids[i], truth[i], 10)
                         for i in range(Q)])
        rec_s = np.mean([refimpl.recall_at_k(rs.ids[i], truth[i], 10)
                         for i in range(Q)])
        assert rec_s >= rec_l - 0.1, (name, rec_l, rec_s)

    # exact f32 brute parity across the 2-shard merge
    flt = paper_filters(schema)["equality_int"]
    mask = F.eval_program(F.compile_filter(flt, schema), attrs.ints,
                          attrs.floats)
    f32 = SearchOptions(k=10, ef=64, force="brute")
    rl = router.execute(local, qs, flt, f32)
    rs = router.execute(shard, qs, flt, f32)
    assert (rl.ids == rs.ids).all()

    # sharded PQ brute: codes streamed per shard, exact re-rank -> recall
    # within 2pts of the f32 scan (same bar as the local quant tests)
    pq = f32.with_(use_pq=True)
    rsq = router.execute(shard, qs, flt, pq)
    truth = [refimpl.bruteforce_filtered(vecs, mask, q, 10)[0] for q in qs]
    rec_f32 = np.mean([refimpl.recall_at_k(rs.ids[i], truth[i], 10)
                       for i in range(Q)])
    rec_pq = np.mean([refimpl.recall_at_k(rsq.ids[i], truth[i], 10)
                      for i in range(Q)])
    assert rec_pq >= rec_f32 - 0.02, (rec_f32, rec_pq)

    # one unmodified ServeEngine over both backends
    for backend in (local, shard):
        eng = ServeEngine(backend, SearchOptions(k=10, ef=64, use_pq=True),
                          max_batch=8)
        for i in range(12):
            eng.submit(qs[i % Q], flt)
        out = eng.run()
        assert len(out) == 12
    print("BACKEND_PARITY_OK", rec_f32, rec_pq)
""")


@pytest.mark.slow
def test_backend_parity_2shard():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "BACKEND_PARITY_OK" in r.stdout
