"""Filter algebra + DNF compiler: unit and property tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e '.[dev]')")
from hypothesis import given, settings, strategies as st

from repro.core import filters as F

SCHEMA = F.paper_schema(n_bool=1, n_int=2, n_float=2)


def _mask(flt, attrs):
    prog = F.compile_filter(flt, SCHEMA)
    return F.eval_program(prog, attrs.ints, attrs.floats)


@pytest.fixture(scope="module")
def attrs():
    return F.random_attributes(SCHEMA, 500, seed=0)


def test_equality_bool(attrs):
    m = _mask(F.Equality("b0", True), attrs)
    assert m.sum() == (attrs.ints[:, 0] == 1).sum()


def test_equality_int(attrs):
    m = _mask(F.Equality("i0", 3), attrs)
    np.testing.assert_array_equal(m, attrs.ints[:, 1] == 3)


def test_inclusion(attrs):
    m = _mask(F.Inclusion("i1", [1, 4, 7]), attrs)
    np.testing.assert_array_equal(m, np.isin(attrs.ints[:, 2], [1, 4, 7]))


def test_range_float(attrs):
    m = _mask(F.Range("f0", 20.0, 60.0), attrs)
    col = attrs.floats[:, 0]
    np.testing.assert_array_equal(m, (col >= 20.0) & (col <= 60.0))


def test_range_int(attrs):
    m = _mask(F.Range("i0", 2, 5), attrs)
    col = attrs.ints[:, 1]
    np.testing.assert_array_equal(m, (col >= 2) & (col <= 5))


def test_logic_and_or_not(attrs):
    f = F.And(F.Equality("b0", True), F.Or(F.Range("f0", None, 50.0),
                                           F.Not(F.Inclusion("i0", [0, 1, 2]))))
    m = _mask(f, attrs)
    expect = np.array([F.eval_filter_python(f, attrs.row(i)) for i in range(attrs.n)])
    np.testing.assert_array_equal(m, expect)


def test_true_false(attrs):
    assert _mask(F.TrueFilter(), attrs).all()
    assert not _mask(F.FalseFilter(), attrs).any()


def test_not_range_strict_bounds(attrs):
    f = F.Not(F.Range("f1", 25.0, 75.0))
    m = _mask(f, attrs)
    col = attrs.floats[:, 1]
    np.testing.assert_array_equal(m, (col < 25.0) | (col > 75.0))


def test_width_overflow_raises():
    clauses = [F.Not(F.Range("f0", i * 10.0, i * 10.0 + 5.0)) for i in range(8)]
    with pytest.raises(ValueError):
        F.compile_filter(F.Or(*[F.And(*clauses)]), SCHEMA, width=4)


def test_stack_programs_pads():
    p1 = F.compile_filter(F.Equality("b0", True), SCHEMA, width=2)
    p2 = F.compile_filter(F.Not(F.Range("f0", 10.0, 20.0)), SCHEMA, width=4)
    batch = F.stack_programs([p1, p2])
    assert batch["valid"].shape == (2, 4)


def test_gathered_eval_matches_batched(attrs):
    progs = [F.compile_filter(F.Equality("i0", v), SCHEMA) for v in (1, 2, 3)]
    batch = F.stack_programs(progs)
    rows = np.array([[0, 1, 2], [3, 4, 5], [6, 7, 8]])
    g = F.eval_program_gathered(batch, attrs.ints[rows], attrs.floats[rows])
    for b in range(3):
        full = F.eval_program(progs[b], attrs.ints, attrs.floats)
        np.testing.assert_array_equal(g[b], full[rows[b]])


# -- property: compiled program == AST interpreter ---------------------------
@st.composite
def filter_trees(draw, depth=0):
    leaf = st.one_of(
        st.builds(F.Equality, st.just("b0"), st.booleans()),
        st.builds(F.Equality, st.just("i0"), st.integers(0, 9)),
        st.builds(lambda v: F.Inclusion("i1", v),
                  st.lists(st.integers(0, 9), min_size=1, max_size=4)),
        st.builds(lambda lo, w: F.Range("f0", lo, lo + w),
                  st.floats(0, 90, allow_nan=False, width=32),
                  st.floats(0.5, 50, allow_nan=False, width=32)),
        st.builds(lambda lo, w: F.Range("f1", lo, lo + w),
                  st.floats(0, 90, allow_nan=False, width=32),
                  st.floats(0.5, 50, allow_nan=False, width=32)),
    )
    if depth >= 2:
        return draw(leaf)
    sub = filter_trees(depth=depth + 1)
    return draw(st.one_of(
        leaf,
        st.builds(lambda a, b: F.And(a, b), sub, sub),
        st.builds(lambda a, b: F.Or(a, b), sub, sub),
        st.builds(F.Not, leaf),
    ))


@settings(max_examples=60, deadline=None)
@given(filter_trees())
def test_property_program_matches_ast(flt):
    attrs = F.random_attributes(SCHEMA, 200, seed=42)
    try:
        prog = F.compile_filter(flt, SCHEMA, width=16)
    except ValueError:
        return  # DNF width overflow is allowed to raise
    m = F.eval_program(prog, attrs.ints, attrs.floats)
    expect = np.array([F.eval_filter_python(flt, attrs.row(i)) for i in range(attrs.n)])
    np.testing.assert_array_equal(m, expect)


# -- property: canonical signatures (cache keys) -----------------------------
def _equivalent_rewrite(f):
    """A semantically identical AST: AND/OR children reversed recursively,
    leaves double-negated."""
    if isinstance(f, F.And):
        return F.And(*[_equivalent_rewrite(c) for c in reversed(f.children)])
    if isinstance(f, F.Or):
        return F.Or(*[_equivalent_rewrite(c) for c in reversed(f.children)])
    if isinstance(f, F.Not):
        return F.Not(_equivalent_rewrite(f.child))
    return F.Not(F.Not(f))


@settings(max_examples=60, deadline=None)
@given(filter_trees())
def test_property_signature_invariant_under_equivalence(flt):
    """Reordered conjuncts/disjuncts, double negation, duplicated or
    absorbed disjuncts, and AND/OR identities all share one signature."""
    try:
        sig = F.filter_signature(flt, SCHEMA, width=16)
        variants = [
            _equivalent_rewrite(flt),
            F.Not(F.Not(flt)),
            F.Or(flt, flt),
            F.Or(flt, F.FalseFilter()),
            F.And(flt, F.TrueFilter()),
            F.And(flt, flt),
        ]
        for v in variants:
            assert F.filter_signature(v, SCHEMA, width=16) == sig
    except ValueError:
        return  # DNF width overflow is allowed to raise


@settings(max_examples=60, deadline=None)
@given(filter_trees(), filter_trees())
def test_property_equal_signature_implies_equal_semantics(f1, f2):
    """Soundness: equal signatures must evaluate identically on every row
    (a cache key collision would silently serve wrong results)."""
    try:
        s1 = F.filter_signature(f1, SCHEMA, width=16)
        s2 = F.filter_signature(f2, SCHEMA, width=16)
        p1 = F.compile_filter(f1, SCHEMA, width=16)
        p2 = F.compile_filter(f2, SCHEMA, width=16)
    except ValueError:
        return
    attrs = F.random_attributes(SCHEMA, 300, seed=43)
    m1 = F.eval_program(p1, attrs.ints, attrs.floats)
    m2 = F.eval_program(p2, attrs.ints, attrs.floats)
    if s1 == s2:
        np.testing.assert_array_equal(m1, m2)
    elif not np.array_equal(m1, m2):
        assert s1 != s2  # contrapositive (always true here; documents intent)
