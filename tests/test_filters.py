"""Filter algebra + DNF compiler: unit and property tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e '.[dev]')")
from hypothesis import given, settings, strategies as st

from repro.core import filters as F

SCHEMA = F.paper_schema(n_bool=1, n_int=2, n_float=2)


def _mask(flt, attrs):
    prog = F.compile_filter(flt, SCHEMA)
    return F.eval_program(prog, attrs.ints, attrs.floats)


@pytest.fixture(scope="module")
def attrs():
    return F.random_attributes(SCHEMA, 500, seed=0)


def test_equality_bool(attrs):
    m = _mask(F.Equality("b0", True), attrs)
    assert m.sum() == (attrs.ints[:, 0] == 1).sum()


def test_equality_int(attrs):
    m = _mask(F.Equality("i0", 3), attrs)
    np.testing.assert_array_equal(m, attrs.ints[:, 1] == 3)


def test_inclusion(attrs):
    m = _mask(F.Inclusion("i1", [1, 4, 7]), attrs)
    np.testing.assert_array_equal(m, np.isin(attrs.ints[:, 2], [1, 4, 7]))


def test_range_float(attrs):
    m = _mask(F.Range("f0", 20.0, 60.0), attrs)
    col = attrs.floats[:, 0]
    np.testing.assert_array_equal(m, (col >= 20.0) & (col <= 60.0))


def test_range_int(attrs):
    m = _mask(F.Range("i0", 2, 5), attrs)
    col = attrs.ints[:, 1]
    np.testing.assert_array_equal(m, (col >= 2) & (col <= 5))


def test_logic_and_or_not(attrs):
    f = F.And(F.Equality("b0", True), F.Or(F.Range("f0", None, 50.0),
                                           F.Not(F.Inclusion("i0", [0, 1, 2]))))
    m = _mask(f, attrs)
    expect = np.array([F.eval_filter_python(f, attrs.row(i)) for i in range(attrs.n)])
    np.testing.assert_array_equal(m, expect)


def test_true_false(attrs):
    assert _mask(F.TrueFilter(), attrs).all()
    assert not _mask(F.FalseFilter(), attrs).any()


def test_not_range_strict_bounds(attrs):
    f = F.Not(F.Range("f1", 25.0, 75.0))
    m = _mask(f, attrs)
    col = attrs.floats[:, 1]
    np.testing.assert_array_equal(m, (col < 25.0) | (col > 75.0))


def test_width_overflow_raises():
    clauses = [F.Not(F.Range("f0", i * 10.0, i * 10.0 + 5.0)) for i in range(8)]
    with pytest.raises(ValueError):
        F.compile_filter(F.Or(*[F.And(*clauses)]), SCHEMA, width=4)


def test_stack_programs_pads():
    p1 = F.compile_filter(F.Equality("b0", True), SCHEMA, width=2)
    p2 = F.compile_filter(F.Not(F.Range("f0", 10.0, 20.0)), SCHEMA, width=4)
    batch = F.stack_programs([p1, p2])
    assert batch["valid"].shape == (2, 4)


def test_gathered_eval_matches_batched(attrs):
    progs = [F.compile_filter(F.Equality("i0", v), SCHEMA) for v in (1, 2, 3)]
    batch = F.stack_programs(progs)
    rows = np.array([[0, 1, 2], [3, 4, 5], [6, 7, 8]])
    g = F.eval_program_gathered(batch, attrs.ints[rows], attrs.floats[rows])
    for b in range(3):
        full = F.eval_program(progs[b], attrs.ints, attrs.floats)
        np.testing.assert_array_equal(g[b], full[rows[b]])


# -- property: compiled program == AST interpreter ---------------------------
@st.composite
def filter_trees(draw, depth=0):
    leaf = st.one_of(
        st.builds(F.Equality, st.just("b0"), st.booleans()),
        st.builds(F.Equality, st.just("i0"), st.integers(0, 9)),
        st.builds(lambda v: F.Inclusion("i1", v),
                  st.lists(st.integers(0, 9), min_size=1, max_size=4)),
        st.builds(lambda lo, w: F.Range("f0", lo, lo + w),
                  st.floats(0, 90, allow_nan=False, width=32),
                  st.floats(0.5, 50, allow_nan=False, width=32)),
        st.builds(lambda lo, w: F.Range("f1", lo, lo + w),
                  st.floats(0, 90, allow_nan=False, width=32),
                  st.floats(0.5, 50, allow_nan=False, width=32)),
    )
    if depth >= 2:
        return draw(leaf)
    sub = filter_trees(depth=depth + 1)
    return draw(st.one_of(
        leaf,
        st.builds(lambda a, b: F.And(a, b), sub, sub),
        st.builds(lambda a, b: F.Or(a, b), sub, sub),
        st.builds(F.Not, leaf),
    ))


@settings(max_examples=60, deadline=None)
@given(filter_trees())
def test_property_program_matches_ast(flt):
    attrs = F.random_attributes(SCHEMA, 200, seed=42)
    try:
        prog = F.compile_filter(flt, SCHEMA, width=16)
    except ValueError:
        return  # DNF width overflow is allowed to raise
    m = F.eval_program(prog, attrs.ints, attrs.floats)
    expect = np.array([F.eval_filter_python(flt, attrs.row(i)) for i in range(attrs.n)])
    np.testing.assert_array_equal(m, expect)
