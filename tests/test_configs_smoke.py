"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs (full configs are exercised only via the
dry-run's ShapeDtypeStructs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_spec
from repro.data import synthetic
from repro.models import gnn, recsys
from repro.models.module import init_with_axes, param_count
from repro.models.transformer import (decode_step, init_lm, lm_loss,
                                      make_cache_specs, prefill)
from repro.training import optimizer as opt
from repro.training.step import make_train_step

LM_ARCHS = ["olmoe-1b-7b", "arctic-480b", "qwen1.5-32b",
            "command-r-plus-104b", "gemma2-2b"]
RS_ARCHS = ["fm", "wide-deep", "dien", "dlrm-rm2"]


def _finite(tree):
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


# ---------------------------------------------------------------------------
# LM architectures
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_train_step(arch):
    spec = get_spec(arch)
    cfg = spec.reduced
    params, _ = init_with_axes(init_lm, jax.random.key(0), cfg)
    assert param_count(params) > 0
    pipe = synthetic.TokenPipeline(vocab=cfg.vocab, seq_len=16, batch=4, seed=1)
    batch, _ = pipe(0)

    def loss_fn(p, b):
        return lm_loss(p, cfg, jnp.asarray(b["tokens"]), jnp.asarray(b["labels"]))

    step = make_train_step(loss_fn, opt.OptConfig(lr=1e-3, total_steps=10))
    st = opt.init_opt_state(params, opt.OptConfig())
    params2, st2, metrics = jax.jit(step)(params, st, batch)
    assert jnp.isfinite(metrics["loss"])
    assert _finite(params2), f"{arch}: NaN params after update"
    assert int(st2.step) == 1


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_prefill_decode(arch):
    spec = get_spec(arch)
    cfg = spec.reduced
    params, _ = init_with_axes(init_lm, jax.random.key(1), cfg)
    toks = jax.random.randint(jax.random.key(2), (2, 8), 0, cfg.vocab)
    logits, caches = jax.jit(lambda p, t: prefill(p, cfg, t, 16))(params, toks)
    assert logits.shape == (2, cfg.vocab)
    assert caches["k"].shape == (cfg.n_layers, 2, 16, cfg.n_kv, cfg.hd)
    assert bool(jnp.isfinite(logits).all())
    nxt = jnp.argmax(logits, -1)[:, None]
    logits2, caches2 = jax.jit(
        lambda p, t, c: decode_step(p, cfg, t, c, jnp.asarray(8)))(params, nxt, caches)
    assert logits2.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(logits2).all())


def test_lm_loss_decreases():
    cfg = get_spec("gemma2-2b").reduced
    params, _ = init_with_axes(init_lm, jax.random.key(3), cfg)
    pipe = synthetic.TokenPipeline(vocab=cfg.vocab, seq_len=32, batch=16, seed=2)

    def loss_fn(p, b):
        return lm_loss(p, cfg, jnp.asarray(b["tokens"]), jnp.asarray(b["labels"]))

    ocfg = opt.OptConfig(lr=1e-2, total_steps=80, warmup_steps=5)
    step = jax.jit(make_train_step(loss_fn, ocfg))
    st = opt.init_opt_state(params, ocfg)
    state, losses = 0, []
    for i in range(60):
        batch, state = pipe(state)
        params, st, m = step(params, st, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------
def test_gcn_full_graph():
    cfg = get_spec("gcn-cora").reduced
    g = synthetic.make_random_graph(300, 1200, cfg.d_feat, cfg.n_classes, seed=0)
    params, _ = init_with_axes(gnn.init_gcn, jax.random.key(0), cfg)

    def loss_fn(p, b):
        return gnn.gcn_loss(p, cfg, jnp.asarray(b["x"]), jnp.asarray(b["edges"]),
                            jnp.asarray(b["deg"]), jnp.asarray(b["labels"]),
                            jnp.asarray(b["mask"]))

    step = jax.jit(make_train_step(loss_fn, opt.OptConfig(lr=1e-2, total_steps=20)))
    st = opt.init_opt_state(params, opt.OptConfig())
    first = last = None
    for i in range(20):
        params, st, m = step(params, st, g)
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first  # learnable signal propagates through segment_sum


def test_gcn_minibatch_sampler():
    from repro.data.graphs import CSRGraph, sample_subgraph
    cfg = get_spec("gcn-cora").reduced
    g = synthetic.make_random_graph(2000, 12000, cfg.d_feat, cfg.n_classes, seed=1)
    csr = CSRGraph.from_edges(g["edges"], 2000)
    rng = np.random.default_rng(0)
    seeds = rng.choice(2000, 64, replace=False)
    sub = sample_subgraph(csr, g["x"], g["labels"], seeds, (5, 3), rng)
    assert sub["x"].shape[0] == 64 + 64 * 5 + 64 * 5 * 3
    params, _ = init_with_axes(gnn.init_gcn, jax.random.key(1), cfg)
    loss, m = jax.jit(lambda p: gnn.gcn_loss(
        p, cfg, jnp.asarray(sub["x"]), jnp.asarray(sub["edges"]),
        jnp.asarray(sub["deg"]), jnp.asarray(sub["labels"]),
        jnp.asarray(sub["mask"])))(params)
    assert bool(jnp.isfinite(loss))


def test_gcn_molecule_batch():
    from repro.models.gnn import GCNConfig
    cfg = GCNConfig(name="mol-red", n_layers=2, d_feat=32, d_hidden=16,
                    n_classes=2, readout="graph")
    b = synthetic.make_molecule_batch(8, 30, 64, 32, seed=2)
    params, _ = init_with_axes(gnn.init_gcn, jax.random.key(2), cfg)
    loss, m = jax.jit(lambda p: gnn.gcn_loss(
        p, cfg, jnp.asarray(b["x"]), jnp.asarray(b["edges"]),
        jnp.asarray(b["deg"]), jnp.asarray(b["labels"]), jnp.asarray(b["mask"]),
        graph_ids=jnp.asarray(b["graph_ids"]), n_graphs=8))(params)
    assert bool(jnp.isfinite(loss))


# ---------------------------------------------------------------------------
# RecSys architectures
# ---------------------------------------------------------------------------
def _rs_batch(arch, cfg, batch=32):
    if arch == "dien":
        pipe = synthetic.RecsysPipeline(n_sparse=0, vocab=cfg.vocab,
                                        batch=batch, seq_len=cfg.seq_len, seed=3)
    elif arch == "dlrm-rm2":
        pipe = synthetic.RecsysPipeline(n_sparse=cfg.n_sparse, vocab=cfg.vocab,
                                        batch=batch, n_dense=cfg.n_dense, seed=3)
    else:
        pipe = synthetic.RecsysPipeline(n_sparse=cfg.n_sparse, vocab=cfg.vocab,
                                        batch=batch, seed=3)
    return pipe(0)[0]


@pytest.mark.parametrize("arch", RS_ARCHS)
def test_recsys_train_step(arch):
    spec = get_spec(arch)
    cfg = spec.reduced
    b = _rs_batch(arch, cfg)
    if arch == "fm":
        init, lf = recsys.init_fm, lambda p, bb: recsys.fm_loss(
            p, cfg, jnp.asarray(bb["ids"]), jnp.asarray(bb["labels"]))
    elif arch == "wide-deep":
        init, lf = recsys.init_wide_deep, lambda p, bb: recsys.wide_deep_loss(
            p, cfg, jnp.asarray(bb["ids"]), jnp.asarray(bb["labels"]))
    elif arch == "dien":
        init, lf = recsys.init_dien, lambda p, bb: recsys.dien_loss(
            p, cfg, jnp.asarray(bb["hist"]), jnp.asarray(bb["target"]),
            jnp.asarray(bb["labels"]))
    else:
        init, lf = recsys.init_dlrm, lambda p, bb: recsys.dlrm_loss(
            p, cfg, jnp.asarray(bb["dense"]), jnp.asarray(bb["ids"]),
            jnp.asarray(bb["labels"]))
    params, _ = init_with_axes(init, jax.random.key(4), cfg)
    step = jax.jit(make_train_step(lf, opt.OptConfig(lr=1e-3, total_steps=10)))
    st = opt.init_opt_state(params, opt.OptConfig())
    params2, st2, m = step(params, st, b)
    assert jnp.isfinite(m["loss"])
    assert _finite(params2), f"{arch}: NaN after update"


def test_recsys_retrieval_cell():
    """retrieval_cand semantics on the reduced scale: FAVOR kernel == jnp."""
    from repro.core import compile_filter, paper_schema, random_attributes, stack_programs
    from repro.core import filters as F
    rng = np.random.default_rng(0)
    items = jnp.asarray(rng.normal(size=(2000, 16)).astype(np.float32))
    user = jnp.asarray(rng.normal(size=(1, 16)).astype(np.float32))
    schema = paper_schema()
    at = random_attributes(schema, 2000, seed=5)
    progs = {k: jnp.asarray(v) for k, v in stack_programs(
        [compile_filter(F.Range("f0", 0.0, 60.0), schema)]).items()}
    i_j, s_j = recsys.retrieval_topk_filtered(
        user, items, progs, jnp.asarray(at.ints), jnp.asarray(at.floats), k=20)
    i_p, s_p = recsys.retrieval_topk_filtered(
        user, items, progs, jnp.asarray(at.ints), jnp.asarray(at.floats), k=20,
        use_pallas=True)
    assert np.array_equal(np.asarray(i_j), np.asarray(i_p))
    np.testing.assert_allclose(np.asarray(s_j), np.asarray(s_p), rtol=1e-4,
                               atol=1e-4)


def test_microbatch_accumulation_equivalence():
    """grad-accum path == single-batch path (same loss, close params)."""
    cfg = get_spec("fm").reduced
    params, _ = init_with_axes(recsys.init_fm, jax.random.key(7), cfg)
    b = _rs_batch("fm", cfg, batch=32)

    def lf(p, bb):
        return recsys.fm_loss(p, cfg, jnp.asarray(bb["ids"]),
                              jnp.asarray(bb["labels"]))

    ocfg = opt.OptConfig(lr=1e-3, total_steps=10)
    s1 = jax.jit(make_train_step(lf, ocfg, microbatches=1))
    s4 = jax.jit(make_train_step(lf, ocfg, microbatches=4))
    st = opt.init_opt_state(params, ocfg)
    p1, _, m1 = s1(params, st, b)
    p4, _, m4 = s4(params, st, b)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-5
    d = max(float(jnp.max(jnp.abs(a - bb)))
            for a, bb in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 1e-5
