"""Live index subsystem: delta segments, tombstones, scoped epochs, merge.

Covers the PR acceptance matrix: empty-delta bit-parity on local / sharded /
caching backends, upsert-is-found / delete-is-gone on every route (including
warm candidate and semantic caches), scoped epoch invalidation (vector-only
upsert keeps the selectivity cache warm), merge equivalence against exact
ground truth, the graph_arrays no-re-upload regression, quantization
persistence, the bulk-build recall bound, and the index edge cases the
mutation path exposes (empty / single-element / delete-everything /
delta-only).
"""
import numpy as np
import pytest

import jax

from repro.cache import CachingBackend
from repro.core import (BuildSpec, FavorIndex, HnswParams, LocalBackend,
                        QuantSpec, SearchOptions, ShardedBackend,
                        paper_schema, random_attributes, router)
from repro.core import filters as F
from repro.core.options import CacheSpec
from repro.index import ComponentEpochs, DeltaSegment, compose_topk
from repro.index.bulk import build_hnsw_bulk
from repro.serving import ServeEngine

OPTS = SearchOptions(k=10, ef=64)


@pytest.fixture(scope="module")
def ds():
    rng = np.random.default_rng(21)
    n, d = 768, 16
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    schema = paper_schema()
    attrs = random_attributes(schema, n, seed=13)
    return vecs, attrs, schema


def _fresh_local(ds):
    vecs, attrs, _ = ds
    return LocalBackend(FavorIndex.build(
        vecs, attrs, HnswParams(M=8, efc=48, seed=3)))


def _exact_topk(vecs, queries, rows, k):
    """Host ground-truth top-k of ``queries`` over the ``rows`` subset."""
    ids = np.full((len(queries), k), -1, np.int64)
    ds_ = np.full((len(queries), k), np.inf, np.float32)
    if len(rows) == 0:
        return ids, ds_
    sub = vecs[rows]
    d = np.sqrt(np.maximum(
        np.sum(queries ** 2, 1)[:, None] + np.sum(sub ** 2, 1)[None, :]
        - 2.0 * queries @ sub.T, 0.0)).astype(np.float32)
    kk = min(k, len(rows))
    order = np.argsort(d, axis=1, kind="stable")[:, :kk]
    ids[:, :kk] = np.asarray(rows)[order]
    ds_[:, :kk] = np.take_along_axis(d, order, axis=1)
    return ids, ds_


def _matching_attrs(attrs, schema, value=3, count=1):
    """Attribute rows copied from a base row with i0 == value."""
    col = schema.int_index("i0")
    row = int(np.nonzero(attrs.ints[:, col] == value)[0][0])
    return (np.tile(attrs.ints[row], (count, 1)),
            np.tile(attrs.floats[row], (count, 1)))


# ---------------------------------------------------------------------------
# building blocks: epochs, delta segment, top-k composition
# ---------------------------------------------------------------------------
def test_component_epochs():
    e = ComponentEpochs()
    assert e.total == 0
    e.bump("vectors")
    e.bump("vectors", "graph")
    assert e.as_dict() == {"vectors": 2, "attributes": 0, "graph": 1}
    assert e.total == 3
    with pytest.raises(ValueError, match="unknown"):
        e.bump("codes")
    e.bump_all()
    assert e.as_dict() == {"vectors": 3, "attributes": 1, "graph": 2}


def test_delta_segment_growth_and_kill():
    d = DeltaSegment(4, 2, 1, min_capacity=4)
    rng = np.random.default_rng(0)
    v = rng.normal(size=(9, 4)).astype(np.float32)
    slots = d.append(v[:3], np.zeros((3, 2), np.int32),
                     np.zeros((3, 1), np.float32),
                     np.arange(100, 103))
    assert list(slots) == [0, 1, 2] and d._cap == 4
    d.append(v[3:], np.zeros((6, 2), np.int32), np.zeros((6, 1), np.float32),
             np.arange(103, 109))
    assert d.count == 9 and d._cap == 16          # pow-2 growth
    assert d.kill(101) and not d.kill(101)        # second kill: already dead
    assert not d.kill(999)
    assert d.live_count == 8 and d.has(100) and not d.has(101)


def test_compose_topk_merge_and_ties():
    bi = np.array([[5, 7, -1]], np.int64)
    bd = np.array([[1.0, 3.0, np.inf]], np.float32)
    ei = np.array([[9, -1, -1]], np.int64)
    ed = np.array([[2.0, np.inf, np.inf]], np.float32)
    ids, ds_ = compose_topk(bi, bd, ei, ed, 3)
    assert ids.tolist() == [[5, 9, 7]]
    np.testing.assert_array_equal(ds_, [[1.0, 2.0, 3.0]])
    # ties prefer the base side (stable merge keeps static results stable)
    ids, _ = compose_topk(np.array([[5]], np.int64),
                          np.array([[2.0]], np.float32),
                          np.array([[9]], np.int64),
                          np.array([[2.0]], np.float32), 1)
    assert ids.tolist() == [[5]]


# ---------------------------------------------------------------------------
# empty-delta bit-parity on all three backend layers
# ---------------------------------------------------------------------------
def _parity_queries(ds, b=6, seed=31):
    vecs, _, schema = ds
    rng = np.random.default_rng(seed)
    qs = rng.normal(size=(b, vecs.shape[1])).astype(np.float32)
    return qs, F.Equality("i0", 3)


def _assert_bit_identical(r0, r1):
    np.testing.assert_array_equal(r0.ids, r1.ids)
    np.testing.assert_array_equal(r0.dists, r1.dists)
    np.testing.assert_array_equal(r0.routed_brute, r1.routed_brute)


def test_empty_delta_bit_parity_local(ds):
    be = _fresh_local(ds)
    qs, flt = _parity_queries(ds)
    for force in (None, "graph", "brute"):
        opts = OPTS.with_(force=force)
        before = router.execute(be, qs, flt, opts)
        # activate the live path without mutating anything observable
        assert be.delete([10 ** 9]) == 0
        assert be.live_view() is not None
        after = router.execute(be, qs, flt, opts)
        _assert_bit_identical(before, after)


def test_empty_delta_bit_parity_sharded(ds):
    vecs, attrs, _ = ds
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    be = ShardedBackend.build(vecs, attrs, mesh,
                              BuildSpec(hnsw=HnswParams(M=8, efc=48, seed=3)))
    qs, flt = _parity_queries(ds)
    for force in (None, "graph", "brute"):
        opts = OPTS.with_(force=force)
        before = router.execute(be, qs, flt, opts)
        assert be.delete([10 ** 9]) == 0
        after = router.execute(be, qs, flt, opts)
        _assert_bit_identical(before, after)


def test_empty_delta_bit_parity_caching(ds):
    be = CachingBackend(_fresh_local(ds), CacheSpec())
    qs, flt = _parity_queries(ds)
    before = router.execute(be, qs, flt, OPTS)
    assert be.delete([10 ** 9]) == 0
    after = router.execute(be, qs, flt, OPTS)
    _assert_bit_identical(before, after)


# ---------------------------------------------------------------------------
# upsert is found, delete is gone -- on every route
# ---------------------------------------------------------------------------
def test_upsert_found_delete_gone_all_routes(ds):
    vecs, attrs, schema = ds
    be = _fresh_local(ds)
    rng = np.random.default_rng(41)
    q = rng.normal(size=(1, vecs.shape[1])).astype(np.float32)
    ints, floats = _matching_attrs(attrs, schema)
    nid = int(be.upsert(q + 1e-3, ints, floats)[0])
    assert nid == vecs.shape[0]                 # positional id allocation
    flt = F.Equality("i0", 3)
    for force in (None, "graph", "brute"):
        r = router.execute(be, q, flt, OPTS.with_(force=force))
        assert r.ids[0, 0] == nid, force        # nearest by construction
    assert be.delete([nid]) == 1
    for force in (None, "graph", "brute"):
        r = router.execute(be, q, flt, OPTS.with_(force=force))
        assert nid not in r.ids, force
    # replace= retires the old id and issues a fresh handle
    rid = int(be.upsert(q + 2e-3, ints, floats)[0])
    rid2 = int(be.upsert(q + 3e-3, ints, floats, replace=[rid])[0])
    assert rid2 != rid
    r = router.execute(be, q, flt, OPTS.with_(force="brute"))
    assert rid2 in r.ids and rid not in r.ids


def test_base_delete_gone_on_graph_route(ds):
    vecs, attrs, schema = ds
    be = _fresh_local(ds)
    flt = F.Equality("i0", 3)
    rng = np.random.default_rng(43)
    q = rng.normal(size=(1, vecs.shape[1])).astype(np.float32)
    r0 = router.execute(be, q, flt, OPTS.with_(force="graph"))
    victim = int(r0.ids[0, 0])
    assert be.delete([victim]) == 1
    r1 = router.execute(be, q, flt, OPTS.with_(force="graph"))
    assert victim not in r1.ids
    r2 = router.execute(be, q, flt, OPTS.with_(force="brute"))
    assert victim not in r2.ids


def test_sharded_upsert_delete_merge(ds):
    vecs, attrs, schema = ds
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    be = ShardedBackend.build(vecs, attrs, mesh,
                              BuildSpec(hnsw=HnswParams(M=8, efc=48, seed=3)))
    rng = np.random.default_rng(47)
    q = rng.normal(size=(1, vecs.shape[1])).astype(np.float32)
    ints, floats = _matching_attrs(attrs, schema, count=3)
    ids = be.upsert(np.concatenate([q + 1e-3, q + 2e-3, q + 3e-3]),
                    ints, floats)
    flt = F.Equality("i0", 3)
    for force in ("graph", "brute"):
        r = router.execute(be, q, flt, OPTS.with_(force=force))
        assert int(r.ids[0, 0]) == int(ids[0]), force
    assert be.delete([int(ids[0])]) == 1
    for force in ("graph", "brute"):
        r = router.execute(be, q, flt, OPTS.with_(force=force))
        assert int(ids[0]) not in r.ids, force
        assert int(r.ids[0, 0]) == int(ids[1]), force
    out = be.merge(wave=256)
    assert out["merged_slots"] == 3
    assert be.live_stats()["delta_rows"] == 0
    for force in ("graph", "brute"):
        r = router.execute(be, q, flt, OPTS.with_(force=force))
        assert int(r.ids[0, 0]) == int(ids[1]), force
        assert int(ids[0]) not in r.ids, force


# ---------------------------------------------------------------------------
# caches: deleted ids never served, scoped invalidation
# ---------------------------------------------------------------------------
def test_delete_not_served_from_warm_caches(ds):
    vecs, _, _ = ds
    cb = CachingBackend(_fresh_local(ds),
                        CacheSpec(candidate_p_max=0.5))
    rng = np.random.default_rng(53)
    flt = F.Equality("i0", 3)
    opts = OPTS.with_(force="brute")
    # two distinct batches (semantic can't serve them) admit the signature
    # into the candidate cache on its second brute miss...
    for _ in range(2):
        router.execute(cb, rng.normal(size=(4, vecs.shape[1]))
                       .astype(np.float32), flt, opts)
    qs = rng.normal(size=(4, vecs.shape[1])).astype(np.float32)
    cb_r = router.execute(cb, qs, flt, opts)     # ...3rd: candidate hit
    router.execute(cb, qs, flt, opts)            # exact repeat: semantic hit
    st = cb.cache_stats()
    assert st["candidates"]["size"] > 0 and st["candidates"]["hits"] > 0
    assert st["semantic"]["size"] > 0 and st["semantic"]["hits"] > 0
    victim = int(cb_r.ids[0, 0])
    assert cb.delete([victim]) == 1
    r1 = router.execute(cb, qs, flt, opts)
    assert victim not in r1.ids
    # exactness: composed warm-cache results == a fresh uncached backend
    fresh = router.execute(LocalBackend(cb.inner.index), qs, flt, opts)
    np.testing.assert_array_equal(r1.ids, fresh.ids)
    np.testing.assert_allclose(r1.dists, fresh.dists, rtol=1e-5, atol=1e-6)
    # ...and those hits really were served from the warm block
    assert cb.cache_stats()["candidates"]["composed"] > 0


def test_vector_only_upsert_keeps_selectivity_and_candidates_warm(ds):
    vecs, attrs, schema = ds
    cb = CachingBackend(_fresh_local(ds),
                        CacheSpec(candidate_p_max=0.5, semantic=False))
    rng = np.random.default_rng(59)
    qs = rng.normal(size=(4, vecs.shape[1])).astype(np.float32)
    flt = F.Equality("i0", 3)
    opts = OPTS.with_(force="brute")
    for _ in range(3):
        router.execute(cb, qs, flt, opts)
    st0 = cb.cache_stats()
    assert st0["selectivity"]["size"] > 0 and st0["candidates"]["size"] > 0
    ints, floats = _matching_attrs(attrs, schema)
    cb.upsert(qs[:1] + 1e-3, ints, floats)  # vector-only mutation
    router.execute(cb, qs, flt, opts)
    st1 = cb.cache_stats()
    # both layers survived the bump: no new misses, entries intact
    assert st1["selectivity"]["size"] == st0["selectivity"]["size"]
    assert st1["selectivity"]["misses"] == st0["selectivity"]["misses"]
    assert st1["candidates"]["size"] == st0["candidates"]["size"]
    assert st1["candidates"]["misses"] == st0["candidates"]["misses"]
    assert cb.invalidations == 1            # scoped, not a full clear


def test_scoped_epochs_matrix(ds):
    be = _fresh_local(ds)
    fi = be.index
    v0 = fi.versions()
    assert v0 == {"vectors": 0, "attributes": 0, "graph": 0}
    vecs, attrs, schema = ds
    ints, floats = _matching_attrs(attrs, schema)
    fi.upsert(np.zeros((1, vecs.shape[1]), np.float32), ints, floats)
    assert fi.versions() == {"vectors": 1, "attributes": 0, "graph": 0}
    fi.delete([10 ** 9])                    # found nothing: no bump
    assert fi.versions()["vectors"] == 1
    fi.merge(wave=256)
    # local merge: sample untouched -> attributes epoch must NOT move
    assert fi.versions() == {"vectors": 2, "attributes": 0, "graph": 1}


# ---------------------------------------------------------------------------
# graph_arrays memoization x mutation: no full re-upload
# ---------------------------------------------------------------------------
def test_no_graph_reupload_on_delete_only_mutation(ds):
    be = _fresh_local(ds)
    fi = be.index
    g_vec, g_nb = fi.g["vectors"], fi.g["neighbors0"]
    g_ai = fi.g["attrs_int"]
    assert fi.delete([0]) == 1
    # tombstones overlay an alive mask; the uploaded arrays stay put
    assert fi.g["vectors"] is g_vec
    assert fi.g["neighbors0"] is g_nb
    assert fi.g["attrs_int"] is g_ai
    assert "alive" in fi.g and not bool(fi.g["alive"][0])
    # component-scoped refresh re-uploads only what moved
    fi.bump_version(components=("attributes",))
    assert fi.g["vectors"] is g_vec          # untouched component reused
    assert fi.g["neighbors0"] is g_nb
    # legacy full bump still re-uploads everything
    fi.bump_version()
    assert fi.g["vectors"] is not g_vec


# ---------------------------------------------------------------------------
# merge equivalence
# ---------------------------------------------------------------------------
def test_merge_folds_to_equivalent_static_index(ds):
    vecs, attrs, schema = ds
    be = _fresh_local(ds)
    rng = np.random.default_rng(61)
    extra = rng.normal(size=(40, vecs.shape[1])).astype(np.float32)
    ints, floats = _matching_attrs(attrs, schema, count=40)
    ids = be.upsert(extra, ints, floats)
    dead_base = [int(np.nonzero(
        attrs.ints[:, schema.int_index("i0")] == 3)[0][0])]
    dead_delta = [int(ids[5])]
    assert be.delete(dead_base + dead_delta) == 2
    out = be.merge(wave=256)
    assert out["merged_slots"] == 40 and out["n"] == vecs.shape[0] + 40
    st = be.live_stats()
    assert st["delta_rows"] == 0 and st["dead_base_rows"] == 2
    # ground truth: exact top-k over live matching rows of the merged corpus
    all_vecs = np.concatenate([vecs, extra])
    col = schema.int_index("i0")
    all_i0 = np.concatenate([attrs.ints[:, col], ints[:, col]])
    alive = np.ones((len(all_vecs),), bool)
    alive[dead_base + dead_delta] = False
    rows = np.nonzero((all_i0 == 3) & alive)[0]
    qs = rng.normal(size=(5, vecs.shape[1])).astype(np.float32)
    want_ids, want_d = _exact_topk(all_vecs, qs, rows, OPTS.k)
    got = router.execute(be, qs, F.Equality("i0", 3),
                         OPTS.with_(force="brute"))
    np.testing.assert_array_equal(got.ids, want_ids)
    np.testing.assert_allclose(got.dists, want_d, rtol=1e-5, atol=1e-5)
    # graph route over the bulk-built merged graph serves the same ids
    # near the top (recall, not bit-parity: the graphs legitimately differ)
    gg = router.execute(be, qs, F.Equality("i0", 3),
                        OPTS.with_(force="graph"))
    overlap = np.mean([
        len(set(gg.ids[i][gg.ids[i] >= 0]) & set(want_ids[i])) / OPTS.k
        for i in range(len(qs))])
    assert overlap >= 0.9
    assert int(ids[5]) not in got.ids           # dead delta id stays dead
    assert int(ids[5]) not in gg.ids


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------
def test_empty_index_then_delta_only_parity():
    rng = np.random.default_rng(67)
    d = 16
    schema = paper_schema()
    attrs0 = random_attributes(schema, 0, seed=1)
    be = LocalBackend(FavorIndex.build(
        np.zeros((0, d), np.float32), attrs0, HnswParams(M=8, efc=48,
                                                         seed=3)))
    qs = rng.normal(size=(3, d)).astype(np.float32)
    flt = F.Equality("i0", 3)
    r = router.execute(be, qs, flt, OPTS)
    assert (r.ids == -1).all() and np.isinf(r.dists).all()
    # stream in a corpus; ids are 0..n-1 (positional over an empty base)
    n = 64
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    attrs = random_attributes(schema, n, seed=5)
    ids = be.upsert(vecs, attrs.ints, attrs.floats)
    assert ids.tolist() == list(range(n))
    got = router.execute(be, qs, flt, OPTS.with_(force="brute"))
    # parity vs a from-scratch static build over the same rows
    want = router.execute(
        LocalBackend(FavorIndex.build(vecs, attrs,
                                      HnswParams(M=8, efc=48, seed=3))),
        qs, flt, OPTS.with_(force="brute"))
    np.testing.assert_array_equal(got.ids, want.ids)
    np.testing.assert_allclose(got.dists, want.dists, rtol=1e-6, atol=1e-6)


def test_single_element_index_mutation():
    rng = np.random.default_rng(71)
    d = 16
    schema = paper_schema()
    attrs = random_attributes(schema, 1, seed=2)
    be = LocalBackend(FavorIndex.build(
        rng.normal(size=(1, d)).astype(np.float32), attrs,
        HnswParams(M=8, efc=48, seed=3)))
    q = rng.normal(size=(1, d)).astype(np.float32)
    flt = F.TrueFilter()
    r = router.execute(be, q, flt, OPTS)
    assert r.ids[0, 0] == 0
    assert be.delete([0]) == 1
    r = router.execute(be, q, flt, OPTS)
    assert (r.ids == -1).all()


def test_delete_everything_then_search(ds):
    vecs, _, _ = ds
    be = _fresh_local(ds)
    assert be.delete(list(range(vecs.shape[0]))) == vecs.shape[0]
    rng = np.random.default_rng(73)
    qs = rng.normal(size=(3, vecs.shape[1])).astype(np.float32)
    for force in ("graph", "brute"):
        r = router.execute(be, qs, F.TrueFilter(), OPTS.with_(force=force))
        assert (r.ids == -1).all(), force    # no ids, not garbage
        assert np.isinf(r.dists).all(), force


def test_insert_after_finalize_bulk_add(ds):
    vecs, attrs, _ = ds
    fi = FavorIndex.build(vecs[:256], random_attributes(paper_schema(), 256,
                                                        seed=13),
                          HnswParams(M=8, efc=48, seed=3))
    grown = build_hnsw_bulk(vecs[:256], HnswParams(M=8, efc=48, seed=3))
    assert grown.n == 256
    from repro.index.bulk import bulk_add
    grown2 = bulk_add(grown, vecs[256:384], wave=64)
    assert grown2.n == 384
    # every appended row is reachable and nearest-to-itself
    from repro.core.search import graph_arrays, favor_graph_search
    from repro.core.search import SearchConfig
    g = graph_arrays(grown2, random_attributes(paper_schema(), 384, seed=13),
                     version=0)
    import jax.numpy as jnp
    qs = vecs[256:264]
    progs = {
        "valid": jnp.ones((8, 1), jnp.float32),
        "imask": jnp.full((8, 1, 2), np.uint32(0xFFFFFFFF), jnp.uint32),
        "flo": jnp.full((8, 1, 1), -np.inf, jnp.float32),
        "fhi": jnp.full((8, 1, 1), np.inf, jnp.float32),
    }
    out = favor_graph_search(g, jnp.asarray(qs), progs,
                             jnp.zeros((8,), jnp.float32),
                             SearchConfig(k=1, ef=64, pbar_min=0.0))
    np.testing.assert_array_equal(np.asarray(out["ids"])[:, 0],
                                  np.arange(256, 264))


# ---------------------------------------------------------------------------
# bulk build recall
# ---------------------------------------------------------------------------
def test_bulk_build_recall_matches_sequential(ds):
    vecs, attrs, _ = ds
    n = 512
    params = HnswParams(M=8, efc=48, seed=3)
    seq = FavorIndex.build(vecs[:n], random_attributes(paper_schema(), n,
                                                       seed=13), params)
    blk = FavorIndex(build_hnsw_bulk(vecs[:n], params, wave=128),
                     random_attributes(paper_schema(), n, seed=13))
    rng = np.random.default_rng(79)
    qs = rng.normal(size=(32, vecs.shape[1])).astype(np.float32)
    want, _ = _exact_topk(vecs[:n], qs, np.arange(n), 10)
    rec = {}
    for name, fi in (("seq", seq), ("bulk", blk)):
        r = router.execute(LocalBackend(fi), qs, F.TrueFilter(),
                           OPTS.with_(force="graph"))
        rec[name] = np.mean([
            len(set(r.ids[i]) & set(want[i])) / 10 for i in range(len(qs))])
    assert rec["bulk"] >= rec["seq"] - 0.05, rec
    assert rec["bulk"] >= 0.8, rec


# ---------------------------------------------------------------------------
# quantization persistence
# ---------------------------------------------------------------------------
def test_quant_state_roundtrip(tmp_path, ds):
    vecs, attrs, _ = ds
    spec = BuildSpec(hnsw=HnswParams(M=8, efc=48, seed=3),
                     quant=QuantSpec(m=8, nbits=5, train_iters=10, rerank=4))
    fi = FavorIndex.build(vecs, attrs, spec=spec)
    rng = np.random.default_rng(83)
    qs = rng.normal(size=(4, vecs.shape[1])).astype(np.float32)
    opts = OPTS.with_(force="brute", use_pq=True)
    flt = F.Equality("i0", 3)
    want = router.execute(LocalBackend(fi), qs, flt, opts)
    path = str(tmp_path / "idx")
    fi.save(path)
    # the reloaded index serves use_pq with the PERSISTED codes -- results
    # are bit-identical, proving no re-train/re-encode happened
    re = FavorIndex.load(path, spec=spec)
    assert re.codebook is not None
    n = fi.index.n
    np.testing.assert_array_equal(np.asarray(re._codes)[:n],
                                  np.asarray(fi._codes)[:n])
    got = router.execute(LocalBackend(re), qs, flt, opts)
    np.testing.assert_array_equal(want.ids, got.ids)
    np.testing.assert_allclose(want.dists, got.dists, rtol=1e-5, atol=1e-6)
    # graph_quant route works from persisted state too
    gq = OPTS.with_(force="graph", graph_quant="pq")
    r1 = router.execute(LocalBackend(fi), qs, flt, gq)
    r2 = router.execute(LocalBackend(re), qs, flt, gq)
    np.testing.assert_array_equal(r1.ids, r2.ids)


def test_quant_requested_but_absent_raises(tmp_path, ds):
    vecs, attrs, _ = ds
    fi = FavorIndex.build(vecs[:128],
                          random_attributes(paper_schema(), 128, seed=13),
                          HnswParams(M=8, efc=48, seed=3))
    path = str(tmp_path / "plain")
    fi.save(path)
    with pytest.raises(ValueError, match="without quantization state"):
        FavorIndex.load(path, spec=BuildSpec(quant=QuantSpec(m=8, nbits=5)))


def test_save_warns_on_unmerged_mutations(tmp_path, ds):
    vecs, attrs, schema = ds
    fi = FavorIndex.build(vecs[:128],
                          random_attributes(paper_schema(), 128, seed=13),
                          HnswParams(M=8, efc=48, seed=3))
    ints, floats = _matching_attrs(attrs, schema)
    fi.upsert(np.zeros((1, vecs.shape[1]), np.float32), ints, floats)
    with pytest.warns(UserWarning, match="unmerged live mutations"):
        fi.save(str(tmp_path / "dirty"))


# ---------------------------------------------------------------------------
# ServeEngine mutation API + merge scheduling
# ---------------------------------------------------------------------------
def test_engine_mutation_stats_and_auto_merge(ds):
    vecs, attrs, schema = ds
    eng = ServeEngine(_fresh_local(ds), SearchOptions(k=5, ef=48),
                      merge_delta_frac=0.01)
    n = vecs.shape[0]
    rng = np.random.default_rng(89)
    ints, floats = _matching_attrs(attrs, schema, count=9)
    ids = eng.upsert(rng.normal(size=(9, vecs.shape[1])).astype(np.float32),
                     ints, floats)
    assert eng.delete([int(ids[0])]) == 1
    flt = F.Equality("i0", 3)
    for _ in range(3):
        eng.submit(rng.normal(size=(vecs.shape[1],)).astype(np.float32), flt)
    out = eng.run()
    assert len(out) == 3
    st = eng.stats["mutations"]
    assert st["upserts"] == 9 and st["deletes"] == 1
    assert st["auto_merges"] == 1           # 9/768 > 1% -> merged post-step
    assert st["delta_rows"] == 0 and st["base_rows"] == n + 9
    # post-merge serving still finds a surviving upserted row
    q = np.asarray(eng.backend.index.index.vectors[int(ids[1])], np.float32)
    eng.submit(q, flt)
    r = eng.run()[0]
    assert int(ids[1]) in r.ids


def test_engine_mutation_unsupported_backend_raises(ds):
    eng = ServeEngine(_fresh_local(ds), SearchOptions(k=5, ef=48))

    class Static:
        def validate(self, o):
            pass

        def version(self):
            return 0

    eng.backend = Static()
    with pytest.raises(ValueError, match="does not support live mutation"):
        eng.upsert(np.zeros((1, 16), np.float32))
    with pytest.raises(ValueError, match="merge_delta_frac"):
        ServeEngine(_fresh_local(ds), SearchOptions(k=5, ef=48),
                    merge_delta_frac=0.0)
