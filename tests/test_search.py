"""JAX production search vs the numpy oracle + baselines + end-to-end API."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (FavorIndex, SearchConfig, compile_filter,
                        favor_graph_search, graph_arrays, paper_filters,
                        rsf_graph_search, stack_programs)
from repro.core import exclusion
from repro.core import filters as F
from repro.core import refimpl


def _truth(vecs, mask, q, k):
    return refimpl.bruteforce_filtered(vecs, mask, q, k)[0]


@pytest.fixture(scope="module")
def queries(small_dataset):
    vecs, _, _ = small_dataset
    rng = np.random.default_rng(5)
    return rng.normal(size=(24, vecs.shape[1])).astype(np.float32)


def _setup(small_index, small_dataset, name):
    vecs, attrs, schema = small_dataset
    flt = paper_filters(schema)[name]
    prog = compile_filter(flt, schema)
    mask = F.eval_program(prog, attrs.ints, attrs.floats)
    return flt, prog, mask


@pytest.mark.parametrize("scenario,ef", [("equality_bool", 80),
                                         ("equality_int", 120),
                                         ("inclusion", 80),
                                         ("range_50", 80),
                                         ("logic", 240)])
def test_jax_matches_oracle_recall(small_index, small_dataset, queries, scenario, ef):
    vecs, attrs, schema = small_dataset
    flt, prog, mask = _setup(small_index, small_dataset, scenario)
    p = mask.mean()
    k = 10
    D = float(exclusion.exclusion_distance(p, ef, small_index.delta_d))
    progs = {kk: jnp.asarray(v) for kk, v in
             stack_programs([prog] * len(queries)).items()}
    cfg = SearchConfig(k=k, ef=ef)
    out = favor_graph_search(small_index.g, jnp.asarray(queries), progs,
                             jnp.full((len(queries),), D, jnp.float32), cfg)
    rec_j, rec_o = [], []
    for i, q in enumerate(queries):
        t = _truth(vecs, mask, q, k)
        oid, _, _ = refimpl.favor_search(small_index.index, q, mask, k, ef, D)
        rec_o.append(refimpl.recall_at_k(oid, t, k))
        rec_j.append(refimpl.recall_at_k(np.asarray(out["ids"][i]), t, k))
    assert np.mean(rec_o) >= 0.85, f"oracle recall degraded: {np.mean(rec_o)}"
    # fixed-capacity pools must track the unbounded-heap oracle closely
    assert np.mean(rec_j) >= np.mean(rec_o) - 0.08


def test_search_returns_only_targets(small_index, small_dataset, queries):
    vecs, attrs, schema = small_dataset
    flt, prog, mask = _setup(small_index, small_dataset, "equality_int")
    res = small_index.search(queries, flt, k=10, ef=80)
    for row in res.ids:
        for v in row[row >= 0]:
            assert mask[v], "non-target row leaked into S"


def test_exclusion_beats_zero_D(small_index, small_dataset, queries):
    """Ablation direction (paper Fig. 10): with D from Eq. 14 the search path
    should touch at least as many targets per hop as with D = 0."""
    vecs, attrs, schema = small_dataset
    flt, prog, mask = _setup(small_index, small_dataset, "equality_int")
    p = mask.mean()
    k, ef = 10, 80
    progs = {kk: jnp.asarray(v) for kk, v in
             stack_programs([prog] * len(queries)).items()}
    cfg = SearchConfig(k=k, ef=ef)
    D = float(exclusion.exclusion_distance(p, ef, small_index.delta_d))
    out_D = favor_graph_search(small_index.g, jnp.asarray(queries), progs,
                               jnp.full((len(queries),), D), cfg)
    out_0 = favor_graph_search(small_index.g, jnp.asarray(queries), progs,
                               jnp.zeros((len(queries),)), cfg)
    frac_D = np.asarray(out_D["path_td"]).sum() / max(1, np.asarray(out_D["hops"]).sum())
    frac_0 = np.asarray(out_0["path_td"]).sum() / max(1, np.asarray(out_0["hops"]).sum())
    assert frac_D >= frac_0 - 0.02


def test_termination_guard_improves_recall(small_index, small_dataset, queries):
    """Section 5.4: pbar_min=0.5 must not lose recall vs pbar_min=0."""
    vecs, attrs, schema = small_dataset
    flt, prog, mask = _setup(small_index, small_dataset, "equality_int")
    k, ef = 10, 40
    r_guard, r_plain = [], []
    res_g = small_index.search(queries, flt, k=k, ef=ef, pbar_min=0.5, force="graph")
    res_p = small_index.search(queries, flt, k=k, ef=ef, pbar_min=0.0, force="graph")
    for i, q in enumerate(queries):
        t = _truth(vecs, mask, q, k)
        r_guard.append(refimpl.recall_at_k(res_g.ids[i], t, k))
        r_plain.append(refimpl.recall_at_k(res_p.ids[i], t, k))
    assert np.mean(r_guard) >= np.mean(r_plain) - 1e-9


def test_rsf_baseline_runs(small_index, small_dataset, queries):
    vecs, attrs, schema = small_dataset
    flt, prog, mask = _setup(small_index, small_dataset, "equality_bool")
    progs = {kk: jnp.asarray(v) for kk, v in
             stack_programs([prog] * len(queries)).items()}
    out = rsf_graph_search(small_index.g, jnp.asarray(queries), progs,
                           SearchConfig(k=10, ef=80))
    recs = [refimpl.recall_at_k(np.asarray(out["ids"][i]),
                                _truth(vecs, mask, queries[i], 10), 10)
            for i in range(len(queries))]
    assert np.mean(recs) >= 0.8


def test_selector_routing(small_index, small_dataset, queries):
    vecs, attrs, schema = small_dataset
    lowsel = F.And(F.Equality("i0", 3), F.Range("f0", 10.0, 16.0))  # ~0.6%
    highsel = F.Equality("b0", True)  # 50%
    res = small_index.search(queries[:8], [lowsel] * 4 + [highsel] * 4, k=5, ef=48)
    assert res.routed_brute[:4].all(), f"low-sel not routed brute: {res.p_hat[:4]}"
    assert not res.routed_brute[4:].any()


def test_brute_route_exact(small_index, small_dataset, queries):
    vecs, attrs, schema = small_dataset
    flt, prog, mask = _setup(small_index, small_dataset, "logic")
    res = small_index.search(queries, flt, k=10, ef=64, force="brute")
    for i, q in enumerate(queries):
        t = _truth(vecs, mask, q, 10)
        assert refimpl.recall_at_k(res.ids[i], t, 10) == 1.0


def test_empty_filter_returns_padding(small_index, queries):
    res = small_index.search(queries[:4], F.FalseFilter(), k=5, ef=48)
    assert (res.ids == -1).all()


def test_save_load_end2end(small_index, small_dataset, queries, tmp_path):
    vecs, attrs, schema = small_dataset
    p = str(tmp_path / "favor")
    small_index.save(p)
    fi2 = FavorIndex.load(p)
    flt = paper_filters(schema)["equality_bool"]
    r1 = small_index.search(queries[:4], flt, k=5, ef=48)
    r2 = fi2.search(queries[:4], flt, k=5, ef=48)
    np.testing.assert_array_equal(r1.ids, r2.ids)
