"""PreFBF fused scan == exact brute force, across chunkings and paddings."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import compile_filter, paper_schema, random_attributes, stack_programs
from repro.core import filters as F
from repro.core import prefbf, refimpl

SCHEMA = paper_schema()


@pytest.fixture(scope="module")
def db():
    rng = np.random.default_rng(3)
    n, d = 3001, 24  # deliberately non-multiple of chunk
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    attrs = random_attributes(SCHEMA, n, seed=4)
    norms = np.einsum("nd,nd->n", vecs, vecs).astype(np.float32)
    return vecs, norms, attrs


@pytest.mark.parametrize("chunk", [256, 512, 1024])
def test_matches_bruteforce(db, chunk):
    vecs, norms, attrs = db
    rng = np.random.default_rng(9)
    queries = rng.normal(size=(8, vecs.shape[1])).astype(np.float32)
    flt = F.Range("f0", 20.0, 70.0)
    prog = compile_filter(flt, SCHEMA)
    mask = F.eval_program(prog, attrs.ints, attrs.floats)
    progs = {k: jnp.asarray(v) for k, v in
             stack_programs([prog] * len(queries)).items()}
    pv, pn, pi, pf = prefbf.pad_db(vecs, norms, attrs.ints, attrs.floats, chunk)
    ids, dists = prefbf.prefbf_topk(jnp.asarray(pv), jnp.asarray(pn),
                                    jnp.asarray(pi), jnp.asarray(pf),
                                    jnp.asarray(queries), progs, k=10, chunk=chunk)
    ids, dists = np.asarray(ids), np.asarray(dists)
    for i, q in enumerate(queries):
        t_ids, t_d = refimpl.bruteforce_filtered(vecs, mask, q, 10)
        assert refimpl.recall_at_k(ids[i], t_ids, 10) == 1.0
        np.testing.assert_allclose(dists[i][: len(t_d)], t_d, rtol=2e-4, atol=2e-4)


def test_per_query_filters(db):
    vecs, norms, attrs = db
    rng = np.random.default_rng(10)
    queries = rng.normal(size=(4, vecs.shape[1])).astype(np.float32)
    flts = [F.Equality("i0", v) for v in range(4)]
    progs_np = stack_programs([compile_filter(f, SCHEMA) for f in flts])
    progs = {k: jnp.asarray(v) for k, v in progs_np.items()}
    pv, pn, pi, pf = prefbf.pad_db(vecs, norms, attrs.ints, attrs.floats, 512)
    ids, _ = prefbf.prefbf_topk(jnp.asarray(pv), jnp.asarray(pn), jnp.asarray(pi),
                                jnp.asarray(pf), jnp.asarray(queries), progs,
                                k=10, chunk=512)
    ids = np.asarray(ids)
    for i, (q, f) in enumerate(zip(queries, flts)):
        mask = F.eval_program(compile_filter(f, SCHEMA), attrs.ints, attrs.floats)
        t_ids, _ = refimpl.bruteforce_filtered(vecs, mask, q, 10)
        assert refimpl.recall_at_k(ids[i], t_ids, 10) == 1.0


def test_fewer_matches_than_k(db):
    vecs, norms, attrs = db
    rng = np.random.default_rng(11)
    q = rng.normal(size=(1, vecs.shape[1])).astype(np.float32)
    flt = F.And(F.Equality("i0", 0), F.Range("f0", 0.0, 1.0))  # ~0.1%
    prog = compile_filter(flt, SCHEMA)
    mask = F.eval_program(prog, attrs.ints, attrs.floats)
    progs = {k: jnp.asarray(v) for k, v in stack_programs([prog]).items()}
    pv, pn, pi, pf = prefbf.pad_db(vecs, norms, attrs.ints, attrs.floats, 512)
    k = max(10, int(mask.sum()) + 5)
    ids, dists = prefbf.prefbf_topk(jnp.asarray(pv), jnp.asarray(pn),
                                    jnp.asarray(pi), jnp.asarray(pf),
                                    jnp.asarray(q), progs, k=k, chunk=512)
    ids = np.asarray(ids)[0]
    n_found = (ids >= 0).sum()
    assert n_found == mask.sum()
    assert (np.asarray(dists)[0][n_found:] == np.inf).all()
