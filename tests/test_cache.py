"""Serving cache subsystem: LruTtlCache policy, canonical-signature keys,
CachingBackend parity over LocalBackend and ShardedBackend (hits and misses
identical to uncached), candidate-block admission, epoch invalidation, and
ServeEngine stats/latency-window accounting."""
import numpy as np
import pytest

import jax

from repro.cache import CachingBackend, LruTtlCache
from repro.core import (BuildSpec, CacheSpec, HnswParams, LocalBackend,
                        SearchOptions, ShardedBackend, paper_filters, router)
from repro.core import filters as F
from repro.serving import ServeEngine


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


# ---------------------------------------------------------------------------
# LRU + TTL container
# ---------------------------------------------------------------------------
def test_lru_evicts_least_recently_used():
    c = LruTtlCache(cap=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1          # touch: "b" is now LRU
    c.put("c", 3)
    assert c.get("b") is None and c.get("a") == 1 and c.get("c") == 3
    assert c.evictions == 1


def test_lru_ttl_expires_entries():
    clk = FakeClock()
    c = LruTtlCache(cap=8, ttl_s=10.0, clock=clk)
    c.put("a", 1)
    clk.t = 9.0
    assert c.get("a") == 1
    clk.t = 21.0
    assert c.get("a") is None
    assert c.expirations == 1 and c.misses == 1


def test_lru_validation_and_stats():
    with pytest.raises(ValueError, match="cap"):
        LruTtlCache(cap=0)
    with pytest.raises(ValueError, match="ttl_s"):
        LruTtlCache(cap=1, ttl_s=0)
    c = LruTtlCache(cap=4)
    c.put("a", None)                # None is a legal cached value
    assert "a" in c
    st = c.stats()
    assert st["size"] == 1 and st["cap"] == 4


def test_semantic_ttl_is_per_entry():
    """A hot key receiving fresh inserts must not keep old entries alive:
    entry age, not key age, decides expiry."""
    from repro.cache import SemanticResultCache
    clk = FakeClock()
    cache = SemanticResultCache(CacheSpec(ttl_s=10.0), clock=clk)
    opts = SearchOptions(k=2)
    old_q = np.zeros((4,), np.float32)
    cache.put("sig", opts, old_q, [1, 2], [0.1, 0.2], 0.5, False)
    for step in range(1, 5):                    # keep the key hot past TTL
        clk.t = 4.0 * step
        q = np.full((4,), float(step), np.float32)
        cache.put("sig", opts, q, [1, 2], [0.1, 0.2], 0.5, False)
    assert clk.t == 16.0                        # old entry is past its TTL
    assert cache.get("sig", opts, old_q) is None
    assert cache.get("sig", opts, np.full((4,), 4.0, np.float32)) is not None


def test_cache_spec_validation():
    with pytest.raises(ValueError, match="selectivity_cap"):
        CacheSpec(selectivity_cap=0)
    with pytest.raises(ValueError, match="candidate_p_max"):
        CacheSpec(candidate_p_max=1.5)
    with pytest.raises(ValueError, match="ttl_s"):
        CacheSpec(ttl_s=-1.0)
    assert CacheSpec().with_(semantic=False).semantic is False


# ---------------------------------------------------------------------------
# canonical signatures as cache keys
# ---------------------------------------------------------------------------
def test_signatures_shared_across_equivalent_filters(small_dataset):
    _, _, schema = small_dataset
    a = F.And(F.Equality("i0", 3), F.Range("f0", 10, 20))
    commuted = F.And(F.Range("f0", 10, 20), F.Equality("i0", 3))
    double_neg = F.Not(F.Not(a))
    dup_disjunct = F.Or(a, a)
    sig = F.filter_signature(a, schema)
    assert F.filter_signature(commuted, schema) == sig
    assert F.filter_signature(double_neg, schema) == sig
    assert F.filter_signature(dup_disjunct, schema) == sig
    assert F.filter_signature(F.Equality("i0", 3), schema) != sig
    # batch signatures match the scalar path
    progs = router.compile_programs([a, commuted], schema, 2)
    assert F.batch_signatures(progs) == [sig, sig]


# ---------------------------------------------------------------------------
# CachingBackend over LocalBackend
# ---------------------------------------------------------------------------
@pytest.fixture()
def cached_local(small_index):
    return CachingBackend(LocalBackend(small_index), CacheSpec())


def test_caching_backend_parity_cold_and_warm(cached_local, small_index,
                                              small_dataset):
    vecs, _, schema = small_dataset
    base = LocalBackend(small_index)
    rng = np.random.default_rng(50)
    qs = rng.normal(size=(6, vecs.shape[1])).astype(np.float32)
    opts = SearchOptions(k=10, ef=64)
    for name, flt in paper_filters(schema).items():
        r0 = router.execute(base, qs, flt, opts)
        cold = router.execute(cached_local, qs, flt, opts)
        warm = router.execute(cached_local, qs, flt, opts)
        np.testing.assert_array_equal(r0.ids, cold.ids, err_msg=name)
        np.testing.assert_array_equal(r0.ids, warm.ids, err_msg=name)
        np.testing.assert_array_equal(r0.routed_brute, warm.routed_brute,
                                      err_msg=name)
        np.testing.assert_allclose(r0.p_hat, warm.p_hat, err_msg=name)
    st = cached_local.cache_stats()
    assert st["semantic"]["hits"] > 0          # warm pass was served cached
    assert st["selectivity"]["size"] > 0


def test_selectivity_cache_skips_inner_estimate(cached_local, small_dataset):
    _, _, schema = small_dataset
    flt = paper_filters(schema)["equality_bool"]
    progs = router.compile_programs([flt] * 4, schema, 4)
    calls = []
    inner_estimate = cached_local.inner.estimate
    cached_local.inner.estimate = lambda p: calls.append(1) or inner_estimate(p)
    try:
        p0 = cached_local.estimate(progs)
        p1 = cached_local.estimate(progs)
    finally:
        cached_local.inner.estimate = inner_estimate
    # 4 identical programs -> one inner call row on the cold pass, zero warm
    assert len(calls) == 1
    np.testing.assert_array_equal(p0, p1)
    st = cached_local.cache_stats()["selectivity"]
    assert st["hits"] == 4 and st["misses"] == 4


def test_candidate_cache_admits_on_second_reference(cached_local, small_index,
                                                    small_dataset):
    vecs, attrs, schema = small_dataset
    base = LocalBackend(small_index)
    # a low-selectivity filter that routes brute under the default lambda
    flt = F.And(F.Equality("i0", 2), F.Range("f0", 5.0, 15.0))
    sel = float(F.eval_program(F.compile_filter(flt, schema), attrs.ints,
                               attrs.floats).mean())
    assert sel < 0.02
    opts = SearchOptions(k=10, ef=64, force="brute")
    rng = np.random.default_rng(51)
    for round_ in range(3):
        # fresh query vectors each round: only the candidate layer can hit
        qs = rng.normal(size=(4, vecs.shape[1])).astype(np.float32)
        rc = router.execute(cached_local, qs, flt, opts)
        rb = router.execute(base, qs, flt, opts)
        np.testing.assert_array_equal(rc.ids, rb.ids, err_msg=f"round {round_}")
        np.testing.assert_allclose(rc.dists, rb.dists, rtol=1e-5, atol=1e-5)
    st = cached_local.cache_stats()["candidates"]
    assert st["size"] == 1          # admitted after the second brute miss
    assert st["hits"] >= 1          # third round scanned the cached block


def test_candidate_cache_respects_p_max_gate(small_index, small_dataset):
    vecs, _, schema = small_dataset
    cb = CachingBackend(LocalBackend(small_index),
                        CacheSpec(candidate_p_max=0.001, semantic=False))
    flt = F.And(F.Equality("i0", 2), F.Range("f0", 5.0, 15.0))  # ~1% > gate
    opts = SearchOptions(k=10, ef=64, force="brute")
    rng = np.random.default_rng(52)
    for _ in range(3):
        qs = rng.normal(size=(2, vecs.shape[1])).astype(np.float32)
        router.execute(cb, qs, flt, opts)
    st = cb.cache_stats()["candidates"]
    assert st["size"] == 0 and st["bypasses"] >= 1


def test_epoch_bump_invalidates_all_layers(cached_local, small_index,
                                           small_dataset):
    vecs, _, schema = small_dataset
    flt = paper_filters(schema)["logic"]
    rng = np.random.default_rng(53)
    qs = rng.normal(size=(4, vecs.shape[1])).astype(np.float32)
    opts = SearchOptions(k=10, ef=64)
    r0 = router.execute(cached_local, qs, flt, opts)
    router.execute(cached_local, qs, flt, opts)   # warm the layers
    assert cached_local.cache_stats()["semantic"]["size"] > 0
    small_index.bump_version()
    r1 = router.execute(cached_local, qs, flt, opts)
    assert cached_local.invalidations == 1
    assert cached_local.version() == small_index.version()
    # stale entries were dropped, recomputed results are identical
    np.testing.assert_array_equal(r0.ids, r1.ids)


def test_semantic_threshold_serves_near_duplicates(small_index, small_dataset):
    vecs, _, schema = small_dataset
    cb = CachingBackend(LocalBackend(small_index),
                        CacheSpec(semantic_threshold=0.5, candidates=False))
    flt = paper_filters(schema)["equality_bool"]
    opts = SearchOptions(k=5, ef=48)
    rng = np.random.default_rng(54)
    q = rng.normal(size=(1, vecs.shape[1])).astype(np.float32)
    r0 = router.execute(cb, q, flt, opts)
    jitter = q + (0.1 / np.sqrt(vecs.shape[1])).astype(np.float32)
    r1 = router.execute(cb, jitter, flt, opts)     # within threshold
    np.testing.assert_array_equal(r0.ids, r1.ids)  # served from cache
    assert cb.cache_stats()["semantic"]["hits"] == 1
    far = q + 10.0
    router.execute(cb, far, flt, opts)             # outside threshold: miss
    assert cb.cache_stats()["semantic"]["misses"] >= 2


def test_disabled_layers_bypass(small_index, small_dataset):
    vecs, _, schema = small_dataset
    spec = CacheSpec(selectivity=False, candidates=False, semantic=False)
    cb = CachingBackend(LocalBackend(small_index), spec)
    flt = paper_filters(schema)["equality_int"]
    qs = np.zeros((2, vecs.shape[1]), np.float32)
    opts = SearchOptions(k=5, ef=48)
    progs = router.compile_programs([flt] * 2, schema, 2)
    assert cb.lookup_result(qs, progs, opts) is None
    r = router.execute(cb, qs, flt, opts)
    assert r.ids.shape == (2, 5)
    st = cb.cache_stats()
    assert st["selectivity"]["hits"] == st["semantic"]["hits"] == 0
    assert st["selectivity"]["bypasses"] > 0


# ---------------------------------------------------------------------------
# CachingBackend over ShardedBackend (1-device mesh)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def sharded_1dev(small_dataset):
    vecs, attrs, _ = small_dataset
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return ShardedBackend.build(vecs, attrs, mesh,
                                BuildSpec(hnsw=HnswParams(M=8, efc=48, seed=3)))


def test_caching_backend_wraps_sharded(sharded_1dev, small_dataset):
    vecs, _, schema = small_dataset
    cb = CachingBackend(sharded_1dev, CacheSpec())
    rng = np.random.default_rng(55)
    qs = rng.normal(size=(4, vecs.shape[1])).astype(np.float32)
    opts = SearchOptions(k=10, ef=64)
    for flt in (paper_filters(schema)["equality_int"],
                F.And(F.Equality("i0", 2), F.Range("f0", 5.0, 15.0))):
        r0 = router.execute(sharded_1dev, qs, flt, opts)
        cold = router.execute(cb, qs, flt, opts)
        warm = router.execute(cb, qs, flt, opts)
        np.testing.assert_array_equal(r0.ids, cold.ids)
        np.testing.assert_array_equal(r0.ids, warm.ids)
    # candidate layer found the sharded corpus view
    assert cb._corpus() is not None
    sharded_1dev.bump_version()
    r1 = router.execute(cb, qs, paper_filters(schema)["equality_int"], opts)
    assert cb.invalidations == 1 and r1.ids.shape == (4, 10)


# ---------------------------------------------------------------------------
# ServeEngine surfacing
# ---------------------------------------------------------------------------
def test_engine_surfaces_cache_stats_and_bounds_latencies(small_index,
                                                          small_dataset):
    vecs, _, schema = small_dataset
    cb = CachingBackend(LocalBackend(small_index), CacheSpec())
    eng = ServeEngine(cb, SearchOptions(k=5, ef=48), max_batch=8,
                      max_wait_ms=1e6, latency_window=8)
    rng = np.random.default_rng(56)
    qs = rng.normal(size=(8, vecs.shape[1])).astype(np.float32)
    flt = paper_filters(schema)["equality_bool"]
    for _ in range(3):                      # 24 requests, window of 8
        for i in range(8):
            eng.submit(qs[i], flt)
        eng.run()
    assert len(eng.latencies) == 8          # rolling window, not append-only
    st = eng.stats
    assert st["graph"] + st["brute"] == 24
    assert st["cache"]["semantic"]["hits"] >= 8   # repeat rounds were cached
    eng.reset_stats()
    assert eng.stats["batches"] == 0 and len(eng.latencies) == 0
    # cache contents survive an engine stats reset
    assert eng.stats["cache"]["semantic"]["size"] > 0
    with pytest.raises(ValueError, match="latency_window"):
        ServeEngine(cb, SearchOptions(), latency_window=0)
