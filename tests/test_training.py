"""Training substrate: optimizer, checkpoint atomicity/resume, fault-tolerant
loop, gradient compression, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import paper_filters
from repro.serving import ServeEngine
from repro.training import checkpoint as ckpt
from repro.training import compression, fault_tolerance as ft
from repro.training import optimizer as opt


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    ocfg = opt.OptConfig(lr=0.2, weight_decay=0.0, total_steps=200,
                         warmup_steps=0)
    st = opt.init_opt_state(params, ocfg)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, st, m = opt.apply_updates(params, g, st, ocfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, gn = opt.clip_by_global_norm(g, 1.0)
    assert abs(float(opt.global_norm(clipped)) - 1.0) < 1e-5
    assert float(gn) > 1.0


def test_schedule_warmup_cosine():
    ocfg = opt.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                         min_lr_frac=0.1)
    assert float(opt.schedule(ocfg, jnp.asarray(5.0))) == pytest.approx(0.5)
    assert float(opt.schedule(ocfg, jnp.asarray(10.0))) == pytest.approx(1.0)
    assert float(opt.schedule(ocfg, jnp.asarray(100.0))) == pytest.approx(0.1)


# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": np.arange(6).reshape(2, 3).astype(np.float32)},
            "opt": (np.ones(3), np.zeros(2)), "step": 7}
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, tree)
    out, meta = ckpt.restore(d)
    assert meta["step"] == 7
    np.testing.assert_array_equal(out["params"]["w"], tree["params"]["w"])
    np.testing.assert_array_equal(out["opt"][0], tree["opt"][0])
    assert int(out["step"]) == 7


def test_checkpoint_retention_and_latest(tmp_path):
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, {"x": np.asarray([s])}, keep=2)
    assert ckpt.latest_step(d) == 5
    steps = sorted(ckpt._complete_steps(d))
    assert steps == [4, 5]


def test_checkpoint_atomic_no_partial(tmp_path):
    """A .tmp dir (simulated crash mid-save) must not be seen as a ckpt."""
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"x": np.asarray([1])})
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert ckpt.latest_step(d) == 1


def test_fault_tolerant_loop_resumes(tmp_path):
    d = str(tmp_path / "ck")

    def step_fn(state, batch):
        state["params"]["w"] = state["params"]["w"] + batch["x"].sum()
        return state, {"loss": jnp.asarray(1.0)}

    def data_iter(s):
        return {"x": np.asarray([1.0])}, s + 1

    state0 = {"params": {"w": np.asarray(0.0)}, "opt": {}, "data_state": 0,
              "step": 0}
    logs = []
    st, m, wd = ft.run_loop(step_fn, dict(state0), data_iter, n_steps=10,
                            ckpt_dir=d, save_every=4, log=logs.append)
    assert float(st["params"]["w"]) == 10.0
    # simulate restart from scratch state -> resumes from step 8
    st2, _, _ = ft.run_loop(step_fn, dict(state0), data_iter, n_steps=12,
                            ckpt_dir=d, save_every=4, log=logs.append)
    assert any("resumed" in l for l in logs)
    assert float(st2["params"]["w"]) == 12.0  # 8 from ckpt + 4 more


def test_straggler_watchdog():
    wd = ft.StragglerWatchdog(threshold=2.0)
    for _ in range(10):
        wd.record(0.1)
    assert wd.record(0.5) is True
    assert wd.slow_steps == 1
    assert wd.record(0.1) is False


# ---------------------------------------------------------------------------
def test_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))}
    res = compression.init_residual(g)
    comp, res2 = compression.compress_tree(g, res)
    # int8 blockwise error is small relative to signal
    rel = float(jnp.linalg.norm(g["w"] - comp["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.02
    # error feedback: residual carries exactly the quantization error
    np.testing.assert_allclose(np.asarray(comp["w"] + res2["w"]),
                               np.asarray(g["w"]), rtol=1e-6, atol=1e-6)
    # accumulated over steps, EF keeps the running sum nearly unbiased
    total_in, total_out = np.zeros(1000), np.zeros(1000)
    res = compression.init_residual(g)
    for i in range(20):
        gi = {"w": jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))}
        comp, res = compression.compress_tree(gi, res)
        total_in += np.asarray(gi["w"])
        total_out += np.asarray(comp["w"])
    err = np.linalg.norm(total_in - total_out) / np.linalg.norm(total_in)
    assert err < 0.05


# ---------------------------------------------------------------------------
def test_serve_engine(small_index, small_dataset):
    vecs, attrs, schema = small_dataset
    eng = ServeEngine(small_index, k=5, ef=48, max_batch=16)
    flts = paper_filters(schema)
    rng = np.random.default_rng(0)
    rids = []
    for i in range(40):
        q = rng.normal(size=(vecs.shape[1],)).astype(np.float32)
        name = list(flts)[i % len(flts)]
        rids.append(eng.submit(q, flts[name]))
    out = eng.run()
    assert len(out) == 40
    assert sorted(r.rid for r in out) == sorted(rids)
    assert eng.stats["graph"] + eng.stats["brute"] == 40
    pct = eng.latency_percentiles()
    assert pct["p50"] <= pct["p99"]


def test_serve_engine_deadline(small_index, small_dataset):
    """max_wait_ms is honored: a partial batch waits for the deadline, a full
    batch flushes immediately, and run(until_empty=) is wired."""
    vecs, attrs, schema = small_dataset
    eng = ServeEngine(small_index, k=5, ef=48, max_batch=8, max_wait_ms=1e6)
    flts = list(paper_filters(schema).values())
    rng = np.random.default_rng(1)

    def submit(n):
        for i in range(n):
            q = rng.normal(size=(vecs.shape[1],)).astype(np.float32)
            eng.submit(q, flts[i % len(flts)])

    # partial batch, deadline far in the future -> engine keeps waiting
    submit(3)
    assert eng.step() == []
    assert eng.run(until_empty=False) == []
    assert len(eng.queue) == 3

    # oldest request past the deadline -> the partial batch flushes
    eng.queue[0].t_submit -= 2 * eng.max_wait_s
    out = eng.step()
    assert len(out) == 3 and not eng.queue

    # full batch flushes immediately despite the huge deadline
    submit(8)
    assert len(eng.step()) == 8

    # drain() forces out partial batches immediately, even with the huge
    # deadline (run(until_empty=True) would wait the straggler window out)
    submit(3)
    assert len(eng.drain()) == 3 and not eng.queue
