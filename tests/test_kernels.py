"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import compile_filter, paper_schema, random_attributes, stack_programs
from repro.core import filters as F
from repro.kernels.embedding_bag import ops as eb_ops
from repro.kernels.embedding_bag import ref as eb_ref
from repro.kernels.filtered_topk import ops as ft_ops
from repro.kernels.filtered_topk import ref as ft_ref
from repro.kernels.gather_distance import ops as gd_ops
from repro.kernels.gather_distance import ref as gd_ref

SCHEMA = paper_schema()


def _db(n, d, seed=0):
    rng = np.random.default_rng(seed)
    vecs = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    norms = jnp.sum(vecs * vecs, axis=-1)
    attrs = random_attributes(SCHEMA, n, seed=seed + 1)
    return vecs, norms, jnp.asarray(attrs.ints), jnp.asarray(attrs.floats), rng


def _progs(b, rng):
    pool = [F.Equality("b0", True), F.Equality("i0", 3),
            F.Inclusion("i0", [1, 5, 9]), F.Range("f0", 10.0, 60.0),
            F.And(F.Equality("b0", False), F.Range("f0", None, 50.0)),
            F.Not(F.Range("f0", 30.0, 80.0)), F.TrueFilter()]
    flts = [pool[i % len(pool)] for i in range(b)]
    return {k: jnp.asarray(v) for k, v in
            stack_programs([compile_filter(f, SCHEMA) for f in flts]).items()}


# ---------------------------------------------------------------------------
# filtered_topk
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,d,b,k,bq,bn", [
    (700, 16, 12, 5, 4, 128),     # non-multiple row count (padding path)
    (1024, 32, 8, 10, 8, 256),
    (512, 64, 16, 10, 16, 512),   # one n-tile
    (2048, 8, 4, 32, 4, 256),     # large k
])
def test_filtered_topk_sweep(n, d, b, k, bq, bn):
    vecs, norms, ints, floats, rng = _db(n, d, seed=n + d)
    qs = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    progs = _progs(b, rng)
    ids, dd = ft_ops.filtered_topk(vecs, norms, ints, floats, qs, progs,
                                   k=k, block_q=bq, block_n=bn)
    rd, ri = ft_ref.filtered_topk_ref(qs, vecs, norms, ints, floats, progs,
                                      jnp.zeros((b,)), k=k, exclude=False)
    dd_c = np.where(np.isinf(np.asarray(dd)), ft_ref.BIG, np.asarray(dd))
    np.testing.assert_allclose(dd_c, np.asarray(rd), rtol=1e-5, atol=1e-5)
    # id agreement where distances are unique
    same = np.asarray(ids) == np.asarray(ri)
    assert same.mean() > 0.99


def test_filtered_topk_exclusion_mode():
    vecs, norms, ints, floats, rng = _db(1000, 24, seed=3)
    b = 8
    qs = jnp.asarray(rng.normal(size=(b, 24)).astype(np.float32))
    progs = _progs(b, rng)
    dvec = jnp.asarray(rng.uniform(0.1, 1.0, size=(b,)).astype(np.float32))
    ids, dd = ft_ops.filtered_topk(vecs, norms, ints, floats, qs, progs,
                                   k=10, dvec=dvec, exclude=True,
                                   block_q=8, block_n=256)
    rd, ri = ft_ref.filtered_topk_ref(qs, vecs, norms, ints, floats, progs,
                                      dvec, k=10, exclude=True)
    np.testing.assert_allclose(np.asarray(dd), np.asarray(rd), rtol=1e-5)
    assert (np.asarray(ids) == np.asarray(ri)).mean() > 0.99


def test_filtered_topk_matches_prefbf():
    """Kernel vs the production jnp PreFBF path (cross-validation)."""
    from repro.core import prefbf
    vecs, norms, ints, floats, rng = _db(1200, 16, seed=9)
    b = 6
    qs = jnp.asarray(rng.normal(size=(b, 16)).astype(np.float32))
    progs = _progs(b, rng)
    pv, pn, pi, pf = prefbf.pad_db(np.asarray(vecs), np.asarray(norms),
                                   np.asarray(ints), np.asarray(floats), 256)
    jid, jd = prefbf.prefbf_topk(jnp.asarray(pv), jnp.asarray(pn),
                                 jnp.asarray(pi), jnp.asarray(pf), qs, progs,
                                 k=10, chunk=256)
    kid, kd = ft_ops.filtered_topk(vecs, norms, ints, floats, qs, progs,
                                   k=10, block_q=8, block_n=256)
    np.testing.assert_allclose(np.asarray(jd), np.asarray(kd), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# gather_distance
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,d,b,m", [(300, 16, 4, 8), (600, 32, 6, 16),
                                     (128, 8, 2, 32)])
def test_gather_distance_sweep(n, d, b, m):
    vecs, norms, ints, floats, rng = _db(n, d, seed=n + m)
    qs = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    progs = _progs(b, rng)
    nbrs = rng.integers(-1, n, size=(b, m)).astype(np.int32)  # includes -1 pads
    dvec = jnp.asarray(rng.uniform(0.0, 1.0, size=(b,)).astype(np.float32))
    kd, ktd = gd_ops.gather_distance(vecs, norms, ints, floats, qs,
                                     jnp.asarray(nbrs), progs, dvec)
    rd, rtd = gd_ref.gather_distance_ref(jnp.asarray(nbrs), qs, vecs, norms,
                                         ints, floats, progs, dvec)
    rd_c = np.where(np.asarray(rd) >= gd_ref.BIG, np.inf, np.asarray(rd))
    np.testing.assert_allclose(np.asarray(kd), rd_c, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ktd), np.asarray(rtd).astype(bool))


# ---------------------------------------------------------------------------
# pq_adc block-gather (graph-route scorer variant)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,d,b,m0,m,nbits", [
    (500, 16, 4, 8, 8, 6),
    (900, 24, 6, 16, 8, 8),   # includes -1 pads below
    (256, 8, 2, 32, 4, 5),
])
def test_pq_adc_gather_sweep(n, d, b, m0, m, nbits):
    from repro.kernels.pq_adc import ops as pq_ops
    from repro.kernels.pq_adc import ref as pq_ref
    from repro.quant import encode, train_pq
    from repro.quant.adc import build_luts
    rng = np.random.default_rng(n + m0)
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    cb = train_pq(vecs, m=m, nbits=nbits, iters=4, seed=0)
    codes = jnp.asarray(encode(cb, vecs))
    qs = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    luts = build_luts(jnp.asarray(cb.centroids), qs)
    nbrs = rng.integers(-1, n, size=(b, m0)).astype(np.int32)
    nbrs[:, 0] = -1          # force the pad path in every parametrization
    nbrs = jnp.asarray(nbrs)
    out = pq_ops.pq_adc_gather(codes, luts, nbrs)
    assert np.isinf(np.asarray(out)[:, 0]).all()   # -1 -> +inf contract
    ref = np.asarray(pq_ref.pq_adc_gather_ref(codes, luts, nbrs))
    ref = np.where(ref >= pq_ref.BIG, np.inf, ref)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-5)
    # the ADC sums really approximate the squared distances
    real = nbrs[0][np.asarray(nbrs[0]) >= 0]
    true2 = np.sum((np.asarray(qs)[0] - vecs[np.asarray(real)]) ** 2, axis=-1)
    approx = np.asarray(out)[0][np.asarray(nbrs[0]) >= 0]
    assert np.corrcoef(true2, approx)[0, 1] > 0.9


def test_pq_adc_gather_edge_rows():
    """Row-batched gather at awkward shapes: b not a block_q multiple, M0
    odd, one row entirely -1 pads -- oracle parity plus the all-inf
    contract for the padded row, for f32 and bf16 LUTs."""
    from repro.kernels.pq_adc import ops as pq_ops
    from repro.kernels.pq_adc import ref as pq_ref
    from repro.quant import encode, train_pq
    from repro.quant.adc import build_luts
    rng = np.random.default_rng(31)
    n, d, b, m0 = 300, 16, 3, 5
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    cb = train_pq(vecs, m=8, nbits=8, iters=4, seed=0)
    codes = jnp.asarray(encode(cb, vecs))
    assert codes.dtype == jnp.uint8    # streamed uncast end-to-end
    qs = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    luts = build_luts(jnp.asarray(cb.centroids), qs)
    nbrs = rng.integers(0, n, size=(b, m0)).astype(np.int32)
    nbrs[1] = -1                       # a fully padded lane
    nbrs[0, 2] = -1
    nbrs = jnp.asarray(nbrs)
    ref = np.asarray(pq_ref.pq_adc_gather_ref(codes, luts, nbrs))
    ref = np.where(ref >= pq_ref.BIG, np.inf, ref)
    out = np.asarray(pq_ops.pq_adc_gather(codes, luts, nbrs))
    assert np.isinf(out[1]).all()
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    # bf16 LUT storage: same gather, entries rounded -- stays within the
    # table's rounding error (~3 significant digits) of the f32 result
    out_bf = np.asarray(pq_ops.pq_adc_gather(
        codes, luts.astype(jnp.bfloat16), nbrs))
    assert np.isinf(out_bf[1]).all()
    fin = np.isfinite(ref)
    np.testing.assert_allclose(out_bf[fin], ref[fin], rtol=2e-2)


def test_pq_adc_gather_all_padded():
    """Every lane padded: the scalar-prefetch index_map must clamp the -1
    ids (no OOB row DMA) and the output is all +inf."""
    from repro.kernels.pq_adc import ops as pq_ops
    from repro.quant import encode, train_pq
    from repro.quant.adc import build_luts
    rng = np.random.default_rng(32)
    n, d, b, m0 = 128, 8, 4, 6
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    cb = train_pq(vecs, m=4, nbits=6, iters=3, seed=1)
    codes = jnp.asarray(encode(cb, vecs))
    qs = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    luts = build_luts(jnp.asarray(cb.centroids), qs)
    nbrs = jnp.full((b, m0), -1, jnp.int32)
    out = np.asarray(pq_ops.pq_adc_gather(codes, luts, nbrs))
    assert out.shape == (b, m0)
    assert np.isinf(out).all()


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("v,d,b,l,mode", [
    (100, 16, 8, 4, "sum"), (100, 16, 8, 4, "mean"),
    (1000, 32, 4, 10, "sum"), (50, 8, 16, 1, "mean"),
    (257, 64, 3, 7, "sum"),
])
def test_embedding_bag_sweep(v, d, b, l, mode):
    rng = np.random.default_rng(v + l)
    table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    bags = rng.integers(0, v, size=(b, l)).astype(np.int32)
    # random -1 padding tail per bag
    for i in range(b):
        cut = rng.integers(1, l + 1)
        bags[i, cut:] = -1
    out = eb_ops.embedding_bag(table, jnp.asarray(bags), mode=mode)
    ref = eb_ref.embedding_bag_ref(jnp.asarray(bags), table, mode=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_embedding_bag_all_padding():
    table = jnp.ones((10, 4), jnp.float32)
    bags = jnp.full((2, 3), -1, jnp.int32)
    out = eb_ops.embedding_bag(table, bags, mode="mean")
    np.testing.assert_allclose(np.asarray(out), 0.0)
