"""Sharded serve correctness: runs a subprocess with 8 fake CPU devices
(the main test process must keep the default single-device view)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import *
    from repro.core import distributed as dist, refimpl
    from repro.core.search import SearchConfig

    assert len(jax.devices()) == 8
    rng = np.random.default_rng(0)
    N, d, S = 4096, 16, 4
    vecs = rng.normal(size=(N, d)).astype(np.float32)
    schema = paper_schema()
    attrs = random_attributes(schema, N, seed=1)
    sh = dist.build_sharded(vecs, attrs, S, HnswParams(M=8, efc=40, seed=0))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    fns = dist.make_serve_fns(mesh, SearchConfig(k=10, ef=48))
    db = dist.device_put_sharded_db(sh.arrays, mesh, fns["db_specs"])

    flt = paper_filters(schema)["equality_bool"]
    Q = 16
    queries = rng.normal(size=(Q, d)).astype(np.float32)
    progs = stack_programs([compile_filter(flt, schema)] * Q)
    progs = {k: jnp.asarray(v) for k, v in progs.items()}

    mask = filters.eval_program(compile_filter(flt, schema), attrs.ints, attrs.floats)
    p_hat = np.asarray(fns["estimate"](db, progs))
    assert abs(p_hat.mean() - mask.mean()) < 0.08, p_hat

    valid = jnp.ones((Q,), bool)
    ids, ds = (np.asarray(x) for x in
               fns["serve_graph"](db, queries, progs, valid))
    recs = [refimpl.recall_at_k(ids[i],
            refimpl.bruteforce_filtered(vecs, mask, queries[i], 10)[0], 10)
            for i in range(Q)]
    assert np.mean(recs) >= 0.9, np.mean(recs)

    bids, _ = (np.asarray(x) for x in
               fns["serve_brute"](db, queries, progs, valid))
    recs_b = [refimpl.recall_at_k(bids[i],
              refimpl.bruteforce_filtered(vecs, mask, queries[i], 10)[0], 10)
              for i in range(Q)]
    assert np.mean(recs_b) == 1.0, np.mean(recs_b)
    # global ids must be valid row indices
    assert ((ids >= -1) & (ids < N)).all()
    print("DISTRIBUTED_OK", np.mean(recs), np.mean(recs_b))
""")


@pytest.mark.slow
def test_sharded_serve_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), capture_output=True, text=True,
        timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "DISTRIBUTED_OK" in r.stdout
