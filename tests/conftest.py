import faulthandler
import importlib.util
import os

import numpy as np
import pytest

from repro.core import (AttributeTable, FavorIndex, HnswParams, paper_schema,
                        random_attributes)

# Per-test hang protection.  With pytest-timeout installed (the dev extra;
# CI has it) the plugin enforces the `timeout` configured in pyproject.toml.
# This fallback covers bare containers without the plugin: a faulthandler
# watchdog dumps every thread's stack and aborts the process if a single
# test exceeds the same budget -- a deadlocked concurrency test then fails
# the run with tracebacks instead of wedging it forever.
_WATCHDOG_S = float(os.environ.get("FAVOR_TEST_TIMEOUT", "300"))
_HAVE_PYTEST_TIMEOUT = importlib.util.find_spec("pytest_timeout") is not None


@pytest.fixture(autouse=True)
def _hang_watchdog():
    if _HAVE_PYTEST_TIMEOUT or _WATCHDOG_S <= 0:
        yield
        return
    faulthandler.dump_traceback_later(_WATCHDOG_S, exit=True)
    try:
        yield
    finally:
        faulthandler.cancel_dump_traceback_later()


@pytest.fixture(scope="session")
def small_dataset():
    rng = np.random.default_rng(7)
    n, d = 2000, 16
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    schema = paper_schema()
    attrs = random_attributes(schema, n, seed=11)
    return vecs, attrs, schema


@pytest.fixture(scope="session")
def small_index(small_dataset):
    vecs, attrs, _ = small_dataset
    return FavorIndex.build(vecs, attrs, HnswParams(M=8, efc=48, seed=3))
