import numpy as np
import pytest

from repro.core import (AttributeTable, FavorIndex, HnswParams, paper_schema,
                        random_attributes)


@pytest.fixture(scope="session")
def small_dataset():
    rng = np.random.default_rng(7)
    n, d = 2000, 16
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    schema = paper_schema()
    attrs = random_attributes(schema, n, seed=11)
    return vecs, attrs, schema


@pytest.fixture(scope="session")
def small_index(small_dataset):
    vecs, attrs, _ = small_dataset
    return FavorIndex.build(vecs, attrs, HnswParams(M=8, efc=48, seed=3))
