"""Shape-stable execution: BatchSpec policy, pad/unpad round-trips, the
compiled-shape registry + warmup, and the acceptance bar -- bucket-padded
execution is bit-identical to the unpadded path on local, sharded and
caching backends, including the all-graph / all-brute / empty-sub-batch
edges -- with a hypothesis sweep over batch sizes and filter mixes."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (BatchSpec, BuildSpec, FavorIndex, HnswParams,
                        LocalBackend, QuantSpec, SearchOptions,
                        ShardedBackend, paper_filters, router)
from repro.core import filters as F
from repro.core.batching import (ShapeRegistry, pad_programs, pad_to_bucket,
                                 unpad, warmup)
from repro.serving import ServeEngine

SPEC = BatchSpec(min_bucket=4, max_bucket=32)
OPTS = SearchOptions(k=10, ef=64)
OPTS_B = OPTS.with_(batch=SPEC)


# ---------------------------------------------------------------------------
# BatchSpec policy
# ---------------------------------------------------------------------------
def test_batchspec_validation():
    with pytest.raises(ValueError, match="power of two"):
        BatchSpec(min_bucket=6)
    with pytest.raises(ValueError, match="power of two"):
        BatchSpec(max_bucket=100)
    with pytest.raises(ValueError, match="min_bucket"):
        BatchSpec(min_bucket=64, max_bucket=32)
    with pytest.raises(ValueError, match="pad_policy"):
        BatchSpec(pad_policy="wrap")
    with pytest.raises(TypeError, match="BatchSpec"):
        SearchOptions(batch={"min_bucket": 8})


def test_bucket_ladder_and_lookup():
    assert SPEC.buckets() == (4, 8, 16, 32)
    assert [SPEC.bucket_for(n) for n in (1, 4, 5, 8, 9, 32)] == \
        [4, 4, 8, 8, 16, 32]
    # above max_bucket: round up to a multiple of it
    assert SPEC.bucket_for(33) == 64
    assert SPEC.bucket_for(65) == 96
    with pytest.raises(ValueError, match="n >= 1"):
        SPEC.bucket_for(0)


def _stacked(schema, flts):
    return {k: jnp.asarray(v) for k, v in F.stack_programs(
        [F.compile_filter(f, schema) for f in flts]).items()}


def test_pad_rows_match_nothing_and_unpad_roundtrip(small_dataset):
    _, attrs, schema = small_dataset
    flts = [paper_filters(schema)["range_50"], F.TrueFilter(),
            paper_filters(schema)["logic"]]
    progs = _stacked(schema, flts)
    queries = jnp.asarray(np.random.default_rng(0).normal(
        size=(3, 16)).astype(np.float32))
    qp, pp, ph, valid = pad_to_bucket(SPEC, queries, progs,
                                      np.ones((3,), np.float32))
    assert qp.shape[0] == 4 and valid.tolist() == [True] * 3 + [False]
    assert ph.shape == (4,) and ph[3] == 0.0
    # pad program rows are always-false: they match no attribute row
    mask = np.asarray(F.eval_program_batched(
        {k: np.asarray(v) for k, v in pp.items()}, attrs.ints, attrs.floats))
    assert not mask[3].any() and mask[1].all()  # TrueFilter row untouched
    # unpad returns the original rows bit-identically
    uq, up = unpad(3, np.asarray(qp), np.asarray(ph))
    np.testing.assert_array_equal(uq, np.asarray(queries))
    for k in progs:
        np.testing.assert_array_equal(np.asarray(pp[k])[:3],
                                      np.asarray(progs[k]))
    # exact bucket size: nothing padded, same objects pass through
    q4 = jnp.concatenate([queries, queries[:1]])
    qp4, pp4, _, v4 = pad_to_bucket(SPEC, q4, progs)
    assert qp4 is q4 and pp4 is progs and v4.all()
    pp_only, v = pad_programs(SPEC, progs)
    assert np.asarray(pp_only["valid"]).shape[0] == 4 and not v[3]


def test_shape_registry_accounting():
    reg = ShapeRegistry()
    assert reg.record("graph", 8, 5, OPTS) is True    # compile
    assert reg.record("graph", 8, 7, OPTS) is False   # reuse
    assert reg.record("graph", 16, 9, OPTS) is True
    assert reg.record("brute", 8, 8, OPTS) is True
    # a different static config is a different executable
    assert reg.record("graph", 8, 8, OPTS.with_(ef=48)) is True
    st = reg.stats()
    assert st["compiled_shapes"] == 4 and st["compile_events"] == 4
    assert st["calls"] == 5
    assert st["pad_rows"] == 3 + 1 + 7 and st["real_rows"] == 5 + 7 + 9 + 8 + 8
    assert reg.sizes_by_kind() == {"graph": (8, 16), "brute": (8,)}
    reg.reset_rows()
    st = reg.stats()
    assert st["pad_rows"] == 0 and st["compiled_shapes"] == 4


def test_gather_distance_valid_mask(small_index, small_dataset):
    """Kernel-op mask contract on the graph-expansion op: masked rows go
    all-+inf / no-TD, unmasked rows are untouched bit-for-bit."""
    from repro.kernels.gather_distance import ops as gd_ops
    vecs, _, schema = small_dataset
    g = small_index.g
    rng = np.random.default_rng(3)
    b, m = 4, 8
    queries = jnp.asarray(rng.normal(size=(b, vecs.shape[1]))
                          .astype(np.float32))
    nbr_ids = jnp.asarray(rng.integers(-1, vecs.shape[0], size=(b, m),
                                       dtype=np.int32))
    progs = _stacked(schema, [paper_filters(schema)["range_50"]] * b)
    dvec = jnp.zeros((b,), jnp.float32)
    args = (g["vectors"], g["norms"], g["attrs_int"], g["attrs_float"],
            queries, nbr_ids, progs, dvec)
    d0, td0 = gd_ops.gather_distance(*args)
    valid = np.array([True, False, True, False])
    d1, td1 = gd_ops.gather_distance(*args, valid=valid)
    np.testing.assert_array_equal(np.asarray(d0)[[0, 2]],
                                  np.asarray(d1)[[0, 2]])
    np.testing.assert_array_equal(np.asarray(td0)[[0, 2]],
                                  np.asarray(td1)[[0, 2]])
    assert np.isinf(np.asarray(d1)[[1, 3]]).all()
    assert not np.asarray(td1)[[1, 3]].any()


def test_take_programs_stays_on_device(small_dataset):
    _, _, schema = small_dataset
    progs = _stacked(schema, [F.TrueFilter()] * 5)
    sub = router.take_programs(progs, np.array([4, 1, 2]))
    for k in progs:
        assert isinstance(sub[k], jax.Array)
        np.testing.assert_array_equal(np.asarray(sub[k]),
                                      np.asarray(progs[k])[[4, 1, 2]])


# ---------------------------------------------------------------------------
# Bit-identical parity: bucket-padded vs. disabled
# ---------------------------------------------------------------------------
def _filter_pool(schema):
    pf = paper_filters(schema)
    return [pf["equality_bool"], pf["equality_int"], pf["range_10"],
            pf["logic"], F.TrueFilter(), F.FalseFilter(),
            F.And(F.Equality("i0", 3), F.Range("f0", 11.0, 13.0))]


def _workload(schema, dim, n, seed):
    rng = np.random.default_rng(seed)
    pool = _filter_pool(schema)
    qs = rng.normal(size=(n, dim)).astype(np.float32)
    flts = [pool[i] for i in rng.integers(0, len(pool), n)]
    return qs, flts


def _assert_bit_identical(ra, rb):
    np.testing.assert_array_equal(ra.ids, rb.ids)
    np.testing.assert_array_equal(ra.dists, rb.dists)
    np.testing.assert_array_equal(ra.p_hat, rb.p_hat)
    np.testing.assert_array_equal(ra.routed_brute, rb.routed_brute)
    if ra.hops is None:
        assert rb.hops is None and rb.path_td is None
    else:
        np.testing.assert_array_equal(ra.hops, rb.hops)
        np.testing.assert_array_equal(ra.path_td, rb.path_td)


@pytest.fixture(scope="module")
def quant_local(small_index, small_dataset):
    vecs, attrs, _ = small_dataset
    return LocalBackend(FavorIndex(
        small_index.index, attrs,
        BuildSpec(quant=QuantSpec(m=8, nbits=5, train_iters=8, rerank=4))))


@pytest.fixture(scope="module")
def sharded_1dev(small_dataset):
    vecs, attrs, _ = small_dataset
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return ShardedBackend.build(vecs, attrs, mesh,
                                BuildSpec(hnsw=HnswParams(M=8, efc=48,
                                                          seed=3)))


@pytest.mark.parametrize("force,n", [
    (None, 7),      # mixed routes, odd size
    (None, 4),      # exact bucket (no padding)
    ("graph", 5),   # all-graph: empty brute sub-batch
    ("brute", 3),   # all-brute: empty graph sub-batch
    (None, 1),      # singleton batch
])
def test_local_padded_parity(small_index, small_dataset, force, n):
    vecs, _, schema = small_dataset
    qs, flts = _workload(schema, vecs.shape[1], n, seed=50 + n)
    backend = LocalBackend(small_index)
    ra = router.execute(backend, qs, flts, OPTS.with_(force=force))
    rb = router.execute(backend, qs, flts, OPTS_B.with_(force=force))
    _assert_bit_identical(ra, rb)
    assert ra.hops is not None  # LocalBackend reports traversal diagnostics


@pytest.mark.parametrize("n", [6, 1])
def test_local_padded_parity_pq(quant_local, small_dataset, n):
    vecs, _, schema = small_dataset
    qs, flts = _workload(schema, vecs.shape[1], n, seed=77)
    ra = router.execute(quant_local, qs, flts,
                        OPTS.with_(use_pq=True, force="brute"))
    rb = router.execute(quant_local, qs, flts,
                        OPTS_B.with_(use_pq=True, force="brute"))
    _assert_bit_identical(ra, rb)


def test_sharded_padded_parity_and_diag(sharded_1dev, small_dataset):
    vecs, _, schema = small_dataset
    for force, n in ((None, 7), ("brute", 3), ("graph", 5)):
        qs, flts = _workload(schema, vecs.shape[1], n, seed=60 + n)
        ra = router.execute(sharded_1dev, qs, flts, OPTS.with_(force=force))
        rb = router.execute(sharded_1dev, qs, flts, OPTS_B.with_(force=force))
        _assert_bit_identical(ra, rb)
    # the sharded top-k merge drops hops/path_td: None, not silently 0
    assert ra.hops is None and ra.path_td is None


def test_sharded_use_pallas_brute(sharded_1dev, small_dataset):
    """use_pallas now runs inside the shard_map path (was a ValueError)."""
    vecs, _, schema = small_dataset
    qs, flts = _workload(schema, vecs.shape[1], 5, seed=91)
    base = OPTS.with_(force="brute")
    rn = router.execute(sharded_1dev, qs, flts, base)
    rp = router.execute(sharded_1dev, qs, flts, base.with_(use_pallas=True))
    # kernel and jnp scan reduce in different orders: ids may swap on exact
    # distance ties, so compare per-row sets + distances (same bar as the
    # kernel suite)
    for i in range(len(qs)):
        assert set(rn.ids[i]) == set(rp.ids[i]), i
    np.testing.assert_allclose(rn.dists, rp.dists, rtol=1e-5, atol=1e-5)
    # and bucket padding composes with the kernel path bit-identically
    rpb = router.execute(sharded_1dev, qs, flts,
                         OPTS_B.with_(force="brute", use_pallas=True))
    _assert_bit_identical(rp, rpb)


def test_caching_padded_parity(small_index, small_dataset):
    from repro.cache import CachingBackend
    from repro.core import CacheSpec
    vecs, _, schema = small_dataset
    qs, flts = _workload(schema, vecs.shape[1], 6, seed=83)
    streams = [(qs, flts)] * 3  # repeats: semantic/candidate layers go hot
    results = {}
    stats = {}
    for tag, opts in (("raw", OPTS), ("padded", OPTS_B)):
        cb = CachingBackend(LocalBackend(small_index), CacheSpec())
        results[tag] = [router.execute(cb, q, f, opts) for q, f in streams]
        stats[tag] = cb.cache_stats()
    for ra, rb in zip(results["raw"], results["padded"]):
        _assert_bit_identical(ra, rb)
    # pad rows must not pollute the cache layers: identical hit/miss
    # counters whether the batch was bucket-padded or not
    for layer in ("selectivity", "candidates", "semantic"):
        assert stats["padded"][layer]["hits"] == stats["raw"][layer]["hits"]
        assert (stats["padded"][layer]["misses"]
                == stats["raw"][layer]["misses"]), layer


# ---------------------------------------------------------------------------
# hypothesis sweep (CI; the container skips without hypothesis installed)
# ---------------------------------------------------------------------------
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    @given(n=st.integers(min_value=1, max_value=9),
           force=st.sampled_from([None, "graph", "brute"]),
           seed=st.integers(min_value=0, max_value=2 ** 16))
    def test_padded_parity_property(small_index, small_dataset, n, force,
                                    seed):
        """Property form of the parity bar: any batch size / filter mix /
        route pin gives bit-identical results with bucket padding on."""
        vecs, _, schema = small_dataset
        qs, flts = _workload(schema, vecs.shape[1], n, seed=seed)
        backend = LocalBackend(small_index)
        ra = router.execute(backend, qs, flts, OPTS.with_(force=force))
        rb = router.execute(backend, qs, flts, OPTS_B.with_(force=force))
        _assert_bit_identical(ra, rb)


# ---------------------------------------------------------------------------
# warmup + engine accounting
# ---------------------------------------------------------------------------
def test_engine_warmup_bounds_compiled_shapes(small_index, small_dataset):
    vecs, _, schema = small_dataset
    eng = ServeEngine(LocalBackend(small_index), OPTS_B, max_batch=16)
    ladder = eng.warmup()
    assert ladder == SPEC.buckets()
    st0 = eng.stats["batching"]
    # estimate + graph + brute, one executable per bucket
    assert st0["compiled_shapes"] == 3 * len(ladder)
    qs, flts = _workload(schema, vecs.shape[1], 29, seed=13)
    for q, f in zip(qs, flts):
        eng.submit(q, f)
    out = eng.run()
    assert len(out) == 29
    st1 = eng.stats["batching"]
    # live traffic hit only warmed shapes: zero new compile events
    assert st1["compiled_shapes"] == st0["compiled_shapes"]
    for kind, sizes in st1["sizes"].items():
        assert set(sizes) <= set(ladder), (kind, sizes)
    assert st1["pad_rows"] > 0 and 0.0 < st1["pad_overhead"] < 1.0
    # local backends report per-request traversal diagnostics as ints
    assert isinstance(eng.stats["hops"], int)
    assert isinstance(eng.stats["path_td"], int)
    eng.reset_stats()
    st2 = eng.stats["batching"]
    assert st2["pad_rows"] == 0  # rows reset; compiled-shape set survives
    assert st2["compiled_shapes"] == st1["compiled_shapes"]
    assert eng.stats["hops"] == 0


def test_engine_warmup_unwraps_cache_and_custom_buckets(small_index):
    from repro.cache import CachingBackend
    from repro.core import CacheSpec
    cb = CachingBackend(LocalBackend(small_index), CacheSpec())
    eng = ServeEngine(cb, OPTS_B, max_batch=16)
    assert eng.warmup(buckets=(4, 8)) == (4, 8)
    st = eng.stats["batching"]
    assert st["compiled_shapes"] == 3 * 2
    # warmup drove the inner backend: no cache-layer counter pollution
    cs = eng.stats["cache"]
    assert cs["semantic"]["misses"] == 0 and cs["selectivity"]["misses"] == 0


def test_warmup_requires_batch_and_honors_force(small_index):
    # batch=None traffic would never reuse warmed shapes: loud, not silent
    with pytest.raises(ValueError, match="batch"):
        ServeEngine(LocalBackend(small_index), OPTS).warmup()
    # a pinned route skips the other route's executables entirely
    eng = ServeEngine(LocalBackend(small_index), OPTS_B.with_(force="brute"))
    ladder = eng.warmup(buckets=(4, 8))
    assert eng.stats["batching"]["compiled_shapes"] == 2 * len(ladder)
    assert "graph" not in eng.stats["batching"]["sizes"]


def test_engine_sharded_hops_none_safe(sharded_1dev, small_dataset):
    vecs, _, schema = small_dataset
    eng = ServeEngine(sharded_1dev, OPTS_B.with_(force="graph"), max_batch=8)
    qs, flts = _workload(schema, vecs.shape[1], 5, seed=29)
    for q, f in zip(qs, flts):
        eng.submit(q, f)
    eng.run()
    assert eng.stats["hops"] is None and eng.stats["path_td"] is None
