"""Deterministic synthetic data pipelines (offline container: no downloads).

Every pipeline is a pure function of (seed, step) -- checkpointable by
storing the integer state, shardable by host (each host draws its slice from
a host-folded key), and resumable bitwise after restarts (fault_tolerance
stores ``data_state`` inside the checkpoint).

Vector datasets follow the paper's section 6.1.2 generation: Gaussian-mixture
vectors (clustered, like SIFT/GIST structure) + attributes (bool equiprob,
int U{0..9}, float U[0,100]).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core import filters as F


# ---------------------------------------------------------------------------
# Vectors (FAVOR datasets)
# ---------------------------------------------------------------------------
def make_vector_dataset(n: int, dim: int, *, n_clusters: int = 32,
                        cluster_std: float = 0.35, seed: int = 0):
    """Gaussian-mixture vectors: cluster structure makes graph ANNS
    non-trivial (pure iid uniform is the easy case)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, dim)).astype(np.float32)
    assign = rng.integers(0, n_clusters, size=n)
    x = centers[assign] + cluster_std * rng.normal(size=(n, dim)).astype(np.float32)
    return np.ascontiguousarray(x, np.float32)


def make_paper_dataset(n: int, dim: int, seed: int = 0):
    vecs = make_vector_dataset(n, dim, seed=seed)
    schema = F.paper_schema()
    attrs = F.random_attributes(schema, n, seed=seed + 1)
    return vecs, attrs, schema


def make_queries(n: int, dim: int, dataset_seed: int = 0, *, n_clusters: int = 32,
                 cluster_std: float = 0.35, seed: int = 100):
    """Queries from the SAME mixture as ``make_vector_dataset(dataset_seed)``:
    identical centers (same seed), fresh assignments/noise.  In-distribution
    queries are the realistic (and HNSW-meaningful) workload -- with foreign
    centers the nearest neighbor sits outside every cluster and recall
    saturates low for any graph method."""
    rng_c = np.random.default_rng(dataset_seed)
    centers = rng_c.normal(size=(n_clusters, dim)).astype(np.float32)
    rng = np.random.default_rng(seed + dataset_seed * 7919)
    assign = rng.integers(0, n_clusters, size=n)
    x = centers[assign] + cluster_std * rng.normal(size=(n, dim)).astype(np.float32)
    return np.ascontiguousarray(x, np.float32)


# ---------------------------------------------------------------------------
# Token stream (LM training)
# ---------------------------------------------------------------------------
@dataclass
class TokenPipeline:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0

    def init_state(self) -> int:
        return 0

    def __call__(self, state: int):
        """Markov-ish synthetic tokens: next-token structure so the LM loss
        actually decreases (pure iid uniform has no learnable signal)."""
        rng = np.random.default_rng((self.seed, state))
        b, s, v = self.batch, self.seq_len, self.vocab
        base = rng.integers(0, v, size=(b, 1), dtype=np.int32)
        drift = rng.integers(0, 7, size=(b, s), dtype=np.int32)
        toks = (base + np.cumsum(drift, axis=1)) % v
        tokens = toks.astype(np.int32)
        labels = np.concatenate([tokens[:, 1:], np.full((b, 1), -1, np.int32)],
                                axis=1)
        return {"tokens": tokens, "labels": labels}, state + 1


# ---------------------------------------------------------------------------
# RecSys batches
# ---------------------------------------------------------------------------
@dataclass
class RecsysPipeline:
    n_sparse: int
    vocab: int
    batch: int
    n_dense: int = 0
    seq_len: int = 0          # DIEN behavior history
    seed: int = 0

    def init_state(self) -> int:
        return 0

    def __call__(self, state: int):
        rng = np.random.default_rng((self.seed, state))
        b = self.batch
        # zipf-ish id distribution (hot items) like production traffic
        raw = rng.zipf(1.2, size=(b, self.n_sparse)) if self.n_sparse else None
        out = {}
        if self.n_sparse:
            out["ids"] = np.minimum(raw, self.vocab - 1).astype(np.int32)
        if self.n_dense:
            out["dense"] = rng.normal(size=(b, self.n_dense)).astype(np.float32)
        if self.seq_len:
            hist = np.minimum(rng.zipf(1.2, size=(b, self.seq_len)),
                              self.vocab - 1).astype(np.int32)
            lens = rng.integers(1, self.seq_len + 1, size=b)
            pad = np.arange(self.seq_len)[None, :] >= lens[:, None]
            hist[pad] = -1
            out["hist"] = hist
            out["target"] = np.minimum(rng.zipf(1.2, size=b),
                                       self.vocab - 1).astype(np.int32)
        # learnable labels: logistic of a fixed random hash of the ids
        key_vec = np.random.default_rng(self.seed + 999).normal(
            size=(self.n_sparse or 1,))
        sig = (out.get("ids", np.zeros((b, 1))) % 97 / 97.0) @ key_vec[:, None]
        prob = 1.0 / (1.0 + np.exp(-(sig[:, 0] - sig.mean())))
        out["labels"] = (rng.random(b) < prob).astype(np.float32)
        return out, state + 1


# ---------------------------------------------------------------------------
# Graphs
# ---------------------------------------------------------------------------
def make_random_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
                      seed: int = 0, power_law: bool = True):
    """Random graph with power-law-ish degrees + self-loops + features whose
    class signal propagates over edges (so GCN accuracy is learnable)."""
    rng = np.random.default_rng(seed)
    if power_law:
        w = 1.0 / np.arange(1, n_nodes + 1) ** 0.8
        p = w / w.sum()
        src = rng.choice(n_nodes, size=n_edges, p=p).astype(np.int32)
    else:
        src = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    dst = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    # undirected + self loops
    loops = np.arange(n_nodes, dtype=np.int32)
    s = np.concatenate([src, dst, loops])
    d = np.concatenate([dst, src, loops])
    edges = np.stack([s, d]).astype(np.int32)

    labels = rng.integers(0, n_classes, size=n_nodes).astype(np.int32)
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    x = centers[labels] + rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    deg = np.zeros(n_nodes, np.float32)
    np.add.at(deg, d, 1.0)
    train_mask = rng.random(n_nodes) < 0.3
    return {"x": x, "edges": edges, "deg": deg, "labels": labels,
            "mask": train_mask}


def make_molecule_batch(batch: int, n_nodes: int, n_edges: int, d_feat: int,
                        n_classes: int = 2, seed: int = 0):
    """Block-diagonal batch of small graphs for the molecule cell."""
    rng = np.random.default_rng(seed)
    xs, es, gids = [], [], []
    for g in range(batch):
        off = g * n_nodes
        src = rng.integers(0, n_nodes, size=n_edges) + off
        dst = rng.integers(0, n_nodes, size=n_edges) + off
        loops = np.arange(n_nodes) + off
        es.append(np.stack([np.concatenate([src, dst, loops]),
                            np.concatenate([dst, src, loops])]))
        xs.append(rng.normal(size=(n_nodes, d_feat)).astype(np.float32))
        gids.append(np.full(n_nodes, g, np.int32))
    x = np.concatenate(xs)
    edges = np.concatenate(es, axis=1).astype(np.int32)
    deg = np.zeros(batch * n_nodes, np.float32)
    np.add.at(deg, edges[1], 1.0)
    labels = rng.integers(0, n_classes, size=batch).astype(np.int32)
    return {"x": x, "edges": edges, "deg": deg,
            "graph_ids": np.concatenate(gids), "labels": labels,
            "mask": np.ones(batch, bool)}
