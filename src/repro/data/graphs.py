"""Neighbor sampler for sampled-subgraph GNN training (minibatch_lg cell).

A real fanout sampler (GraphSAGE, arXiv:1706.02216): CSR adjacency built
once; per batch, seed nodes expand layer by layer with per-node uniform
neighbor sampling (fanout_l at layer l), producing a *padded static-shape*
subgraph (node list, remapped edge index, features) that the standard GCN
forward consumes unchanged.  Static shapes = one compiled program for every
batch; padding edges carry (-1, -1).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray   # (N+1,)
    indices: np.ndarray  # (E,)

    @property
    def n(self) -> int:
        return len(self.indptr) - 1

    @staticmethod
    def from_edges(edges: np.ndarray, n_nodes: int) -> "CSRGraph":
        src, dst = edges[0], edges[1]
        order = np.argsort(dst, kind="stable")  # CSR over incoming edges
        s = src[order]
        counts = np.bincount(dst, minlength=n_nodes)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return CSRGraph(indptr, s.astype(np.int32))

    def sample_neighbors(self, nodes: np.ndarray, fanout: int,
                         rng: np.random.Generator) -> np.ndarray:
        """(B,) -> (B, fanout) sampled in-neighbors, -1 padded."""
        out = np.full((len(nodes), fanout), -1, np.int32)
        for i, v in enumerate(nodes):
            lo, hi = self.indptr[v], self.indptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(fanout, deg)
            sel = rng.choice(deg, size=take, replace=deg < fanout)
            out[i, :take] = self.indices[lo + sel]
        return out


def sample_subgraph(csr: CSRGraph, feats: np.ndarray, labels: np.ndarray,
                    seeds: np.ndarray, fanouts: tuple, rng) -> dict:
    """Layered fanout expansion -> padded block-diagonal-free subgraph.

    Returns dict with x (Nmax, F), edges (2, Emax), deg, labels (Nmax,),
    mask (Nmax,) -- True only at seed rows -- with STATIC shapes given by
    (len(seeds), fanouts)."""
    n_max = len(seeds)
    e_max = 0
    layer_sizes = [len(seeds)]
    for f in fanouts:
        e_max += layer_sizes[-1] * f
        layer_sizes.append(layer_sizes[-1] * f)
        n_max += layer_sizes[-1]

    node_ids = np.full(n_max, -1, np.int64)
    node_ids[: len(seeds)] = seeds
    local = {int(v): i for i, v in enumerate(seeds)}
    n_used = len(seeds)

    edges = np.full((2, e_max + n_max), -1, np.int32)  # + self loops
    e_used = 0
    frontier = np.asarray(seeds)
    for f in fanouts:
        nbrs = csr.sample_neighbors(frontier, f, rng)   # (B, f)
        next_frontier = []
        for i, v in enumerate(frontier):
            vi = local[int(v)]
            for u in nbrs[i]:
                if u < 0:
                    continue
                ui = local.get(int(u))
                if ui is None:
                    ui = n_used
                    local[int(u)] = ui
                    node_ids[ui] = u
                    n_used += 1
                edges[0, e_used] = ui
                edges[1, e_used] = vi
                e_used += 1
                next_frontier.append(u)
        frontier = np.asarray(next_frontier, np.int64) if next_frontier else frontier[:0]
        if len(frontier) == 0:
            break
    # self-loops on used nodes
    for i in range(n_used):
        edges[0, e_used] = i
        edges[1, e_used] = i
        e_used += 1

    ids_safe = np.maximum(node_ids, 0)
    x = feats[ids_safe].astype(np.float32)
    x[node_ids < 0] = 0.0
    lab = labels[ids_safe].astype(np.int32)
    deg = np.zeros(n_max, np.float32)
    valid_e = edges[1] >= 0
    np.add.at(deg, edges[1][valid_e], 1.0)
    mask = np.zeros(n_max, bool)
    mask[: len(seeds)] = True
    return {"x": x, "edges": edges, "deg": deg, "labels": lab, "mask": mask,
            "n_used": n_used}


def minibatch_shapes(batch_nodes: int, fanouts: tuple, d_feat: int):
    """Static shapes of a sampled subgraph (for the dry-run input specs)."""
    n_max = batch_nodes
    e_max = 0
    sz = batch_nodes
    for f in fanouts:
        e_max += sz * f
        sz *= f
        n_max += sz
    return {"n": n_max, "e": e_max + n_max, "d": d_feat}
