"""repro: FAVOR (filter-agnostic vector ANNS) as a production JAX framework.

NOTE: this module must stay import-light (no jax import here) so that
launch/dryrun.py can set XLA_FLAGS before jax initializes.
"""
__version__ = "1.1.0"
