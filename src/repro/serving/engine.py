"""Batched FAVOR serving engine (paper Figure 1 online phase, production
shape): request queue -> batch assembly -> selector routing -> per-route
compiled executables -> response reassembly + latency accounting.

Routing (section 4.1) happens on estimated selectivity *before* search; the
engine groups each assembled batch into a brute sub-batch and a graph
sub-batch so every executable runs with uniform static shapes (one XLA
program per route, padded to bucket sizes to bound recompilation).

The engine is backend-agnostic: it drives any ``core.backend.Backend``
(LocalBackend on one host, ShardedBackend across a mesh, future cache/async
backends) through the shared ``router.execute`` pipeline, configured by one
frozen ``SearchOptions``:

    eng = ServeEngine(LocalBackend(fi), SearchOptions(k=10, ef=96))
    eng = ServeEngine(ShardedBackend.build(vecs, attrs, mesh, spec), opts)

Passing a FavorIndex (optionally with the legacy k=/ef=/use_pq= kwargs)
still works and wraps it in a LocalBackend.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from ..core import batching
from ..core import filters as F
from ..core import router
from ..core.backend import LocalBackend
from ..core.batching import BatchSpec, ShapeRegistry
from ..core.favor import FavorIndex
from ..core.options import SearchOptions


@dataclass
class Request:
    rid: int
    query: np.ndarray
    flt: "F.Filter"
    scope: int = 0
    t_submit: float = field(default_factory=time.perf_counter)


@dataclass
class Response:
    rid: int
    ids: np.ndarray
    dists: np.ndarray
    route: str
    p_hat: float
    latency_s: float


def _bucket(n: int, spec: BatchSpec | None = None) -> int:
    """Bucket size for an n-row batch off the one BatchSpec ladder (the
    engine's legacy whole-batch pre-pad and the router's sub-batch padding
    round against the same source of truth; the old hardcoded
    (8, ..., 512) tuple was exactly BatchSpec's default ladder)."""
    return (spec or BatchSpec()).bucket_for(n)


class ServeEngine:
    """Queue/batch/deadline front-end over one execution backend."""

    def __init__(self, backend, opts: SearchOptions | None = None, *,
                 max_batch: int = 256, max_wait_ms: float = 2.0,
                 latency_window: int = 4096,
                 merge_delta_frac: float | None = None,
                 k: int | None = None, ef: int | None = None,
                 use_pq: bool | None = None):
        if isinstance(backend, FavorIndex):
            backend = LocalBackend(backend)
        if isinstance(opts, int) and not isinstance(opts, bool):
            # pre-1.1 second positional was k: ServeEngine(fi, 10)
            if k is not None:
                raise ValueError("k passed both positionally and by keyword")
            k, opts = opts, None
        if opts is not None and not isinstance(opts, SearchOptions):
            raise TypeError("opts must be a SearchOptions, got "
                            f"{type(opts).__name__}")
        if k is not None or ef is not None or use_pq is not None:
            if opts is not None:
                raise ValueError("pass either opts=SearchOptions(...) or "
                                 "legacy k=/ef=/use_pq= kwargs, not both")
            warnings.warn(
                "ServeEngine(k=, ef=, use_pq=) is deprecated; pass "
                "SearchOptions(...)", DeprecationWarning, stacklevel=2)
            opts = SearchOptions(k=k if k is not None else 10,
                                 ef=ef if ef is not None else 100,
                                 use_pq=bool(use_pq))
        self.backend = backend
        self.opts = opts or SearchOptions()
        # incompatible (backend, opts) pairs fail here, not mid-serve
        backend.validate(self.opts)
        self.max_batch = max_batch
        # one bucket ladder everywhere: the router pads sub-batches with
        # opts.batch; the legacy whole-batch pre-pad (opts.batch None)
        # rounds against the same BatchSpec ladder (its defaults ARE the
        # old hardcoded bucket tuple)
        self.pad_spec = self.opts.batch or BatchSpec()
        self.max_wait_s = max_wait_ms / 1e3
        if latency_window < 1:
            raise ValueError(f"latency_window must be >= 1, "
                             f"got {latency_window}")
        self.queue: list[Request] = []
        self._counters = {"graph": 0, "brute": 0, "batches": 0}
        # bounded rolling window: long-running engines must not grow memory
        # with request count (percentiles are over the last N requests)
        self.latencies: deque[float] = deque(maxlen=latency_window)
        self._next_rid = 0
        # compiled-shape + pad-overhead ledger (core.batching); fed by every
        # router.execute call and by warmup()
        self.registry = ShapeRegistry()
        # graph-traversal diagnostics: totals across served requests, or
        # None-safe "unknown" once a backend that doesn't report them (the
        # sharded serve path) handled a graph sub-batch
        self._hops = 0
        self._path_td = 0
        self._diag_known = True
        # live-index mutation plumbing: merge_delta_frac schedules a
        # background compaction between steps once the unmerged delta grows
        # past that fraction of the base row count (None = manual merge only)
        if merge_delta_frac is not None and merge_delta_frac <= 0.0:
            raise ValueError(f"merge_delta_frac must be > 0, "
                             f"got {merge_delta_frac}")
        self.merge_delta_frac = merge_delta_frac
        self._mutations = {"upserts": 0, "deletes": 0, "merges": 0,
                           "auto_merges": 0}

    # -- live-index mutation API ---------------------------------------------
    def _mutable(self, op: str):
        fn = getattr(self.backend, op, None)
        if fn is None:
            raise ValueError(
                f"backend {type(self.backend).__name__} does not support "
                f"live mutation ({op}); use a LocalBackend/ShardedBackend "
                f"(optionally cache-wrapped)")
        return fn

    def upsert(self, vectors, ints=None, floats=None, *, replace=None):
        """Stream rows into the backend's live delta; returns their ids."""
        ids = self._mutable("upsert")(vectors, ints, floats, replace=replace)
        self._mutations["upserts"] += int(len(ids))
        return ids

    def delete(self, ids) -> int:
        """Tombstone ids; returns how many were found alive."""
        n = int(self._mutable("delete")(ids))
        self._mutations["deletes"] += n
        return n

    def merge(self, *, wave: int = 512) -> dict:
        """Fold the delta into the base index now (manual compaction)."""
        out = self._mutable("merge")(wave=wave)
        self._mutations["merges"] += 1
        return out

    def _maybe_merge(self) -> None:
        """Between-steps merge scheduling: compact once the delta fraction
        crosses ``merge_delta_frac`` (checked after each served batch, so
        compaction cost never lands inside a request's latency path)."""
        if self.merge_delta_frac is None:
            return
        live_stats = getattr(self.backend, "live_stats", None)
        if live_stats is None:
            return
        st = live_stats()
        if st["delta_rows"] and (st["delta_rows"] >=
                                 self.merge_delta_frac *
                                 max(st["base_rows"], 1)):
            self._mutable("merge")()
            self._mutations["merges"] += 1
            self._mutations["auto_merges"] += 1

    def _route_scorers(self) -> dict:
        """Which scorer serves each route under this engine's options:
        the graph route per ``opts.graph_quant`` (core.scoring), the brute
        route per ``opts.use_pq`` + the backend's code kind."""
        target = self.backend
        inner = getattr(target, "inner", None)
        while inner is not None:        # unwrap cache decorators
            target, inner = inner, getattr(inner, "inner", None)
        kind = getattr(target, "quant", None)
        if kind is None:
            kind = getattr(getattr(target, "index", None), "quantize", None)
        return {"graph": self.opts.graph_quant or "exact",
                "brute": (kind or "exact") if self.opts.use_pq else "exact",
                "use_pallas": self.opts.use_pallas}

    @property
    def stats(self) -> dict:
        """Routing counters; ``scorers`` -- which scorer (exact/pq/sq)
        serves each route under the engine's options; ``hops``/``path_td``
        graph-traversal totals (``None`` -- not silently 0 -- when the
        backend does not report them, e.g. the sharded top-k merge);
        ``batching`` compiled-shape and pad-overhead counters; plus the
        backend's per-layer cache hit/miss/bypass counters when it is
        cache-capable (CachingBackend)."""
        out = dict(self._counters)
        out["scorers"] = self._route_scorers()
        out["hops"] = self._hops if self._diag_known else None
        out["path_td"] = self._path_td if self._diag_known else None
        out["batching"] = self.registry.stats()
        cache_stats = getattr(self.backend, "cache_stats", None)
        if cache_stats is not None:
            out["cache"] = cache_stats()
        # engine-level mutation counters + the backend's live-state gauges
        # (delta/tombstone occupancy) when it supports streaming mutation
        out["mutations"] = dict(self._mutations)
        live_stats = getattr(self.backend, "live_stats", None)
        if live_stats is not None:
            out["mutations"].update(live_stats())
        return out

    def reset_stats(self) -> None:
        """Zero the routing counters, diagnostics and pad-overhead rows and
        drop the latency window.  The compiled-shape set survives (it
        mirrors still-live executables), as do cached *entries*; use
        backend.clear() to drop those too."""
        self._counters = {"graph": 0, "brute": 0, "batches": 0}
        self.latencies.clear()
        self._hops = 0
        self._path_td = 0
        self._diag_known = True
        self._mutations = {"upserts": 0, "deletes": 0, "merges": 0,
                           "auto_merges": 0}
        self.registry.reset_rows()

    def warmup(self, buckets=None) -> tuple[int, ...]:
        """Compile every (estimate/graph/brute, bucket) executable now, so
        first-request traffic never pays an XLA/Pallas compile.  Requires
        ``opts.batch`` to be set (raises ValueError otherwise: unpadded
        traffic would never reuse the warmed shapes); routes pinned away by
        ``opts.force`` are skipped.  Returns the warmed ladder."""
        ladder = batching.warmup(self.backend, self.opts, buckets=buckets,
                                 registry=self.registry)
        # warmup batches are 100% pad rows; drop them from the row counters
        # so stats["batching"]["pad_overhead"] reflects live traffic only
        # (the compiled-shape set they created survives)
        self.registry.reset_rows()
        return ladder

    @property
    def k(self) -> int:
        return self.opts.k

    @property
    def ef(self) -> int:
        return self.opts.ef

    def submit(self, query: np.ndarray, flt: "F.Filter",
               scope: int = 0) -> int:
        """Enqueue one request; ``scope`` is the optional tenant/session
        scope id (0 = unscoped) the cache subsystem keys its semantic and
        candidate layers on -- the async front-end sets it per tenant."""
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(query, np.float32), flt,
                                  scope=int(scope)))
        return rid

    def _assemble(self) -> list[Request]:
        take = min(len(self.queue), self.max_batch)
        batch, self.queue = self.queue[:take], self.queue[take:]
        return batch

    def _due(self) -> bool:
        """A batch is due when it is full or the oldest request has waited
        past the max_wait_ms deadline (latency/throughput trade-off knob)."""
        if not self.queue:
            return False
        if len(self.queue) >= self.max_batch:
            return True
        return time.perf_counter() - self.queue[0].t_submit >= self.max_wait_s

    def step(self, force: bool = False) -> list[Response]:
        """Drain one batch if it is due (or ``force``); returns completed
        responses ([] when the engine decided to keep waiting for more
        requests to fill the batch)."""
        if not self.queue or not (force or self._due()):
            return []
        batch = self._assemble()
        self._counters["batches"] += 1
        queries = np.stack([r.query for r in batch])
        flts = [r.flt for r in batch]
        scopes = [r.scope for r in batch]
        if self.opts.batch is None:
            # legacy whole-batch repeat-padding: reuses a compiled program
            # per batch size, but the post-route gi/bi sub-batches still
            # recompile per split.  With opts.batch set the router bucket-
            # pads every sub-batch itself (mask rows, bit-identical results)
            # so no pre-padding is needed here.
            b = _bucket(len(batch), self.pad_spec)
            if b > len(batch):
                queries = np.concatenate(
                    [queries, np.repeat(queries[-1:], b - len(batch), 0)])
                flts = flts + [flts[-1]] * (b - len(batch))
                scopes = scopes + [scopes[-1]] * (b - len(batch))
        res = router.execute(self.backend, queries, flts, self.opts,
                             registry=self.registry, scopes=scopes)
        t_done = time.perf_counter()
        if res.hops is None:
            self._diag_known = False
        else:  # slice off legacy whole-batch pad rows, if any
            self._hops += int(res.hops[:len(batch)].sum())
            self._path_td += int(res.path_td[:len(batch)].sum())
        out = []
        for i, r in enumerate(batch):
            route = "brute" if res.routed_brute[i] else "graph"
            self._counters[route] += 1
            lat = t_done - r.t_submit
            self.latencies.append(lat)
            out.append(Response(r.rid, res.ids[i], res.dists[i], route,
                                float(res.p_hat[i]), lat))
        self._maybe_merge()
        return out

    def run(self, until_empty: bool = True) -> list[Response]:
        """until_empty=True serves the whole queue *deadline-aware*: full
        batches flush immediately, but a straggling partial batch waits out
        the remainder of ``max_wait_ms`` (its coalescing window) before it
        is forced -- so a near-future arrival can still join it, instead of
        the pre-1.7 behavior of forcing sub-batches the instant the queue
        was non-empty.  Shutdown paths that must not wait use ``drain()``.
        until_empty=False processes only batches that are already due and
        leaves the rest waiting for the deadline."""
        out = []
        if until_empty:
            while self.queue:
                if not self._due():
                    rem = self.max_wait_s - (time.perf_counter()
                                             - self.queue[0].t_submit)
                    if rem > 0:
                        time.sleep(rem)
                out.extend(self.step(force=True))
        else:
            while self._due():
                out.extend(self.step())
        return out

    def drain(self) -> list[Response]:
        """Force every queued request out NOW, ignoring ``max_wait_ms``
        (the front-end shutdown path: nothing new is coming, so waiting out
        straggler deadlines would only add latency)."""
        out = []
        while self.queue:
            out.extend(self.step(force=True))
        return out

    def latency_percentiles(self) -> dict:
        if not self.latencies:
            return {}
        arr = np.asarray(self.latencies) * 1e3
        return {f"p{p}": float(np.percentile(arr, p)) for p in (50, 90, 99)}
