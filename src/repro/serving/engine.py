"""Batched FAVOR serving engine (paper Figure 1 online phase, production
shape): request queue -> batch assembly -> selector routing -> per-route
compiled executables -> response reassembly + latency accounting.

Routing (section 4.1) happens on estimated selectivity *before* search; the
engine groups each assembled batch into a brute sub-batch and a graph
sub-batch so every executable runs with uniform static shapes (one XLA
program per route, padded to bucket sizes to bound recompilation).

The engine is backend-agnostic: it drives any ``core.backend.Backend``
(LocalBackend on one host, ShardedBackend across a mesh, future cache/async
backends) through the shared ``router.execute`` pipeline, configured by one
frozen ``SearchOptions``:

    eng = ServeEngine(LocalBackend(fi), SearchOptions(k=10, ef=96))
    eng = ServeEngine(ShardedBackend.build(vecs, attrs, mesh, spec), opts)

Passing a FavorIndex (optionally with the legacy k=/ef=/use_pq= kwargs)
still works and wraps it in a LocalBackend.
"""
from __future__ import annotations

import threading
import time
import warnings
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from ..core import batching
from ..core import filters as F
from ..core import router
from ..core.backend import LocalBackend
from ..core.batching import BatchSpec, ShapeRegistry
from ..core.favor import FavorIndex
from ..core.options import ObsSpec, SearchOptions
from ..obs import Obs

# p_hat lives in [0,1]; bounds straddle the default route lambda (0.01) so
# the selectivity-band request distribution is readable off one histogram
P_HAT_BUCKETS = (0.001, 0.003, 0.01, 0.03, 0.1, 0.3, 1.0)

# traversal wave counts: bounded by SearchConfig.steps (default 64 plus a
# compaction-ladder tail), pow-2 edges so the lane-compaction win (fewer
# full-width waves) shows up as mass shifting left
WAVE_BUCKETS = (4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0)


@dataclass
class Request:
    rid: int
    query: np.ndarray
    flt: "F.Filter"
    scope: int = 0
    t_submit: float = field(default_factory=time.perf_counter)


@dataclass
class Response:
    rid: int
    ids: np.ndarray
    dists: np.ndarray
    route: str
    p_hat: float
    latency_s: float


@dataclass(eq=False)
class _InflightStep:
    """One dispatched-but-unfinished engine step (``begin_batch`` output):
    the host phase ran and the device work is queued; ``finish_batch``
    blocks on it and does the per-request accounting."""
    batch: list[Request]
    pending: "router.PendingExecution"
    queries: np.ndarray
    flts: list


def _bucket(n: int, spec: BatchSpec | None = None) -> int:
    """Bucket size for an n-row batch off the one BatchSpec ladder (the
    engine's legacy whole-batch pre-pad and the router's sub-batch padding
    round against the same source of truth; the old hardcoded
    (8, ..., 512) tuple was exactly BatchSpec's default ladder)."""
    return (spec or BatchSpec()).bucket_for(n)


class ServeEngine:
    """Queue/batch/deadline front-end over one execution backend."""

    def __init__(self, backend, opts: SearchOptions | None = None, *,
                 max_batch: int = 256, max_wait_ms: float = 2.0,
                 latency_window: int = 4096,
                 merge_delta_frac: float | None = None,
                 merge_background: bool = False,
                 obs: "Obs | ObsSpec | None" = None,
                 time_fn=time.perf_counter,
                 k: int | None = None, ef: int | None = None,
                 use_pq: bool | None = None):
        if isinstance(backend, FavorIndex):
            backend = LocalBackend(backend)
        if isinstance(opts, int) and not isinstance(opts, bool):
            # pre-1.1 second positional was k: ServeEngine(fi, 10)
            if k is not None:
                raise ValueError("k passed both positionally and by keyword")
            k, opts = opts, None
        if opts is not None and not isinstance(opts, SearchOptions):
            raise TypeError("opts must be a SearchOptions, got "
                            f"{type(opts).__name__}")
        if k is not None or ef is not None or use_pq is not None:
            if opts is not None:
                raise ValueError("pass either opts=SearchOptions(...) or "
                                 "legacy k=/ef=/use_pq= kwargs, not both")
            warnings.warn(
                "ServeEngine(k=, ef=, use_pq=) is deprecated; pass "
                "SearchOptions(...)", DeprecationWarning, stacklevel=2)
            opts = SearchOptions(k=k if k is not None else 10,
                                 ef=ef if ef is not None else 100,
                                 use_pq=bool(use_pq))
        self.backend = backend
        self.opts = opts or SearchOptions()
        # incompatible (backend, opts) pairs fail here, not mid-serve
        backend.validate(self.opts)
        self.max_batch = max_batch
        # one bucket ladder everywhere: the router pads sub-batches with
        # opts.batch; the legacy whole-batch pre-pad (opts.batch None)
        # rounds against the same BatchSpec ladder (its defaults ARE the
        # old hardcoded bucket tuple)
        self.pad_spec = self.opts.batch or BatchSpec()
        self.max_wait_s = max_wait_ms / 1e3
        if latency_window < 1:
            raise ValueError(f"latency_window must be >= 1, "
                             f"got {latency_window}")
        self.queue: list[Request] = []
        # injectable monotonic clock: latency/deadline behavior becomes
        # deterministic under a fake clock (obs + tests share it)
        self._time = time_fn
        # bounded rolling window: long-running engines must not grow memory
        # with request count (percentiles are over the last N requests)
        self.latencies: deque[float] = deque(maxlen=latency_window)
        self._next_rid = 0
        # compiled-shape + pad-overhead ledger (core.batching); fed by every
        # router.execute call and by warmup()
        self.registry = ShapeRegistry()
        # graph-traversal diagnostics are None-safe "unknown" once a backend
        # that doesn't report them (the sharded serve path) handled a graph
        # sub-batch
        self._diag_known = True
        # live-index mutation plumbing: merge_delta_frac schedules a
        # background compaction between steps once the unmerged delta grows
        # past that fraction of the base row count (None = manual merge only)
        if merge_delta_frac is not None and merge_delta_frac <= 0.0:
            raise ValueError(f"merge_delta_frac must be > 0, "
                             f"got {merge_delta_frac}")
        self.merge_delta_frac = merge_delta_frac
        # one reentrant lock guards every host-side mutable surface (queue,
        # counters, cache/backend hooks, merge commit).  Device work is
        # dispatched *inside* the lock but synced *outside* it
        # (PendingExecution.finish), so N pipelined steps overlap their
        # device waits while host phases stay serialized.  Reentrant
        # because finish-side hooks (_maybe_merge -> backend.merge) and the
        # merge controller's commit both re-enter engine methods.
        self._lock = threading.RLock()
        # one metrics registry serves every stats surface (repro.obs): the
        # engine records typed instruments, and nested legacy dicts (shape
        # ledger, cache layers, scorers, live gauges) join as views, so
        # snapshot()/prometheus_text() export the whole stack
        if obs is None or isinstance(obs, ObsSpec):
            obs = Obs(obs, time_fn=time_fn)
        elif not isinstance(obs, Obs):
            raise TypeError("obs must be an Obs, ObsSpec or None, got "
                            f"{type(obs).__name__}")
        self.obs = obs
        reg = obs.registry
        self._m_requests = reg.counter(
            "favor_requests_total", "Requests served, by route",
            labels=("route",))
        self._m_batches = reg.counter(
            "favor_batches_total", "Engine batches dispatched")
        self._m_latency = reg.histogram(
            "favor_request_latency_seconds",
            "End-to-end request latency (submit to response)",
            buckets=obs.spec.latency_buckets)
        self._m_p_hat = reg.histogram(
            "favor_p_hat", "Estimated selectivity of served requests",
            buckets=P_HAT_BUCKETS)
        self._m_hops = reg.counter(
            "favor_graph_hops_total",
            "Graph-traversal hops across served requests")
        self._m_path_td = reg.counter(
            "favor_graph_path_td_total",
            "Exclusion-distance path totals across served requests")
        self._m_waves = reg.histogram(
            "favor_graph_waves",
            "Traversal wave count (lane-compacted while_loop iterations) "
            "observed by each served request, by route",
            labels=("route",), buckets=WAVE_BUCKETS)
        self._m_bytes_hop = reg.gauge(
            "favor_bytes_per_hop",
            "Bytes one gathered neighbor row streams from HBM under this "
            "engine's graph scorer (4*d f32, M codes PQ, d codes SQ)")
        bph = getattr(self._base_backend(), "bytes_per_hop", None)
        if bph is not None:
            self._m_bytes_hop.set(float(bph(self.opts)))
        self._m_mutations = reg.counter(
            "favor_mutations_total", "Live-index mutations, by operation",
            labels=("op",))
        self._m_inflight = reg.gauge(
            "favor_inflight_steps",
            "Engine steps dispatched to the device but not yet finished "
            "(pipelined serving depth)")
        self._last_step_end = 0.0   # perf_counter of last finish_batch
        self._m_merge_active = reg.gauge(
            "favor_merge_active",
            "1 while a background merge is building or committing")
        self._m_merge_s = reg.histogram(
            "favor_merge_seconds",
            "Wall time of one whole merge (prepare + commit)",
            buckets=(0.01, 0.03, 0.1, 0.3, 1.0, 3.0, 10.0, 30.0))
        self._m_merge_stall = reg.histogram(
            "favor_merge_stall_seconds",
            "Time a merge commit held the engine lock (the only slice of a "
            "background merge that can stall a step)",
            buckets=(1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0))
        reg.register_view("batching", self.registry.stats)
        reg.register_view("scorers", self._route_scorers)
        reg.register_view("mutations", self._mutation_view)
        cache_stats = getattr(backend, "cache_stats", None)
        if cache_stats is not None:
            reg.register_view("cache", cache_stats)
        live_stats = getattr(backend, "live_stats", None)
        if live_stats is not None:
            reg.register_view("live", live_stats)
        # resets cascade: obs.reset() zeroes the instruments above, then
        # these hooks clear every legacy counter the registry can't own
        reg.on_reset(self._on_registry_reset)
        cache_reset = getattr(backend, "reset_cache_counters", None)
        if callable(cache_reset):
            reg.on_reset(cache_reset)
        # background incremental merge: a MergeController worker owns the
        # expensive build phase off the serving path; _maybe_merge pokes it
        # instead of merging inline
        self._merge_ctl = None
        if merge_background:
            from .merge import MergeController
            self._merge_ctl = MergeController(self)

    def close(self) -> None:
        """Stop the background merge worker (if any).  Idempotent; the
        engine itself keeps serving after close -- only the worker dies."""
        if self._merge_ctl is not None:
            self._merge_ctl.stop()
            self._merge_ctl = None

    # -- live-index mutation API ---------------------------------------------
    def _mutable(self, op: str):
        fn = getattr(self.backend, op, None)
        if fn is None:
            raise ValueError(
                f"backend {type(self.backend).__name__} does not support "
                f"live mutation ({op}); use a LocalBackend/ShardedBackend "
                f"(optionally cache-wrapped)")
        return fn

    def upsert(self, vectors, ints=None, floats=None, *, replace=None):
        """Stream rows into the backend's live delta; returns their ids."""
        with self._lock:
            ids = self._mutable("upsert")(vectors, ints, floats,
                                          replace=replace)
            self._m_mutations.inc(int(len(ids)), op="upserts")
            return ids

    def delete(self, ids) -> int:
        """Tombstone ids; returns how many were found alive."""
        with self._lock:
            n = int(self._mutable("delete")(ids))
            self._m_mutations.inc(n, op="deletes")
            return n

    def merge(self, *, wave: int = 512) -> dict:
        """Fold the delta into the base index now (manual compaction)."""
        with self._lock:
            out = self._mutable("merge")(wave=wave)
            self._m_mutations.inc(op="merges")
            return out

    def _merge_due(self) -> bool:
        """True once the unmerged delta crosses ``merge_delta_frac`` of the
        base row count (shared trigger for the inline scheduler and the
        background controller)."""
        if self.merge_delta_frac is None:
            return False
        live_stats = getattr(self.backend, "live_stats", None)
        if live_stats is None:
            return False
        st = live_stats()
        return bool(st["delta_rows"] and
                    (st["delta_rows"] >=
                     self.merge_delta_frac * max(st["base_rows"], 1)))

    def _maybe_merge(self) -> None:
        """Between-steps merge scheduling: compact once the delta fraction
        crosses ``merge_delta_frac`` (checked after each served batch, so
        compaction cost never lands inside a request's latency path).  With
        a background controller attached, this only *pokes* the worker --
        the build runs off-thread and commits via an epoch-guarded swap."""
        if self._merge_ctl is not None:
            if self._merge_due():
                self._merge_ctl.poke()
            return
        if self._merge_due():
            self._mutable("merge")()
            self._m_mutations.inc(op="merges")
            self._m_mutations.inc(op="auto_merges")

    def _base_backend(self):
        """The innermost backend (cache decorators unwrapped)."""
        target = self.backend
        inner = getattr(target, "inner", None)
        while inner is not None:
            target, inner = inner, getattr(inner, "inner", None)
        return target

    def _route_scorers(self) -> dict:
        """Which scorer serves each route under this engine's options:
        the graph route per ``opts.graph_quant`` (core.scoring), the brute
        route per ``opts.use_pq`` + the backend's code kind."""
        target = self._base_backend()
        kind = getattr(target, "quant", None)
        if kind is None:
            kind = getattr(getattr(target, "index", None), "quantize", None)
        return {"graph": self.opts.graph_quant or "exact",
                "brute": (kind or "exact") if self.opts.use_pq else "exact",
                "use_pallas": self.opts.use_pallas}

    def _mutation_view(self) -> dict:
        """Engine mutation counters + the backend's live-state gauges
        (delta/tombstone occupancy) when it supports streaming mutation."""
        out = {op: int(self._m_mutations.value(op=op))
               for op in ("upserts", "deletes", "merges", "auto_merges")}
        live_stats = getattr(self.backend, "live_stats", None)
        if live_stats is not None:
            out.update(live_stats())
        return out

    @property
    def stats(self) -> dict:
        """Thin view over the one metrics registry (``self.obs.registry``):
        routing counters; ``scorers`` -- which scorer (exact/pq/sq) serves
        each route under the engine's options; ``hops``/``path_td``
        graph-traversal totals (``None`` -- not silently 0 -- when the
        backend does not report them, e.g. the sharded top-k merge);
        ``batching`` compiled-shape and pad-overhead counters; the
        backend's per-layer cache hit/miss/bypass counters when it is
        cache-capable (CachingBackend); ``obs`` -- trace/slow-query ring
        occupancy.  ``obs.snapshot()`` / ``obs.prometheus_text()`` export
        the same registry for machines."""
        reg = self.obs.registry
        out = {"graph": int(self._m_requests.value(route="graph")),
               "brute": int(self._m_requests.value(route="brute")),
               "batches": int(self._m_batches.value())}
        out["scorers"] = reg.view("scorers")
        out["hops"] = (int(self._m_hops.value())
                       if self._diag_known else None)
        out["path_td"] = (int(self._m_path_td.value())
                          if self._diag_known else None)
        out["bytes_per_hop"] = (int(self._m_bytes_hop.value())
                                or None)  # 0 = backend doesn't report it
        n_waves = self._m_waves.count(route="graph")
        out["graph_waves_avg"] = (self._m_waves.sum(route="graph") / n_waves
                                  if n_waves else None)
        out["batching"] = reg.view("batching")
        if reg.has_view("cache"):
            out["cache"] = reg.view("cache")
        out["mutations"] = reg.view("mutations")
        out["obs"] = self.obs.summary()
        return out

    def _on_registry_reset(self) -> None:
        """Legacy-state half of the reset cascade (see reset_stats)."""
        self.latencies.clear()
        self._diag_known = True
        self.registry.reset_rows()

    def reset_stats(self) -> None:
        """Zero every counter in the stack through the registry's reset
        cascade: routing/mutation/latency instruments, diagnostics,
        pad-overhead rows, trace + slow-query rings, cache layer counters,
        and any front-end tenant/coalesce ledgers hooked onto this engine.
        The compiled-shape set survives (it mirrors still-live
        executables), as do cached *entries*; use backend.clear() to drop
        those too."""
        self.obs.reset()

    def warmup(self, buckets=None) -> tuple[int, ...]:
        """Compile every (estimate/graph/brute, bucket) executable now, so
        first-request traffic never pays an XLA/Pallas compile.  Requires
        ``opts.batch`` to be set (raises ValueError otherwise: unpadded
        traffic would never reuse the warmed shapes); routes pinned away by
        ``opts.force`` are skipped.  Returns the warmed ladder."""
        ladder = batching.warmup(self.backend, self.opts, buckets=buckets,
                                 registry=self.registry)
        # warmup batches are 100% pad rows; drop them from the row counters
        # so stats["batching"]["pad_overhead"] reflects live traffic only
        # (the compiled-shape set they created survives)
        self.registry.reset_rows()
        return ladder

    @property
    def k(self) -> int:
        return self.opts.k

    @property
    def ef(self) -> int:
        return self.opts.ef

    def submit(self, query: np.ndarray, flt: "F.Filter",
               scope: int = 0) -> int:
        """Enqueue one request; ``scope`` is the optional tenant/session
        scope id (0 = unscoped) the cache subsystem keys its semantic and
        candidate layers on -- the async front-end sets it per tenant."""
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self.queue.append(Request(rid, np.asarray(query, np.float32),
                                      flt, scope=int(scope),
                                      t_submit=self._time()))
            return rid

    def _assemble(self) -> list[Request]:
        take = min(len(self.queue), self.max_batch)
        batch, self.queue = self.queue[:take], self.queue[take:]
        return batch

    def _due(self) -> bool:
        """A batch is due when it is full or the oldest request has waited
        past the max_wait_ms deadline (latency/throughput trade-off knob)."""
        if not self.queue:
            return False
        if len(self.queue) >= self.max_batch:
            return True
        return self._time() - self.queue[0].t_submit >= self.max_wait_s

    def begin_batch(self, force: bool = False) -> "_InflightStep | None":
        """Host phase of one step: assemble a due batch, run routing +
        bucket padding, *dispatch* the per-route device work, and return
        without waiting for it.  The whole phase runs under the engine
        lock; the returned step's device work rides JAX async dispatch.
        Returns None when no batch is due."""
        with self._lock:
            if not self.queue or not (force or self._due()):
                return None
            batch = self._assemble()
            self._m_batches.inc()
            queries = np.stack([r.query for r in batch])
            flts = [r.flt for r in batch]
            scopes = [r.scope for r in batch]
            if self.opts.batch is None:
                # legacy whole-batch repeat-padding: reuses a compiled
                # program per batch size, but the post-route gi/bi
                # sub-batches still recompile per split.  With opts.batch
                # set the router bucket-pads every sub-batch itself (mask
                # rows, bit-identical results) so no pre-padding is needed
                b = _bucket(len(batch), self.pad_spec)
                if b > len(batch):
                    queries = np.concatenate(
                        [queries,
                         np.repeat(queries[-1:], b - len(batch), 0)])
                    flts = flts + [flts[-1]] * (b - len(batch))
                    scopes = scopes + [scopes[-1]] * (b - len(batch))
            pending = router.execute(
                self.backend, queries, flts, self.opts,
                registry=self.registry, scopes=scopes,
                obs=self.obs if self.obs.enabled else None, defer=True)
            self._m_inflight.add(1.0)
            return _InflightStep(batch, pending, queries, flts)

    def finish_batch(self, step: "_InflightStep") -> list[Response]:
        """Device phase of one step: block on the dispatched work (no lock
        held -- other threads keep dispatching/submitting), then do the
        per-request accounting under the lock."""
        try:
            # mutating finish hooks (cache record, obs trace) take the
            # engine lock; the device sync itself runs outside it
            res = step.pending.finish(hook_lock=self._lock)
        finally:
            self._m_inflight.add(-1.0)
            # lets the merge controller tell "between steps" from "no
            # traffic" when pacing its build waves
            self._last_step_end = time.perf_counter()
        batch = step.batch
        with self._lock:
            t_done = self._time()
            if res.hops is None:
                self._diag_known = False
            else:  # slice off legacy whole-batch pad rows, if any
                self._m_hops.inc(int(res.hops[:len(batch)].sum()))
                self._m_path_td.inc(int(res.path_td[:len(batch)].sum()))
            out = []
            for i, r in enumerate(batch):
                route = "brute" if res.routed_brute[i] else "graph"
                self._m_requests.inc(route=route)
                # waves==0 means no traversal ran for this lane (cache
                # hit): keep those out of the traversal-depth histogram
                if (res.waves is not None and route == "graph"
                        and res.waves[i]):
                    self._m_waves.observe(float(res.waves[i]), route=route)
                lat = t_done - r.t_submit
                self.latencies.append(lat)
                self._m_latency.observe(lat)
                out.append(Response(r.rid, res.ids[i], res.dists[i], route,
                                    float(res.p_hat[i]), lat))
            self._m_p_hat.observe_many(res.p_hat[:len(batch)])
            if self.obs.enabled and self.obs.wants_probe:
                self.obs.probe(self.backend, step.queries[:len(batch)],
                               step.flts[:len(batch)], res, self.opts)
            self._maybe_merge()
            return out

    def step(self, force: bool = False) -> list[Response]:
        """Drain one batch if it is due (or ``force``); returns completed
        responses ([] when the engine decided to keep waiting for more
        requests to fill the batch).  Equivalent to ``begin_batch`` +
        ``finish_batch`` back to back; pipelined callers (the front-end's
        executor slots) call the two halves from different threads."""
        step = self.begin_batch(force)
        return [] if step is None else self.finish_batch(step)

    def run(self, until_empty: bool = True) -> list[Response]:
        """until_empty=True serves the whole queue *deadline-aware*: full
        batches flush immediately, but a straggling partial batch waits out
        the remainder of ``max_wait_ms`` (its coalescing window) before it
        is forced -- so a near-future arrival can still join it, instead of
        the pre-1.7 behavior of forcing sub-batches the instant the queue
        was non-empty.  Shutdown paths that must not wait use ``drain()``.
        until_empty=False processes only batches that are already due and
        leaves the rest waiting for the deadline."""
        out = []
        if until_empty:
            while self.queue:
                if not self._due():
                    rem = self.max_wait_s - (self._time()
                                             - self.queue[0].t_submit)
                    if rem > 0:
                        time.sleep(rem)
                out.extend(self.step(force=True))
        else:
            while self._due():
                out.extend(self.step())
        return out

    def drain(self) -> list[Response]:
        """Force every queued request out NOW, ignoring ``max_wait_ms``
        (the front-end shutdown path: nothing new is coming, so waiting out
        straggler deadlines would only add latency)."""
        out = []
        while self.queue:
            out.extend(self.step(force=True))
        return out

    def latency_percentiles(self) -> dict:
        if not self.latencies:
            return {}
        arr = np.asarray(self.latencies) * 1e3
        return {f"p{p}": float(np.percentile(arr, p)) for p in (50, 90, 99)}
