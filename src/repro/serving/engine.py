"""Batched FAVOR serving engine (paper Figure 1 online phase, production
shape): request queue -> batch assembly -> selector routing -> per-route
compiled executables -> response reassembly + latency accounting.

Routing (section 4.1) happens on estimated selectivity *before* search; the
engine groups each assembled batch into a brute sub-batch and a graph
sub-batch so every executable runs with uniform static shapes (one XLA
program per route, padded to bucket sizes to bound recompilation).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..core import filters as F
from ..core.favor import FavorIndex


@dataclass
class Request:
    rid: int
    query: np.ndarray
    flt: "F.Filter"
    t_submit: float = field(default_factory=time.perf_counter)


@dataclass
class Response:
    rid: int
    ids: np.ndarray
    dists: np.ndarray
    route: str
    p_hat: float
    latency_s: float


def _bucket(n: int, buckets=(8, 16, 32, 64, 128, 256, 512)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return -(-n // buckets[-1]) * buckets[-1]


class ServeEngine:
    """Single-host engine over a FavorIndex (the sharded variant swaps the
    search calls for distributed.make_serve_fns; same control flow)."""

    def __init__(self, index: FavorIndex, k: int = 10, ef: int = 100,
                 max_batch: int = 256, max_wait_ms: float = 2.0,
                 use_pq: bool = False):
        self.index = index
        self.k, self.ef = k, ef
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self.use_pq = use_pq
        self.queue: list[Request] = []
        self.stats = {"graph": 0, "brute": 0, "batches": 0}
        self.latencies: list[float] = []
        self._next_rid = 0

    def submit(self, query: np.ndarray, flt: "F.Filter") -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(query, np.float32), flt))
        return rid

    def _assemble(self) -> list[Request]:
        take = min(len(self.queue), self.max_batch)
        batch, self.queue = self.queue[:take], self.queue[take:]
        return batch

    def _due(self) -> bool:
        """A batch is due when it is full or the oldest request has waited
        past the max_wait_ms deadline (latency/throughput trade-off knob)."""
        if not self.queue:
            return False
        if len(self.queue) >= self.max_batch:
            return True
        return time.perf_counter() - self.queue[0].t_submit >= self.max_wait_s

    def step(self, force: bool = False) -> list[Response]:
        """Drain one batch if it is due (or ``force``); returns completed
        responses ([] when the engine decided to keep waiting for more
        requests to fill the batch)."""
        if not self.queue or not (force or self._due()):
            return []
        batch = self._assemble()
        self.stats["batches"] += 1
        queries = np.stack([r.query for r in batch])
        flts = [r.flt for r in batch]
        # bucket-pad so each (route, size) pair reuses a compiled program
        b = _bucket(len(batch))
        if b > len(batch):
            queries = np.concatenate(
                [queries, np.repeat(queries[-1:], b - len(batch), 0)])
            flts = flts + [flts[-1]] * (b - len(batch))
        res = self.index.search(queries, flts, k=self.k, ef=self.ef,
                                use_pq=self.use_pq)
        t_done = time.perf_counter()
        out = []
        for i, r in enumerate(batch):
            route = "brute" if res.routed_brute[i] else "graph"
            self.stats[route] += 1
            lat = t_done - r.t_submit
            self.latencies.append(lat)
            out.append(Response(r.rid, res.ids[i], res.dists[i], route,
                                float(res.p_hat[i]), lat))
        return out

    def run(self, until_empty: bool = True) -> list[Response]:
        """until_empty=True drains the whole queue (forcing partial final
        batches); until_empty=False processes only batches that are already
        due and leaves the rest waiting for the deadline."""
        out = []
        if until_empty:
            while self.queue:
                out.extend(self.step(force=True))
        else:
            while self._due():
                out.extend(self.step())
        return out

    def latency_percentiles(self) -> dict:
        if not self.latencies:
            return {}
        arr = np.asarray(self.latencies) * 1e3
        return {f"p{p}": float(np.percentile(arr, p)) for p in (50, 90, 99)}
