"""Background incremental merge: the delta-fold build phase off the serving
path.

The inline scheduler (``ServeEngine._maybe_merge``) compacts the live delta
*between* steps -- correct, but the whole bulk build (seconds at scale)
lands on the serving thread and every queued request waits it out.  The
controller moves the expensive phase to a worker:

    poke -> snapshot delta under component epochs -> device-parallel bulk
    build (merge_prepare; no engine lock held) -> epoch-guarded atomic swap
    (merge_commit; engine lock held only for the pointer swap)

``merge_prepare`` reads a point-in-time snapshot of the delta (slot count
captured before any array ref; appends past it are invisible, and the base
graph is immutable between commits) and records the graph epoch it built
against.  ``merge_commit`` re-checks that epoch under the engine lock: if a
foreground rebuild moved the graph meanwhile, the prepared merge is stale
and is thrown away (the worker just retries).  Deletes that landed *during*
the build are not lost -- commit re-reads the delta's alive mask at swap
time, and rows upserted during the build are carried into the fresh delta
with their ids intact (positional-id discipline: old id = old_base + slot =
new_base + carried_slot).

The only slice of a background merge that can stall a ``step()`` is the
commit swap itself -- host pointer swaps plus one device upload -- which
``favor_merge_stall_seconds`` measures, and which the concurrency suite
bounds.  Everything else overlaps serving.
"""
from __future__ import annotations

import threading
import time


class _Aborted(Exception):
    """Raised inside a build wave when the controller is stopping."""


class MergeController:
    """Worker thread running epoch-guarded background merges for one
    engine.  Started by ``ServeEngine(merge_background=True)``; poked by
    ``_maybe_merge`` when the delta crosses ``merge_delta_frac``, stopped
    by ``engine.close()``.
    """

    def __init__(self, engine, *, wave: int = 512,
                 poll_s: float = 0.05, max_yield_s: float = 0.02,
                 idle_grace_s: float = 0.05, commit_retries: int = 3):
        self.engine = engine
        self.wave = wave
        self.poll_s = poll_s
        # upper bound on how long one build wave defers to foreground
        # steps: prevents a saturated pipeline from starving the build
        self.max_yield_s = max_yield_s
        # no step has *finished* for this long -> the engine is idle (not
        # merely between steps) and waves launch without waiting for one
        self.idle_grace_s = idle_grace_s
        self.commit_retries = commit_retries
        self.merges = 0       # committed background merges
        self.stale = 0        # prepared merges thrown away (epoch moved)
        self._stop = threading.Event()
        self._poke = threading.Event()
        self._thread = threading.Thread(
            target=self._run, name="favor-merge", daemon=True)
        self._thread.start()

    # -- lifecycle ----------------------------------------------------------
    def poke(self) -> None:
        """Ask the worker to check the merge trigger now."""
        self._poke.set()

    def stop(self) -> None:
        """Stop and join the worker; an in-flight build aborts at its next
        wave boundary, an in-flight commit completes first."""
        self._stop.set()
        self._poke.set()
        self._thread.join()

    @property
    def active(self) -> bool:
        return self._thread.is_alive()

    # -- worker -------------------------------------------------------------
    def _run(self) -> None:
        while not self._stop.is_set():
            self._poke.wait(self.poll_s)
            self._poke.clear()
            if self._stop.is_set():
                return
            try:
                if self.engine._merge_due():
                    self.merge_once()
            except _Aborted:
                return

    def _on_wave(self) -> None:
        """Between-waves pacing point (runs with NO lock held), called
        immediately before each device burst of the build.  Edge-triggered:
        the wave launches right after a foreground step *finishes*
        (busy->idle transition of ``favor_inflight_steps``) so the burst
        lands at the start of the inter-step gap instead of anywhere inside
        it -- on a timeshared host an unpaced burst overlapping a step
        roughly doubles that step's latency.  Two fallbacks keep the build
        moving: an idle engine (no step *finished* within ``idle_grace_s``
        -- a recent finish means we are merely between steps) launches
        immediately, and a saturated pipeline (steps always in flight)
        launches after ``max_yield_s``."""
        deadline = time.perf_counter() + self.max_yield_s
        saw_step = False
        while time.perf_counter() < deadline:
            if self._stop.is_set():
                raise _Aborted()
            if self.engine._m_inflight.value() > 0:
                saw_step = True     # mid-step: wait for its finish
            else:
                if saw_step:        # busy->idle edge: gap starts now
                    return
                # idle right now -- but a *recent* finish means we are in
                # the gap between steps (launching here would overlap the
                # next step), so keep waiting for the next edge
                since = time.perf_counter() - self.engine._last_step_end
                if since >= self.idle_grace_s:
                    return          # no traffic: build at full speed
            time.sleep(2.5e-4)
        if self._stop.is_set():
            raise _Aborted()

    def merge_once(self) -> dict | None:
        """Run one background merge to completion; returns the commit
        summary, or None when there was nothing to merge (or every prepared
        build went stale ``commit_retries`` times -- the next poke retries).
        Falls back to a foreground (lock-held) merge for backends that
        don't implement the prepare/commit split."""
        eng = self.engine
        prepare = getattr(eng.backend, "merge_prepare", None)
        commit = getattr(eng.backend, "merge_commit", None)
        eng._m_merge_active.set(1.0)
        t0 = time.perf_counter()
        try:
            if prepare is None or commit is None:
                with eng._lock:
                    out = eng.backend.merge(wave=self.wave)
            else:
                out = None
                for _ in range(self.commit_retries):
                    prep = prepare(wave=self.wave, on_wave=self._on_wave)
                    if prep is None:
                        return None       # nothing to merge
                    t_swap = time.perf_counter()
                    with eng._lock:
                        out = commit(prep)
                    if out is not None:
                        eng._m_merge_stall.observe(
                            time.perf_counter() - t_swap)
                        break
                    self.stale += 1       # epoch moved under us: rebuild
                if out is None:
                    return None
            self.merges += 1
            eng._m_mutations.inc(op="merges")
            eng._m_mutations.inc(op="auto_merges")
            eng._m_merge_s.observe(time.perf_counter() - t0)
            return out
        finally:
            eng._m_merge_active.set(0.0)
