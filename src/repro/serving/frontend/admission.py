"""Admission control and fair scheduling primitives for the async front-end.

Host-side policy only -- nothing here touches the device or the engine:

  TokenBucket            -- classic leaky-bucket rate limiter; ``try_take``
                            refills from elapsed wall time and spends one
                            token per admitted request, ``retry_after_s``
                            tells a shed client when one token will exist.
  TenantState            -- one tenant's runtime: its TenantSpec, scope id,
                            bucket, bounded FIFO of pending requests, fair-
                            queue virtual time, and served/shed accounting.
  WeightedFairScheduler  -- start-time weighted fair queuing over the
                            tenant queues: dequeue picks the smallest
                            virtual time, and each dequeue advances that
                            tenant's clock by 1/weight -- so a tenant with
                            weight w receives a w-proportional share of
                            dequeue slots under contention and a hot tenant
                            can delay, but never starve, the others.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

from ...core.options import TenantSpec

SHED_REASONS = ("rate_limit", "queue_full", "deadline", "closed")


class TokenBucket:
    """rate_qps tokens/s up to ``burst``; one token per admitted request."""

    def __init__(self, rate_qps: float, burst: int, clock=time.monotonic):
        if not rate_qps > 0.0:
            raise ValueError(f"rate_qps must be > 0, got {rate_qps}")
        if burst < 1:
            raise ValueError(f"burst must be >= 1, got {burst}")
        self.rate = float(rate_qps)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._clock = clock
        self._t = clock()

    def _refill(self) -> None:
        now = self._clock()
        self.tokens = min(self.burst, self.tokens + (now - self._t) * self.rate)
        self._t = now

    def try_take(self) -> bool:
        self._refill()
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False

    def retry_after_s(self) -> float:
        """Seconds until one token exists (0.0 when one already does)."""
        self._refill()
        return max(0.0, (1.0 - self.tokens) / self.rate)


@dataclass
class Pending:
    """One queued request: payload plus its future and timing metadata."""
    query: object
    flt: object
    tenant: str
    future: object              # asyncio.Future resolved by the scheduler
    t_submit: float             # front-end arrival (frontend clock)
    deadline: float | None      # absolute shed deadline, or None
    seq: int                    # global arrival order (FIFO mode)


@dataclass
class TenantState:
    """Runtime state for one tenant under a front-end."""
    name: str
    spec: TenantSpec
    scope: int
    bucket: TokenBucket | None
    queue: deque = field(default_factory=deque)
    vtime: float = 0.0          # weighted-fair virtual finish time
    submitted: int = 0
    served: int = 0
    shed: dict = field(default_factory=lambda: {r: 0 for r in SHED_REASONS})
    latencies: deque = field(default_factory=lambda: deque(maxlen=4096))


class WeightedFairScheduler:
    """Start-time weighted fair queuing across TenantState queues."""

    def __init__(self):
        self._vnow = 0.0

    def on_enqueue(self, st: TenantState) -> None:
        """Call BEFORE appending to ``st.queue``: a tenant going from idle
        to backlogged re-enters at the current virtual time (it must not
        bank credit from its idle period, or a sleeping tenant could burst
        past everyone on wake)."""
        if not st.queue:
            st.vtime = max(st.vtime, self._vnow)

    def pick(self, states) -> TenantState | None:
        """The backlogged tenant with the smallest virtual time."""
        best = None
        for st in states:
            if st.queue and (best is None or st.vtime < best.vtime):
                best = st
        return best

    def on_dequeue(self, st: TenantState) -> None:
        """Advance the picked tenant's clock by one weighted quantum."""
        self._vnow = st.vtime
        st.vtime += 1.0 / st.spec.weight
