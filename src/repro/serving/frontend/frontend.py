"""Asyncio multi-tenant serving front-end over one ServeEngine.

``FrontEnd.submit`` is the production traffic entry point: every request
gets an asyncio future, a scheduler task drains the per-tenant queues into
``ServeEngine.step()`` batches, and the response resolves the future --
concurrent, bursty, tenant-scoped traffic over the same synchronous engine
the benchmarks drive directly, with bit-identical results.

Three serving policies compose here (all pure config, ``FrontEndSpec``):

  * **Cross-step batch coalescing** -- an under-filled batch is held up to
    ``coalesce_ms`` for more arrivals before dispatch, so low arrival rates
    stop paying bucket-pad overhead (every lone request otherwise pads to
    the smallest bucket; the engine's ShapeRegistry ledger measures the
    pad fraction either way).  Held batches release early when they reach
    ``coalesce_target`` rows or when a request's deadline approaches.
  * **Admission control / load shedding** -- per-tenant token buckets
    (rate_qps/burst) and bounded queues shed excess load at the door with
    a structured ``Overloaded`` (reason + retry_after_ms); queued requests
    whose deadline lapses are shed at dispatch time, never served late.
    Shed requests NEVER reach the backend.
  * **Weighted fair dequeue** -- dispatch slots are split across
    backlogged tenants by ``TenantSpec.weight`` (start-time fair queuing),
    so one hot tenant cannot starve the rest; ``fair=False`` degrades to
    global FIFO (the baseline the bench compares against).

Tenancy also scopes the cache subsystem: when the engine's backend is a
``CachingBackend``, each tenant name is interned to a scope id and every
request carries it, so semantic/candidate cache entries are per-tenant
(tenant A's hits can never serve tenant B) and per-tenant hit rates land in
``stats["tenants"]``.  Multiple FrontEnds -- each its own spec, tenants and
engine -- can share one backend: isolation is config, not copies.
"""
from __future__ import annotations

import asyncio
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass

import numpy as np

from ...core.options import FrontEndSpec, TenantSpec
from ..engine import Response, ServeEngine
from .admission import Pending, TenantState, TokenBucket, WeightedFairScheduler


@dataclass
class Overloaded(Exception):
    """Structured load-shed response: the request never reached the backend.

    ``reason`` is one of "rate_limit" (token bucket empty), "queue_full"
    (tenant queue at queue_cap), "deadline" (still queued past its
    deadline), or "closed" (front-end shut down).  ``retry_after_ms`` is
    populated for rate-limit sheds (when the bucket will hold a token).
    """
    tenant: str
    reason: str
    retry_after_ms: float | None = None

    def __str__(self):
        retry = (f", retry_after_ms={self.retry_after_ms:.1f}"
                 if self.retry_after_ms is not None else "")
        return f"Overloaded(tenant={self.tenant!r}, reason={self.reason!r}{retry})"


class FrontEnd:
    """Async multi-tenant entry point over one ServeEngine (see module doc).

    One FrontEnd binds to one asyncio event loop (the one running when the
    first ``submit`` arrives).  The engine runs inside the default executor,
    so arrivals keep accumulating -- and coalescing -- while a batch is on
    the device.
    """

    def __init__(self, engine: ServeEngine, spec: FrontEndSpec | None = None,
                 *, clock=time.monotonic):
        if not isinstance(engine, ServeEngine):
            raise TypeError("FrontEnd wraps a ServeEngine, got "
                            f"{type(engine).__name__} (build one over your "
                            "backend first: ServeEngine(backend, opts))")
        self.engine = engine
        self.spec = spec or FrontEndSpec()
        self._clock = clock
        self._tenants: dict[str, TenantState] = {}
        self._fair = WeightedFairScheduler()
        self._dispatch_cap = self.spec.max_batch or engine.max_batch
        self._target = min(self.spec.coalesce_target or self._dispatch_cap,
                           self._dispatch_cap)
        self._seq = 0
        self._closing = False
        self._task: asyncio.Task | None = None
        self._wake: asyncio.Event | None = None
        self._dispatches = 0
        self._dispatched_rows = 0
        # pipelined step dispatch: up to parallel_steps engine steps ride
        # the executor at once.  Each slot runs the engine's host phase
        # (serialized by the engine lock) then blocks on its own device
        # work -- so slot k+1's routing/compile overlaps slot k's device
        # wait.  Results resolve strictly in dispatch order (the scheduler
        # only settles the pipeline head).
        self._slots = self.spec.parallel_steps
        self._exec = ThreadPoolExecutor(max_workers=self._slots,
                                        thread_name_prefix="favor-step")
        self._inflight: deque[asyncio.Future] = deque()
        # join the engine's metrics registry: tenant/coalesce ledgers become
        # a view (snapshot + prometheus exposition), and engine.reset_stats()
        # cascades here -- pre-obs, a bench warmup could never zero the
        # per-tenant counters or the dispatch ledger without rebuilding the
        # front-end
        reg = engine.obs.registry
        reg.register_view("frontend", self._ledger_view)
        reg.on_reset(self._reset_ledgers)

    # -- tenant bookkeeping ---------------------------------------------------
    def _scope_for(self, name: str) -> int:
        """Tenant name -> cache scope id: interned on the backend when it is
        scope-aware (shared across every front-end over that backend), a
        local intern otherwise (the engine then carries it inertly)."""
        scope_id = getattr(self.engine.backend, "scope_id", None)
        if scope_id is not None:
            return int(scope_id(name))
        return 1 + len(self._tenants)  # called once per new tenant name

    def _tenant(self, name: str) -> TenantState:
        st = self._tenants.get(name)
        if st is None:
            spec = self.spec.tenant(name)
            bucket = (TokenBucket(spec.rate_qps, spec.burst, self._clock)
                      if spec.rate_qps is not None else None)
            st = TenantState(name=name, spec=spec, scope=self._scope_for(name),
                             bucket=bucket)
            st.latencies = deque(maxlen=self.spec.latency_window)
            self._tenants[name] = st
        return st

    def _pending(self) -> int:
        return sum(len(st.queue) for st in self._tenants.values())

    # -- submission -----------------------------------------------------------
    async def submit(self, query, flt, *, tenant: str = "default",
                     deadline_ms: float | None = None) -> Response:
        """Submit one request; resolves to the engine Response (with
        ``latency_s`` rewritten to the end-to-end front-end latency) or
        raises a structured ``Overloaded`` when the request is shed."""
        loop = asyncio.get_running_loop()
        st = self._tenant(tenant)
        st.submitted += 1
        if self._closing:
            st.shed["closed"] += 1
            raise Overloaded(tenant, "closed")
        if self.spec.admission:
            if st.bucket is not None and not st.bucket.try_take():
                st.shed["rate_limit"] += 1
                raise Overloaded(tenant, "rate_limit",
                                 retry_after_ms=st.bucket.retry_after_s() * 1e3)
            if len(st.queue) >= st.spec.queue_cap:
                st.shed["queue_full"] += 1
                raise Overloaded(tenant, "queue_full")
        now = self._clock()
        if deadline_ms is None:
            deadline_ms = st.spec.deadline_ms
        deadline = now + deadline_ms / 1e3 if deadline_ms is not None else None
        p = Pending(query=np.asarray(query, np.float32), flt=flt,
                    tenant=tenant, future=loop.create_future(),
                    t_submit=now, deadline=deadline, seq=self._seq)
        self._seq += 1
        if self.spec.fair:
            self._fair.on_enqueue(st)
        st.queue.append(p)
        self._ensure_scheduler(loop)
        self._wake.set()
        return await p.future

    # -- scheduler ------------------------------------------------------------
    def _ensure_scheduler(self, loop) -> None:
        if self._task is None or self._task.done():
            self._wake = asyncio.Event()
            self._task = loop.create_task(self._run())

    def _hold_delay(self) -> float:
        """Seconds to keep coalescing before dispatch (0.0 = dispatch now):
        a batch goes out when it reaches the coalesce target, when its
        oldest request has waited out the window, when a deadline is about
        to lapse, or immediately during shutdown drain."""
        if self._closing or self.spec.coalesce_ms <= 0.0:
            return 0.0
        if self._pending() >= self._target:
            return 0.0
        now = self._clock()
        oldest = min(st.queue[0].t_submit
                     for st in self._tenants.values() if st.queue)
        delay = self.spec.coalesce_ms / 1e3 - (now - oldest)
        for st in self._tenants.values():
            for p in st.queue:
                if p.deadline is not None:
                    delay = min(delay, p.deadline - now)
        return max(delay, 0.0)

    def _dequeue(self) -> list[Pending]:
        """Pull up to one dispatch of requests: weighted-fair across
        backlogged tenants (or global FIFO), shedding any whose deadline
        already lapsed -- those resolve with Overloaded and are never
        submitted to the engine."""
        batch: list[Pending] = []
        now = self._clock()
        while len(batch) < self._dispatch_cap:
            if self.spec.fair:
                st = self._fair.pick(self._tenants.values())
            else:
                st = min((s for s in self._tenants.values() if s.queue),
                         key=lambda s: s.queue[0].seq, default=None)
            if st is None:
                break
            p = st.queue.popleft()
            if self.spec.fair:
                self._fair.on_dequeue(st)
            if p.deadline is not None and now > p.deadline:
                st.shed["deadline"] += 1
                if not p.future.done():
                    p.future.set_exception(Overloaded(st.name, "deadline"))
                continue
            batch.append(p)
        return batch

    def _serve(self, batch: list[Pending]):
        """Runs in an executor slot: submit + host-phase dispatch under the
        engine lock (atomic, so a concurrent slot can never steal this
        batch's rows), then block on the device work with no lock held.
        Returns (pending, engine Response) pairs."""
        eng = self.engine
        with eng._lock:
            by_rid = {}
            for p in batch:
                rid = eng.submit(p.query, p.flt,
                                 scope=self._tenants[p.tenant].scope)
                by_rid[rid] = p
            steps = []
            while True:
                s = eng.begin_batch(force=True)
                if s is None:
                    break
                steps.append(s)
        out = []
        for s in steps:
            out.extend(eng.finish_batch(s))
        return [(by_rid[r.rid], r) for r in out if r.rid in by_rid]

    def _settle(self, pairs) -> None:
        """Resolve one completed step's futures (loop thread only)."""
        now = self._clock()
        for p, r in pairs:
            st = self._tenants[p.tenant]
            st.served += 1
            lat = now - p.t_submit
            st.latencies.append(lat)
            if not p.future.done():
                p.future.set_result(Response(
                    r.rid, r.ids, r.dists, r.route, r.p_hat, lat))

    async def _run(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            # settle whatever finished at the head of the pipeline first
            # (strictly in dispatch order: only the head is ever popped)
            while self._inflight and self._inflight[0].done():
                self._settle(self._inflight.popleft().result())
            if self._inflight and len(self._inflight) >= self._slots:
                # every slot busy: wait for the oldest step, keep order
                self._settle(await self._inflight.popleft())
                continue
            if not self._pending():
                if self._closing:
                    if self._inflight:
                        # drain: join outstanding device phases before the
                        # scheduler exits -- a dispatched request always
                        # resolves with its real result, never a cancel
                        self._settle(await self._inflight.popleft())
                        continue
                    return
                self._wake.clear()
                if (not self._pending() and not self._closing
                        and not (self._inflight
                                 and self._inflight[0].done())):
                    await self._wake.wait()
                continue
            delay = self._hold_delay()
            if delay > 0.0:
                # hold for more arrivals; a new submit may hit the target
                # and wake us early, otherwise the window lapses
                self._wake.clear()
                try:
                    await asyncio.wait_for(self._wake.wait(), timeout=delay)
                except asyncio.TimeoutError:
                    pass
                continue
            batch = self._dequeue()
            if not batch:
                continue
            self._dispatches += 1
            self._dispatched_rows += len(batch)
            fut = loop.run_in_executor(self._exec, self._serve, batch)
            # completion must wake the scheduler even when no new submits
            # arrive (callback runs on the loop thread)
            fut.add_done_callback(lambda _f: self._wake.set())
            self._inflight.append(fut)

    # -- shutdown -------------------------------------------------------------
    async def close(self, *, drain: bool = True) -> None:
        """Stop the front-end.  ``drain=True`` serves everything already
        queued (coalescing windows collapse -- shutdown never waits on a
        hold), then stops; ``drain=False`` cancels every still-queued
        future instead (clean cancellation: callers see CancelledError,
        the backend never sees the requests).  New submits raise
        ``Overloaded(reason="closed")`` either way.

        Either way, steps already *dispatched* to an executor slot are
        joined -- the scheduler drains the whole pipeline before exiting,
        so a dispatched request always resolves with its real result;
        cancellation only ever reaches requests still sitting in a tenant
        queue, and a post-close ``submit`` sheds at the door without racing
        any in-flight step."""
        self._closing = True
        if not drain:
            # cancel only still-queued requests: in-flight executor work is
            # past the point of no return and resolves normally below
            for st in self._tenants.values():
                while st.queue:
                    p = st.queue.popleft()
                    if not p.future.done():
                        p.future.cancel()
        if self._task is not None and not self._task.done():
            self._wake.set()
            await self._task
        self._task = None
        # scheduler exit already joined every in-flight step; this just
        # reaps the worker threads
        self._exec.shutdown(wait=True)

    # -- accounting -----------------------------------------------------------
    def _ledger_view(self) -> dict:
        """Tenant + coalesce ledgers as one nested dict: the front-end's
        view on the engine's metrics registry (joins every registry
        snapshot and Prometheus scrape)."""
        sem_scope, cand_scope = {}, {}
        cache_stats = getattr(self.engine.backend, "cache_stats", None)
        if cache_stats is not None:
            cs = cache_stats()
            sem_scope = cs["semantic"].get("by_scope", {})
            cand_scope = cs["candidates"].get("by_scope", {})
        tenants = {}
        for name, st in self._tenants.items():
            d = {"scope": st.scope, "submitted": st.submitted,
                 "served": st.served, "shed": dict(st.shed),
                 "shed_total": sum(st.shed.values()),
                 "queued": len(st.queue)}
            if st.latencies:
                arr = np.asarray(st.latencies) * 1e3
                d["p50_ms"] = float(np.percentile(arr, 50))
                d["p99_ms"] = float(np.percentile(arr, 99))
            if st.scope in sem_scope:
                d["semantic"] = sem_scope[st.scope]
            if st.scope in cand_scope:
                d["candidates"] = cand_scope[st.scope]
            tenants[name] = d
        return {
            "tenants": tenants,
            "coalesce": {
                "dispatches": self._dispatches,
                "rows": self._dispatched_rows,
                "mean_batch": (self._dispatched_rows / self._dispatches
                               if self._dispatches else 0.0),
                "slots": self._slots,
                "inflight": len(self._inflight),
            },
        }

    def _reset_ledgers(self) -> None:
        """engine.reset_stats() cascade target: zero the per-tenant
        submitted/served/shed counters, latency windows and the coalesce
        dispatch ledger (tenant identities, scopes and queued requests
        survive -- only the accounting resets)."""
        self._dispatches = 0
        self._dispatched_rows = 0
        for st in self._tenants.values():
            st.submitted = 0
            st.served = 0
            for k in st.shed:
                st.shed[k] = 0
            st.latencies.clear()

    def reset_stats(self) -> None:
        """Zero the whole stack's counters (cascades through the engine's
        registry: engine + cache + obs + this front-end's ledgers)."""
        self.engine.reset_stats()

    @property
    def stats(self) -> dict:
        """``tenants`` -- per-tenant submitted/served/shed counters, queue
        depth, end-to-end p50/p99 and (under a CachingBackend) per-tenant
        semantic/candidate hit rates; ``coalesce`` -- dispatch count and
        mean coalesced batch size; ``engine`` -- the engine's own stats
        (routing, batching/pad ledger, cache layers, mutations)."""
        out = self._ledger_view()
        out["engine"] = self.engine.stats
        return out
