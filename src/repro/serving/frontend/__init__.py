"""Async multi-tenant serving front-end (futures, coalescing, QoS).

    from repro.serving import FrontEnd, FrontEndSpec, TenantSpec

    eng = ServeEngine(CachingBackend(LocalBackend(fi)), opts)
    fe = FrontEnd(eng, FrontEndSpec(
        coalesce_ms=5.0,
        tenants={"hot": TenantSpec(rate_qps=500, weight=1.0),
                 "gold": TenantSpec(weight=4.0)}))
    resp = await fe.submit(q, flt, tenant="gold", deadline_ms=50)

See ``frontend.FrontEnd`` for the full semantics (coalescing, admission
control / load shedding with structured ``Overloaded``, weighted fair
dequeue, tenant-scoped caches).
"""
from ...core.options import FrontEndSpec, TenantSpec
from .admission import TenantState, TokenBucket, WeightedFairScheduler
from .frontend import FrontEnd, Overloaded

__all__ = ["FrontEnd", "FrontEndSpec", "Overloaded", "TenantSpec",
           "TenantState", "TokenBucket", "WeightedFairScheduler"]
