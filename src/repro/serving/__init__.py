from ..core.options import FrontEndSpec, TenantSpec
from .engine import Request, Response, ServeEngine
from .frontend import FrontEnd, Overloaded

__all__ = ["FrontEnd", "FrontEndSpec", "Overloaded", "Request", "Response",
           "ServeEngine", "TenantSpec"]
