from .engine import Request, Response, ServeEngine

__all__ = ["Request", "Response", "ServeEngine"]
