from ..core.options import FrontEndSpec, TenantSpec
from .engine import Request, Response, ServeEngine
from .frontend import FrontEnd, Overloaded
from .merge import MergeController

__all__ = ["FrontEnd", "FrontEndSpec", "MergeController", "Overloaded",
           "Request", "Response", "ServeEngine", "TenantSpec"]
