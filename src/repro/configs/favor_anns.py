"""favor-anns: the paper's own system at production scale -- 64M vectors x
128 dims sharded 16-way on "model", serve batch 4096 sharded on data/pod.
Not one of the 40 assigned cells; lowered in the dry-run as the paper's
serve_step (graph route, brute route, selectivity estimate)."""
from dataclasses import dataclass

from .base import ArchSpec, ShapeCell


@dataclass(frozen=True)
class FavorServeConfig:
    name: str = "favor-anns"
    n: int = 64_000_000
    dim: int = 128
    m_i: int = 2          # bool + int attribute columns
    m_f: int = 1
    k: int = 10
    ef: int = 128
    m0: int = 32
    m: int = 16
    n_upper: int = 3
    width: int = 8
    batch: int = 1024
    # compressed brute path (quant subsystem): 32 x uint8 PQ codes per
    # 128-dim vector = 16x fewer bytes streamed by the PreFBF scan, on both
    # the local backend and the sharded serve path (codes sharded on "model",
    # per-shard ADC scan + exact re-rank before the top-k merge).
    quantize: str | None = "pq"
    pq_m: int = 32
    pq_nbits: int = 8
    rerank: int = 8

    def quant_spec(self):
        """QuantSpec realizing this config's compressed memory format."""
        if self.quantize is None:
            return None
        from ..core.options import QuantSpec
        return QuantSpec(kind=self.quantize, m=self.pq_m, nbits=self.pq_nbits,
                         rerank=self.rerank)

    def build_spec(self, hnsw=None, quant="config", **overrides):
        """BuildSpec for FavorIndex.build / ShardedBackend.build; pass
        quant=None (or a QuantSpec) to override this config's format."""
        from ..core.options import BuildSpec
        if quant == "config":
            quant = self.quant_spec()
        return BuildSpec(hnsw=hnsw, quant=quant, **overrides)

    def search_options(self, **overrides):
        """SearchOptions matching this config's serve shape."""
        from ..core.options import SearchOptions
        kw = {"k": self.k, "ef": self.ef, "use_pq": self.quantize is not None}
        kw.update(overrides)
        return SearchOptions(**kw)

    def quant_kwargs(self) -> dict:
        """Deprecated: legacy FavorIndex(**kwargs) blob; use build_spec()."""
        import warnings
        warnings.warn("FavorServeConfig.quant_kwargs() is deprecated; use "
                      "build_spec()/quant_spec()", DeprecationWarning,
                      stacklevel=2)
        if self.quantize is None:
            return {}
        return {"quantize": self.quantize, "pq_m": self.pq_m,
                "pq_nbits": self.pq_nbits, "rerank": self.rerank}


def spec() -> ArchSpec:
    cfg = FavorServeConfig()
    red = FavorServeConfig(name="favor-red", n=4096, dim=16, batch=16, ef=48)
    cells = (
        ShapeCell("serve_graph", "favor_serve", {"route": "graph"}),
        ShapeCell("serve_brute", "favor_serve", {"route": "brute"}),
    )
    return ArchSpec("favor-anns", "favor", "this paper", cfg, red, cells)
