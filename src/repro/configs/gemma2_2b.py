"""gemma2-2b [arXiv:2408.00118]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000; local(4096)/global alternating windows, attn softcap 50,
final softcap 30, post-norms, (1+w) RMSNorm, embed scaling, head_dim=256."""
from ..models.transformer import LMConfig
from .base import ArchSpec, lm_cells


def spec() -> ArchSpec:
    cfg = LMConfig(
        name="gemma2-2b", n_layers=26, d_model=2304, n_heads=8, n_kv=4,
        d_ff=9216, vocab=256000, head_dim=256, attn_softcap=50.0,
        final_softcap=30.0, local_window=4096, layer_pattern="local_global",
        post_norms=True, gemma_norm=True, embed_scale=True,
        tie_embeddings=True, param_dtype="bfloat16")
    red = LMConfig(
        name="gemma2-red", n_layers=2, d_model=64, n_heads=4, n_kv=2,
        d_ff=128, vocab=512, head_dim=32, attn_softcap=50.0, final_softcap=30.0,
        local_window=8, layer_pattern="local_global", post_norms=True,
        gemma_norm=True, embed_scale=True, remat=False)
    # hybrid local/global: long_500k decode is bounded (local layers attend a
    # 4096 window; global layers are linear-in-cache at decode)
    return ArchSpec("gemma2-2b", "lm", "arXiv:2408.00118; hf", cfg, red,
                    lm_cells(long_ok=True, arch="gemma2-2b"))
