"""qwen1.5-32b [hf:Qwen/Qwen1.5-*]: 64L d_model=5120 40H (kv=40)
d_ff=27392 vocab=152064, QKV bias."""
from ..models.transformer import LMConfig
from .base import ArchSpec, lm_cells


def spec() -> ArchSpec:
    cfg = LMConfig(
        name="qwen1.5-32b", n_layers=64, d_model=5120, n_heads=40, n_kv=40,
        d_ff=27392, vocab=152064, qkv_bias=True, tie_embeddings=False,
        param_dtype="bfloat16")
    red = LMConfig(
        name="qwen-red", n_layers=2, d_model=64, n_heads=4, n_kv=4,
        d_ff=128, vocab=512, qkv_bias=True, tie_embeddings=False, remat=False)
    return ArchSpec("qwen1.5-32b", "lm", "hf:Qwen/Qwen1.5-0.5B; hf", cfg, red,
                    lm_cells(long_ok=False, arch="qwen1.5-32b"))
