"""wide-deep [arXiv:1606.07792]: 40 sparse fields, embed_dim=32,
deep MLP 1024-512-256, concat interaction."""
from ..models.recsys import WideDeepConfig
from .base import ArchSpec, RECSYS_CELLS


def spec() -> ArchSpec:
    cfg = WideDeepConfig(name="wide-deep", n_sparse=40, vocab=1_000_000,
                         embed_dim=32, mlp=(1024, 512, 256))
    red = WideDeepConfig(name="wd-red", n_sparse=8, vocab=1000, embed_dim=8,
                         mlp=(32, 16))
    return ArchSpec("wide-deep", "recsys", "arXiv:1606.07792; paper", cfg,
                    red, RECSYS_CELLS)
