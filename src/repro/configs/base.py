"""Architecture registry plumbing: each configs/<arch>.py exposes ``spec()``
returning an ArchSpec with the exact published configuration, a reduced
config for CPU smoke tests, and its assigned shape cells."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # train | prefill | decode | graph_train | serve | retrieval
    meta: dict
    skip: str | None = None  # reason when the cell is inapplicable


@dataclass
class ArchSpec:
    arch_id: str
    family: str          # lm | gnn | recsys
    source: str
    config: object       # full published config
    reduced: object      # smoke-test config
    cells: tuple
    notes: str = ""

    def cell(self, name: str) -> ShapeCell:
        for c in self.cells:
            if c.name == name:
                return c
        raise KeyError(name)


def lm_cells(long_ok: bool, arch: str) -> tuple:
    """The assigned LM shape set (seq_len x global_batch)."""
    skip = (None if long_ok else
            f"{arch} is pure full attention; 524k-token prefill is quadratic "
            "with no windowing to bound it (assignment rule; DESIGN.md section 5)")
    return (
        ShapeCell("train_4k", "train", {"seq": 4096, "batch": 256}),
        ShapeCell("prefill_32k", "prefill", {"seq": 32768, "batch": 32}),
        ShapeCell("decode_32k", "decode", {"seq": 32768, "batch": 128}),
        ShapeCell("long_500k", "decode", {"seq": 524288, "batch": 1}, skip=skip),
    )


RECSYS_CELLS = (
    ShapeCell("train_batch", "train", {"batch": 65536}),
    ShapeCell("serve_p99", "serve", {"batch": 512}),
    ShapeCell("serve_bulk", "serve", {"batch": 262144}),
    ShapeCell("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}),
)

GNN_CELLS = (
    ShapeCell("full_graph_sm", "graph_train",
              {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433}),
    ShapeCell("minibatch_lg", "graph_train",
              {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
               "fanout": (15, 10), "d_feat": 602, "sampled": True}),
    ShapeCell("ogb_products", "graph_train",
              {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100}),
    ShapeCell("molecule", "graph_train",
              {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 32,
               "graphs": True}),
)
