"""fm [Rendle ICDM'10]: 39 sparse fields, embed_dim=10, 2-way interactions
via the O(nk) sum-square trick."""
from ..models.recsys import FMConfig
from .base import ArchSpec, RECSYS_CELLS


def spec() -> ArchSpec:
    cfg = FMConfig(name="fm", n_sparse=39, vocab=1_000_000, embed_dim=10)
    red = FMConfig(name="fm-red", n_sparse=8, vocab=1000, embed_dim=10)
    return ArchSpec("fm", "recsys", "ICDM'10 (Rendle); paper", cfg, red,
                    RECSYS_CELLS,
                    notes="uniform 1e6-row vocab per field (criteo-scale)")
