"""command-r-plus-104b [hf:CohereForAI/c4ai-command-r-v01; unverified]:
64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000, LayerNorm, no bias."""
from ..models.transformer import LMConfig
from .base import ArchSpec, lm_cells


def spec() -> ArchSpec:
    cfg = LMConfig(
        name="command-r-plus-104b", n_layers=64, d_model=12288, n_heads=96,
        n_kv=8, d_ff=33792, vocab=256000, norm="layernorm",
        tie_embeddings=True, param_dtype="bfloat16")
    red = LMConfig(
        name="commandr-red", n_layers=2, d_model=96, n_heads=8, n_kv=2,
        d_ff=192, vocab=512, norm="layernorm", remat=False)
    return ArchSpec("command-r-plus-104b", "lm",
                    "hf:CohereForAI/c4ai-command-r-v01; unverified", cfg, red,
                    lm_cells(long_ok=False, arch="command-r-plus-104b"))
