"""Architecture registry: ``get_spec("--arch id")`` for every assigned arch."""
from . import (arctic_480b, command_r_plus_104b, dien, dlrm_rm2, favor_anns,
               fm, gcn_cora, gemma2_2b, olmoe_1b_7b, qwen15_32b, wide_deep)
from .base import ArchSpec, ShapeCell

_MODULES = {
    "olmoe-1b-7b": olmoe_1b_7b,
    "arctic-480b": arctic_480b,
    "qwen1.5-32b": qwen15_32b,
    "command-r-plus-104b": command_r_plus_104b,
    "gemma2-2b": gemma2_2b,
    "gcn-cora": gcn_cora,
    "fm": fm,
    "wide-deep": wide_deep,
    "dien": dien,
    "dlrm-rm2": dlrm_rm2,
    "favor-anns": favor_anns,
}

ASSIGNED = [k for k in _MODULES if k != "favor-anns"]


def get_spec(arch_id: str) -> ArchSpec:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; available: {list(_MODULES)}")
    return _MODULES[arch_id].spec()


def all_specs(include_favor: bool = True):
    ids = list(_MODULES) if include_favor else ASSIGNED
    return {a: get_spec(a) for a in ids}
