"""arctic-480b [hf:Snowflake/snowflake-arctic-base]: 35L d_model=7168 56H
(GQA kv=8) MoE 128 experts top-2 + dense residual, d_ff=4864, vocab=32000."""
from ..models.moe import MoEConfig
from ..models.transformer import LMConfig
from .base import ArchSpec, lm_cells


def spec() -> ArchSpec:
    cfg = LMConfig(
        name="arctic-480b", n_layers=35, d_model=7168, n_heads=56, n_kv=8,
        d_ff=4864, vocab=32000, tie_embeddings=False, param_dtype="bfloat16",
        moe=MoEConfig(n_experts=128, top_k=2, d_model=7168, d_ff=4864,
                      dense_residual=True, d_ff_dense=4864))
    red = LMConfig(
        name="arctic-red", n_layers=2, d_model=64, n_heads=8, n_kv=2,
        d_ff=48, vocab=512, tie_embeddings=False, remat=False,
        moe=MoEConfig(n_experts=4, top_k=2, d_model=64, d_ff=48,
                      dense_residual=True, d_ff_dense=48))
    return ArchSpec("arctic-480b", "lm", "hf:Snowflake/snowflake-arctic-base",
                    cfg, red, lm_cells(long_ok=False, arch="arctic-480b"))
