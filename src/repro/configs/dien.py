"""dien [arXiv:1809.03672; unverified]: embed_dim=18, seq_len=100,
GRU dim=108, AUGRU interest evolution, MLP 200-80."""
from ..models.recsys import DIENConfig
from .base import ArchSpec, RECSYS_CELLS


def spec() -> ArchSpec:
    cfg = DIENConfig(name="dien", vocab=1_000_000, embed_dim=18, seq_len=100,
                     gru_dim=108, mlp=(200, 80))
    red = DIENConfig(name="dien-red", vocab=1000, embed_dim=18, seq_len=12,
                     gru_dim=24, mlp=(20, 8))
    return ArchSpec("dien", "recsys", "arXiv:1809.03672; unverified", cfg,
                    red, RECSYS_CELLS,
                    notes="aux loss of the original omitted (DESIGN.md)")
