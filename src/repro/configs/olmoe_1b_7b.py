"""olmoe-1b-7b [arXiv:2409.02060]: 16L d_model=2048 16H (GQA kv=16)
MoE 64 experts top-8, expert d_ff=1024, vocab=50304."""
from ..models.moe import MoEConfig
from ..models.transformer import LMConfig
from .base import ArchSpec, lm_cells


def spec() -> ArchSpec:
    cfg = LMConfig(
        name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16, n_kv=16,
        d_ff=1024, vocab=50304, tie_embeddings=False, param_dtype="bfloat16",
        moe=MoEConfig(n_experts=64, top_k=8, d_model=2048, d_ff=1024))
    red = LMConfig(
        name="olmoe-red", n_layers=2, d_model=64, n_heads=4, n_kv=4,
        d_ff=32, vocab=512, tie_embeddings=False, remat=False,
        moe=MoEConfig(n_experts=4, top_k=2, d_model=64, d_ff=32))
    return ArchSpec("olmoe-1b-7b", "lm", "arXiv:2409.02060; hf", cfg, red,
                    lm_cells(long_ok=False, arch="olmoe-1b-7b"))
