"""dlrm-rm2 [arXiv:1906.00091]: 13 dense + 26 sparse, embed_dim=64,
bot 512-256-64, top 512-512-256-1, dot interaction."""
from ..models.recsys import DLRMConfig
from .base import ArchSpec, RECSYS_CELLS


def spec() -> ArchSpec:
    cfg = DLRMConfig(name="dlrm-rm2", n_dense=13, n_sparse=26,
                     vocab=1_000_000, embed_dim=64,
                     bot_mlp=(512, 256, 64), top_mlp=(512, 512, 256))
    red = DLRMConfig(name="dlrm-red", n_dense=13, n_sparse=6, vocab=1000,
                     embed_dim=16, bot_mlp=(32, 16), top_mlp=(32, 16))
    return ArchSpec("dlrm-rm2", "recsys", "arXiv:1906.00091; paper", cfg,
                    red, RECSYS_CELLS)
