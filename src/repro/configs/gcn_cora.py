"""gcn-cora [arXiv:1609.02907]: 2 layers, d_hidden=16, sym normalization."""
from ..models.gnn import GCNConfig
from .base import ArchSpec, GNN_CELLS


def spec() -> ArchSpec:
    cfg = GCNConfig(name="gcn-cora", n_layers=2, d_feat=1433, d_hidden=16,
                    n_classes=7, norm="sym")
    red = GCNConfig(name="gcn-red", n_layers=2, d_feat=32, d_hidden=16,
                    n_classes=7, norm="sym")
    return ArchSpec("gcn-cora", "gnn", "arXiv:1609.02907; paper", cfg, red,
                    GNN_CELLS,
                    notes="d_feat/n_classes follow each cell's dataset: "
                          "cora 1433/7, ogb_products 100/47, molecule 32/2")
