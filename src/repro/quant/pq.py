"""Product-quantization codebooks (JAX k-means) + scalar-quantization fallback.

PQ splits each d-dim vector into ``M`` contiguous subvectors of ``dsub``
dims (zero-padded when ``M`` does not divide ``d``) and learns one K=2^nbits
centroid codebook per subspace with Lloyd's algorithm, vmapped over
subspaces so all M k-means runs share the same compiled program.  A vector
is stored as M uint8 codes (nbits <= 8), i.e. ``M`` bytes instead of
``4 * d`` -- a 16x compression at the paper's 128-dim scale with M=32.

The scalar-quantization (SQ) fallback is per-dimension affine int8: 4x
compression, no training beyond a min/max pass, and trivially exact decode
arithmetic -- the safety net when a dataset is too small or too skewed for
k-means codebooks to converge well.

Both codebooks round-trip through a single npz (``save_codebook`` /
``load_codebook``) so FavorIndex persistence can carry them alongside the
HNSW arrays.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class PQCodebook:
    """Per-subspace centroid tables.

    centroids : (M, K, dsub) float32
    dim       : original vector dimensionality (<= M * dsub; the tail of the
                last subspace is zero padding)
    """

    centroids: np.ndarray
    dim: int

    @property
    def m(self) -> int:
        return int(self.centroids.shape[0])

    @property
    def ksub(self) -> int:
        return int(self.centroids.shape[1])

    @property
    def dsub(self) -> int:
        return int(self.centroids.shape[2])

    @property
    def nbits(self) -> int:
        return int(round(float(np.log2(self.ksub))))

    @property
    def padded_dim(self) -> int:
        return self.m * self.dsub

    def bytes_per_vector(self) -> int:
        return self.m  # one uint8 code per subspace (nbits <= 8)


@dataclass
class SQCodebook:
    """Per-dimension affine int8 quantizer: x ~= code * scale + lo."""

    lo: np.ndarray     # (d,) float32
    scale: np.ndarray  # (d,) float32
    dim: int

    def bytes_per_vector(self) -> int:
        return self.dim  # one uint8 code per dimension

    @property
    def padded_dim(self) -> int:
        return self.dim


def _pad_split(x: np.ndarray | jnp.ndarray, m: int, dsub: int):
    """(N, d) -> (N, m, dsub) with zero padding on the feature tail."""
    n, d = x.shape
    pad = m * dsub - d
    if pad:
        x = jnp.concatenate(
            [jnp.asarray(x), jnp.zeros((n, pad), jnp.float32)], axis=1)
    return jnp.asarray(x).reshape(n, m, dsub)


# ---------------------------------------------------------------------------
# k-means (one subspace; vmapped over M)
# ---------------------------------------------------------------------------
def _assign(x, c):
    """(n, d), (k, d) -> (n,) nearest-centroid ids (squared L2)."""
    d2 = (jnp.sum(x * x, axis=1)[:, None]
          - 2.0 * (x @ c.T) + jnp.sum(c * c, axis=1)[None, :])
    return jnp.argmin(d2, axis=1)


def _lloyd_step(c, x, k: int):
    a = _assign(x, c)
    oh = jax.nn.one_hot(a, k, dtype=jnp.float32)        # (n, k)
    cnt = jnp.sum(oh, axis=0)                            # (k,)
    sums = oh.T @ x                                      # (k, d) MXU
    # empty clusters keep their previous centroid (no respawn: deterministic)
    return jnp.where(cnt[:, None] > 0, sums / jnp.maximum(cnt, 1.0)[:, None], c)


@partial(jax.jit, static_argnames=("k", "iters"))
def _kmeans(x, key, *, k: int, iters: int):
    """x (n, d) -> centroids (k, d).  Init: k distinct sample rows."""
    idx = jax.random.choice(key, x.shape[0], (k,), replace=False)
    c0 = x[idx]
    c = jax.lax.fori_loop(0, iters, lambda _, c: _lloyd_step(c, x, k), c0)
    return c


def train_pq(vectors: np.ndarray, m: int = 8, nbits: int = 8, *,
             iters: int = 20, sample: int = 65536, seed: int = 0) -> PQCodebook:
    """Train an M x 2^nbits PQ codebook on (a sample of) the dataset."""
    assert 1 <= nbits <= 8, "codes are uint8: nbits must be in [1, 8]"
    n, d = vectors.shape
    k = 1 << nbits
    rng = np.random.default_rng(seed)
    if n > sample:
        rows = rng.choice(n, size=sample, replace=False)
        vectors = vectors[rows]
        n = sample
    assert n >= k, f"need >= {k} training vectors for 2^{nbits} centroids, got {n}"

    dsub = -(-d // m)
    xs = _pad_split(np.asarray(vectors, np.float32), m, dsub)  # (n, m, dsub)
    xs = jnp.transpose(xs, (1, 0, 2))                          # (m, n, dsub)
    keys = jax.random.split(jax.random.PRNGKey(seed), m)
    cents = jax.vmap(lambda x, kk: _kmeans(x, kk, k=k, iters=iters))(xs, keys)
    return PQCodebook(np.asarray(cents, np.float32), dim=d)


@partial(jax.jit, static_argnames=())
def _encode_chunk(xs, centroids):
    """xs (n, m, dsub), centroids (m, k, dsub) -> codes (n, m) int32."""
    return jax.vmap(_assign, in_axes=(1, 0), out_axes=1)(xs, centroids)


def encode(cb: PQCodebook | SQCodebook, vectors: np.ndarray,
           chunk: int = 65536) -> np.ndarray:
    """Vectors (N, d) -> uint8 codes: (N, M) for PQ, (N, d) for SQ."""
    vectors = np.asarray(vectors, np.float32)
    if isinstance(cb, SQCodebook):
        q = np.rint((vectors - cb.lo[None, :]) / cb.scale[None, :])
        return np.clip(q, 0, 255).astype(np.uint8)
    cents = jnp.asarray(cb.centroids)
    out = np.empty((vectors.shape[0], cb.m), np.uint8)
    for s in range(0, vectors.shape[0], chunk):
        xs = _pad_split(vectors[s:s + chunk], cb.m, cb.dsub)
        out[s:s + chunk] = np.asarray(_encode_chunk(xs, cents), np.uint8)
    return out


def decode(cb: PQCodebook | SQCodebook, codes: np.ndarray) -> np.ndarray:
    """Codes -> approximate float32 vectors (N, dim)."""
    codes = np.asarray(codes)
    if isinstance(cb, SQCodebook):
        return codes.astype(np.float32) * cb.scale[None, :] + cb.lo[None, :]
    # gather (N, m, dsub) then flatten and drop the zero-padded tail
    recon = cb.centroids[np.arange(cb.m)[None, :], codes.astype(np.int64)]
    return recon.reshape(codes.shape[0], cb.padded_dim)[:, :cb.dim].copy()


def train_sq(vectors: np.ndarray) -> SQCodebook:
    """Per-dimension affine int8 quantizer from a min/max pass."""
    vectors = np.asarray(vectors, np.float32)
    lo = vectors.min(axis=0)
    hi = vectors.max(axis=0)
    scale = np.maximum((hi - lo) / 255.0, 1e-12).astype(np.float32)
    return SQCodebook(lo.astype(np.float32), scale, dim=vectors.shape[1])


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------
def save_codebook(path: str, cb: PQCodebook | SQCodebook) -> None:
    if isinstance(cb, PQCodebook):
        np.savez_compressed(path, kind="pq", centroids=cb.centroids,
                            dim=np.int64(cb.dim))
    else:
        np.savez_compressed(path, kind="sq", lo=cb.lo, scale=cb.scale,
                            dim=np.int64(cb.dim))


def load_codebook(path: str) -> PQCodebook | SQCodebook:
    z = np.load(path)
    kind = str(z["kind"])
    if kind == "pq":
        return PQCodebook(z["centroids"].astype(np.float32), int(z["dim"]))
    if kind == "sq":
        return SQCodebook(z["lo"].astype(np.float32),
                          z["scale"].astype(np.float32), int(z["dim"]))
    raise ValueError(f"unknown codebook kind {kind!r}")
