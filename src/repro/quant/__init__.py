"""Compressed-domain distance computation for FAVOR (quantization subsystem).

Both online paths of the seed scan full-precision float32 vectors, so at
production scale they are memory-bandwidth-bound.  This package adds the
standard lever: product quantization (PQ) with asymmetric distance
computation (ADC) and an exact float32 re-rank of the short candidate list,
so the hot scan reads ``M`` bytes per vector instead of ``4 * d`` while
Recall@10 stays within noise of the uncompressed path.

Modules:
  pq.py  -- codebook training (JAX k-means per subspace), encode/decode,
            scalar-quantization fallback, npz persistence
  adc.py -- per-query LUT construction, chunked compressed filtered scans
            (``pq_prefbf_topk`` / ``sq_prefbf_topk``) reusing the DNF filter
            programs from core.filters, finishing with an exact re-rank

The fused Pallas kernel lives in kernels/pq_adc (same kernel/ops/ref layout
as kernels/filtered_topk) and is reached via ``use_pallas=True``.
"""
from .pq import (PQCodebook, SQCodebook, decode, encode, load_codebook,
                 save_codebook, train_pq, train_sq)
from .adc import build_luts, pq_prefbf_topk, sq_prefbf_topk

__all__ = [
    "PQCodebook", "SQCodebook", "build_luts", "decode", "encode",
    "load_codebook", "pq_prefbf_topk", "save_codebook", "sq_prefbf_topk",
    "train_pq", "train_sq",
]
