"""Asymmetric distance computation: compressed filtered scans + exact re-rank.

Online, each query builds one lookup table of squared sub-distances to every
centroid (``build_luts``: (B, M, K)); scanning the DB then reads only the
uint8 codes -- ADC distance is M table lookups + adds per vector instead of a
d-dim dot product.  The scan is chunked with a running top-R merge exactly
like core.prefbf (same DNF filter-program masking, same +inf conventions for
failing and padded rows), but it keeps R = rerank * k candidates instead of
k: ADC distances are approximations, so the final answer is an exact float32
re-rank of those R rows (the only full-precision reads on the whole path).

``use_pallas=True`` routes the scan through kernels/pq_adc, which fuses the
LUT gather-accumulate (as K-wide one-hot matmuls feeding the MXU), the
filter mask and the running top-R entirely in VMEM.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core import filters as F

INF = jnp.inf


def build_luts(centroids, queries):
    """Per-query squared-distance tables.

    centroids (M, K, dsub); queries (B, d) with d <= M * dsub -- the query is
    zero-padded on the feature tail exactly like the encoded vectors, so the
    padded dims contribute |c_pad|^2 identically to every row and preserve
    the ADC ranking.  Returns (B, M, K) float32.
    """
    m, k, dsub = centroids.shape
    b, d = queries.shape
    pad = m * dsub - d
    if pad:
        queries = jnp.concatenate(
            [queries, jnp.zeros((b, pad), jnp.float32)], axis=1)
    qs = queries.reshape(b, m, dsub)
    qn = jnp.sum(qs * qs, axis=-1)            # (B, M)
    cn = jnp.sum(centroids * centroids, -1)   # (M, K)
    dot = jnp.einsum("bmd,mkd->bmk", qs, centroids)
    return jnp.maximum(qn[:, :, None] + cn[None, :, :] - 2.0 * dot, 0.0)


def _merge_topr(best_d, best_i, tile_d, tile_i, r: int):
    d = jnp.concatenate([best_d, tile_d], axis=1)
    i = jnp.concatenate([best_i, tile_i], axis=1)
    order = jnp.argsort(d, axis=1)[:, :r]
    return (jnp.take_along_axis(d, order, axis=1),
            jnp.take_along_axis(i, order, axis=1))


def _adc_scan(codes, norms, ints, floats, luts, programs, *, r: int,
              chunk: int):
    """Chunked compressed scan -> top-R (adc_d2 (B,R), ids (B,R))."""
    n, m = codes.shape
    b, _, ksub = luts.shape
    assert n % chunk == 0, f"N={n} not a multiple of chunk={chunk}"
    n_chunks = n // chunk
    luts_flat = luts.reshape(b, m * ksub)

    cc = codes.reshape(n_chunks, chunk, m)
    nc = norms.reshape(n_chunks, chunk)
    ic = ints.reshape(n_chunks, chunk, -1)
    fc = floats.reshape(n_chunks, chunk, -1)
    init = (jnp.full((b, r), INF), jnp.full((b, r), -1, jnp.int32))

    def step(carry, xs):
        best_d, best_i = carry
        c, nn, ii, ff, start = xs
        # one flat gather on the (B, M*K) table -- subspace mm's code
        # addresses entry mm*K + code (see PqAdcScorer.score_block)
        flat = (c.astype(jnp.int32)
                + (jnp.arange(m, dtype=jnp.int32) * ksub)[None, :])
        g = jnp.take_along_axis(luts_flat[:, None, :], flat[None], axis=2)
        adc = jnp.sum(g.astype(jnp.float32), axis=-1)        # (B, chunk)
        mask = F.eval_program_batched(programs, ii, ff, xp=jnp)
        ok = mask & jnp.isfinite(nn)[None, :]                # padded rows out
        adc = jnp.where(ok, adc, INF)
        ids = (start + jnp.arange(chunk, dtype=jnp.int32))[None, :].repeat(b, 0)
        return _merge_topr(best_d, best_i, adc, ids, r), None

    starts = jnp.arange(n_chunks, dtype=jnp.int32) * chunk
    (best_d, best_i), _ = jax.lax.scan(step, init, (cc, nc, ic, fc, starts))
    return best_d, jnp.where(jnp.isfinite(best_d), best_i, -1)


def _exact_rerank(vectors, norms, queries, cand_i, *, k: int, valid=None):
    """Exact float32 top-k over the (B, R) ADC candidate lists.  ``valid``
    is the optional (B,) bool query mask: False rows return -1 / +inf."""
    safe = jnp.maximum(cand_i, 0)
    v = vectors[safe]                                        # (B, R, d)
    vn = norms[safe]
    qn = jnp.sum(queries * queries, axis=-1)
    # batched mat-vec as multiply + reduce: bit-identical across batch
    # sizes (bucket padding), unlike a dot_general (see search._pairwise_dist)
    dot = jnp.sum(queries[:, None, :] * v, axis=-1)
    dist = jnp.sqrt(jnp.maximum(vn + qn[:, None] - 2.0 * dot, 0.0))
    dist = jnp.where(cand_i >= 0, dist, INF)
    order = jnp.argsort(dist, axis=1)[:, :k]
    out_d = jnp.take_along_axis(dist, order, axis=1)
    out_i = jnp.take_along_axis(cand_i, order, axis=1)
    if valid is not None:
        vmask = jnp.asarray(valid, bool)[:, None]
        out_d = jnp.where(vmask, out_d, INF)
    return jnp.where(jnp.isfinite(out_d), out_i, -1), out_d


@partial(jax.jit, static_argnames=("k", "rerank", "chunk", "use_pallas"))
def pq_prefbf_topk(codes, norms, ints, floats, queries, programs, centroids,
                   vectors, *, k: int, rerank: int = 4, chunk: int = 8192,
                   use_pallas: bool = False, valid=None):
    """Compressed filtered brute-force top-k with exact re-rank.

    codes (N, M) uint8; norms/ints/floats/vectors: the padded DB arrays from
    prefbf.pad_db (norms also gate out padded rows here, since a padded code
    row is a legal code word); queries (B, d); programs batched filter
    programs; centroids (M, K, dsub); ``valid`` an optional (B,) bool query
    mask (bucket padding) -- False rows return -1 / +inf.

    Same contract as prefbf_topk: ids (B, k) int32 (-1 missing) and exact
    float32 dists (B, k) (+inf missing).
    """
    r = max(k, rerank * k)
    luts = build_luts(centroids, queries)
    if use_pallas:
        from ..kernels.pq_adc import ops as pq_ops
        # the kernel's VMEM budget is sized for bn<=512 tiles (it builds a
        # (bn, K) one-hot per subspace); don't forward the scan chunk as-is
        cand_i, _ = pq_ops.pq_adc_topr(codes, norms, ints, floats, luts,
                                       programs, r=r,
                                       block_n=min(chunk, 512), valid=valid)
    else:
        _, cand_i = _adc_scan(codes, norms, ints, floats, luts, programs,
                              r=r, chunk=chunk)
    return _exact_rerank(vectors, norms, queries, cand_i, k=k, valid=valid)


@partial(jax.jit, static_argnames=("k", "rerank", "chunk"))
def sq_prefbf_topk(codes, lo, scale, norms, ints, floats, queries, programs,
                   vectors, *, k: int, rerank: int = 4, chunk: int = 8192,
                   valid=None):
    """Scalar-quantization fallback scan: per-chunk dequantize + matmul.

    codes (N, d) uint8.  The approximate distance is computed against the
    int8-dequantized vectors (still 4x fewer bytes streamed than float32);
    candidates then get the same exact float32 re-rank as the PQ path.
    ``valid`` is the optional (B,) bool query mask (bucket padding).
    """
    r = max(k, rerank * k)
    n, d = codes.shape
    b = queries.shape[0]
    assert n % chunk == 0, f"N={n} not a multiple of chunk={chunk}"
    n_chunks = n // chunk
    qn = jnp.sum(queries * queries, axis=-1)

    cc = codes.reshape(n_chunks, chunk, d)
    nc = norms.reshape(n_chunks, chunk)
    ic = ints.reshape(n_chunks, chunk, -1)
    fc = floats.reshape(n_chunks, chunk, -1)
    init = (jnp.full((b, r), INF), jnp.full((b, r), -1, jnp.int32))

    def step(carry, xs):
        best_d, best_i = carry
        c, nn, ii, ff, start = xs
        deq = c.astype(jnp.float32) * scale[None, :] + lo[None, :]
        dn = jnp.sum(deq * deq, axis=-1)                     # (chunk,)
        d2 = dn[None, :] + qn[:, None] - 2.0 * (queries @ deq.T)
        d2 = jnp.maximum(d2, 0.0)
        mask = F.eval_program_batched(programs, ii, ff, xp=jnp)
        ok = mask & jnp.isfinite(nn)[None, :]
        d2 = jnp.where(ok, d2, INF)
        ids = (start + jnp.arange(chunk, dtype=jnp.int32))[None, :].repeat(b, 0)
        return _merge_topr(best_d, best_i, d2, ids, r), None

    starts = jnp.arange(n_chunks, dtype=jnp.int32) * chunk
    (best_d, cand_i), _ = jax.lax.scan(step, init, (cc, nc, ic, fc, starts))
    cand_i = jnp.where(jnp.isfinite(best_d), cand_i, -1)
    return _exact_rerank(vectors, norms, queries, cand_i, k=k, valid=valid)
