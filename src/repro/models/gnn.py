"""GCN (Kipf & Welling, arXiv:1609.02907) via segment_sum message passing.

JAX sparse is BCOO-only, so message passing is implemented the way the
assignment prescribes: an edge-index (2, E) int32 array drives
gather -> scale-by-sym-norm -> ``jax.ops.segment_sum`` scatter.  Edges are
padded with (-1, -1) rows (weight 0) so every shape is static and the edge
axis shards evenly across the mesh; degree normalization assumes self-loops
were added by the data pipeline.

Supports the four assigned shape cells: full-graph node classification
(cora, ogb_products), sampled-subgraph minibatch training (the neighbor
sampler in data/graphs.py produces padded static-shape subgraphs), and
batched small graphs (molecule) via block-diagonal batching + graph readout.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .module import Ctx, fan_in_init, zeros_init


@dataclass(frozen=True)
class GCNConfig:
    name: str
    n_layers: int = 2
    d_feat: int = 1433
    d_hidden: int = 16
    n_classes: int = 7
    norm: str = "sym"          # symmetric normalization (paper)
    readout: str = "node"      # "node" | "graph" (molecule cells)
    dropout: float = 0.0       # (inference path ignores)

    def dims(self):
        dims = [self.d_feat] + [self.d_hidden] * (self.n_layers - 1) + [self.n_classes]
        return list(zip(dims[:-1], dims[1:]))


def init_gcn(ctx: Ctx, cfg: GCNConfig):
    for i, (din, dout) in enumerate(cfg.dims()):
        sc = ctx.scope(f"conv{i}")
        sc.param("w", (din, dout), ("feat", "hidden"), fan_in_init())
        sc.param("b", (dout,), ("hidden",), zeros_init())
    if cfg.readout == "graph":
        sc = ctx.scope("head")
        sc.param("w", (cfg.n_classes, cfg.n_classes), ("hidden", "hidden"),
                 fan_in_init())
        sc.param("b", (cfg.n_classes,), ("hidden",), zeros_init())


def _sym_coeff(edges, deg):
    """1/sqrt(deg_src * deg_dst); padded edges (src = -1) get weight 0."""
    src, dst = edges[0], edges[1]
    ok = src >= 0
    s = jnp.maximum(src, 0)
    d = jnp.maximum(dst, 0)
    c = jax.lax.rsqrt(jnp.maximum(deg[s] * deg[d], 1.0).astype(jnp.float32))
    return jnp.where(ok, c, 0.0), s, d


def gcn_forward(params, cfg: GCNConfig, x, edges, deg, graph_ids=None,
                n_graphs: int = 0):
    """x (N, F); edges (2, E) int32 with -1 padding; deg (N,) float
    (in-degree + self-loop).  graph_ids (N,) for graph readout."""
    n = x.shape[0]
    coeff, s, d = _sym_coeff(edges, deg)
    h = x
    n_conv = len(cfg.dims())
    for i in range(n_conv):
        h = h @ params[f"conv{i}"]["w"]                     # (N, dout) first: cheaper gather
        msg = h[s] * coeff[:, None]                          # (E, dout)
        h = jax.ops.segment_sum(msg, d, num_segments=n)
        h = h + params[f"conv{i}"]["b"]
        if i < n_conv - 1:
            h = jax.nn.relu(h)
    if cfg.readout == "graph":
        assert graph_ids is not None
        pooled = jax.ops.segment_sum(h, jnp.maximum(graph_ids, 0),
                                     num_segments=n_graphs)
        cnt = jax.ops.segment_sum(jnp.ones((n, 1)), jnp.maximum(graph_ids, 0),
                                  num_segments=n_graphs)
        pooled = pooled / jnp.maximum(cnt, 1.0)              # mean pool
        h = jax.nn.relu(pooled) @ params["head"]["w"] + params["head"]["b"]
    return h


def gcn_loss(params, cfg: GCNConfig, x, edges, deg, labels, mask,
             graph_ids=None, n_graphs: int = 0):
    """Masked softmax cross entropy (mask: which nodes/graphs are labeled)."""
    logits = gcn_forward(params, cfg, x, edges, deg, graph_ids, n_graphs)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    lbl = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(logp, lbl[:, None], axis=-1)[:, 0]
    w = mask.astype(jnp.float32)
    loss = jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
    acc = jnp.sum((logits.argmax(-1) == labels) * w) / jnp.maximum(jnp.sum(w), 1.0)
    return loss, {"ce_loss": loss, "acc": acc}
