"""Minimal functional module system with logical-axis sharding (no flax).

Params are nested dicts of arrays.  ``Ctx`` collects, during init, a parallel
tree of *logical axis names* per parameter; ``logical_to_sharding`` maps those
through a rules table (MaxText-style) to ``NamedSharding``s on the production
mesh.  Init functions are pure jax (traceable), so the dry-run can derive
parameter ShapeDtypeStructs via ``jax.eval_shape`` without materializing
multi-hundred-GB weights.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def normal_init(scale: float = 0.02):
    def f(key, shape, dtype):
        return (scale * jax.random.normal(key, shape)).astype(dtype)
    return f


def fan_in_init():
    def f(key, shape, dtype):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = 1.0 / np.sqrt(max(1, fan_in))
        return (scale * jax.random.normal(key, shape)).astype(dtype)
    return f


def zeros_init():
    return lambda key, shape, dtype: jnp.zeros(shape, dtype)


def ones_init():
    return lambda key, shape, dtype: jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Init context
# ---------------------------------------------------------------------------
class Ctx:
    """Parameter collection context.  ``ctx.param(name, shape, axes)`` creates
    the array and records its logical axes at the same tree path."""

    def __init__(self, key, params: dict | None = None, axes: dict | None = None,
                 dtype=jnp.float32):
        self._key = key
        self._n = 0
        self.params = params if params is not None else {}
        self.axes = axes if axes is not None else {}
        self.dtype = dtype

    def _next_key(self):
        self._n += 1
        return jax.random.fold_in(self._key, self._n)

    def param(self, name: str, shape: tuple, axes: tuple,
              init: Callable | None = None, dtype=None):
        assert len(shape) == len(axes), f"{name}: shape {shape} vs axes {axes}"
        init = init or normal_init()
        arr = init(self._next_key(), shape, dtype or self.dtype)
        self.params[name] = arr
        self.axes[name] = axes
        return arr

    def scope(self, name: str) -> "Ctx":
        sub_p = self.params.setdefault(name, {})
        sub_a = self.axes.setdefault(name, {})
        child = Ctx(jax.random.fold_in(self._key, hash(name) % (2**31)),
                    sub_p, sub_a, self.dtype)
        return child


def init_with_axes(init_fn, key, *args, dtype=jnp.float32, **kw):
    """Run ``init_fn(ctx, *args)`` and return (params, axes)."""
    ctx = Ctx(key, dtype=dtype)
    init_fn(ctx, *args, **kw)
    return ctx.params, ctx.axes


# ---------------------------------------------------------------------------
# Logical axis rules -> NamedSharding
# ---------------------------------------------------------------------------
# Default rules for the production mesh (DESIGN.md section 4):
#   batch-like axes  -> data (+pod) parallelism
#   big contraction / head / expert / vocab / table axes -> tensor ("model")
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": None,
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "layers": None,
    "table": "model",   # recsys embedding rows
    "feat": None,
    "stats": None,
    "hidden": None,
}


def spec_for_axes(axes: tuple, rules: dict) -> P:
    parts = []
    for a in axes:
        r = rules.get(a, None) if a is not None else None
        parts.append(r)
    return P(*parts)


def logical_to_sharding(axes_tree, mesh: Mesh, rules: dict | None = None):
    """Map an axes tree to a NamedSharding pytree for the mesh."""
    rules = {**DEFAULT_RULES, **(rules or {})}
    avail = set(mesh.axis_names)

    def fix(spec_part):
        if spec_part is None:
            return None
        if isinstance(spec_part, tuple):
            kept = tuple(s for s in spec_part if s in avail)
            return kept if kept else None
        return spec_part if spec_part in avail else None

    def one(axes):
        spec = spec_for_axes(axes, rules)
        return NamedSharding(mesh, P(*[fix(s) for s in spec]))

    return jax.tree.map(one, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


def spec_tree(axes_tree, mesh: Mesh, rules: dict | None = None):
    """Same as logical_to_sharding but returns PartitionSpecs (for shard_map
    or in_shardings on lowered fns)."""
    rules = {**DEFAULT_RULES, **(rules or {})}
    avail = set(mesh.axis_names)

    def fix(spec_part):
        if spec_part is None:
            return None
        if isinstance(spec_part, tuple):
            kept = tuple(s for s in spec_part if s in avail)
            return kept if kept else None
        return spec_part if spec_part in avail else None

    def one(axes):
        spec = spec_for_axes(axes, rules)
        return P(*[fix(s) for s in spec])

    return jax.tree.map(one, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


def constrain(x, mesh: Mesh, *axes, rules: dict | None = None):
    """with_sharding_constraint by logical axes (no-op off-mesh)."""
    rules = {**DEFAULT_RULES, **(rules or {})}
    avail = set(mesh.axis_names) if mesh is not None else set()

    def fix(spec_part):
        if spec_part is None:
            return None
        if isinstance(spec_part, tuple):
            kept = tuple(s for s in spec_part if s in avail)
            return kept if kept else None
        return spec_part if spec_part in avail else None

    if mesh is None:
        return x
    spec = spec_for_axes(axes, rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*[fix(s) for s in spec])))


def param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def param_bytes(params) -> int:
    return sum(int(np.prod(p.shape)) * p.dtype.itemsize
               for p in jax.tree.leaves(params))
