"""Decoder-only LM covering the five assigned transformer architectures.

Config-driven features: GQA (any n_kv), QKV bias (qwen1.5), attention/final
logit softcaps + post-norms + embedding scaling + local/global alternating
sliding windows (gemma2), MoE with top-k routing (olmoe) and dense-residual
MoE (arctic), tied/untied embeddings.

Layers run under ``jax.lax.scan`` over stacked (L, ...) parameters -- one
layer's HLO regardless of depth (compile-time and cache friendly at 512-way
SPMD).  ``remat`` wraps the scanned body with jax.checkpoint for activation
rematerialization.  All matmuls carry logical-axis sharding via module.py
rules; activations get explicit constraints at layer boundaries.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp

from .attention import (AttnConfig, attention_decode, attention_train)
from .layers import apply_mlp, apply_norm, init_mlp, init_norm, softcap
from .module import Ctx, constrain, fan_in_init, normal_init
from .moe import MoEConfig, apply_moe, init_moe


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    norm: str = "rmsnorm"
    norm_eps: float = 1e-6
    qkv_bias: bool = False
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    rope_theta: float = 10000.0
    local_window: int = 0             # sliding window for local layers
    layer_pattern: str = "global"     # "global" | "local_global"
    post_norms: bool = False          # gemma2 post-attn/post-mlp norms
    gemma_norm: bool = False          # (1 + scale) RMSNorm
    embed_scale: bool = False         # x *= sqrt(d_model)
    tie_embeddings: bool = True
    moe: MoEConfig | None = None
    remat: bool = True
    param_dtype: str = "float32"
    unroll_layers: bool = False   # dry-run: unroll the layer scan so HLO cost
                                  # analysis sees every layer (while bodies are
                                  # otherwise counted once)
    attn_chunk: int = 0           # >0: flash-style chunked attention (no S^2
                                  # score tensor); perf lever, see EXPERIMENTS

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(self.d_model, self.n_heads, self.n_kv, self.hd,
                          self.qkv_bias, self.attn_softcap, self.rope_theta)

    def windows(self) -> jnp.ndarray:
        if self.layer_pattern == "local_global":
            w = [self.local_window if i % 2 == 0 else 0
                 for i in range(self.n_layers)]
        else:
            w = [self.local_window] * self.n_layers
        return jnp.asarray(w, jnp.int32)

    def param_count(self) -> int:
        d, ff, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        h, kv, hd = self.n_heads, self.n_kv, self.hd
        attn = d * h * hd * 2 + d * kv * hd * 2
        if self.moe:
            m = self.moe
            mlp = d * m.n_experts + m.n_experts * 3 * d * m.d_ff
            if m.dense_residual:
                mlp += 3 * d * (m.d_ff_dense or m.d_ff)
        else:
            mlp = 3 * d * ff
        emb = v * d * (1 if self.tie_embeddings else 2)
        return L * (attn + mlp) + emb

    def active_param_count(self) -> int:
        if not self.moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        h, kv, hd = self.n_heads, self.n_kv, self.hd
        m = self.moe
        attn = d * h * hd * 2 + d * kv * hd * 2
        mlp = d * m.n_experts + m.top_k * 3 * d * m.d_ff
        if m.dense_residual:
            mlp += 3 * d * (m.d_ff_dense or m.d_ff)
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return L * (attn + mlp) + emb


# ---------------------------------------------------------------------------
# Init (stacked layers: every layer weight carries a leading (L,) axis)
# ---------------------------------------------------------------------------
def init_lm(ctx: Ctx, cfg: LMConfig):
    L, d = cfg.n_layers, cfg.d_model
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    ctx.param("embed", (cfg.vocab, d), ("vocab", "embed"), normal_init(0.02))
    if not cfg.tie_embeddings:
        ctx.param("lm_head", (d, cfg.vocab), ("embed", "vocab"), normal_init(0.02))

    lyr = ctx.scope("layers")
    one = lambda: None  # readability
    lyr.param("pre_attn_norm", (L, d), ("layers", "embed"),
              lambda k, s, dt: jnp.zeros(s, dt) if cfg.gemma_norm else jnp.ones(s, dt))
    lyr.param("pre_mlp_norm", (L, d), ("layers", "embed"),
              lambda k, s, dt: jnp.zeros(s, dt) if cfg.gemma_norm else jnp.ones(s, dt))
    if cfg.post_norms:
        lyr.param("post_attn_norm", (L, d), ("layers", "embed"),
                  lambda k, s, dt: jnp.zeros(s, dt) if cfg.gemma_norm else jnp.ones(s, dt))
        lyr.param("post_mlp_norm", (L, d), ("layers", "embed"),
                  lambda k, s, dt: jnp.zeros(s, dt) if cfg.gemma_norm else jnp.ones(s, dt))
    if cfg.norm == "layernorm":
        lyr.param("pre_attn_bias", (L, d), ("layers", "embed"),
                  lambda k, s, dt: jnp.zeros(s, dt))
        lyr.param("pre_mlp_bias", (L, d), ("layers", "embed"),
                  lambda k, s, dt: jnp.zeros(s, dt))

    att = lyr.scope("attn")
    att.param("wq", (L, d, h, hd), ("layers", "embed", "heads", "head_dim"), fan_in_init())
    att.param("wk", (L, d, kv, hd), ("layers", "embed", "kv_heads", "head_dim"), fan_in_init())
    att.param("wv", (L, d, kv, hd), ("layers", "embed", "kv_heads", "head_dim"), fan_in_init())
    att.param("wo", (L, h, hd, d), ("layers", "heads", "head_dim", "embed"), fan_in_init())
    if cfg.qkv_bias:
        att.param("bq", (L, h, hd), ("layers", "heads", "head_dim"),
                  lambda k, s, dt: jnp.zeros(s, dt))
        att.param("bk", (L, kv, hd), ("layers", "kv_heads", "head_dim"),
                  lambda k, s, dt: jnp.zeros(s, dt))
        att.param("bv", (L, kv, hd), ("layers", "kv_heads", "head_dim"),
                  lambda k, s, dt: jnp.zeros(s, dt))

    if cfg.moe:
        m = cfg.moe
        mo = lyr.scope("moe")
        mo.param("router", (L, d, m.n_experts), ("layers", "embed", "experts"),
                 normal_init(0.02))
        mo.param("wi_gate", (L, m.n_experts, d, m.d_ff),
                 ("layers", "experts", "embed", "expert_mlp"), fan_in_init())
        mo.param("wi_up", (L, m.n_experts, d, m.d_ff),
                 ("layers", "experts", "embed", "expert_mlp"), fan_in_init())
        mo.param("wo", (L, m.n_experts, m.d_ff, d),
                 ("layers", "experts", "expert_mlp", "embed"), fan_in_init())
        if m.dense_residual:
            dff = m.d_ff_dense or m.d_ff
            mo.param("dense_gate", (L, d, dff), ("layers", "embed", "mlp"), fan_in_init())
            mo.param("dense_up", (L, d, dff), ("layers", "embed", "mlp"), fan_in_init())
            mo.param("dense_down", (L, dff, d), ("layers", "mlp", "embed"), fan_in_init())
    else:
        ml = lyr.scope("mlp")
        ml.param("gate", (L, d, cfg.d_ff), ("layers", "embed", "mlp"), fan_in_init())
        ml.param("up", (L, d, cfg.d_ff), ("layers", "embed", "mlp"), fan_in_init())
        ml.param("down", (L, cfg.d_ff, d), ("layers", "mlp", "embed"), fan_in_init())

    ctx.param("final_norm", (d,), ("embed",),
              lambda k, s, dt: jnp.zeros(s, dt) if cfg.gemma_norm else jnp.ones(s, dt))


def _norm(cfg, scale, bias, x):
    p = {"scale": scale}
    if bias is not None:
        p["bias"] = bias
    return apply_norm(p, x, cfg.norm, cfg.norm_eps, gemma_style=cfg.gemma_norm)


# ---------------------------------------------------------------------------
# Layer body (used by train/prefill/decode scans)
# ---------------------------------------------------------------------------
def _layer(cfg: LMConfig, lp: dict, h, window, mesh, decode_state=None):
    """One transformer layer.  decode_state = (cache_k, cache_v, pos) or None.
    Returns (h, aux, new_caches_or_kv)."""
    bias_a = lp.get("pre_attn_bias")
    bias_m = lp.get("pre_mlp_bias")
    x = _norm(cfg, lp["pre_attn_norm"], bias_a, h)
    if decode_state is None:
        attn_out, kvs = attention_train(lp["attn"], x, cfg.attn_cfg, window,
                                        chunk=cfg.attn_chunk,
                                        unroll=cfg.unroll_layers)
        new_cache = kvs
    else:
        ck, cv, pos = decode_state
        attn_out, ck, cv = attention_decode(lp["attn"], x, ck, cv, pos,
                                            cfg.attn_cfg, window)
        new_cache = (ck, cv)
    if cfg.post_norms:
        attn_out = _norm(cfg, lp["post_attn_norm"], None, attn_out)
    h = h + attn_out
    h = constrain(h, mesh, "batch", "seq", "embed")

    x = _norm(cfg, lp["pre_mlp_norm"], bias_m, h)
    aux = {}
    if cfg.moe:
        mlp_out, aux = apply_moe(lp["moe"], x, cfg.moe)
    else:
        mlp_out = apply_mlp(lp["mlp"], x)
    if cfg.post_norms:
        mlp_out = _norm(cfg, lp["post_mlp_norm"], None, mlp_out)
    h = h + mlp_out
    h = constrain(h, mesh, "batch", "seq", "embed")
    return h, aux, new_cache


def _embed(params, cfg: LMConfig, tokens):
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        h = h * math.sqrt(cfg.d_model)
    return h


def _logits(params, cfg: LMConfig, h):
    h = _norm(cfg, params["final_norm"], None, h)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------
def forward_train(params, cfg: LMConfig, tokens, mesh=None):
    """tokens (B, S) -> logits (B, S, V) f32 + moe aux dict."""
    h = _embed(params, cfg, tokens).astype(jnp.bfloat16
                                           if cfg.param_dtype == "bfloat16"
                                           else jnp.float32)
    h = constrain(h, mesh, "batch", "seq", "embed")
    windows = cfg.windows()

    def body(carry, xs):
        lp, window = xs
        h, aux_sum = carry
        h, aux, _ = _layer(cfg, lp, h, window, mesh)
        if aux:
            aux_sum = {k: aux_sum[k] + aux[k] for k in aux_sum}
        return (h, aux_sum), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    aux0 = ({"lb_loss": jnp.zeros(()), "z_loss": jnp.zeros(()),
             "dropped_frac": jnp.zeros(())} if cfg.moe else {})
    (h, aux), _ = jax.lax.scan(body_fn, (h, aux0), (params["layers"], windows),
                               unroll=cfg.n_layers if cfg.unroll_layers else 1)
    if cfg.moe:
        aux = {k: v / cfg.n_layers for k, v in aux.items()}
    return _logits(params, cfg, h), aux


def lm_loss(params, cfg: LMConfig, tokens, labels, mesh=None,
            lb_coef: float = 0.01, z_coef: float = 1e-3):
    """Next-token cross entropy (labels = tokens shifted by caller; -1 pads)."""
    logits, aux = forward_train(params, cfg, tokens, mesh)
    valid = labels >= 0
    lbl = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, lbl[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)
    metrics = {"ce_loss": loss}
    if cfg.moe:
        loss = loss + lb_coef * aux["lb_loss"] + z_coef * aux["z_loss"]
        metrics.update(aux)
    return loss, metrics


def prefill(params, cfg: LMConfig, tokens, cache_len: int, mesh=None):
    """tokens (B, S) -> (logits (B, V) f32 last position, caches)."""
    b, s = tokens.shape
    h = _embed(params, cfg, tokens)
    h = constrain(h, mesh, "batch", "seq", "embed")
    windows = cfg.windows()

    def body(h, xs):
        lp, window = xs
        h, _, (k, v) = _layer(cfg, lp, h, window, mesh)
        pad = cache_len - k.shape[1]
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return h, (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, (ck, cv) = jax.lax.scan(body_fn, h, (params["layers"], windows),
                               unroll=cfg.n_layers if cfg.unroll_layers else 1)
    logits = _logits(params, cfg, h[:, -1:, :])[:, 0]
    return logits, {"k": ck, "v": cv}          # caches (L, B, cache_len, kv, hd)


def decode_step(params, cfg: LMConfig, token, caches, pos, mesh=None):
    """One-token decode.  token (B, 1); caches {k,v} (L, B, S, kv, hd);
    pos scalar int32.  Returns (logits (B, V) f32, new caches)."""
    h = _embed(params, cfg, token)
    windows = cfg.windows()

    def body(h, xs):
        lp, window, ck, cv = xs
        h, _, (ck, cv) = _layer(cfg, lp, h, window, mesh,
                                decode_state=(ck, cv, pos))
        return h, (ck, cv)

    h, (ck, cv) = jax.lax.scan(body, h,
                               (params["layers"], windows,
                                caches["k"], caches["v"]),
                               unroll=cfg.n_layers if cfg.unroll_layers else 1)
    logits = _logits(params, cfg, h)[:, 0]
    return logits, {"k": ck, "v": cv}


def make_cache_specs(cfg: LMConfig, batch: int, cache_len: int):
    shape = (cfg.n_layers, batch, cache_len, cfg.n_kv, cfg.hd)
    return {"k": jax.ShapeDtypeStruct(shape, jnp.bfloat16),
            "v": jax.ShapeDtypeStruct(shape, jnp.bfloat16)}
