"""GQA attention with RoPE, QKV-bias, logit softcap, sliding windows, and a
KV-cache decode path.

Layer-type selection (gemma2 local/global alternation) is arithmetic: each
layer carries a scalar ``window`` (0 = global) consumed inside the scanned
layer body, so one compiled program covers both layer kinds.

Decode attends one query token against a (B, S_cache, kv, h) cache that is
updated in place (dynamic_update_slice at ``pos``); softmax statistics are
computed in f32.  Sharding: q/o head axes on "model"; for decode shapes whose
kv-head count does not divide the model axis the cache is sharded on the
*sequence* axis instead and GSPMD inserts the split-softmax reductions
(flash-decoding split-K layout; see configs/*.py rules).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .layers import apply_rope, softcap
from .module import Ctx, fan_in_init, zeros_init

NEG = -2.0e38


@dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    qkv_bias: bool = False
    attn_softcap: float = 0.0
    rope_theta: float = 10000.0
    query_scale: float | None = None  # default 1/sqrt(head_dim)


def init_attention(ctx: Ctx, cfg: AttnConfig):
    h, kv, hd, d = cfg.n_heads, cfg.n_kv, cfg.head_dim, cfg.d_model
    ctx.param("wq", (d, h, hd), ("embed", "heads", "head_dim"), fan_in_init())
    ctx.param("wk", (d, kv, hd), ("embed", "kv_heads", "head_dim"), fan_in_init())
    ctx.param("wv", (d, kv, hd), ("embed", "kv_heads", "head_dim"), fan_in_init())
    ctx.param("wo", (h, hd, d), ("heads", "head_dim", "embed"), fan_in_init())
    if cfg.qkv_bias:
        ctx.param("bq", (h, hd), ("heads", "head_dim"), zeros_init())
        ctx.param("bk", (kv, hd), ("kv_heads", "head_dim"), zeros_init())
        ctx.param("bv", (kv, hd), ("kv_heads", "head_dim"), zeros_init())


def _qkv(params, x, cfg: AttnConfig, positions):
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _scores_mask(q_pos, k_pos, window):
    """causal + optional sliding window; window is a traced scalar (0=off)."""
    causal = k_pos[None, :] <= q_pos[:, None]
    in_win = (q_pos[:, None] - k_pos[None, :]) < jnp.maximum(window, 1)
    use_win = window > 0
    return causal & (in_win | ~use_win)


def attend(q, k, v, mask, cfg: AttnConfig):
    """q (B,S,nq,h); k/v (B,T,kv,h); mask (S,T) or (B,S,T) bool."""
    b, s, nq, hd = q.shape
    kvh = k.shape[2]
    group = nq // kvh
    scale = cfg.query_scale or (hd ** -0.5)
    qg = q.reshape(b, s, kvh, group, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * scale
    logits = softcap(logits, cfg.attn_softcap)
    while mask.ndim < logits.ndim:
        mask = mask[None]
    logits = jnp.where(mask, logits, NEG)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(b, s, nq, hd)


def attend_chunked(q, k, v, window, cfg: AttnConfig, chunk: int,
                   unroll: bool = False):
    """Flash-style online-softmax attention over KV chunks (XLA formulation).

    Never materializes the (S, S) score tensor: a scan over KV chunks carries
    running (max, sum, acc) statistics, so peak intermediate is (..., chunk).
    This is the beyond-paper memory-term optimization for the train/prefill
    cells (EXPERIMENTS.md section Perf); the TPU-native version would be a
    Pallas splash kernel -- the XLA scan already removes the O(S^2) HBM
    traffic, which is what the roofline memory term charges."""
    b, s, nq, hd = q.shape
    kvh = k.shape[2]
    group = nq // kvh
    scale = cfg.query_scale or (hd ** -0.5)
    qg = q.reshape(b, s, kvh, group, hd)
    n_chunks = s // chunk
    kc = k.reshape(b, n_chunks, chunk, kvh, hd)
    vc = v.reshape(b, n_chunks, chunk, kvh, hd)
    q_pos = jnp.arange(s, dtype=jnp.int32)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        k_pos = j * chunk + jnp.arange(chunk, dtype=jnp.int32)
        logits = jnp.einsum("bskgh,btkh->bkgst", qg, kj).astype(jnp.float32)
        logits = softcap(logits * scale, cfg.attn_softcap)
        mask = _scores_mask(q_pos, k_pos, window)
        logits = jnp.where(mask[None, None, None], logits, NEG)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * corr + p.sum(axis=-1)
        # accumulate in f32 (flash-attention convention; also keeps the scan
        # carry dtype stable when activations are bf16)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", p.astype(q.dtype), vj).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, group, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, group, s), jnp.float32)
    a0 = jnp.zeros((b, kvh, group, s, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
         jnp.arange(n_chunks, dtype=jnp.int32)),
        unroll=n_chunks if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(b, s, nq, hd)
    return out.astype(q.dtype)


def attention_train(params, x, cfg: AttnConfig, window, positions=None,
                    chunk: int = 0, unroll: bool = False):
    """Full (pre-fill / training) self-attention.  window: traced scalar.
    chunk > 0 routes through the flash-style chunked path."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q, k, v = _qkv(params, x, cfg, positions)
    if chunk and s % chunk == 0 and s > chunk:
        out = attend_chunked(q, k, v, window, cfg, chunk, unroll)
    else:
        pos = jnp.arange(s, dtype=jnp.int32)
        mask = _scores_mask(pos, pos, window)
        out = attend(q, k, v, mask, cfg)
    return jnp.einsum("bsnh,nhd->bsd", out, params["wo"]), (k, v)


def attention_decode(params, x, cache_k, cache_v, pos, cfg: AttnConfig, window):
    """One-token decode.  x (B,1,d); cache_k/v (B,S,kv,h); pos scalar int32.
    Returns (out (B,1,d), new_cache_k, new_cache_v)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _qkv(params, x, cfg, positions)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, pos, 0, 0))
    s_cache = cache_k.shape[1]
    k_pos = jnp.arange(s_cache, dtype=jnp.int32)
    valid = k_pos <= pos
    in_win = (pos - k_pos) < jnp.maximum(window, 1)
    mask = (valid & (in_win | (window <= 0)))[None, :]      # (1, T)
    out = attend(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype), mask, cfg)
    return jnp.einsum("bsnh,nhd->bsd", out, params["wo"]), cache_k, cache_v
