"""Shared layers: norms, gated MLP, RoPE, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .module import Ctx, fan_in_init, normal_init, ones_init, zeros_init


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(ctx: Ctx, name: str, dim: int, kind: str):
    sc = ctx.scope(name)
    sc.param("scale", (dim,), ("embed",), ones_init())
    if kind == "layernorm":
        sc.param("bias", (dim,), ("embed",), zeros_init())


def apply_norm(params, x, kind: str, eps: float = 1e-6,
               gemma_style: bool = False):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"]
        if params.get("bias") is not None:
            y = y + params["bias"]
    else:  # rmsnorm
        var = (xf ** 2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        scale = (1.0 + params["scale"]) if gemma_style else params["scale"]
        y = y * scale
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------
def init_mlp(ctx: Ctx, name: str, d_model: int, d_ff: int):
    sc = ctx.scope(name)
    sc.param("gate", (d_model, d_ff), ("embed", "mlp"), fan_in_init())
    sc.param("up", (d_model, d_ff), ("embed", "mlp"), fan_in_init())
    sc.param("down", (d_ff, d_model), ("mlp", "embed"), fan_in_init())


def apply_mlp(params, x):
    h = jax.nn.silu(x @ params["gate"]) * (x @ params["up"])
    return h @ params["down"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float = 10000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10000.0):
    """x (..., S, n, h); positions (..., S) int32."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                  # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap) if cap and cap > 0 else x
