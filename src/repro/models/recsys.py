"""RecSys architectures: FM, Wide&Deep, DIEN (GRU + AUGRU), DLRM (dot).

Embedding tables: JAX has no nn.EmbeddingBag / CSR -- lookups are gathers
over stacked per-field tables (F, V, d) and bag reductions are
``jax.ops.segment_sum`` (or the fused Pallas embedding_bag kernel).  Tables
are *field-sharded* on the model axis (table-wise sharding, the DLRM
production layout): each model rank owns F/16 whole tables; batch is data
parallel.  Uniform per-field vocab keeps shapes static (noted in DESIGN.md).

``retrieval_cand`` cells use the factorized dot-scoring form (two-tower /
FM retrieval): a user vector against the item-embedding table, served by the
FAVOR filtered_topk kernel -- the paper's technique as the retrieval layer.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .module import Ctx, fan_in_init, normal_init, zeros_init


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------
def init_tables(ctx: Ctx, name: str, n_fields: int, vocab: int, dim: int):
    ctx.param(name, (n_fields, vocab, dim), ("fields", "table", "embed_dim"),
              normal_init(0.01))


def lookup(tables, ids):
    """tables (F, V, d); ids (B, F) -> (B, F, d)."""
    f = tables.shape[0]
    return tables[jnp.arange(f)[None, :], ids]


def init_mlp_stack(ctx: Ctx, name: str, dims: list[int]):
    sc = ctx.scope(name)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        sc.param(f"w{i}", (a, b), ("feat", "mlp"), fan_in_init())
        sc.param(f"b{i}", (b,), ("mlp",), zeros_init())


def apply_mlp_stack(params, x, n: int, final_act: bool = False):
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def bce_loss(logit, label):
    logit = logit.astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logit, 0) - logit * label +
                    jnp.log1p(jnp.exp(-jnp.abs(logit))))
    return loss


# ---------------------------------------------------------------------------
# FM  (Rendle ICDM'10)  -- O(nk) sum-square trick
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class FMConfig:
    name: str = "fm"
    n_sparse: int = 39
    vocab: int = 1_000_000
    embed_dim: int = 10


def init_fm(ctx: Ctx, cfg: FMConfig):
    ctx.param("w0", (1,), ("stats",), zeros_init())
    ctx.param("w_lin", (cfg.n_sparse, cfg.vocab, 1), ("fields", "table", "embed_dim"),
              normal_init(0.01))
    init_tables(ctx, "v", cfg.n_sparse, cfg.vocab, cfg.embed_dim)


def fm_forward(params, cfg: FMConfig, ids):
    """ids (B, F) -> logit (B,).  Pairwise interactions via
    0.5 * ((sum_f v_f)^2 - sum_f v_f^2) summed over the latent dim."""
    lin = lookup(params["w_lin"], ids)[..., 0].sum(axis=1)        # (B,)
    e = lookup(params["v"], ids)                                  # (B, F, k)
    s = e.sum(axis=1)                                             # (B, k)
    fm = 0.5 * (s * s - (e * e).sum(axis=1)).sum(axis=-1)         # (B,)
    return params["w0"][0] + lin + fm


def fm_loss(params, cfg: FMConfig, ids, labels):
    logit = fm_forward(params, cfg, ids)
    loss = bce_loss(logit, labels)
    return loss, {"bce": loss}


# ---------------------------------------------------------------------------
# Wide & Deep  (arXiv:1606.07792)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class WideDeepConfig:
    name: str = "wide-deep"
    n_sparse: int = 40
    vocab: int = 1_000_000
    embed_dim: int = 32
    mlp: tuple = (1024, 512, 256)


def init_wide_deep(ctx: Ctx, cfg: WideDeepConfig):
    ctx.param("wide", (cfg.n_sparse, cfg.vocab, 1),
              ("fields", "table", "embed_dim"), normal_init(0.01))
    init_tables(ctx, "deep_emb", cfg.n_sparse, cfg.vocab, cfg.embed_dim)
    dims = [cfg.n_sparse * cfg.embed_dim, *cfg.mlp, 1]
    init_mlp_stack(ctx, "deep_mlp", dims)


def wide_deep_forward(params, cfg: WideDeepConfig, ids):
    wide = lookup(params["wide"], ids)[..., 0].sum(axis=1)
    e = lookup(params["deep_emb"], ids).reshape(ids.shape[0], -1)
    deep = apply_mlp_stack(params["deep_mlp"], e, len(cfg.mlp) + 1)[:, 0]
    return wide + deep


def wide_deep_loss(params, cfg: WideDeepConfig, ids, labels):
    logit = wide_deep_forward(params, cfg, ids)
    loss = bce_loss(logit, labels)
    return loss, {"bce": loss}


# ---------------------------------------------------------------------------
# DIEN  (arXiv:1809.03672)  -- interest extraction GRU + AUGRU evolution
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DIENConfig:
    name: str = "dien"
    vocab: int = 1_000_000
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp: tuple = (200, 80)
    unroll: bool = False  # dry-run: unroll the GRU scans for HLO cost accuracy


def _init_gru(ctx: Ctx, name: str, d_in: int, d_h: int):
    sc = ctx.scope(name)
    sc.param("wx", (d_in, 3 * d_h), ("feat", "hidden"), fan_in_init())
    sc.param("wh", (d_h, 3 * d_h), ("hidden", "hidden"), fan_in_init())
    sc.param("b", (3 * d_h,), ("hidden",), zeros_init())


def _gru_cell(p, h, x, a=None):
    """Standard GRU; if attention score ``a`` is given, AUGRU: z <- a*z."""
    gx = x @ p["wx"] + p["b"]
    gh = h @ p["wh"]
    dh = h.shape[-1]
    r = jax.nn.sigmoid(gx[..., :dh] + gh[..., :dh])
    z = jax.nn.sigmoid(gx[..., dh:2 * dh] + gh[..., dh:2 * dh])
    n = jnp.tanh(gx[..., 2 * dh:] + r * gh[..., 2 * dh:])
    if a is not None:
        z = a[..., None] * z
    return (1.0 - z) * h + z * n


def init_dien(ctx: Ctx, cfg: DIENConfig):
    init_tables(ctx, "item_emb", 1, cfg.vocab, cfg.embed_dim)
    _init_gru(ctx, "gru1", cfg.embed_dim, cfg.gru_dim)
    _init_gru(ctx, "augru", cfg.gru_dim, cfg.gru_dim)
    sc = ctx.scope("att")
    sc.param("w", (cfg.gru_dim + cfg.embed_dim, 1), ("feat", "embed_dim"),
             fan_in_init())
    dims = [cfg.gru_dim + cfg.embed_dim, *cfg.mlp, 1]
    init_mlp_stack(ctx, "head", dims)


def dien_forward(params, cfg: DIENConfig, hist, target):
    """hist (B, S) behavior ids (-1 pad); target (B,) item id -> logit (B,)."""
    b, s = hist.shape
    emb = params["item_emb"][0]                              # (V, d)
    he = emb[jnp.maximum(hist, 0)] * (hist >= 0)[..., None]  # (B, S, d)
    te = emb[target]                                         # (B, d)

    p1 = params["gru1"]
    def step1(h, x):
        h = _gru_cell(p1, h, x)
        return h, h
    h0 = jnp.zeros((b, cfg.gru_dim), he.dtype)
    _, states = jax.lax.scan(step1, h0, jnp.swapaxes(he, 0, 1),
                             unroll=cfg.seq_len if cfg.unroll else 1)
    states = jnp.swapaxes(states, 0, 1)                      # (B, S, gru)

    # attention of each interest state on the target item
    att_in = jnp.concatenate(
        [states, jnp.broadcast_to(te[:, None, :], (b, s, cfg.embed_dim))], -1)
    scores = (att_in @ params["att"]["w"])[..., 0]           # (B, S)
    scores = jnp.where(hist >= 0, scores, -1e30)
    a = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(he.dtype)

    p2 = params["augru"]
    def step2(h, xs):
        x, at = xs
        h = _gru_cell(p2, h, x, at)
        return h, None
    hT, _ = jax.lax.scan(step2, h0, (jnp.swapaxes(states, 0, 1),
                                     jnp.swapaxes(a, 0, 1)),
                         unroll=cfg.seq_len if cfg.unroll else 1)

    z = jnp.concatenate([hT, te], axis=-1)
    return apply_mlp_stack(params["head"], z, len(cfg.mlp) + 1)[:, 0]


def dien_loss(params, cfg: DIENConfig, hist, target, labels):
    logit = dien_forward(params, cfg, hist, target)
    loss = bce_loss(logit, labels)
    return loss, {"bce": loss}


# ---------------------------------------------------------------------------
# DLRM-RM2  (arXiv:1906.00091)  -- bottom MLP + dot interaction + top MLP
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    vocab: int = 1_000_000
    embed_dim: int = 64
    bot_mlp: tuple = (512, 256, 64)
    top_mlp: tuple = (512, 512, 256)


def init_dlrm(ctx: Ctx, cfg: DLRMConfig):
    init_tables(ctx, "emb", cfg.n_sparse, cfg.vocab, cfg.embed_dim)
    init_mlp_stack(ctx, "bot", [cfg.n_dense, *cfg.bot_mlp])
    n_vec = cfg.n_sparse + 1
    d_int = n_vec * (n_vec - 1) // 2 + cfg.embed_dim
    init_mlp_stack(ctx, "top", [d_int, *cfg.top_mlp, 1])


def dlrm_forward(params, cfg: DLRMConfig, dense, ids):
    """dense (B, 13) f32; ids (B, 26) int32 -> logit (B,)."""
    b = dense.shape[0]
    x = apply_mlp_stack(params["bot"], dense, len(cfg.bot_mlp), final_act=True)
    e = lookup(params["emb"], ids)                           # (B, 26, 64)
    vecs = jnp.concatenate([x[:, None, :], e], axis=1)       # (B, 27, 64)
    gram = jnp.einsum("bnd,bmd->bnm", vecs, vecs)            # (B, 27, 27)
    n_vec = cfg.n_sparse + 1
    iu, ju = jnp.triu_indices(n_vec, k=1)
    inter = gram[:, iu, ju]                                  # (B, 351)
    z = jnp.concatenate([x, inter], axis=-1)
    return apply_mlp_stack(params["top"], z, len(cfg.top_mlp) + 1)[:, 0]


def dlrm_loss(params, cfg: DLRMConfig, dense, ids, labels):
    logit = dlrm_forward(params, cfg, dense, ids)
    loss = bce_loss(logit, labels)
    return loss, {"bce": loss}


# ---------------------------------------------------------------------------
# Retrieval scoring (retrieval_cand cells) -- FAVOR as the retrieval layer
# ---------------------------------------------------------------------------
def retrieval_scores(user_vec, item_table):
    """Factorized dot scoring: (B, d) x (N, d) -> (B, N)."""
    return user_vec @ item_table.T


def retrieval_topk_filtered(user_vec, item_table, programs, attrs_int,
                            attrs_float, k: int = 100, use_pallas: bool = False):
    """Top-k candidates under attribute filters, served by FAVOR's PreFBF
    machinery (the paper's technique as the recsys retrieval layer).

    Max-inner-product -> min-L2 uses the exact augmentation reduction
    (Shrivastava & Li): give every item the *constant* augmented norm
    M^2 = max_row |v|^2 (the virtual extra coordinate sqrt(M^2 - |v|^2)
    contributes nothing to q.v since the query's extra coordinate is 0), so
    the kernel's d2 = M^2 + |q|^2 - 2 q.v is >= (M - |q|)^2 >= 0 and exactly
    MIP-ordered."""
    if use_pallas:
        from ..kernels.filtered_topk import ops as ft
        m2 = jnp.max(jnp.sum(item_table * item_table, axis=-1))
        norms = jnp.full((item_table.shape[0],), m2, jnp.float32)
        ids, d = ft.filtered_topk(item_table, norms, attrs_int, attrs_float,
                                  user_vec, programs, k=k)
        qn = jnp.sum(user_vec * user_vec, axis=-1, keepdims=True)
        scores = 0.5 * (m2 + qn - d * d)       # invert the reduction
        return ids, jnp.where(ids >= 0, scores, -jnp.inf)
    from ..core import filters as F
    scores = retrieval_scores(user_vec, item_table)
    mask = F.eval_program_batched(programs, attrs_int, attrs_float, xp=jnp)
    scores = jnp.where(mask, scores, -jnp.inf)
    sc, idx = jax.lax.top_k(scores, k)
    return idx, sc
