"""Mixture-of-Experts block: top-k routing + sort-based static-capacity
dispatch + expert-parallel grouped matmul.

Dispatch is the sort-based static-shape formulation (no (T, E, C) one-hot
tensors): flatten (token, choice) pairs, argsort by expert id, compute each
pair's position inside its expert group via an exclusive-cumsum of expert
counts, drop pairs beyond the static capacity C = ceil(T*k/E * cf), scatter
the survivors into (E, C) slots, run the per-expert SwiGLU as batched
einsums over the expert axis (sharded on "model" = expert parallelism), and
scatter-add the weighted outputs back to token order.

Aux losses: standard load-balancing loss (Switch) + router z-loss.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .module import Ctx, fan_in_init, normal_init


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int                 # per-expert hidden
    capacity_factor: float = 1.25
    renormalize: bool = True
    dense_residual: bool = False  # arctic-style parallel dense FFN
    d_ff_dense: int = 0


def init_moe(ctx: Ctx, cfg: MoEConfig):
    ctx.param("router", (cfg.d_model, cfg.n_experts), ("embed", "experts"),
              normal_init(0.02))
    ctx.param("wi_gate", (cfg.n_experts, cfg.d_model, cfg.d_ff),
              ("experts", "embed", "expert_mlp"), fan_in_init())
    ctx.param("wi_up", (cfg.n_experts, cfg.d_model, cfg.d_ff),
              ("experts", "embed", "expert_mlp"), fan_in_init())
    ctx.param("wo", (cfg.n_experts, cfg.d_ff, cfg.d_model),
              ("experts", "expert_mlp", "embed"), fan_in_init())
    if cfg.dense_residual:
        dff = cfg.d_ff_dense or cfg.d_ff
        ctx.param("dense_gate", (cfg.d_model, dff), ("embed", "mlp"), fan_in_init())
        ctx.param("dense_up", (cfg.d_model, dff), ("embed", "mlp"), fan_in_init())
        ctx.param("dense_down", (dff, cfg.d_model), ("mlp", "embed"), fan_in_init())


def moe_capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def apply_moe(params, x, cfg: MoEConfig):
    """x (..., T, d) flattened internally.  Returns (y, aux) where aux carries
    the load-balance and z losses."""
    orig_shape = x.shape
    d = orig_shape[-1]
    xf = x.reshape(-1, d)
    t = xf.shape[0]
    e, k = cfg.n_experts, cfg.top_k
    c = moe_capacity(t, cfg)

    logits = (xf @ params["router"]).astype(jnp.float32)     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                   # (T, k)
    if cfg.renormalize:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # aux losses (Switch load balance + z-loss)
    me = probs.mean(axis=0)                                  # (E,)
    ce = jnp.zeros((e,)).at[top_i.reshape(-1)].add(1.0) / (t * k)
    lb_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- sort-based dispatch -------------------------------------------------
    flat_e = top_i.reshape(-1)                               # (T*k,)
    flat_t = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(-1)
    flat_p = top_p.reshape(-1).astype(x.dtype)
    order = jnp.argsort(flat_e)                              # stable
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[se]
    keep = pos < c
    slot = jnp.where(keep, se * c + pos, e * c)              # drop -> sentinel

    disp_tok = jnp.zeros((e * c + 1,), jnp.int32).at[slot].set(st, mode="drop")[: e * c]
    disp_p = jnp.zeros((e * c + 1,), x.dtype).at[slot].set(sp, mode="drop")[: e * c]
    disp_ok = jnp.zeros((e * c + 1,), bool).at[slot].set(keep, mode="drop")[: e * c]

    x_e = xf[disp_tok].reshape(e, c, d)
    x_e = jnp.where(disp_ok.reshape(e, c, 1), x_e, 0)

    # ---- expert SwiGLU (einsum over the expert axis -> EP on "model") -------
    g = jnp.einsum("ecd,edf->ecf", x_e, params["wi_gate"])
    u = jnp.einsum("ecd,edf->ecf", x_e, params["wi_up"])
    h = jax.nn.silu(g) * u
    y_e = jnp.einsum("ecf,efd->ecd", h, params["wo"])        # (E, C, d)

    # ---- combine -------------------------------------------------------------
    w = (disp_p * disp_ok).reshape(e * c, 1)
    y = jnp.zeros_like(xf).at[disp_tok].add(y_e.reshape(e * c, d) * w)

    if cfg.dense_residual:
        dg = jax.nn.silu(xf @ params["dense_gate"]) * (xf @ params["dense_up"])
        y = y + dg @ params["dense_down"]

    aux = {"lb_loss": lb_loss, "z_loss": z_loss,
           "dropped_frac": 1.0 - keep.mean()}
    return y.reshape(orig_shape), aux
