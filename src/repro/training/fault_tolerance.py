"""Fault tolerance for long multi-pod runs: preemption-safe training loop,
straggler watchdog, and elastic restart glue (DESIGN.md section 4).

* ``PreemptionGuard`` converts SIGTERM/SIGINT into a cooperative "save and
  exit" flag checked once per step (TPU preemption notice pattern).
* ``StragglerWatchdog`` tracks a robust step-time EMA; steps slower than
  ``threshold``x the median are logged and counted -- at scale this signal
  feeds the scheduler to drain the slow host (here: surfaced in metrics).
* ``run_loop`` wires both to the checkpoint module: restore-latest on start,
  periodic + on-preemption saves, crash-consistent resume (the data pipeline
  state is part of the checkpoint, so resumed runs are bitwise continuable).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field

import numpy as np

from . import checkpoint as ckpt


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._old = {}
        for s in signals:
            try:
                self._old[s] = signal.signal(s, self._handler)
            except ValueError:  # non-main thread (tests)
                pass

    def _handler(self, signum, frame):
        self.requested = True

    def restore_handlers(self):
        for s, h in self._old.items():
            signal.signal(s, h)


@dataclass
class StragglerWatchdog:
    threshold: float = 2.0
    window: int = 50
    times: list = field(default_factory=list)
    slow_steps: int = 0

    def record(self, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        med = float(np.median(self.times))
        slow = len(self.times) >= 5 and dt > self.threshold * med
        if slow:
            self.slow_steps += 1
        return slow

    @property
    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


def run_loop(step_fn, state: dict, data_iter, *, n_steps: int, ckpt_dir: str,
             save_every: int = 100, log_every: int = 10, log=print,
             guard: PreemptionGuard | None = None):
    """Generic fault-tolerant loop.

    state: {"params":..., "opt":..., "data_state":..., "step": int}
    step_fn(state, batch) -> (state, metrics); data_iter(data_state) ->
    (batch, data_state).  Resumes from the latest checkpoint if present.
    """
    guard = guard or PreemptionGuard()
    watchdog = StragglerWatchdog()

    latest = ckpt.latest_step(ckpt_dir)
    if latest is not None:
        tree, meta = ckpt.restore(ckpt_dir, latest)
        state = tree
        log(f"[ft] resumed from step {latest}")

    start = int(state["step"])
    metrics = {}
    for i in range(start, n_steps):
        t0 = time.perf_counter()
        batch, state["data_state"] = data_iter(state["data_state"])
        state, metrics = step_fn(state, batch)
        state["step"] = i + 1
        dt = time.perf_counter() - t0
        slow = watchdog.record(dt)
        if slow:
            log(f"[ft] straggler step {i}: {dt:.3f}s vs median {watchdog.median:.3f}s")
        if (i + 1) % log_every == 0:
            loss = metrics.get("loss")
            log(f"step {i + 1}: loss={float(loss):.4f} dt={dt * 1e3:.1f}ms")
        if (i + 1) % save_every == 0 or guard.requested:
            ckpt.save(ckpt_dir, i + 1, state)
        if guard.requested:
            log(f"[ft] preemption requested; saved at step {i + 1}, exiting")
            break
    return state, metrics, watchdog
