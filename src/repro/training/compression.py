"""Gradient compression for the data-parallel all-reduce: int8 blockwise
quantization with error feedback (1-bit-Adam family, arXiv:2102.02888-style).

At 1000+ node scale the DP all-reduce of dense grads is the dominant WAN/DCN
collective; int8 with per-block scales cuts those bytes 4x vs f32 (2x vs
bf16) at negligible quality cost *when error feedback carries the residual*.

Mechanics: the returned ``compress(grads)`` callable quantize-dequantizes
each leaf (simulating the wire format -- XLA then all-reduces the already
low-rank-error tensor) and folds the quantization error into a persistent
residual that is added to the next step's grads.  The residual state lives in
a host-side closure updated functionally; for the jit path use
``quantize_dequantize`` directly inside the step with the residual threaded
through opt_state-like state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_dequantize(x, block: int = 256):
    """Blockwise symmetric int8 quantize -> dequantize.  Returns (y, err)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % block
    fp = jnp.pad(flat, (0, pad))
    blocks = fp.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127)
    deq = (q * scale).reshape(-1)[: flat.shape[0]].reshape(x.shape)
    return deq.astype(x.dtype), (x - deq).astype(x.dtype)


def compress_tree(grads, residual):
    """Error-feedback compression over a grad pytree.
    Returns (compressed_grads, new_residual)."""
    def one(g, r):
        y, err = quantize_dequantize(g + r)
        return y, err
    pairs = jax.tree.map(one, grads, residual)
    comp = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return comp, res


def init_residual(params):
    return jax.tree.map(jnp.zeros_like, params)
