"""Fault-tolerant sharded checkpointing (no orbax): flat-key npz shards with
atomic rename, retention, async save, and restore-with-resharding.

Layout:  <dir>/step_<N>/shard_<host>.npz + meta.json, written to a tmp dir
and atomically renamed only after every array is flushed (a preempted save
can never corrupt the latest good checkpoint).  ``latest_step`` scans for
complete checkpoints (meta.json present).  Restore loads host-side numpy and
``jax.device_put``s against the *current* mesh sharding, so a job restarted
on a different device count (elastic re-mesh, launch/elastic.py) reshards
transparently.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, v in flat.items():
        parts = key.split("/")
        cur = root
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("#") for k in node):
            items = sorted(node.items(), key=lambda kv: int(kv[0][1:]))
            return tuple(fix(v) for _, v in items)
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save(ckpt_dir: str, step: int, tree, *, meta: dict | None = None,
         keep: int = 3, host_id: int = 0) -> str:
    """Atomic checkpoint write.  ``tree``: pytree of arrays (device or host)."""
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **flat)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "time": time.time(), **(meta or {})}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _retain(ckpt_dir, keep)
    return final


def save_async(ckpt_dir: str, step: int, tree, **kw) -> threading.Thread:
    """Fire-and-forget save on a background thread (device->host copy happens
    eagerly so training can mutate donated buffers immediately)."""
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree),
                         kwargs=kw, daemon=True)
    t.start()
    return t


def _retain(ckpt_dir: str, keep: int) -> None:
    steps = sorted(_complete_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def _complete_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
                out.append(int(name[5:]))
    return out


def latest_step(ckpt_dir: str) -> int | None:
    steps = _complete_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int | None = None, *, shardings=None,
            host_id: int = 0):
    """Load a checkpoint; optionally device_put against a shardings pytree
    (same structure) for elastic resharding.  Returns (tree, meta)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    z = np.load(os.path.join(d, f"shard_{host_id}.npz"))
    tree = _unflatten({k: z[k] for k in z.files})
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, meta
