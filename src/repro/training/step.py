"""Train-step factory: grad + optimizer update, with optional microbatch
gradient accumulation (scan), gradient compression hooks, and donation.

``make_train_step(loss_fn, opt_cfg, microbatches)`` returns a jit-able
``step(params, opt_state, batch) -> (params, opt_state, metrics)``:
  * microbatches > 1 reshapes every batch leaf (B, ...) -> (m, B/m, ...) and
    accumulates grads with a lax.scan -- the standard activation-memory lever
    for the big train shapes (arctic/olmoe at 1M tokens per step);
  * the optional ``compress`` hook (training/compression.py) quantizes grads
    before the data-parallel all-reduce that jit inserts at the psum point.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import optimizer as opt


def make_train_step(loss_fn, opt_cfg: opt.OptConfig, *, microbatches: int = 1,
                    compress=None, donate: bool = True):
    """loss_fn(params, batch) -> (loss, metrics dict)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, metrics, grads

    def step(params, opt_state, batch):
        if microbatches > 1:
            def micro(carry, mb):
                acc, = carry
                loss, metrics, grads = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc,), (loss, metrics)

            mb_batch = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (gsum,), (losses, metricses) = jax.lax.scan(micro, (zero,), mb_batch)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = losses.mean()
            metrics = jax.tree.map(lambda m: m.mean(), metricses)
        else:
            loss, metrics, grads = grads_of(params, batch)

        if compress is not None:
            grads = compress(grads)
        params, opt_state, om = opt.apply_updates(params, grads, opt_state,
                                                  opt_cfg)
        metrics = {**metrics, **om, "loss": loss}
        return params, opt_state, metrics

    return step


def jit_train_step(step, mesh=None, in_shardings=None, out_shardings=None,
                   donate: bool = True):
    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    if donate:
        kw["donate_argnums"] = (0, 1)
    return jax.jit(step, **kw)
