"""Optimizers from scratch (no optax): AdamW + SGD-momentum, global-norm
clipping, warmup-cosine schedules, and ZeRO-1-style optimizer-state sharding
hooks (the state tree reuses the parameter logical axes, so mapping "data"
into the rules table shards moments across the data axis)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    kind: str = "adamw"  # adamw | sgdm


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict  # unused for sgdm (zeros-like placeholder kept for uniform tree)


def init_opt_state(params, cfg: OptConfig) -> OptState:
    # moments are f32 regardless of (possibly bf16) param dtype
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    zeros = jax.tree.map(f32, params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(f32, params)
                    if cfg.kind == "adamw" else zeros)


def schedule(cfg: OptConfig, step):
    """Linear warmup -> cosine decay to min_lr_frac * lr."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def apply_updates(params, grads, state: OptState, cfg: OptConfig):
    """One optimizer step.  Returns (new_params, new_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step.astype(jnp.float32))
    b1, b2 = cfg.betas

    if cfg.kind == "adamw":
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) *
                          jnp.square(g.astype(v.dtype)), state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mh = m / bc1
            vh = v / bc2
            u = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(m.dtype)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        new_state = OptState(step=step, mu=mu, nu=nu)
    else:  # sgd + momentum
        mu = jax.tree.map(lambda m, g: b1 * m + g.astype(m.dtype),
                          state.mu, grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, mu)
        new_state = OptState(step=step, mu=mu, nu=state.nu)

    return new_params, new_state, {"lr": lr, "grad_norm": gn}
