"""Serving-side cache subsystem (see core.options.CacheSpec for knobs).

``CachingBackend`` wraps any ``core.backend.Backend`` and plugs into
``router.execute``/``ServeEngine`` unchanged:

    from repro.cache import CachingBackend
    eng = ServeEngine(CachingBackend(LocalBackend(fi), CacheSpec()), opts)

Keys are canonical filter signatures (``core.filters.filter_signature``), so
semantically equivalent predicates share cache entries across all three
layers (selectivity, candidate block, semantic result).
"""
from ..core.options import CacheSpec
from .backend import CachingBackend
from .layers import CandidateCache, SelectivityCache, SemanticResultCache
from .lru import LruTtlCache

__all__ = ["CacheSpec", "CachingBackend", "CandidateCache", "LruTtlCache",
           "SelectivityCache", "SemanticResultCache"]
