"""The three serving-cache layers, all keyed by canonical filter signatures.

SelectivityCache    signature -> p_hat (float).  The sample estimator is
                    deterministic, so hits are bit-identical to recomputing.
CandidateCache      signature -> sorted matching-ID array.  Admission is
                    gated on exact selectivity (p <= p_max) and entry size,
                    because only brute-routed (low-selectivity) filters win
                    from scanning a candidate block instead of the corpus.
SemanticResultCache (signature, opts) -> [(query vector, top-k, route), ...]
                    redisvl-style: a lookup scans the per-key entry list for
                    a cached query vector within ``threshold`` L2 distance.
                    threshold 0.0 serves only exact repeats (lossless).

Each layer wraps one ``LruTtlCache`` and adds its own admission/matching
semantics plus a ``bypass`` counter for lookups the layer declined to serve
by policy (disabled layer, over-cap entry, no corpus access) -- distinct
from a miss, which is demand the layer could have served with a warmer
cache.

The candidate and semantic layers additionally take an optional integer
``scope`` (tenant/session id, 0 = unscoped): the scope joins the cache key,
so tenant A's entries can never serve tenant B -- the isolation contract the
multi-tenant front-end relies on -- and per-scope hit/miss counters surface
in ``stats()["by_scope"]``.  The selectivity layer stays global: p_hat is a
property of the data, not of who asked.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from ..core.options import CacheSpec
from .lru import LruTtlCache, _MISS


class _ScopeCounters:
    """Per-scope hit/miss accounting shared by the scoped layers."""

    def __init__(self):
        self._counts: dict[int, list] = {}

    def count(self, scope: int, hit: bool) -> None:
        row = self._counts.setdefault(int(scope), [0, 0])
        row[0 if hit else 1] += 1

    def stats(self) -> dict:
        out = {}
        for scope, (h, m) in sorted(self._counts.items()):
            out[scope] = {"hits": h, "misses": m,
                          "hit_rate": h / (h + m) if h + m else 0.0}
        return out

    def reset(self) -> None:
        self._counts.clear()


class SelectivityCache:
    """signature -> p_hat; skips backend.estimate for repeat filters."""

    def __init__(self, spec: CacheSpec, clock=time.monotonic):
        self.enabled = spec.selectivity
        self._lru = LruTtlCache(spec.selectivity_cap, spec.ttl_s, clock)
        self.bypasses = 0

    def get(self, sig: str) -> float | None:
        if not self.enabled:
            self.bypasses += 1
            return None
        return self._lru.get(sig)

    def peek(self, sig: str) -> float | None:
        """Non-counting read for other layers' admission heuristics."""
        if not self.enabled:
            return None
        v = self._lru.peek(sig)
        return None if v is _MISS else v

    def put(self, sig: str, p_hat: float) -> None:
        if self.enabled:
            self._lru.put(sig, float(p_hat))

    def clear(self) -> int:
        return self._lru.clear()

    def reset_counters(self) -> None:
        self._lru.reset_counters()
        self.bypasses = 0

    def stats(self) -> dict:
        return {**self._lru.stats(), "bypasses": self.bypasses,
                "enabled": self.enabled}


class CandidateCache:
    """signature -> matching-ID block for hot low-selectivity filters.

    Blocks store the *base-corpus* extension only; under a live index the
    backend composes tombstones and delta rows over the block at hit time
    (counted in ``composed``), so entries survive vector-only mutations.

    ``scope`` joins the key: the same signature admitted under two tenants
    stores two entries (isolation costs sharing, by design)."""

    def __init__(self, spec: CacheSpec, clock=time.monotonic):
        self.enabled = spec.candidates
        self.p_max = spec.candidate_p_max
        self.max_ids = spec.candidate_max_ids
        self._lru = LruTtlCache(spec.candidate_cap, spec.ttl_s, clock)
        self.bypasses = 0
        self.composed = 0   # hits served through live-state composition
        self._by_scope = _ScopeCounters()

    def get(self, sig: str, scope: int = 0) -> np.ndarray | None:
        if not self.enabled:
            self.bypasses += 1
            return None
        out = self._lru.get((scope, sig))
        self._by_scope.count(scope, out is not None)
        return out

    def admit(self, sig: str, ids: np.ndarray, n_rows: int,
              scope: int = 0) -> bool:
        """Admission-controlled insert; True when the entry was stored."""
        if not self.enabled:
            return False
        if len(ids) > self.max_ids or len(ids) > self.p_max * n_rows:
            self.bypasses += 1
            return False
        self._lru.put((scope, sig), np.ascontiguousarray(ids, np.int64))
        return True

    def clear(self) -> int:
        return self._lru.clear()

    def reset_counters(self) -> None:
        self._lru.reset_counters()
        self.bypasses = 0
        self.composed = 0
        self._by_scope.reset()

    def stats(self) -> dict:
        return {**self._lru.stats(), "bypasses": self.bypasses,
                "composed": self.composed, "enabled": self.enabled,
                "by_scope": self._by_scope.stats()}


@dataclass
class _SemanticEntry:
    query: np.ndarray          # (d,) float32
    ids: np.ndarray            # (k,) int64
    dists: np.ndarray          # (k,) float32
    p_hat: float
    routed_brute: bool
    t: float = 0.0             # insert time (per-entry TTL)


class SemanticResultCache:
    """(signature, opts) -> cached query vectors with their exact top-k.

    TTL is enforced **per entry**, not per key: a hot key that keeps
    receiving fresh queries must not keep serving results computed before
    the TTL horizon (the key-level LruTtlCache timestamp refreshes on every
    put, so it only bounds idle keys)."""

    def __init__(self, spec: CacheSpec, clock=time.monotonic):
        self.enabled = spec.semantic
        self.threshold = spec.semantic_threshold
        self.per_key = spec.semantic_per_key
        self.ttl_s = spec.ttl_s
        self._clock = clock
        self._lru = LruTtlCache(spec.semantic_cap, spec.ttl_s, clock)
        self.bypasses = 0
        self._by_scope = _ScopeCounters()

    def _prune(self, entries: list) -> list:
        """Drop entries older than the TTL (counted as expirations)."""
        if self.ttl_s is None:
            return entries
        now = self._clock()
        live = [e for e in entries if now - e.t <= self.ttl_s]
        self._lru.expirations += len(entries) - len(live)
        return live

    def get(self, sig: str, opts, query: np.ndarray,
            scope: int = 0) -> _SemanticEntry | None:
        """Nearest cached entry for (scope, sig, opts) within threshold, else
        None.  Counts one hit or one miss on the underlying LRU either way."""
        if not self.enabled:
            self.bypasses += 1
            return None
        key = (scope, sig, opts)
        entries = self._lru.peek(key)
        if entries is _MISS:
            self._lru.misses += 1
            self._by_scope.count(scope, False)
            return None
        entries[:] = self._prune(entries)
        q = np.asarray(query, np.float32)
        best, best_d = None, np.inf
        for e in entries:
            d = float(np.sqrt(np.sum((e.query - q) ** 2, dtype=np.float32)))
            if d <= self.threshold and d < best_d:
                best, best_d = e, d
        self._by_scope.count(scope, best is not None)
        if best is None:
            self._lru.misses += 1
            return None
        self._lru.get(key)  # touch recency + count the hit
        return best

    def put(self, sig: str, opts, query: np.ndarray, ids, dists,
            p_hat: float, routed_brute: bool, scope: int = 0) -> None:
        if not self.enabled:
            return
        key = (scope, sig, opts)
        entries = self._lru.peek(key)
        if entries is _MISS:
            entries = []
        entries = self._prune(entries)
        q = np.asarray(query, np.float32).copy()
        entry = _SemanticEntry(q, np.asarray(ids, np.int64).copy(),
                               np.asarray(dists, np.float32).copy(),
                               float(p_hat), bool(routed_brute),
                               t=self._clock())
        # replace an entry the new query would already hit (dedupe: batch
        # padding repeats the same query several times per batch)
        for i, e in enumerate(entries):
            d = float(np.sqrt(np.sum((e.query - q) ** 2, dtype=np.float32)))
            if d <= self.threshold:
                entries[i] = entry
                self._lru.put(key, entries)
                return
        entries.append(entry)
        if len(entries) > self.per_key:
            entries = entries[-self.per_key:]
        self._lru.put(key, entries)

    def clear(self) -> int:
        return self._lru.clear()

    def reset_counters(self) -> None:
        self._lru.reset_counters()
        self.bypasses = 0
        self._by_scope.reset()

    def stats(self) -> dict:
        return {**self._lru.stats(), "bypasses": self.bypasses,
                "enabled": self.enabled, "threshold": self.threshold,
                "by_scope": self._by_scope.stats()}
