"""Bounded LRU + TTL map: the one eviction policy every cache layer shares.

Kept deliberately free of cache-layer semantics: keys and values are opaque,
time comes from an injectable monotonic clock (tests pass a fake), and the
counters record only what this container can observe (hits, misses,
evictions, expirations).  Layer-level notions -- bypasses, invalidation
epochs, what a "hit" means for a semantic entry -- live in ``layers.py``.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from typing import Any, Callable

_MISS = object()  # sentinel: None is a legal cached value


class LruTtlCache:
    """OrderedDict-backed LRU with optional per-entry TTL.

    cap    : max live entries; inserting past it evicts the LRU entry.
    ttl_s  : entry lifetime in seconds (None = entries never expire).
    clock  : monotonic time source; injectable so tests control expiry.
    """

    def __init__(self, cap: int, ttl_s: float | None = None,
                 clock: Callable[[], float] = time.monotonic):
        if cap < 1:
            raise ValueError(f"cap must be >= 1, got {cap}")
        if ttl_s is not None and ttl_s <= 0:
            raise ValueError(f"ttl_s must be None or > 0, got {ttl_s}")
        self.cap = cap
        self.ttl_s = ttl_s
        self.clock = clock
        self._d: OrderedDict[Any, tuple[float, Any]] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.expirations = 0

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key) -> bool:
        return self.peek(key) is not _MISS

    def peek(self, key):
        """Like get() but without touching recency or hit/miss counters
        (expired entries are still dropped)."""
        ent = self._d.get(key)
        if ent is None:
            return _MISS
        t, value = ent
        if self.ttl_s is not None and self.clock() - t > self.ttl_s:
            del self._d[key]
            self.expirations += 1
            return _MISS
        return value

    def get(self, key, default=None):
        value = self.peek(key)
        if value is _MISS:
            self.misses += 1
            return default
        self._d.move_to_end(key)
        self.hits += 1
        return value

    def put(self, key, value) -> None:
        if key in self._d:
            del self._d[key]
        elif len(self._d) >= self.cap:
            self._d.popitem(last=False)
            self.evictions += 1
        self._d[key] = (self.clock(), value)

    def pop(self, key, default=None):
        ent = self._d.pop(key, None)
        return default if ent is None else ent[1]

    def clear(self) -> int:
        n = len(self._d)
        self._d.clear()
        return n

    def stats(self) -> dict:
        return {"size": len(self._d), "cap": self.cap, "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "expirations": self.expirations}

    def reset_counters(self) -> None:
        """Zero the hit/miss/eviction/expiration counters; entries survive
        (the registry reset cascade zeroes accounting, not state)."""
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.expirations = 0
