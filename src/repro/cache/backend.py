"""CachingBackend: a Backend decorator that layers the serving caches over
any inner backend (LocalBackend, ShardedBackend, future remotes) without the
router or ServeEngine changing shape.

Layer placement follows the online pipeline (estimate -> route -> scan):

  * ``lookup_result``/``record_result`` -- the optional router hooks -- run
    the SemanticResultCache *before* estimation, so an exact-repeat
    (query, filter) pair skips the whole pipeline.
  * ``estimate`` runs the SelectivityCache keyed on canonical signatures and
    forwards only first-occurrence cache misses to the inner estimator.
  * ``search_brute`` runs the CandidateCache: a hit scans the cached
    matching-ID block (exact distances, identical results) instead of the
    corpus; admission is on the *second* brute miss of a signature so one-off
    filters never pay the O(N) extension computation.

Every call first syncs against ``inner.version()``.  Backends that expose
per-component epochs (``versions()`` -> vectors/attributes/graph, the live
index subsystem) get *scoped* invalidation: an attributes bump drops the
selectivity layer (the estimator sample changed), attributes|graph drops the
candidate layer (cached extensions describe stale base rows), and any bump
drops the semantic layer (final top-k results can shift under every mutation
class).  A vectors-only bump -- streaming upsert/delete, which never touches
the base arrays or the estimator sample -- therefore leaves the selectivity
and candidate layers warm: the candidate hit path composes the live state at
serve time (tombstoned base rows masked out, live delta rows folded in), so
warm blocks still produce exact results.  Backends without ``versions()``
fall back to the drop-everything epoch bump.

Tenant scoping: the backend declares ``scope_aware``, so ``router.execute``
attaches the per-request tenant/session scope ids (when the caller supplies
them) as a ``"scope"`` sidecar row on the stacked program dict.  The sidecar
is stripped before every inner (compiled) call -- device backends and their
warmed jit signatures never see it -- and consumed host-side: the semantic
and candidate layers key on (scope, signature), so one tenant's cached
results/ID blocks can never serve another, while the selectivity layer stays
global (p_hat is a property of the data, not of who asked).  ``scope_id``
interns tenant names -> dense ids (0 is the unscoped default); per-scope
hit/miss counters surface through ``cache_stats()``.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from ..core import batching
from ..core import filters as F
from ..core.options import CacheSpec, SearchOptions
from ..core.router import take_programs
from .layers import CandidateCache, SelectivityCache, SemanticResultCache
from .lru import LruTtlCache

_REJECTED = -1  # _brute_seen sentinel: signature failed candidate admission


def _corpus_view(inner):
    """Host-side (vectors, norms, ints, floats) of the inner backend's rows,
    or None when the backend does not expose its corpus (candidate layer
    then bypasses).  Row order matches the IDs the backend returns."""
    fi = getattr(inner, "index", None)           # LocalBackend -> FavorIndex
    if fi is not None:
        hx = fi.index
        return (np.asarray(hx.vectors, np.float32),
                np.asarray(hx.norms, np.float32),
                fi.attrs.ints, fi.attrs.floats)
    sharded = getattr(inner, "sharded", None)    # ShardedBackend
    if sharded is not None:
        a = sharded.arrays
        return (np.asarray(a["vectors"], np.float32),
                np.asarray(a["norms"], np.float32),
                a["attrs_int"], a["attrs_float"])
    return None


def _split_scope(programs: dict):
    """Split the host-side ``"scope"`` sidecar off a stacked program dict.

    Returns ``(inner_programs, scopes)`` where ``inner_programs`` carries
    only real program rows (safe for compiled inner calls -- attaching an
    extra pytree leaf would fork the warmed jit signatures) and ``scopes``
    is a host (B,) int array, or None when the batch is unscoped."""
    if "scope" not in programs:
        return programs, None
    inner = {k: v for k, v in programs.items() if k != "scope"}
    return inner, np.asarray(programs["scope"], np.int64)


class CachingBackend:
    """Wrap ``inner`` with the selectivity/candidate/semantic cache layers."""

    # router.execute attaches per-request tenant scopes only to backends
    # that declare they consume (and strip) the sidecar
    scope_aware = True

    def __init__(self, inner, spec: CacheSpec | None = None, *,
                 clock=time.monotonic):
        self.inner = inner
        self.spec = spec or CacheSpec()
        # every public entry point below is host-side (dict/LRU walks):
        # one reentrant lock makes lookups, admissions and epoch
        # invalidation safe under pipelined serving, where cache record
        # (step k, finish thread) and cache lookup (step k+1, dispatch
        # thread) would otherwise interleave mid-eviction.  Device work is
        # never awaited while holding it except on the brute miss path,
        # which the engine lock already serializes when driven through
        # ServeEngine.
        self._lock = threading.RLock()
        self.selectivity_cache = SelectivityCache(self.spec, clock)
        self.candidate_cache = CandidateCache(self.spec, clock)
        self.semantic_cache = SemanticResultCache(self.spec, clock)
        # signature -> brute-miss count; admission to the candidate cache
        # happens on the second miss (cache-on-re-reference)
        self._brute_seen = LruTtlCache(4 * self.spec.candidate_cap,
                                       self.spec.ttl_s, clock)
        # lazy: resolved on the first brute batch that can use it, so
        # wrapping a backend never materializes a corpus view it won't need
        self._corpus_view = None
        # signature memo keyed on program-array identity: router.execute
        # hands the *same* program-dict object to lookup_result, estimate
        # and record_result whenever the sub-batch is the whole batch, but
        # with bucket padding up to three distinct padded dicts (estimate,
        # graph, brute) sit between the first and last use of the original
        # -- four slots keep the full call chain memoized (the held
        # references keep the identity-keys valid)
        self._sig_memo: list = []
        self._epoch = inner.version()
        self._versions = self._inner_versions()
        self.invalidations = 0
        # tenant/session scope registry: name -> dense id (0 = unscoped);
        # the front-end interns its tenants here so scopes stay consistent
        # across every logical front-end sharing this backend
        self._scope_ids: dict[str, int] = {"": 0}
        # the live BatchSpec, captured in validate() (which router.execute
        # calls before every batch): the cache split re-introduces
        # data-dependent miss counts, so inner estimate/brute calls are
        # re-bucketed with the SAME ladder the caller padded (and warmup()
        # compiled) with -- a private default here would compile shapes
        # warmup never covered
        self._batch = None

    # -- Backend protocol (delegated identity) -------------------------------
    @property
    def schema(self) -> F.Schema:
        return self.inner.schema

    @property
    def sel_cfg(self):
        return self.inner.sel_cfg

    def validate(self, opts: SearchOptions) -> None:
        self._batch = opts.batch
        self.inner.validate(opts)

    def version(self) -> int:
        return self.inner.version()

    def scope_id(self, name) -> int:
        """Intern a tenant/session name to its dense scope id ("" -> 0)."""
        with self._lock:
            s = str(name)
            if s not in self._scope_ids:
                self._scope_ids[s] = len(self._scope_ids)
            return self._scope_ids[s]

    def __getattr__(self, name):
        # transparent decorator: anything outside the cache surface
        # (bytes_per_vector, mesh, index, ...) resolves on the inner backend
        if name.startswith("_"):
            raise AttributeError(name)
        return getattr(self.inner, name)

    # -- epoch invalidation ---------------------------------------------------
    def _corpus(self):
        """Host corpus view for the candidate layer (lazily resolved)."""
        if not self.spec.candidates:
            return None
        if self._corpus_view is None:
            self._corpus_view = _corpus_view(self.inner)
        return self._corpus_view

    def _inner_versions(self):
        """Per-component epochs of the inner backend, or None when it only
        reports an aggregate version (legacy clear-everything granularity)."""
        fn = getattr(self.inner, "versions", None)
        return dict(fn()) if fn is not None else None

    def _live_view(self):
        """The inner backend's (base_alive, delta) live state, or None for
        static backends / an inactive live path."""
        fn = getattr(self.inner, "live_view", None)
        return fn() if fn is not None else None

    def _sync_epoch(self) -> None:
        v = self.inner.version()
        if v == self._epoch:
            return
        self.invalidations += 1
        new = self._inner_versions()
        if new is None or self._versions is None:
            self.clear()
            self._corpus_view = None  # re-resolved on next use
        else:
            # scoped invalidation (see module docstring for the matrix)
            attrs_moved = new["attributes"] != self._versions["attributes"]
            graph_moved = new["graph"] != self._versions["graph"]
            if attrs_moved:
                self.selectivity_cache.clear()
            if attrs_moved or graph_moved:
                self.candidate_cache.clear()
                self._brute_seen.clear()
                self._corpus_view = None  # base arrays were rebuilt
            self.semantic_cache.clear()
        self._epoch = v
        self._versions = new

    def clear(self) -> None:
        """Drop every cached entry in all three layers (counters survive)."""
        with self._lock:
            self.selectivity_cache.clear()
            self.candidate_cache.clear()
            self.semantic_cache.clear()
            self._brute_seen.clear()
            self._sig_memo = []

    def reset_cache_counters(self) -> None:
        """Zero every layer's hit/miss/bypass/eviction counters and the
        invalidation count; entries, epochs and scope interning survive.
        ``ServeEngine.reset_stats()`` calls this through the metrics
        registry's reset cascade (the dual of ``clear()``, which drops
        entries but keeps counters)."""
        with self._lock:
            self.selectivity_cache.reset_counters()
            self.candidate_cache.reset_counters()
            self.semantic_cache.reset_counters()
            self.invalidations = 0

    def _signatures(self, programs: dict) -> list[str]:
        """Per-query canonical signatures, memoized on array identity."""
        vals = tuple(programs[k] for k in ("valid", "imask", "flo", "fhi"))
        for j, (prev, sigs) in enumerate(self._sig_memo):
            if len(prev) == len(vals) and all(a is b for a, b in
                                              zip(prev, vals)):
                if j:
                    self._sig_memo.insert(0, self._sig_memo.pop(j))
                return sigs
        sigs = F.batch_signatures(programs)
        self._sig_memo.insert(0, (vals, sigs))
        del self._sig_memo[4:]
        return sigs

    # -- semantic layer: router fast-path hooks -------------------------------
    def lookup_result(self, queries: np.ndarray, programs: dict,
                      opts: SearchOptions):
        """Optional router hook: per-query semantic hits for the batch, or
        None when the layer is disabled / nothing hit."""
        with self._lock:
            return self._lookup_result(queries, programs, opts)

    def _lookup_result(self, queries, programs, opts):
        self._sync_epoch()
        if not self.semantic_cache.enabled:
            return None
        programs, scopes = _split_scope(programs)
        queries = np.asarray(queries, np.float32)
        sigs = self._signatures(programs)
        hit = np.zeros((len(sigs),), bool)
        rows = []
        for i, sig in enumerate(sigs):
            scope = int(scopes[i]) if scopes is not None else 0
            e = self.semantic_cache.get(sig, opts, queries[i], scope=scope)
            if e is not None:
                hit[i] = True
                rows.append(e)
        if not rows:
            return None
        return {
            "hit": hit,
            "ids": np.stack([e.ids for e in rows]),
            "dists": np.stack([e.dists for e in rows]),
            "p_hat": np.asarray([e.p_hat for e in rows], np.float32),
            "routed_brute": np.asarray([e.routed_brute for e in rows], bool),
        }

    def record_result(self, queries: np.ndarray, programs: dict,
                      opts: SearchOptions, ids, dists, p_hat,
                      routed_brute) -> None:
        """Optional router hook: store freshly computed per-query results."""
        with self._lock:
            self._record_result(queries, programs, opts, ids, dists, p_hat,
                                routed_brute)

    def _record_result(self, queries, programs, opts, ids, dists, p_hat,
                       routed_brute):
        if not self.semantic_cache.enabled:
            return
        programs, scopes = _split_scope(programs)
        queries = np.asarray(queries, np.float32)
        sigs = self._signatures(programs)
        ids = np.asarray(ids)
        dists = np.asarray(dists)
        p_hat = np.asarray(p_hat)
        routed_brute = np.asarray(routed_brute)
        for i, sig in enumerate(sigs):
            scope = int(scopes[i]) if scopes is not None else 0
            self.semantic_cache.put(sig, opts, queries[i], ids[i], dists[i],
                                    float(p_hat[i]), bool(routed_brute[i]),
                                    scope=scope)

    # -- selectivity layer ----------------------------------------------------
    def estimate(self, programs: dict, valid=None):
        with self._lock:
            return self._estimate(programs, valid)

    def _estimate(self, programs, valid=None):
        self._sync_epoch()
        # the selectivity layer is scope-blind (p_hat is data, not tenant);
        # the sidecar is stripped so inner compiled calls never see it
        programs, _ = _split_scope(programs)
        sigs = self._signatures(programs)
        b = len(sigs)
        # pad rows (valid False) never touch the cache: no phantom
        # always-false entries, no inflated hit/miss counters (same
        # hygiene as search_brute); their p_hat is 0, sliced off upstream
        real = (range(b) if valid is None
                else np.nonzero(np.asarray(valid, bool))[0])
        p_hat = np.zeros((b,), np.float32)
        first_row: dict[str, int] = {}   # sig -> first miss row
        for i in real:
            cached = self.selectivity_cache.get(sigs[i])
            if cached is not None:
                p_hat[i] = cached
            elif sigs[i] not in first_row:
                first_row[sigs[i]] = int(i)
        if first_row:
            rows = np.asarray(sorted(first_row.values()), np.int64)
            sub = take_programs(programs, rows)
            if self._batch is None:
                fresh = np.asarray(self.inner.estimate(sub), np.float32)
            else:
                sub, sub_valid = batching.pad_programs(self._batch, sub)
                fresh = np.asarray(self.inner.estimate(sub, valid=sub_valid),
                                   np.float32)[:len(rows)]
            by_sig = {sigs[r]: fresh[j] for j, r in enumerate(rows)}
            for sig, p in by_sig.items():
                self.selectivity_cache.put(sig, float(p))
            for i in real:
                if sigs[i] in by_sig:
                    p_hat[i] = by_sig[sigs[i]]
        return p_hat

    # -- graph route: pass-through --------------------------------------------
    def search_graph(self, queries, programs: dict, p_hat,
                     opts: SearchOptions, valid=None) -> dict:
        with self._lock:
            self._sync_epoch()
            programs, _ = _split_scope(programs)
        # pass-through dispatch needs no cache state: drop the lock first
        return self.inner.search_graph(queries, programs, p_hat, opts,
                                       valid=valid)

    # -- candidate layer: brute route -----------------------------------------
    def _extension(self, programs: dict, row: int) -> np.ndarray:
        """Exact matching-ID set of one program row over the full corpus."""
        _, _, ints, floats = self._corpus()
        prog = {k: np.asarray(v)[row] for k, v in programs.items()}
        mask = F.eval_program(prog, ints, floats)
        return np.nonzero(mask)[0].astype(np.int64)

    def _delta_extension(self, delta, programs: dict, row: int):
        """Live delta rows matching one program row, as (ids, vectors,
        norms) ready to fold into a candidate block -- None when the delta
        contributes nothing (empty, all dead, or no row matches)."""
        cnt = delta.count
        if delta.live_count == 0:
            return None
        prog = {k: np.asarray(v)[row] for k, v in programs.items()}
        m = np.asarray(F.eval_program(prog, delta.ints[:cnt],
                                      delta.floats[:cnt]), bool)
        m &= delta.alive[:cnt]
        slots = np.nonzero(m)[0]
        if not len(slots):
            return None
        return (delta.ids[slots], delta.vectors[slots], delta.norms[slots])

    def _scan_block(self, queries: np.ndarray, cand: np.ndarray, k: int,
                    extra=None):
        """Exact top-k of ``queries`` over the candidate rows: the same
        qn + vn - 2*q.v distance the PreFBF scan computes, restricted to the
        predicate's true extension (so results match the full scan).
        ``extra`` -- (ids, vectors, norms) of matching live delta rows --
        extends the block with out-of-base rows at their global ids."""
        vectors, norms, _, _ = self._corpus()
        v = vectors[cand]                      # (C, d)
        vn = norms[cand]                       # (C,)
        id_map = cand
        if extra is not None:
            eids, ev, en = extra
            v = np.concatenate([v, ev], axis=0)
            vn = np.concatenate([vn, en])
            id_map = np.concatenate([cand, eids])
        qn = np.einsum("bd,bd->b", queries, queries).astype(np.float32)
        d2 = qn[:, None] + vn[None, :] - 2.0 * (queries @ v.T)
        dist = np.sqrt(np.maximum(d2, 0.0), dtype=np.float32)
        c = dist.shape[1]
        ids = np.full((len(queries), k), -1, np.int64)
        out = np.full((len(queries), k), np.inf, np.float32)
        kk = min(k, c)
        if kk:  # an always-false predicate has an empty (legal) extension
            part = np.argpartition(dist, kk - 1, axis=1)[:, :kk]
            pd = np.take_along_axis(dist, part, axis=1)
            order = np.argsort(pd, axis=1, kind="stable")
            ids[:, :kk] = id_map[np.take_along_axis(part, order, axis=1)]
            out[:, :kk] = np.take_along_axis(pd, order, axis=1)
        return ids, out

    def _inner_brute(self, queries_np, programs: dict, rows,
                     opts: SearchOptions):
        """Run the inner brute scan on a row subset, re-bucketing the
        sub-batch when ``opts.batch`` is set: the cache split re-introduces
        data-dependent miss counts, so shape stability must be restored
        before the (compiled) inner call."""
        sub_q = queries_np[rows]
        sub_p = take_programs(programs, rows)
        if opts.batch is None:
            mid, md = self.inner.search_brute(sub_q, sub_p, opts)
        else:
            sub_q, sub_p, _, sub_valid = batching.pad_to_bucket(
                opts.batch, sub_q, sub_p)
            mid, md = self.inner.search_brute(sub_q, sub_p, opts,
                                              valid=sub_valid)
        return np.asarray(mid)[:len(rows)], np.asarray(md)[:len(rows)]

    def search_brute(self, queries, programs: dict, opts: SearchOptions,
                     valid=None):
        with self._lock:
            return self._search_brute(queries, programs, opts, valid)

    def _search_brute(self, queries, programs, opts, valid=None):
        self._sync_epoch()
        programs, scopes = _split_scope(programs)
        b = int(queries.shape[0])
        # this layer is host-side: pad rows (valid False) are dropped here
        # and the inner compiled call is re-bucketed in _inner_brute, so
        # they never pollute signatures, counters or admission
        real = (np.arange(b) if valid is None
                else np.nonzero(np.asarray(valid, bool))[0])
        # a compressed (ADC) scan is not the exact-distance computation the
        # candidate block runs, so use_pq bypasses this layer entirely
        serveable = (self.candidate_cache.enabled and not opts.use_pq
                     and self._corpus() is not None)
        if not serveable:
            if self.candidate_cache.enabled:
                self.candidate_cache.bypasses += int(len(real))
            return self.inner.search_brute(queries, programs, opts,
                                           valid=valid)

        queries_np = np.asarray(queries, np.float32)
        sigs = self._signatures(programs)
        scope_of = (lambda i: int(scopes[i])) if scopes is not None \
            else (lambda i: 0)
        ids = np.full((b, opts.k), -1, np.int64)
        dists = np.full((b, opts.k), np.inf, np.float32)

        # candidate bookkeeping is keyed on (scope, signature): blocks
        # cached by one tenant never serve another, per the isolation
        # contract (the extension itself is tenant-independent, so the
        # cost of isolation is duplicate entries, not wrong results)
        hit_rows: dict[tuple, list[int]] = {}
        blocks: dict[tuple, np.ndarray] = {}
        miss: list[int] = []
        for i in real:
            skey = (scope_of(i), sigs[i])
            # one get() per ROW (not per unique signature) so the reported
            # hit/miss counters reflect served lookups, not distinct keys
            cand = self.candidate_cache.get(sigs[i], scope=skey[0])
            if cand is None:
                miss.append(int(i))
                continue
            blocks[skey] = cand
            hit_rows.setdefault(skey, []).append(int(i))

        lv = self._live_view() if hit_rows else None
        for skey, rows in hit_rows.items():
            # compose the live state over the cached base extension: dead
            # base rows drop out, matching live delta rows join at their
            # global ids -- warm blocks stay exact under streaming mutation
            cand = blocks[skey]
            extra = None
            if lv is not None:
                if lv.base_alive is not None:
                    cand = cand[lv.base_alive[cand]]
                extra = self._delta_extension(lv.delta, programs, rows[0])
                if lv.base_alive is not None or extra is not None:
                    self.candidate_cache.composed += len(rows)
            rid, rd = self._scan_block(queries_np[rows], cand, opts.k,
                                       extra=extra)
            ids[rows] = rid
            dists[rows] = rd

        if miss:
            rows = np.asarray(miss, np.int64)
            mid, md = self._inner_brute(queries_np, programs, rows, opts)
            ids[rows] = mid
            dists[rows] = md
            n_rows = self._corpus()[0].shape[0]
            miss_first: dict[tuple, int] = {}  # one reference per key per batch
            for i in miss:
                miss_first.setdefault((scope_of(i), sigs[i]), i)
            for (scope, sig), i in miss_first.items():
                seen = self._brute_seen.get((scope, sig), 0)
                if seen == _REJECTED:
                    continue  # known-ineligible: never recompute extensions
                self._brute_seen.put((scope, sig), seen + 1)
                if seen < 1:
                    continue  # first miss: one-off filters stay free
                # second miss: admit.  A cached estimate far above the
                # admission bound rejects without the O(N) extension pass
                # (2x slack absorbs sample-estimator error)
                p_est = self.selectivity_cache.peek(sig)
                if p_est is not None and p_est > 2.0 * self.candidate_cache.p_max:
                    self._brute_seen.put((scope, sig), _REJECTED)
                    self.candidate_cache.bypasses += 1
                    continue
                if not self.candidate_cache.admit(
                        sig, self._extension(programs, i), n_rows,
                        scope=scope):
                    self._brute_seen.put((scope, sig), _REJECTED)
        return ids, dists

    # -- accounting -----------------------------------------------------------
    def cache_stats(self) -> dict:
        """Per-layer hit/miss/bypass counters (surfaced by ServeEngine)."""
        with self._lock:
            return self._cache_stats()

    def _cache_stats(self) -> dict:
        out = {
            "selectivity": self.selectivity_cache.stats(),
            "candidates": self.candidate_cache.stats(),
            "semantic": self.semantic_cache.stats(),
            "epoch": self._epoch,
            "versions": dict(self._versions) if self._versions else None,
            "invalidations": self.invalidations,
            "scopes": dict(self._scope_ids),
        }
        for layer in ("selectivity", "candidates", "semantic"):
            st = out[layer]
            asked = st["hits"] + st["misses"]
            st["hit_rate"] = st["hits"] / asked if asked else 0.0
        return out
