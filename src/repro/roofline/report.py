"""Render dryrun_results.json into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import json
import sys

from . import hw


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def fmt_t(x) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1.0:
        return f"{x*1e3:.2f}ms"
    return f"{x:.3f}s"


def table(results: list[dict], mesh: str) -> str:
    rows = []
    head = ("| arch | shape | t_compute | t_memory | t_collective | bound | "
            "model TF | useful% | roofline% | mem/dev |")
    sep = "|" + "---|" * 10
    rows.append(head)
    rows.append(sep)
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        if r.get("skipped"):
            rows.append(f"| {r['arch']} | {r['shape']} | skip | skip | skip | "
                        f"- | - | - | - | - |")
            continue
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | | |")
            continue
        ro = r["roofline"]
        mem = r.get("memory", {})
        dev_mem = (mem.get("argument_size_in_bytes", 0) +
                   mem.get("temp_size_in_bytes", 0))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_t(ro['t_compute_s'])} | "
            f"{fmt_t(ro['t_memory_s'])} | {fmt_t(ro['t_collective_s'])} | "
            f"{ro['bottleneck']} | {ro['model_flops']/1e12:.1f} | "
            f"{100*ro['useful_flops_frac']:.1f} | "
            f"{100*ro['roofline_frac']:.2f} | {fmt_bytes(dev_mem)} |")
    return "\n".join(rows)


def collective_summary(results: list[dict], mesh: str) -> str:
    rows = ["| arch | shape | collectives (count) | link bytes/dev |",
            "|---|---|---|---|"]
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh or not r.get("ok") or r.get("skipped"):
            continue
        ro = r["roofline"]
        cc = ro["collectives"]["counts"]
        cs = " ".join(f"{k}:{v}" for k, v in sorted(cc.items())) or "none"
        rows.append(f"| {r['arch']} | {r['shape']} | {cs} | "
                    f"{fmt_bytes(ro['coll_link_bytes'])} |")
    return "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        results = json.load(f)
    for mesh in ("16x16", "2x16x16"):
        n_ok = sum(1 for r in results if r["mesh"] == mesh and r.get("ok"))
        n = sum(1 for r in results if r["mesh"] == mesh)
        print(f"\n## Roofline -- mesh {mesh} ({n_ok}/{n} cells ok)\n")
        print(table(results, mesh))
    print("\n## Collective schedule (single-pod)\n")
    print(collective_summary(results, "16x16"))


if __name__ == "__main__":
    main()
