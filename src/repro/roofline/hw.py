"""TPU v5e hardware constants (the TARGET machine; this container is CPU)."""

PEAK_FLOPS_BF16 = 197e12      # per chip, bf16
HBM_BW = 819e9                # bytes/s per chip
ICI_LINK_BW = 50e9            # bytes/s per link (~ both directions usable)

CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
HBM_PER_CHIP = 16 * 2**30     # 16 GiB
