"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md section
Roofline).

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs / (peak_FLOP/s)            [per-chip]
    memory     = HLO_bytes / HBM_bw                   [per-chip]
    collective = collective_link_bytes / ICI_link_bw  [per-chip]

``compiled.cost_analysis()`` supplies per-device FLOPs / bytes accessed
(XLA compiles the per-device SPMD module).  Collective bytes are NOT in
cost_analysis: we parse the optimized HLO text and sum, per collective op,
the link bytes under ring algorithms:

    all-reduce      2 * (g-1)/g * bytes(operand)
    all-gather      (g-1)/g * bytes(result)
    reduce-scatter  (g-1)/g * bytes(operand)
    all-to-all      (g-1)/g * bytes(operand)
    collective-permute  bytes(operand)

with g the replica-group size parsed from the op's replica_groups.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

import numpy as np

from . import hw

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{.*?\}\}|\[\d+,\d+\]<=\[\d+\])")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape in a fragment (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(attr: str | None, default: int) -> int:
    if not attr:
        return default
    if attr.startswith("[{") or attr.startswith("{{"):
        first = attr.split("}")[0]
        return max(1, first.count(",") + 1)
    m = re.match(r"\[(\d+),(\d+)\]<=\[(\d+)\]", attr)
    if m:
        return int(m.group(2))
    return default


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)
    link_bytes: float = 0.0
    raw_bytes: float = 0.0
    by_op: dict = field(default_factory=dict)


def parse_collectives(hlo_text: str, n_devices: int,
                      loop_multipliers: dict | None = None) -> CollectiveStats:
    """Scan optimized HLO for collectives; returns per-device link bytes.

    Optimized-HLO lines print only the RESULT shape inline, so link bytes are
    derived from the output:  all-reduce/all-to-all/permute outputs equal the
    operand, all-gather outputs are the gathered (g x) tensor, reduce-scatter
    outputs are the scattered (1/g) tensor.  Substring matching (no complex
    regex: HLO lines are megabytes and catastrophic backtracking is real).
    """
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        op = None
        for cand in _OPS:
            i = line.find(" " + cand)
            if i >= 0:
                nxt = line[i + 1 + len(cand):]
                if nxt.startswith("(") or nxt.startswith("-start("):
                    op = cand
                    break
        if op is None:
            continue
        line = line.strip()
        lhs = line.split(" = ", 1)
        if len(lhs) != 2:
            continue
        # result may be a bare shape `f32[...] all-reduce(` or a TUPLE
        # `(f32[...], f32[...]) all-reduce(` -- take everything left of the op
        out_b = _shape_bytes(lhs[1].split(" " + op, 1)[0])
        if not out_b:
            continue
        gm = _GROUPS_RE.search(line)
        g = _group_size(gm.group(1) if gm else None, n_devices)
        if g <= 1:
            continue
        frac = (g - 1) / g
        if op == "all-reduce":
            link = 2.0 * frac * out_b
            raw = out_b
        elif op == "all-gather":
            link = frac * out_b           # operand = out/g; ring moves (g-1)/g out
            raw = out_b / g
        elif op == "reduce-scatter":
            link = (g - 1) * out_b        # operand = out*g
            raw = out_b * g
        elif op == "all-to-all":
            link = frac * out_b
            raw = out_b
        else:  # collective-permute
            link = float(out_b)
            raw = out_b
        st.counts[op] = st.counts.get(op, 0) + 1
        st.by_op[op] = st.by_op.get(op, 0.0) + link
        st.link_bytes += link
        st.raw_bytes += raw
    return st


@dataclass
class Roofline:
    flops: float                # per-device HLO flops
    hbm_bytes: float            # per-device bytes accessed
    coll_link_bytes: float      # per-device collective link bytes
    n_devices: int
    collectives: dict
    model_flops: float = 0.0    # 6ND (train) / 2ND (inference), GLOBAL

    @property
    def t_compute(self) -> float:
        return self.flops / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_link_bytes / hw.ICI_LINK_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (per-device HLO flops x devices)."""
        total = self.flops * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def roofline_frac(self) -> float:
        """Fraction of the dominant-term bound that is useful model compute:
        (model_flops / chips / peak) / max(t_compute, t_memory, t_coll)."""
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        if t_bound <= 0 or self.model_flops <= 0:
            return 0.0
        t_ideal = self.model_flops / self.n_devices / hw.PEAK_FLOPS_BF16
        return t_ideal / t_bound

    def to_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops, "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_link_bytes": self.coll_link_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
            "collectives": self.collectives,
        }


def analyze(compiled, n_devices: int, model_flops: float = 0.0,
            hlo_text: str | None = None) -> Roofline:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text, n_devices)
    return Roofline(flops=flops, hbm_bytes=byts,
                    coll_link_bytes=coll.link_bytes, n_devices=n_devices,
                    collectives={"counts": coll.counts, "by_op": coll.by_op},
                    model_flops=model_flops)


def memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out
