"""Scoped data epochs: per-component version counters for live indexes.

``Backend.version()`` stays a single monotonic int (the aggregate), but live
backends additionally expose ``versions()`` -> one counter per component so
cache layers can invalidate only what a mutation actually touched:

  vectors    -- the set of live rows changed (delta append, tombstone).  The
                base arrays themselves are untouched; caches that compose
                the delta/tombstones at serve time may keep their entries.
  attributes -- estimator-visible attribute data changed (attribute rewrite,
                resample).  Selectivity estimates are stale.
  graph      -- the base index arrays were rebuilt (merge, reshard): any
                cached view of base rows is stale.

A vector-only upsert bumps ``vectors`` alone, which is exactly what lets the
selectivity cache stay warm across streaming ingestion (the estimator runs
over a fixed build-time sample that appends and tombstones do not touch).
"""
from __future__ import annotations

COMPONENTS = ("vectors", "attributes", "graph")


class ComponentEpochs:
    """Monotonic per-component counters; ``total`` is the legacy aggregate."""

    __slots__ = ("vectors", "attributes", "graph")

    def __init__(self, vectors: int = 0, attributes: int = 0, graph: int = 0):
        self.vectors = int(vectors)
        self.attributes = int(attributes)
        self.graph = int(graph)

    @property
    def total(self) -> int:
        """Aggregate epoch: any component bump changes it, so component-blind
        consumers of ``version()`` still invalidate correctly (just more
        often than they need to)."""
        return self.vectors + self.attributes + self.graph

    def bump(self, *components: str) -> int:
        for c in components:
            if c not in COMPONENTS:
                raise ValueError(f"unknown epoch component {c!r}; "
                                 f"expected one of {COMPONENTS}")
            setattr(self, c, getattr(self, c) + 1)
        return self.total

    def bump_all(self) -> int:
        return self.bump(*COMPONENTS)

    def as_dict(self) -> dict:
        return {c: getattr(self, c) for c in COMPONENTS}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"ComponentEpochs(vectors={self.vectors}, "
                f"attributes={self.attributes}, graph={self.graph})")
