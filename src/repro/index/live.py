"""LiveState: the shared mutation state machine behind every live backend.

Owns id allocation, the base-row tombstone mask and the DeltaSegment; the
local and sharded backends both delegate here and only differ in how they
thread the resulting tombstones onto their device arrays (+inf norms for the
brute scans, an ``alive`` mask for the graph traversal).

ID semantics (positional-id discipline): search results identify rows by
position, so ids ARE row positions.  A fresh upsert gets
``id = base_n + delta_slot``, which is exactly the row the slot lands on
when ``merge()`` appends delta slots to the base in order -- merge never
renumbers a surviving row.  Replacing an existing id therefore *retires* it
(the old row is tombstoned) and issues a fresh id for the new row; callers
get the new handles back from ``upsert``.

``LiveView`` is the host-side read view cache layers use to compose
tombstones and delta rows onto cached candidate blocks at serve time.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .delta import DeltaSegment


@dataclass
class LiveView:
    """Host-side view of the mutation state (for cache-layer composition)."""
    base_n: int
    base_alive: np.ndarray | None   # (base_n,) bool; None -> no tombstones
    delta: DeltaSegment


class LiveState:
    """Tombstones + delta + id allocation over a base index of ``base_n``
    rows.  Pure host state; device threading is the owning backend's job."""

    def __init__(self, base_n: int, dim: int, m_i: int, m_f: int):
        self.base_n = int(base_n)
        self.delta = DeltaSegment(dim, m_i, m_f)
        self.base_alive: np.ndarray | None = None   # lazy: None == all alive
        self.counters = {"upserts": 0, "deletes": 0, "replaced": 0,
                         "missing_deletes": 0}

    # -- helpers --------------------------------------------------------------
    def _base_mask(self) -> np.ndarray:
        if self.base_alive is None:
            self.base_alive = np.ones((self.base_n,), bool)
        return self.base_alive

    def _retire(self, id_: int) -> tuple[bool, int]:
        """Tombstone one live id; returns (found, base_row | -1)."""
        id_ = int(id_)
        if self.delta.kill(id_):
            return True, -1
        if 0 <= id_ < self.base_n:
            mask = self._base_mask()
            if mask[id_]:
                mask[id_] = False
                return True, id_
        return False, -1

    # -- mutation API ---------------------------------------------------------
    def upsert(self, vectors: np.ndarray, ints, floats,
               replace=None) -> tuple[np.ndarray, np.ndarray]:
        """Append rows; optionally retire ``replace`` ids first.

        Returns (fresh ids (B,) int64, newly-dead base rows (m,) int64).
        """
        vectors = np.ascontiguousarray(vectors, np.float32)
        b = vectors.shape[0]
        dead_base: list[int] = []
        if replace is not None:
            replace = np.atleast_1d(np.asarray(replace, np.int64))
            if replace.shape[0] != b:
                raise ValueError(f"replace must name one id per row: got "
                                 f"{replace.shape[0]} ids for {b} rows")
            for r in replace:
                found, row = self._retire(r)
                if found:
                    self.counters["replaced"] += 1
                    if row >= 0:
                        dead_base.append(row)
        ids = self.base_n + self.delta.append(
            vectors,
            np.zeros((b, self.delta.m_i), np.int32) if ints is None else ints,
            np.zeros((b, self.delta.m_f), np.float32) if floats is None
            else floats,
            self.base_n + np.arange(self.delta.count,
                                    self.delta.count + b, dtype=np.int64))
        self.counters["upserts"] += b
        return ids.astype(np.int64), np.asarray(dead_base, np.int64)

    def delete(self, ids) -> tuple[int, np.ndarray]:
        """Tombstone ids; returns (found count, newly-dead base rows)."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        dead_base: list[int] = []
        n = 0
        for i in ids:
            found, row = self._retire(i)
            if found:
                n += 1
                if row >= 0:
                    dead_base.append(row)
            else:
                self.counters["missing_deletes"] += 1
        self.counters["deletes"] += n
        return n, np.asarray(dead_base, np.int64)

    # -- merge support --------------------------------------------------------
    def merged_alive(self) -> np.ndarray:
        """(base_n + delta.count,) alive mask of the post-merge index (delta
        slots appended in order; dead slots carried as tombstoned rows)."""
        base = (self.base_alive if self.base_alive is not None
                else np.ones((self.base_n,), bool))
        return np.concatenate([base, self.delta.alive[: self.delta.count]])

    def reset_after_merge(self, new_base_n: int,
                          new_alive: np.ndarray | None, *,
                          from_slot: int | None = None) -> None:
        """Fold-complete: the delta is now part of the base.  Cumulative
        counters survive; id allocation continues from the new row count.

        ``from_slot`` supports background merges: the merge built from a
        snapshot of the first ``from_slot`` delta slots, so slots that
        arrived during the build carry into the fresh delta with their OLD
        ids.  The positional-id invariant keeps those ids valid: a carried
        slot ``s`` had ``id = old_base_n + s``, and since the merge appended
        exactly ``from_slot`` rows (``new_base_n = old_base_n + from_slot``),
        that id equals ``new_base_n + (s - from_slot)`` -- exactly its slot
        in the fresh segment.  Slots that died mid-build are re-killed so
        they stay positional tombstones."""
        old = self.delta
        self.base_n = int(new_base_n)
        self.base_alive = (None if new_alive is None
                           else np.asarray(new_alive, bool).copy())
        fresh = DeltaSegment(old.dim, old.m_i, old.m_f)
        if from_slot is not None and int(from_slot) < old.count:
            sl = slice(int(from_slot), old.count)
            fresh.append(old.vectors[sl], old.ints[sl], old.floats[sl],
                         old.ids[sl])
            for s in range(int(from_slot), old.count):
                if not old.alive[s]:
                    fresh.kill(int(old.ids[s]))
        self.delta = fresh

    # -- read views -----------------------------------------------------------
    def view(self) -> LiveView:
        return LiveView(self.base_n, self.base_alive, self.delta)

    @property
    def has_tombstones(self) -> bool:
        return self.base_alive is not None and not self.base_alive.all()

    def stats(self) -> dict:
        dead_base = (0 if self.base_alive is None
                     else int((~self.base_alive).sum()))
        return {"base_rows": self.base_n, "dead_base_rows": dead_base,
                "delta_rows": self.delta.live_count,
                "delta_slots": self.delta.count, **self.counters}
