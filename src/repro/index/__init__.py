"""Live-index mutation subsystem: streaming upsert/delete over a built index.

The serving index stays a *static* artifact (the HNSW arrays + padded scan
arrays uploaded once); mutations accumulate beside it in three small pieces
that every query path composes at serve time:

  DeltaSegment     -- append-only buffer of fresh rows, brute-scanned per
                      query (exact f32 PreFBF over a pow-2-padded buffer)
                      and top-k-merged into every route's results.
  tombstones       -- a base-row alive bitmask threaded through the existing
                      +inf-norm / validity-mask plumbing, so dead ids never
                      surface from the graph, brute or cache paths.
  ComponentEpochs  -- scoped version counters (vectors / attributes / graph)
                      so layered caches invalidate surgically instead of
                      dropping everything on any change.

``merge()`` (index.bulk) folds the delta back into the HNSW with a
device-parallel bulk build, returning the index to the static fast path.
IDs are dense row positions: a replaced row retires its id and the new row
gets a fresh one, so merge never renumbers surviving rows.
"""
from .bulk import build_hnsw_bulk, bulk_add
from .delta import DeltaSegment, compose_topk
from .epochs import COMPONENTS, ComponentEpochs
from .live import LiveState, LiveView

__all__ = ["DeltaSegment", "compose_topk", "ComponentEpochs", "COMPONENTS",
           "LiveState", "LiveView", "bulk_add", "build_hnsw_bulk"]
