"""DeltaSegment: the append-only buffer fresh rows land in before a merge.

Freshly upserted vectors+attributes are held host-side in growable arrays
and mirrored to the device as a pow-2-capacity padded block (the same
bounded-compile-shapes discipline as the serving bucket ladder: the jitted
scan recompiles only on capacity doubling, never per append).  Per query the
segment is brute-scanned with the existing PreFBF machinery -- exact float32
always, even when the base route streams PQ/SQ codes: the delta is small, so
exactness there costs nothing and only sharpens the compressed route.

Dead slots (a delta row replaced or deleted before it was merged) and unused
capacity reuse the padded-row convention end to end: +inf norms make their
distance +inf, so they can never win a top-k slot -- no kernel or scan
changes, no compaction.

``compose_topk`` is the host-side sort-merge every backend uses to fold
base-index results and delta results into one (ids, dists) answer.  The
stable sort prefers base rows on exact ties, keeping composition
deterministic across runs.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from ..core import prefbf

_MIN_CAPACITY = 64


def compose_topk(base_ids: np.ndarray, base_d: np.ndarray,
                 extra_ids: np.ndarray, extra_d: np.ndarray,
                 k: int) -> tuple[np.ndarray, np.ndarray]:
    """Merge two (B, *) id/dist blocks into the global top-k (B, k).

    Missing entries follow the SearchResult contract (-1 / +inf) on both
    inputs and the output; ids come back int64.
    """
    ids = np.concatenate([np.asarray(base_ids, np.int64),
                          np.asarray(extra_ids, np.int64)], axis=1)
    d = np.concatenate([np.asarray(base_d, np.float32),
                        np.asarray(extra_d, np.float32)], axis=1)
    order = np.argsort(d, axis=1, kind="stable")[:, :k]
    out_d = np.take_along_axis(d, order, axis=1)
    out_i = np.take_along_axis(ids, order, axis=1)
    return np.where(np.isfinite(out_d), out_i, -1), out_d


def compose_topk_dev(base_ids, base_d, extra_ids, extra_d, k: int):
    """Device-side ``compose_topk``: same stable sort-merge, but on jnp
    arrays so the composition rides JAX async dispatch instead of forcing a
    host sync mid-step.  ``jnp.argsort`` is stable by default, so base rows
    win exact ties just like the host path; the caller converts to int64 at
    the final host transfer.  Returns (ids (B, k) int32, dists (B, k) f32).
    """
    ids = jnp.concatenate([jnp.asarray(base_ids, jnp.int32),
                           jnp.asarray(extra_ids, jnp.int32)], axis=1)
    d = jnp.concatenate([jnp.asarray(base_d, jnp.float32),
                         jnp.asarray(extra_d, jnp.float32)], axis=1)
    order = jnp.argsort(d, axis=1)[:, :k]
    out_d = jnp.take_along_axis(d, order, axis=1)
    out_i = jnp.take_along_axis(ids, order, axis=1)
    return jnp.where(jnp.isfinite(out_d), out_i, -1), out_d


class DeltaSegment:
    """Append-only (vectors, attributes, global ids) buffer with an alive
    mask, scannable on device.

    Slots are never reused or compacted: a slot's position is stable for the
    segment's lifetime, which is what lets ``merge()`` append slots to the
    base index *in slot order* and keep every live row's global id equal to
    its final row position (ids are positional in this system).
    """

    def __init__(self, dim: int, m_i: int, m_f: int,
                 min_capacity: int = _MIN_CAPACITY):
        self.dim = int(dim)
        self.m_i = int(m_i)
        self.m_f = int(m_f)
        self.count = 0        # slots used (live + dead)
        self.live_count = 0
        self._cap = 0
        self._min_cap = max(1, int(min_capacity))
        self.vectors = np.zeros((0, self.dim), np.float32)
        self.norms = np.zeros((0,), np.float32)
        self.ints = np.zeros((0, self.m_i), np.int32)
        self.floats = np.zeros((0, self.m_f), np.float32)
        self.ids = np.full((0,), -1, np.int64)
        self.alive = np.zeros((0,), bool)
        self._slot_of: dict[int, int] = {}   # live id -> slot
        self._dev = None                     # cached padded device arrays

    # -- capacity -------------------------------------------------------------
    def _grow(self, need: int) -> None:
        cap = max(self._cap, self._min_cap)
        while cap < need:
            cap *= 2
        if cap == self._cap:
            return

        def ext(a, fill, shape_tail=()):
            out = np.full((cap, *shape_tail), fill, a.dtype)
            out[: self.count] = a[: self.count]
            return out

        self.vectors = ext(self.vectors, 0.0, (self.dim,))
        self.norms = ext(self.norms, 0.0)
        self.ints = ext(self.ints, -1, (self.m_i,))
        self.floats = ext(self.floats, np.nan, (self.m_f,))
        self.ids = ext(self.ids, -1)
        self.alive = ext(self.alive, False)
        self._cap = cap

    # -- mutation -------------------------------------------------------------
    def append(self, vectors: np.ndarray, ints: np.ndarray,
               floats: np.ndarray, ids: np.ndarray) -> np.ndarray:
        """Append rows (already carrying their global ids); returns slots."""
        vectors = np.ascontiguousarray(vectors, np.float32)
        b = vectors.shape[0]
        if vectors.shape[1] != self.dim:
            raise ValueError(f"delta rows must be dim={self.dim}, "
                             f"got {vectors.shape[1]}")
        self._grow(self.count + b)
        sl = np.arange(self.count, self.count + b)
        self.vectors[sl] = vectors
        self.norms[sl] = np.einsum("nd,nd->n", vectors, vectors)
        self.ints[sl] = np.asarray(ints, np.int32).reshape(b, self.m_i)
        self.floats[sl] = np.asarray(floats, np.float32).reshape(b, self.m_f)
        self.ids[sl] = np.asarray(ids, np.int64)
        self.alive[sl] = True
        for s, i in zip(sl, np.asarray(ids, np.int64)):
            self._slot_of[int(i)] = int(s)
        self.count += b
        self.live_count += b
        self._dev = None
        return sl

    def kill(self, id_: int) -> bool:
        """Tombstone a live delta row by global id (no compaction)."""
        slot = self._slot_of.pop(int(id_), None)
        if slot is None:
            return False
        self.alive[slot] = False
        self.live_count -= 1
        self._dev = None
        return True

    def has(self, id_: int) -> bool:
        return int(id_) in self._slot_of

    # -- device scan ----------------------------------------------------------
    def _device_view(self) -> dict:
        """Padded device mirror, rebuilt lazily after any mutation.  Norms of
        dead and unused slots are +inf (the padded-row convention), so one
        where() is the whole tombstone mechanism for this buffer."""
        if self._dev is None:
            cap = max(self._cap, self._min_cap)
            self._grow(cap)
            norms = np.where(self.alive, self.norms, np.inf).astype(np.float32)
            self._dev = {
                "vectors": jnp.asarray(self.vectors),
                "norms": jnp.asarray(norms),
                "ints": jnp.asarray(self.ints),
                "floats": jnp.asarray(self.floats),
                "ids": jnp.asarray(self.ids.astype(np.int32)),
            }
        return self._dev

    def scan(self, queries, programs: dict, *, k: int,
             valid=None) -> tuple[np.ndarray, np.ndarray]:
        """Exact filtered top-k over the live delta rows.

        Returns host (ids (B, k) int64 global ids, dists (B, k) f32) under
        the usual -1 / +inf missing-row contract.  The scan is the plain jnp
        PreFBF path (never Pallas): the buffer is a few thousand rows at
        most, far below kernel-tile scale.
        """
        b = int(np.asarray(queries).shape[0])
        if self.live_count == 0:
            return (np.full((b, k), -1, np.int64),
                    np.full((b, k), np.inf, np.float32))
        dv = self._device_view()
        slots, d = prefbf.prefbf_topk(
            dv["vectors"], dv["norms"], dv["ints"], dv["floats"],
            jnp.asarray(queries), programs, k=k, chunk=self._cap,
            use_pallas=False, valid=valid)
        slots = np.asarray(slots)
        d = np.asarray(d)
        gids = np.where(slots >= 0, self.ids[np.maximum(slots, 0)], -1)
        return gids.astype(np.int64), d

    def scan_dev(self, queries, programs: dict, *, k: int, valid=None):
        """``scan`` staying on device: returns jnp (global ids (B, k) int32,
        dists (B, k) f32) without synchronizing, so callers can fold the
        delta into base results via ``compose_topk_dev`` and keep the whole
        step async.  The id gather uses the device mirror of ``self.ids``."""
        b = int(np.asarray(queries).shape[0])
        if self.live_count == 0:
            return (jnp.full((b, k), -1, jnp.int32),
                    jnp.full((b, k), jnp.inf, jnp.float32))
        dv = self._device_view()
        slots, d = prefbf.prefbf_topk(
            dv["vectors"], dv["norms"], dv["ints"], dv["floats"],
            jnp.asarray(queries), programs, k=k, chunk=self._cap,
            use_pallas=False, valid=valid)
        gids = jnp.where(slots >= 0, dv["ids"][jnp.maximum(slots, 0)], -1)
        return gids, d

    # -- accounting -----------------------------------------------------------
    def stats(self) -> dict:
        return {"slots": self.count, "live": self.live_count,
                "dead": self.count - self.live_count, "capacity": self._cap}
