"""Device-parallel HNSW bulk build: batched JAX candidate search per wave.

The sequential builder (core/hnsw.py) spends essentially all of its time in
``_search_layer`` -- a host-side heap walk issuing one tiny numpy GEMM per
expanded node, one *query* at a time.  ``merge()`` folding a delta of
thousands of rows through that loop would serialize the exact computation
the production search already runs batched on device.

This module reuses ``favor_graph_search`` as the candidate generator:

 * new nodes are processed in *waves*; each wave runs ONE batched device
   search (an always-true filter program, D = 0, ef = efc, pbar guard off)
   over a snapshot of the graph built so far -- a plain beam search, the
   same Algorithm-1 candidates the host ``_search_layer(ef=efc)`` returns;
 * linking stays on host: per node the returned ascending candidate row is
   fed through the builder's own ``_select_arrays`` heuristic + reciprocal
   ``_shrink``, and its Delta_d curve (Eq. 5) is recorded from the same row;
 * nodes that drew an upper level (~1/M of them) and the small-graph seed
   phase take the sequential ``_link_node`` path unchanged -- correctness
   there, throughput on the level-0 bulk.

Compile-shape discipline (the serving bucket-ladder rule): the graph
snapshot is padded to a power-of-two row count (padded rows are unreachable
-- no edge points at them) and waves are power-of-two sized with a ``valid``
lane mask on the ragged tail, so the jitted search retraces O(log n) times
over an entire build instead of once per wave.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from ..core.hnsw import HnswIndex, HnswParams, _Builder
from ..core.search import SearchConfig, favor_graph_search

_MIN_PAD = 64     # smallest padded graph snapshot
_SEED_SEQ = 32    # graph smaller than this links sequentially (wave <= n rule)


def _builder_from_index(index: HnswIndex, capacity: int) -> _Builder:
    """Re-open a finalized index as a mutable builder with room for
    ``capacity`` total rows.  The Delta_d accumulator is primed with
    pseudo-sums reproducing the stored slope, so Eq. 5 over the grown index
    is the count-weighted blend of the old estimate and the new curves."""
    n = index.n
    p = index.params
    b = _Builder(index.dim, p, capacity)
    b.vectors[:n] = index.vectors
    b.norms[:n] = index.norms.astype(np.float32)
    b.adj = [
        [[int(u) for u in index.levels[lv][v] if u >= 0]
         for lv in range(int(index.node_level[v]) + 1)]
        for v in range(n)
    ]
    b.node_level = [int(x) for x in index.node_level]
    b.entry_point = int(index.entry_point)
    b.max_level = int(index.max_level)
    b.n = n
    # fresh stream, offset so repeated merges don't replay the build's draws
    b.rng = np.random.default_rng(p.seed + n + 1)
    if n > 0:
        span = float(n) * float(max(p.efc - p.alpha, 1))
        b._d_alpha_sum = 0.0
        b._d_beta_sum = float(index.delta_d) * span
        b._d_span_sum = span
        b._d_count = n
    return b


def _pow2_at_least(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


def _graph_view(b: _Builder, npad: int) -> dict:
    """Flatten the builder's current adjacency into a padded
    ``graph_arrays``-shaped dict (dummy always-pass attributes)."""
    n = b.n
    p = b.p
    vecs = np.zeros((npad, b.dim), np.float32)
    vecs[:n] = b.vectors[:n]
    norms = np.full((npad,), np.inf, np.float32)
    norms[:n] = b.norms[:n]
    nb0 = np.full((npad, p.M0), -1, np.int32)
    for v in range(n):
        row = b.adj[v][0][: p.M0]
        nb0[v, : len(row)] = row
    if b.max_level >= 1:
        upper = np.full((b.max_level, npad, p.M), -1, np.int32)
        for v in range(n):
            for lv in range(1, len(b.adj[v])):
                row = b.adj[v][lv][: p.M]
                upper[lv - 1, v, : len(row)] = row
    else:
        upper = np.zeros((0, npad, p.M), np.int32)
    return {
        "vectors": jnp.asarray(vecs),
        "norms": jnp.asarray(norms),
        "neighbors0": jnp.asarray(nb0),
        "upper": jnp.asarray(upper),
        "entry": jnp.asarray(b.entry_point, jnp.int32),
        "attrs_int": jnp.asarray(np.zeros((npad, 1), np.int32)),
        "attrs_float": jnp.asarray(np.zeros((npad, 0), np.float32)),
    }


def _true_programs(batch: int) -> dict:
    """Always-true filter program batch matching the dummy attribute shapes
    of ``_graph_view`` (one int column, full-vocab mask; no float columns)."""
    return {
        "valid": jnp.ones((batch, 1), jnp.float32),
        "imask": jnp.full((batch, 1, 1), np.uint32(0xFFFFFFFF), jnp.uint32),
        "flo": jnp.zeros((batch, 1, 0), jnp.float32),
        "fhi": jnp.zeros((batch, 1, 0), jnp.float32),
    }


def _link_from_row(b: _Builder, node: int, ids: np.ndarray,
                   ds: np.ndarray) -> None:
    """Host-side level-0 linking from one ascending device candidate row."""
    b.record_curve(ds)
    sel = b._select_arrays(ids.astype(np.int64), ds, b.p.M0)
    b.adj[node][0] = list(sel)
    for u in sel:
        b.adj[u][0].append(node)
        b._shrink(u, 0, b.p.M0)


def bulk_add(index: HnswIndex, new_vectors: np.ndarray, *,
             wave: int = 512, link: np.ndarray | None = None,
             on_wave=None) -> HnswIndex:
    """Append ``new_vectors`` to a finalized index and return the grown one.

    ``link`` (optional bool mask per new row) marks which rows participate
    in the graph: False rows are *registered* -- they occupy their row
    position, keeping ids positional -- but never linked, which is how
    ``merge()`` carries already-tombstoned delta slots.  Rows keep their
    order: new row j becomes node ``index.n + j``.

    ``on_wave`` (optional zero-arg callable) is invoked between device
    waves; background merges use it as a pacing point to yield to foreground
    serving without holding any lock across the build.
    """
    new_vectors = np.ascontiguousarray(new_vectors, np.float32)
    m = new_vectors.shape[0]
    if m and new_vectors.shape[1] != index.dim:
        raise ValueError(f"bulk_add rows must be dim={index.dim}, "
                         f"got {new_vectors.shape[1]}")
    link = (np.ones((m,), bool) if link is None
            else np.asarray(link, bool).reshape(m))
    b = _builder_from_index(index, index.n + m)
    cfg = SearchConfig(k=b.p.efc, ef=b.p.efc, pbar_min=0.0, gamma=1.0)

    i = 0
    while i < m:
        # sequential seed / trickle: tiny graphs, or a tail too small to
        # justify a device dispatch
        if b.n < _SEED_SEQ:
            node = b._register(new_vectors[i], b.draw_level() if link[i] else 0)
            if link[i]:
                b._link_node(node, new_vectors[i], b.node_level[node])
            i += 1
            continue

        # wave size: pow-2, never larger than the current graph (so every
        # node still links against a graph at least its wave's size)
        w = _pow2_at_least(min(wave, b.n, m - i) + 1) // 2
        w = max(w, 1)
        batch = new_vectors[i: i + w]
        lanes = link[i: i + w]
        wb = batch.shape[0]

        if not lanes.any():
            for j in range(wb):
                b._register(batch[j], 0)
            i += wb
            continue

        if on_wave is not None:
            on_wave()

        # one batched candidate search over the pre-wave snapshot
        npad = _pow2_at_least(max(b.n, _MIN_PAD))
        g = _graph_view(b, npad)
        qpad = np.zeros((w, b.dim), np.float32)
        qpad[:wb] = batch
        lane_valid = np.zeros((w,), bool)
        lane_valid[:wb] = lanes
        out = favor_graph_search(
            g, jnp.asarray(qpad), _true_programs(w),
            jnp.zeros((w,), jnp.float32), cfg, valid=jnp.asarray(lane_valid))
        cand_i = np.asarray(out["ids"])
        cand_d = np.asarray(out["dists"])

        for j in range(wb):
            if not lanes[j]:
                b._register(batch[j], 0)
                continue
            lvl = b.draw_level()
            node = b._register(batch[j], lvl)
            row = cand_i[j]
            keep = (row >= 0) & np.isfinite(cand_d[j])
            if lvl > 0 or not keep.any():
                # upper-level node (needs per-level descent) or a lane the
                # device search came back empty for: sequential path
                b._link_node(node, batch[j], lvl)
            else:
                _link_from_row(b, node, row[keep], cand_d[j][keep])
        i += wb

    return b.finalize()


def build_hnsw_bulk(vectors: np.ndarray, params: HnswParams | None = None,
                    *, wave: int = 512, on_wave=None) -> HnswIndex:
    """Build an index from scratch through the wave pipeline (a from-zero
    ``bulk_add``); drop-in for ``build_hnsw`` where throughput matters more
    than draw-for-draw RNG parity with the sequential loop."""
    params = params or HnswParams()
    vectors = np.ascontiguousarray(vectors, np.float32)
    empty = HnswIndex(
        vectors=np.zeros((0, vectors.shape[1]), np.float32),
        levels=[np.zeros((0, params.M0), np.int32)],
        node_level=np.zeros((0,), np.int16),
        entry_point=-1, max_level=-1, delta_d=0.0, params=params,
        norms=np.zeros((0,), np.float32))
    return bulk_add(empty, vectors, wave=wave, on_wave=on_wave)
