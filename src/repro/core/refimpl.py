"""Pure numpy/python oracle implementations (exact paper semantics).

These are the correctness references for the JAX/Pallas production path:
 * ``favor_search``       -- Algorithms 2 + 3 with real unbounded heaps,
                             exclusion distance (Eq. 2) and the optimized
                             termination condition (section 5.4).
 * ``rsf_search``         -- Result-Set Filtering baseline (section 2.3.1):
                             identical to HNSW except only TD may enter R.
 * ``acorn_search``       -- ACORN-esque baseline: the search path extends
                             only through TD neighbors (distances computed for
                             TD only), with optional 2-hop expansion when the
                             1-hop neighborhood has no TD (ACORN-1 style).
 * ``postfilter_search``  -- vanilla HNSW with inflated ef, filter applied to
                             the result set afterwards.
 * ``bruteforce_filtered``-- exact ground truth (recall denominators).

All searches return (ids, dists) of the k nearest *target* points, ascending,
plus a stats dict (distance computations, hops, TD-on-path proportion) used by
the verification benchmarks (paper Figs. 12/13).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np

from . import filters as F
from .hnsw import HnswIndex


@dataclass
class SearchStats:
    dist_comps: int = 0
    hops: int = 0
    path_td: int = 0  # TD points among path-extension nodes
    terminated_early: bool = False

    @property
    def path_td_fraction(self) -> float:
        return self.path_td / max(1, self.hops)


def _dists(index: HnswIndex, q: np.ndarray, ids: np.ndarray) -> np.ndarray:
    v = index.vectors[ids]
    d2 = index.norms[ids] - 2.0 * (v @ q) + float(q @ q)
    return np.sqrt(np.maximum(d2, 0.0))


def _descend(index: HnswIndex, q: np.ndarray, stats: SearchStats) -> tuple[float, int]:
    """Upper-layer greedy descent, ef=1, no filtering (Algorithm 2 lines 5-7)."""
    ep = index.entry_point
    d = float(_dists(index, q, np.asarray([ep]))[0])
    stats.dist_comps += 1
    for level in range(index.max_level, 0, -1):
        improved = True
        while improved:
            improved = False
            nbrs = index.neighbors(ep, level)
            if len(nbrs) == 0:
                break
            ds = _dists(index, q, nbrs)
            stats.dist_comps += len(nbrs)
            j = int(np.argmin(ds))
            if ds[j] < d:
                d, ep = float(ds[j]), int(nbrs[j])
                improved = True
    return d, ep


def bruteforce_filtered(vectors: np.ndarray, mask: np.ndarray, q: np.ndarray,
                        k: int) -> tuple[np.ndarray, np.ndarray]:
    ids = np.nonzero(mask)[0]
    if len(ids) == 0:
        return np.empty((0,), np.int64), np.empty((0,), np.float64)
    d = np.linalg.norm(vectors[ids] - q[None, :], axis=1)
    order = np.argsort(d, kind="stable")[:k]
    return ids[order], d[order]


# ---------------------------------------------------------------------------
# FAVOR (Algorithms 2 + 3)
# ---------------------------------------------------------------------------
def favor_search(index: HnswIndex, q: np.ndarray, mask: np.ndarray, k: int,
                 ef: int, D: float, *, pbar_min: float = 0.5,
                 gamma: float = 1.0) -> tuple[np.ndarray, np.ndarray, SearchStats]:
    """OptiGreedySearch with exclusion distance.

    mask : (N,) bool -- True for TD (attributes satisfy the filter).
    D    : exclusion distance added to every NTD (Eq. 2).
    pbar_min : TD-fraction termination threshold (0 disables the section 5.4
               optimization and recovers the plain adjusted-distance rule).
    Distances stored in C and R are the *adjusted* Dis_bar values; the final
    S is the k nearest TD in R under true distance ordering (identical to
    Dis_bar ordering for TD since their distance is unmodified).
    """
    stats = SearchStats()
    _, ep = _descend(index, q, stats)

    d_ep = float(_dists(index, q, np.asarray([ep]))[0])
    dbar_ep = d_ep + (0.0 if mask[ep] else D)
    visited = {ep}
    cand = [(dbar_ep, ep)]              # min-heap over Dis_bar
    res: list[tuple[float, int]] = [(-dbar_ep, ep)]  # max-heap over Dis_bar
    n_td = 1 if mask[ep] else 0

    while cand:
        dbar_a, v_a = heapq.heappop(cand)
        worst = -res[0][0]
        if dbar_a > gamma * worst and len(res) >= ef:
            pbar = n_td / len(res)
            if pbar_min <= 0.0 or pbar > pbar_min:
                stats.terminated_early = True
                break
            # conservative strategy: keep exploring until enough TD in R
        stats.hops += 1
        if mask[v_a]:
            stats.path_td += 1
        nbrs = [u for u in index.neighbors(v_a, 0) if u not in visited]
        if not nbrs:
            continue
        visited.update(nbrs)
        ids = np.asarray(nbrs, np.int64)
        ds = _dists(index, q, ids)
        stats.dist_comps += len(nbrs)
        dbars = ds + np.where(mask[ids], 0.0, D)
        for dbar, u in zip(dbars.tolist(), nbrs):
            worst = -res[0][0]
            if dbar < worst or len(res) < ef:
                heapq.heappush(cand, (dbar, u))
                heapq.heappush(res, (-dbar, u))
                if mask[u]:
                    n_td += 1
                if len(res) > ef:
                    _, evicted = heapq.heappop(res)
                    if mask[evicted]:
                        n_td -= 1

    pairs = sorted((-nd, u) for nd, u in res)
    td = [(d, u) for d, u in pairs if mask[u]][:k]
    ids = np.asarray([u for _, u in td], np.int64)
    return ids, _dists(index, q, ids) if len(ids) else np.empty((0,)), stats


# ---------------------------------------------------------------------------
# Result-Set Filtering (RSF) baseline
# ---------------------------------------------------------------------------
def rsf_search(index: HnswIndex, q: np.ndarray, mask: np.ndarray, k: int,
               ef: int) -> tuple[np.ndarray, np.ndarray, SearchStats]:
    """hnswlib-style result-set filtering: C takes everything, R only TD."""
    stats = SearchStats()
    _, ep = _descend(index, q, stats)

    d_ep = float(_dists(index, q, np.asarray([ep]))[0])
    visited = {ep}
    cand = [(d_ep, ep)]
    res: list[tuple[float, int]] = []
    if mask[ep]:
        heapq.heappush(res, (-d_ep, ep))

    while cand:
        d_a, v_a = heapq.heappop(cand)
        if len(res) >= ef and d_a > -res[0][0]:
            stats.terminated_early = True
            break
        stats.hops += 1
        if mask[v_a]:
            stats.path_td += 1
        nbrs = [u for u in index.neighbors(v_a, 0) if u not in visited]
        if not nbrs:
            continue
        visited.update(nbrs)
        ids = np.asarray(nbrs, np.int64)
        ds = _dists(index, q, ids)
        stats.dist_comps += len(nbrs)
        for d, u in zip(ds.tolist(), nbrs):
            if len(res) < ef or d < -res[0][0]:
                heapq.heappush(cand, (d, u))
                if mask[u]:
                    heapq.heappush(res, (-d, u))
                    if len(res) > ef:
                        heapq.heappop(res)

    pairs = sorted((-nd, u) for nd, u in res)[:k]
    ids = np.asarray([u for _, u in pairs], np.int64)
    ds = np.asarray([d for d, _ in pairs])
    return ids, ds, stats


# ---------------------------------------------------------------------------
# ACORN-esque predicate-first baseline
# ---------------------------------------------------------------------------
def acorn_search(index: HnswIndex, q: np.ndarray, mask: np.ndarray, k: int,
                 ef: int, *, two_hop: bool = True
                 ) -> tuple[np.ndarray, np.ndarray, SearchStats]:
    """Search-path extension restricted to TD; distances computed on TD only.

    Emulates ACORN-1 on a conventional graph: neighbor lists are filtered by
    the predicate *before* distance computation; if no 1-hop TD neighbor
    exists, expand to the 2-hop neighborhood (ACORN's neighbor expansion)."""
    stats = SearchStats()
    _, ep0 = _descend(index, q, stats)

    # walk to a TD entry if the descent landed on NTD
    start = None
    frontier = [ep0]
    seen = {ep0}
    for _ in range(64):
        tds = [u for u in frontier if mask[u]]
        if tds:
            start = tds
            break
        nxt = []
        for u in frontier:
            for w in index.neighbors(u, 0):
                if w not in seen:
                    seen.add(w)
                    nxt.append(w)
        if not nxt:
            break
        frontier = nxt
    if start is None:
        return np.empty((0,), np.int64), np.empty((0,)), stats

    ids0 = np.asarray(start, np.int64)
    ds0 = _dists(index, q, ids0)
    stats.dist_comps += len(ids0)
    visited = set(start)
    cand = [(float(d), int(u)) for d, u in zip(ds0, ids0)]
    heapq.heapify(cand)
    res = [(-d, u) for d, u in cand]
    heapq.heapify(res)
    while len(res) > ef:
        heapq.heappop(res)

    while cand:
        d_a, v_a = heapq.heappop(cand)
        if len(res) >= ef and d_a > -res[0][0]:
            stats.terminated_early = True
            break
        stats.hops += 1
        stats.path_td += 1  # path is TD-only by construction
        nbrs1 = index.neighbors(v_a, 0)
        td_nbrs = [u for u in nbrs1 if mask[u] and u not in visited]
        if not td_nbrs and two_hop:
            for u in nbrs1:
                for w in index.neighbors(u, 0):
                    if mask[w] and w not in visited:
                        td_nbrs.append(int(w))
        if not td_nbrs:
            continue
        visited.update(td_nbrs)
        ids = np.asarray(td_nbrs, np.int64)
        ds = _dists(index, q, ids)
        stats.dist_comps += len(ids)
        for d, u in zip(ds.tolist(), td_nbrs):
            if len(res) < ef or d < -res[0][0]:
                heapq.heappush(cand, (d, u))
                heapq.heappush(res, (-d, u))
                if len(res) > ef:
                    heapq.heappop(res)

    pairs = sorted((-nd, u) for nd, u in res)[:k]
    ids = np.asarray([u for _, u in pairs], np.int64)
    ds = np.asarray([d for d, _ in pairs])
    return ids, ds, stats


# ---------------------------------------------------------------------------
# Post-filtering baseline
# ---------------------------------------------------------------------------
def postfilter_search(index: HnswIndex, q: np.ndarray, mask: np.ndarray, k: int,
                      ef: int) -> tuple[np.ndarray, np.ndarray, SearchStats]:
    """Vanilla HNSW search with beam ef, filter applied to R afterwards."""
    stats = SearchStats()
    _, ep = _descend(index, q, stats)
    d_ep = float(_dists(index, q, np.asarray([ep]))[0])
    visited = {ep}
    cand = [(d_ep, ep)]
    res = [(-d_ep, ep)]
    while cand:
        d_a, v_a = heapq.heappop(cand)
        if d_a > -res[0][0] and len(res) >= ef:
            break
        stats.hops += 1
        if mask[v_a]:
            stats.path_td += 1
        nbrs = [u for u in index.neighbors(v_a, 0) if u not in visited]
        if not nbrs:
            continue
        visited.update(nbrs)
        ids = np.asarray(nbrs, np.int64)
        ds = _dists(index, q, ids)
        stats.dist_comps += len(nbrs)
        for d, u in zip(ds.tolist(), nbrs):
            if len(res) < ef or d < -res[0][0]:
                heapq.heappush(cand, (d, u))
                heapq.heappush(res, (-d, u))
                if len(res) > ef:
                    heapq.heappop(res)
    pairs = sorted((-nd, u) for nd, u in res)
    td = [(d, u) for d, u in pairs if mask[u]][:k]
    ids = np.asarray([u for _, u in td], np.int64)
    ds = np.asarray([d for d, _ in td])
    return ids, ds, stats


def recall_at_k(found: np.ndarray, truth: np.ndarray, k: int) -> float:
    if len(truth) == 0:
        return 1.0
    t = set(truth[:k].tolist())
    return len(t.intersection(set(found[:k].tolist()))) / min(k, len(t))
