"""Selector routing shared by every execution backend (paper section 4.1).

The seed buried the route/partition logic inside ``FavorIndex.search``, so
the sharded serve path could never reuse it and the two paths drifted.  This
module owns the whole host-side online pipeline:

    compile filters -> estimate p_hat -> plan routes -> partition the batch
    -> backend.search_graph / backend.search_brute -> reassemble

``execute()`` is the single entry point; ``FavorIndex.query`` and
``ServeEngine`` both call it, with the backend (local single-host or sharded
multi-device) supplied as a parameter.  Identical queries therefore take
identical routes on every backend -- the selector decision is made exactly
once, here, from the backend's own selectivity estimate.
"""
from __future__ import annotations

import time
from contextlib import nullcontext
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from . import batching
from . import filters as F
from . import selector
from .options import ROUTES, SearchOptions


@dataclass
class SearchResult:
    ids: np.ndarray      # (B, k) int64, -1 padded
    dists: np.ndarray    # (B, k) float32, +inf padded
    p_hat: np.ndarray    # (B,)
    routed_brute: np.ndarray  # (B,) bool
    # hops/path_td are per-query graph traversal diagnostics: 0 for
    # brute-routed (and cache-served) queries, and ``None`` for the whole
    # batch when a graph sub-batch ran on a backend that does not report
    # them (the sharded serve path returns only ids/dists from its top-k
    # merge) -- None-safe so operators can tell "no hops" from "unknown"
    hops: np.ndarray | None     # (B,) or None
    path_td: np.ndarray | None  # (B,) or None
    # waves: expansion rounds the traversal's lane-compacted while_loop ran
    # for the query's sub-batch (every lane in a stage shares the wave
    # count, so this is a batch-shape diagnostic, not a per-lane hop count)
    waves: np.ndarray | None = None  # (B,) or None
    elapsed_s: float = 0.0

    @property
    def qps(self) -> float:
        return len(self.ids) / max(self.elapsed_s, 1e-12)


@dataclass(eq=False)
class PendingExecution:
    """In-flight result of ``execute(..., defer=True)``.

    The host phase (filter compile, cache lookup, selectivity estimate,
    routing, bucket padding, backend dispatch) has already run and the
    backend's device work is in flight behind JAX's async dispatch;
    ``finish()`` blocks on the device transfers, reassembles the batch, and
    fires the backend's ``record_result`` hook plus the obs trace.  Passing
    ``hook_lock`` runs only those *mutating* host hooks (cache record, obs
    registry) under the lock -- the device sync itself never holds it, which
    is what lets a pipelined engine overlap one step's device wait with the
    next step's host phase.  ``finish()`` is idempotent: the first call
    materializes the SearchResult, later calls return the same object.
    """
    backend: object
    opts: SearchOptions
    b: int
    t0: float
    ids: np.ndarray
    dists: np.ndarray
    p_hat: np.ndarray
    routed_brute: np.ndarray
    hops: np.ndarray
    path_td: np.ndarray
    waves: np.ndarray
    miss: np.ndarray
    tr: object = None
    obs: object = None
    programs: dict | None = None
    mq: object = None
    mprogs: dict | None = None
    mp_hat: np.ndarray | None = None
    plan: RoutePlan | None = None
    gi: np.ndarray | None = None
    bi: np.ndarray | None = None
    graph_out: dict | None = None
    brute_out: tuple | None = None
    graph_diag: bool = True
    waves_diag: bool = True
    _result: SearchResult | None = None

    def finish(self, hook_lock=None) -> SearchResult:
        if self._result is not None:
            return self._result
        ids, dists, miss = self.ids, self.dists, self.miss
        gi, bi = self.gi, self.bi
        if self.graph_out is not None:
            out = self.graph_out
            ids[miss[gi]] = np.asarray(out["ids"])[:len(gi)]
            dists[miss[gi]] = np.asarray(out["dists"])[:len(gi)]
            if "hops" in out:
                self.hops[miss[gi]] = np.asarray(out["hops"])[:len(gi)]
                self.path_td[miss[gi]] = np.asarray(
                    out["path_td"])[:len(gi)]
            else:
                self.graph_diag = False
            if "waves" in out:
                self.waves[miss[gi]] = np.asarray(out["waves"])[:len(gi)]
            else:
                self.waves_diag = False
        if self.brute_out is not None:
            bid, bd = self.brute_out
            ids[miss[bi]] = np.asarray(bid)[:len(bi)]
            dists[miss[bi]] = np.asarray(bd)[:len(bi)]
        # the np.asarray conversions above synced the in-flight device work
        elapsed = time.perf_counter() - self.t0
        with (hook_lock if hook_lock is not None else nullcontext()):
            record = getattr(self.backend, "record_result", None)
            if record is not None and len(miss):
                with (self.tr.span("cache_record") if self.tr is not None
                      else nullcontext()):
                    record(np.asarray(self.mq), self.mprogs, self.opts,
                           ids[miss], dists[miss], self.mp_hat,
                           self.plan.brute)
            if self.tr is not None:
                self.tr.attrs["cache_hits"] = int(self.b - len(miss))
                self.tr.attrs["graph"] = int(
                    self.b - int(self.routed_brute.sum()))
                self.tr.attrs["brute"] = int(self.routed_brute.sum())
                programs = self.programs
                self.obs.finish_trace(
                    self.tr, p_hat=self.p_hat,
                    routed_brute=self.routed_brute, ef=self.opts.ef,
                    signatures=lambda: F.batch_signatures(programs))
        self._result = SearchResult(
            ids, dists, self.p_hat, self.routed_brute,
            self.hops if self.graph_diag else None,
            self.path_td if self.graph_diag else None,
            waves=self.waves if self.waves_diag else None,
            elapsed_s=elapsed)
        return self._result


@dataclass(frozen=True)
class RoutePlan:
    """Per-query routing decision: True -> PreFBF brute scan."""
    p_hat: np.ndarray
    brute: np.ndarray

    @property
    def graph_idx(self) -> np.ndarray:
        return np.nonzero(~self.brute)[0]

    @property
    def brute_idx(self) -> np.ndarray:
        return np.nonzero(self.brute)[0]


def broadcast_filters(filters, batch: int) -> list:
    """One filter -> one per query; otherwise the count must match."""
    if isinstance(filters, F.Filter):
        filters = [filters] * batch
    filters = list(filters)
    if len(filters) != batch:
        raise ValueError(f"expected one filter per query: got {len(filters)} "
                         f"filters for {batch} queries")
    return filters


def compile_programs(filters, schema: F.Schema, batch: int,
                     width: int = 8) -> dict:
    """Compile + stack one DNF program per query (device-resident dict)."""
    filters = broadcast_filters(filters, batch)
    progs = [F.compile_filter(f, schema, width) for f in filters]
    return {k: jnp.asarray(v) for k, v in F.stack_programs(progs).items()}


def plan_routes(p_hat: np.ndarray, lam: float,
                force: str | None = None) -> RoutePlan:
    """Route each query by estimated selectivity (p_hat < lambda -> brute);
    ``force`` pins every query to one route (validated, not pattern-matched:
    a typo'd route name raises instead of silently auto-routing)."""
    if force not in ROUTES:
        raise ValueError(f"force must be one of {ROUTES}, got {force!r}")
    # a NaN estimate (empty selectivity sample: freshly-created live index
    # with no merged base) routes as p_hat=1 -- graph side, where the
    # delta-compose path serves it
    p_hat = np.nan_to_num(np.asarray(p_hat, np.float32), nan=1.0)
    if force == "brute":
        brute = np.ones(p_hat.shape, bool)
    elif force == "graph":
        brute = np.zeros(p_hat.shape, bool)
    else:
        brute = selector.route(p_hat, lam)
    return RoutePlan(p_hat, brute)


def take_programs(programs: dict, idx: np.ndarray) -> dict:
    """Row-slice a stacked program dict to a sub-batch (device-side gather;
    the seed's ``np.asarray(v)[idx]`` forced a device->host->device round
    trip per route split)."""
    idx = jnp.asarray(np.asarray(idx, np.int32))
    return {k: jnp.take(jnp.asarray(v), idx, axis=0)
            for k, v in programs.items()}


def execute(backend, queries, filters, opts: SearchOptions, *,
            registry=None, scopes=None, obs=None,
            defer: bool = False) -> SearchResult | PendingExecution:
    """Run one filtered-ANNS batch through ``backend`` (paper Fig. 1 online
    phase): result-cache fast path -> estimate -> route -> per-route
    execution -> reassembly.

    When ``opts.batch`` is a BatchSpec, the estimate call and each route
    sub-batch are bucket-padded before hitting the backend: pad rows carry
    an always-false filter program plus a False entry in the ``valid`` mask
    the backend receives, and are stripped on reassembly -- so the compiled
    shape set is bounded by the bucket ladder while results stay
    bit-identical to the unpadded path.  ``registry`` (a
    batching.ShapeRegistry) optionally records every compiled-entry-point
    shape and the pad overhead paid.

    Backends may optionally implement two duck-typed hooks (the cache
    subsystem's ``CachingBackend`` does; plain backends need neither):

      lookup_result(queries, programs, opts) -> None | {"hit": (B,) bool,
          "ids"/"dists"/"p_hat"/"routed_brute": hit-row arrays}
          served *before* estimation, so a hit skips the whole pipeline.
      record_result(queries, programs, opts, ids, dists, p_hat, routed_brute)
          called with the freshly computed miss rows after execution.

    ``scopes`` is an optional (B,) int array of per-request tenant/session
    scope ids (0 = unscoped).  It rides the stacked program dict as a
    ``"scope"`` sidecar row -- so it is sliced, padded (with 0) and
    sub-batched in lockstep with the filter programs -- but only when the
    backend declares ``scope_aware`` (the cache subsystem's CachingBackend,
    which keys its semantic/candidate layers on it and strips it before any
    inner compiled call); plain device backends never see it, keeping their
    jit pytree signatures unchanged.

    ``obs`` is an optional ``repro.obs.Obs``: when its tracer samples this
    batch, every pipeline stage below runs inside a span (wall time, route,
    bucket shape, pad fraction, cache hits), and -- when the spec enables
    kernel annotations -- the route dispatches run inside host-side
    ``jax.profiler.TraceAnnotation`` scopes named by route and bucket.
    Obs hooks only *observe*; results are bit-identical with obs absent,
    disabled, or sampled out.

    ``defer=True`` returns a ``PendingExecution`` after the host phase:
    the backend searches are *dispatched* (device work queued behind JAX
    async dispatch) but not fetched, and no mutating hook has fired.  The
    caller finishes the step -- possibly from another thread, possibly
    after dispatching more steps -- with ``pending.finish()``, which
    yields the identical SearchResult the synchronous path returns.
    """
    backend.validate(opts)
    queries = jnp.asarray(np.ascontiguousarray(queries, np.float32))
    b = queries.shape[0]

    tr = obs.start_trace(b) if obs is not None else None
    if tr is None:
        def _span(name, **attrs):
            return nullcontext()
    else:
        _span = tr.span
    _ann = obs.annotate if obs is not None else (lambda name: nullcontext())

    with _span("compile", rows=b):
        programs = compile_programs(filters, backend.schema, b)
    if scopes is not None and getattr(backend, "scope_aware", False):
        scopes = np.asarray(scopes, np.int32)
        if scopes.shape != (b,):
            raise ValueError(f"scopes must be shaped ({b},), "
                             f"got {scopes.shape}")
        if scopes.any():   # all-zero means unscoped: skip the sidecar
            programs["scope"] = jnp.asarray(scopes)
    spec = opts.batch

    t0 = time.perf_counter()
    ids = np.full((b, opts.k), -1, np.int64)
    dists = np.full((b, opts.k), np.inf, np.float32)
    p_hat = np.zeros((b,), np.float32)
    routed_brute = np.zeros((b,), bool)
    hops = np.zeros((b,), np.int64)
    path_td = np.zeros((b,), np.int64)
    waves = np.zeros((b,), np.int64)
    lookup = getattr(backend, "lookup_result", None)
    with _span("cache_lookup") as sp:
        cached = (lookup(np.asarray(queries), programs, opts)
                  if lookup else None)
        if sp is not None:
            sp.attrs["hits"] = (int(np.asarray(cached["hit"]).sum())
                                if cached is not None else 0)
    if cached is not None:
        hi = np.nonzero(np.asarray(cached["hit"], bool))[0]
        ids[hi] = np.asarray(cached["ids"])
        dists[hi] = np.asarray(cached["dists"])
        p_hat[hi] = np.asarray(cached["p_hat"])
        routed_brute[hi] = np.asarray(cached["routed_brute"])
        miss = np.nonzero(~np.asarray(cached["hit"], bool))[0]
    else:
        miss = np.arange(b)

    pend = PendingExecution(
        backend=backend, opts=opts, b=b, t0=t0, ids=ids, dists=dists,
        p_hat=p_hat, routed_brute=routed_brute, hops=hops, path_td=path_td,
        waves=waves, miss=miss, tr=tr, obs=obs, programs=programs)

    if len(miss):
        # avoid re-slicing (device round-trips) when a sub-batch is the
        # whole batch -- the common case for plain (hook-less) backends
        full = len(miss) == b
        mq = queries if full else queries[miss]
        mprogs = programs if full else take_programs(programs, miss)
        with _span("estimate", rows=len(miss)) as sp:
            if spec is None:
                batching.record(registry, "estimate", len(miss), len(miss))
                mp_hat = np.asarray(backend.estimate(mprogs))
            else:
                eprogs, evalid = batching.pad_programs(spec, mprogs)
                batching.record(registry, "estimate", len(evalid), len(miss))
                if sp is not None:
                    sp.attrs["bucket"] = int(len(evalid))
                with _ann(f"favor/estimate/b{len(evalid)}"):
                    mp_hat = np.asarray(backend.estimate(
                        eprogs, valid=evalid))[:len(miss)]
        with _span("route") as sp:
            plan = plan_routes(mp_hat, backend.sel_cfg.lam, opts.force)
            if sp is not None:
                sp.attrs["graph"] = int(len(plan.graph_idx))
                sp.attrs["brute"] = int(len(plan.brute_idx))
        p_hat[miss] = plan.p_hat
        routed_brute[miss] = plan.brute

        gi, bi = plan.graph_idx, plan.brute_idx
        pend.mq, pend.mprogs, pend.mp_hat = mq, mprogs, mp_hat
        pend.plan, pend.gi, pend.bi = plan, gi, bi
        if len(gi):
            with _span("graph", rows=len(gi)) as gspan:
                whole = len(gi) == len(miss)
                gq = mq if whole else mq[gi]
                gprogs = mprogs if whole else take_programs(mprogs, gi)
                gp = mp_hat if whole else mp_hat[gi]
                gvalid = None
                if spec is not None:
                    with _span("pad"):
                        gq, gprogs, gp, gvalid = batching.pad_to_bucket(
                            spec, gq, gprogs, gp)
                bucket = int(gq.shape[0])
                if gspan is not None:
                    gspan.attrs["bucket"] = bucket
                    gspan.attrs["pad_frac"] = 1.0 - len(gi) / bucket
                batching.record(registry, "graph", bucket, len(gi), opts)
                with _span("search"), _ann(f"favor/graph/b{bucket}"):
                    pend.graph_out = backend.search_graph(
                        gq, gprogs, jnp.asarray(gp), opts, valid=gvalid)
        if len(bi):
            with _span("brute", rows=len(bi)) as bspan:
                whole = len(bi) == len(miss)
                bq = mq if whole else mq[bi]
                bprogs = mprogs if whole else take_programs(mprogs, bi)
                bvalid = None
                if spec is not None:
                    with _span("pad"):
                        bq, bprogs, _, bvalid = batching.pad_to_bucket(
                            spec, bq, bprogs)
                bucket = int(bq.shape[0])
                if bspan is not None:
                    bspan.attrs["bucket"] = bucket
                    bspan.attrs["pad_frac"] = 1.0 - len(bi) / bucket
                batching.record(registry, "brute", bucket, len(bi), opts)
                with _span("search"), _ann(f"favor/brute/b{bucket}"):
                    pend.brute_out = backend.search_brute(bq, bprogs, opts,
                                                          valid=bvalid)

    return pend if defer else pend.finish()
