"""FAVOR core: the paper's contribution as a composable JAX library."""
from . import batching, exclusion, filters, prefbf, refimpl, router, selectivity, selector
from .batching import BatchSpec, ShapeRegistry
from .favor import FavorIndex
from .filters import (And, AttributeTable, ColumnSpec, Equality, FalseFilter,
                      Filter, Inclusion, Not, Or, Range, Schema, TrueFilter,
                      batch_signatures, compile_filter, filter_signature,
                      paper_filters, paper_schema, program_signature,
                      random_attributes, stack_programs)
from .hnsw import HnswIndex, HnswParams, build_hnsw
from .options import (BuildSpec, CacheSpec, FrontEndSpec, ObsSpec, QuantSpec,
                      SearchOptions, TenantSpec)
from .backend import Backend, LocalBackend, ShardedBackend
from .router import RoutePlan, SearchResult
from .scoring import (ExactScorer, PqAdcScorer, Scorer, SqScorer,
                      exclusion_compose, scorer_for)
from .search import SearchConfig, favor_graph_search, graph_arrays, rsf_graph_search

__all__ = [
    "And", "AttributeTable", "Backend", "BatchSpec", "BuildSpec",
    "CacheSpec", "ColumnSpec", "Equality", "ExactScorer", "FalseFilter",
    "Filter", "FavorIndex", "FrontEndSpec", "HnswIndex", "HnswParams",
    "Inclusion",
    "LocalBackend", "Not", "ObsSpec", "Or", "PqAdcScorer", "QuantSpec",
    "Range",
    "RoutePlan", "Schema", "Scorer", "SearchConfig", "SearchOptions",
    "SearchResult", "ShapeRegistry", "ShardedBackend", "SqScorer",
    "TenantSpec", "TrueFilter", "batch_signatures", "batching", "build_hnsw",
    "compile_filter", "exclusion", "exclusion_compose",
    "favor_graph_search", "filter_signature", "filters", "graph_arrays",
    "paper_filters", "paper_schema", "prefbf", "program_signature",
    "random_attributes", "refimpl", "router", "rsf_graph_search",
    "scorer_for", "selectivity", "selector", "stack_programs",
]
