"""FAVOR core: the paper's contribution as a composable JAX library."""
from . import exclusion, filters, prefbf, refimpl, router, selectivity, selector
from .favor import FavorIndex
from .filters import (And, AttributeTable, ColumnSpec, Equality, FalseFilter,
                      Filter, Inclusion, Not, Or, Range, Schema, TrueFilter,
                      compile_filter, paper_filters, paper_schema,
                      random_attributes, stack_programs)
from .hnsw import HnswIndex, HnswParams, build_hnsw
from .options import BuildSpec, QuantSpec, SearchOptions
from .backend import Backend, LocalBackend, ShardedBackend
from .router import RoutePlan, SearchResult
from .search import SearchConfig, favor_graph_search, graph_arrays, rsf_graph_search

__all__ = [
    "And", "AttributeTable", "Backend", "BuildSpec", "ColumnSpec", "Equality",
    "FalseFilter", "Filter", "FavorIndex", "HnswIndex", "HnswParams",
    "Inclusion", "LocalBackend", "Not", "Or", "QuantSpec", "Range",
    "RoutePlan", "Schema", "SearchConfig", "SearchOptions", "SearchResult",
    "ShardedBackend", "TrueFilter", "build_hnsw", "compile_filter",
    "exclusion", "favor_graph_search", "filters", "graph_arrays",
    "paper_filters", "paper_schema", "prefbf", "random_attributes", "refimpl",
    "router", "rsf_graph_search", "selectivity", "selector", "stack_programs",
]
