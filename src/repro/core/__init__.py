"""FAVOR core: the paper's contribution as a composable JAX library."""
from . import exclusion, filters, prefbf, refimpl, selectivity, selector
from .favor import FavorIndex, SearchResult
from .filters import (And, AttributeTable, ColumnSpec, Equality, FalseFilter,
                      Filter, Inclusion, Not, Or, Range, Schema, TrueFilter,
                      compile_filter, paper_filters, paper_schema,
                      random_attributes, stack_programs)
from .hnsw import HnswIndex, HnswParams, build_hnsw
from .search import SearchConfig, favor_graph_search, graph_arrays, rsf_graph_search

__all__ = [
    "And", "AttributeTable", "ColumnSpec", "Equality", "FalseFilter", "Filter",
    "FavorIndex", "HnswIndex", "HnswParams", "Inclusion", "Not", "Or", "Range",
    "Schema", "SearchConfig", "SearchResult", "TrueFilter", "build_hnsw",
    "compile_filter", "exclusion", "favor_graph_search", "filters",
    "graph_arrays", "paper_filters", "paper_schema", "prefbf",
    "random_attributes", "refimpl", "rsf_graph_search", "selectivity",
    "selector", "stack_programs",
]
