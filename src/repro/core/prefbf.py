"""Pre-filtering brute-force search (paper Sections 3.2.1 and 4.1).

On CPU the paper gathers the predicate-passing rows and scans them.  On TPU
data-dependent compaction is the enemy: the MXU prefers scanning *all* rows of
a statically-shaped block at matmul speed and masking the predicate failures
to +inf -- the arithmetic (and the results) are identical to pre-filtering,
with the filter evaluated as the compiled DNF program.  This is the fused
distance + mask + top-k scan; the Pallas kernel in kernels/filtered_topk is
the hand-tiled version of this exact loop, and ``use_pallas=True`` routes
through it.

The scan is chunked over the DB axis with a running top-k merge so the live
working set stays O(B * chunk) regardless of N (VMEM-friendly blocking; on
CPU it also bounds peak memory).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import filters as F

INF = jnp.inf


def pad_db(vectors: np.ndarray, norms: np.ndarray, ints: np.ndarray,
           floats: np.ndarray, chunk: int):
    """Pad the DB row count to a multiple of ``chunk``; padded rows get +inf
    norms so their distance is +inf and an all-False filter row."""
    n = vectors.shape[0]
    pad = (-n) % chunk
    if pad == 0:
        return vectors, norms, ints, floats
    return (
        np.concatenate([vectors, np.zeros((pad, vectors.shape[1]), vectors.dtype)]),
        np.concatenate([norms, np.full((pad,), np.inf, norms.dtype)]),
        np.concatenate([ints, np.full((pad, ints.shape[1]), -1, ints.dtype)]),
        np.concatenate([floats, np.full((pad, floats.shape[1]), np.nan, floats.dtype)]),
    )


@partial(jax.jit, static_argnames=("k", "chunk", "use_pallas"))
def prefbf_topk(vectors, norms, ints, floats, queries, programs, *,
                k: int, chunk: int = 16384, use_pallas: bool = False,
                valid=None):
    """Fused filtered brute-force top-k.

    vectors (N, d), norms (N,), ints (N, m_i), floats (N, m_f);
    queries (B, d); programs batched filter programs; ``valid`` an optional
    (B,) bool query mask (bucket padding) -- False rows return -1 / +inf.
    Returns ids (B, k) int32 (-1 for missing) and dists (B, k) (+inf missing).
    N must be a multiple of ``chunk`` (see pad_db).
    """
    if use_pallas:
        from ..kernels.filtered_topk import ops as ft_ops
        return ft_ops.filtered_topk(vectors, norms, ints, floats, queries,
                                    programs, k=k, block_n=chunk,
                                    valid=valid)

    n, d = vectors.shape
    b = queries.shape[0]
    assert n % chunk == 0, f"N={n} not a multiple of chunk={chunk}; use pad_db"
    n_chunks = n // chunk
    qn = jnp.sum(queries * queries, axis=-1)  # (B,)

    vc = vectors.reshape(n_chunks, chunk, d)
    nc = norms.reshape(n_chunks, chunk)
    ic = ints.reshape(n_chunks, chunk, -1)
    fc = floats.reshape(n_chunks, chunk, -1)

    init = (jnp.full((b, k), INF), jnp.full((b, k), -1, jnp.int32))

    def step(carry, xs):
        # The carry holds *squared* (clamped) distances; sqrt is monotone on
        # [0, inf) so the running top-k selection is unchanged and the sqrt is
        # deferred to the final (B, k) rows after the scan.
        best_d, best_i = carry
        v, nn, ii, ff, start = xs
        dot = queries @ v.T                                  # (B, chunk) MXU
        d2 = jnp.maximum(nn[None, :] + qn[:, None] - 2.0 * dot, 0.0)
        mask = F.eval_program_batched(programs, ii, ff, xp=jnp)  # (B, chunk)
        d2 = jnp.where(mask, d2, INF)
        ids = (start + jnp.arange(chunk, dtype=jnp.int32))[None, :].repeat(b, 0)
        md = jnp.concatenate([best_d, d2], axis=1)
        mi = jnp.concatenate([best_i, ids], axis=1)
        # O((k+chunk) log k) selection instead of a full argsort.  lax.top_k
        # breaks ties toward the lower index, same as the stable argsort it
        # replaces: carried entries (lower concat index) beat equal chunk
        # entries, and within a chunk the smaller db id wins.
        neg_d, order = jax.lax.top_k(-md, k)
        return (-neg_d, jnp.take_along_axis(mi, order, axis=1)), None

    starts = (jnp.arange(n_chunks, dtype=jnp.int32) * chunk)
    (best_d, best_i), _ = jax.lax.scan(step, init, (vc, nc, ic, fc, starts))
    best_d = jnp.sqrt(best_d)
    best_i = jnp.where(jnp.isfinite(best_d), best_i, -1)
    if valid is not None:
        vmask = jnp.asarray(valid, bool)[:, None]
        best_d = jnp.where(vmask, best_d, INF)
        best_i = jnp.where(vmask, best_i, -1)
    return best_i, best_d
