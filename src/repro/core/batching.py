"""Shape-stable sub-batch execution: bucket padding from router to kernels.

The selector (paper section 4.1) splits every serve batch into graph/brute
sub-batches whose sizes are *data dependent* -- each new ``(route, size)``
pair used to trigger a fresh XLA/Pallas compile, which is exactly the
serving-p99 spike the filtered-ANNS system studies attribute to
mixed-selectivity traffic.  This module pins every compiled entry point to a
small, fixed set of power-of-two bucket shapes:

  BatchSpec      -- frozen policy: pow-2 bucket sizes between ``min_bucket``
                    and ``max_bucket`` plus the pad-row content policy.
                    Carried on ``SearchOptions.batch``; ``None`` disables
                    padding (the pre-1.2 behavior).
  pad_to_bucket  -- pad queries + stacked filter programs (+ optional p_hat)
                    up to the bucket size.  Pad rows carry an ALWAYS-FALSE
                    filter program (no disjunct live, infeasible intervals)
                    and a False entry in the returned validity mask, so they
                    match nothing and every backend/kernel can drop them
                    without touching real rows -- results stay bit-identical
                    to the unpadded path.
  unpad          -- strip the pad rows off result arrays.
  ShapeRegistry  -- per-engine ledger of the distinct shapes that reached a
                    compiled entry point (compile events) and of the padding
                    overhead actually paid.
  warmup         -- explicitly drive every (route, bucket) executable once
                    with an all-pad batch, so first-request traffic never
                    pays a compile.

Everything here is host-side policy: the device-side contract is only the
``valid`` mask that ``Backend.search_graph``/``search_brute`` and the
filtered_topk / gather_distance / pq_adc kernel ops accept.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from . import filters as F

PAD_POLICIES = ("zero", "repeat")


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


@dataclass(frozen=True)
class BatchSpec:
    """Frozen bucket-padding policy for one engine/options instance.

    min_bucket/max_bucket bound the pow-2 bucket set (both must themselves
    be powers of two); batches above ``max_bucket`` round up to a multiple
    of it (the engine's ``max_batch`` normally caps them first).
    ``pad_policy`` picks the pad *query* rows: "zero" rows (default) or
    "repeat" of the last real row -- pad *filter* rows are always the
    always-false program, so the choice never affects results.
    """
    min_bucket: int = 8
    max_bucket: int = 512
    pad_policy: str = "zero"

    def __post_init__(self):
        for name in ("min_bucket", "max_bucket"):
            v = getattr(self, name)
            if not _is_pow2(v):
                raise ValueError(f"BatchSpec.{name} must be a power of two "
                                 f">= 1, got {v}")
        if self.min_bucket > self.max_bucket:
            raise ValueError(f"BatchSpec.min_bucket ({self.min_bucket}) must "
                             f"be <= max_bucket ({self.max_bucket})")
        if self.pad_policy not in PAD_POLICIES:
            raise ValueError(f"BatchSpec.pad_policy must be one of "
                             f"{PAD_POLICIES}, got {self.pad_policy!r}")

    def buckets(self) -> tuple[int, ...]:
        """The full bucket ladder, min_bucket, 2*min_bucket, ..., max_bucket."""
        out = []
        b = self.min_bucket
        while b <= self.max_bucket:
            out.append(b)
            b *= 2
        return tuple(out)

    def bucket_for(self, n: int) -> int:
        """Smallest bucket >= n (above max_bucket: next multiple of it)."""
        if n < 1:
            raise ValueError(f"bucket_for needs n >= 1, got {n}")
        for b in self.buckets():
            if n <= b:
                return b
        return -(-n // self.max_bucket) * self.max_bucket


def false_program_rows(programs: dict, pad: int) -> dict:
    """``pad`` always-false stacked program rows shaped like ``programs``.

    No disjunct is live (valid == 0) and the interval constraints are
    infeasible (flo=+inf > fhi=-inf), matching compile_filter's dead-row
    convention, so the rows match no DB row under any evaluator.  Host-side
    sidecar keys riding on the dict (the per-row tenant ``scope`` the cache
    subsystem consumes) are zero-padded: scope 0 is the unscoped default.
    """
    fills = {"flo": jnp.inf, "fhi": -jnp.inf}
    out = {}
    for k, v in programs.items():
        v = jnp.asarray(v)
        shape = (pad,) + tuple(v.shape[1:])
        fill = fills.get(k)
        out[k] = (jnp.zeros(shape, v.dtype) if fill is None
                  else jnp.full(shape, fill, v.dtype))
    return out


def pad_programs(spec: BatchSpec, programs: dict):
    """Pad a stacked program dict alone to its bucket.

    Returns ``(programs, valid)`` with ``valid`` a host (bucket,) bool mask
    that is True exactly on the original rows.
    """
    n = int(np.asarray(programs["valid"]).shape[0])
    bucket = spec.bucket_for(n)
    valid = np.arange(bucket) < n
    if bucket == n:
        return programs, valid
    pad_rows = false_program_rows(programs, bucket - n)
    programs = {k: jnp.concatenate([jnp.asarray(v), pad_rows[k]])
                for k, v in programs.items()}
    return programs, valid


def pad_to_bucket(spec: BatchSpec, queries, programs: dict, p_hat=None):
    """Pad one sub-batch up to its bucket size.

    queries (n, d) and the stacked program dict gain ``bucket - n`` pad rows
    (always-false programs; query content per ``spec.pad_policy``); the
    optional per-query ``p_hat`` is zero-padded.  Returns
    ``(queries, programs, p_hat, valid)``; strip results with ``unpad``.
    """
    queries = jnp.asarray(queries)
    n = int(queries.shape[0])
    bucket = spec.bucket_for(n)
    valid = np.arange(bucket) < n
    if bucket == n:
        return queries, programs, p_hat, valid
    pad = bucket - n
    if spec.pad_policy == "repeat":
        qpad = jnp.repeat(queries[-1:], pad, axis=0)
    else:
        qpad = jnp.zeros((pad,) + tuple(queries.shape[1:]), queries.dtype)
    queries = jnp.concatenate([queries, qpad])
    pad_rows = false_program_rows(programs, pad)
    programs = {k: jnp.concatenate([jnp.asarray(v), pad_rows[k]])
                for k, v in programs.items()}
    if p_hat is not None:
        p_hat = np.concatenate([np.asarray(p_hat, np.float32),
                                np.zeros((pad,), np.float32)])
    return queries, programs, p_hat, valid


def unpad(n: int, *arrays):
    """Strip pad rows: slice every array back to its first ``n`` rows."""
    out = tuple(a[:n] for a in arrays)
    return out[0] if len(out) == 1 else out


# ---------------------------------------------------------------------------
# Compiled-shape accounting
# ---------------------------------------------------------------------------
def route_key(kind: str, opts) -> tuple:
    """The jit-static identity of one backend entry point: shapes recorded
    under different keys correspond to genuinely different executables."""
    if opts is None or kind == "estimate":
        return ()  # the estimate executable is SearchConfig-independent
    cfg = opts.search_config()
    if kind == "brute":
        return (cfg, opts.use_pq, opts.rerank)
    return (cfg,)


class ShapeRegistry:
    """Ledger of distinct (kind, batch-shape, static-config) triples that
    reached a compiled backend entry point, plus the padding overhead paid.

    A *new* triple is a compile event (XLA/Pallas trace + compile); repeat
    triples reuse the cached executable.  ``ServeEngine`` owns one registry
    and surfaces ``stats()`` to operators; the smoke benchmark asserts the
    per-kind shape count stays bounded by the bucket-ladder length.
    """

    def __init__(self):
        self._shapes: dict[tuple, int] = {}
        self.compile_events = 0
        self.pad_rows = 0
        self.real_rows = 0

    def record(self, kind: str, size: int, real: int, opts=None) -> bool:
        """Note one backend call; True when its shape is new (a compile)."""
        key = (kind, int(size)) + route_key(kind, opts)
        new = key not in self._shapes
        self._shapes[key] = self._shapes.get(key, 0) + 1
        if new:
            self.compile_events += 1
        self.pad_rows += int(size) - int(real)
        self.real_rows += int(real)
        return new

    @property
    def compiled_shapes(self) -> int:
        return len(self._shapes)

    def sizes_by_kind(self) -> dict[str, tuple[int, ...]]:
        """kind -> sorted distinct batch sizes seen (the compile guard)."""
        out: dict[str, set] = {}
        for (kind, size, *_rest) in self._shapes:
            out.setdefault(kind, set()).add(size)
        return {k: tuple(sorted(v)) for k, v in out.items()}

    def reset_rows(self) -> None:
        """Zero the pad/real row counters (the shape set -- which mirrors
        still-live compiled executables -- survives)."""
        self.pad_rows = 0
        self.real_rows = 0

    def stats(self) -> dict:
        total = self.pad_rows + self.real_rows
        return {
            "compiled_shapes": self.compiled_shapes,
            "compile_events": self.compile_events,
            "calls": sum(self._shapes.values()),
            "pad_rows": self.pad_rows,
            "real_rows": self.real_rows,
            "pad_overhead": self.pad_rows / total if total else 0.0,
            "sizes": self.sizes_by_kind(),
        }


def record(registry, kind: str, size: int, real: int, opts=None) -> None:
    """Registry-optional convenience used by router.execute / warmup."""
    if registry is not None:
        registry.record(kind, size, real, opts)


# ---------------------------------------------------------------------------
# Explicit warm-up
# ---------------------------------------------------------------------------
def warmup(backend, opts, *, buckets=None, registry=None) -> tuple[int, ...]:
    """Compile every (estimate / graph / brute, bucket) executable now.

    Drives the innermost backend (cache decorators are unwrapped -- their
    host-side layers never compile) with all-pad batches: zero queries,
    always-false programs, an all-False validity mask and p_hat = 0, i.e.
    exactly the shapes + static config live traffic will hit once
    ``opts.batch`` bucket-pads the sub-batches.  Returns the bucket ladder
    warmed.  Graph lanes with a False mask never expand, so warm-up cost is
    compile time, not search time.

    ``opts.batch`` must be set: without it, live traffic runs raw
    data-dependent shapes with no validity mask -- a different jit
    signature per batch -- so nothing warmed here would ever be reused and
    the compile cost would buy nothing.  Routes excluded by ``opts.force``
    are skipped (a pinned-brute engine never dispatches graph executables).
    """
    if opts.batch is None:
        raise ValueError(
            "warmup() needs SearchOptions.batch set: unpadded traffic runs "
            "raw data-dependent shapes that never match the warmed "
            "executables (pass batch=BatchSpec(...) on the engine options)")
    spec = opts.batch
    if buckets is None:
        bucket_list = spec.buckets()
    else:
        bucket_list = tuple(int(b) for b in buckets)
    target = backend
    inner = getattr(target, "inner", None)
    while inner is not None:
        target, inner = inner, getattr(inner, "inner", None)
    dim = int(target.dim)
    fp = F.compile_filter(F.FalseFilter(), target.schema)
    for b in bucket_list:
        queries = jnp.zeros((b, dim), jnp.float32)
        progs = {k: jnp.asarray(v)
                 for k, v in F.stack_programs([fp] * b).items()}
        valid = np.zeros((b,), bool)
        record(registry, "estimate", b, 0)
        np.asarray(target.estimate(progs))
        if opts.force != "brute":
            record(registry, "graph", b, 0, opts)
            out = target.search_graph(queries, progs,
                                      jnp.zeros((b,), jnp.float32), opts,
                                      valid=valid)
            np.asarray(out["ids"])
        if opts.force != "graph":
            record(registry, "brute", b, 0, opts)
            bid, _ = target.search_brute(queries, progs, opts, valid=valid)
            np.asarray(bid)
    return bucket_list
