"""Selectivity estimation by random sampling (paper Section 4.2).

The estimator draws a fixed random subset of the dataset once at index-build
time (so the sampled attribute rows are a small dense block that stays hot in
VMEM/cache) and, per query, evaluates the compiled filter program over the
sample: ``p_hat = mean(mask)``.

Because the number of target points in a sample without replacement follows a
hyper-geometric distribution, the relative error of ``p_hat`` is (Eq. 1)

    rel_err = sqrt((1-p) / (n p) * (1 - n/N))

which stays around 1% for million-scale datasets at a 1% sampling rate down to
p ~ 1%; below that the selector routes to PreFBF anyway (whose execution does
not consume ``p_hat``), so estimator error there is inconsequential.
"""
from __future__ import annotations

import numpy as np

from . import filters as F


def sample_indices(n: int, rate: float = 0.01, min_size: int = 256,
                   max_size: int = 65536, seed: int = 0) -> np.ndarray:
    """Fixed sample drawn once at build time (without replacement)."""
    size = int(np.clip(int(round(n * rate)), min(min_size, n), min(max_size, n)))
    rng = np.random.default_rng(seed)
    return np.sort(rng.choice(n, size=size, replace=False)).astype(np.int32)


def relative_error(n: int, p: float, total: int) -> float:
    """Eq. 1: hyper-geometric relative error of the sampled estimate."""
    if p <= 0.0:
        return float("inf")
    return float(np.sqrt((1.0 - p) / (n * p) * max(0.0, 1.0 - n / total)))


def estimate_selectivity(program, sample_ints, sample_floats, xp=np):
    """p_hat for a single compiled program over the pre-drawn sample rows."""
    mask = F.eval_program(program, sample_ints, sample_floats, xp=xp)
    return mask.mean(dtype=sample_floats.dtype if xp is not np else np.float64)


def estimate_selectivity_batched(programs, sample_ints, sample_floats, xp=np):
    """(B,) p_hat for batched programs.  Pure ufunc math: works as numpy or
    traced jax (the distributed selector psum-averages per-shard results)."""
    mask = F.eval_program_batched(programs, sample_ints, sample_floats, xp=xp)
    return mask.mean(axis=1)


def exact_selectivity(program, attrs: "F.AttributeTable") -> float:
    """Ground-truth p by full scan (tests / benchmarks only)."""
    mask = F.eval_program(program, attrs.ints, attrs.floats)
    return float(mask.mean())
