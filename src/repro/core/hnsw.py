"""Offline HNSW construction (paper Section 2.2) with Delta_d recording.

FAVOR deliberately uses a *conventional* proximity graph (guideline G.1): the
index is a vanilla HNSW built with the standard insertion algorithm and the
select-neighbors heuristic -- no attribute-aware edges.  Construction is an
offline, host-side phase (the paper builds on CPU too), so this module is
plain numpy; the *search* phase is the TPU-side JAX/Pallas code in search.py.

During construction we record, for every inserted node, the distance to its
alpha-th and beta-th (= efc-th) nearest candidates (paper section 6.3.1 uses
the efc-range candidates as approximate alpha/beta-th nearest neighbors) and
store the dataset-global Delta_d (Eq. 5) in the index metadata.

The finalized index is a set of flat, padded int32 neighbor arrays -- exactly
the layout the JAX search consumes and the dry-run shards across the mesh.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from dataclasses import dataclass, field

import numpy as np

from . import exclusion


@dataclass
class HnswParams:
    M: int = 16            # max degree at levels > 0
    M0: int | None = None  # max degree at base layer (default 2M)
    efc: int = 100         # construction beam width
    ml: float | None = None  # level sampling scale (default 1/ln M)
    alpha: int = 10        # Delta_d curve anchor (paper: alpha=10, beta=efc)
    heuristic: bool = True  # select-neighbors heuristic vs simple closest
    seed: int = 0

    def __post_init__(self):
        if self.M0 is None:
            self.M0 = 2 * self.M
        if self.ml is None:
            self.ml = 1.0 / math.log(self.M)


@dataclass
class HnswIndex:
    vectors: np.ndarray          # (N, d) float32
    levels: list[np.ndarray]     # levels[l]: (N, M_l) int32 neighbor ids, -1 pad
    node_level: np.ndarray       # (N,) int16 topmost level of each node
    entry_point: int             # -1 when the graph has no linked node
    max_level: int
    delta_d: float
    params: HnswParams
    norms: np.ndarray = field(default=None)  # (N,) |v|^2 cache
    # persisted quantization state (save/load round-trips it alongside the
    # graph arrays): {"kind": "pq"|"sq", "codes": (N, *) uint8, "dim": int,
    # plus the codebook tables} -- None when the index carries no codes
    quant_state: dict | None = field(default=None, repr=False)

    def __post_init__(self):
        if self.norms is None:
            self.norms = np.einsum("nd,nd->n", self.vectors, self.vectors)

    @property
    def n(self) -> int:
        return int(self.vectors.shape[0])

    @property
    def dim(self) -> int:
        return int(self.vectors.shape[1])

    def neighbors(self, node: int, level: int) -> np.ndarray:
        row = self.levels[level][node]
        return row[row >= 0]

    def storage_bytes(self) -> int:
        b = self.vectors.nbytes + self.node_level.nbytes
        for lv in self.levels:
            b += lv.nbytes
        return b

    def save(self, path: str, quant: dict | None = None) -> None:
        """Persist the index; ``quant`` (or ``self.quant_state``) rides along
        under ``quant_*`` keys so a reloaded index can serve the compressed
        routes without re-training or re-encoding."""
        arrs = {f"level_{l}": lv for l, lv in enumerate(self.levels)}
        q = quant if quant is not None else self.quant_state
        if q is not None:
            arrs.update({f"quant_{k}": np.asarray(v) for k, v in q.items()})
        np.savez_compressed(
            path, vectors=self.vectors, node_level=self.node_level,
            entry_point=self.entry_point, max_level=self.max_level,
            delta_d=self.delta_d, n_levels=len(self.levels),
            params=np.array([self.params.M, self.params.M0, self.params.efc,
                             self.params.alpha, self.params.seed], np.int64),
            ml=self.params.ml, **arrs)

    @staticmethod
    def load(path: str) -> "HnswIndex":
        z = np.load(path)
        n_levels = int(z["n_levels"])
        M, M0, efc, alpha, seed = (int(x) for x in z["params"])
        params = HnswParams(M=M, M0=M0, efc=efc, alpha=alpha, seed=seed,
                            ml=float(z["ml"]))
        quant_state = None
        qkeys = [k for k in z.files if k.startswith("quant_")]
        if qkeys:
            quant_state = {}
            for k in qkeys:
                v = z[k]
                name = k[len("quant_"):]
                if name == "kind":
                    quant_state[name] = str(v)
                elif name == "dim":
                    quant_state[name] = int(v)
                else:
                    quant_state[name] = v
        return HnswIndex(
            vectors=z["vectors"],
            levels=[z[f"level_{l}"] for l in range(n_levels)],
            node_level=z["node_level"],
            entry_point=int(z["entry_point"]),
            max_level=int(z["max_level"]),
            delta_d=float(z["delta_d"]),
            params=params,
            quant_state=quant_state,
        )


class _Builder:
    """Insertion-based construction with list-of-list adjacency."""

    def __init__(self, dim: int, params: HnswParams, capacity: int):
        self.p = params
        self.dim = dim
        self.vectors = np.zeros((capacity, dim), np.float32)
        self.norms = np.zeros((capacity,), np.float32)
        self.adj: list[list[list[int]]] = []  # adj[node][level] -> neighbor ids
        self.node_level: list[int] = []
        self.entry_point = -1
        self.max_level = -1
        self.n = 0
        self.rng = np.random.default_rng(params.seed)
        self._d_alpha_sum = 0.0
        self._d_beta_sum = 0.0
        self._d_span_sum = 0.0
        self._d_count = 0

    # -- distances ----------------------------------------------------------
    def _dist_many(self, q: np.ndarray, ids: np.ndarray) -> np.ndarray:
        v = self.vectors[ids]
        d2 = self.norms[ids] - 2.0 * (v @ q) + q @ q
        return np.sqrt(np.maximum(d2, 0.0))

    # -- greedy layer search --------------------------------------------------
    def _search_layer(self, q: np.ndarray, eps: list[tuple[float, int]], ef: int,
                      level: int) -> list[tuple[float, int]]:
        """GreedySearch (Algorithm 1).  Returns ascending (dist, id) list."""
        visited = set()
        cand: list[tuple[float, int]] = []   # min-heap
        res: list[tuple[float, int]] = []    # max-heap via negated dist
        for d, e in eps:
            if e in visited:
                continue
            visited.add(e)
            heapq.heappush(cand, (d, e))
            heapq.heappush(res, (-d, e))
        while cand:
            d_a, v_a = heapq.heappop(cand)
            if d_a > -res[0][0]:
                break
            nbrs = [u for u in self.adj[v_a][level] if u not in visited]
            if not nbrs:
                continue
            visited.update(nbrs)
            ids = np.asarray(nbrs, np.int64)
            ds = self._dist_many(q, ids)
            for d, u in zip(ds.tolist(), nbrs):
                if len(res) < ef or d < -res[0][0]:
                    heapq.heappush(cand, (d, u))
                    heapq.heappush(res, (-d, u))
                    if len(res) > ef:
                        heapq.heappop(res)
        out = sorted((-nd, u) for nd, u in res)
        return out

    # -- neighbor selection ---------------------------------------------------
    def _select_arrays(self, ids: np.ndarray, ds: np.ndarray, m: int) -> list[int]:
        """select_neighbors_heuristic: keep c iff it is closer to q than to any
        already-kept neighbor (relative-neighborhood pruning).  ``ids``/``ds``
        must be ascending by distance.  One (c x c) GEMM, then a cheap greedy."""
        c = len(ids)
        if not self.p.heuristic or c <= m:
            return [int(u) for u in ids[:m]]
        v = self.vectors[ids]
        nn = self.norms[ids]
        dc2 = nn[:, None] + nn[None, :] - 2.0 * (v @ v.T)  # squared cand-cand
        ds2 = ds * ds
        # greedy RNG prune: i is dominated once some kept j has d(i,j) <= d(q,i);
        # one vectorized update per KEPT element.
        dom = np.zeros(c, bool)
        kept: list[int] = []
        for i in range(c):
            if len(kept) >= m:
                break
            if dom[i]:
                continue
            kept.append(i)
            dom |= dc2[:, i] <= ds2
        if len(kept) < m:  # backfill with closest pruned candidates
            chosen = np.zeros(c, bool)
            chosen[kept] = True
            for i in range(c):
                if len(kept) >= m:
                    break
                if not chosen[i]:
                    kept.append(i)
        return [int(ids[i]) for i in kept]

    def _select(self, cands: list[tuple[float, int]], m: int) -> list[int]:
        ids = np.asarray([u for _, u in cands], np.int64)
        ds = np.asarray([d for d, _ in cands])
        return self._select_arrays(ids, ds, m)

    def _shrink(self, node: int, level: int, m: int) -> None:
        lst = self.adj[node][level]
        if len(lst) <= m:
            return
        ids = np.asarray(lst, np.int64)
        ds = self._dist_many(self.vectors[node], ids)
        order = np.argsort(ds, kind="stable")
        self.adj[node][level] = self._select_arrays(ids[order], ds[order], m)

    # -- insertion ------------------------------------------------------------
    def _register(self, q: np.ndarray, lvl: int) -> int:
        """Allocate a node row (vector + empty adjacency) without linking."""
        node = self.n
        self.vectors[node] = q
        self.norms[node] = float(q @ q)
        self.adj.append([[] for _ in range(lvl + 1)])
        self.node_level.append(lvl)
        self.n += 1
        return node

    def record_curve(self, curve: np.ndarray) -> None:
        """Eq. 5 slope from one node's ascending candidate-distance curve
        (approximate alpha-th / beta-th nearest neighbors, section 6.3.1).
        Shared by the sequential insert loop and the bulk-build path."""
        if len(curve) < 2:
            return
        a = min(self.p.alpha, len(curve)) - 1
        b = len(curve) - 1
        if b > a:
            self._d_alpha_sum += float(curve[a])
            self._d_beta_sum += float(curve[b])
            self._d_span_sum += float(b - a)
            self._d_count += 1

    def draw_level(self) -> int:
        return int(-math.log(max(self.rng.random(), 1e-12)) * self.p.ml)

    def _link_node(self, node: int, q: np.ndarray, lvl: int) -> None:
        """Descend + per-level candidate search + reciprocal linking for an
        already-registered node (the body of the standard insert)."""
        if self.entry_point < 0:
            self.entry_point = node
            self.max_level = lvl
            return

        ep = self.entry_point
        d_ep = float(self._dist_many(q, np.asarray([ep]))[0])
        eps = [(d_ep, ep)]
        for level in range(self.max_level, lvl, -1):
            eps = self._search_layer(q, eps, 1, level)[:1]

        for level in range(min(lvl, self.max_level), -1, -1):
            cands = self._search_layer(q, eps, self.p.efc, level)
            if level == 0:
                self.record_curve(np.asarray([d for d, _ in cands]))
            m = self.p.M0 if level == 0 else self.p.M
            sel = self._select(cands, m)
            self.adj[node][level] = list(sel)
            for u in sel:
                self.adj[u][level].append(node)
                self._shrink(u, level, m)
            eps = cands
        if lvl > self.max_level:
            self.max_level = lvl
            self.entry_point = node

    def insert(self, q: np.ndarray) -> int:
        node = self._register(q, self.draw_level())
        self._link_node(node, q, self.node_level[node])
        return node

    # -- finalize --------------------------------------------------------------
    def finalize(self) -> HnswIndex:
        n = self.n
        levels: list[np.ndarray] = []
        # always emit level 0, even for an empty or all-unlinked builder:
        # downstream consumers (graph_arrays, the sharded flatten) index
        # levels[0] unconditionally
        for level in range(max(self.max_level, 0) + 1):
            m = self.p.M0 if level == 0 else self.p.M
            arr = np.full((n, m), -1, np.int32)
            for v in range(n):
                if level < len(self.adj[v]):
                    nb = self.adj[v][level][:m]
                    arr[v, : len(nb)] = nb
            levels.append(arr)
        if self._d_count:
            # Eq. 5: Delta_d = (mean d_beta - mean d_alpha) / (beta - alpha)
            delta_d = (self._d_beta_sum - self._d_alpha_sum) / max(
                self._d_span_sum, 1e-12)
        else:
            delta_d = 0.0
        return HnswIndex(
            vectors=self.vectors[:n].copy(),
            levels=levels,
            node_level=np.asarray(self.node_level, np.int16),
            entry_point=self.entry_point,
            max_level=self.max_level,
            delta_d=float(delta_d),
            params=self.p,
            norms=self.norms[:n].copy(),
        )


def build_hnsw(vectors: np.ndarray, params: HnswParams | None = None,
               progress_every: int = 0) -> HnswIndex:
    """Build an HNSW index over ``vectors`` (N, d) float32."""
    params = params or HnswParams()
    vectors = np.ascontiguousarray(vectors, np.float32)
    b = _Builder(vectors.shape[1], params, vectors.shape[0])
    for i in range(vectors.shape[0]):
        b.insert(vectors[i])
        if progress_every and (i + 1) % progress_every == 0:
            print(f"  hnsw build {i + 1}/{vectors.shape[0]}")
    return b.finalize()
