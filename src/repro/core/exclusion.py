"""Exclusion-distance determination (paper Sections 5.1 and 5.3).

Equation (2):   Dis_bar(q, v) = Dis(q, v) + D * [attributes violate F]

Equation (5):   global linear-model slope of the m-th-NN distance curve,
                Delta_d = (d_alpha - d_beta) / (alpha - beta),
                with d_m the dataset-average distance to the m-th nearest
                neighbor; alpha = 10 and beta = efc in the paper's setup.
                Recorded offline during index construction from each inserted
                node's efc-candidate list (paper section 6.3.1).

Equation (14):  D = (1-p) (ef - p) Delta_d / (2 p), then normalized by ef
                ("Empirically, normalizing this value by ef is found to
                further enhance robustness"), i.e.

                    D = (1 - p) (ef - p) Delta_d / (2 p ef)

                This is the midpoint of the admissible band (Ineq. 13)
                    (1-p)(k/p - 1) Dd  <  D  <  (1-p)(ef-k)/p Dd
                by the minimax argument of section 5.3.2.

``p`` is the estimated selectivity; the selector guarantees p >= lambda when
the graph path runs, but benchmarks may force the graph path at tiny p, so we
clamp to ``p_min`` to keep D finite.
"""
from __future__ import annotations

import numpy as np


def delta_d_from_curve(dists_sorted, alpha: int = 10, beta: int = 100):
    """Eq. 5 from one node's sorted neighbor-distance curve.

    dists_sorted: (m,) ascending distances to the 1st..m-th nearest neighbor.
    Uses the last entry when the curve is shorter than beta (paper 6.3.1
    uses the efc-range candidates as approximate alpha/beta-th neighbors).
    """
    m = len(dists_sorted)
    if m < 2:
        return 0.0
    a = min(alpha, m) - 1
    b = min(beta, m) - 1
    if b <= a:
        a, b = 0, m - 1
    return float((dists_sorted[b] - dists_sorted[a]) / (b - a))


def delta_d_global(per_node_alpha, per_node_beta, alpha: int, beta: int) -> float:
    """Eq. 5 with dataset-average d_alpha / d_beta accumulated during build."""
    d_a = float(np.mean(per_node_alpha))
    d_b = float(np.mean(per_node_beta))
    return (d_b - d_a) / float(beta - alpha)


def exclusion_distance(p, ef: int, delta_d: float, *, k: int = 10,
                       strategy: str = "lo", normalize: bool | None = None,
                       p_min: float = 1e-4, xp=np):
    """Selectivity-aware exclusion distance.  Traced-safe; per-query ``p``.

    strategy:
      "lo"   (default) -- the LOWER edge of the admissible band (Ineq. 13):
             D = (1-p)(k/p - 1) Delta_d.  *Minimal sufficient exclusion*:
             NTD are pushed just beyond the target-set radius R(q, S) -- the
             exclusion guarantee of Fig. 3c with maximal connectivity margin.
             Measured across both data regimes (EXPERIMENTS.md section Perf
             fidelity iterations 0-1) this wins or ties everywhere the
             paper's midpoint or its ef-normalized variant degrade.
      "mid"  -- the paper's Eq. 14 midpoint, (1-p)(ef-p) Delta_d / (2p).
             Optimal under the minimax argument WHEN the linear model holds
             out to the ef/p-th neighbor; at small N or tight clusters it
             lands in the excessive-D regime (Fig. 3b) and recall drops.
      "mid_norm" -- Eq. 14 divided by ef (the other reading of the paper's
             "normalizing by ef" remark); ~ef x too small at low p.

    ``normalize`` (bool) is kept for backwards compatibility and maps to
    "mid" / "mid_norm".
    """
    if normalize is not None:
        strategy = "mid_norm" if normalize else "mid"
    p = xp.clip(p, p_min, 1.0)
    if strategy == "lo":
        return (1.0 - p) * (k / p - 1.0) * delta_d
    d = (1.0 - p) * (ef - p) * delta_d / (2.0 * p)
    if strategy == "mid_norm":
        d = d / ef
    return d


def exclusion_bounds(p: float, ef: int, k: int, delta_d: float) -> tuple[float, float]:
    """Ineq. 13 admissible band (diagnostics / property tests)."""
    lo = (1.0 - p) * (k / p - 1.0) * delta_d
    hi = (1.0 - p) * (ef - k) / p * delta_d
    return lo, hi


def d_max(query, vectors, mask) -> float:
    """Ablation strategy D_max (section 6.4.1): push every TD in front of every
    NTD:  max_T Dis(q, v^T) - min_N Dis(q, v^N).  Brute force; ablation only."""
    d = np.linalg.norm(vectors - query[None, :], axis=1)
    td = d[mask]
    ntd = d[~mask]
    if len(td) == 0 or len(ntd) == 0:
        return 0.0
    return max(0.0, float(td.max() - ntd.min()))
