"""Filter algebra and the DNF "filter program" compiler.

The paper (Section 2.1.2) supports four predicate families over scalar
attributes -- Equality, Inclusion, Range, Logic (AND/OR/NOT) -- and FAVOR is
*filter-agnostic*: any predicate must be evaluable during search without
touching the index structure.

TPU adaptation (DESIGN.md section 3): predicates are compiled once per query
into a dense **filter program** -- a fixed-width disjunctive normal form whose
conjunctions are (per-int-column bitmask, per-float-column interval) tests.
Evaluation is branch-free vectorized arithmetic, so it can run inside jit,
shard_map and Pallas kernels, batched over queries, with the predicate as
*data* rather than *code*.

Columns:
  * ``bool`` / ``int`` columns: small ordinal vocabulary (< 32); conjunction
    constraint is an allowed-value bitmask (uint32).  Equality -> one bit,
    Inclusion -> several bits, Range -> a run of bits, NOT -> complement.
  * ``float`` columns: conjunction constraint is a closed interval
    ``[lo, hi]``; NOT(Range) splits into two disjuncts with nextafter-strict
    bounds.

The compiler lowers the AST to negation normal form and distributes AND over
OR to DNF, erroring out above ``max_width`` (default 8) rather than silently
truncating.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from dataclasses import dataclass
from typing import Sequence

import numpy as np

INT_KINDS = ("bool", "int")
MAX_INT_VOCAB = 32


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ColumnSpec:
    name: str
    kind: str  # "bool" | "int" | "float"
    vocab: int | None = None  # required for int; bool -> 2

    def __post_init__(self):
        if self.kind not in ("bool", "int", "float"):
            raise ValueError(f"unknown column kind {self.kind!r}")
        if self.kind == "bool":
            object.__setattr__(self, "vocab", 2)
        if self.kind == "int":
            if self.vocab is None:
                raise ValueError(f"int column {self.name!r} needs a vocab size")
            if self.vocab > MAX_INT_VOCAB:
                raise ValueError(
                    f"int column {self.name!r} vocab {self.vocab} > {MAX_INT_VOCAB}; "
                    "declare it as a float (ordered) column instead"
                )


@dataclass(frozen=True)
class Schema:
    columns: tuple[ColumnSpec, ...]

    def __post_init__(self):
        names = [c.name for c in self.columns]
        if len(set(names)) != len(names):
            raise ValueError("duplicate column names")

    @property
    def int_columns(self) -> tuple[ColumnSpec, ...]:
        return tuple(c for c in self.columns if c.kind in INT_KINDS)

    @property
    def float_columns(self) -> tuple[ColumnSpec, ...]:
        return tuple(c for c in self.columns if c.kind == "float")

    def int_index(self, name: str) -> int:
        for i, c in enumerate(self.int_columns):
            if c.name == name:
                return i
        raise KeyError(name)

    def float_index(self, name: str) -> int:
        for i, c in enumerate(self.float_columns):
            if c.name == name:
                return i
        raise KeyError(name)

    def column(self, name: str) -> ColumnSpec:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(name)


# Paper section 6.1.2: every vector carries one bool, one int in U{0..9} and one
# float in U[0,100].
def paper_schema(n_bool: int = 1, n_int: int = 1, n_float: int = 1,
                 int_vocab: int = 10) -> Schema:
    cols: list[ColumnSpec] = []
    for i in range(n_bool):
        cols.append(ColumnSpec(f"b{i}", "bool"))
    for i in range(n_int):
        cols.append(ColumnSpec(f"i{i}", "int", int_vocab))
    for i in range(n_float):
        cols.append(ColumnSpec(f"f{i}", "float"))
    return Schema(tuple(cols))


# ---------------------------------------------------------------------------
# Filter AST
# ---------------------------------------------------------------------------
class Filter:
    def __and__(self, other: "Filter") -> "Filter":
        return And(self, other)

    def __or__(self, other: "Filter") -> "Filter":
        return Or(self, other)

    def __invert__(self) -> "Filter":
        return Not(self)


@dataclass(frozen=True)
class TrueFilter(Filter):
    pass


@dataclass(frozen=True)
class FalseFilter(Filter):
    pass


@dataclass(frozen=True)
class Equality(Filter):
    column: str
    value: float | int | bool


@dataclass(frozen=True)
class Inclusion(Filter):
    column: str
    values: tuple

    def __init__(self, column: str, values: Sequence):
        object.__setattr__(self, "column", column)
        object.__setattr__(self, "values", tuple(values))


@dataclass(frozen=True)
class Range(Filter):
    """Closed interval lo <= a <= hi (either bound may be None = unbounded)."""

    column: str
    lo: float | None = None
    hi: float | None = None


@dataclass(frozen=True)
class And(Filter):
    children: tuple

    def __init__(self, *children: Filter):
        object.__setattr__(self, "children", tuple(children))


@dataclass(frozen=True)
class Or(Filter):
    children: tuple

    def __init__(self, *children: Filter):
        object.__setattr__(self, "children", tuple(children))


@dataclass(frozen=True)
class Not(Filter):
    child: Filter


# ---------------------------------------------------------------------------
# Conjunction representation used during compilation
# ---------------------------------------------------------------------------
@dataclass
class _Conj:
    imask: np.ndarray  # (m_i,) uint32 allowed-value bitmasks
    flo: np.ndarray  # (m_f,) float32
    fhi: np.ndarray  # (m_f,) float32

    def copy(self) -> "_Conj":
        return _Conj(self.imask.copy(), self.flo.copy(), self.fhi.copy())

    def feasible(self) -> bool:
        return bool(np.all(self.imask != 0) and np.all(self.flo <= self.fhi))


def _full_conj(schema: Schema) -> _Conj:
    m_i = len(schema.int_columns)
    m_f = len(schema.float_columns)
    imask = np.zeros((m_i,), np.uint32)
    for j, c in enumerate(schema.int_columns):
        imask[j] = np.uint32((1 << c.vocab) - 1)
    flo = np.full((m_f,), -np.inf, np.float32)
    fhi = np.full((m_f,), np.inf, np.float32)
    return _Conj(imask, flo, fhi)


def _int_bits(values: Sequence[int], vocab: int, column: str) -> np.uint32:
    mask = np.uint32(0)
    for v in values:
        v = int(v)
        if not (0 <= v < vocab):
            raise ValueError(f"value {v} out of vocab [0,{vocab}) for column {column!r}")
        mask |= np.uint32(1) << np.uint32(v)
    return mask


def _strict_below(x: float) -> float:
    return float(np.nextafter(np.float32(x), np.float32(-np.inf)))


def _strict_above(x: float) -> float:
    return float(np.nextafter(np.float32(x), np.float32(np.inf)))


def _leaf_conjs(f: Filter, schema: Schema, negated: bool) -> list[_Conj]:
    """Compile a (possibly negated) leaf to a list of conjunctions (a DNF)."""
    if isinstance(f, TrueFilter):
        return [] if negated else [_full_conj(schema)]
    if isinstance(f, FalseFilter):
        return [_full_conj(schema)] if negated else []

    if isinstance(f, Equality):
        col = schema.column(f.column)
        if col.kind in INT_KINDS:
            j = schema.int_index(f.column)
            bits = _int_bits([int(f.value)], col.vocab, f.column)
            c = _full_conj(schema)
            c.imask[j] = ~bits & c.imask[j] if negated else bits
            return [c]
        j = schema.float_index(f.column)
        v = float(f.value)
        if not negated:
            c = _full_conj(schema)
            c.flo[j], c.fhi[j] = v, v
            return [c]
        lo_c, hi_c = _full_conj(schema), _full_conj(schema)
        lo_c.fhi[j] = _strict_below(v)
        hi_c.flo[j] = _strict_above(v)
        return [lo_c, hi_c]

    if isinstance(f, Inclusion):
        col = schema.column(f.column)
        if col.kind not in INT_KINDS:
            # float inclusion == OR of equalities
            dnf: list[_Conj] = []
            for v in f.values:
                dnf.extend(_leaf_conjs(Equality(f.column, v), schema, False))
            if negated:
                raise ValueError("NOT(Inclusion) on float columns is not supported; "
                                 "use Range complements")
            return dnf
        j = schema.int_index(f.column)
        bits = _int_bits(f.values, col.vocab, f.column)
        c = _full_conj(schema)
        full = c.imask[j]
        c.imask[j] = (~bits & full) if negated else bits
        return [c]

    if isinstance(f, Range):
        col = schema.column(f.column)
        lo = -math.inf if f.lo is None else float(f.lo)
        hi = math.inf if f.hi is None else float(f.hi)
        if col.kind in INT_KINDS:
            j = schema.int_index(f.column)
            vals = [v for v in range(col.vocab) if lo <= v <= hi]
            bits = _int_bits(vals, col.vocab, f.column)
            c = _full_conj(schema)
            full = c.imask[j]
            c.imask[j] = (~bits & full) if negated else bits
            return [c]
        j = schema.float_index(f.column)
        if not negated:
            c = _full_conj(schema)
            c.flo[j], c.fhi[j] = lo, hi
            return [c]
        out = []
        if lo > -math.inf:
            c = _full_conj(schema)
            c.fhi[j] = _strict_below(lo)
            out.append(c)
        if hi < math.inf:
            c = _full_conj(schema)
            c.flo[j] = _strict_above(hi)
            out.append(c)
        return out

    raise TypeError(f"not a leaf filter: {f!r}")


def _conj_and(a: _Conj, b: _Conj) -> _Conj:
    return _Conj(a.imask & b.imask, np.maximum(a.flo, b.flo), np.minimum(a.fhi, b.fhi))


def _to_dnf(f: Filter, schema: Schema, negated: bool, max_width: int) -> list[_Conj]:
    if isinstance(f, Not):
        return _to_dnf(f.child, schema, not negated, max_width)
    if isinstance(f, And) or isinstance(f, Or):
        is_and = isinstance(f, And) != negated  # de Morgan
        child_dnfs = [_to_dnf(c, schema, negated, max_width) for c in f.children]
        if not is_and:
            out = [c for d in child_dnfs for c in d]
        else:
            out = [_full_conj(schema)]
            for d in child_dnfs:
                out = [_conj_and(a, b) for a in out for b in d]
                out = [c for c in out if c.feasible()]
                if len(out) > 4 * max_width:
                    raise ValueError(
                        f"filter DNF exceeds width {max_width}; simplify the predicate")
        out = [c for c in out if c.feasible()]
        if len(out) > 4 * max_width:
            raise ValueError(f"filter DNF exceeds width {max_width}")
        return out
    return [c for c in _leaf_conjs(f, schema, negated) if c.feasible()]


# ---------------------------------------------------------------------------
# Compiled program
# ---------------------------------------------------------------------------
@dataclass
class FilterProgram:
    """Fixed-width DNF as dense numpy arrays (one query).

    valid : (W,)  float32 in {0,1} -- disjunct is live
    imask : (W, m_i) uint32        -- per-int-column allowed-value bitmask
    flo   : (W, m_f) float32       -- per-float-column interval low
    fhi   : (W, m_f) float32       -- per-float-column interval high
    """

    valid: np.ndarray
    imask: np.ndarray
    flo: np.ndarray
    fhi: np.ndarray

    @property
    def width(self) -> int:
        return int(self.valid.shape[0])


def compile_filter(f: Filter, schema: Schema, width: int = 8) -> FilterProgram:
    conjs = _to_dnf(f, schema, False, max_width=width)
    if len(conjs) > width:
        raise ValueError(f"filter needs DNF width {len(conjs)} > {width}")
    m_i = len(schema.int_columns)
    m_f = len(schema.float_columns)
    valid = np.zeros((width,), np.float32)
    imask = np.zeros((width, m_i), np.uint32)
    flo = np.full((width, m_f), np.inf, np.float32)   # infeasible padding
    fhi = np.full((width, m_f), -np.inf, np.float32)
    for w, c in enumerate(conjs):
        valid[w] = 1.0
        imask[w] = c.imask
        flo[w] = c.flo
        fhi[w] = c.fhi
    return FilterProgram(valid, imask, flo, fhi)


# ---------------------------------------------------------------------------
# Canonical signatures (serving-side cache keys)
#
# Two predicates that compile to the same *set* of DNF conjunctions are
# semantically identical, whatever the AST looked like: the compiler already
# normalizes double negation (NNF) and associativity/commutativity of AND is
# elementwise (bitmask-&, interval-intersect), so only the disjunct *order*
# and duplicate/subsumed disjuncts distinguish equivalent programs.  The
# canonical form therefore drops dead rows, drops rows subsumed by another
# row, sorts the survivors bytewise and hashes them -- a stable 128-bit key
# that every cache layer (selectivity, candidate, semantic) can share.
# Signature equality is *sound* (equal signature => equal predicate on every
# row); it is deliberately not complete (e.g. two overlapping ranges that
# union to a third are not merged).
# ---------------------------------------------------------------------------
SIGNATURE_VERSION = 1  # bump when the canonical byte layout changes


def _canon_rows(valid, imask, flo, fhi) -> list[bytes]:
    """Canonical serialized conjunctions of one program (see module note).

    Runs once per query per cache operation on the serving hot path, so the
    subsumption test is vectorized over all W^2 row pairs instead of a
    Python pair loop.
    """
    valid = np.asarray(valid)
    imask = np.asarray(imask, np.uint32)
    # -0.0 normalization: -0.0 and 0.0 compare equal but serialize
    # differently; force the canonical zero before taking bytes
    flo = np.asarray(flo, np.float32) + 0.0
    fhi = np.asarray(fhi, np.float32) + 0.0
    live = np.nonzero(valid > 0)[0]
    if live.size == 0:
        return []
    im, lo, hi = imask[live], flo[live], fhi[live]
    # cover[v, w] -- row v covers row w: superset bitmask on every int
    # column AND containing interval on every float column; mutual cover is
    # row identity, strict cover marks w subsumed (Or(a, a), Or(a, And(a,b)))
    cover = np.ones((live.size, live.size), bool)
    if im.shape[1]:
        cover &= ((im[:, None, :] & im[None, :, :]) == im[None, :, :]).all(-1)
    if lo.shape[1]:
        cover &= (lo[:, None, :] <= lo[None, :, :]).all(-1)
        cover &= (hi[:, None, :] >= hi[None, :, :]).all(-1)
    strict = cover & ~cover.T     # covers w without being covered back
    keep = ~strict.any(axis=0)
    rows = {im[w].tobytes() + lo[w].tobytes() + hi[w].tobytes()
            for w in np.nonzero(keep)[0]}
    return sorted(rows)


def program_signature(program) -> str:
    """Stable hex signature of one program's canonical DNF.

    ``program`` is a FilterProgram or a dict with 1-query arrays
    (valid (W,), imask (W, m_i), flo/fhi (W, m_f)).
    """
    if isinstance(program, FilterProgram):
        valid, imask = program.valid, program.imask
        flo, fhi = program.flo, program.fhi
    else:
        valid, imask = program["valid"], program["imask"]
        flo, fhi = program["flo"], program["fhi"]
    h = hashlib.blake2b(digest_size=16)
    m_i = int(np.asarray(imask).shape[-1])
    m_f = int(np.asarray(flo).shape[-1])
    h.update(f"favor-sig-v{SIGNATURE_VERSION}:{m_i}:{m_f}".encode())
    for row in _canon_rows(valid, imask, flo, fhi):
        h.update(b"|")
        h.update(row)
    return h.hexdigest()


def filter_signature(f: Filter, schema: Schema, width: int = 8) -> str:
    """Canonical signature of a filter AST: semantically equivalent
    reorderings (commuted AND/OR children, double negation, duplicate
    disjuncts) hash identically, so cache entries are shared across them."""
    return program_signature(compile_filter(f, schema, width))


def batch_signatures(programs: dict) -> list[str]:
    """Per-query signatures of a stacked (B, W, ...) program dict."""
    valid = np.asarray(programs["valid"])
    imask = np.asarray(programs["imask"])
    flo = np.asarray(programs["flo"])
    fhi = np.asarray(programs["fhi"])
    return [program_signature({"valid": valid[b], "imask": imask[b],
                               "flo": flo[b], "fhi": fhi[b]})
            for b in range(valid.shape[0])]


def stack_programs(programs: Sequence[FilterProgram]) -> dict[str, np.ndarray]:
    """Stack per-query programs into batched arrays (B, ...)."""
    width = max(p.width for p in programs)

    def pad(p: FilterProgram) -> FilterProgram:
        if p.width == width:
            return p
        pw = width - p.width
        return FilterProgram(
            np.pad(p.valid, (0, pw)),
            np.pad(p.imask, ((0, pw), (0, 0))),
            np.pad(p.flo, ((0, pw), (0, 0)), constant_values=np.inf),
            np.pad(p.fhi, ((0, pw), (0, 0)), constant_values=-np.inf),
        )

    ps = [pad(p) for p in programs]
    return {
        "valid": np.stack([p.valid for p in ps]),
        "imask": np.stack([p.imask for p in ps]),
        "flo": np.stack([p.flo for p in ps]),
        "fhi": np.stack([p.fhi for p in ps]),
    }


# ---------------------------------------------------------------------------
# Evaluation (works under numpy AND jax.numpy: only uses ufuncs/broadcasting)
# ---------------------------------------------------------------------------
def eval_program(program, attrs_int, attrs_float, xp=np):
    """Evaluate one filter program over attribute rows.

    program     : dict/FilterProgram with valid (W,), imask (W,m_i),
                  flo/fhi (W,m_f)
    attrs_int   : (N, m_i) int32   (bool columns stored as 0/1)
    attrs_float : (N, m_f) float32
    returns     : (N,) bool mask
    """
    if isinstance(program, FilterProgram):
        program = {"valid": program.valid, "imask": program.imask,
                   "flo": program.flo, "fhi": program.fhi}
    valid = program["valid"]  # (W,)
    imask = program["imask"]  # (W, m_i)
    flo, fhi = program["flo"], program["fhi"]  # (W, m_f)

    ok = valid[:, None] > 0  # (W, 1) broadcast over N
    if imask.shape[-1]:
        shifted = imask[:, None, :] >> attrs_int[None, :, :].astype(imask.dtype)
        ibit = (shifted & 1).astype(bool)  # (W, N, m_i)
        ok = ok & ibit.all(axis=-1)
    if flo.shape[-1]:
        af = attrs_float[None, :, :]
        fok = (af >= flo[:, None, :]) & (af <= fhi[:, None, :])
        ok = ok & fok.all(axis=-1)
    return ok.any(axis=0)


def eval_program_batched(programs, attrs_int, attrs_float, xp=np):
    """Batched programs (B, W, ...) over rows -> (B, N) mask."""
    valid = programs["valid"]  # (B, W)
    imask = programs["imask"]  # (B, W, m_i)
    flo, fhi = programs["flo"], programs["fhi"]  # (B, W, m_f)

    ok = valid[:, :, None] > 0  # (B, W, 1)
    if imask.shape[-1]:
        shifted = imask[:, :, None, :] >> attrs_int[None, None, :, :].astype(imask.dtype)
        ibit = (shifted & 1).astype(bool)  # (B, W, N, m_i)
        ok = ok & ibit.all(axis=-1)
    if flo.shape[-1]:
        af = attrs_float[None, None, :, :]
        fok = (af >= flo[:, :, None, :]) & (af <= fhi[:, :, None, :])
        ok = ok & fok.all(axis=-1)
    return ok.any(axis=1)  # (B, N)


def eval_program_gathered(programs, ints, floats, xp=np):
    """Batched programs over per-query gathered rows.

    programs : dict with valid (B, W), imask (B, W, m_i), flo/fhi (B, W, m_f)
    ints     : (B, M, m_i) -- M rows gathered *per query* (graph neighbors)
    floats   : (B, M, m_f)
    returns  : (B, M) bool mask
    """
    valid = programs["valid"]  # (B, W)
    imask = programs["imask"]
    flo, fhi = programs["flo"], programs["fhi"]

    ok = valid[:, :, None] > 0  # (B, W, 1)
    if imask.shape[-1]:
        shifted = imask[:, :, None, :] >> ints[:, None, :, :].astype(imask.dtype)
        ok = ok & ((shifted & 1).astype(bool)).all(axis=-1)  # (B, W, M)
    if flo.shape[-1]:
        af = floats[:, None, :, :]
        fok = (af >= flo[:, :, None, :]) & (af <= fhi[:, :, None, :])
        ok = ok & fok.all(axis=-1)
    return ok.any(axis=1)  # (B, M)


def eval_filter_python(f: Filter, row: dict) -> bool:
    """Direct AST interpreter over one attribute row (property-test oracle)."""
    if isinstance(f, TrueFilter):
        return True
    if isinstance(f, FalseFilter):
        return False
    if isinstance(f, Equality):
        return row[f.column] == f.value
    if isinstance(f, Inclusion):
        return row[f.column] in f.values
    if isinstance(f, Range):
        lo = -math.inf if f.lo is None else f.lo
        hi = math.inf if f.hi is None else f.hi
        return lo <= row[f.column] <= hi
    if isinstance(f, And):
        return all(eval_filter_python(c, row) for c in f.children)
    if isinstance(f, Or):
        return any(eval_filter_python(c, row) for c in f.children)
    if isinstance(f, Not):
        return not eval_filter_python(f.child, row)
    raise TypeError(f"unknown filter {f!r}")


# ---------------------------------------------------------------------------
# Attribute table
# ---------------------------------------------------------------------------
@dataclass
class AttributeTable:
    schema: Schema
    ints: np.ndarray    # (N, m_i) int32
    floats: np.ndarray  # (N, m_f) float32

    def __post_init__(self):
        assert self.ints.ndim == 2 and self.floats.ndim == 2
        assert self.ints.shape[1] == len(self.schema.int_columns)
        assert self.floats.shape[1] == len(self.schema.float_columns)
        assert self.ints.shape[0] == self.floats.shape[0]

    @property
    def n(self) -> int:
        return int(self.ints.shape[0])

    def row(self, i: int) -> dict:
        out = {}
        for j, c in enumerate(self.schema.int_columns):
            v = int(self.ints[i, j])
            out[c.name] = bool(v) if c.kind == "bool" else v
        for j, c in enumerate(self.schema.float_columns):
            out[c.name] = float(self.floats[i, j])
        return out

    def take(self, idx: np.ndarray) -> "AttributeTable":
        return AttributeTable(self.schema, self.ints[idx], self.floats[idx])


def random_attributes(schema: Schema, n: int, seed: int = 0) -> AttributeTable:
    """Paper section 6.1.2 attribute generation: bool equiprobable, int uniform
    over the vocab, float uniform over [0, 100]."""
    rng = np.random.default_rng(seed)
    ints = np.zeros((n, len(schema.int_columns)), np.int32)
    for j, c in enumerate(schema.int_columns):
        ints[:, j] = rng.integers(0, c.vocab, size=n, dtype=np.int32)
    floats = rng.uniform(0.0, 100.0, size=(n, len(schema.float_columns))).astype(np.float32)
    return AttributeTable(schema, ints, floats)


# Paper section 6.1.1 canonical experiment filters ---------------------------
def paper_filters(schema: Schema, rng: np.random.Generator | None = None) -> dict[str, Filter]:
    """The six filtering scenarios of section 6.1.1 (selectivities in parens)."""
    rng = rng or np.random.default_rng(0)
    bcol = schema.int_columns[0].name            # bool col (b0)
    icol = [c for c in schema.int_columns if c.kind == "int"][0].name
    fcol = schema.float_columns[0].name
    eq_bool = Equality(bcol, True)               # 50%
    eq_int = Equality(icol, int(rng.integers(0, 10)))  # 10%
    inclusion = Inclusion(icol, sorted(rng.choice(10, size=3, replace=False).tolist()))  # 30%
    lo10 = float(rng.uniform(0, 90))
    range10 = Range(fcol, lo10, lo10 + 10.0)     # 10%
    lo50 = float(rng.uniform(0, 50))
    range50 = Range(fcol, lo50, lo50 + 50.0)     # 50%
    logic = And(eq_int, range50)                 # ~5%
    return {
        "equality_bool": eq_bool,
        "equality_int": eq_int,
        "inclusion": inclusion,
        "range_10": range10,
        "range_50": range50,
        "logic": logic,
    }
