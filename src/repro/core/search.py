"""JAX production search: batched FAVOR graph traversal on TPU.

TPU-native realization of Algorithms 2 + 3 (DESIGN.md section 3):

 * the query batch runs as ONE ``lax.while_loop`` whose state carries a lane
   per query; finished lanes are masked, the loop ends when all lanes do;
 * the candidate set C and result set R are fixed-capacity distance-sorted
   pools updated by merge-sort of (pool || new-neighbor-block) -- no dynamic
   heaps.  C capacity = ``cand_cap`` (default ef) is the bounded-memory
   approximation of the paper's unbounded heap; recall parity with the
   refimpl oracle is asserted in tests and measured in benchmarks;
 * each step gathers one neighbor block (B, M0) and evaluates distances with
   a single (B, M0, d) einsum -- MXU work -- plus the compiled filter program
   on the gathered attribute rows (branch-free bitmask/interval math);
 * the exclusion distance (Eq. 2) is a fused ``d + D * (1 - mask)`` select;
 * termination implements section 5.4: the usual adjusted-distance condition
   AND the TD-fraction guard ``pbar > pbar_min`` (0 disables);
 * the visited set is a dense per-query bool bitmap (O(B*N) bytes).

Everything here is jit/shard_map friendly: shapes static, no host callbacks.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import filters as F
from .hnsw import HnswIndex

INF = jnp.inf


@dataclass(frozen=True)
class SearchConfig:
    k: int = 10
    ef: int = 100
    cand_cap: int = 0          # 0 -> ef
    max_steps: int = 0         # 0 -> 8 * ef safety bound
    pbar_min: float = 0.5      # section 5.4 threshold (0 disables)
    gamma: float = 1.0         # Algorithm 3 line 8 slack
    use_pallas: bool = False   # route neighbor distance eval through Pallas

    @property
    def ccap(self) -> int:
        return self.cand_cap or self.ef

    @property
    def steps(self) -> int:
        return self.max_steps or 8 * self.ef


def graph_arrays(index: HnswIndex, attrs: F.AttributeTable) -> dict:
    """Flatten an HnswIndex + attribute table to the device array dict the
    production search (and the dry-run input_specs) consume."""
    upper = (np.stack(index.levels[1:], axis=0) if index.max_level >= 1
             else np.zeros((0, index.n, index.params.M), np.int32))
    return {
        "vectors": jnp.asarray(index.vectors),
        "norms": jnp.asarray(index.norms.astype(np.float32)),
        "neighbors0": jnp.asarray(index.levels[0]),
        "upper": jnp.asarray(upper),
        "entry": jnp.asarray(index.entry_point, jnp.int32),
        "attrs_int": jnp.asarray(attrs.ints),
        "attrs_float": jnp.asarray(attrs.floats),
    }


def _pairwise_dist(q: jnp.ndarray, vecs: jnp.ndarray, vnorm: jnp.ndarray) -> jnp.ndarray:
    """(B, d), (B, M, d), (B, M) -> true Euclidean distance (B, M).

    The dot is a *batched mat-vec* (one d-contraction per (b, m) pair), so
    it is written as multiply + last-axis reduce rather than an einsum:
    XLA lowers the reduce with a batch-size-independent accumulation order,
    which keeps results bit-identical when bucket padding changes B (a
    dot_general here picks different codegen for B=1 vs B=8 on CPU).  The
    contraction never fed the MXU efficiently anyway -- b is a batch dim.
    """
    qn = jnp.sum(q * q, axis=-1)  # (B,)
    dot = jnp.sum(q[:, None, :] * vecs, axis=-1)
    d2 = vnorm + qn[:, None] - 2.0 * dot
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def _descend(g: dict, queries: jnp.ndarray) -> jnp.ndarray:
    """Upper-layer greedy descent (no filtering), returns entry ids (B,)."""
    B = queries.shape[0]
    cur = jnp.full((B,), g["entry"], jnp.int32)
    curd = _pairwise_dist(queries, g["vectors"][cur][:, None, :],
                          g["norms"][cur][:, None])[:, 0]
    n_upper = g["upper"].shape[0]
    for li in range(n_upper - 1, -1, -1):
        level = g["upper"][li]

        def cond(state):
            _, _, moved = state
            return jnp.any(moved)

        def body(state):
            cur, curd, moved = state
            nbrs = level[cur]                      # (B, M)
            ok = nbrs >= 0
            safe = jnp.maximum(nbrs, 0)
            d = _pairwise_dist(queries, g["vectors"][safe], g["norms"][safe])
            d = jnp.where(ok, d, INF)
            j = jnp.argmin(d, axis=1)
            best = jnp.take_along_axis(d, j[:, None], axis=1)[:, 0]
            better = moved & (best < curd)
            new_cur = jnp.where(better, jnp.take_along_axis(safe, j[:, None], axis=1)[:, 0], cur)
            new_d = jnp.where(better, best, curd)
            return new_cur, new_d, better

        cur, curd, _ = jax.lax.while_loop(
            cond, body, (cur, curd, jnp.ones((B,), bool)))
    return cur


def _merge_pool(pool_d, pool_i, pool_t, new_d, new_i, new_t, cap: int):
    """Merge (B, cap) pools with (B, M) new entries, keep best ``cap``.
    Ineligible new entries must carry d=+inf."""
    d = jnp.concatenate([pool_d, new_d], axis=1)
    i = jnp.concatenate([pool_i, new_i], axis=1)
    t = jnp.concatenate([pool_t, new_t], axis=1)
    order = jnp.argsort(d, axis=1)[:, :cap]
    return (jnp.take_along_axis(d, order, axis=1),
            jnp.take_along_axis(i, order, axis=1),
            jnp.take_along_axis(t, order, axis=1))


@partial(jax.jit, static_argnames=("cfg",))
def favor_graph_search(g: dict, queries: jnp.ndarray, programs: dict,
                       D: jnp.ndarray, cfg: SearchConfig,
                       valid=None) -> dict:
    """Batched OptiGreedySearch (Algorithm 3) with exclusion distances.

    g         : graph_arrays dict (possibly one shard of the DB)
    queries   : (B, d) float32
    programs  : batched filter programs {valid (B,W), imask, flo, fhi}
    D         : (B,) per-query exclusion distance (Eq. 14, from p_hat)
    valid     : optional (B,) bool lane mask (bucket padding): False lanes
                start inactive -- they never expand a node, cost no search
                work, and return ids=-1 / dists=+inf / hops=0
    returns   : {"ids": (B,k) int32 (-1 pad), "dists": (B,k) f32 (+inf pad),
                 "hops": (B,), "path_td": (B,)}
    """
    B, dim = queries.shape
    N = g["vectors"].shape[0]
    M0 = g["neighbors0"].shape[1]
    ef, ccap = cfg.ef, cfg.ccap
    rows = jnp.arange(B)

    ep = _descend(g, queries)                        # (B,)

    # --- init pools with the entry point -----------------------------------
    ep_vec = g["vectors"][ep][:, None, :]
    ep_d = _pairwise_dist(queries, ep_vec, g["norms"][ep][:, None])[:, 0]
    ep_td = F.eval_program_gathered(
        programs, g["attrs_int"][ep][:, None, :],
        g["attrs_float"][ep][:, None, :], xp=jnp)[:, 0]
    ep_dbar = ep_d + jnp.where(ep_td, 0.0, D)

    cand_d = jnp.full((B, ccap), INF).at[:, 0].set(ep_dbar)
    cand_i = jnp.full((B, ccap), -1, jnp.int32).at[:, 0].set(ep)
    res_d = jnp.full((B, ef), INF).at[:, 0].set(ep_dbar)
    res_i = jnp.full((B, ef), -1, jnp.int32).at[:, 0].set(ep)
    res_t = jnp.zeros((B, ef), bool).at[:, 0].set(ep_td)
    visited = jnp.zeros((B, N), bool).at[rows, ep].set(True)
    active = (jnp.ones((B,), bool) if valid is None
              else jnp.asarray(valid, bool))
    hops = jnp.zeros((B,), jnp.int32)
    path_td = jnp.zeros((B,), jnp.int32)

    def cond(s):
        return jnp.any(s["active"]) & (s["step"] < cfg.steps)

    def body(s):
        cand_d, cand_i = s["cand_d"], s["cand_i"]
        res_d, res_i, res_t = s["res_d"], s["res_i"], s["res_t"]
        visited, active = s["visited"], s["active"]

        # -- extract argmin of C (Algorithm 3 line 6) ------------------------
        j = jnp.argmin(cand_d, axis=1)
        da = cand_d[rows, j]
        va = cand_i[rows, j]
        popped = active & jnp.isfinite(da)
        cand_d = jnp.where(active[:, None],
                           cand_d.at[rows, j].set(INF), cand_d)

        # -- termination (line 8, with section 5.4 guard) --------------------
        worst = jnp.max(res_d, axis=1)               # +inf while R not full
        n_valid = jnp.sum(jnp.isfinite(res_d), axis=1)
        n_td = jnp.sum(res_t & jnp.isfinite(res_d), axis=1)
        pbar = n_td / jnp.maximum(n_valid, 1)
        full = jnp.isfinite(worst)
        plain_term = (da > cfg.gamma * worst) & full
        guard_ok = (cfg.pbar_min <= 0.0) | (pbar > cfg.pbar_min)
        terminate = plain_term & guard_ok
        exhausted = ~jnp.isfinite(da)
        new_active = active & ~terminate & ~exhausted
        expand = new_active                          # lanes that expand v_a

        # -- gather neighbor block -------------------------------------------
        va_safe = jnp.maximum(va, 0)
        nbrs = jnp.where(expand[:, None], g["neighbors0"][va_safe], -1)  # (B, M0)
        ok = nbrs >= 0
        safe = jnp.maximum(nbrs, 0)
        seen = s["visited"][rows[:, None], safe]
        new = ok & ~seen
        visited = visited.at[rows[:, None], safe].max(new)

        d = _pairwise_dist(queries, g["vectors"][safe], g["norms"][safe])
        td = F.eval_program_gathered(
            programs, g["attrs_int"][safe], g["attrs_float"][safe], xp=jnp)
        dbar = d + jnp.where(td, 0.0, D[:, None])    # Eq. 2

        # -- pool insertion (lines 15-24) -------------------------------------
        worst_now = jnp.max(res_d, axis=1)           # +inf when R not full
        eligible = new & (dbar < worst_now[:, None])
        dbar_m = jnp.where(eligible, dbar, INF)
        nbr_m = jnp.where(eligible, nbrs, -1)

        res_d, res_i, res_t = _merge_pool(res_d, res_i, res_t,
                                          dbar_m, nbr_m, td & eligible, ef)
        cand_d, cand_i, _ = _merge_pool(cand_d, cand_i,
                                        jnp.zeros_like(cand_i, bool),
                                        dbar_m, nbr_m,
                                        jnp.zeros_like(nbr_m, bool), ccap)

        va_td = F.eval_program_gathered(
            programs, g["attrs_int"][va_safe][:, None, :],
            g["attrs_float"][va_safe][:, None, :], xp=jnp)[:, 0]
        return {
            "cand_d": cand_d, "cand_i": cand_i,
            "res_d": res_d, "res_i": res_i, "res_t": res_t,
            "visited": visited, "active": new_active,
            "step": s["step"] + 1,
            "hops": s["hops"] + expand.astype(jnp.int32),
            "path_td": s["path_td"] + (expand & va_td).astype(jnp.int32),
        }

    state = {
        "cand_d": cand_d, "cand_i": cand_i,
        "res_d": res_d, "res_i": res_i, "res_t": res_t,
        "visited": visited, "active": active,
        "step": jnp.asarray(0, jnp.int32), "hops": hops, "path_td": path_td,
    }
    state = jax.lax.while_loop(cond, body, state)

    # --- final S: k nearest TD in R (Algorithm 2 line 9) --------------------
    sd = jnp.where(state["res_t"], state["res_d"], INF)   # TD dbar == true dist
    order = jnp.argsort(sd, axis=1)[:, : cfg.k]
    out_d = jnp.take_along_axis(sd, order, axis=1)
    out_i = jnp.take_along_axis(state["res_i"], order, axis=1)
    out_i = jnp.where(jnp.isfinite(out_d), out_i, -1)
    if valid is not None:
        vmask = jnp.asarray(valid, bool)[:, None]
        out_i = jnp.where(vmask, out_i, -1)
        out_d = jnp.where(vmask, out_d, INF)
    return {"ids": out_i, "dists": out_d,
            "hops": state["hops"], "path_td": state["path_td"]}


@partial(jax.jit, static_argnames=("cfg",))
def rsf_graph_search(g: dict, queries: jnp.ndarray, programs: dict,
                     cfg: SearchConfig) -> dict:
    """Result-Set-Filtering baseline on the same machinery: D = 0 and R only
    admits TD (C takes everything) -- used by benchmarks for head-to-head
    QPS/recall under identical batching."""
    B = queries.shape[0]
    N = g["vectors"].shape[0]
    ef, ccap = cfg.ef, cfg.ccap
    rows = jnp.arange(B)
    ep = _descend(g, queries)

    ep_d = _pairwise_dist(queries, g["vectors"][ep][:, None, :],
                          g["norms"][ep][:, None])[:, 0]
    ep_td = F.eval_program_gathered(
        programs, g["attrs_int"][ep][:, None, :],
        g["attrs_float"][ep][:, None, :], xp=jnp)[:, 0]

    cand_d = jnp.full((B, ccap), INF).at[:, 0].set(ep_d)
    cand_i = jnp.full((B, ccap), -1, jnp.int32).at[:, 0].set(ep)
    res_d = jnp.full((B, ef), INF).at[:, 0].set(jnp.where(ep_td, ep_d, INF))
    res_i = jnp.full((B, ef), -1, jnp.int32).at[:, 0].set(jnp.where(ep_td, ep, -1))
    res_t = jnp.zeros((B, ef), bool).at[:, 0].set(ep_td)
    visited = jnp.zeros((B, N), bool).at[rows, ep].set(True)

    def cond(s):
        return jnp.any(s["active"]) & (s["step"] < cfg.steps)

    def body(s):
        cand_d, cand_i = s["cand_d"], s["cand_i"]
        res_d, res_i, res_t = s["res_d"], s["res_i"], s["res_t"]
        visited, active = s["visited"], s["active"]

        j = jnp.argmin(cand_d, axis=1)
        da = cand_d[rows, j]
        va = cand_i[rows, j]
        cand_d = jnp.where(active[:, None], cand_d.at[rows, j].set(INF), cand_d)

        worst = jnp.max(res_d, axis=1)
        full = jnp.sum(jnp.isfinite(res_d), axis=1) >= ef
        terminate = (da > worst) & full
        exhausted = ~jnp.isfinite(da)
        new_active = active & ~terminate & ~exhausted
        expand = new_active

        va_safe = jnp.maximum(va, 0)
        nbrs = jnp.where(expand[:, None], g["neighbors0"][va_safe], -1)
        ok = nbrs >= 0
        safe = jnp.maximum(nbrs, 0)
        new = ok & ~s["visited"][rows[:, None], safe]
        visited = visited.at[rows[:, None], safe].max(new)

        d = _pairwise_dist(queries, g["vectors"][safe], g["norms"][safe])
        td = F.eval_program_gathered(
            programs, g["attrs_int"][safe], g["attrs_float"][safe], xp=jnp)

        worst_now = jnp.max(res_d, axis=1)
        admit = new & ((d < worst_now[:, None]) | ~full[:, None])
        d_c = jnp.where(admit, d, INF)
        i_c = jnp.where(admit, nbrs, -1)
        cand_d, cand_i, _ = _merge_pool(cand_d, cand_i,
                                        jnp.zeros_like(cand_i, bool),
                                        d_c, i_c, jnp.zeros_like(i_c, bool), ccap)
        d_r = jnp.where(admit & td, d, INF)
        i_r = jnp.where(admit & td, nbrs, -1)
        res_d, res_i, res_t = _merge_pool(res_d, res_i, res_t, d_r, i_r,
                                          td & admit, ef)
        return {
            "cand_d": cand_d, "cand_i": cand_i,
            "res_d": res_d, "res_i": res_i, "res_t": res_t,
            "visited": visited, "active": new_active,
            "step": s["step"] + 1,
            "hops": s["hops"] + expand.astype(jnp.int32),
        }

    state = jax.lax.while_loop(cond, body, {
        "cand_d": cand_d, "cand_i": cand_i,
        "res_d": res_d, "res_i": res_i, "res_t": res_t,
        "visited": visited, "active": jnp.ones((B,), bool),
        "step": jnp.asarray(0, jnp.int32), "hops": jnp.zeros((B,), jnp.int32),
    })
    sd = jnp.where(state["res_t"], state["res_d"], INF)
    order = jnp.argsort(sd, axis=1)[:, : cfg.k]
    out_d = jnp.take_along_axis(sd, order, axis=1)
    out_i = jnp.take_along_axis(state["res_i"], order, axis=1)
    out_i = jnp.where(jnp.isfinite(out_d), out_i, -1)
    return {"ids": out_i, "dists": out_d, "hops": state["hops"]}
