"""JAX production search: batched FAVOR graph traversal on TPU.

TPU-native realization of Algorithms 2 + 3 (DESIGN.md section 3):

 * the query batch runs as ONE ``lax.while_loop`` whose state carries a lane
   per query; finished lanes are masked, the loop ends when all lanes do;
 * the candidate set C and result set R are fixed-capacity distance-sorted
   pools updated by merge-sort of (pool || new-neighbor-block) -- no dynamic
   heaps.  C capacity = ``cand_cap`` (default ef) is the bounded-memory
   approximation of the paper's unbounded heap; recall parity with the
   refimpl oracle is asserted in tests and measured in benchmarks;
 * neighbor-block scoring is pluggable (``core.scoring``): the same
   traversal body runs full-precision f32 (ExactScorer), PQ asymmetric
   distances over gathered uint8 codes (PqAdcScorer: the ADC LUT is built
   once per query before the loop) or dequantized int8 (SqScorer),
   selected by the jit-static ``SearchConfig.graph_quant``;
 * the exclusion distance (Eq. 2) composes *on top of* whatever the scorer
   returns (``scoring.exclusion_compose``); quantized scorers get an exact
   f32 re-rank of the final top-``graph_rerank * k`` TD candidates (the
   same pass the brute route uses, quant/adc.py);
 * termination implements section 5.4: the usual adjusted-distance condition
   AND the TD-fraction guard ``pbar > pbar_min`` (0 disables);
 * the visited set is a packed per-query uint32 bitfield
   ``(B, ceil(N/32))`` -- 8x less HBM per lane than the former (B, N) bool
   bitmap at multi-million-N scale;
 * the while_loop is *lane-compacted* (``SearchConfig.lane_compact``): a
   static ladder of stage widths B, B/2, ... -- each stage exits once the
   active-lane population fits the next, survivors are packed into a
   half-width batch, and finished lanes stop costing wave work.  Results
   are bit-identical to the single-stage loop because every per-lane op is
   row-wise and every scorer is bit-stable across batch sizes.

``favor_graph_search`` (exclusion distances) and ``rsf_graph_search``
(result-set-filtering baseline: D = 0, R admits TD only) are two thin
entry points over ONE parameterized traversal body, so they stay in
lockstep on the lane-mask (bucket padding) contract and the hops/path_td
diagnostics.

Everything here is jit/shard_map friendly: shapes static, no host callbacks.
"""
from __future__ import annotations

import weakref
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import filters as F
from .hnsw import HnswIndex
from .scoring import exclusion_compose, pairwise_dist, scorer_for

INF = jnp.inf

# back-compat alias: callers (and the batching docs) reference the
# mul+reduce pairwise distance by its historical private name
_pairwise_dist = pairwise_dist


@dataclass(frozen=True)
class SearchConfig:
    k: int = 10
    ef: int = 100
    cand_cap: int = 0          # 0 -> ef
    max_steps: int = 0         # 0 -> 8 * ef safety bound
    pbar_min: float = 0.5      # section 5.4 threshold (0 disables)
    gamma: float = 1.0         # Algorithm 3 line 8 slack
    use_pallas: bool = False   # route scoring through the Pallas kernels
    graph_quant: str | None = None  # None (f32) | "pq" | "sq" scorer
    graph_rerank: int = 4      # exact-re-rank depth: top max(k, rr*k) TD
                               # candidates, capped at ef (quantized only)
    lane_compact: int = 2      # halve the wave width whenever the active-lane
                               # population fits the next stage, down to this
                               # floor (0 disables; results are bit-identical).
                               # 2 keeps straggler waves cheap -- quantized
                               # scorers run ~1.7x more waves than f32 (noisy
                               # distances delay termination), almost all in
                               # the compacted tail

    @property
    def ccap(self) -> int:
        return self.cand_cap or self.ef

    @property
    def steps(self) -> int:
        return self.max_steps or 8 * self.ef

    def stage_sizes(self, b: int) -> tuple[int, ...]:
        """The static lane-count ladder the traversal runs through for a
        batch of ``b`` queries: full width first, then repeated halvings
        while the next stage still holds >= ``lane_compact`` lanes.  One
        entry -> no compaction (the pre-compaction behavior)."""
        sizes = [b]
        if self.lane_compact > 0:
            while sizes[-1] // 2 >= self.lane_compact:
                sizes.append(sizes[-1] // 2)
        return tuple(sizes)


# ---------------------------------------------------------------------------
# Graph array preparation (memoized)
# ---------------------------------------------------------------------------
_GRAPH_ARRAYS_CACHE: dict = {}
_GRAPH_ARRAYS_CAP = 8


def graph_arrays(index: HnswIndex, attrs: F.AttributeTable,
                 version: int = 0) -> dict:
    """Flatten an HnswIndex + attribute table to the device array dict the
    production search (and the dry-run input_specs) consume.

    Memoized per ``(index identity, attrs identity, version)``: repeated
    FavorIndex / ServeEngine construction over the same built index (the
    benchmark-cache pattern) reuses the device arrays instead of re-uploading
    the corpus.  Entries die with their index/attrs (weakrefs, identity
    checked on hit so recycled ``id()``s never alias) and the cache is
    bounded.  Treat the returned dict as immutable -- copy before adding
    keys (FavorIndex does, for the quantized-scorer arrays).
    """
    key = (id(index), id(attrs), int(version))
    hit = _GRAPH_ARRAYS_CACHE.get(key)
    if hit is not None:
        iref, aref, g = hit
        if iref() is index and aref() is attrs:
            return g
        del _GRAPH_ARRAYS_CACHE[key]

    def _evict(k=key):
        _GRAPH_ARRAYS_CACHE.pop(k, None)

    upper = (np.stack(index.levels[1:], axis=0) if index.max_level >= 1
             else np.zeros((0, index.n, index.params.M), np.int32))
    g = {
        "vectors": jnp.asarray(index.vectors),
        "norms": jnp.asarray(index.norms.astype(np.float32)),
        "neighbors0": jnp.asarray(index.levels[0]),
        "upper": jnp.asarray(upper),
        "entry": jnp.asarray(index.entry_point, jnp.int32),
        "attrs_int": jnp.asarray(attrs.ints),
        "attrs_float": jnp.asarray(attrs.floats),
    }
    while len(_GRAPH_ARRAYS_CACHE) >= _GRAPH_ARRAYS_CAP:
        _GRAPH_ARRAYS_CACHE.pop(next(iter(_GRAPH_ARRAYS_CACHE)))
    # finalizers evict the entry the moment index/attrs die, so the cache
    # never pins device arrays of freed corpora (the hit-time identity
    # check above covers id() reuse in the window before GC runs)
    _GRAPH_ARRAYS_CACHE[key] = (weakref.ref(index), weakref.ref(attrs), g)
    weakref.finalize(index, _evict)
    weakref.finalize(attrs, _evict)
    return g


# which graph_arrays keys each epoch component owns: a refresh re-uploads
# only the keys of the components that actually changed
_COMPONENT_KEYS = {
    "vectors": ("vectors", "norms"),
    "graph": ("neighbors0", "upper", "entry"),
    "attributes": ("attrs_int", "attrs_float"),
}


def refresh_graph_arrays(index: HnswIndex, attrs: F.AttributeTable,
                         *, base: dict, changed: tuple[str, ...],
                         version: int) -> dict:
    """Incremental re-memoization after a mutation: build the dict for the
    new ``version`` by REUSING the device arrays of every component not in
    ``changed`` from ``base`` (the previous graph_arrays dict) and uploading
    only what moved.  A delete-only mutation, for example, re-uploads
    *nothing* here -- the tombstone mask is a separate ``alive`` key the
    caller overlays.  Extra keys on ``base`` (scorer codes, alive) are the
    caller's to carry; this handles the canonical seven only.
    """
    for c in changed:
        if c not in _COMPONENT_KEYS:
            raise ValueError(f"unknown component {c!r}; "
                             f"expected one of {tuple(_COMPONENT_KEYS)}")
    key = (id(index), id(attrs), int(version))
    hit = _GRAPH_ARRAYS_CACHE.get(key)
    if hit is not None:
        iref, aref, g = hit
        if iref() is index and aref() is attrs:
            return g
        del _GRAPH_ARRAYS_CACHE[key]

    def _evict(k=key):
        _GRAPH_ARRAYS_CACHE.pop(k, None)

    g = {k: base[k] for ks in _COMPONENT_KEYS.values() for k in ks}
    if "vectors" in changed:
        g["vectors"] = jnp.asarray(index.vectors)
        g["norms"] = jnp.asarray(index.norms.astype(np.float32))
    if "graph" in changed:
        upper = (np.stack(index.levels[1:], axis=0) if index.max_level >= 1
                 else np.zeros((0, index.n, index.params.M), np.int32))
        g["neighbors0"] = jnp.asarray(index.levels[0])
        g["upper"] = jnp.asarray(upper)
        g["entry"] = jnp.asarray(index.entry_point, jnp.int32)
    if "attributes" in changed:
        g["attrs_int"] = jnp.asarray(attrs.ints)
        g["attrs_float"] = jnp.asarray(attrs.floats)
    while len(_GRAPH_ARRAYS_CACHE) >= _GRAPH_ARRAYS_CAP:
        _GRAPH_ARRAYS_CACHE.pop(next(iter(_GRAPH_ARRAYS_CACHE)))
    _GRAPH_ARRAYS_CACHE[key] = (weakref.ref(index), weakref.ref(attrs), g)
    weakref.finalize(index, _evict)
    weakref.finalize(attrs, _evict)
    return g


# ---------------------------------------------------------------------------
# Packed visited set: (B, ceil(N/32)) uint32 bitfield
# ---------------------------------------------------------------------------
def _visited_words(n: int) -> int:
    return (n + 31) // 32


def _seen_bits(visited, rows, safe):
    """(B, W) words, (B, M) clamped ids -> (B, M) bool already-visited."""
    word = visited[rows[:, None], safe >> 5]
    return ((word >> (safe & 31).astype(jnp.uint32)) & 1) > 0


def _visit_bits(visited, rows, safe, mark):
    """Set the bits for ``mark``-ed entries of ``safe``.

    The scatter is an *add* (JAX has no scatter-or), which is exact only if
    every bit lands at most once -- so duplicates of an id **within one
    block** are dropped from the scatter first.  ``mark`` itself is left
    untouched for pool admission, preserving the old bool-bitmap semantics
    (``.at[].max`` was idempotent) bit for bit.
    """
    m = safe.shape[1]
    col = jnp.arange(m)
    dup = ((safe[:, :, None] == safe[:, None, :])
           & mark[:, :, None] & mark[:, None, :]
           & (col[None, None, :] < col[None, :, None]))
    first = mark & ~jnp.any(dup, axis=2)
    bits = jnp.where(first,
                     jnp.uint32(1) << (safe & 31).astype(jnp.uint32),
                     jnp.uint32(0))
    return visited.at[rows[:, None], safe >> 5].add(bits)


# ---------------------------------------------------------------------------
# Traversal building blocks
# ---------------------------------------------------------------------------
def _descend(g: dict, queries: jnp.ndarray, scorer, sstate: dict) -> jnp.ndarray:
    """Upper-layer greedy descent (no filtering), returns entry ids (B,)."""
    B = queries.shape[0]
    cur = jnp.full((B,), g["entry"], jnp.int32)
    curd = scorer.score_block(g, sstate, cur[:, None])[:, 0]
    n_upper = g["upper"].shape[0]
    for li in range(n_upper - 1, -1, -1):
        level = g["upper"][li]

        def cond(state):
            _, _, moved = state
            return jnp.any(moved)

        def body(state):
            cur, curd, moved = state
            nbrs = level[cur]                      # (B, M)
            ok = nbrs >= 0
            safe = jnp.maximum(nbrs, 0)
            d = scorer.score_block(g, sstate, safe)
            d = jnp.where(ok, d, INF)
            j = jnp.argmin(d, axis=1)
            best = jnp.take_along_axis(d, j[:, None], axis=1)[:, 0]
            better = moved & (best < curd)
            new_cur = jnp.where(better, jnp.take_along_axis(safe, j[:, None], axis=1)[:, 0], cur)
            new_d = jnp.where(better, best, curd)
            return new_cur, new_d, better

        cur, curd, _ = jax.lax.while_loop(
            cond, body, (cur, curd, jnp.ones((B,), bool)))
    return cur


def _merge_pool(pool_d, pool_i, pool_t, new_d, new_i, new_t, cap: int):
    """Merge (B, cap) pools with (B, M) new entries, keep best ``cap``.
    Ineligible new entries must carry d=+inf."""
    d = jnp.concatenate([pool_d, new_d], axis=1)
    i = jnp.concatenate([pool_i, new_i], axis=1)
    t = jnp.concatenate([pool_t, new_t], axis=1)
    order = jnp.argsort(d, axis=1)[:, :cap]
    return (jnp.take_along_axis(d, order, axis=1),
            jnp.take_along_axis(i, order, axis=1),
            jnp.take_along_axis(t, order, axis=1))


def _graph_traverse(g: dict, queries: jnp.ndarray, programs: dict,
                    D: jnp.ndarray, cfg: SearchConfig, scorer, valid,
                    *, rsf: bool) -> dict:
    """The ONE traversal body behind favor_graph_search / rsf_graph_search.

    ``scorer`` supplies the (approximate or exact) distances; the exclusion
    select, the validity-mask plumbing, the pools and the diagnostics are
    identical across scorers and across the FAVOR/RSF modes.  ``rsf=True``
    is the Result-Set-Filtering baseline: callers pass D = 0, R admits only
    TD rows, and the section-5.4 pbar guard is off (the baseline has no
    exclusion statistics to guard with).
    """
    B, _ = queries.shape
    N = g["vectors"].shape[0]
    ef, ccap = cfg.ef, cfg.ccap
    rows = jnp.arange(B)

    # optional live-index tombstone mask (N,) bool: dead nodes stay routable
    # (their edges still carry the walk) but are never admitted to R -- the
    # key is absent until the first delete, so static indexes trace the
    # exact pre-live program and stay bit-identical
    alive = g.get("alive")

    sstate = scorer.prepare(g, queries, programs)
    ep = _descend(g, queries, scorer, sstate)        # (B,)

    # --- init pools with the entry point -----------------------------------
    ep_d = scorer.score_block(g, sstate, ep[:, None])[:, 0]
    ep_td = F.eval_program_gathered(
        programs, g["attrs_int"][ep][:, None, :],
        g["attrs_float"][ep][:, None, :], xp=jnp)[:, 0]
    if alive is not None:
        ep_td = ep_td & alive[ep]
    ep_key = exclusion_compose(ep_d, ep_td, D)       # rsf: D = 0 -> plain d
    seed_ok = ep_td if rsf else jnp.ones((B,), bool)

    cand_d = jnp.full((B, ccap), INF).at[:, 0].set(ep_key)
    cand_i = jnp.full((B, ccap), -1, jnp.int32).at[:, 0].set(ep)
    res_d = jnp.full((B, ef), INF).at[:, 0].set(
        jnp.where(seed_ok, ep_key, INF))
    res_i = jnp.full((B, ef), -1, jnp.int32).at[:, 0].set(
        jnp.where(seed_ok, ep, -1))
    res_t = jnp.zeros((B, ef), bool).at[:, 0].set(ep_td)
    visited = jnp.zeros((B, _visited_words(N)), jnp.uint32).at[
        rows, ep >> 5].add(jnp.uint32(1) << (ep & 31).astype(jnp.uint32))
    active = (jnp.ones((B,), bool) if valid is None
              else jnp.asarray(valid, bool))
    hops = jnp.zeros((B,), jnp.int32)
    path_td = jnp.zeros((B,), jnp.int32)

    def stage_loop(state, programs, D, sstate, limit: int):
        """One while_loop over the (possibly compacted) lane set.

        ``limit > 0`` adds the compaction exit: the loop also stops once the
        active-lane population fits the next (half-width) stage, so the
        caller can gather the survivors into a narrower batch.  Every op in
        the body is row-wise (argmin/merge/gather per lane) and every scorer
        is bit-stable across batch sizes (see ``pairwise_dist``), so lanes
        produce identical trajectories whichever stage width carries them.
        """
        S = state["active"].shape[0]
        rows = jnp.arange(S)

        def cond(s):
            go = jnp.any(s["active"]) & (s["step"] < cfg.steps)
            if limit > 0:
                go = go & (jnp.sum(s["active"]) > limit)
            return go

        def body(s):
            cand_d, cand_i = s["cand_d"], s["cand_i"]
            res_d, res_i, res_t = s["res_d"], s["res_i"], s["res_t"]
            active = s["active"]

            # -- extract argmin of C (Algorithm 3 line 6) --------------------
            j = jnp.argmin(cand_d, axis=1)
            da = cand_d[rows, j]
            va = cand_i[rows, j]
            cand_d = jnp.where(active[:, None],
                               cand_d.at[rows, j].set(INF), cand_d)

            # -- termination (line 8, with section 5.4 guard) ----------------
            worst = jnp.max(res_d, axis=1)           # +inf while R not full
            full = jnp.isfinite(worst)
            plain_term = (da > cfg.gamma * worst) & full
            if rsf:
                guard_ok = jnp.ones((S,), bool)
            else:
                n_valid = jnp.sum(jnp.isfinite(res_d), axis=1)
                n_td = jnp.sum(res_t & jnp.isfinite(res_d), axis=1)
                pbar = n_td / jnp.maximum(n_valid, 1)
                guard_ok = (cfg.pbar_min <= 0.0) | (pbar > cfg.pbar_min)
            terminate = plain_term & guard_ok
            exhausted = ~jnp.isfinite(da)
            new_active = active & ~terminate & ~exhausted
            expand = new_active                      # lanes that expand v_a

            # -- gather + score the neighbor block ---------------------------
            va_safe = jnp.maximum(va, 0)
            nbrs = jnp.where(expand[:, None], g["neighbors0"][va_safe], -1)  # (S, M0)
            ok = nbrs >= 0
            safe = jnp.maximum(nbrs, 0)
            seen = _seen_bits(s["visited"], rows, safe)
            new = ok & ~seen
            visited = _visit_bits(s["visited"], rows, safe, new)

            # profiling scope: stamps the per-wave gather+score+filter ops
            # into HLO metadata so device traces attribute traversal time to
            # waves (trace-time only; see repro.obs.profiling)
            with jax.named_scope("favor.graph_wave"):
                d = scorer.score_block(g, sstate, safe)
                td = F.eval_program_gathered(
                    programs, g["attrs_int"][safe], g["attrs_float"][safe],
                    xp=jnp)
                if alive is not None:
                    td = td & alive[safe]
                key = exclusion_compose(d, td, D[:, None])   # Eq. 2

            # -- pool insertion (lines 15-24) --------------------------------
            worst_now = jnp.max(res_d, axis=1)       # +inf when R not full
            eligible = new & (key < worst_now[:, None])
            res_ok = (eligible & td) if rsf else eligible
            res_d, res_i, res_t = _merge_pool(
                res_d, res_i, res_t,
                jnp.where(res_ok, key, INF), jnp.where(res_ok, nbrs, -1),
                td & res_ok, ef)
            cand_d, cand_i, _ = _merge_pool(
                cand_d, cand_i, jnp.zeros_like(cand_i, bool),
                jnp.where(eligible, key, INF), jnp.where(eligible, nbrs, -1),
                jnp.zeros_like(nbrs, bool), ccap)

            va_td = F.eval_program_gathered(
                programs, g["attrs_int"][va_safe][:, None, :],
                g["attrs_float"][va_safe][:, None, :], xp=jnp)[:, 0]
            if alive is not None:
                va_td = va_td & alive[va_safe]
            return {
                "cand_d": cand_d, "cand_i": cand_i,
                "res_d": res_d, "res_i": res_i, "res_t": res_t,
                "visited": visited, "active": new_active,
                "step": s["step"] + 1,
                "hops": s["hops"] + expand.astype(jnp.int32),
                "path_td": s["path_td"] + (expand & va_td).astype(jnp.int32),
            }

        return jax.lax.while_loop(cond, body, state)

    state = {
        "cand_d": cand_d, "cand_i": cand_i,
        "res_d": res_d, "res_i": res_i, "res_t": res_t,
        "visited": visited, "active": active,
        "step": jnp.asarray(0, jnp.int32), "hops": hops, "path_td": path_td,
    }

    # --- lane-compacted traversal: a static ladder of stage widths ----------
    # The full-width loop exits as soon as the active-lane population fits
    # half the batch; survivors are packed (active-first, original order --
    # a stable argsort on the inactive flag) into the next stage and the
    # finished lanes' pools are scattered back into the full-width buffers.
    # A padded bucket (or a long straggler tail) therefore stops paying
    # B-wide waves the moment most lanes are done, instead of running every
    # wave at the width of the slowest lane.  Each stage is one more traced
    # while_loop inside the SAME jitted executable, so the compiled-shape
    # count per bucket is unchanged (the CI compile guard asserts this).
    sizes = cfg.stage_sizes(B)
    out_keys = ("res_d", "res_i", "res_t", "hops", "path_td")
    final = {k: state[k] for k in out_keys}
    perm = jnp.arange(B)
    progs_s, D_s, sstate_s = programs, D, sstate
    with jax.named_scope("favor.graph_traverse"):
        for si, S in enumerate(sizes):
            limit = sizes[si + 1] if si + 1 < len(sizes) else 0
            state = stage_loop(state, progs_s, D_s, sstate_s, limit)
            if len(sizes) == 1:
                final = {k: state[k] for k in out_keys}
                break
            final = {k: final[k].at[perm].set(state[k]) for k in out_keys}
            if si + 1 < len(sizes):
                nxt = sizes[si + 1]
                sel = jnp.argsort(~state["active"], stable=True)[:nxt]
                perm = perm[sel]
                state = {k: (v if k == "step" else v[sel])
                         for k, v in state.items()}
                progs_s = {k: v[sel] for k, v in progs_s.items()}
                D_s = D_s[sel]
                # scorer state is per-query EXCEPT the keys the scorer
                # declares shared (e.g. SqScorer's query-independent
                # quadratic weights) -- those must not be lane-sliced
                shared = getattr(scorer, "shared_state", ())
                sstate_s = {
                    k: (v if k in shared
                        else jax.tree_util.tree_map(lambda a: a[sel], v))
                    for k, v in sstate_s.items()}
    waves = state["step"]
    state = final

    # --- final S: k nearest TD in R (Algorithm 2 line 9) --------------------
    sd = jnp.where(state["res_t"], state["res_d"], INF)  # TD dbar == scorer dist
    if scorer.exact:
        order = jnp.argsort(sd, axis=1)[:, : cfg.k]
        out_d = jnp.take_along_axis(sd, order, axis=1)
        out_i = jnp.take_along_axis(state["res_i"], order, axis=1)
        out_i = jnp.where(jnp.isfinite(out_d), out_i, -1)
        if valid is not None:
            vmask = jnp.asarray(valid, bool)[:, None]
            out_i = jnp.where(vmask, out_i, -1)
            out_d = jnp.where(vmask, out_d, INF)
    else:
        # quantized scorer: the pool holds approximate distances -- exact
        # f32 re-rank of the top-R TD candidates, exactly like the brute
        # route's ADC scan (quant/adc.py); R caps at ef (the pool size)
        from ..quant.adc import _exact_rerank
        r = min(ef, max(cfg.k, cfg.graph_rerank * cfg.k))
        order = jnp.argsort(sd, axis=1)[:, :r]
        cand = jnp.take_along_axis(state["res_i"], order, axis=1)
        cand = jnp.where(jnp.isfinite(
            jnp.take_along_axis(sd, order, axis=1)), cand, -1)
        out_i, out_d = _exact_rerank(g["vectors"], g["norms"], queries,
                                     cand, k=cfg.k, valid=valid)
        if valid is not None:
            out_i = jnp.where(jnp.asarray(valid, bool)[:, None], out_i, -1)
    return {"ids": out_i, "dists": out_d,
            "hops": state["hops"], "path_td": state["path_td"],
            # broadcast: a wave is a batch-wide event (every co-resident lane
            # pays it), so each query reports the ladder's total wave count
            "waves": jnp.broadcast_to(waves, state["hops"].shape)}


# ---------------------------------------------------------------------------
# Public entry points (thin wrappers over the shared body)
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("cfg",))
def favor_graph_search(g: dict, queries: jnp.ndarray, programs: dict,
                       D: jnp.ndarray, cfg: SearchConfig,
                       valid=None) -> dict:
    """Batched OptiGreedySearch (Algorithm 3) with exclusion distances.

    g         : graph_arrays dict (possibly one shard of the DB); for
                ``cfg.graph_quant`` it must also carry the scorer arrays
                (codes + centroids | sq_lo/sq_scale)
    queries   : (B, d) float32
    programs  : batched filter programs {valid (B,W), imask, flo, fhi}
    D         : (B,) per-query exclusion distance (Eq. 14, from p_hat)
    valid     : optional (B,) bool lane mask (bucket padding): False lanes
                start inactive -- they never expand a node, cost no search
                work, and return ids=-1 / dists=+inf / hops=0
    returns   : {"ids": (B,k) int32 (-1 pad), "dists": (B,k) f32 (+inf pad),
                 "hops": (B,), "path_td": (B,), "waves": (B,) int32 -- total
                 while_loop iterations across the compaction stage ladder
                 (batch-wide, so identical for every lane of the batch)}
    """
    return _graph_traverse(g, queries, programs, D, cfg, scorer_for(cfg),
                           valid, rsf=False)


@partial(jax.jit, static_argnames=("cfg",))
def rsf_graph_search(g: dict, queries: jnp.ndarray, programs: dict,
                     cfg: SearchConfig, valid=None) -> dict:
    """Result-Set-Filtering baseline on the same machinery: D = 0 and R only
    admits TD (C takes everything) -- used by benchmarks for head-to-head
    QPS/recall under identical batching.  Same lane-mask contract and
    hops/path_td diagnostics as favor_graph_search (one traversal body)."""
    B = queries.shape[0]
    return _graph_traverse(g, queries, programs,
                           jnp.zeros((B,), jnp.float32), cfg,
                           scorer_for(cfg), valid, rsf=True)
