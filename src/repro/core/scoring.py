"""Pluggable distance scorers for the graph traversal (one protocol, three
memory formats).

FAVOR's exclusion-distance mechanism (Eq. 2) is scorer-agnostic: it reshapes
*whatever* distance distribution the traversal sees.  The traversal loop in
``core.search`` therefore composes three orthogonal pieces per neighbor
block:

    score_block -> (B, M) distances      (this module: f32 / PQ-ADC / SQ)
    filter eval -> (B, M) TD mask        (filters.eval_program_gathered)
    exclusion   -> dbar = d + (1-td)*D   (``exclusion_compose`` below)

A Scorer is a *frozen, array-free* dataclass so it can ride along as a
jit-static parameter (it is derived from the jit-static ``SearchConfig`` via
``scorer_for``); all device state lives in the ``g`` array dict and in the
per-query ``state`` dict built once by ``prepare`` before the while_loop:

    prepare(g, queries, programs) -> state      # e.g. the ADC LUTs (B, M, K)
    score_block(g, state, ids)    -> (B, M) f32 # distances for gathered ids

``programs`` is threaded through ``prepare`` only so the Pallas exact path
can reuse the fused gather_distance kernel (which evaluates the filter
in-kernel); the jnp scorers ignore it.

Scorers return *distance-scale* values (sqrt of the squared forms) so the
exclusion distance D -- calibrated in true-distance units from Delta_d --
composes identically whichever scorer runs.  Quantized scorers are
approximate: the traversal re-ranks their final TD candidates with the same
exact float32 pass the brute route uses (``quant.adc._exact_rerank``).

Bandwidth accounting: ``bytes_per_row`` is what one gathered neighbor row
streams from HBM -- 4*d for f32, M codes for PQ, d codes for SQ -- the
``bench_qps_recall --smoke`` sweep reports the per-hop reduction.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

GRAPH_QUANT_KINDS = (None, "pq", "sq")


def pairwise_dist(q: jnp.ndarray, vecs: jnp.ndarray,
                  vnorm: jnp.ndarray) -> jnp.ndarray:
    """(B, d), (B, M, d), (B, M) -> true Euclidean distance (B, M).

    The dot is a *batched mat-vec* (one d-contraction per (b, m) pair), so
    it is written as multiply + last-axis reduce rather than an einsum:
    XLA lowers the reduce with a batch-size-independent accumulation order,
    which keeps results bit-identical when bucket padding changes B (a
    dot_general here picks different codegen for B=1 vs B=8 on CPU).  The
    contraction never fed the MXU efficiently anyway -- b is a batch dim.
    """
    qn = jnp.sum(q * q, axis=-1)  # (B,)
    dot = jnp.sum(q[:, None, :] * vecs, axis=-1)
    d2 = vnorm + qn[:, None] - 2.0 * dot
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def exclusion_compose(d: jnp.ndarray, td: jnp.ndarray,
                      D: jnp.ndarray) -> jnp.ndarray:
    """Eq. 2: adjusted distance ``d + D`` for non-target rows, ``d`` for TD.

    Order-preserving within each class: for two TD rows (or two non-TD
    rows) the composition adds the same constant, so their relative order
    under any scorer is unchanged -- the property test in test_scoring
    checks exactly this.
    """
    return d + jnp.where(td, 0.0, D)


@runtime_checkable
class Scorer(Protocol):
    """Distance scorer contract consumed by the unified traversal."""

    kind: str    # "exact" | "pq" | "sq" -- the SearchOptions.graph_quant name
    exact: bool  # True -> score_block returns true f32 distances (no re-rank)
    # optional ``shared_state``: names of prepare() keys that are
    # query-independent (no leading batch axis); the lane-compaction ladder
    # slices every other state leaf per stage and must leave these alone

    def required_keys(self) -> tuple[str, ...]:
        """g-dict arrays this scorer reads (validation happens host-side)."""
        ...

    def prepare(self, g: dict, queries, programs: dict) -> dict:
        """Per-query device state built once before the traversal loop."""
        ...

    def score_block(self, g: dict, state: dict, ids) -> jnp.ndarray:
        """(B, M) distances for the gathered DB rows ``ids`` (clamped >= 0;
        masking of pad/visited entries stays in the traversal)."""
        ...

    def bytes_per_row(self, g: dict) -> int:
        """Bytes one gathered neighbor row streams from HBM."""
        ...


@dataclass(frozen=True)
class ExactScorer:
    """Full-precision float32 scoring (the seed behavior).

    ``use_pallas=True`` routes each neighbor block through the
    kernels/gather_distance scalar-prefetch kernel (row DMAs picked by the
    prefetched ids) instead of the jnp gather + mul/reduce.
    """
    use_pallas: bool = False
    kind = "exact"
    exact = True

    def required_keys(self) -> tuple[str, ...]:
        return ("vectors", "norms")

    def prepare(self, g: dict, queries, programs: dict) -> dict:
        state = {"q": jnp.asarray(queries)}
        if self.use_pallas:
            state["programs"] = programs
        return state

    def score_block(self, g: dict, state: dict, ids) -> jnp.ndarray:
        if self.use_pallas:
            from ..kernels.gather_distance import ops as gd_ops
            # dvec=0 -> plain distances; the traversal owns the exclusion
            # composition (and re-evaluates TD where it needs the mask)
            d, _ = gd_ops.gather_distance(
                g["vectors"], g["norms"], g["attrs_int"], g["attrs_float"],
                state["q"], ids, state["programs"],
                jnp.zeros((state["q"].shape[0],), jnp.float32))
            return jnp.minimum(d, 3.0e38)  # keep +inf out of the pools' math
        return pairwise_dist(state["q"], g["vectors"][ids], g["norms"][ids])

    def bytes_per_row(self, g: dict) -> int:
        return 4 * int(g["vectors"].shape[1])


@dataclass(frozen=True)
class PqAdcScorer:
    """Compressed scoring: per-query ADC LUTs + gathered uint8 codes.

    ``prepare`` builds the (B, M, K) squared-subdistance tables once
    (quant.adc.build_luts) and stores them **bfloat16** by default, halving
    the per-query LUT state; every lookup widens back to float32 before the
    subspace accumulation, so only the table entries themselves are rounded
    (~3 significant digits -- noise next to the PQ quantization error, and
    the traversal's final candidates get an exact f32 re-rank regardless).
    Each neighbor block is then M table lookups + adds per row, through ONE
    flat (B, M*K) gather -- the gathered-row traffic drops from 4*d to M
    bytes.  ``use_pallas=True`` runs the row-batched block-gather ADC kernel
    (kernels/pq_adc.pq_adc_gather) instead of the jnp take_along_axis.
    """
    use_pallas: bool = False
    lut_bf16: bool = True
    kind = "pq"
    exact = False

    def required_keys(self) -> tuple[str, ...]:
        return ("codes", "centroids")

    def prepare(self, g: dict, queries, programs: dict) -> dict:
        from ..quant.adc import build_luts
        luts = build_luts(g["centroids"], jnp.asarray(queries))
        if self.lut_bf16:
            luts = luts.astype(jnp.bfloat16)
        return {"luts": luts}

    def score_block(self, g: dict, state: dict, ids) -> jnp.ndarray:
        luts = state["luts"]
        if self.use_pallas:
            from ..kernels.pq_adc import ops as pq_ops
            adc2 = pq_ops.pq_adc_gather(g["codes"], luts, ids)
        else:
            b, m, k = luts.shape
            codes = g["codes"][ids].astype(jnp.int32)        # (B, M0, m)
            # ONE flat jnp.take against the fully flattened (B*M*K) table:
            # row b / subspace mm / code c addresses entry (b*M + mm)*K + c.
            # Globalizing the row index lets XLA lower a single 1-d gather
            # (~2.5x faster on CPU than the per-batch take_along_axis or the
            # former 4-d broadcast gather).  Indices are stage-local, so
            # lane compaction's sliced LUTs line up row for row.
            gidx = ((jnp.arange(b, dtype=jnp.int32)[:, None, None] * m
                     + jnp.arange(m, dtype=jnp.int32)[None, None, :]) * k
                    + codes)
            gath = jnp.take(luts.reshape(-1), gidx)
            adc2 = jnp.sum(gath.astype(jnp.float32), axis=-1)  # f32 accum
        # sqrt: ADC tables are squared sub-distances; the exclusion D and
        # the termination test live in true-distance units
        return jnp.sqrt(jnp.maximum(adc2, 0.0))

    def bytes_per_row(self, g: dict) -> int:
        return int(g["codes"].shape[1])

    def lut_bytes(self, g: dict, batch: int) -> int:
        m, k = int(g["centroids"].shape[0]), int(g["centroids"].shape[1])
        return (2 if self.lut_bf16 else 4) * batch * m * k


@dataclass(frozen=True)
class SqScorer:
    """Scalar-quantization scoring: gathered int8 codes contracted against
    folded affine weights (4x fewer bytes than f32; exact when the corpus
    lies on the int8 grid, which the lossless bit-parity test exploits).

    With x = c*s + lo (per-dim scale/offset) the squared distance folds to

        d2 = sum_j c_j^2 s_j^2                      (query-independent)
           + sum_j c_j * (2 s_j lo_j - 2 q_j s_j)   (per-query linear)
           + ||lo||^2 + ||q||^2 - 2 q.lo            (per-query constant)

    so ``prepare`` bakes the three weight groups once per batch and
    ``score_block`` touches the gathered codes exactly once -- no (B, M, d)
    dequantized copy, no recomputed row norms.  The quadratic term is ONE
    2-d ``dot_general`` with ``preferred_element_type=f32`` (on TPU that is
    the low-precision-in / f32-accumulate MXU shape; gemv on CPU); the
    per-query linear term is a multiply + last-axis reduce, NOT a batched
    dot, for the bucket-size bit-stability ``pairwise_dist`` documents --
    lane compaction re-invokes the scorer at every stage width, so
    distances must not depend on the leading batch dimension.
    """
    kind = "sq"
    exact = False
    # w2 is query-independent (d, 1) -- exempt from lane-compaction slicing
    shared_state = ("w2",)

    def required_keys(self) -> tuple[str, ...]:
        return ("codes", "sq_lo", "sq_scale")

    def prepare(self, g: dict, queries, programs: dict) -> dict:
        q = jnp.asarray(queries)
        s, lo = g["sq_scale"], g["sq_lo"]
        qn = jnp.sum(q * q, axis=-1)                          # (B,)
        return {
            "w2": (s * s)[:, None],                           # (d, 1)
            "w_lin": 2.0 * s[None, :] * (lo[None, :] - q),    # (B, d)
            # mul+reduce (not q @ lo): bit-stable across bucket widths
            "const": jnp.sum(lo * lo) + qn
                     - 2.0 * jnp.sum(q * lo[None, :], axis=-1),
        }

    def score_block(self, g: dict, state: dict, ids) -> jnp.ndarray:
        c = g["codes"][ids].astype(jnp.float32)               # (B, M, d)
        b, m0, d = c.shape
        quad = jax.lax.dot_general(
            (c * c).reshape(b * m0, d), state["w2"],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(b, m0)
        lin = jnp.sum(c * state["w_lin"][:, None, :], axis=-1)
        d2 = quad + lin + state["const"][:, None]
        return jnp.sqrt(jnp.maximum(d2, 0.0))

    def bytes_per_row(self, g: dict) -> int:
        return int(g["codes"].shape[1])


def scorer_for(cfg) -> Scorer:
    """The Scorer implied by a jit-static SearchConfig (same cfg -> same
    scorer, so compiled-executable caches keyed on cfg stay sound)."""
    if cfg.graph_quant == "pq":
        return PqAdcScorer(use_pallas=cfg.use_pallas)
    if cfg.graph_quant == "sq":
        return SqScorer()
    if cfg.graph_quant is not None:
        raise ValueError(f"graph_quant must be one of {GRAPH_QUANT_KINDS}, "
                         f"got {cfg.graph_quant!r}")
    return ExactScorer(use_pallas=cfg.use_pallas)
