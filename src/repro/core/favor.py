"""FavorIndex: the end-to-end FAVOR API (paper Figure 1 workflow).

Offline:  build a conventional HNSW over the vectors, record Delta_d (Eq. 5),
          draw the selectivity sample, attach the attribute table.
Online :  compile each query's filter to a DNF program, estimate p_hat on the
          sample (section 4.2), route by lambda (section 4.1), compute the
          exclusion distance D(p_hat) (Eq. 14) and execute either the PreFBF
          scan or the exclusion-distance graph search (section 5), returning
          the k nearest target points.

The online pipeline itself lives in router.execute (shared with the serving
engine and the sharded backend); this class owns offline state -- device
arrays, selectivity sample, optional PQ/SQ codes -- and exposes it through a
LocalBackend.  ``query(queries, filters, SearchOptions(...))`` is the typed
API; ``search(**kwargs)`` remains as a deprecated shim over it.
"""
from __future__ import annotations

import os
import time
import warnings
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from . import filters as F
from . import prefbf, selectivity, selector
from .hnsw import HnswIndex, HnswParams, build_hnsw
from .options import BuildSpec, QuantSpec, SearchOptions
from .router import SearchResult, compile_programs, execute
from .search import graph_arrays, refresh_graph_arrays
from ..index.epochs import ComponentEpochs
from ..index.live import LiveState

__all__ = ["FavorIndex", "SearchResult"]

_LEGACY_BUILD_KW = ("sel_cfg", "prefbf_chunk", "quantize", "pq_m", "pq_nbits",
                    "pq_train_iters", "pq_train_sample", "rerank")


@dataclass
class _MergePrep:
    """Everything ``merge_prepare`` built off the serving path, ready for an
    atomic ``merge_commit`` swap.  ``graph_epoch`` guards staleness."""
    from_slot: int
    n_live: int
    graph_epoch: int
    index: HnswIndex
    attrs: "F.AttributeTable"
    chunk: int
    pv: object
    pn0: object
    pi: object
    pf: object
    codes: object
    g: dict


def _spec_from_legacy(kw: dict) -> BuildSpec:
    """Map the pre-BuildSpec __init__ kwargs onto a BuildSpec."""
    quant = None
    if kw.get("quantize") is not None:
        quant = QuantSpec(
            kind=kw["quantize"],
            m=kw.get("pq_m") if kw.get("pq_m") is not None else 8,
            nbits=kw.get("pq_nbits") if kw.get("pq_nbits") is not None else 8,
            train_iters=(kw.get("pq_train_iters")
                         if kw.get("pq_train_iters") is not None else 20),
            train_sample=(kw.get("pq_train_sample")
                          if kw.get("pq_train_sample") is not None else 65536),
            rerank=kw.get("rerank") if kw.get("rerank") is not None else 4)
    return BuildSpec(
        selector=kw.get("sel_cfg") or selector.SelectorConfig(),
        prefbf_chunk=(kw.get("prefbf_chunk")
                      if kw.get("prefbf_chunk") is not None else 8192),
        quant=quant)


class FavorIndex:
    """Single-host FAVOR index.  Execution goes through a LocalBackend; the
    multi-device variant is core.backend.ShardedBackend behind the same
    Backend protocol (and the same ServeEngine)."""

    def __init__(self, index: HnswIndex, attrs: F.AttributeTable,
                 spec: BuildSpec | None = None, *, codebook=None,
                 codes=None, **legacy):
        if isinstance(spec, selector.SelectorConfig):
            # pre-1.1 third positional was sel_cfg
            if legacy.get("sel_cfg") is not None:
                raise ValueError("sel_cfg passed both positionally and by "
                                 "keyword")
            legacy["sel_cfg"], spec = spec, None
        elif spec is not None and not isinstance(spec, BuildSpec):
            raise TypeError("spec must be a BuildSpec, got "
                            f"{type(spec).__name__}")
        unknown = set(legacy) - set(_LEGACY_BUILD_KW)
        if unknown:
            raise TypeError(f"unexpected FavorIndex kwargs: {sorted(unknown)}")
        if legacy and any(v is not None for v in legacy.values()):
            if spec is not None:
                raise ValueError("pass either spec=BuildSpec(...) or legacy "
                                 "kwargs, not both")
            warnings.warn(
                "FavorIndex(sel_cfg=/quantize=/pq_*=/rerank=...) is "
                "deprecated; pass spec=BuildSpec(...)",
                DeprecationWarning, stacklevel=2)
            spec = _spec_from_legacy(legacy)
        if spec is None:
            spec = BuildSpec()
        # an externally trained/loaded codebook implies its quant kind AND
        # geometry: derive the spec from the codebook so fi.spec faithfully
        # describes the memory format actually in use (reusable for e.g.
        # ShardedBackend.build parity)
        if spec.quant is None and codebook is not None:
            from ..quant import PQCodebook
            rr = legacy.get("rerank")
            rr = rr if rr is not None else 4
            if isinstance(codebook, PQCodebook):
                q = QuantSpec(kind="pq", m=codebook.m, nbits=codebook.nbits,
                              rerank=rr)
            else:
                q = QuantSpec(kind="sq", rerank=rr)
            spec = BuildSpec(hnsw=spec.hnsw, selector=spec.selector,
                             prefbf_chunk=spec.prefbf_chunk, quant=q)

        self.spec = spec
        self.index = index
        self.attrs = attrs
        self.sel_cfg = spec.selector
        self.schema = attrs.schema
        # memoized per (index, attrs): rebuilding a FavorIndex over the same
        # built HNSW (benchmark cache, test fixtures) reuses device arrays;
        # copy so the quantized-scorer keys below never touch the cache
        self.g = dict(graph_arrays(index, attrs))

        samp = selectivity.sample_indices(
            index.n, self.sel_cfg.sample_rate, self.sel_cfg.min_sample,
            self.sel_cfg.max_sample, seed=index.params.seed + 17)
        self.sample_idx = samp
        self.sample_ints = jnp.asarray(attrs.ints[samp])
        self.sample_floats = jnp.asarray(attrs.floats[samp])

        self.prefbf_chunk = min(spec.prefbf_chunk, max(256, index.n))
        pv, pn, pi, pf = prefbf.pad_db(index.vectors,
                                       index.norms.astype(np.float32),
                                       attrs.ints, attrs.floats,
                                       self.prefbf_chunk)
        self._pf = (jnp.asarray(pv), jnp.asarray(pn), jnp.asarray(pi),
                    jnp.asarray(pf))
        # pristine padded norms, kept so tombstones can be (re)masked onto
        # the scan arrays without re-reading the host copy
        self._pn0 = self._pf[1]

        # -- live mutation state (index subsystem) ----------------------------
        self.epochs = ComponentEpochs()
        self.live: LiveState | None = None
        self._alive: np.ndarray | None = None   # base-row tombstone mask

        # -- optional compressed-domain scan state (quant subsystem) ---------
        q = spec.quant
        if q is not None and codebook is not None:
            from ..quant import PQCodebook
            cb_kind = "pq" if isinstance(codebook, PQCodebook) else "sq"
            if cb_kind != q.kind:
                raise ValueError(f"spec.quant.kind={q.kind!r} does not match "
                                 f"the supplied {cb_kind!r} codebook")
            if cb_kind == "pq" and (codebook.m, codebook.nbits) != (q.m, q.nbits):
                raise ValueError(
                    f"spec.quant geometry (m={q.m}, nbits={q.nbits}) does not "
                    f"match the supplied codebook (m={codebook.m}, "
                    f"nbits={codebook.nbits})")
        self.quantize = q.kind if q is not None else None
        self.rerank = q.rerank if q is not None else 4
        self.codebook = codebook
        self._codes = None
        self._cb_dev = None
        self._backend = None
        if codes is not None and q is None:
            raise ValueError("codes= supplied but the index requests no "
                             "quantization (spec.quant is None and no "
                             "codebook was given)")
        if q is not None:
            from .. import quant
            if codebook is None:
                if index.n == 0:
                    raise ValueError(
                        "cannot train a codebook on an empty index; pass "
                        "codebook= (or build unquantized and re-quantize "
                        "after the first merge)")
                if q.kind == "pq":
                    codebook = quant.train_pq(
                        index.vectors, m=q.m, nbits=q.nbits,
                        iters=q.train_iters, sample=q.train_sample,
                        seed=index.params.seed)
                else:
                    codebook = quant.train_sq(index.vectors)
            self.codebook = codebook
            # encode the *padded* DB so code rows align with the _pf arrays
            # (padded rows encode the zero vector; their +inf norms gate them
            # out of the compressed scan)
            if codes is not None:
                codes = np.asarray(codes)
                if codes.shape[0] != index.n:
                    raise ValueError(f"codes= carries {codes.shape[0]} rows "
                                     f"for an index of {index.n}")
                pad = pv.shape[0] - index.n
                if pad:
                    codes = np.concatenate([
                        codes, quant.encode(
                            codebook, np.zeros((pad, index.dim), np.float32))])
                self._codes = jnp.asarray(codes)
            else:
                self._codes = jnp.asarray(quant.encode(codebook, pv))
            if q.kind == "pq":
                self._cb_dev = (jnp.asarray(codebook.centroids),)
            else:
                self._cb_dev = (jnp.asarray(codebook.lo),
                                jnp.asarray(codebook.scale))
            self._attach_scorer_arrays()

    # -- construction --------------------------------------------------------
    @staticmethod
    def build(vectors: np.ndarray, attrs: F.AttributeTable,
              params: HnswParams | None = None,
              spec: BuildSpec | None = None, **kw) -> "FavorIndex":
        if spec is not None and spec.hnsw is not None:
            if params is not None:
                raise ValueError("pass HNSW params via either params= or "
                                 "spec.hnsw, not both")
            params = spec.hnsw
        t0 = time.perf_counter()
        index = build_hnsw(vectors, params)
        build_s = time.perf_counter() - t0
        fi = FavorIndex(index, attrs, spec, **kw)
        fi.build_seconds = build_s
        return fi

    @property
    def delta_d(self) -> float:
        return self.index.delta_d

    def _attach_scorer_arrays(self) -> None:
        """Graph-route scorer arrays (core.scoring): code rows 0..N-1 of the
        padded encoding align with the graph arrays (pad_db appends), so the
        traversal can score on codes via SearchOptions.graph_quant."""
        if self._codes is None:
            return
        self.g["codes"] = self._codes[: self.index.n]
        if self.quantize == "pq":
            self.g["centroids"] = self._cb_dev[0]
        else:
            self.g["sq_lo"], self.g["sq_scale"] = self._cb_dev

    def version(self) -> int:
        """Aggregate data epoch consumed by layered caches
        (Backend.version): any component bump changes it."""
        return self.epochs.total

    def versions(self) -> dict:
        """Scoped epochs (vectors / attributes / graph) for caches that
        invalidate per component instead of dropping everything."""
        return self.epochs.as_dict()

    def bump_version(self, components: tuple[str, ...] | None = None) -> int:
        """Mark served rows as changed (rebuild, attribute update):
        CachingBackend wrappers invalidate on the next call and the memoized
        graph arrays are re-uploaded under the new epoch (an in-place attrs
        edit would otherwise keep serving the stale device copies).

        ``components`` (subset of vectors/attributes/graph) scopes the bump:
        only the named components' device arrays are re-uploaded (the rest
        are reused from the current dict) and only their epochs move.  None
        keeps the legacy bump-everything behavior.
        """
        if components is None:
            self.epochs.bump_all()
            self.g = dict(graph_arrays(self.index, self.attrs,
                                       version=self.epochs.total))
        else:
            self.epochs.bump(*components)
            self.g = dict(refresh_graph_arrays(
                self.index, self.attrs, base=self.g,
                changed=tuple(components), version=self.epochs.total))
        self._attach_scorer_arrays()
        if self._alive is not None:
            self.g["alive"] = jnp.asarray(self._alive)
        return self.epochs.total

    # -- live mutation API (index subsystem) ----------------------------------
    def _ensure_live(self) -> LiveState:
        if self.live is None:
            self.live = LiveState(self.index.n, self.index.dim,
                                  self.attrs.ints.shape[1],
                                  self.attrs.floats.shape[1])
        return self.live

    def _apply_tombstones(self, dead_rows: np.ndarray) -> None:
        """Thread newly-dead base rows onto the device arrays: an ``alive``
        key for the graph traversal and +inf norms for every brute scan.
        Nothing else re-uploads -- vectors/neighbors/attrs stay put."""
        if len(dead_rows) == 0:
            return
        alive = self.live.base_alive
        self._alive = alive
        self.g["alive"] = jnp.asarray(alive)
        pad = self._pn0.shape[0] - self.index.n
        alive_pad = np.concatenate([alive, np.ones((pad,), bool)])
        self._pf = (self._pf[0],
                    jnp.where(jnp.asarray(alive_pad), self._pn0, jnp.inf),
                    self._pf[2], self._pf[3])

    def upsert(self, vectors: np.ndarray, ints=None, floats=None, *,
               replace=None) -> np.ndarray:
        """Stream rows into the live delta; returns their ids (positional:
        ``base_n + slot``).  ``replace=`` retires the named ids first (an
        update is delete + fresh insert; the new ids are the handles)."""
        live = self._ensure_live()
        ids, dead = live.upsert(vectors, ints, floats, replace=replace)
        self._apply_tombstones(dead)
        self.epochs.bump("vectors")
        return ids

    def delete(self, ids) -> int:
        """Tombstone ids (base rows or unmerged delta rows); returns how
        many were found alive."""
        live = self._ensure_live()
        n, dead = live.delete(ids)
        self._apply_tombstones(dead)
        if n:
            self.epochs.bump("vectors")
        return n

    def live_view(self):
        return None if self.live is None else self.live.view()

    def live_stats(self) -> dict:
        if self.live is None:
            return {"base_rows": self.index.n, "dead_base_rows": 0,
                    "delta_rows": 0, "delta_slots": 0, "upserts": 0,
                    "deletes": 0, "replaced": 0, "missing_deletes": 0}
        return self.live.stats()

    def merge_prepare(self, *, wave: int = 512,
                      on_wave=None) -> "_MergePrep | None":
        """Phase 1 of a merge: snapshot the delta and run the expensive work
        (bulk graph build, attribute concat, scan-array padding, code
        re-encode, device upload) WITHOUT mutating any served state.

        Safe to run off-thread while serving continues: the snapshot
        boundary is ``cnt = delta.count`` read *before* any array reference
        (append never rewrites rows below ``count`` and ``_grow`` reallocs,
        so rows ``[:cnt]`` of whatever arrays we then see are stable), and
        ``bulk_add`` builds into a fresh builder without touching the source
        index.  Returns None when there is nothing to merge.
        """
        from ..index.bulk import bulk_add
        live = self.live
        if live is None or live.delta.count == 0:
            return None
        d = live.delta
        cnt = int(d.count)       # snapshot boundary: read BEFORE array refs
        index, attrs = self.index, self.attrs
        vecs = d.vectors[:cnt].copy()
        ints = d.ints[:cnt].copy()
        flts = d.floats[:cnt].copy()
        link = d.alive[:cnt].copy()
        graph_epoch = self.epochs.graph
        new_index = bulk_add(index, vecs, wave=wave, link=link,
                             on_wave=on_wave)
        new_attrs = F.AttributeTable(
            self.schema,
            np.concatenate([attrs.ints, ints]),
            np.concatenate([attrs.floats, flts]))
        chunk = min(self.spec.prefbf_chunk, max(256, new_index.n))
        pv, pn, pi, pf = prefbf.pad_db(new_index.vectors,
                                       new_index.norms.astype(np.float32),
                                       new_attrs.ints, new_attrs.floats,
                                       chunk)
        codes = None
        if self.codebook is not None:
            from .. import quant
            codes = jnp.asarray(quant.encode(self.codebook, pv))
        # pre-upload the graph/scan arrays here (the slow part); the commit
        # assigns the dict directly instead of re-keying the memo
        g = dict(graph_arrays(new_index, new_attrs, version=0))
        return _MergePrep(
            from_slot=cnt, n_live=int(link.sum()), graph_epoch=graph_epoch,
            index=new_index, attrs=new_attrs, chunk=chunk,
            pv=jnp.asarray(pv), pn0=jnp.asarray(pn), pi=jnp.asarray(pi),
            pf=jnp.asarray(pf), codes=codes, g=g)

    def merge_commit(self, prep: "_MergePrep") -> dict | None:
        """Phase 2: atomic swap of the served state onto the prepared merge.

        Cheap (no device upload, no build) -- callers holding a serving lock
        can run it without a perceptible stall.  Mutations that landed since
        the snapshot are honored: deletes become tombstones on the fresh
        arrays (current ``live`` alive state wins over the snapshot's), and
        delta slots past the snapshot boundary carry into the new delta with
        their ids intact (positional-id discipline).  Returns None -- and
        changes nothing -- if the base graph was rebuilt since the snapshot
        (a competing merge or explicit rebuild), in which case the prepared
        state is stale and must be discarded.
        """
        live = self.live
        if live is None or self.epochs.graph != prep.graph_epoch:
            return None
        cnt = prep.from_slot
        base = (live.base_alive if live.base_alive is not None
                else np.ones((live.base_n,), bool))
        alive = np.concatenate([base, live.delta.alive[:cnt]])
        self._alive = None if alive.all() else alive
        self.index = prep.index
        self.attrs = prep.attrs
        self.prefbf_chunk = prep.chunk

        self._pn0 = prep.pn0
        pn = prep.pn0
        if self._alive is not None:
            pad = int(pn.shape[0]) - prep.index.n
            alive_pad = np.concatenate([self._alive, np.ones((pad,), bool)])
            pn = jnp.where(jnp.asarray(alive_pad), pn, jnp.inf)
        self._pf = (prep.pv, pn, prep.pi, prep.pf)
        self._codes = prep.codes

        # vectors (membership) and graph (base arrays rebuilt) move;
        # attributes deliberately do not -- the estimator sample is untouched
        self.epochs.bump("vectors", "graph")
        self.g = dict(prep.g)
        self._attach_scorer_arrays()
        if self._alive is not None:
            self.g["alive"] = jnp.asarray(self._alive)
        live.reset_after_merge(prep.index.n, self._alive, from_slot=cnt)
        return {"merged_slots": cnt, "merged_live": prep.n_live,
                "n": prep.index.n}

    def merge(self, *, wave: int = 512) -> dict:
        """Fold the delta segment into the base HNSW (device-parallel bulk
        build) and return to the static fast path.

        Every delta *slot* is appended in order -- dead slots ride along as
        tombstoned, unlinked rows -- so surviving ids keep their positions.
        The selectivity sample is intentionally left untouched: base rows
        keep their ids and their attributes, so the estimator (and any
        selectivity cache over it) stays warm across merges.

        Implemented as ``merge_prepare`` + ``merge_commit``; background
        callers run the two phases on different threads.
        """
        prep = self.merge_prepare(wave=wave)
        if prep is None:
            return {"merged_slots": 0, "merged_live": 0, "n": self.index.n}
        out = self.merge_commit(prep)
        if out is None:  # pragma: no cover - single-threaded epochs are stable
            raise RuntimeError("merge_commit rejected a same-thread prepare")
        return out

    @property
    def backend(self):
        """The LocalBackend view of this index (cached)."""
        if self._backend is None:
            from .backend import LocalBackend
            self._backend = LocalBackend(self)
        return self._backend

    def compile_filters(self, filters, width: int = 8) -> dict:
        if isinstance(filters, F.Filter):
            filters = [filters]
        return compile_programs(filters, self.schema, len(filters), width)

    # -- online search --------------------------------------------------------
    def query(self, queries: np.ndarray, filters,
              opts: SearchOptions | None = None) -> SearchResult:
        """Typed search API: one SearchOptions drives routing + execution
        (shared router; identical on every backend)."""
        return execute(self.backend, queries, filters, opts or SearchOptions())

    def search(self, queries: np.ndarray, filters, k: int = 10, ef: int = 100,
               *, pbar_min: float = 0.5, gamma: float = 1.0,
               force: str | None = None, use_pallas: bool = False,
               cand_cap: int = 0, use_pq: bool = False,
               rerank: int | None = None) -> SearchResult:
        """Deprecated kwarg shim over ``query``; kept so pre-SearchOptions
        callers run unmodified.  ``rerank=0`` is honored (re-rank exactly the
        top k) -- it is no longer swallowed by a falsy-or default."""
        warnings.warn(
            "FavorIndex.search(k=, ef=, ...) is deprecated; use "
            "FavorIndex.query(queries, filters, SearchOptions(...))",
            DeprecationWarning, stacklevel=2)
        opts = SearchOptions(k=k, ef=ef, pbar_min=pbar_min, gamma=gamma,
                             force=force, cand_cap=cand_cap,
                             use_pallas=use_pallas, use_pq=use_pq,
                             rerank=rerank)
        return self.query(queries, filters, opts)

    def bytes_per_vector(self, quantized: bool = False) -> int:
        """Bytes streamed per DB row by the brute scan (float32 vs codes)."""
        if quantized:
            if self.codebook is None:
                raise ValueError("index is not quantized")
            return self.codebook.bytes_per_vector()
        return 4 * self.index.dim

    # -- persistence -----------------------------------------------------------
    def _quant_payload(self) -> dict | None:
        """Quantization state persisted inside the .hnsw.npz: the codebook
        tables AND the encoded codes (unpadded), so a reloaded index serves
        use_pq / graph_quant without re-training or re-encoding."""
        if self.codebook is None:
            return None
        payload = {"kind": self.quantize, "dim": self.codebook.dim,
                   "codes": np.asarray(self._codes)[: self.index.n]}
        if self.quantize == "pq":
            payload["centroids"] = np.asarray(self.codebook.centroids)
        else:
            payload["lo"] = np.asarray(self.codebook.lo)
            payload["scale"] = np.asarray(self.codebook.scale)
        return payload

    def save(self, path: str) -> None:
        if self.live is not None and (self.live.delta.count
                                      or self.live.has_tombstones):
            warnings.warn(
                "FavorIndex.save: unmerged live mutations (delta rows or "
                "tombstones) are not persisted -- call merge() first",
                stacklevel=2)
        self.index.save(path + ".hnsw.npz", quant=self._quant_payload())
        np.savez_compressed(path + ".attrs.npz", ints=self.attrs.ints,
                            floats=self.attrs.floats,
                            kinds=np.array([c.kind for c in self.schema.columns]),
                            names=np.array([c.name for c in self.schema.columns]),
                            vocabs=np.array([c.vocab or 0 for c in self.schema.columns]))
        if self.codebook is not None:
            from ..quant import save_codebook
            save_codebook(path + ".quant.npz", self.codebook)

    @staticmethod
    def load(path: str, spec: BuildSpec | None = None, **kw) -> "FavorIndex":
        index = HnswIndex.load(path + ".hnsw.npz")
        z = np.load(path + ".attrs.npz")
        cols = tuple(
            F.ColumnSpec(str(n), str(k), int(v) if str(k) == "int" else None)
            for n, k, v in zip(z["names"], z["kinds"], z["vocabs"]))
        attrs = F.AttributeTable(F.Schema(cols), z["ints"], z["floats"])
        qs = index.quant_state
        if qs is not None and kw.get("codebook") is None:
            from ..quant import PQCodebook, SQCodebook
            if qs["kind"] == "pq":
                kw["codebook"] = PQCodebook(qs["centroids"], int(qs["dim"]))
            else:
                kw["codebook"] = SQCodebook(qs["lo"], qs["scale"],
                                            int(qs["dim"]))
            kw.setdefault("codes", qs["codes"])
        elif kw.get("codebook") is None:
            qpath = path + ".quant.npz"
            if os.path.exists(qpath):
                from ..quant import load_codebook
                kw["codebook"] = load_codebook(qpath)  # kind is inferred
            elif spec is not None and spec.quant is not None:
                raise ValueError(
                    f"spec requests quant kind={spec.quant.kind!r} but "
                    f"{path!r} was saved without quantization state; rebuild "
                    "with a QuantSpec or pass codebook= explicitly")
        qs_kind = qs["kind"] if qs is not None else None
        if (qs_kind is not None and spec is not None and spec.quant is not None
                and spec.quant.kind != qs_kind):
            raise ValueError(f"spec requests quant kind={spec.quant.kind!r} "
                             f"but the saved index carries {qs_kind!r}")
        return FavorIndex(index, attrs, spec, **kw)
