"""FavorIndex: the end-to-end FAVOR API (paper Figure 1 workflow).

Offline:  build a conventional HNSW over the vectors, record Delta_d (Eq. 5),
          draw the selectivity sample, attach the attribute table.
Online :  compile each query's filter to a DNF program, estimate p_hat on the
          sample (section 4.2), route by lambda (section 4.1), compute the
          exclusion distance D(p_hat) (Eq. 14) and execute either the PreFBF
          scan or the exclusion-distance graph search (section 5), returning
          the k nearest target points.

The two online paths are separate jitted programs (one compiled executable
per route); the host-side engine partitions each batch by route -- mixing
them in one program would force both computations on every query.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import exclusion
from . import filters as F
from . import prefbf, selectivity, selector
from .hnsw import HnswIndex, HnswParams, build_hnsw
from .search import SearchConfig, favor_graph_search, graph_arrays


@dataclass
class SearchResult:
    ids: np.ndarray      # (B, k) int64, -1 padded
    dists: np.ndarray    # (B, k) float32, +inf padded
    p_hat: np.ndarray    # (B,)
    routed_brute: np.ndarray  # (B,) bool
    hops: np.ndarray     # (B,) graph hops (0 for brute-routed queries)
    path_td: np.ndarray  # (B,)
    elapsed_s: float = 0.0

    @property
    def qps(self) -> float:
        return len(self.ids) / max(self.elapsed_s, 1e-12)


class FavorIndex:
    """Single-host FAVOR index (the sharded serve path lives in
    distributed.py and reuses the same array layout per shard)."""

    def __init__(self, index: HnswIndex, attrs: F.AttributeTable,
                 sel_cfg: selector.SelectorConfig | None = None,
                 prefbf_chunk: int = 8192, quantize: str | None = None,
                 pq_m: int = 8, pq_nbits: int = 8, pq_train_iters: int = 20,
                 pq_train_sample: int = 65536, rerank: int = 4,
                 codebook=None):
        self.index = index
        self.attrs = attrs
        self.sel_cfg = sel_cfg or selector.SelectorConfig()
        self.schema = attrs.schema
        self.g = graph_arrays(index, attrs)

        samp = selectivity.sample_indices(
            index.n, self.sel_cfg.sample_rate, self.sel_cfg.min_sample,
            self.sel_cfg.max_sample, seed=index.params.seed + 17)
        self.sample_idx = samp
        self.sample_ints = jnp.asarray(attrs.ints[samp])
        self.sample_floats = jnp.asarray(attrs.floats[samp])

        self.prefbf_chunk = min(prefbf_chunk, max(256, index.n))
        pv, pn, pi, pf = prefbf.pad_db(index.vectors,
                                       index.norms.astype(np.float32),
                                       attrs.ints, attrs.floats,
                                       self.prefbf_chunk)
        self._pf = (jnp.asarray(pv), jnp.asarray(pn), jnp.asarray(pi),
                    jnp.asarray(pf))

        # -- optional compressed-domain scan state (quant subsystem) ---------
        if quantize is None and codebook is not None:
            from ..quant import PQCodebook
            quantize = "pq" if isinstance(codebook, PQCodebook) else "sq"
        self.quantize = quantize
        self.rerank = rerank
        self.codebook = codebook
        self._codes = None
        self._cb_dev = None
        if quantize is not None:
            from .. import quant
            if codebook is None:
                if quantize == "pq":
                    codebook = quant.train_pq(
                        index.vectors, m=pq_m, nbits=pq_nbits,
                        iters=pq_train_iters, sample=pq_train_sample,
                        seed=index.params.seed)
                elif quantize == "sq":
                    codebook = quant.train_sq(index.vectors)
                else:
                    raise ValueError(
                        f"quantize must be 'pq', 'sq' or None, got {quantize!r}")
            self.codebook = codebook
            # encode the *padded* DB so code rows align with the _pf arrays
            # (padded rows encode the zero vector; their +inf norms gate them
            # out of the compressed scan)
            self._codes = jnp.asarray(quant.encode(codebook, pv))
            if quantize == "pq":
                self._cb_dev = (jnp.asarray(codebook.centroids),)
            else:
                self._cb_dev = (jnp.asarray(codebook.lo),
                                jnp.asarray(codebook.scale))

    # -- construction --------------------------------------------------------
    @staticmethod
    def build(vectors: np.ndarray, attrs: F.AttributeTable,
              params: HnswParams | None = None, **kw) -> "FavorIndex":
        t0 = time.perf_counter()
        index = build_hnsw(vectors, params)
        build_s = time.perf_counter() - t0
        fi = FavorIndex(index, attrs, **kw)
        fi.build_seconds = build_s
        return fi

    @property
    def delta_d(self) -> float:
        return self.index.delta_d

    def compile_filters(self, filters, width: int = 8) -> dict:
        if isinstance(filters, F.Filter):
            filters = [filters]
        progs = [F.compile_filter(f, self.schema, width) for f in filters]
        return {k: jnp.asarray(v) for k, v in F.stack_programs(progs).items()}

    # -- online search --------------------------------------------------------
    def search(self, queries: np.ndarray, filters, k: int = 10, ef: int = 100,
               *, pbar_min: float = 0.5, gamma: float = 1.0,
               force: str | None = None, use_pallas: bool = False,
               cand_cap: int = 0, use_pq: bool = False,
               rerank: int | None = None) -> SearchResult:
        """force in {None, "graph", "brute"} pins the route (benchmarks).

        use_pq routes the brute path through the compressed ADC scan (the
        index must have been built with quantize=); results are exact
        float32 re-ranks of the top rerank*k ADC candidates."""
        if use_pq and self.codebook is None:
            raise ValueError("use_pq=True needs an index built with "
                             "quantize='pq' or 'sq'")
        queries = jnp.asarray(np.ascontiguousarray(queries, np.float32))
        B = queries.shape[0]
        if isinstance(filters, F.Filter):
            filters = [filters] * B
        assert len(filters) == B, "one filter per query"
        programs = self.compile_filters(filters)

        t0 = time.perf_counter()
        p_hat = np.asarray(selector.estimate_batched(
            programs, self.sample_ints, self.sample_floats))
        if force == "brute":
            brute = np.ones((B,), bool)
        elif force == "graph":
            brute = np.zeros((B,), bool)
        else:
            brute = selector.route(p_hat, self.sel_cfg.lam)

        ids = np.full((B, k), -1, np.int64)
        dists = np.full((B, k), np.inf, np.float32)
        hops = np.zeros((B,), np.int64)
        path_td = np.zeros((B,), np.int64)

        gi = np.nonzero(~brute)[0]
        bi = np.nonzero(brute)[0]
        if len(gi):
            cfg = SearchConfig(k=k, ef=ef, pbar_min=pbar_min, gamma=gamma,
                               cand_cap=cand_cap, use_pallas=use_pallas)
            progs_g = {kk: jnp.asarray(np.asarray(v)[gi]) for kk, v in programs.items()}
            D = exclusion.exclusion_distance(
                jnp.asarray(p_hat[gi]), ef, self.delta_d, k=k,
                p_min=self.sel_cfg.p_min, xp=jnp)
            out = favor_graph_search(self.g, queries[gi], progs_g, D, cfg)
            ids[gi] = np.asarray(out["ids"])
            dists[gi] = np.asarray(out["dists"])
            hops[gi] = np.asarray(out["hops"])
            path_td[gi] = np.asarray(out["path_td"])
        if len(bi):
            progs_b = {kk: jnp.asarray(np.asarray(v)[bi]) for kk, v in programs.items()}
            if use_pq:
                from ..quant import adc as quant_adc
                pv, pn, pi, pf = self._pf
                rr = rerank or self.rerank
                if self.quantize == "pq":
                    bid, bd = quant_adc.pq_prefbf_topk(
                        self._codes, pn, pi, pf, queries[bi], progs_b,
                        self._cb_dev[0], pv, k=k, rerank=rr,
                        chunk=self.prefbf_chunk, use_pallas=use_pallas)
                else:
                    bid, bd = quant_adc.sq_prefbf_topk(
                        self._codes, self._cb_dev[0], self._cb_dev[1],
                        pn, pi, pf, queries[bi], progs_b, pv,
                        k=k, rerank=rr, chunk=self.prefbf_chunk)
            else:
                bid, bd = prefbf.prefbf_topk(*self._pf, queries[bi], progs_b,
                                             k=k, chunk=self.prefbf_chunk,
                                             use_pallas=use_pallas)
            ids[bi] = np.asarray(bid)
            dists[bi] = np.asarray(bd)
        jax.block_until_ready(dists)
        elapsed = time.perf_counter() - t0
        return SearchResult(ids, dists, p_hat, brute, hops, path_td, elapsed)

    def bytes_per_vector(self, quantized: bool = False) -> int:
        """Bytes streamed per DB row by the brute scan (float32 vs codes)."""
        if quantized:
            if self.codebook is None:
                raise ValueError("index is not quantized")
            return self.codebook.bytes_per_vector()
        return 4 * self.index.dim

    # -- persistence -----------------------------------------------------------
    def save(self, path: str) -> None:
        self.index.save(path + ".hnsw.npz")
        np.savez_compressed(path + ".attrs.npz", ints=self.attrs.ints,
                            floats=self.attrs.floats,
                            kinds=np.array([c.kind for c in self.schema.columns]),
                            names=np.array([c.name for c in self.schema.columns]),
                            vocabs=np.array([c.vocab or 0 for c in self.schema.columns]))
        if self.codebook is not None:
            from ..quant import save_codebook
            save_codebook(path + ".quant.npz", self.codebook)

    @staticmethod
    def load(path: str, **kw) -> "FavorIndex":
        index = HnswIndex.load(path + ".hnsw.npz")
        z = np.load(path + ".attrs.npz")
        cols = tuple(
            F.ColumnSpec(str(n), str(k), int(v) if str(k) == "int" else None)
            for n, k, v in zip(z["names"], z["kinds"], z["vocabs"]))
        attrs = F.AttributeTable(F.Schema(cols), z["ints"], z["floats"])
        qpath = path + ".quant.npz"
        if os.path.exists(qpath) and kw.get("codebook") is None:
            from ..quant import load_codebook
            kw["codebook"] = load_codebook(qpath)  # __init__ infers quantize
        return FavorIndex(index, attrs, **kw)
