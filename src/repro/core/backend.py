"""Pluggable execution backends behind the unified search API.

``router.execute`` owns the host-side pipeline (compile -> estimate -> route
-> partition); a ``Backend`` owns the device-side execution of each route.
The protocol is the paper's Figure-1 seam:

    estimate(programs)                      -> (B,) selectivity p_hat
    search_graph(queries, programs, p_hat, opts) -> {"ids","dists",...}
    search_brute(queries, programs, opts)        -> (ids, dists)

Two implementations ship here:

  LocalBackend   -- single-host, extracted from the seed ``FavorIndex.search``
                    body: per-route jitted executables, PQ/SQ ADC brute scan.
                    The graph route's scorer (f32 / PQ-ADC / SQ, see
                    core.scoring) is picked by ``SearchOptions.graph_quant``,
                    which lowers into the jit-static SearchConfig.
  ShardedBackend -- multi-device serve path over ``distributed.make_serve_fns``
                    (DB sharded on "model", queries on "data"), including the
                    sharded compressed brute route: PQ codes are co-sharded
                    with their vectors and each shard runs the ADC LUT scan +
                    exact re-rank before the cross-shard top-k merge.
                    ``use_pallas=True`` routes each shard's brute scan through
                    the filtered_topk / pq_adc Pallas kernels inside the
                    shard_map body (previously LocalBackend-only).

Both expose ``schema`` / ``sel_cfg`` so the router takes identical routing
decisions regardless of where execution lands, and ``validate(opts)`` so
option/state mismatches (e.g. ``use_pq`` without a codebook) fail before any
device work.  Future backends (caching, async, remote) implement the same
three methods and plug into ``ServeEngine`` unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Protocol, runtime_checkable

import jax.numpy as jnp
import numpy as np

from . import distributed as dist
from . import exclusion
from . import filters as F
from . import prefbf, selector
from .options import BuildSpec, SearchOptions
from .search import favor_graph_search
# gated host-side profiler scopes (nullcontext unless ObsSpec enables
# kernel annotations); repro.obs.profiling imports nothing from core
from ..obs.profiling import annotate as _annotate
from ..index.delta import compose_topk_dev
from ..index.epochs import ComponentEpochs
from ..index.live import LiveState

if TYPE_CHECKING:
    from .favor import FavorIndex


@dataclass
class _ShardMergePrep:
    """Off-thread-prepared sharded merge, ready for an atomic commit.
    ``kind`` is "incr" (grow the last shard in place) or "full" (fresh
    build_sharded with headroom); ``graph_epoch`` guards staleness."""
    kind: str
    from_slot: int
    n_live: int
    graph_epoch: int
    base_n: int
    shard: int = -1
    index: object = None       # incr: the grown last-shard HnswIndex
    vectors: object = None     # incr: snapshot delta rows
    ints: object = None
    floats: object = None
    codes: object = None
    sharded: object = None     # full: the rebuilt ShardedFavorArrays
    parts: object = None       # full: per-shard index handles
    n_tot: int = -1


@runtime_checkable
class Backend(Protocol):
    """Execution backend contract consumed by router.execute / ServeEngine.

    The search methods take an optional ``valid`` (B,) bool mask (the
    bucket-padding contract, core.batching): rows with ``valid=False`` are
    pad rows -- they carry always-false filter programs, must return
    ids=-1 / dists=+inf, and must never influence real rows.  ``valid=None``
    means every row is real (the unpadded path)."""

    schema: F.Schema
    sel_cfg: selector.SelectorConfig

    def validate(self, opts: SearchOptions) -> None:
        """Raise ValueError when ``opts`` cannot run on this backend."""
        ...

    def version(self) -> int:
        """Monotonic data epoch: bumped whenever the served rows change, so
        layered caches (repro.cache.CachingBackend) can drop stale entries
        without tracking individual mutations."""
        ...

    def estimate(self, programs: dict, valid=None):
        """(B,) estimated selectivity over the backend's sample.  ``valid``
        marks pad rows exactly as in the search methods; device backends
        may ignore it (always-false pad programs estimate to 0 and are
        sliced off), host-side layers (CachingBackend) use it to keep pad
        rows out of their caches and counters."""
        ...

    def search_graph(self, queries, programs: dict, p_hat,
                     opts: SearchOptions, valid=None) -> dict:
        """Exclusion-distance graph route; returns at least ids/dists."""
        ...

    def search_brute(self, queries, programs: dict, opts: SearchOptions,
                     valid=None):
        """PreFBF brute route (float32 or compressed); returns (ids, dists)."""
        ...

    def bytes_per_hop(self, opts: SearchOptions) -> int:
        """Bytes one gathered neighbor row streams from HBM under ``opts``'
        graph scorer (4*d for f32, M codes for PQ, d codes for SQ) -- the
        bandwidth story ServeEngine exports as the favor_bytes_per_hop
        gauge."""
        ...


# ---------------------------------------------------------------------------
# Local (single-host) backend
# ---------------------------------------------------------------------------
class LocalBackend:
    """Single-host execution over a built FavorIndex's device arrays."""

    def __init__(self, index: "FavorIndex"):
        self.index = index

    @property
    def schema(self) -> F.Schema:
        return self.index.schema

    @property
    def sel_cfg(self) -> selector.SelectorConfig:
        return self.index.sel_cfg

    @property
    def dim(self) -> int:
        """Query vector dimensionality (warmup builds dummy batches off it)."""
        return int(self.index.index.dim)

    def validate(self, opts: SearchOptions) -> None:
        if opts.use_pq and self.index.codebook is None:
            raise ValueError("use_pq=True needs an index built with "
                             "quantize='pq' or 'sq' (BuildSpec.quant)")
        if (opts.graph_quant is not None
                and self.index.quantize != opts.graph_quant):
            raise ValueError(
                f"graph_quant={opts.graph_quant!r} needs an index built "
                f"with quantize={opts.graph_quant!r} codes "
                f"(this one has {self.index.quantize!r})")

    def version(self) -> int:
        """Data epoch of the underlying FavorIndex (see Backend.version)."""
        return self.index.version()

    def versions(self) -> dict:
        """Scoped epochs (index subsystem): vectors / attributes / graph."""
        return self.index.versions()

    # -- live mutation passthrough (index subsystem) --------------------------
    def upsert(self, vectors, ints=None, floats=None, *, replace=None):
        return self.index.upsert(vectors, ints, floats, replace=replace)

    def delete(self, ids):
        return self.index.delete(ids)

    def merge(self, *, wave: int = 512) -> dict:
        return self.index.merge(wave=wave)

    def merge_prepare(self, *, wave: int = 512, on_wave=None):
        """Background-merge phase 1 (no served-state mutation); see
        FavorIndex.merge_prepare."""
        return self.index.merge_prepare(wave=wave, on_wave=on_wave)

    def merge_commit(self, prep):
        """Background-merge phase 2 (cheap atomic swap); see
        FavorIndex.merge_commit."""
        return self.index.merge_commit(prep)

    def live_view(self):
        return self.index.live_view()

    def live_stats(self) -> dict:
        return self.index.live_stats()

    def _delta(self):
        """The live delta segment when it has rows to serve, else None."""
        live = self.index.live
        if live is None or live.delta.live_count == 0:
            return None
        return live.delta

    def estimate(self, programs: dict, valid=None):
        # pad rows carry always-false programs (p_hat 0) -- no mask needed
        if self.index.sample_ints.shape[0] == 0:
            # empty base (delta-only index): no sample to estimate over --
            # claim p_hat=1 so the router keeps everything on the graph/
            # compose path rather than trusting a 0/0
            b = int(next(iter(programs.values())).shape[0])
            return jnp.ones((b,), jnp.float32)
        return selector.estimate_batched(programs, self.index.sample_ints,
                                         self.index.sample_floats)

    def search_graph(self, queries, programs: dict, p_hat,
                     opts: SearchOptions, valid=None) -> dict:
        idx = self.index
        cfg = opts.search_config()
        if idx.index.n > 0:
            D = exclusion.exclusion_distance(
                jnp.asarray(p_hat), opts.ef, idx.delta_d, k=opts.k,
                p_min=idx.sel_cfg.p_min, xp=jnp)
            with _annotate("favor/local/graph_search"):
                base = favor_graph_search(idx.g, queries, programs, D, cfg,
                                          valid=valid)
        else:
            b = int(queries.shape[0])
            base = {"ids": np.full((b, opts.k), -1, np.int64),
                    "dists": np.full((b, opts.k), np.inf, np.float32),
                    "hops": np.zeros((b,), np.int32),
                    "path_td": np.zeros((b,), np.int32),
                    "waves": np.zeros((b,), np.int32)}
        delta = self._delta()
        if delta is None:
            return base
        # device-side compose: the fold stays on the async-dispatch path (no
        # host sync mid-step); bit-identical to the host sort-merge (stable
        # argsort, base-first concat)
        gi, gd = delta.scan_dev(queries, programs, k=opts.k, valid=valid)
        ci, cd = compose_topk_dev(base["ids"], base["dists"], gi, gd, opts.k)
        out = dict(base)
        out["ids"], out["dists"] = ci, cd
        return out

    def search_brute(self, queries, programs: dict, opts: SearchOptions,
                     valid=None):
        idx = self.index
        pv, pn, pi, pf = idx._pf
        if idx.index.n == 0:
            # empty base (delta-only index): nothing to scan -- and the
            # chunked reshape cannot infer a -1 axis over zero rows
            b = int(queries.shape[0])
            ids = np.full((b, opts.k), -1, np.int64)
            dists = np.full((b, opts.k), np.inf, np.float32)
        elif not opts.use_pq:
            with _annotate("favor/local/prefbf_scan"):
                ids, dists = prefbf.prefbf_topk(pv, pn, pi, pf, queries,
                                                programs, k=opts.k,
                                                chunk=idx.prefbf_chunk,
                                                use_pallas=opts.use_pallas,
                                                valid=valid)
        else:
            from ..quant import adc as quant_adc
            rr = opts.rerank if opts.rerank is not None else idx.rerank
            if idx.quantize == "pq":
                with _annotate("favor/local/pq_adc_scan"):
                    ids, dists = quant_adc.pq_prefbf_topk(
                        idx._codes, pn, pi, pf, queries, programs,
                        idx._cb_dev[0], pv, k=opts.k, rerank=rr,
                        chunk=idx.prefbf_chunk, use_pallas=opts.use_pallas,
                        valid=valid)
            else:
                with _annotate("favor/local/sq_adc_scan"):
                    ids, dists = quant_adc.sq_prefbf_topk(
                        idx._codes, idx._cb_dev[0], idx._cb_dev[1], pn, pi,
                        pf, queries, programs, pv, k=opts.k, rerank=rr,
                        chunk=idx.prefbf_chunk, valid=valid)
        delta = self._delta()
        if delta is None:
            return ids, dists
        # delta rows are scanned exact f32 even under use_pq: the buffer is
        # tiny, so exactness is free and only sharpens the compressed route
        gi, gd = delta.scan_dev(queries, programs, k=opts.k, valid=valid)
        return compose_topk_dev(ids, dists, gi, gd, opts.k)

    # -- accounting -----------------------------------------------------------
    def bytes_per_hop(self, opts: SearchOptions) -> int:
        """Bytes one gathered neighbor row streams under ``opts``' graph
        scorer (see Backend.bytes_per_hop)."""
        from .scoring import scorer_for
        return int(scorer_for(opts.search_config())
                   .bytes_per_row(self.index.g))


# ---------------------------------------------------------------------------
# Sharded (multi-device) backend
# ---------------------------------------------------------------------------
class ShardedBackend:
    """Multi-device serve path: DB rows (and PQ codes) sharded on
    ``model_axis``, query batches sharded on ``query_axes``.

    Per-(k, ef, ...) serve executables are built lazily from
    ``distributed.make_serve_fns`` and cached on the jit-static SearchConfig,
    mirroring the per-route compiled-program reuse of the local path.
    """

    def __init__(self, mesh, sharded: dist.ShardedFavorArrays,
                 schema: F.Schema, *, sel_cfg=None, codebook=None,
                 rerank: int = 4, prefbf_chunk: int = 65536,
                 query_axes=("data",), model_axis: str = "model",
                 hnsw_params=None, seed: int = 0,
                 merge_headroom: float = 1.0):
        self.mesh = mesh
        self.schema = schema
        self.sel_cfg = sel_cfg or selector.SelectorConfig()
        self.rerank = rerank
        self.prefbf_chunk = prefbf_chunk
        self.query_axes = tuple(query_axes)
        self.model_axis = model_axis
        self.codebook = codebook
        self.hnsw_params = hnsw_params   # needed by merge() to rebuild shards
        self.seed = seed
        if codebook is not None and sharded.quant is None:
            sharded = dist.attach_quant(sharded, codebook)
        self.sharded = sharded
        self.quant = sharded.quant
        self._fns_cache: dict = {}
        self.db = dist.device_put_sharded_db(
            sharded.arrays, mesh, dist.db_specs(model_axis, self.quant))
        self._qmult = 1
        for ax in self.query_axes:
            self._qmult *= mesh.shape[ax]
        # live mutation state (index subsystem): the delta segment is
        # replicated host-side (it is tiny) and scanned unsharded after the
        # cross-shard merge; only the tombstone mask is device-sharded
        self.epochs = ComponentEpochs()
        self.shard_epochs = [0] * sharded.n_shards
        self._live: LiveState | None = None
        self._live_active = False   # db carries an "alive" array
        # incremental-merge state: the per-shard HnswIndex handles (kept by
        # build()/full merges) and the headroom fraction -- a full-rebuild
        # merge reserves ~merge_headroom x the merged delta as dead tail rows
        # in the LAST shard, which later merges fill in place by growing just
        # that shard's graph instead of rebuilding every shard
        self.merge_headroom = float(merge_headroom)
        self._shard_indexes: list | None = None

    # -- construction --------------------------------------------------------
    @classmethod
    def build(cls, vectors: np.ndarray, attrs: F.AttributeTable, mesh,
              spec: BuildSpec | None = None, *, codebook=None,
              query_axes=("data",), model_axis: str = "model",
              seed: int = 0) -> "ShardedBackend":
        """Build per-shard HNSWs (+ optional codebook) straight from the
        raw vectors and attach them to ``mesh``."""
        spec = spec or BuildSpec()
        if spec.quant is not None and codebook is not None:
            from .. import quant
            q = spec.quant
            cb_kind = ("pq" if isinstance(codebook, quant.PQCodebook)
                       else "sq")
            if cb_kind != q.kind:
                raise ValueError(f"spec.quant.kind={q.kind!r} does not match "
                                 f"the supplied {cb_kind!r} codebook")
            if cb_kind == "pq" and (codebook.m, codebook.nbits) != (q.m, q.nbits):
                raise ValueError(
                    f"spec.quant geometry (m={q.m}, nbits={q.nbits}) does not "
                    f"match the supplied codebook (m={codebook.m}, "
                    f"nbits={codebook.nbits})")
        n_shards = mesh.shape[model_axis]
        sharded, parts = dist.build_sharded(
            vectors, attrs, n_shards, spec.hnsw,
            sample_rate=spec.selector.sample_rate, seed=seed,
            min_sample=spec.selector.min_sample,
            max_sample=spec.selector.max_sample, keep_parts=True)
        rerank = 4
        if codebook is None and spec.quant is not None:
            from .. import quant
            q = spec.quant
            if q.kind == "pq":
                codebook = quant.train_pq(vectors, m=q.m, nbits=q.nbits,
                                          iters=q.train_iters,
                                          sample=q.train_sample, seed=seed)
            else:
                codebook = quant.train_sq(vectors)
        if spec.quant is not None:
            rerank = spec.quant.rerank
        be = cls(mesh, sharded, attrs.schema, sel_cfg=spec.selector,
                 codebook=codebook, rerank=rerank,
                 prefbf_chunk=max(spec.prefbf_chunk, 1),
                 query_axes=query_axes, model_axis=model_axis,
                 hnsw_params=spec.hnsw, seed=seed)
        be._shard_indexes = parts
        return be

    # -- serve executables ----------------------------------------------------
    def _fns(self, opts: SearchOptions, *, for_pq: bool = False) -> dict:
        """Serve-fns set for ``opts``.  The graph/brute/estimate executables
        depend only on the jit-static SearchConfig, so they are cached on it
        alone (rerank pinned to the backend default); a non-default
        ``opts.rerank`` creates an extra set whose serve_brute_pq is the only
        member ever called -- the rerank-independent executables never
        recompile per rerank value."""
        rr = self.rerank
        if for_pq and opts.rerank is not None:
            rr = opts.rerank
        # the live flag is part of the key (and the cache is cleared when it
        # flips): a live DB carries an extra "alive" array, so the shard_map
        # in_specs of pre-live executables no longer match the db dict
        key = (opts.search_config(), rr, self._live_active)
        fns = self._fns_cache.get(key)
        if fns is None:
            fns = dist.make_serve_fns(
                self.mesh, opts.search_config(), prefbf_chunk=self.prefbf_chunk,
                query_axes=self.query_axes, model_axis=self.model_axis,
                quant=self.quant, rerank=rr, live=self._live_active)
            self._fns_cache[key] = fns
        return fns

    def _pad(self, queries, programs: dict, valid=None):
        """Pad the batch to a multiple of the query-axis device count (the
        shard_map data-parallel split needs an even division).  The serve
        executables always take a validity mask, so ``valid=None`` is
        materialized as all-True for the real rows; alignment pad rows are
        marked False."""
        b = int(queries.shape[0])
        valid = (np.ones((b,), bool) if valid is None
                 else np.asarray(valid, bool))
        pad = (-b) % self._qmult
        if pad:
            queries = jnp.concatenate(
                [queries, jnp.repeat(queries[-1:], pad, axis=0)])
            programs = {k: jnp.concatenate(
                [v, jnp.repeat(v[-1:], pad, axis=0)]) for k, v in
                programs.items()}
            valid = np.concatenate([valid, np.zeros((pad,), bool)])
        return queries, programs, jnp.asarray(valid), b

    # -- Backend protocol -----------------------------------------------------
    def version(self) -> int:
        """Data epoch (see Backend.version); ``bump_version()`` after any
        reshard/re-attach that changes the served rows."""
        return self.epochs.total

    def versions(self) -> dict:
        """Scoped epochs (index subsystem): vectors / attributes / graph."""
        return self.epochs.as_dict()

    def shard_versions(self) -> tuple:
        """Per-shard mutation counters: shard s moves when a row it owns is
        tombstoned or its subgraph is rebuilt (merge/reshard)."""
        return tuple(self.shard_epochs)

    def bump_version(self) -> int:
        self.epochs.bump_all()
        self.shard_epochs = [e + 1 for e in self.shard_epochs]
        return self.epochs.total

    # -- live mutation API (index subsystem) ----------------------------------
    def _ensure_live(self) -> LiveState:
        if self._live is None:
            a = self.sharded.arrays
            self._live = LiveState(a["vectors"].shape[0],
                                   a["vectors"].shape[1],
                                   a["attrs_int"].shape[1],
                                   a["attrs_float"].shape[1])
        return self._live

    def _put_alive(self, alive: np.ndarray) -> None:
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        alive = np.asarray(alive, bool)
        cap = self.sharded.arrays["vectors"].shape[0]
        if alive.shape[0] < cap:
            # headroom tail rows (reserved by a full-rebuild merge) are dead
            # until an incremental merge registers real rows onto them
            alive = np.concatenate(
                [alive, np.zeros((cap - alive.shape[0],), bool)])
        self.db["alive"] = jax.device_put(
            alive, NamedSharding(self.mesh, P(self.model_axis)))

    def _apply_tombstones(self, dead_rows: np.ndarray) -> None:
        if len(dead_rows) == 0:
            return
        if not self._live_active:
            self._live_active = True
            self._fns_cache.clear()
        self._put_alive(self._live.base_alive)
        for r in dead_rows:
            self.shard_epochs[int(r) // self.sharded.shard_rows] += 1

    def _delta(self):
        if self._live is None or self._live.delta.live_count == 0:
            return None
        return self._live.delta

    def upsert(self, vectors, ints=None, floats=None, *, replace=None):
        live = self._ensure_live()
        ids, dead = live.upsert(vectors, ints, floats, replace=replace)
        self._apply_tombstones(dead)
        self.epochs.bump("vectors")
        return ids

    def delete(self, ids):
        live = self._ensure_live()
        n, dead = live.delete(ids)
        self._apply_tombstones(dead)
        if n:
            self.epochs.bump("vectors")
        return n

    def live_view(self):
        return None if self._live is None else self._live.view()

    def live_stats(self) -> dict:
        if self._live is None:
            return {"base_rows": self.sharded.arrays["vectors"].shape[0],
                    "dead_base_rows": 0, "delta_rows": 0, "delta_slots": 0,
                    "upserts": 0, "deletes": 0, "replaced": 0,
                    "missing_deletes": 0}
        return self._live.stats()

    def _pick_capacity(self, n_tot: int, cnt: int) -> int:
        """Array capacity for a full-rebuild merge: shard-aligned, with up
        to ``merge_headroom * cnt`` extra dead-tail rows -- but never so many
        that the tail spills out of the LAST shard (the invariant that lets
        an incremental merge grow exactly one shard)."""
        s = self.sharded.n_shards

        def align(x):
            return -(-x // s) * s

        cap = align(n_tot)
        want = align(n_tot + max(0, int(self.merge_headroom * cnt)))
        while cap < want and (cap + s - n_tot) < (cap + s) // s:
            cap += s
        return cap

    def merge_prepare(self, *, wave: int = 512, on_wave=None):
        """Phase 1 of a sharded merge, safe to run off-thread (nothing
        served is mutated).  Two shapes:

        * incremental -- the delta fits in the headroom tail reserved by the
          last full rebuild AND the per-shard index handles are held: grow
          only the last shard's HNSW via ``bulk_add`` (positions
          [base_n, base_n+cnt) are that shard's unclaimed rows, so global
          ids stay positional without touching any other shard);
        * full -- rebuild every shard through ``build_sharded`` over the
          logical rows, reserving fresh headroom for future increments.

        Returns None when there is nothing to merge; pass the result to
        ``merge_commit`` under the serving lock.
        """
        from ..index.bulk import build_hnsw_bulk, bulk_add
        live = self._live
        if live is None or live.delta.count == 0:
            return None
        if self.quant is not None and self.codebook is None:
            raise ValueError("cannot merge: codes were pre-attached without "
                             "a codebook to re-encode the grown DB with")
        d = live.delta
        cnt = int(d.count)      # snapshot boundary: read BEFORE array refs
        vecs = d.vectors[:cnt].copy()
        ints = d.ints[:cnt].copy()
        flts = d.floats[:cnt].copy()
        link = d.alive[:cnt].copy()
        graph_epoch = self.epochs.graph
        base_n = int(live.base_n)
        sharded = self.sharded
        a = sharded.arrays
        cap = a["vectors"].shape[0]
        s_last = sharded.n_shards - 1
        if (self._shard_indexes is not None and base_n + cnt <= cap
                and self._shard_indexes[s_last].n
                == base_n - s_last * sharded.shard_rows):
            new_idx = bulk_add(self._shard_indexes[s_last], vecs, wave=wave,
                               link=link, on_wave=on_wave)
            codes = None
            if self.codebook is not None:
                from .. import quant
                codes = quant.encode(self.codebook, vecs)
            return _ShardMergePrep(
                kind="incr", from_slot=cnt, n_live=int(link.sum()),
                graph_epoch=graph_epoch, base_n=base_n, shard=s_last,
                index=new_idx, vectors=vecs, ints=ints, floats=flts,
                codes=codes)

        n_tot = base_n + cnt
        vectors = np.concatenate([a["vectors"][:base_n], vecs])
        ints_all = np.concatenate([a["attrs_int"][:base_n], ints])
        flts_all = np.concatenate([a["attrs_float"][:base_n], flts])
        cap_new = self._pick_capacity(n_tot, cnt)
        pad = cap_new - n_tot
        if pad:
            # alignment + headroom rows: zero attrs (NOT the -1/nan
            # padded-row fill -- attr=-1 would shift out of the imask range)
            # and alive=False until an incremental merge claims them
            vectors = np.concatenate(
                [vectors, np.zeros((pad, vectors.shape[1]), np.float32)])
            ints_all = np.concatenate(
                [ints_all, np.zeros((pad, ints_all.shape[1]), np.int32)])
            flts_all = np.concatenate(
                [flts_all, np.zeros((pad, flts_all.shape[1]), np.float32)])
        attrs = F.AttributeTable(self.schema, ints_all, flts_all)
        new_sharded, parts = dist.build_sharded(
            vectors, attrs, sharded.n_shards, self.hnsw_params,
            sample_rate=self.sel_cfg.sample_rate, seed=self.seed,
            min_sample=self.sel_cfg.min_sample,
            max_sample=self.sel_cfg.max_sample,
            build_fn=lambda v, p: build_hnsw_bulk(v, p, wave=wave,
                                                  on_wave=on_wave),
            n_valid=n_tot, keep_parts=True)
        if self.codebook is not None:
            new_sharded = dist.attach_quant(new_sharded, self.codebook)
        return _ShardMergePrep(
            kind="full", from_slot=cnt, n_live=int(link.sum()),
            graph_epoch=graph_epoch, base_n=base_n, sharded=new_sharded,
            parts=parts, n_tot=n_tot)

    def merge_commit(self, prep) -> dict | None:
        """Phase 2: atomic swap under the caller's serving lock.  Mutations
        since the snapshot are honored exactly like the local backend:
        current tombstones win, and delta slots past the snapshot boundary
        carry into the fresh delta with their ids intact.  Returns None --
        and changes nothing -- when the base graph moved since the snapshot
        (competing merge / explicit rebuild): the prep is stale."""
        live = self._live
        if live is None or self.epochs.graph != prep.graph_epoch:
            return None
        cnt = prep.from_slot
        base = (live.base_alive if live.base_alive is not None
                else np.ones((live.base_n,), bool))
        alive = np.concatenate([base, live.delta.alive[:cnt]])
        if prep.kind == "incr":
            out = self._commit_incremental(prep, alive)
        else:
            out = self._commit_full(prep, alive)
        live.reset_after_merge(out["n"], None if alive.all() else alive,
                               from_slot=cnt)
        return out

    def _commit_full(self, prep, alive: np.ndarray) -> dict:
        sharded = prep.sharded
        self.sharded = sharded
        self.quant = sharded.quant
        self._shard_indexes = prep.parts
        cap = sharded.arrays["vectors"].shape[0]
        self._live_active = bool(cap > prep.n_tot or not alive.all())
        self._fns_cache.clear()
        self.db = dist.device_put_sharded_db(
            sharded.arrays, self.mesh,
            dist.db_specs(self.model_axis, self.quant))
        if self._live_active:
            self._put_alive(alive)
        # all three epochs move: the selectivity sample is re-drawn over the
        # new sharding, unlike the local merge
        self.epochs.bump("vectors", "attributes", "graph")
        self.shard_epochs = [e + 1 for e in self.shard_epochs]
        return {"merged_slots": prep.from_slot, "merged_live": prep.n_live,
                "n": prep.n_tot, "incremental": False}

    def _commit_incremental(self, prep, alive: np.ndarray) -> dict:
        old = self.sharded
        a = dict(old.arrays)
        R = old.shard_rows
        cap = a["vectors"].shape[0]
        s = prep.shard
        idx = prep.index
        cnt = prep.from_slot
        nl = prep.base_n + cnt
        # copy-on-swap: in-flight device phases keep reading the old arrays;
        # the new dict becomes visible only through the atomic assignments
        # below (all under the caller's serving lock)
        rows = slice(prep.base_n, nl)
        vectors = a["vectors"].copy()
        vectors[rows] = prep.vectors
        norms = a["norms"].copy()
        norms[rows] = np.einsum("nd,nd->n", prep.vectors, prep.vectors)
        attrs_i = a["attrs_int"].copy()
        attrs_i[rows] = prep.ints
        attrs_f = a["attrs_float"].copy()
        attrs_f[rows] = prep.floats
        nb0 = a["neighbors0"].copy()
        nb0[s * R: s * R + idx.n] = idx.levels[0]
        lup = len(idx.levels) - 1
        upper = a["upper"]
        if lup > upper.shape[0]:
            upper = np.concatenate([
                upper, np.full((lup - upper.shape[0], cap, upper.shape[2]),
                               -1, np.int32)], axis=0)
        else:
            upper = upper.copy()
        upper[:, s * R:(s + 1) * R, :] = -1   # links may have been rewired
        for li, lvl in enumerate(idx.levels[1:]):
            upper[li, s * R: s * R + idx.n] = lvl
        entry = a["entry"].copy()
        entry[s] = idx.entry_point
        delta_d = a["delta_d"].copy()
        delta_d[s] = idx.delta_d
        a.update(vectors=vectors, norms=norms, attrs_int=attrs_i,
                 attrs_float=attrs_f, neighbors0=nb0, upper=upper,
                 entry=entry, delta_d=delta_d)
        if prep.codes is not None:
            codes = a["codes"].copy()
            codes[rows] = prep.codes
            a["codes"] = codes
        self.sharded = dist.ShardedFavorArrays(a, old.n_shards, R,
                                               old.sample_rows, old.quant)
        self._shard_indexes = list(self._shard_indexes)
        self._shard_indexes[s] = idx
        if not self._live_active:
            self._live_active = True
            self._fns_cache.clear()
        self.db = dist.device_put_sharded_db(
            a, self.mesh, dist.db_specs(self.model_axis, self.quant))
        self._put_alive(alive)
        # the selectivity sample is untouched (no attributes bump) and only
        # the grown shard's subgraph moved
        self.epochs.bump("vectors", "graph")
        self.shard_epochs[s] += 1
        return {"merged_slots": cnt, "merged_live": prep.n_live,
                "n": nl, "incremental": True}

    def merge(self, *, wave: int = 512) -> dict:
        """Fold the delta into the base.  Implemented as ``merge_prepare``
        + ``merge_commit`` (background callers split the phases across
        threads); the first merge after a full rebuild reserves headroom so
        later merges grow only the last shard (see merge_prepare)."""
        prep = self.merge_prepare(wave=wave)
        if prep is None:
            n = (self._live.base_n if self._live is not None
                 else self.sharded.arrays["vectors"].shape[0])
            return {"merged_slots": 0, "merged_live": 0, "n": n}
        out = self.merge_commit(prep)
        if out is None:  # pragma: no cover - single-threaded epochs are stable
            raise RuntimeError("merge_commit rejected a same-thread prepare")
        return out

    @property
    def dim(self) -> int:
        """Query vector dimensionality (warmup builds dummy batches off it)."""
        return int(self.sharded.arrays["vectors"].shape[1])

    def validate(self, opts: SearchOptions) -> None:
        if opts.use_pq and self.quant is None:
            raise ValueError("use_pq=True needs a ShardedBackend built with "
                             "quantize codes (BuildSpec.quant, codebook=, or "
                             "attach_quant)")
        if opts.graph_quant is not None and self.quant != opts.graph_quant:
            raise ValueError(
                f"graph_quant={opts.graph_quant!r} needs a ShardedBackend "
                f"with {opts.graph_quant!r} codes attached "
                f"(this one has {self.quant!r})")

    def estimate(self, programs: dict, valid=None):
        # pad rows carry always-false programs (p_hat 0) -- no mask needed
        dummy = jnp.zeros((int(next(iter(programs.values())).shape[0]), 1),
                          jnp.float32)
        _, programs, _, b = self._pad(dummy, programs)
        # the estimate executable is SearchConfig-independent: reuse any
        # cached serve-fns set rather than keying a fresh one on defaults
        fns = (next(iter(self._fns_cache.values())) if self._fns_cache
               else self._fns(SearchOptions()))
        return fns["estimate"](self.db, programs)[:b]

    def search_graph(self, queries, programs: dict, p_hat,
                     opts: SearchOptions, valid=None) -> dict:
        q0, programs0, valid0 = queries, programs, valid
        queries, programs, valid, b = self._pad(queries, programs, valid)
        p_hat = jnp.asarray(p_hat, jnp.float32)
        pad = queries.shape[0] - p_hat.shape[0]
        if pad:
            p_hat = jnp.concatenate([p_hat, jnp.repeat(p_hat[-1:], pad)])
        with _annotate("favor/sharded/graph_search"):
            ids, dists = self._fns(opts)["serve_graph_phat"](
                self.db, queries, programs, p_hat, valid)
        ids, dists = ids[:b], dists[:b]
        delta = self._delta()
        if delta is not None:
            # delta rows are host-replicated -- scan them unsharded on the
            # original (un-padded) batch and fold into the merged top-k,
            # staying on device so the step keeps its async dispatch
            gi, gd = delta.scan_dev(q0, programs0, k=opts.k, valid=valid0)
            ids, dists = compose_topk_dev(ids, dists, gi, gd, opts.k)
        return {"ids": ids, "dists": dists}

    def search_brute(self, queries, programs: dict, opts: SearchOptions,
                     valid=None):
        q0, programs0, valid0 = queries, programs, valid
        queries, programs, valid, b = self._pad(queries, programs, valid)
        fn = "serve_brute_pq" if opts.use_pq else "serve_brute"
        fns = self._fns(opts, for_pq=opts.use_pq)
        with _annotate(f"favor/sharded/{fn}"):
            ids, dists = fns[fn](self.db, queries, programs, valid)
        ids, dists = ids[:b], dists[:b]
        delta = self._delta()
        if delta is not None:
            gi, gd = delta.scan_dev(q0, programs0, k=opts.k, valid=valid0)
            ids, dists = compose_topk_dev(ids, dists, gi, gd, opts.k)
        return ids, dists

    # -- accounting -----------------------------------------------------------
    def bytes_per_hop(self, opts: SearchOptions) -> int:
        """Bytes one gathered neighbor row streams under ``opts``' graph
        scorer (see Backend.bytes_per_hop).  Shard-local: each shard's
        traversal gathers from its own slice of the code/vector arrays."""
        if opts.graph_quant is not None:
            return int(self.sharded.arrays["codes"].shape[1])
        return 4 * int(self.sharded.arrays["vectors"].shape[1])

    def bytes_per_vector(self, quantized: bool = False) -> int:
        """Bytes streamed per DB row by the brute scan on each shard."""
        if quantized:
            if self.quant is None:
                raise ValueError("backend has no quantize codes attached")
            # one uint8 code per column, whether the codebook object is held
            # here or the codes were pre-attached via attach_quant
            return int(self.sharded.arrays["codes"].shape[1])
        return 4 * int(self.sharded.arrays["vectors"].shape[1])
