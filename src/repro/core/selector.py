"""Selectivity-driven search selector (paper Section 4).

Routes each query by its sampled selectivity estimate: ``p_hat < lambda``
(= 1%, paper section 4.1) goes to the pre-filtering brute-force scan, the rest
to the exclusion-distance graph search.  The middle band (1% < p < 3%) is
deliberately biased toward the graph path -- its QPS response is flat there
(< 8% variation, Fig. 7) so estimator error is cheap, whereas the brute-force
path swings > 50%.

The estimate itself is one vectorized filter-program evaluation over the
fixed sample block (selectivity.py); under the sharded serve path each shard
holds a slice of the sample and the counts are psum-combined so every shard
takes the same routing decision deterministically.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import filters as F


@dataclass(frozen=True)
class SelectorConfig:
    lam: float = 0.01          # lambda threshold (section 4.1)
    sample_rate: float = 0.01  # section 4.2: 1% sampling
    min_sample: int = 256
    max_sample: int = 65536
    p_min: float = 1e-4        # clamp for D computation off-route


@jax.jit
def estimate_batched(programs, sample_ints, sample_floats):
    """(B,) p_hat over the pre-drawn sample rows (jit; runs every batch)."""
    mask = F.eval_program_batched(programs, sample_ints, sample_floats, xp=jnp)
    return jnp.mean(mask.astype(jnp.float32), axis=1)


def route(p_hat: np.ndarray, lam: float) -> np.ndarray:
    """True -> PreFBF (brute force); False -> FAVOR graph search."""
    return np.asarray(p_hat) < lam
