"""Typed build/search options: the one configuration surface for every
backend (paper Figure 1 knobs as frozen dataclasses).

The seed grew two parallel kwarg blobs -- ``FavorIndex.__init__`` /
``FavorIndex.search`` on the single-host path and ``make_serve_fns`` on the
sharded path -- that drifted apart one keyword at a time.  This module pins
the pipeline's three decision points to three immutable specs:

  QuantSpec     -- offline memory format of the brute-scan DB (PQ/SQ codes)
  BuildSpec     -- offline construction: HNSW params, selectivity sampling,
                   scan chunking, optional QuantSpec
  SearchOptions -- per-query-batch online knobs (k/ef, routing force,
                   termination, compressed-scan toggle)

All three validate eagerly in ``__post_init__`` so a typo'd route or a
falsy-but-meaningful ``rerank=0`` fails loudly at construction instead of
silently auto-routing mid-serve.  ``SearchOptions.search_config()`` lowers
to the jit-static ``SearchConfig`` consumed by the compiled executables.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

from .batching import BatchSpec
from .hnsw import HnswParams
from .scoring import GRAPH_QUANT_KINDS
from .search import SearchConfig
from .selector import SelectorConfig

ROUTES = (None, "graph", "brute")
QUANT_KINDS = ("pq", "sq")
GRAPH_QUANT = GRAPH_QUANT_KINDS  # one constant: options + scorer_for agree


@dataclass(frozen=True)
class QuantSpec:
    """Compressed memory format for the brute-scan rows (quant subsystem).

    kind "pq": M uint8 codes per vector (m bytes); "sq": per-dim affine int8
    (dim bytes).  ``rerank`` is the default exact-re-rank depth (top
    ``rerank * k`` ADC candidates get full-precision distances); 0 means
    re-rank exactly the top k -- an explicit 0 is honored, not coerced.
    """
    kind: str = "pq"
    m: int = 8
    nbits: int = 8
    train_iters: int = 20
    train_sample: int = 65536
    rerank: int = 4

    def __post_init__(self):
        if self.kind not in QUANT_KINDS:
            raise ValueError(f"QuantSpec.kind must be one of {QUANT_KINDS}, "
                             f"got {self.kind!r}")
        if not 1 <= self.nbits <= 8:
            raise ValueError(f"QuantSpec.nbits must be in [1, 8] (uint8 "
                             f"codes), got {self.nbits}")
        if self.m < 1:
            raise ValueError(f"QuantSpec.m must be >= 1, got {self.m}")
        if self.rerank < 0:
            raise ValueError(f"QuantSpec.rerank must be >= 0, got {self.rerank}")


@dataclass(frozen=True)
class BuildSpec:
    """Offline construction spec for any backend (local or sharded)."""
    hnsw: HnswParams | None = None
    selector: SelectorConfig = field(default_factory=SelectorConfig)
    prefbf_chunk: int = 8192
    quant: QuantSpec | None = None

    def __post_init__(self):
        if self.prefbf_chunk < 1:
            raise ValueError(f"BuildSpec.prefbf_chunk must be >= 1, "
                             f"got {self.prefbf_chunk}")
        if self.quant is not None and not isinstance(self.quant, QuantSpec):
            raise TypeError("BuildSpec.quant must be a QuantSpec or None, "
                            f"got {self.quant!r} (for a bare kind string use "
                            "QuantSpec(kind=...))")
        if self.hnsw is not None and not isinstance(self.hnsw, HnswParams):
            raise TypeError("BuildSpec.hnsw must be HnswParams or None, "
                            f"got {type(self.hnsw).__name__}")


@dataclass(frozen=True)
class CacheSpec:
    """Serving-side cache configuration (cache subsystem, ``repro.cache``).

    Three layers, all keyed by the canonical filter signature
    (``filters.filter_signature``) and all LRU+TTL bounded:

      selectivity -- signature -> p_hat; skips ``backend.estimate`` for
                     repeat filters.  Exact: the estimator is deterministic
                     over the fixed sample, so a hit returns the same value.
      candidates  -- signature -> matching-ID set for hot *low-selectivity*
                     filters; repeat brute routes scan only the cached block
                     instead of the full corpus.  Exact: the ID set is the
                     predicate's true extension.
      semantic    -- (query vector, signature, opts) -> top-k, redisvl-style.
                     ``semantic_threshold`` is the max L2 distance between
                     the incoming and cached query vector for a hit; the
                     default 0.0 serves only exact repeats and is therefore
                     lossless, larger values trade recall for QPS.

    ``ttl_s=None`` disables time-based expiry (epoch invalidation via
    ``Backend.version()`` still applies).  ``candidate_p_max`` gates which
    signatures get an ID set (only filters that route brute benefit);
    ``candidate_max_ids`` bounds one entry's memory.
    """
    selectivity: bool = True
    candidates: bool = True
    semantic: bool = True
    selectivity_cap: int = 4096
    candidate_cap: int = 64
    candidate_p_max: float = 0.02
    candidate_max_ids: int = 262144
    semantic_cap: int = 1024
    semantic_per_key: int = 32
    semantic_threshold: float = 0.0
    ttl_s: float | None = None

    def __post_init__(self):
        for name in ("selectivity_cap", "candidate_cap", "semantic_cap",
                     "semantic_per_key", "candidate_max_ids"):
            if getattr(self, name) < 1:
                raise ValueError(f"CacheSpec.{name} must be >= 1, "
                                 f"got {getattr(self, name)}")
        if not 0.0 <= self.candidate_p_max <= 1.0:
            raise ValueError("CacheSpec.candidate_p_max must be in [0, 1], "
                             f"got {self.candidate_p_max}")
        if self.semantic_threshold < 0.0:
            raise ValueError("CacheSpec.semantic_threshold must be >= 0, "
                             f"got {self.semantic_threshold}")
        if self.ttl_s is not None and self.ttl_s <= 0:
            raise ValueError(f"CacheSpec.ttl_s must be None or > 0, "
                             f"got {self.ttl_s}")

    def with_(self, **overrides) -> "CacheSpec":
        return replace(self, **overrides)


@dataclass(frozen=True)
class TenantSpec:
    """Per-tenant QoS contract for the async serving front-end
    (``repro.serving.frontend``).

    ``weight`` sets the tenant's share under weighted fair dequeue (2.0 gets
    twice the dequeue rate of 1.0 under contention).  ``rate_qps``/``burst``
    parameterize the admission token bucket (None disables rate limiting);
    ``queue_cap`` bounds the tenant's pending queue (overflow is shed with a
    structured ``Overloaded``); ``deadline_ms`` is the default per-request
    deadline (requests still queued past it are shed, never served late).
    """
    weight: float = 1.0
    rate_qps: float | None = None
    burst: int = 16
    queue_cap: int = 1024
    deadline_ms: float | None = None

    def __post_init__(self):
        if not self.weight > 0.0:
            raise ValueError(f"TenantSpec.weight must be > 0, "
                             f"got {self.weight}")
        if self.rate_qps is not None and not self.rate_qps > 0.0:
            raise ValueError(f"TenantSpec.rate_qps must be None or > 0, "
                             f"got {self.rate_qps}")
        if self.burst < 1:
            raise ValueError(f"TenantSpec.burst must be >= 1, "
                             f"got {self.burst}")
        if self.queue_cap < 1:
            raise ValueError(f"TenantSpec.queue_cap must be >= 1, "
                             f"got {self.queue_cap}")
        if self.deadline_ms is not None and not self.deadline_ms > 0.0:
            raise ValueError(f"TenantSpec.deadline_ms must be None or > 0, "
                             f"got {self.deadline_ms}")

    def with_(self, **overrides) -> "TenantSpec":
        return replace(self, **overrides)


@dataclass(frozen=True)
class FrontEndSpec:
    """Policy for one logical async front-end over a ServeEngine.

    ``coalesce_ms`` is the cross-step batch-coalescing window: an
    under-filled batch is held up to this long for more arrivals before it
    is dispatched, so low arrival rates stop paying bucket-pad overhead
    (0.0 dispatches immediately -- the uncoalesced baseline).
    ``coalesce_target`` is the fill level (rows) that releases a held batch
    early; None targets the dispatch cap.  ``max_batch`` caps one dispatch
    (None defers to the engine's ``max_batch``).  ``admission=False``
    disables the token buckets *and* the queue caps (pure unbounded FIFO --
    the no-QoS baseline); ``fair=False`` replaces weighted fair dequeue
    with global FIFO order.  ``tenants`` maps tenant name -> TenantSpec
    (accepted as a dict, stored canonically as a sorted tuple of pairs);
    unknown tenants fall back to ``default_tenant``.
    """
    coalesce_ms: float = 0.0
    coalesce_target: int | None = None
    max_batch: int | None = None
    admission: bool = True
    fair: bool = True
    default_tenant: TenantSpec = field(default_factory=TenantSpec)
    tenants: tuple = ()
    latency_window: int = 4096
    # executor slots for pipelined step dispatch: N > 1 lets the front-end
    # overlap one step's device wait with the next step's host phase
    # (routing/compile/cache), riding JAX async dispatch.  Responses still
    # resolve in dispatch order; 1 = the serialized baseline.
    parallel_steps: int = 1

    def __post_init__(self):
        if self.coalesce_ms < 0.0:
            raise ValueError(f"FrontEndSpec.coalesce_ms must be >= 0, "
                             f"got {self.coalesce_ms}")
        if self.parallel_steps < 1:
            raise ValueError(f"FrontEndSpec.parallel_steps must be >= 1, "
                             f"got {self.parallel_steps}")
        for name in ("coalesce_target", "max_batch"):
            v = getattr(self, name)
            if v is not None and v < 1:
                raise ValueError(f"FrontEndSpec.{name} must be None or >= 1, "
                                 f"got {v}")
        if self.latency_window < 1:
            raise ValueError(f"FrontEndSpec.latency_window must be >= 1, "
                             f"got {self.latency_window}")
        if not isinstance(self.default_tenant, TenantSpec):
            raise TypeError("FrontEndSpec.default_tenant must be a "
                            f"TenantSpec, got {self.default_tenant!r}")
        tenants = self.tenants
        if isinstance(tenants, dict):
            tenants = tuple(sorted(tenants.items()))
            object.__setattr__(self, "tenants", tenants)
        for pair in tenants:
            if (not isinstance(pair, tuple) or len(pair) != 2
                    or not isinstance(pair[0], str)
                    or not isinstance(pair[1], TenantSpec)):
                raise TypeError("FrontEndSpec.tenants must map tenant name "
                                f"-> TenantSpec, got {pair!r}")

    def tenant(self, name: str) -> TenantSpec:
        """The spec configured for ``name`` (``default_tenant`` otherwise)."""
        for n, spec in self.tenants:
            if n == name:
                return spec
        return self.default_tenant

    def with_(self, **overrides) -> "FrontEndSpec":
        return replace(self, **overrides)


@dataclass(frozen=True)
class ObsSpec:
    """Observability policy for one serving stack (``repro.obs``).

    ``enabled=False`` turns off tracing, probes and kernel annotations
    wholesale -- the engine still keeps its registry counters (they back
    ``ServeEngine.stats``) but the router's hot path takes zero extra
    branches per stage and results are bit-identical.

    ``trace_sample`` is the fraction of engine batches that get a full
    per-stage span trace (deterministic 1-in-N, not random, so runs
    reproduce); traced batches whose wall time exceeds ``slow_ms`` land
    per-query entries -- filter signature, p_hat, route, ef, stage
    timings -- in a ``slow_cap``-bounded ring buffer (``slow_ms=None``
    disables the slow-query log).

    ``probe_sample`` is the fraction of batches on which one query's
    estimated selectivity is checked against the filter's *true* match
    fraction over the corpus attributes (estimator-accuracy error
    histogram + route-flip counter); ``shadow_sample`` is the fraction on
    which one query is additionally re-executed on BOTH routes against the
    cache-unwrapped backend to populate the route-decision confusion
    counter (would-have-been-faster-on-the-other-route).  Both default to
    0.0: they cost real work and are bench/diagnostic knobs, not
    steady-state ones.

    ``kernel_annotations`` wraps backend dispatches in host-side
    ``jax.profiler.TraceAnnotation`` scopes named by route and bucket, so
    a ``jax.profiler`` capture attributes device time to kernels by route
    (the jitted kernels themselves carry always-on ``jax.named_scope``
    HLO metadata, which costs nothing at runtime).

    ``latency_buckets`` are the shared histogram upper bounds (seconds)
    for request latency and per-stage timings.
    """
    enabled: bool = True
    trace_sample: float = 1.0
    trace_cap: int = 256
    slow_ms: float | None = 100.0
    slow_cap: int = 128
    probe_sample: float = 0.0
    shadow_sample: float = 0.0
    kernel_annotations: bool = False
    latency_buckets: tuple = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
                              0.05, 0.1, 0.25, 0.5, 1.0, 2.5)

    def __post_init__(self):
        for name in ("trace_sample", "probe_sample", "shadow_sample"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"ObsSpec.{name} must be in [0, 1], "
                                 f"got {v}")
        for name in ("trace_cap", "slow_cap"):
            if getattr(self, name) < 1:
                raise ValueError(f"ObsSpec.{name} must be >= 1, "
                                 f"got {getattr(self, name)}")
        if self.slow_ms is not None and self.slow_ms < 0.0:
            raise ValueError(f"ObsSpec.slow_ms must be None or >= 0, "
                             f"got {self.slow_ms}")
        buckets = tuple(float(b) for b in self.latency_buckets)
        if not buckets or any(b <= 0 for b in buckets) or \
                any(a >= b for a, b in zip(buckets, buckets[1:])):
            raise ValueError("ObsSpec.latency_buckets must be strictly "
                             f"increasing positive bounds, got {buckets}")
        object.__setattr__(self, "latency_buckets", buckets)

    def with_(self, **overrides) -> "ObsSpec":
        return replace(self, **overrides)


@dataclass(frozen=True)
class SearchOptions:
    """Online per-batch options; one instance drives every backend.

    force pins the route for benchmarks/ablations and must be None, "graph"
    or "brute" -- anything else is a ValueError (the seed treated typos as
    auto-route).  ``rerank=None`` defers to the index/backend default;
    ``rerank=0`` means "exact-re-rank only the top k" and is honored as such.

    ``graph_quant`` selects the graph-route *scorer* (core.scoring): None
    keeps full-precision f32 traversal, "pq"/"sq" score neighbor blocks on
    the backend's quantize codes (ADC LUTs / dequantized int8) and exact-
    re-rank the final top TD candidates -- the per-hop HBM traffic drops
    from 4*d to M (or d) bytes per gathered row.  The backend must hold
    codes of the same kind (validated in Backend.validate, like use_pq).
    ``graph_rerank`` is that re-rank's depth multiplier (top
    ``max(k, graph_rerank * k)`` TD candidates, capped at ef; 0 means
    exactly the top k); ``None`` defers to the default 4.  Both are
    jit-static: they lower into SearchConfig, so each (scorer, rerank)
    pair is its own compiled executable.

    ``max_steps`` bounds the total traversal waves (while_loop iterations
    across the whole lane-compaction ladder); 0 keeps the 8*ef safety
    bound.  A uniform budget makes scorers comparable on wall-clock:
    quantized scorers' noisy distances delay Algorithm 3's termination for
    a few straggler lanes (~1.7x the f32 wave count with identical mean
    hops), and the cap trims exactly that tail -- lanes stopped at the
    budget still return their current result pool.

    ``batch`` is the shape-stable execution policy (core.batching): when set,
    the router bucket-pads the estimate call and the graph/brute sub-batches
    to pow-2 sizes (pad rows carry always-false filter programs and a False
    validity mask), bounding the compiled-shape set to the bucket ladder.
    ``None`` (default) keeps the pre-1.2 raw-shape behavior; results are
    bit-identical either way.
    """
    k: int = 10
    ef: int = 100
    pbar_min: float = 0.5
    gamma: float = 1.0
    force: str | None = None
    cand_cap: int = 0
    max_steps: int = 0
    use_pallas: bool = False
    use_pq: bool = False
    rerank: int | None = None
    graph_quant: str | None = None
    graph_rerank: int | None = None
    batch: BatchSpec | None = None

    def __post_init__(self):
        if self.force not in ROUTES:
            raise ValueError(f"SearchOptions.force must be one of {ROUTES}, "
                             f"got {self.force!r}")
        if self.k < 1:
            raise ValueError(f"SearchOptions.k must be >= 1, got {self.k}")
        if self.ef < 1:
            raise ValueError(f"SearchOptions.ef must be >= 1, got {self.ef}")
        if self.cand_cap < 0:
            raise ValueError(f"SearchOptions.cand_cap must be >= 0, "
                             f"got {self.cand_cap}")
        if self.max_steps < 0:
            raise ValueError(f"SearchOptions.max_steps must be >= 0, "
                             f"got {self.max_steps}")
        if self.rerank is not None and self.rerank < 0:
            raise ValueError(f"SearchOptions.rerank must be None or >= 0, "
                             f"got {self.rerank}")
        if self.graph_quant not in GRAPH_QUANT:
            raise ValueError(f"SearchOptions.graph_quant must be one of "
                             f"{GRAPH_QUANT}, got {self.graph_quant!r}")
        if self.graph_rerank is not None and self.graph_rerank < 0:
            raise ValueError(f"SearchOptions.graph_rerank must be None or "
                             f">= 0, got {self.graph_rerank}")
        if self.batch is not None and not isinstance(self.batch, BatchSpec):
            raise TypeError("SearchOptions.batch must be a BatchSpec or "
                            f"None, got {self.batch!r}")

    def search_config(self) -> SearchConfig:
        """Lower to the jit-static config the compiled executables key on."""
        return SearchConfig(k=self.k, ef=self.ef, cand_cap=self.cand_cap,
                            max_steps=self.max_steps,
                            pbar_min=self.pbar_min, gamma=self.gamma,
                            use_pallas=self.use_pallas,
                            graph_quant=self.graph_quant,
                            graph_rerank=(4 if self.graph_rerank is None
                                          else self.graph_rerank))

    def with_(self, **overrides) -> "SearchOptions":
        return replace(self, **overrides)
