"""Sharded FAVOR serving across the production mesh (DESIGN.md section 4).

Layout (classic distributed-ANNS segment model, Milvus/Vearch style):
 * the DB (vectors, attributes, per-shard HNSW subgraphs, selectivity sample)
   is sharded on the ``model`` axis: shard s owns rows [s*Ns, (s+1)*Ns);
 * the query batch is sharded on (``pod``, ``data``) -- pure data parallelism;
 * every (data, model) mesh cell runs the single-shard search from search.py
   on its query block x DB shard, then local top-k are ``all_gather``-ed along
   ``model`` and sort-merged (k per shard -> k global; tiny collective);
 * selectivity estimation psum-combines per-shard sample counts so every
   shard computes the same p_hat and takes the same route deterministically.

Each shard has its own HNSW (built independently offline -- embarrassingly
parallel build, linear scaling in shards), its own entry point and its own
Delta_d; D is computed per shard from the *global* p_hat and the local
Delta_d, which matches the paper's global-statistic design per shard.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import exclusion
from . import filters as F
from . import prefbf, selectivity
from .hnsw import HnswIndex, HnswParams, build_hnsw
from .search import SearchConfig, favor_graph_search


def largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` (e.g. scan chunk sizes and
    mesh-axis extents that must evenly split a row count)."""
    d = max(1, min(cap, n))
    while n % d:
        d -= 1
    return d


# ---------------------------------------------------------------------------
# Sharded index container
# ---------------------------------------------------------------------------
def db_specs(model_axis: str = "model", quant: str | None = None,
             live: bool = False) -> dict:
    """Partition specs for the serve DB dict.

    ``quant`` extends the base layout with the compressed-scan arrays:
    "codes" rows are co-sharded with their vectors on ``model_axis``; the
    (tiny) codebook tables are replicated on every device.  ``live`` adds
    the tombstone mask ("alive", row-co-sharded) of a mutated backend.
    """
    sh = {
        "vectors": P(model_axis, None), "norms": P(model_axis),
        "neighbors0": P(model_axis, None), "upper": P(None, model_axis, None),
        "attrs_int": P(model_axis, None), "attrs_float": P(model_axis, None),
        "entry": P(model_axis), "delta_d": P(model_axis),
        "sample_int": P(model_axis, None), "sample_float": P(model_axis, None),
    }
    if live:
        sh["alive"] = P(model_axis)
    if quant is not None:
        sh["codes"] = P(model_axis, None)
        if quant == "pq":
            sh["centroids"] = P(None, None, None)
        elif quant == "sq":
            sh["sq_lo"] = P(None)
            sh["sq_scale"] = P(None)
        else:
            raise ValueError(f"quant must be 'pq', 'sq' or None, got {quant!r}")
    return sh


@dataclass
class ShardedFavorArrays:
    """Global-shaped arrays; axis 0 of every DB array is sharded on "model".

    vectors     (S*Ns, d)      norms      (S*Ns,)
    neighbors0  (S*Ns, M0)     upper      (L_up, S*Ns, M)   [local node ids]
    attrs_int   (S*Ns, m_i)    attrs_float(S*Ns, m_f)
    entry       (S,) int32     delta_d    (S,) f32
    sample_int  (S*ns, m_i)    sample_float (S*ns, m_f)

    With a codebook attached (attach_quant): codes (S*Ns, M) uint8 plus the
    replicated codebook tables (centroids | sq_lo/sq_scale).
    """
    arrays: dict
    n_shards: int
    shard_rows: int
    sample_rows: int  # per shard
    quant: str | None = None  # "pq" | "sq" once attach_quant has run

    def specs(self) -> dict:
        return db_specs(quant=self.quant)


def attach_quant(sharded: ShardedFavorArrays, codebook) -> ShardedFavorArrays:
    """Encode the sharded DB under ``codebook`` so the brute route can
    stream codes instead of float32 rows.  Row i's code lands on the same
    shard as vector i (contiguous row partition on "model")."""
    from .. import quant
    arrays = dict(sharded.arrays)
    arrays["codes"] = quant.encode(codebook, arrays["vectors"])
    if isinstance(codebook, quant.PQCodebook):
        kind = "pq"
        arrays["centroids"] = np.asarray(codebook.centroids, np.float32)
    else:
        kind = "sq"
        arrays["sq_lo"] = np.asarray(codebook.lo, np.float32)
        arrays["sq_scale"] = np.asarray(codebook.scale, np.float32)
    return ShardedFavorArrays(arrays, sharded.n_shards, sharded.shard_rows,
                              sharded.sample_rows, quant=kind)


def build_sharded(vectors: np.ndarray, attrs: F.AttributeTable, n_shards: int,
                  params: HnswParams | None = None, sample_rate: float = 0.01,
                  seed: int = 0, min_sample: int = 8,
                  max_sample: int = 65536,
                  build_fn=None, n_valid: int | None = None,
                  keep_parts: bool = False):
    """Partition rows round-robin-contiguously, build one HNSW per shard.

    ``min_sample``/``max_sample`` bound the TOTAL selectivity-sample size
    (split evenly across shards) exactly like SelectorConfig bounds the
    single-host sample, so the psum-combined p_hat matches the single-host
    estimator's variance and both backends take the same routes -- and the
    per-batch jitted estimate stays O(max_sample) however large the DB.

    ``build_fn(vectors, params) -> HnswIndex`` overrides the per-shard build
    (default sequential ``build_hnsw``; pass ``index.bulk.build_hnsw_bulk``
    for the device-parallel wave pipeline).

    ``n_valid`` marks rows >= n_valid as permanently-dead headroom: they are
    excluded from the per-shard graph build (their neighbor rows stay -1, so
    a later incremental merge can register real rows onto those positions)
    and from the selectivity sample.  The headroom convention requires the
    dead tail to live inside the LAST shard; a fully-dead shard falls back
    to the legacy zero-vector build so its entry/delta_d stay defined.

    ``keep_parts=True`` additionally returns the per-shard HnswIndex objects
    (the handles an incremental merge grows via ``bulk_add``)."""
    n = vectors.shape[0]
    assert n % n_shards == 0, "row count must divide the model axis"
    build_fn = build_fn or build_hnsw
    ns = n // n_shards
    n_valid = n if n_valid is None else int(n_valid)
    parts = []
    lvs = []
    max_lup = 0
    for s in range(n_shards):
        sl = slice(s * ns, (s + 1) * ns)
        p = params or HnswParams()
        p = HnswParams(M=p.M, M0=p.M0, efc=p.efc, ml=p.ml, alpha=p.alpha,
                       heuristic=p.heuristic, seed=p.seed + s)
        lv = min(ns, n_valid - s * ns)
        lv = ns if lv < 1 else lv
        idx = build_fn(vectors[sl][:lv], p)
        parts.append((idx, sl))
        lvs.append(lv)
        max_lup = max(max_lup, len(idx.levels) - 1)

    sample_n = max(8, -(-min_sample // n_shards), int(round(ns * sample_rate)))
    sample_n = min(sample_n, ns, max(8, max_sample // n_shards))
    rng = np.random.default_rng(seed + 31)

    neighbors0 = np.full((n, parts[0][0].params.M0), -1, np.int32)
    upper = np.full((max_lup, n, parts[0][0].params.M), -1, np.int32)
    entry = np.zeros((n_shards,), np.int32)
    delta_d = np.zeros((n_shards,), np.float32)
    s_int = np.zeros((n_shards * sample_n, attrs.ints.shape[1]), np.int32)
    s_flt = np.zeros((n_shards * sample_n, attrs.floats.shape[1]), np.float32)
    norms = np.einsum("nd,nd->n", vectors, vectors).astype(np.float32)

    for s, (idx, sl) in enumerate(parts):
        lo, lv = sl.start, lvs[s]
        neighbors0[lo:lo + idx.n] = idx.levels[0]
        for li, lvl in enumerate(idx.levels[1:]):
            upper[li, lo:lo + idx.n] = lvl
        entry[s] = idx.entry_point
        delta_d[s] = idx.delta_d
        samp = rng.choice(lv, size=sample_n, replace=sample_n > lv) + lo
        s_int[s * sample_n:(s + 1) * sample_n] = attrs.ints[samp]
        s_flt[s * sample_n:(s + 1) * sample_n] = attrs.floats[samp]

    arrays = {
        "vectors": vectors.astype(np.float32), "norms": norms,
        "neighbors0": neighbors0, "upper": upper,
        "attrs_int": attrs.ints, "attrs_float": attrs.floats,
        "entry": entry, "delta_d": delta_d,
        "sample_int": s_int, "sample_float": s_flt,
    }
    sharded = ShardedFavorArrays(arrays, n_shards, ns, sample_n)
    if keep_parts:
        return sharded, [idx for idx, _ in parts]
    return sharded


def input_specs(n: int, dim: int, m_i: int, m_f: int, n_shards: int, *,
                m0: int = 32, m: int = 16, n_upper: int = 3,
                sample_rate: float = 0.01, width: int = 8,
                batch: int = 4096, dtype=jnp.float32) -> dict:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    ns = n // n_shards
    sample_n = max(8, int(round(ns * sample_rate)))
    f32, i32 = dtype, jnp.int32
    sds = jax.ShapeDtypeStruct
    return {
        "db": {
            "vectors": sds((n, dim), f32), "norms": sds((n,), f32),
            "neighbors0": sds((n, m0), i32), "upper": sds((n_upper, n, m), i32),
            "attrs_int": sds((n, m_i), i32), "attrs_float": sds((n, m_f), f32),
            "entry": sds((n_shards,), i32), "delta_d": sds((n_shards,), jnp.float32),
            "sample_int": sds((n_shards * sample_n, m_i), i32),
            "sample_float": sds((n_shards * sample_n, m_f), f32),
        },
        "queries": sds((batch, dim), f32),
        "programs": {
            "valid": sds((batch, width), jnp.float32),
            "imask": sds((batch, width, m_i), jnp.uint32),
            "flo": sds((batch, width, m_f), f32),
            "fhi": sds((batch, width, m_f), f32),
        },
        "valid": sds((batch,), jnp.bool_),
    }


# ---------------------------------------------------------------------------
# Sharded serve steps
# ---------------------------------------------------------------------------
def _merge_topk(local_d, local_i, k: int, axis: str):
    """all_gather local (B, k) results along ``axis`` and sort-merge."""
    gd = jax.lax.all_gather(local_d, axis)          # (S, B, k)
    gi = jax.lax.all_gather(local_i, axis)
    s, b, _ = gd.shape
    gd = jnp.moveaxis(gd, 0, 1).reshape(b, s * k)
    gi = jnp.moveaxis(gi, 0, 1).reshape(b, s * k)
    order = jnp.argsort(gd, axis=1)[:, :k]
    return (jnp.take_along_axis(gd, order, axis=1),
            jnp.take_along_axis(gi, order, axis=1))


def make_serve_fns(mesh: Mesh, cfg: SearchConfig, *, ef_sel: int | None = None,
                   prefbf_chunk: int = 65536, query_axes=("data",),
                   model_axis: str = "model", quant: str | None = None,
                   rerank: int = 4, live: bool = False):
    """Build the jitted sharded serve steps for ``mesh``.

    Returns dict with:
      estimate(db, programs)                     -> (B,) p_hat (replicated)
      serve_graph(db, queries, programs, valid)  -> ids (B,k) GLOBAL ids, dists
      serve_brute(db, queries, programs, valid)  -> ids (B,k), dists
      serve_brute_pq(db, queries, programs, valid) [quant only] -> ids, dists

    ``valid`` is the (B,) bool row mask of the bucket-padding contract
    (core.batching): False rows are pad rows and come back as -1 / +inf
    (pass all-True when every row is real).

    With ``cfg.use_pallas`` the per-shard brute scans run through the
    filtered_topk / pq_adc Pallas kernels inside the shard_map body (each
    shard launches the kernel over its own row slice; the cross-shard top-k
    merge is unchanged).

    With ``quant`` set ("pq"/"sq") the db dict must carry the attach_quant
    arrays; serve_brute_pq streams only the uint8 codes per shard (ADC LUT
    scan, same DNF masking), exact-re-ranks the top ``rerank * k`` local
    candidates against the shard's float32 rows, and only then joins the
    cross-shard top-k merge -- so the bandwidth-bound scan never touches
    float32.

    With ``cfg.graph_quant`` set the *graph* route also scores on the
    attached codes (core.scoring): each shard's traversal gathers uint8
    code rows per hop and exact-re-ranks its final TD candidates before the
    cross-shard merge, so the per-hop neighbor fetch is code-resident too
    (requires ``quant`` == ``cfg.graph_quant``).
    """
    qspec = P(query_axes if len(query_axes) > 1 else query_axes[0], None)
    pspec_each = {"valid": P(qspec[0], None), "imask": P(qspec[0], None, None),
                  "flo": P(qspec[0], None, None), "fhi": P(qspec[0], None, None)}
    vspec = P(qspec[0])  # (B,) validity mask, co-sharded with the queries
    ef = ef_sel or cfg.ef
    dspecs = db_specs(model_axis, quant, live)

    def _scan_norms(db):
        """Per-shard norms for the brute scans: with a live DB, tombstoned
        rows take +inf (the padded-row convention) so they can never win."""
        if live:
            return jnp.where(db["alive"], db["norms"], jnp.inf)
        return db["norms"]

    # -- selectivity estimate (psum-combined; identical on all shards) -------
    def _estimate(db, programs):
        mask = F.eval_program_batched(
            programs, db["sample_int"], db["sample_float"], xp=jnp)  # (B, ns)
        cnt = jnp.sum(mask.astype(jnp.float32), axis=1)
        tot = jnp.asarray(mask.shape[1], jnp.float32)
        cnt = jax.lax.psum(cnt, model_axis)
        tot = jax.lax.psum(tot, model_axis)
        return cnt / tot

    estimate = jax.jit(shard_map(
        _estimate, mesh=mesh,
        in_specs=(dspecs, pspec_each),
        out_specs=P(qspec[0]),
        check_rep=False))

    # -- graph route ----------------------------------------------------------
    if cfg.graph_quant is not None and cfg.graph_quant != quant:
        raise ValueError(
            f"cfg.graph_quant={cfg.graph_quant!r} needs the serve DB built "
            f"with matching attach_quant codes (quant={quant!r})")

    def _graph_from_phat(db, queries, programs, p_hat, valid):
        local_g = {
            "vectors": db["vectors"], "norms": db["norms"],
            "neighbors0": db["neighbors0"], "upper": db["upper"],
            "entry": db["entry"][0],
            "attrs_int": db["attrs_int"], "attrs_float": db["attrs_float"],
        }
        if live:
            local_g["alive"] = db["alive"]
        if cfg.graph_quant is not None:
            # scorer arrays (core.scoring): each shard scores its own code
            # rows; the replicated codebook tables ride along
            local_g["codes"] = db["codes"]
            if cfg.graph_quant == "pq":
                local_g["centroids"] = db["centroids"]
            else:
                local_g["sq_lo"] = db["sq_lo"]
                local_g["sq_scale"] = db["sq_scale"]
        D = exclusion.exclusion_distance(p_hat, ef, db["delta_d"][0],
                                         k=cfg.k, xp=jnp)
        out = favor_graph_search(local_g, queries, programs, D, cfg,
                                 valid=valid)
        shard = jax.lax.axis_index(model_axis).astype(jnp.int32)
        n_local = db["vectors"].shape[0]
        gids = jnp.where(out["ids"] >= 0, out["ids"] + shard * n_local, -1)
        d, i = _merge_topk(out["dists"], gids, cfg.k, model_axis)
        return jnp.where(jnp.isfinite(d), i, -1), d

    def _serve_graph(db, queries, programs, valid):
        return _graph_from_phat(db, queries, programs,
                                _estimate(db, programs), valid)

    serve_graph = jax.jit(shard_map(
        _serve_graph, mesh=mesh,
        in_specs=(dspecs, qspec, pspec_each, vspec),
        out_specs=(qspec, qspec),
        check_rep=False))

    # same route with the selectivity estimate supplied by the caller (the
    # router already ran it to take the routing decision -- don't pay the
    # O(B x sample) evaluation twice per batch)
    serve_graph_phat = jax.jit(shard_map(
        _graph_from_phat, mesh=mesh,
        in_specs=(dspecs, qspec, pspec_each, P(qspec[0]), vspec),
        out_specs=(qspec, qspec),
        check_rep=False))

    # -- brute route -----------------------------------------------------------
    def _serve_brute(db, queries, programs, valid):
        n_local = db["vectors"].shape[0]
        chunk = largest_divisor(n_local, prefbf_chunk)
        if cfg.use_pallas:
            # the scan chunk becomes the kernel's n-tile; keep it VMEM-sized
            # (the kernel pads the shard's row count internally)
            chunk = min(chunk, 512)
        ids, d = prefbf.prefbf_topk(
            db["vectors"], _scan_norms(db), db["attrs_int"],
            db["attrs_float"], queries, programs, k=cfg.k, chunk=chunk,
            use_pallas=cfg.use_pallas, valid=valid)
        shard = jax.lax.axis_index(model_axis).astype(jnp.int32)
        gids = jnp.where(ids >= 0, ids + shard * n_local, -1)
        d, i = _merge_topk(d, gids, cfg.k, model_axis)
        return jnp.where(jnp.isfinite(d), i, -1), d

    serve_brute = jax.jit(shard_map(
        _serve_brute, mesh=mesh,
        in_specs=(dspecs, qspec, pspec_each, vspec),
        out_specs=(qspec, qspec),
        check_rep=False))

    fns = {"estimate": estimate, "serve_graph": serve_graph,
           "serve_graph_phat": serve_graph_phat, "serve_brute": serve_brute,
           "db_specs": dspecs, "query_spec": qspec}

    # -- compressed brute route (quant subsystem, sharded) --------------------
    if quant is not None:
        from ..quant import adc as quant_adc

        def _serve_brute_pq(db, queries, programs, valid):
            """Per shard: ADC LUT scan over the local uint8 codes -> exact
            float32 re-rank of the top rerank*k local candidates -> global
            ids -> cross-shard top-k merge.  The O(Ns) scan reads only codes;
            float32 rows are touched for the R re-rank candidates alone.
            With cfg.use_pallas the PQ scan runs the pq_adc kernel (the SQ
            fallback has no kernel and ignores the flag, like LocalBackend)."""
            n_local = db["norms"].shape[0]
            chunk = largest_divisor(n_local, prefbf_chunk)
            norms = _scan_norms(db)
            if quant == "pq":
                ids, d = quant_adc.pq_prefbf_topk(
                    db["codes"], norms, db["attrs_int"],
                    db["attrs_float"], queries, programs, db["centroids"],
                    db["vectors"], k=cfg.k, rerank=rerank, chunk=chunk,
                    use_pallas=cfg.use_pallas, valid=valid)
            else:
                ids, d = quant_adc.sq_prefbf_topk(
                    db["codes"], db["sq_lo"], db["sq_scale"], norms,
                    db["attrs_int"], db["attrs_float"], queries, programs,
                    db["vectors"], k=cfg.k, rerank=rerank, chunk=chunk,
                    valid=valid)
            shard = jax.lax.axis_index(model_axis).astype(jnp.int32)
            n_loc = jnp.asarray(n_local, jnp.int32)
            gids = jnp.where(ids >= 0, ids + shard * n_loc, -1)
            d, i = _merge_topk(d, gids, cfg.k, model_axis)
            return jnp.where(jnp.isfinite(d), i, -1), d

        fns["serve_brute_pq"] = jax.jit(shard_map(
            _serve_brute_pq, mesh=mesh,
            in_specs=(dspecs, qspec, pspec_each, vspec),
            out_specs=(qspec, qspec),
            check_rep=False))

    return fns


def device_put_sharded_db(arrays: dict, mesh: Mesh, specs: dict) -> dict:
    return {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
            for k, v in arrays.items()}
