"""Pure-jnp oracle for the pq_adc kernel."""
from __future__ import annotations

import jax.numpy as jnp

from ...core import filters as F

BIG = 3.0e38


def pq_adc_gather_ref(codes, luts, nbr_ids):
    """Oracle for the block-gather variant: per-(query, neighbor) ADC sums.

    codes (N, M); luts (B, M, K); nbr_ids (B, M0) int32 (-1 -> BIG).
    Returns adc_d2 (B, M0) float32."""
    safe = jnp.maximum(nbr_ids, 0)
    idx = codes.astype(jnp.int32)[safe][..., None]           # (B, M0, M, 1)
    g = jnp.take_along_axis(luts[:, None, :, :], idx, axis=3)
    adc = jnp.sum(g[..., 0], axis=-1)                        # (B, M0)
    return jnp.where(nbr_ids < 0, BIG, adc)


def pq_adc_topr_ref(luts, codes, norms, ints, floats, programs, *, r: int):
    """Dense (B, N) ADC matrix + filter program + top-R via argsort.

    Same semantics as the kernel: ADC distance is the sum over subspaces of
    the per-centroid LUT entries; failing and padded (norm >= BIG) rows go
    to BIG.  Returns (adc_d2 (B, R), ids (B, R) int32)."""
    idx = codes.astype(jnp.int32)[None, :, :, None]          # (1, N, M, 1)
    g = jnp.take_along_axis(luts[:, None, :, :], idx, axis=3)
    adc = jnp.sum(g[..., 0], axis=-1)                        # (B, N)
    mask = F.eval_program_batched(programs, ints, floats, xp=jnp)
    ok = mask & (norms < BIG)[None, :]
    adc = jnp.minimum(jnp.where(ok, adc, BIG), BIG)
    order = jnp.argsort(adc, axis=1)[:, :r]
    return (jnp.take_along_axis(adc, order, axis=1),
            order.astype(jnp.int32))
