"""Fused PQ asymmetric-distance + filter mask + running top-R Pallas kernel.

Compressed-domain sibling of kernels/filtered_topk: one invocation scans the
whole code table for a tile of queries,

  grid = (B/bq, N/bn); the n-axis is sequential so the running per-query
  top-R candidate list lives in VMEM scratch across n-tiles.

Per (i, j) step, entirely in VMEM:
  * load the query LUT tile (bq, M*K) and the code tile (bn, M) **uint8**
    (codes stream from HBM in their stored byte layout -- widening to int32
    happens in-register, never in memory traffic),
  * ADC accumulation as M one-hot matmuls: for each subspace the code column
    becomes a (bn, K) one-hot and contracts with the (bq, K) LUT slice on the
    MXU -- a gather expressed as arithmetic, since TPU Pallas has no
    in-kernel vector gather,
  * evaluate the DNF filter program on the attribute rows (shared helper
    from filtered_topk) and mask failing + padded rows (norm >= BIG) to BIG,
  * merge into the running (bq, R) top-R scratch (R = rerank * k; the exact
    float32 re-rank happens outside, in quant/adc.py).

VMEM working set per step: bq*M*K + bn*M + bn*K + bq*bn + bq*R floats;
defaults (bq, bn, M, K) = (128, 512, 8, 256) stay well under 16 MB.

The graph-route sibling ``pq_adc_gather_pallas`` is **row-batched**: one
sequential pass per bq-query tile stages the whole (bq, M0) gathered
neighbor code block into VMEM scratch (one uint8 row DMA per inner grid
step, picked by the scalar-prefetch index_map), then scores all bq*M0 rows
against the LUT tile with the same M one-hot MXU matmuls the full-scan
kernel uses and slices each query's own M0 columns off the result cube.
That replaces the former per-(query, neighbor)-cell launch whose LUT lookup
ran as M*K scalar fmas on the VPU -- the MXU form does bq x redundant math
(every query scores every staged row) but turns ~bq*M0*M*K scalar ops per
tile into M dense (bq, K) x (K, bq*M0) contractions, which is the shape the
hardware is actually fast at.  Keep bq small (default 8, one MXU sublane
block): the redundancy factor is exactly bq.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..filtered_topk.kernel import BIG, _eval_program_tile, _topk_merge


def _kernel(lut_ref, c_ref, n_ref, ai_ref, af_ref, valid_ref, imask_ref,
            flo_ref, fhi_ref, od_ref, oi_ref, bd_ref, bi_ref,
            *, r: int, bn: int, m: int, ksub: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        bd_ref[...] = jnp.full_like(bd_ref, BIG)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    lut = lut_ref[...].astype(jnp.float32)   # (bq, M*K); accepts bf16 tables
    codes = c_ref[...].astype(jnp.int32)     # (bn, M) uint8 -> in-register
    kcols = jax.lax.broadcasted_iota(jnp.int32, (1, ksub), 1)
    acc = jnp.zeros((lut.shape[0], bn), jnp.float32)
    for mm in range(m):                 # static unroll: M is small (<= 32)
        oh = (codes[:, mm:mm + 1] == kcols).astype(jnp.float32)   # (bn, K)
        acc = acc + jax.lax.dot_general(
            lut[:, mm * ksub:(mm + 1) * ksub], oh,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                   # MXU

    mask = _eval_program_tile(valid_ref[...], imask_ref[...], flo_ref[...],
                              fhi_ref[...], ai_ref[...], af_ref[...])
    ok = mask & (n_ref[...] < BIG)[None, :]   # padded rows carry BIG norms
    dist = jnp.minimum(jnp.where(ok, acc, BIG), BIG)

    ids = (j * bn + jnp.arange(bn, dtype=jnp.int32))[None, :]
    ids = jnp.broadcast_to(ids, dist.shape)

    bd, bi = _topk_merge(bd_ref[...], bi_ref[...], dist, ids, r)
    bd_ref[...] = bd
    bi_ref[...] = bi
    od_ref[...] = bd
    oi_ref[...] = bi


def _gather_kernel(idx_ref, lut_ref, c_ref, ids_ref, o_ref, stage_ref,
                   *, bq: int, m0: int, m: int, ksub: int):
    """Row-batched gather scoring: stage bq*M0 code rows, then M MXU matmuls.

    The inner grid axis walks the bq-query tile's flattened (bq*M0,) neighbor
    list; each step's code row arrives via the scalar-prefetch index_map (the
    paged-attention indirection gather_distance uses) and is parked in the
    VMEM ``stage_ref`` block.  The last step scores the whole staged block
    against the LUT tile exactly like the full-scan kernel -- per subspace a
    (bq*M0, K) one-hot contracts with the (bq, K) LUT slice on the MXU --
    and extracts each query's own M0-slice from the (bq, bq, M0) result cube
    (row j of the stage belongs to query j // M0).
    """
    j = pl.program_id(1)
    r0 = bq * m0

    # one uint8 row DMA per step: M bytes of HBM traffic per neighbor
    stage_ref[pl.ds(j, 1), :] = c_ref[...].astype(jnp.int32)

    @pl.when(j == r0 - 1)
    def _score():
        codes = stage_ref[...]                     # (bq*M0, M)
        lut = lut_ref[...].astype(jnp.float32)     # (bq, M*K); accepts bf16
        kcols = jax.lax.broadcasted_iota(jnp.int32, (1, ksub), 1)
        acc = jnp.zeros((bq, r0), jnp.float32)
        for mm in range(m):             # static unroll: M is small (<= 32)
            oh = (codes[:, mm:mm + 1] == kcols).astype(jnp.float32)
            acc = acc + jax.lax.dot_general(
                lut[:, mm * ksub:(mm + 1) * ksub], oh,
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)               # MXU
        # every query scored every staged row (bq x redundant, MXU-cheap);
        # keep the diagonal blocks of the (bq, bq, M0) cube
        cube = acc.reshape(bq, bq, m0)
        qi = jax.lax.broadcasted_iota(jnp.int32, (bq, bq), 0)
        qj = jax.lax.broadcasted_iota(jnp.int32, (bq, bq), 1)
        eye = (qi == qj).astype(jnp.float32)
        out = jnp.sum(cube * eye[:, :, None], axis=1)             # (bq, M0)
        o_ref[...] = jnp.where(ids_ref[...] < 0, BIG, out)


def pq_adc_gather_pallas(nbr_ids, luts, codes, *, block_q: int,
                         interpret: bool):
    """Row-batched block-gather ADC scoring (graph-route sibling of
    pq_adc_pallas).

    nbr_ids (B, M0) int32 (-1 pad); luts (B, M*K) flattened (f32 or bf16);
    codes (N, M) uint8 -- NOT widened host-side, so each gathered row
    streams M bytes.  B must be a multiple of block_q (ops.py pads).
    Returns adc_d2 (B, M0) float32 with BIG at padding.
    """
    b, m0 = nbr_ids.shape
    n, m = codes.shape
    mk = luts.shape[1]
    ksub = mk // m
    bq = block_q
    assert b % bq == 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b // bq, bq * m0),
        in_specs=[
            pl.BlockSpec((bq, mk), lambda i, j, idx: (i, 0)),     # LUT tile
            pl.BlockSpec((1, m),                                  # code[gather]
                         lambda i, j, idx: (
                             jnp.maximum(idx[i * bq + j // m0, j % m0], 0),
                             0)),
            pl.BlockSpec((bq, m0), lambda i, j, idx: (i, 0)),     # raw ids
        ],
        out_specs=[
            pl.BlockSpec((bq, m0), lambda i, j, idx: (i, 0)),
        ],
        scratch_shapes=[
            # staged gathered code rows for the whole query tile
            pltpu.VMEM((bq * m0, m), jnp.int32),
        ],
    )
    (out,) = pl.pallas_call(
        functools.partial(_gather_kernel, bq=bq, m0=m0, m=m, ksub=ksub),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, m0), jnp.float32)],
        interpret=interpret,
    )(nbr_ids, luts, codes, nbr_ids)
    return out


def pq_adc_pallas(luts, codes, norms, ints, floats, programs, *, r: int,
                  block_q: int, block_n: int, interpret: bool):
    """Launch the kernel.  All shapes must already be padded to block
    multiples (ops.py does this).  luts (B, M*K) flattened;
    returns (adc_d2 (B, R), ids (B, R))."""
    b, mk = luts.shape
    n, m = codes.shape
    ksub = mk // m
    bq, bn = block_q, block_n
    assert b % bq == 0 and n % bn == 0
    w = programs["valid"].shape[1]
    mi = ints.shape[1]
    mf = floats.shape[1]
    grid = (b // bq, n // bn)

    kern = functools.partial(_kernel, r=r, bn=bn, m=m, ksub=ksub)
    out_d, out_i = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, mk), lambda i, j: (i, 0)),         # LUTs
            pl.BlockSpec((bn, m), lambda i, j: (j, 0)),          # codes
            pl.BlockSpec((bn,), lambda i, j: (j,)),              # norms
            pl.BlockSpec((bn, mi), lambda i, j: (j, 0)),         # attrs int
            pl.BlockSpec((bn, mf), lambda i, j: (j, 0)),         # attrs float
            pl.BlockSpec((bq, w), lambda i, j: (i, 0)),          # valid
            pl.BlockSpec((bq, w, mi), lambda i, j: (i, 0, 0)),   # imask
            pl.BlockSpec((bq, w, mf), lambda i, j: (i, 0, 0)),   # flo
            pl.BlockSpec((bq, w, mf), lambda i, j: (i, 0, 0)),   # fhi
        ],
        out_specs=[
            pl.BlockSpec((bq, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, r), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, r), jnp.float32),
            jax.ShapeDtypeStruct((b, r), jnp.int32),
        ],
        scratch_shapes=[
            # running top-R state lives in VMEM across the sequential n-axis
            pltpu.VMEM((bq, r), jnp.float32),
            pltpu.VMEM((bq, r), jnp.int32),
        ],
        interpret=interpret,
    )(luts, codes, norms, ints, floats, programs["valid"],
      programs["imask"], programs["flo"], programs["fhi"])
    return out_d, out_i
