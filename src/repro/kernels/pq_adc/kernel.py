"""Fused PQ asymmetric-distance + filter mask + running top-R Pallas kernel.

Compressed-domain sibling of kernels/filtered_topk: one invocation scans the
whole code table for a tile of queries,

  grid = (B/bq, N/bn); the n-axis is sequential so the running per-query
  top-R candidate list lives in VMEM scratch across n-tiles.

Per (i, j) step, entirely in VMEM:
  * load the query LUT tile (bq, M*K) and the code tile (bn, M) int32,
  * ADC accumulation as M one-hot matmuls: for each subspace the code column
    becomes a (bn, K) one-hot and contracts with the (bq, K) LUT slice on the
    MXU -- a gather expressed as arithmetic, since TPU Pallas has no
    in-kernel vector gather,
  * evaluate the DNF filter program on the attribute rows (shared helper
    from filtered_topk) and mask failing + padded rows (norm >= BIG) to BIG,
  * merge into the running (bq, R) top-R scratch (R = rerank * k; the exact
    float32 re-rank happens outside, in quant/adc.py).

VMEM working set per step: bq*M*K + bn*M + bn*K + bq*bn + bq*R floats;
defaults (bq, bn, M, K) = (128, 512, 8, 256) stay well under 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..filtered_topk.kernel import BIG, _eval_program_tile, _topk_merge


def _kernel(lut_ref, c_ref, n_ref, ai_ref, af_ref, valid_ref, imask_ref,
            flo_ref, fhi_ref, od_ref, oi_ref, bd_ref, bi_ref,
            *, r: int, bn: int, m: int, ksub: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        bd_ref[...] = jnp.full_like(bd_ref, BIG)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    lut = lut_ref[...]                  # (bq, M*K)
    codes = c_ref[...]                  # (bn, M) int32
    kcols = jax.lax.broadcasted_iota(jnp.int32, (1, ksub), 1)
    acc = jnp.zeros((lut.shape[0], bn), jnp.float32)
    for mm in range(m):                 # static unroll: M is small (<= 32)
        oh = (codes[:, mm:mm + 1] == kcols).astype(jnp.float32)   # (bn, K)
        acc = acc + jax.lax.dot_general(
            lut[:, mm * ksub:(mm + 1) * ksub], oh,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                   # MXU

    mask = _eval_program_tile(valid_ref[...], imask_ref[...], flo_ref[...],
                              fhi_ref[...], ai_ref[...], af_ref[...])
    ok = mask & (n_ref[...] < BIG)[None, :]   # padded rows carry BIG norms
    dist = jnp.minimum(jnp.where(ok, acc, BIG), BIG)

    ids = (j * bn + jnp.arange(bn, dtype=jnp.int32))[None, :]
    ids = jnp.broadcast_to(ids, dist.shape)

    bd, bi = _topk_merge(bd_ref[...], bi_ref[...], dist, ids, r)
    bd_ref[...] = bd
    bi_ref[...] = bi
    od_ref[...] = bd
    oi_ref[...] = bi


def _gather_kernel(idx_ref, lut_ref, c_ref, o_ref, *, m: int, ksub: int):
    """One (query, neighbor) cell: ADC-accumulate the gathered code row.

    The code row arrives via the scalar-prefetch index_map (the same
    paged-attention indirection gather_distance uses); the LUT slice is the
    query's full (1, M*K) table.  TPU Pallas has no in-kernel vector gather,
    so the per-subspace lookup is an (M, K) one-hot mask-and-reduce on the
    VPU -- M*K fmas per neighbor, tiny next to the row DMA it replaces.
    """
    b = pl.program_id(0)
    mm = pl.program_id(1)
    raw = idx_ref[b, mm]

    # codes stay uint8 end to end -- the row DMA moves M bytes, not 4*M
    # (the whole point of scoring on codes); widen in-register for the
    # comparison only
    codes = c_ref[0].astype(jnp.int32)                  # (M,)
    lut = lut_ref[...].reshape(m, ksub)                 # (M, K)
    kcols = jax.lax.broadcasted_iota(jnp.int32, (m, ksub), 1)
    oh = (codes[:, None] == kcols).astype(jnp.float32)
    adc = jnp.sum(lut * oh)

    o_ref[0, 0] = jnp.where(raw < 0, BIG, adc)


def pq_adc_gather_pallas(nbr_ids, luts, codes, *, interpret: bool):
    """Block-gather ADC scoring (graph-route sibling of pq_adc_pallas).

    nbr_ids (B, M0) int32 (-1 pad); luts (B, M*K) flattened; codes (N, M)
    uint8 -- NOT widened host-side, so each gathered row streams M bytes.
    Returns adc_d2 (B, M0) float32 with BIG at padding.
    """
    b, m0 = nbr_ids.shape
    n, m = codes.shape
    mk = luts.shape[1]
    ksub = mk // m

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, m0),
        in_specs=[
            pl.BlockSpec((1, mk), lambda bi, mi, idx: (bi, 0)),   # LUT row
            pl.BlockSpec((1, m),                                  # code[gather]
                         lambda bi, mi, idx: (jnp.maximum(idx[bi, mi], 0), 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda bi, mi, idx: (bi, mi)),
        ],
    )
    (out,) = pl.pallas_call(
        functools.partial(_gather_kernel, m=m, ksub=ksub),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, m0), jnp.float32)],
        interpret=interpret,
    )(nbr_ids, luts, codes)
    return out


def pq_adc_pallas(luts, codes, norms, ints, floats, programs, *, r: int,
                  block_q: int, block_n: int, interpret: bool):
    """Launch the kernel.  All shapes must already be padded to block
    multiples (ops.py does this).  luts (B, M*K) flattened;
    returns (adc_d2 (B, R), ids (B, R))."""
    b, mk = luts.shape
    n, m = codes.shape
    ksub = mk // m
    bq, bn = block_q, block_n
    assert b % bq == 0 and n % bn == 0
    w = programs["valid"].shape[1]
    mi = ints.shape[1]
    mf = floats.shape[1]
    grid = (b // bq, n // bn)

    kern = functools.partial(_kernel, r=r, bn=bn, m=m, ksub=ksub)
    out_d, out_i = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, mk), lambda i, j: (i, 0)),         # LUTs
            pl.BlockSpec((bn, m), lambda i, j: (j, 0)),          # codes
            pl.BlockSpec((bn,), lambda i, j: (j,)),              # norms
            pl.BlockSpec((bn, mi), lambda i, j: (j, 0)),         # attrs int
            pl.BlockSpec((bn, mf), lambda i, j: (j, 0)),         # attrs float
            pl.BlockSpec((bq, w), lambda i, j: (i, 0)),          # valid
            pl.BlockSpec((bq, w, mi), lambda i, j: (i, 0, 0)),   # imask
            pl.BlockSpec((bq, w, mf), lambda i, j: (i, 0, 0)),   # flo
            pl.BlockSpec((bq, w, mf), lambda i, j: (i, 0, 0)),   # fhi
        ],
        out_specs=[
            pl.BlockSpec((bq, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, r), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, r), jnp.float32),
            jax.ShapeDtypeStruct((b, r), jnp.int32),
        ],
        scratch_shapes=[
            # running top-R state lives in VMEM across the sequential n-axis
            pltpu.VMEM((bq, r), jnp.float32),
            pltpu.VMEM((bq, r), jnp.int32),
        ],
        interpret=interpret,
    )(luts, codes, norms, ints, floats, programs["valid"],
      programs["imask"], programs["flo"], programs["fhi"])
    return out_d, out_i
