"""Public jit'd wrapper for the pq_adc kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import default_interpret
from ..filtered_topk.ops import _pad_rows
from .kernel import BIG, pq_adc_gather_pallas, pq_adc_pallas


@partial(jax.jit, static_argnames=("block_q", "interpret"))
def pq_adc_gather(codes, luts, nbr_ids, *, block_q: int = 8,
                  interpret: bool | None = None):
    """Graph-expansion ADC scoring (row-batched Pallas block-gather).

    codes (N, M) uint8/int32; luts (B, M, K) from quant.adc.build_luts (f32
    or bf16 -- accumulation is f32 either way); nbr_ids (B, M0) int32
    per-query neighbor ids (-1 pad -> +inf).  Returns adc_d2 (B, M0) float32
    -- squared approximate distances; the traversal masks pad/visited
    entries and re-ranks its final candidates exactly.

    B is padded up to a block_q multiple with -1 ids (scored then sliced
    off); block_q is also the kernel's redundant-scoring factor, so keep it
    at one MXU sublane block.
    """
    b, m, ksub = luts.shape
    if interpret is None:
        interpret = default_interpret()
    # named_scope stamps the kernel into HLO op metadata at trace time, so
    # a jax.profiler capture attributes its device time by name -- compiled
    # executables carry it for free (repro.obs.profiling)
    with jax.named_scope("favor.pq_adc_gather"):
        # codes pass through in their stored uint8 layout: widening here
        # would materialize a 4x corpus copy and quadruple every gathered
        # row's DMA
        bq = min(block_q, max(1, b))
        b_pad = ((b + bq - 1) // bq) * bq
        ids = _pad_rows(nbr_ids.astype(jnp.int32), b_pad, -1)
        luts_p = _pad_rows(luts.reshape(b, m * ksub), b_pad, 0)
        out = pq_adc_gather_pallas(ids, luts_p, codes,
                                   block_q=bq, interpret=interpret)[:b]
        return jnp.where(out >= BIG, jnp.inf, out)


@partial(jax.jit, static_argnames=("r", "block_q", "block_n", "interpret"))
def pq_adc_topr(codes, norms, ints, floats, luts, programs, *,
                r: int = 40, block_q: int = 128, block_n: int = 512,
                interpret: bool | None = None, valid=None):
    """Fused compressed filtered top-R candidate scan (Pallas).

    codes (N, M) uint8/int32; norms (N,) float32 (+inf/BIG rows are treated
    as padding); luts (B, M, K) from quant.adc.build_luts; programs batched
    filter programs; ``valid`` an optional (B,) bool query mask (bucket
    padding): False rows return -1 / +inf.  Returns (ids (B, R) int32 with
    -1 for missing, adc_d2 (B, R) f32 with +inf for missing) -- ADC
    distances are squared and approximate; callers re-rank exactly
    (quant/adc.py).
    """
    b, m, ksub = luts.shape
    n = codes.shape[0]
    bq = min(block_q, max(8, b))
    bn = min(block_n, max(32, n))

    # pad DB rows: BIG norms mark padded rows, any code word is fine.
    # codes keep their stored (uint8) dtype -- the kernel widens in-register,
    # so every code tile DMA moves 1 byte per entry instead of 4
    n_pad = ((n + bn - 1) // bn) * bn
    codes = _pad_rows(codes, n_pad, 0)
    norms = _pad_rows(jnp.minimum(norms, BIG), n_pad, BIG)
    ints = _pad_rows(ints, n_pad, 0)
    floats = _pad_rows(floats, n_pad, jnp.nan)

    # pad query rows
    b_pad = ((b + bq - 1) // bq) * bq
    luts_p = _pad_rows(luts.reshape(b, m * ksub), b_pad, 0)
    programs_p = {
        "valid": _pad_rows(programs["valid"], b_pad, 0),
        "imask": _pad_rows(programs["imask"], b_pad, 0),
        "flo": _pad_rows(programs["flo"], b_pad, jnp.inf),
        "fhi": _pad_rows(programs["fhi"], b_pad, -jnp.inf),
    }

    if interpret is None:
        interpret = default_interpret()
    with jax.named_scope("favor.pq_adc_topr"):
        out_d, out_i = pq_adc_pallas(
            luts_p, codes, norms, ints, floats, programs_p,
            r=r, block_q=bq, block_n=bn, interpret=interpret)
    out_d, out_i = out_d[:b], out_i[:b]
    missing = out_d >= BIG
    if valid is not None:
        missing = missing | ~jnp.asarray(valid, bool)[:, None]
    return (jnp.where(missing, -1, out_i),
            jnp.where(missing, jnp.inf, out_d))
