"""Public jit'd wrapper for the embedding_bag kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import default_interpret
from .kernel import embedding_bag_pallas


@partial(jax.jit, static_argnames=("mode", "interpret"))
def embedding_bag(table, bags, *, mode: str = "sum",
                  interpret: bool | None = None):
    """EmbeddingBag(table (V, d), bags (B, L) int32 -1-padded) -> (B, d)."""
    assert mode in ("sum", "mean")
    if interpret is None:
        interpret = default_interpret()
    return embedding_bag_pallas(bags.astype(jnp.int32),
                                table.astype(jnp.float32),
                                mode=mode, interpret=interpret)
