"""EmbeddingBag Pallas kernel: scalar-prefetch gather + bag reduce.

JAX has no native ``nn.EmbeddingBag`` (kernel_taxonomy section RecSys); the
recsys architectures implement it as gather + segment_sum.  This kernel fuses
the two: bag indices are scalar-prefetched, each grid step DMAs one embedding
row straight into VMEM and accumulates into the output bag row -- the table
itself never materializes a (B*L, d) gathered intermediate in HBM.

Grid (B, L): output block (1, d) at row b is revisited across the sequential
l axis; initialized at l == 0, divided by the bag's valid count at l == L-1
for mean mode.  Padding ids (< 0) clamp to row 0 in the index_map and are
masked out of the accumulation via the prefetched scalar.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, row_ref, out_ref, *, mode: str, length: int):
    b = pl.program_id(0)
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    valid = idx_ref[b, l] >= 0
    out_ref[...] += jnp.where(valid, row_ref[...], 0.0)

    if mode == "mean":
        @pl.when(l == length - 1)
        def _finish():
            cnt = jnp.zeros((), jnp.float32)
            for ll in range(length):
                cnt += (idx_ref[b, ll] >= 0).astype(jnp.float32)
            out_ref[...] = out_ref[...] / jnp.maximum(cnt, 1.0)


def embedding_bag_pallas(bags, table, *, mode: str, interpret: bool):
    """bags (B, L) int32 (-1 pad); table (V, d) f32 -> (B, d) f32."""
    b, length = bags.shape
    dim = table.shape[1]
    kern = functools.partial(_kernel, mode=mode, length=length)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, length),
        in_specs=[
            pl.BlockSpec((1, dim), lambda bi, li, idx: (jnp.maximum(idx[bi, li], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, dim), lambda bi, li, idx: (bi, 0)),
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, dim), jnp.float32),
        interpret=interpret,
    )(bags, table)
