"""Pure-jnp oracle for the embedding_bag kernel (gather + masked reduce)."""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(bags, table, *, mode: str = "sum"):
    """bags (B, L) int32 with -1 padding; table (V, d) -> (B, d)."""
    safe = jnp.maximum(bags, 0)
    rows = table[safe]                        # (B, L, d)
    valid = (bags >= 0)[..., None]
    out = jnp.sum(jnp.where(valid, rows, 0.0), axis=1)
    if mode == "mean":
        cnt = jnp.maximum(jnp.sum(bags >= 0, axis=1, keepdims=True), 1)
        out = out / cnt
    return out
