"""Pallas TPU kernels for FAVOR's compute hot spots.

Each kernel package ships three files:
  kernel.py -- pl.pallas_call body with explicit BlockSpec VMEM tiling
  ops.py    -- jit'd public wrapper (padding, program flattening, interpret
               auto-detection: interpret=True on CPU, compiled on TPU)
  ref.py    -- pure-jnp oracle used by the shape/dtype sweep tests

Kernels:
  filtered_topk   -- fused L2 distance + filter-program mask + exclusion
                     distance + running top-k (PreFBF / retrieval_cand path)
  gather_distance -- scalar-prefetch neighbor gather + distance + exclusion
                     (graph-search expansion; paged-attention indirection idiom)
  embedding_bag   -- scalar-prefetch row gather + segment-sum bag reduce
                     (recsys embedding lookup; JAX has no native EmbeddingBag)
  pq_adc          -- fused PQ asymmetric-distance LUT accumulate + filter
                     mask + running top-R over uint8 code chunks (the
                     compressed PreFBF scan; quant/adc.py re-ranks exactly)
"""
import jax


def default_interpret() -> bool:
    """Pallas interpret mode on CPU (validation), compiled on TPU (target)."""
    return jax.default_backend() != "tpu"
