"""Pure-jnp oracle for the gather_distance kernel."""
from __future__ import annotations

import jax.numpy as jnp

from ...core import filters as F

BIG = 3.0e38


def gather_distance_ref(nbr_ids, queries, vectors, norms, ints, floats,
                        programs, dvec):
    """Gather + distance + exclusion, same contract as the kernel."""
    safe = jnp.maximum(nbr_ids, 0)
    v = vectors[safe]                       # (B, M, d)
    vn = norms[safe]                        # (B, M)
    qn = jnp.sum(queries * queries, axis=-1)
    dot = jnp.einsum("bd,bmd->bm", queries, v)
    dist = jnp.sqrt(jnp.maximum(vn + qn[:, None] - 2.0 * dot, 0.0))
    td = F.eval_program_gathered(programs, ints[safe], floats[safe], xp=jnp)
    dbar = dist + jnp.where(td, 0.0, dvec[:, None])
    invalid = nbr_ids < 0
    return (jnp.where(invalid, BIG, dbar),
            jnp.where(invalid, 0, td.astype(jnp.int32)))
