"""Public jit'd wrapper for the gather_distance kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import default_interpret
from .kernel import BIG, gather_distance_pallas


@partial(jax.jit, static_argnames=("interpret",))
def gather_distance(vectors, norms, ints, floats, queries, nbr_ids, programs,
                    dvec, *, interpret: bool | None = None, valid=None):
    """Graph-expansion distance evaluation (Pallas).

    ``valid`` is an optional (B,) bool query mask (bucket padding): False
    rows return all-+inf distances and no TD hits.
    Returns (dbar (B, M) f32 -- +inf at -1 padding, td (B, M) bool)."""
    if interpret is None:
        interpret = default_interpret()
    # HLO-metadata profiling scope (see repro.obs.profiling): trace-time
    # only, zero runtime cost
    with jax.named_scope("favor.gather_distance"):
        out_d, out_td = gather_distance_pallas(
            nbr_ids.astype(jnp.int32), queries, vectors, norms, ints, floats,
            programs, dvec.astype(jnp.float32), interpret=interpret)
    out_d = jnp.where(out_d >= BIG, jnp.inf, out_d)
    out_td = out_td.astype(bool)
    if valid is not None:
        vmask = jnp.asarray(valid, bool)[:, None]
        out_d = jnp.where(vmask, out_d, jnp.inf)
        out_td = out_td & vmask
    return (out_d, out_td)
