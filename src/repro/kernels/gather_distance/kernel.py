"""Scalar-prefetch neighbor gather + distance + exclusion Pallas kernel.

The graph-search expansion hot spot: given per-query neighbor-id rows
(B, M) into the DB shard, produce the adjusted distances Dis_bar (Eq. 2)
and the TD mask for each (query, neighbor) pair.

TPU realization of pointer-chasing (DESIGN.md section 3): neighbor ids are a
**scalar-prefetch** operand (SMEM), and every DB-side BlockSpec index_map
dereferences them to pick the HBM row to DMA -- the paged-attention
indirection idiom (vLLM block tables).  Unlike paged KV, graph neighbors are
inherently scattered single rows, so the grid is (B, M) with (1, d) row
blocks; Mosaic pipelines the row DMAs across grid steps.

Padding ids (< 0) are clamped in the index_map (the DMA must target a real
row) and masked to +BIG in the kernel body via the prefetched scalar.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 3.0e38


def _eval_row(valid, imask, flo, fhi, ints, floats):
    """Filter program of one query over one gathered row -> bool scalar.
    valid (1, W); imask (1, W, mi); flo/fhi (1, W, mf); ints (1, mi);
    floats (1, mf)."""
    ok = valid[0, :] > 0  # (W,)
    if imask.shape[-1]:
        shifted = imask[0] >> ints[0][None, :].astype(jnp.uint32)  # (W, mi)
        ok = ok & ((shifted & 1) == 1).all(axis=-1)
    if flo.shape[-1]:
        af = floats[0][None, :]
        ok = ok & ((af >= flo[0]) & (af <= fhi[0])).all(axis=-1)
    return ok.any()


def _kernel(idx_ref, q_ref, v_ref, n_ref, ai_ref, af_ref, valid_ref,
            imask_ref, flo_ref, fhi_ref, d_ref, od_ref, otd_ref):
    b = pl.program_id(0)
    m = pl.program_id(1)
    raw = idx_ref[b, m]

    q = q_ref[0]
    v = v_ref[0]
    d2 = n_ref[0] + jnp.sum(q * q) - 2.0 * jnp.sum(q * v)
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))

    td = _eval_row(valid_ref[...], imask_ref[...], flo_ref[...],
                   fhi_ref[...], ai_ref[...], af_ref[...])
    dbar = dist + jnp.where(td, 0.0, d_ref[0])

    invalid = raw < 0
    od_ref[0, 0] = jnp.where(invalid, BIG, dbar)
    otd_ref[0, 0] = jnp.where(invalid, 0, td.astype(jnp.int32))


def gather_distance_pallas(nbr_ids, queries, vectors, norms, ints, floats,
                           programs, dvec, *, interpret: bool):
    """nbr_ids (B, M) int32 (-1 pad); queries (B, d); DB arrays (N, ...).
    Returns (dbar (B, M) f32 with BIG at padding, td (B, M) int32)."""
    b, m = nbr_ids.shape
    dim = queries.shape[1]
    w = programs["valid"].shape[1]
    mi = ints.shape[1]
    mf = floats.shape[1]

    def row(idx, bi, mi_):
        return (jnp.maximum(idx[bi, mi_], 0), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, m),
        in_specs=[
            pl.BlockSpec((1, dim), lambda bi, mi_, idx: (bi, 0)),           # q
            pl.BlockSpec((1, dim), lambda bi, mi_, idx: row(idx, bi, mi_)),  # v[gather]
            pl.BlockSpec((1,), lambda bi, mi_, idx: (jnp.maximum(idx[bi, mi_], 0),)),
            pl.BlockSpec((1, mi), lambda bi, mi_, idx: row(idx, bi, mi_)),   # attrs int
            pl.BlockSpec((1, mf), lambda bi, mi_, idx: row(idx, bi, mi_)),   # attrs float
            pl.BlockSpec((1, w), lambda bi, mi_, idx: (bi, 0)),
            pl.BlockSpec((1, w, mi), lambda bi, mi_, idx: (bi, 0, 0)),
            pl.BlockSpec((1, w, mf), lambda bi, mi_, idx: (bi, 0, 0)),
            pl.BlockSpec((1, w, mf), lambda bi, mi_, idx: (bi, 0, 0)),
            pl.BlockSpec((1,), lambda bi, mi_, idx: (bi,)),                  # D
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda bi, mi_, idx: (bi, mi_)),
            pl.BlockSpec((1, 1), lambda bi, mi_, idx: (bi, mi_)),
        ],
    )
    out_d, out_td = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, m), jnp.float32),
            jax.ShapeDtypeStruct((b, m), jnp.int32),
        ],
        interpret=interpret,
    )(nbr_ids, queries, vectors, norms, ints, floats, programs["valid"],
      programs["imask"], programs["flo"], programs["fhi"], dvec)
    return out_d, out_td
