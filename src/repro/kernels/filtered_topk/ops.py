"""Public jit'd wrapper for the filtered_topk kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .. import default_interpret
from .kernel import BIG, filtered_topk_pallas


def _pad_rows(x, n_to, fill):
    pad = n_to - x.shape[0]
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)], axis=0)


@partial(jax.jit, static_argnames=("k", "block_q", "block_n", "exclude",
                                   "interpret"))
def filtered_topk(vectors, norms, ints, floats, queries, programs, *,
                  k: int = 10, block_q: int = 128, block_n: int = 512,
                  dvec=None, exclude: bool = False,
                  interpret: bool | None = None, valid=None):
    """Fused filtered brute-force top-k over the DB (Pallas).

    ``valid`` is an optional (B,) bool query mask (bucket padding): False
    rows return -1 / +inf without needing a special filter program.
    Returns (ids (B, k) int32 with -1 for missing, dists (B, k) f32 with +inf
    for missing) -- same contract as core.prefbf.prefbf_topk.
    """
    if interpret is None:
        interpret = default_interpret()
    b, dim = queries.shape
    n = vectors.shape[0]
    bq = min(block_q, max(8, b))
    bn = min(block_n, max(32, n))

    # pad DB rows: BIG norms make padded rows unreachable
    n_pad = ((n + bn - 1) // bn) * bn
    vectors = _pad_rows(vectors, n_pad, 0)
    norms = _pad_rows(norms, n_pad, BIG)
    ints = _pad_rows(ints, n_pad, 0)
    floats = _pad_rows(floats, n_pad, jnp.nan)

    # pad query rows
    b_pad = ((b + bq - 1) // bq) * bq
    qpad = b_pad - b
    queries_p = _pad_rows(queries, b_pad, 0)
    programs_p = {
        "valid": _pad_rows(programs["valid"], b_pad, 0),
        "imask": _pad_rows(programs["imask"], b_pad, 0),
        "flo": _pad_rows(programs["flo"], b_pad, jnp.inf),
        "fhi": _pad_rows(programs["fhi"], b_pad, -jnp.inf),
    }
    if dvec is None:
        dvec = jnp.zeros((b,), jnp.float32)
    dvec_p = _pad_rows(dvec.astype(jnp.float32), b_pad, 0)

    # HLO-metadata profiling scope (see repro.obs.profiling): trace-time
    # only, zero runtime cost
    with jax.named_scope("favor.filtered_topk"):
        out_d, out_i = filtered_topk_pallas(
            queries_p, vectors, norms, ints, floats, programs_p, dvec_p,
            k=k, block_q=bq, block_n=bn, exclude=exclude, interpret=interpret)
    out_d, out_i = out_d[:b], out_i[:b]
    missing = out_d >= BIG
    if valid is not None:
        missing = missing | ~jnp.asarray(valid, bool)[:, None]
    return (jnp.where(missing, -1, out_i),
            jnp.where(missing, jnp.inf, out_d))
