"""Pure-jnp oracle for the filtered_topk kernel."""
from __future__ import annotations

import jax.numpy as jnp

from ...core import filters as F

BIG = 3.0e38


def filtered_topk_ref(queries, vectors, norms, ints, floats, programs, dvec,
                      *, k: int, exclude: bool):
    """Dense (B, N) distance matrix + filter program + top-k via argsort.

    Same semantics as the kernel: PreFBF mode (exclude=False) masks failing
    rows to BIG; exclusion mode adds D per query (Eq. 2).  Rows with
    norm >= BIG (padding) never win."""
    qn = jnp.sum(queries * queries, axis=-1)
    d2 = norms[None, :] + qn[:, None] - 2.0 * (queries @ vectors.T)
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    mask = F.eval_program_batched(programs, ints, floats, xp=jnp)  # (B, N)
    if exclude:
        dist = dist + jnp.where(mask, 0.0, dvec[:, None])
    else:
        dist = jnp.where(mask, dist, BIG)
    dist = jnp.minimum(dist, BIG)
    order = jnp.argsort(dist, axis=1)[:, :k]
    return (jnp.take_along_axis(dist, order, axis=1),
            order.astype(jnp.int32))
