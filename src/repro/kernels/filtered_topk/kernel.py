"""Fused filtered distance + top-k Pallas TPU kernel.

One kernel invocation scans the whole DB shard for a tile of queries:

  grid = (B/bq, N/bn); the n-axis is sequential ("arbitrary") so a running
  per-query top-k lives in VMEM scratch across n-tiles; the q-axis is
  parallel.

Per (i, j) step, entirely in VMEM:
  * load query tile (bq, d), DB tile (bn, d) + norms + attribute rows,
  * distances via one MXU dot:  d2 = |v|^2 + |q|^2 - 2 q.v^T   (bq, bn)
  * evaluate the DNF filter program (bitmask + interval tests, branch-free),
  * PreFBF mode (exclude=False): failing rows -> +BIG (pre-filter semantics);
    exclusion mode (exclude=True): failing rows get +D (Eq. 2),
  * merge the tile into the running (bq, k) top-k scratch by k iterations of
    masked row-min extraction (k is small: 10-100; sort-free, TPU-friendly).

VMEM working set per step: bq*d + bn*d + bq*bn + bq*k floats; defaults
(bq, bn, d) = (128, 512, <=1024) stay well under 16 MB.  MXU dims (bq, d, bn)
are multiples of 128 after ops.py padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 3.0e38  # python literal: jnp scalars may not be captured by pallas kernels


def _eval_program_tile(valid, imask, flo, fhi, ints, floats):
    """DNF filter program over a DB tile.

    valid (bq, W); imask (bq, W, mi) uint32; flo/fhi (bq, W, mf)
    ints (bn, mi) int32; floats (bn, mf) f32      ->  (bq, bn) bool
    """
    ok = valid[:, :, None] > 0  # (bq, W, 1)
    if imask.shape[-1]:
        # (bq, W, 1, mi) >> (1, 1, bn, mi) -> bit test, all columns
        shifted = imask[:, :, None, :] >> ints[None, None, :, :].astype(jnp.uint32)
        ok = ok & ((shifted & 1) == 1).all(axis=-1)
    if flo.shape[-1]:
        af = floats[None, None, :, :]
        fok = (af >= flo[:, :, None, :]) & (af <= fhi[:, :, None, :])
        ok = ok & fok.all(axis=-1)
    return ok.any(axis=1)  # (bq, bn)


def _topk_merge(best_d, best_i, tile_d, tile_i, k: int):
    """Merge (bq, bn) tile into running (bq, k) top-k by iterated masked min.

    Scatter-free (TPU Pallas has no in-kernel scatter): each extraction uses a
    one-hot select built from argmin, so everything is elementwise + reduces."""
    d = jnp.concatenate([best_d, tile_d], axis=1)   # (bq, k+bn)
    i = jnp.concatenate([best_i, tile_i], axis=1)
    cols = jnp.arange(d.shape[1], dtype=jnp.int32)[None, :]
    out_d = []
    out_i = []
    for _ in range(k):
        j = jnp.argmin(d, axis=1)                    # (bq,)
        sel = cols == j[:, None].astype(jnp.int32)   # one-hot (bq, k+bn)
        out_d.append(jnp.min(d, axis=1))
        out_i.append(jnp.sum(jnp.where(sel, i, 0), axis=1))
        d = jnp.where(sel, BIG, d)
    return jnp.stack(out_d, axis=1), jnp.stack(out_i, axis=1)


def _kernel(q_ref, v_ref, n_ref, ai_ref, af_ref, valid_ref, imask_ref,
            flo_ref, fhi_ref, dvec_ref, od_ref, oi_ref, bd_ref, bi_ref,
            *, k: int, bn: int, exclude: bool):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        bd_ref[...] = jnp.full_like(bd_ref, BIG)
        bi_ref[...] = jnp.full_like(bi_ref, -1)

    q = q_ref[...]                     # (bq, d)
    v = v_ref[...]                     # (bn, d)
    vn = n_ref[...]                    # (bn,)
    qn = jnp.sum(q * q, axis=-1)       # (bq,)
    dot = jax.lax.dot_general(q, v, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # MXU
    d2 = vn[None, :] + qn[:, None] - 2.0 * dot
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))  # (bq, bn)

    mask = _eval_program_tile(valid_ref[...], imask_ref[...], flo_ref[...],
                              fhi_ref[...], ai_ref[...], af_ref[...])
    if exclude:
        dist = dist + jnp.where(mask, 0.0, dvec_ref[...][:, None])
    else:
        dist = jnp.where(mask, dist, BIG)
    # padded DB rows carry +BIG norms -> dist overflows to BIG and never wins
    dist = jnp.minimum(dist, BIG)

    ids = (j * bn + jnp.arange(bn, dtype=jnp.int32))[None, :]
    ids = jnp.broadcast_to(ids, dist.shape)

    bd, bi = _topk_merge(bd_ref[...], bi_ref[...], dist, ids, k)
    bd_ref[...] = bd
    bi_ref[...] = bi
    od_ref[...] = bd
    oi_ref[...] = bi


def filtered_topk_pallas(queries, vectors, norms, ints, floats, programs,
                         dvec, *, k: int, block_q: int, block_n: int,
                         exclude: bool, interpret: bool):
    """Launch the kernel.  All shapes must already be padded to block
    multiples (ops.py does this).  Returns (dists (B,k), ids (B,k))."""
    b, dim = queries.shape
    n = vectors.shape[0]
    bq, bn = block_q, block_n
    assert b % bq == 0 and n % bn == 0
    w = programs["valid"].shape[1]
    mi = ints.shape[1]
    mf = floats.shape[1]
    grid = (b // bq, n // bn)

    kern = functools.partial(_kernel, k=k, bn=bn, exclude=exclude)
    out_d, out_i = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, dim), lambda i, j: (i, 0)),        # queries
            pl.BlockSpec((bn, dim), lambda i, j: (j, 0)),        # vectors
            pl.BlockSpec((bn,), lambda i, j: (j,)),              # norms
            pl.BlockSpec((bn, mi), lambda i, j: (j, 0)),         # attrs int
            pl.BlockSpec((bn, mf), lambda i, j: (j, 0)),         # attrs float
            pl.BlockSpec((bq, w), lambda i, j: (i, 0)),          # valid
            pl.BlockSpec((bq, w, mi), lambda i, j: (i, 0, 0)),   # imask
            pl.BlockSpec((bq, w, mf), lambda i, j: (i, 0, 0)),   # flo
            pl.BlockSpec((bq, w, mf), lambda i, j: (i, 0, 0)),   # fhi
            pl.BlockSpec((bq,), lambda i, j: (i,)),              # D per query
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        scratch_shapes=[
            # running top-k state lives in VMEM across the sequential n-axis
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, vectors, norms, ints, floats, programs["valid"],
      programs["imask"], programs["flo"], programs["fhi"], dvec)
    return out_d, out_i
