"""Selectivity-estimator accuracy probes and route-decision confusion.

FAVOR's stable-QPS claim stands on the selector routing queries correctly
off an *estimated* selectivity (paper section 4.1: ``p_hat < lambda`` ->
brute PreFBF, else exclusion-distance graph search).  Generic metrics stacks
can't see whether that estimate is right; these probes can, because they sit
next to the corpus:

  * ``EstimatorProbe`` -- on a sampled batch, pick one query and evaluate
    its compiled filter program over the backend's *actual* attribute
    columns (host-side, exact).  ``|p_hat - p_true|`` lands in an error
    histogram; when truth and estimate fall on opposite sides of lambda the
    route-flip counter increments (labeled by the route actually taken) --
    a flip means the selector mis-routed that query.

  * ``RouteConfusion`` -- estimator error only matters when the *other*
    route would have been faster.  On a sampled batch, re-execute one query
    on BOTH routes (force="graph" / force="brute") against the innermost
    (cache-unwrapped) backend and time them; the confusion counter is
    labeled (chosen, faster), and the regret counter accumulates the
    seconds lost when chosen != faster.  Shadow executions never touch the
    cache layers and never record into the engine's shape ledger.

Both are sampled (deterministic 1-in-N) and default OFF in ``ObsSpec`` --
they do real work and are meant for benches and diagnosis windows.
"""
from __future__ import annotations

import time

import numpy as np

from .trace import sample_period

# |p_hat - p_true| bounds: estimates live in [0,1]; sub-0.001 error is noise
ERROR_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                 0.1, 0.25, 0.5, 1.0)


def innermost(backend):
    """Unwrap cache/decorator backends to the executing one."""
    target, inner = backend, getattr(backend, "inner", None)
    while inner is not None:
        target, inner = inner, getattr(inner, "inner", None)
    return target


def corpus_attrs(backend):
    """(ints, floats) attribute columns of the backend's base corpus, or
    None when the backend shape is unknown.  LocalBackend exposes them on
    its FavorIndex; ShardedBackend on its device-array dict."""
    target = innermost(backend)
    fi = getattr(target, "index", None)
    attrs = getattr(fi, "attrs", None)
    if attrs is not None:
        return np.asarray(attrs.ints), np.asarray(attrs.floats)
    sh = getattr(target, "sharded", None)
    arrays = getattr(sh, "arrays", None)
    if arrays is not None and "attrs_int" in arrays:
        return (np.asarray(arrays["attrs_int"]),
                np.asarray(arrays["attrs_float"]))
    return None


def true_fraction(backend, flt) -> float | None:
    """Exact corpus match fraction of ``flt`` (the estimator's ground
    truth), or None when the corpus attributes are unreachable/empty."""
    from ..core import filters as F  # lazy: keep obs import-light
    attrs = corpus_attrs(backend)
    if attrs is None or not len(attrs[0]):
        return None
    prog = F.compile_filter(flt, backend.schema)
    mask = np.asarray(F.eval_program(prog, attrs[0], attrs[1]))
    return float(mask.mean())


class EstimatorProbe:
    def __init__(self, spec, registry):
        self._period = sample_period(spec.probe_sample)
        self._seen = 0
        self._next_q = 0
        self._m_err = registry.histogram(
            "favor_estimator_abs_error",
            "|p_hat - true match fraction| on probed queries",
            buckets=ERROR_BUCKETS)
        self._m_probes = registry.counter(
            "favor_estimator_probes_total",
            "Estimator accuracy probes run, by route taken",
            labels=("route",))
        self._m_flips = registry.counter(
            "favor_estimator_route_flips_total",
            "Probes where truth and estimate disagree across lambda",
            labels=("route",))

    def maybe_probe(self, backend, flts, res) -> None:
        """Sampled: check one query of this batch against ground truth."""
        if not self._period:
            return
        self._seen += 1
        if (self._seen - 1) % self._period:
            return
        i = self._next_q % len(flts)
        self._next_q += 1
        p_true = true_fraction(backend, flts[i])
        if p_true is None:
            return
        p_hat = float(res.p_hat[i])
        route = "brute" if res.routed_brute[i] else "graph"
        self._m_err.observe(abs(p_hat - p_true))
        self._m_probes.inc(route=route)
        lam = float(backend.sel_cfg.lam)
        if (p_true < lam) != (p_hat < lam):
            self._m_flips.inc(route=route)

    def reset(self) -> None:
        self._seen = 0
        self._next_q = 0


class RouteConfusion:
    def __init__(self, spec, registry, time_fn=time.perf_counter):
        self._period = sample_period(spec.shadow_sample)
        self._seen = 0
        self._next_q = 0
        self._time = time_fn
        self._m_shadow = registry.counter(
            "favor_route_shadow_total",
            "Shadow executions, by (route chosen, route that was faster)",
            labels=("chosen", "faster"))
        self._m_regret = registry.counter(
            "favor_route_regret_seconds_total",
            "Wall time lost to queries routed onto the slower route "
            "(shadow-measured)")

    def maybe_shadow(self, backend, queries, flts, res, opts) -> None:
        """Sampled: run one query on both routes, record which was faster.

        Executes against the innermost backend so shadow traffic cannot
        pollute (or be served by) the cache layers; first-shadow timings can
        include a compile for a not-yet-warmed forced-route bucket, which
        sampling amortizes away."""
        if not self._period:
            return
        self._seen += 1
        if (self._seen - 1) % self._period:
            return
        from ..core import router  # lazy: avoid core<->obs import cycles
        i = self._next_q % len(flts)
        self._next_q += 1
        target = innermost(backend)
        q = np.asarray(queries[i:i + 1])
        times = {}
        for route in ("graph", "brute"):
            t0 = self._time()
            router.execute(target, q, [flts[i]], opts.with_(force=route))
            times[route] = self._time() - t0
        chosen = "brute" if res.routed_brute[i] else "graph"
        faster = min(times, key=times.get)
        self._m_shadow.inc(chosen=chosen, faster=faster)
        if faster != chosen:
            self._m_regret.inc(times[chosen] - times[faster])

    def reset(self) -> None:
        self._seen = 0
        self._next_q = 0
