"""Per-request route traces and the slow-query log.

``router.execute`` opens one ``RequestTrace`` per (sampled) batch and wraps
every pipeline stage -- compile/signature, cache lookup, estimate, route
decision, bucket/pad, graph/brute search, cache record -- in a ``span``,
recording wall time plus stage attributes (route, bucket shape, pad
fraction, cache hits).  Spans nest: the pad step inside a route sub-batch is
a child of that route's span, so traces read like the pipeline executes.

The ``Tracer`` keeps the last ``trace_cap`` traces in a ring buffer, feeds
every top-level span into a per-stage latency histogram on the registry, and
-- when a traced batch's wall time crosses ``slow_ms`` -- logs one
``SlowQuery`` entry per request (canonical filter signature, estimated
selectivity, route, ef, per-stage timings) into a second ring.  Sampling is
deterministic 1-in-N on the batch counter, so two runs over the same
workload trace the same batches.
"""
from __future__ import annotations

import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field


def sample_period(fraction: float) -> int:
    """1-in-N period for a [0,1] sampling fraction (0 disables)."""
    if fraction <= 0.0:
        return 0
    return max(1, int(round(1.0 / fraction)))


@dataclass
class Span:
    name: str
    t0: float
    t1: float | None = None
    attrs: dict = field(default_factory=dict)
    children: list = field(default_factory=list)

    @property
    def duration_s(self) -> float:
        return (self.t1 or self.t0) - self.t0

    def to_dict(self) -> dict:
        return {"name": self.name, "duration_ms": self.duration_s * 1e3,
                "attrs": dict(self.attrs),
                "children": [c.to_dict() for c in self.children]}


class RequestTrace:
    """Span tree for one engine batch through ``router.execute``."""

    def __init__(self, trace_id: int, batch: int, time_fn):
        self.trace_id = trace_id
        self.batch = batch
        self._time = time_fn
        self.t0 = time_fn()
        self.t1: float | None = None
        self.spans: list[Span] = []
        self.attrs: dict = {}
        self._stack: list[Span] = []

    @contextmanager
    def span(self, name: str, **attrs):
        sp = Span(name, self._time(), attrs=attrs)
        (self._stack[-1].children if self._stack else self.spans).append(sp)
        self._stack.append(sp)
        try:
            yield sp
        finally:
            sp.t1 = self._time()
            self._stack.pop()

    def finish(self) -> None:
        if self.t1 is None:
            self.t1 = self._time()

    @property
    def duration_s(self) -> float:
        return (self.t1 if self.t1 is not None else self._time()) - self.t0

    def stage_ms(self) -> dict:
        """Top-level stage name -> wall ms (duplicate names summed)."""
        out: dict[str, float] = {}
        for sp in self.spans:
            out[sp.name] = out.get(sp.name, 0.0) + sp.duration_s * 1e3
        return out

    def to_dict(self) -> dict:
        return {"trace_id": self.trace_id, "batch": self.batch,
                "duration_ms": self.duration_s * 1e3, "attrs": dict(self.attrs),
                "spans": [s.to_dict() for s in self.spans]}


@dataclass
class SlowQuery:
    """One slow-batch request in the ring: everything an operator needs to
    reproduce it (signature identifies the filter, route+ef the execution)."""
    trace_id: int
    signature: str
    p_hat: float
    route: str
    ef: int
    total_ms: float
    stages_ms: dict

    def to_dict(self) -> dict:
        return {"trace_id": self.trace_id, "signature": self.signature,
                "p_hat": self.p_hat, "route": self.route, "ef": self.ef,
                "total_ms": self.total_ms, "stages_ms": dict(self.stages_ms)}


class Tracer:
    def __init__(self, spec, registry, time_fn=time.perf_counter):
        self.spec = spec
        self._time = time_fn
        self.traces: deque[RequestTrace] = deque(maxlen=spec.trace_cap)
        self.slow_log: deque[SlowQuery] = deque(maxlen=spec.slow_cap)
        self._seq = 0
        self._period = sample_period(spec.trace_sample)
        self._m_traced = registry.counter(
            "favor_traces_total", "Engine batches traced (post-sampling)")
        self._m_slow = registry.counter(
            "favor_slow_queries_total",
            "Requests logged to the slow-query ring")
        self._m_stage = registry.histogram(
            "favor_stage_seconds",
            "Per-stage wall time inside router.execute", labels=("stage",),
            buckets=spec.latency_buckets)

    def start(self, batch: int) -> RequestTrace | None:
        """A RequestTrace for this batch, or None when sampled out."""
        self._seq += 1
        if not self._period or (self._seq - 1) % self._period:
            return None
        return RequestTrace(self._seq, batch, self._time)

    def finish(self, tr: RequestTrace, *, p_hat=None, routed_brute=None,
               signatures=None, ef: int = 0) -> None:
        """Close a trace: ring-buffer it, feed the stage histogram, and --
        when the batch crossed slow_ms -- log per-query slow entries.
        ``signatures`` is a zero-arg thunk (the canonical signature is only
        worth computing for slow batches)."""
        tr.finish()
        self.traces.append(tr)
        self._m_traced.inc()
        for sp in tr.spans:
            self._m_stage.observe(sp.duration_s, stage=sp.name)
        if self.spec.slow_ms is None:
            return
        total_ms = tr.duration_s * 1e3
        if total_ms < self.spec.slow_ms:
            return
        stages = tr.stage_ms()
        sigs = list(signatures()) if callable(signatures) else []
        for i in range(tr.batch):
            route = "unknown"
            if routed_brute is not None and i < len(routed_brute):
                route = "brute" if routed_brute[i] else "graph"
            ph = float(p_hat[i]) if p_hat is not None and i < len(p_hat) \
                else float("nan")
            sig = sigs[i] if i < len(sigs) else ""
            self.slow_log.append(SlowQuery(tr.trace_id, sig, ph, route,
                                           int(ef), total_ms, stages))
            self._m_slow.inc()

    def stats(self) -> dict:
        return {"traced": len(self.traces), "sampled_seq": self._seq,
                "slow": len(self.slow_log),
                "last_trace": (self.traces[-1].to_dict()
                               if self.traces else None)}

    def reset(self) -> None:
        self.traces.clear()
        self.slow_log.clear()
        self._seq = 0
