"""Unified observability layer for the FAVOR serving stack.

One ``Obs`` object per ``ServeEngine`` bundles the four pieces this package
provides behind a single ``ObsSpec`` (``core.options``):

  registry   -- MetricsRegistry: every counter/gauge/histogram plus the
                stats *views* (cache layers, ShapeRegistry ledger, frontend
                tenant ledgers), exported via ``snapshot()`` (JSON) and
                ``prometheus_text()``.  ``ServeEngine.stats`` is a thin
                read through it.
  tracer     -- per-request route traces through ``router.execute`` with a
                slow-query ring (``trace.py``).
  probes     -- estimator-accuracy + route-confusion probes (``probes.py``).
  profiling  -- gated ``jax.profiler.TraceAnnotation`` dispatch scopes
                (``profiling.py``); jitted kernels carry always-on
                ``jax.named_scope`` metadata independently.

``ObsSpec(enabled=False)`` degrades every per-request hook to a no-op while
keeping the registry live (stats still work); results are bit-identical
either way -- the obs layer observes, it never steers.
"""
from __future__ import annotations

import time
from contextlib import nullcontext

from . import profiling
from .probes import EstimatorProbe, RouteConfusion
from .registry import Counter, Gauge, Histogram, MetricsRegistry
from .trace import RequestTrace, SlowQuery, Span, Tracer

__all__ = ["Counter", "EstimatorProbe", "Gauge", "Histogram",
           "MetricsRegistry", "Obs", "RequestTrace", "RouteConfusion",
           "SlowQuery", "Span", "Tracer", "profiling"]


class Obs:
    """Facade owning one registry + tracer + probe set (module docstring).

    ``time_fn`` is the injected monotonic clock shared with the engine, so
    latency/deadline tests drive spans and histograms deterministically.
    """

    def __init__(self, spec=None, *, time_fn=time.perf_counter,
                 registry: MetricsRegistry | None = None):
        # lazy: core.options pulls in the whole core package; obs must stay
        # importable from anywhere (kernels, backends) without a cycle
        from ..core.options import ObsSpec
        if spec is None:
            spec = ObsSpec()
        if not isinstance(spec, ObsSpec):
            raise TypeError(f"Obs takes an ObsSpec, got {type(spec).__name__}")
        self.spec = spec
        self.time_fn = time_fn
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = (Tracer(spec, self.registry, time_fn)
                       if spec.enabled and spec.trace_sample > 0 else None)
        self.estimator_probe = (EstimatorProbe(spec, self.registry)
                                if spec.enabled and spec.probe_sample > 0
                                else None)
        self.route_confusion = (RouteConfusion(spec, self.registry, time_fn)
                                if spec.enabled and spec.shadow_sample > 0
                                else None)
        if spec.enabled and spec.kernel_annotations:
            profiling.set_kernel_annotations(True)
        self._annotate = spec.enabled and spec.kernel_annotations
        self.registry.on_reset(self._reset_components)

    @property
    def enabled(self) -> bool:
        return self.spec.enabled

    # -- tracing --------------------------------------------------------------
    def start_trace(self, batch: int) -> RequestTrace | None:
        if self.tracer is None:
            return None
        return self.tracer.start(batch)

    def finish_trace(self, tr: RequestTrace, **kw) -> None:
        if self.tracer is not None:
            self.tracer.finish(tr, **kw)

    # -- kernel dispatch annotation -------------------------------------------
    def annotate(self, name: str):
        """Host-side TraceAnnotation context (nullcontext unless the spec
        enables kernel annotations)."""
        if not self._annotate:
            return nullcontext()
        return profiling.annotate(name)

    # -- probes ---------------------------------------------------------------
    @property
    def wants_probe(self) -> bool:
        return (self.estimator_probe is not None
                or self.route_confusion is not None)

    def probe(self, backend, queries, flts, res, opts) -> None:
        """Run whichever sampled probes the spec enabled on this batch."""
        if self.estimator_probe is not None:
            self.estimator_probe.maybe_probe(backend, flts, res)
        if self.route_confusion is not None:
            self.route_confusion.maybe_shadow(backend, queries, flts, res,
                                              opts)

    # -- export ---------------------------------------------------------------
    def summary(self) -> dict:
        """The obs layer's own health corner of ``ServeEngine.stats``."""
        out = {"enabled": self.spec.enabled,
               "trace_sample": self.spec.trace_sample}
        if self.tracer is not None:
            st = self.tracer.stats()
            out["traces"] = st["traced"]
            out["slow_queries"] = st["slow"]
        return out

    def snapshot(self) -> dict:
        return self.registry.snapshot()

    def prometheus_text(self) -> str:
        return self.registry.prometheus_text()

    def reset(self) -> None:
        """Zero everything: instruments, ring buffers, and every legacy
        counter hooked onto the registry's reset cascade."""
        self.registry.reset()

    def _reset_components(self) -> None:
        for c in (self.tracer, self.estimator_probe, self.route_confusion):
            if c is not None:
                c.reset()
