"""Kernel profiling hooks: host-side trace annotations + HLO name scopes.

Two complementary mechanisms, matching how JAX profiling actually works:

  * ``annotate(name)`` -- a host-side ``jax.profiler.TraceAnnotation``
    context.  Wrapped around *dispatch sites* (the router's graph/brute
    sub-batch calls, scan dispatch), it brackets the host span that enqueues
    and waits on device work, so a ``jax.profiler.trace`` capture attributes
    device time to routes and bucket shapes.  Runtime-gated: it is a
    ``nullcontext`` unless ``set_kernel_annotations(True)`` ran (the ``Obs``
    facade flips it when ``ObsSpec.kernel_annotations`` is set), so the
    steady-state cost of the hook is one global read.

  * ``jax.named_scope(name)`` -- used directly *inside* jitted kernel
    wrappers (``pq_adc``, ``filtered_topk``, ``gather_distance``) and the
    graph-traversal wave body.  It runs at trace time only, stamping the
    scope name into HLO op metadata; compiled executables carry it for free,
    so it needs no gating and never perturbs results.
"""
from __future__ import annotations

from contextlib import nullcontext

import jax

_KERNEL_ANNOTATIONS = False


def set_kernel_annotations(on: bool) -> None:
    """Globally enable/disable host-side dispatch annotations."""
    global _KERNEL_ANNOTATIONS
    _KERNEL_ANNOTATIONS = bool(on)


def kernel_annotations_enabled() -> bool:
    return _KERNEL_ANNOTATIONS


def annotate(name: str):
    """A TraceAnnotation context for ``name`` (nullcontext when disabled or
    when the installed jax lacks the profiler API)."""
    if not _KERNEL_ANNOTATIONS:
        return nullcontext()
    try:
        return jax.profiler.TraceAnnotation(name)
    except Exception:  # pragma: no cover - profiler backend unavailable
        return nullcontext()
