"""Metrics registry: labeled counters, gauges and fixed-bucket histograms.

One ``MetricsRegistry`` per serving stack (``ServeEngine`` owns it through
``Obs``); every stats surface in the repo -- engine routing counters,
request-latency window, ShapeRegistry pad ledger, cache layer hit/miss,
mutation counters, frontend tenant ledgers -- either records into a typed
instrument here or registers a *view* (a zero-argument callable returning a
nested dict) so one ``snapshot()`` / ``prometheus_text()`` call exports the
whole system.

Design constraints, in order:

  * **Lock-cheap on the hot path.**  An ``inc``/``observe`` is a dict lookup
    plus a float add on a plain ``dict`` -- atomic under the GIL, so no lock
    is taken per sample.  The registry lock guards registration only (cold
    path, idempotent ``counter()``/``gauge()``/``histogram()`` lookups).
  * **Labels declared once.**  Each instrument fixes its label *names* at
    creation; a sample supplies the label *values* as kwargs and lands in
    its own series.  Mismatched label sets raise instead of silently
    creating junk series.
  * **Resets cascade.**  ``reset()`` zeroes every instrument, then runs the
    registered ``on_reset`` hooks -- the engine, frontend and cache layers
    hang their legacy-counter resets there, so one call zeroes the stack
    (the ``ServeEngine.reset_stats`` contract).
"""
from __future__ import annotations

import threading

# shared default: matches ObsSpec.latency_buckets (seconds)
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
                   0.05, 0.1, 0.25, 0.5, 1.0, 2.5)


def _fmt(v: float) -> str:
    """Prometheus sample value: integral floats print as integers."""
    f = float(v)
    return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


def _fmt_le(b: float) -> str:
    return "+Inf" if b == float("inf") else ("%g" % b)


def _label_str(names: tuple, values: tuple) -> str:
    return ",".join(f'{n}="{v}"' for n, v in zip(names, values))


class _Instrument:
    kind = "untyped"

    def __init__(self, name: str, help: str, labels=()):
        if not name or not name.replace("_", "a").isalnum():
            raise ValueError(f"bad metric name {name!r} (use "
                             "[a-zA-Z0-9_], prometheus-style)")
        self.name = name
        self.help = help
        self.labels = tuple(labels)
        self._series: dict[tuple, object] = {}

    def _key(self, labels: dict) -> tuple:
        if len(labels) != len(self.labels) or \
                any(k not in labels for k in self.labels):
            raise ValueError(f"{self.name} takes labels {self.labels}, "
                             f"got {tuple(labels)}")
        return tuple(str(labels[k]) for k in self.labels)

    def series(self) -> dict:
        """label-values tuple -> raw series state (copy)."""
        return dict(self._series)


class Counter(_Instrument):
    """Monotonically increasing float (resets only via registry.reset)."""
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(amount={amount})")
        key = self._key(labels)
        self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return float(self._series.get(self._key(labels), 0.0))

    def total(self) -> float:
        return float(sum(self._series.values()))

    def reset(self) -> None:
        for key in self._series:
            self._series[key] = 0.0


class Gauge(_Instrument):
    """Point-in-time value (set/add semantics)."""
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[self._key(labels)] = float(value)

    def add(self, amount: float, **labels) -> None:
        key = self._key(labels)
        self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return float(self._series.get(self._key(labels), 0.0))

    def reset(self) -> None:
        for key in self._series:
            self._series[key] = 0.0


class Histogram(_Instrument):
    """Fixed-bucket histogram: per-series cumulative-able counts + sum.

    ``buckets`` are inclusive upper bounds (prometheus ``le`` semantics);
    an implicit +Inf bucket catches the overflow.  ``observe_many`` takes a
    sequence and bins it in one numpy pass -- the engine uses it for
    per-batch p_hat distributions without a python loop per row.
    """
    kind = "histogram"

    def __init__(self, name, help, labels=(), buckets=DEFAULT_BUCKETS):
        super().__init__(name, help, labels)
        buckets = tuple(float(b) for b in buckets)
        if not buckets or any(a >= b for a, b in zip(buckets, buckets[1:])):
            raise ValueError(f"histogram {name} buckets must be strictly "
                             f"increasing, got {buckets}")
        self.buckets = buckets

    def _slot(self, key: tuple) -> list:
        s = self._series.get(key)
        if s is None:
            # [per-bucket counts (+Inf last), sum, count]
            s = self._series[key] = [[0] * (len(self.buckets) + 1), 0.0, 0]
        return s

    def observe(self, value: float, **labels) -> None:
        s = self._slot(self._key(labels))
        value = float(value)
        lo, hi = 0, len(self.buckets)
        while lo < hi:  # bisect_left over bounds: first bucket with le >= v
            mid = (lo + hi) // 2
            if self.buckets[mid] < value:
                lo = mid + 1
            else:
                hi = mid
        s[0][lo] += 1
        s[1] += value
        s[2] += 1

    def observe_many(self, values, **labels) -> None:
        import numpy as np
        values = np.asarray(values, np.float64).ravel()
        if not len(values):
            return
        s = self._slot(self._key(labels))
        idx = np.searchsorted(np.asarray(self.buckets), values, side="left")
        for i, c in zip(*np.unique(idx, return_counts=True)):
            s[0][int(i)] += int(c)
        s[1] += float(values.sum())
        s[2] += int(len(values))

    def count(self, **labels) -> int:
        s = self._series.get(self._key(labels))
        return int(s[2]) if s else 0

    def sum(self, **labels) -> float:
        s = self._series.get(self._key(labels))
        return float(s[1]) if s else 0.0

    def percentile(self, p: float, **labels) -> float | None:
        """Bucket-interpolated percentile estimate (None when empty)."""
        s = self._series.get(self._key(labels))
        if not s or not s[2]:
            return None
        target = s[2] * min(max(p / 100.0, 0.0), 1.0)
        cum, lo = 0, 0.0
        for i, c in enumerate(s[0]):
            if cum + c >= target and c:
                hi = (self.buckets[i] if i < len(self.buckets)
                      else self.buckets[-1])
                frac = (target - cum) / c
                return lo + (hi - lo) * frac
            cum += c
            if i < len(self.buckets):
                lo = self.buckets[i]
        return self.buckets[-1]

    def reset(self) -> None:
        for key in self._series:
            self._series[key] = [[0] * (len(self.buckets) + 1), 0.0, 0]


class MetricsRegistry:
    """Instrument + view + reset-hook registry (module docstring)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, _Instrument] = {}
        self._views: dict[str, object] = {}
        self._reset_hooks: list = []

    # -- registration (idempotent: same name returns the same instrument) ----
    def _get(self, cls, name, help, labels, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if type(m) is not cls or m.labels != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{type(m).__name__}{m.labels}, cannot re-register "
                        f"as {cls.__name__}{tuple(labels)}")
                return m
            m = cls(name, help, labels, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name, help="", labels=()) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name, help="", labels=()) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name, help="", labels=(),
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def register_view(self, name: str, fn) -> None:
        """Attach a zero-arg callable whose nested-dict result joins every
        snapshot/exposition (last registration under a name wins -- e.g. a
        rebuilt frontend re-binding its ledger view)."""
        with self._lock:
            self._views[name] = fn

    def has_view(self, name: str) -> bool:
        return name in self._views

    def view(self, name: str) -> dict:
        return self._views[name]()

    def on_reset(self, fn) -> None:
        """Run ``fn`` on every ``reset()`` -- the cascade hook legacy
        counters (latency deques, tenant ledgers, cache layers) hang on."""
        with self._lock:
            self._reset_hooks.append(fn)

    # -- lifecycle -----------------------------------------------------------
    def reset(self) -> None:
        for m in self._metrics.values():
            m.reset()
        for fn in list(self._reset_hooks):
            fn()

    # -- export --------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able export of every instrument and view."""
        out = {"counters": {}, "gauges": {}, "histograms": {}, "views": {}}
        for name, m in self._metrics.items():
            if isinstance(m, Histogram):
                series = {}
                for key, s in m.series().items():
                    cum, buckets = 0, []
                    for le, c in zip(m.buckets + (float("inf"),), s[0]):
                        cum += c
                        buckets.append([_fmt_le(le), cum])
                    series[_label_str(m.labels, key)] = {
                        "buckets": buckets, "sum": s[1], "count": s[2]}
                out["histograms"][name] = {"help": m.help, "series": series}
            else:
                slot = "counters" if isinstance(m, Counter) else "gauges"
                out[slot][name] = {
                    "help": m.help,
                    "series": {_label_str(m.labels, k): v
                               for k, v in m.series().items()}}
        for name, fn in self._views.items():
            out["views"][name] = fn()
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format, views flattened into one
        ``favor_view`` gauge family labeled (view, path)."""
        lines = []
        for name, m in self._metrics.items():
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                for key, s in sorted(m.series().items()):
                    base = _label_str(m.labels, key)
                    sep = "," if base else ""
                    cum = 0
                    for le, c in zip(m.buckets + (float("inf"),), s[0]):
                        cum += c
                        lines.append(f'{name}_bucket{{{base}{sep}le='
                                     f'"{_fmt_le(le)}"}} {cum}')
                    suffix = f"{{{base}}}" if base else ""
                    lines.append(f"{name}_sum{suffix} {_fmt(s[1])}")
                    lines.append(f"{name}_count{suffix} {s[2]}")
            else:
                for key, v in sorted(m.series().items()):
                    base = _label_str(m.labels, key)
                    suffix = f"{{{base}}}" if base else ""
                    lines.append(f"{name}{suffix} {_fmt(v)}")
        if self._views:
            lines.append("# HELP favor_view Flattened numeric leaves of "
                         "registered stats views")
            lines.append("# TYPE favor_view gauge")
            for vname in sorted(self._views):
                for path, v in _flatten(self._views[vname]()):
                    lines.append(f'favor_view{{view="{vname}",'
                                 f'path="{path}"}} {_fmt(v)}')
        return "\n".join(lines) + "\n"


def _flatten(d, prefix=""):
    """Numeric leaves of a nested dict as (dot.path, value) pairs."""
    out = []
    if not isinstance(d, dict):
        return out
    for k in sorted(d, key=str):
        v = d[k]
        path = f"{prefix}{k}"
        if isinstance(v, dict):
            out.extend(_flatten(v, path + "."))
        elif isinstance(v, bool):
            out.append((path, 1.0 if v else 0.0))
        elif isinstance(v, (int, float)):
            out.append((path, float(v)))
    return out
