import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first init)

import argparse
import json
import time
import traceback

import jax

from repro.configs import all_specs, get_spec
from repro.launch import cells as C
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis as RA


def _lower_compile(cell, mesh):
    if cell.in_shardings is not None:
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings)
    else:  # shard_map cells carry their own specs
        jitted = cell.step_fn
    t0 = time.perf_counter()
    with mesh:
        lowered = jitted.lower(*cell.args)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    return lowered, compiled, t_lower, time.perf_counter() - t0


def _cost_of(compiled, n_dev):
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = RA.parse_collectives(text, n_dev)
    return (float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)),
            coll.link_bytes, coll.counts, coll.by_op)


def run_cell(arch: str, shape: str, multi_pod: bool, *, builder=None,
             probe: bool = True, probe_builder=None) -> dict:
    """Lower + compile one (arch x shape x mesh) cell; return the record.

    Full-depth compile (layers under scan) -> memory_analysis (exact buffer
    sizing).  Cost terms come from the (2, 4)-depth unrolled probes
    extrapolated to full depth (see cells.probe_depths) because HLO cost
    analysis counts while bodies once.
    """
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = len(mesh.devices.reshape(-1))
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16", "ok": False}
    try:
        cell = (builder or C.build_cell)(arch, shape, mesh)
        lowered, compiled, rec["lower_s"], rec["compile_s"] = \
            _lower_compile(cell, mesh)
        rec["memory"] = RA.memory_analysis_dict(compiled)

        depths = C.probe_depths(arch) if probe else None
        if depths is not None:
            axis, l1, l2, lf = depths
            pb = probe_builder or C.build_probe_cell
            t0 = time.perf_counter()
            c1 = _cost_of(_lower_compile(
                pb(arch, shape, mesh, l1), mesh)[1], n_dev)
            c2 = _cost_of(_lower_compile(
                pb(arch, shape, mesh, l2), mesh)[1], n_dev)
            rec["probe_s"] = time.perf_counter() - t0
            r = (lf - l1) / (l2 - l1)
            flops = c1[0] + r * (c2[0] - c1[0])
            byts = c1[1] + r * (c2[1] - c1[1])
            link = c1[2] + r * (c2[2] - c1[2])
            counts = {k: int(round(c1[3].get(k, 0) +
                                   r * (c2[3].get(k, 0) - c1[3].get(k, 0))))
                      for k in set(c1[3]) | set(c2[3])}
            by_op = {k: c1[4].get(k, 0.0) + r * (c2[4].get(k, 0.0) -
                                                 c1[4].get(k, 0.0))
                     for k in set(c1[4]) | set(c2[4])}
            rec["probe"] = {"axis": axis, "depths": [l1, l2], "full": lf,
                            "probe_flops": [c1[0], c2[0]]}
        else:
            flops, byts, link, counts, by_op = _cost_of(compiled, n_dev)

        roof = RA.Roofline(flops=flops, hbm_bytes=byts, coll_link_bytes=link,
                           n_devices=n_dev,
                           collectives={"counts": counts, "by_op": by_op},
                           model_flops=cell.model_flops)
        rec["roofline"] = roof.to_dict()
        rec["note"] = cell.note
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--skip-favor", action="store_true")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    todo = []
    for arch, spec in all_specs(include_favor=not args.skip_favor).items():
        if args.arch and arch != args.arch:
            continue
        for cell in spec.cells:
            if args.shape and cell.name != args.shape:
                continue
            todo.append((arch, cell.name, cell.skip))

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results if r.get("ok")}

    for arch, shape, skip in todo:
        for multi in meshes:
            mesh_name = "2x16x16" if multi else "16x16"
            if (arch, shape, mesh_name) in done:
                print(f"[skip-done] {arch} x {shape} x {mesh_name}")
                continue
            if skip:
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "ok": True, "skipped": skip}
                print(f"[SKIP] {arch} x {shape}: {skip}")
            else:
                print(f"[run ] {arch} x {shape} x {mesh_name} ...", flush=True)
                rec = run_cell(arch, shape, multi)
                if rec["ok"]:
                    r = rec["roofline"]
                    print(f"   ok lower={rec['lower_s']:.1f}s "
                          f"compile={rec['compile_s']:.1f}s "
                          f"bottleneck={r['bottleneck']} "
                          f"tc={r['t_compute_s']:.4f} tm={r['t_memory_s']:.4f} "
                          f"tx={r['t_collective_s']:.4f} "
                          f"roofline_frac={r['roofline_frac']:.3f}", flush=True)
                else:
                    print(f"   FAIL {rec['error']}", flush=True)
            results = [r for r in results
                       if (r["arch"], r["shape"], r["mesh"]) !=
                       (arch, shape, mesh_name)]
            results.append(rec)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells ok -> {args.out}")


if __name__ == "__main__":
    main()
