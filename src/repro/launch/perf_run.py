import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init)

import argparse
import json
import time

from repro.launch import dryrun as D
from repro.launch import perf as P


EXPERIMENTS = {
    # --- hillclimb 1: gemma2-2b train_4k (worst meaningful roofline frac) ---
    "gemma_chunked": dict(
        arch="gemma2-2b", shape="train_4k",
        mk=lambda: P.lm_variant("gemma2-2b", "train_4k", attn_chunk=512),
        probe=True,
        hypothesis="flash-style chunked attention removes the O(S^2) f32 "
                   "score tensors: memory term (dominant) drops; flops ~same"),
    "gemma_chunked_mb8": dict(
        arch="gemma2-2b", shape="train_4k",
        mk=lambda: P.lm_variant("gemma2-2b", "train_4k", attn_chunk=512,
                                microbatches=8),
        probe=False,  # mb scan hides per-layer cost; memory_analysis is the metric
        hypothesis="8x microbatch accumulation cuts live activation memory "
                   "~8x (memory_analysis temp bytes), roofline terms ~flat"),
    "gemma_prefill_chunked": dict(
        arch="gemma2-2b", shape="prefill_32k",
        mk=lambda: P.lm_variant("gemma2-2b", "prefill_32k", attn_chunk=2048),
        probe=True,
        hypothesis="train_4k refuted the chunked-attention memory win (scores "
                   "were minor there); at S=32k the (2,4,2,32k,32k) f32 score "
                   "tensors ARE the temp memory (34GB/layer): chunking should "
                   "collapse temp bytes and the HLO memory term"),
    "olmoe_cf10": dict(
        arch="olmoe-1b-7b", shape="train_4k",
        mk=lambda: P.lm_variant("olmoe-1b-7b", "train_4k",
                                capacity_factor=1.0),
        probe=True,
        hypothesis="(post-parser-fix: olmoe train is the most collective-"
                   "bound LM cell, tx=13.2s from dispatch all-gathers). "
                   "Capacity 1.25->1.0 shrinks the (E,C,d) expert buffers "
                   "and GEMMs 20%: tc/tm down ~10-20%; tx ~flat (the token "
                   "all-gather is capacity-independent) -- confirming the "
                   "a2a dispatch rewrite, not capacity, is the tx lever"),
    # --- hillclimb 2: gcn ogb_products (most collective-bound) --------------
    "gcn_bf16": dict(
        arch="gcn-cora", shape="ogb_products",
        mk=lambda: P.gnn_variant("gcn-cora", "ogb_products", bf16_msgs=True),
        probe=False,
        hypothesis="bf16 message features halve the edge-psum all-reduce "
                   "bytes: collective term (dominant) ~2x down"),
    "gcn_bf16_prune": dict(
        arch="gcn-cora", shape="ogb_products",
        mk=lambda: P.gnn_variant("gcn-cora", "ogb_products", bf16_msgs=True,
                                 label_prune=0.08),
        probe=False,
        hypothesis="final conv aggregates only edges into the ~8% labeled "
                   "nodes: the widest (n x 47) all-reduce shrinks ~12x; "
                   "combined with bf16 expect >4x total collective win"),
    # --- hillclimb 3: favor-anns serve_graph (paper's own technique) --------
    "favor_sample4k": dict(
        arch="favor-anns", shape="serve_graph",
        mk=lambda: P.favor_variant("favor-anns", "serve_graph",
                                   sample_rate=0.001),
        probe=False,
        hypothesis="selectivity sample 1% -> 0.1% of shard rows (global n "
                    "~64k, rel-err ~4% at p=1%, Eq. 1): the batched "
                    "filter-program eval over the sample shrinks 10x; if the "
                    "memory term drops materially, estimation was the hog"),
    "favor_ccap256": dict(
        arch="favor-anns", shape="serve_graph",
        mk=lambda: P.favor_variant("favor-anns", "serve_graph",
                                   sample_rate=0.001, cand_cap=256),
        probe=False,
        hypothesis="wider candidate pool (256 vs ef=128) raises per-step "
                   "merge traffic but should be minor vs visited/sample"),
    # diagnostic: if tm scales with the DB shard size, the memory term is an
    # HloCostAnalysis artifact (gathers charged the FULL operand) rather than
    # real per-step traffic
    "favor_n16m": dict(
        arch="favor-anns", shape="serve_graph",
        mk=lambda: P.favor_variant("favor-anns", "serve_graph", n=16_000_000),
        probe=False,
        hypothesis="shrink the DB 4x: if t_memory drops ~4x the term is "
                   "dominated by whole-DB-array charges on gathers (cost-"
                   "model artifact), not by batch/step-proportional traffic"),
    "gcn_bf16_v2": dict(
        arch="gcn-cora", shape="ogb_products",
        mk=lambda: P.gnn_variant("gcn-cora", "ogb_products", bf16_msgs=True,
                                 bf16_end2end=True, label_prune=0.08),
        probe=False,
        hypothesis="v1 refuted: the f32 convert sat between scatter and "
                   "all-reduce so XLA hoisted it. Keep hidden features bf16 "
                   "through relu/matmul so the collective must carry bf16: "
                   "expect ~2x on the remaining collective bytes"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default=None, help="experiment name or 'all'")
    ap.add_argument("--out", default="perf_results.json")
    ap.add_argument("--multi", action="store_true")
    args = ap.parse_args()

    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))

    todo = ([args.exp] if args.exp and args.exp != "all" else list(EXPERIMENTS))
    for name in todo:
        e = EXPERIMENTS[name]
        if any(r["exp"] == name for r in results):
            print(f"[skip-done] {name}")
            continue
        print(f"[perf] {name}: {e['hypothesis'][:70]} ...", flush=True)
        build, probe_build = e["mk"]()
        t0 = time.perf_counter()
        rec = D.run_cell(e["arch"], e["shape"], args.multi, builder=build,
                         probe=e["probe"], probe_builder=probe_build)
        rec["exp"] = name
        rec["hypothesis"] = e["hypothesis"]
        rec["wall_s"] = time.perf_counter() - t0
        if rec["ok"]:
            r = rec["roofline"]
            print(f"   ok tc={r['t_compute_s']:.4f} tm={r['t_memory_s']:.4f} "
                  f"tx={r['t_collective_s']:.4f} bottleneck={r['bottleneck']} "
                  f"mem={rec['memory'].get('temp_size_in_bytes', 0)/2**30:.1f}GiB",
                  flush=True)
        else:
            print(f"   FAIL {rec['error']}", flush=True)
        results.append(rec)
        json.dump(results, open(args.out, "w"), indent=1)


if __name__ == "__main__":
    main()
