"""Dry-run cell builders: for every (architecture x shape) cell produce
(step_fn, example_args as ShapeDtypeStructs, in_shardings, model_flops).

Nothing here allocates device memory -- parameters come from
``jax.eval_shape`` over the init functions and inputs are ShapeDtypeStructs;
``dryrun.py`` lowers + compiles each cell on the production mesh.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_spec
from ..configs.base import ArchSpec, ShapeCell
from ..models import gnn, recsys
from ..models.module import Ctx, logical_to_sharding
from ..models.transformer import (LMConfig, decode_step, init_lm, lm_loss,
                                  make_cache_specs, prefill)
from ..training import optimizer as opt
from ..training.step import make_train_step
from .mesh import batch_axes

F32, BF16, I32 = jnp.float32, jnp.bfloat16, jnp.int32
SDS = jax.ShapeDtypeStruct


@dataclass
class Cell:
    arch: str
    shape: str
    step_fn: object          # callable to jit
    args: tuple              # ShapeDtypeStructs (pytrees)
    in_shardings: tuple
    model_flops: float
    note: str = ""
    donate: tuple = ()


def _mesh_axis_size(mesh, name: str) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get(name, 1)


def _repl(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def _eval_init(init_fn, cfg, dtype):
    axes_box = {}

    def initfn(key):
        ctx = Ctx(key, dtype=dtype)
        init_fn(ctx, cfg)
        axes_box.clear()
        axes_box.update(ctx.axes)
        return ctx.params

    params_sds = jax.eval_shape(initfn, jax.random.key(0))
    return params_sds, dict(axes_box)


def _opt_sds(params_sds):
    f32 = lambda p: SDS(p.shape, jnp.float32)
    return opt.OptState(step=SDS((), jnp.int32),
                        mu=jax.tree.map(f32, params_sds),
                        nu=jax.tree.map(f32, params_sds))


def _opt_shardings(param_sh, mesh):
    return opt.OptState(step=NamedSharding(mesh, P()),
                        mu=param_sh, nu=param_sh)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------
def _lm_rules(cfg: LMConfig, mesh) -> dict:
    model = _mesh_axis_size(mesh, "model")
    rules = {}
    if cfg.n_kv % model == 0 and cfg.n_kv >= model:
        rules["kv_heads"] = "model"
    if cfg.n_heads % model:
        rules["heads"] = None
    return rules


def build_lm_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    cfg: LMConfig = spec.config
    dtype = BF16 if cfg.param_dtype == "bfloat16" else F32
    params_sds, axes = _eval_init(init_lm, cfg, dtype)
    rules = _lm_rules(cfg, mesh)
    param_sh = logical_to_sharding(axes, mesh, rules)
    b = cell.meta["batch"]
    s = cell.meta["seq"]
    bax = batch_axes(b, mesh)
    bspec = P(bax if len(bax) != 1 else bax[0]) if bax else P()

    if cell.kind == "train":
        ocfg = opt.OptConfig(total_steps=10000)

        def loss_fn(p, batch):
            return lm_loss(p, cfg, batch["tokens"], batch["labels"], mesh)

        step = make_train_step(loss_fn, ocfg)
        batch_sds = {"tokens": SDS((b, s), I32), "labels": SDS((b, s), I32)}
        bsh = {k: NamedSharding(mesh, P(*(bspec + P(None))))
               for k in batch_sds}
        args = (params_sds, _opt_sds(params_sds), batch_sds)
        shard = (param_sh, _opt_shardings(param_sh, mesh), bsh)
        mf = 6.0 * cfg.active_param_count() * b * s
        return Cell(spec.arch_id, cell.name, step, args, shard, mf,
                    donate=(0, 1))

    if cell.kind == "prefill":
        def step(p, tokens):
            return prefill(p, cfg, tokens, s, mesh)
        tok_sds = SDS((b, s), I32)
        tsh = NamedSharding(mesh, P(*(bspec + P(None))))
        mf = 2.0 * cfg.active_param_count() * b * s
        return Cell(spec.arch_id, cell.name, step, (params_sds, tok_sds),
                    (param_sh, tsh), mf)

    # decode: one new token against a seq-long cache
    model = _mesh_axis_size(mesh, "model")
    kv_on_model = cfg.n_kv % model == 0 and cfg.n_kv >= model
    seq_ax = None if kv_on_model else "model"
    # cache layout: (layers, batch, seq, kv, hd); when kv heads don't divide
    # the model axis the cache shards on SEQ instead (split-KV decode; GSPMD
    # inserts the partial-softmax reductions)
    cache_spec = P(None,
                   bax if len(bax) > 1 else (bax[0] if bax else None),
                   seq_ax,
                   "model" if kv_on_model else None,
                   None)
    if not bax and seq_ax == "model":
        # batch=1 long-context: spread the cache over data + model
        cache_spec = P(None, None, ("data", "model"), None, None)

    cache_sds = make_cache_specs(cfg, b, s)
    cache_sh = {k: NamedSharding(mesh, cache_spec) for k in cache_sds}

    def step(p, token, caches):
        return decode_step(p, cfg, token, caches, jnp.asarray(s - 1, I32), mesh)

    tok_sds = SDS((b, 1), I32)
    tsh = NamedSharding(mesh, P(bax if len(bax) > 1 else (bax[0] if bax else None), None))
    mf = 2.0 * cfg.active_param_count() * b
    return Cell(spec.arch_id, cell.name, step, (params_sds, tok_sds, cache_sds),
                (param_sh, tsh, cache_sh), mf, donate=(2,))


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------
_GNN_CLASSES = {"full_graph_sm": 7, "minibatch_lg": 41, "ogb_products": 47,
                "molecule": 2}


def _gnn_flops(cfg, n, e, b_graphs=0) -> float:
    f = 0.0
    for din, dout in cfg.dims():
        f += 2.0 * n * din * dout + 4.0 * e * dout
    return 3.0 * f  # fwd + bwd


def build_gnn_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    meta = cell.meta
    n_classes = _GNN_CLASSES[cell.name]
    cfg = dataclasses.replace(spec.config, d_feat=meta["d_feat"],
                              n_classes=n_classes,
                              readout="graph" if meta.get("graphs") else "node")
    params_sds, axes = _eval_init(gnn.init_gcn, cfg, F32)
    param_sh = logical_to_sharding(axes, mesh, {"hidden": None, "feat": None})

    n_dev = int(np.prod(mesh.devices.shape))
    all_ax = tuple(mesh.axis_names)

    if meta.get("sampled"):
        from ..data.graphs import minibatch_shapes
        sh = minibatch_shapes(meta["batch_nodes"], meta["fanout"], meta["d_feat"])
        n, e = sh["n"], sh["e"]
    elif meta.get("graphs"):
        bg = meta["batch"]
        n = bg * meta["n_nodes"]
        e = bg * (2 * meta["n_edges"] + meta["n_nodes"])
    else:
        n, e = meta["n_nodes"], 2 * meta["n_edges"] + meta["n_nodes"]
    e_pad = -(-e // n_dev) * n_dev

    batch_sds = {
        "x": SDS((n, cfg.d_feat), F32),
        "edges": SDS((2, e_pad), I32),
        "deg": SDS((n,), F32),
        "labels": SDS((n if not meta.get("graphs") else meta["batch"],), I32),
        "mask": SDS((n if not meta.get("graphs") else meta["batch"],), jnp.bool_),
    }
    bsh = {
        "x": NamedSharding(mesh, P()),
        "edges": NamedSharding(mesh, P(None, all_ax)),
        "deg": NamedSharding(mesh, P()),
        "labels": NamedSharding(mesh, P()),
        "mask": NamedSharding(mesh, P()),
    }
    if meta.get("graphs"):
        batch_sds["graph_ids"] = SDS((n,), I32)
        bsh["graph_ids"] = NamedSharding(mesh, P())
    ocfg = opt.OptConfig(total_steps=1000)

    n_graphs = meta.get("batch", 0)

    def loss_fn(p, batch):
        return gnn.gcn_loss(p, cfg, batch["x"], batch["edges"], batch["deg"],
                            batch["labels"], batch["mask"],
                            graph_ids=batch.get("graph_ids"),
                            n_graphs=n_graphs)

    step = make_train_step(loss_fn, ocfg)
    args = (params_sds, _opt_sds(params_sds), batch_sds)
    shard = (param_sh, _opt_shardings(param_sh, mesh), bsh)
    return Cell(spec.arch_id, cell.name, step, args, shard,
                _gnn_flops(cfg, n, e), donate=(0, 1))


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------
def _rs_mlp_params(cfg) -> int:
    total = 0
    if hasattr(cfg, "mlp") and hasattr(cfg, "n_sparse"):  # wide&deep
        dims = [cfg.n_sparse * cfg.embed_dim, *cfg.mlp, 1]
        total += sum(a * b for a, b in zip(dims[:-1], dims[1:]))
    if hasattr(cfg, "bot_mlp"):
        dims = [cfg.n_dense, *cfg.bot_mlp]
        total += sum(a * b for a, b in zip(dims[:-1], dims[1:]))
        nv = cfg.n_sparse + 1
        dint = nv * (nv - 1) // 2 + cfg.embed_dim
        dims = [dint, *cfg.top_mlp, 1]
        total += sum(a * b for a, b in zip(dims[:-1], dims[1:]))
    if hasattr(cfg, "gru_dim"):
        total += 2 * 3 * (cfg.embed_dim + cfg.gru_dim) * cfg.gru_dim * cfg.seq_len
        dims = [cfg.gru_dim + cfg.embed_dim, *cfg.mlp, 1]
        total += sum(a * b for a, b in zip(dims[:-1], dims[1:]))
    if type(cfg).__name__ == "FMConfig":
        total += 3 * cfg.n_sparse * cfg.embed_dim
    return max(total, 1)


_RS_DEFS = {
    "fm": (recsys.init_fm, recsys.fm_loss, recsys.fm_forward),
    "wide-deep": (recsys.init_wide_deep, recsys.wide_deep_loss,
                  recsys.wide_deep_forward),
    "dien": (recsys.init_dien, recsys.dien_loss, recsys.dien_forward),
    "dlrm-rm2": (recsys.init_dlrm, recsys.dlrm_loss, recsys.dlrm_forward),
}


def _rs_batch_sds(arch, cfg, b):
    out = {}
    if arch == "dien":
        out["hist"] = SDS((b, cfg.seq_len), I32)
        out["target"] = SDS((b,), I32)
    else:
        out["ids"] = SDS((b, cfg.n_sparse), I32)
        if arch == "dlrm-rm2":
            out["dense"] = SDS((b, cfg.n_dense), F32)
    out["labels"] = SDS((b,), F32)
    return out


def _rs_loss_args(arch, cfg, loss, p, batch):
    if arch == "dien":
        return loss(p, cfg, batch["hist"], batch["target"], batch["labels"])
    if arch == "dlrm-rm2":
        return loss(p, cfg, batch["dense"], batch["ids"], batch["labels"])
    return loss(p, cfg, batch["ids"], batch["labels"])


def build_recsys_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    arch = spec.arch_id
    cfg = spec.config
    init_fn, loss_fn_, fwd_fn = _RS_DEFS[arch]
    # row (vocab) sharding: uniform across archs -- field counts (26/39/40/1)
    # don't divide the 16-way model axis, vocab (1e6) does.  Table-wise
    # sharding is the shard_map alternative evaluated in section Perf.
    rules = {"fields": None, "table": "model",
             # recsys MLPs are small (<=1024 hidden, odd dims incl. the final
             # scalar head) -- replicate them; batch parallelism dominates
             "mlp": None, "feat": None, "hidden": None}
    params_sds, axes = _eval_init(init_fn, cfg, F32)
    param_sh = logical_to_sharding(axes, mesh, rules)

    if cell.kind == "retrieval":
        # FAVOR as the retrieval layer: user vec x 1e6 candidates + filter
        nc = cell.meta["n_candidates"]
        d = cfg.embed_dim
        items_sds = SDS((nc, d), F32)
        user_sds = SDS((cell.meta["batch"], d), F32)
        ai = SDS((nc, 2), I32)
        af = SDS((nc, 1), F32)
        progs = {"valid": SDS((1, 8), F32), "imask": SDS((1, 8, 2), jnp.uint32),
                 "flo": SDS((1, 8, 1), F32), "fhi": SDS((1, 8, 1), F32)}

        def step(user, items, programs, attrs_int, attrs_float):
            return recsys.retrieval_topk_filtered(
                user, items, programs, attrs_int, attrs_float, k=100)

        row = NamedSharding(mesh, P("model", None))
        shard = (NamedSharding(mesh, P()), row, _repl(mesh, progs), row, row)
        mf = 2.0 * nc * d * cell.meta["batch"]
        return Cell(arch, cell.name, step,
                    (user_sds, items_sds, progs, ai, af), shard, mf,
                    note="FAVOR PreFBF path as retrieval layer")

    b = cell.meta["batch"]
    bax = batch_axes(b, mesh)
    bspec = bax if len(bax) > 1 else (bax[0] if bax else None)
    batch_sds = _rs_batch_sds(arch, cfg, b)
    bsh = {k: NamedSharding(mesh, P(*([bspec] + [None] * (len(v.shape) - 1))))
           for k, v in batch_sds.items()}

    if cell.kind == "train":
        ocfg = opt.OptConfig(total_steps=10000)

        def lf(p, batch):
            return _rs_loss_args(arch, cfg, loss_fn_, p, batch)

        step = make_train_step(lf, ocfg)
        args = (params_sds, _opt_sds(params_sds), batch_sds)
        shard = (param_sh, _opt_shardings(param_sh, mesh), bsh)
        mf = 6.0 * _rs_mlp_params(cfg) * b
        return Cell(arch, cell.name, step, args, shard, mf, donate=(0, 1))

    # serve
    def step(p, batch):
        if arch == "dien":
            return fwd_fn(p, cfg, batch["hist"], batch["target"])
        if arch == "dlrm-rm2":
            return fwd_fn(p, cfg, batch["dense"], batch["ids"])
        return fwd_fn(p, cfg, batch["ids"])

    mf = 2.0 * _rs_mlp_params(cfg) * b
    return Cell(arch, cell.name, step, (params_sds, batch_sds),
                (param_sh, bsh), mf)


# ---------------------------------------------------------------------------
# FAVOR serve cells (the paper's own system)
# ---------------------------------------------------------------------------
def build_favor_cell(spec: ArchSpec, cell: ShapeCell, mesh) -> Cell:
    from ..core import distributed as dist
    from ..core.search import SearchConfig
    cfg = spec.config
    model = _mesh_axis_size(mesh, "model")
    qax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    specs = dist.input_specs(cfg.n, cfg.dim, cfg.m_i, cfg.m_f, model,
                             m0=cfg.m0, m=cfg.m, n_upper=cfg.n_upper,
                             width=cfg.width, batch=cfg.batch)
    scfg = SearchConfig(k=cfg.k, ef=cfg.ef)
    fns = dist.make_serve_fns(mesh, scfg, query_axes=qax)
    route = cell.meta["route"]
    fn = fns["serve_graph"] if route == "graph" else fns["serve_brute"]
    if route == "graph":
        # estimated expansion work: ~4*ef hops x M0 neighbors x 2d flops
        mf = cfg.batch * 4.0 * cfg.ef * cfg.m0 * 2.0 * cfg.dim
    else:
        mf = cfg.batch * cfg.n * 2.0 * cfg.dim
    return Cell("favor-anns", cell.name, fn,
                (specs["db"], specs["queries"], specs["programs"],
                 specs["valid"]),
                None, mf, note=f"paper serve step ({route} route)")


BUILDERS = {"lm": build_lm_cell, "gnn": build_gnn_cell,
            "recsys": build_recsys_cell, "favor": build_favor_cell}


def build_cell(arch: str, shape: str, mesh) -> Cell:
    spec = get_spec(arch)
    cell = spec.cell(shape)
    if cell.skip:
        raise ValueError(f"cell skipped: {cell.skip}")
    return BUILDERS[spec.family](spec, cell, mesh)


def probe_depths(arch: str) -> tuple | None:
    """Cost-extrapolation probes (DESIGN.md section Roofline methodology).

    HLO cost analysis counts a while (scan) body ONCE, so the full-depth
    scanned compile under-reports flops/bytes/collectives by ~L.  Instead we
    compile two small *unrolled* probes and extrapolate linearly:

        cost(L) = cost(L1) + (L - L1)/(L2 - L1) * (cost(L2) - cost(L1))

    with (L1, L2) = (2, 4) so the delta covers one local/global layer PAIR
    (gemma2 alternation) and any residual per-program constant (embedding,
    logits, loss, optimizer) is kept exactly once.  DIEN probes its GRU
    sequence length the same way.  Memory analysis still comes from the
    full-depth scanned compile (buffers are sized correctly there).
    """
    spec = get_spec(arch)
    if spec.family == "lm":
        return ("n_layers", 2, 4, spec.config.n_layers)
    if arch == "dien":
        return ("seq_len", 2, 4, spec.config.seq_len)
    return None


def build_probe_cell(arch: str, shape: str, mesh, depth: int) -> Cell:
    spec = get_spec(arch)
    cell = spec.cell(shape)
    if spec.family == "lm":
        cfg = dataclasses.replace(spec.config, n_layers=depth,
                                  unroll_layers=True)
    else:  # dien
        cfg = dataclasses.replace(spec.config, seq_len=depth, unroll=True)
    spec2 = dataclasses.replace(spec, config=cfg)
    return BUILDERS[spec.family](spec2, cell, mesh)


def all_cells(include_favor: bool = True):
    from ..configs import all_specs
    out = []
    for arch, spec in all_specs(include_favor).items():
        for cell in spec.cells:
            out.append((arch, cell.name, cell.skip))
    return out
