"""Production mesh definition (function, not module constant: importing this
module must never touch jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for multi-device CPU tests (8 fake devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(batch: int, mesh) -> tuple:
    """Greedy batch-dim sharding: use pod/data axes whose sizes divide B."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    rem = batch
    for name in ("pod", "data"):
        if name in sizes and rem % sizes[name] == 0:
            out.append(name)
            rem //= sizes[name]
    return tuple(out)
