# NOTE: deliberately empty -- launch/dryrun.py must set XLA_FLAGS before any
# jax import, so this package must not import jax at import time.
