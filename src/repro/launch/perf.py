"""Perf hillclimbing variants (EXPERIMENTS.md section Perf).

Each variant is a named builder that reshapes ONE lever of a target cell;
``python -m repro.launch.perf`` (through dryrun-style lowering) measures the
three roofline terms before/after and appends to perf_results.json.

Variants:
  lm:    chunked attention (attn_chunk), microbatch accumulation, remat off
  gnn:   bf16 message collectives, label-pruned final layer
  favor: selectivity-sample sizing, candidate-pool width
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_spec
from ..models import gnn
from ..models.transformer import lm_loss
from ..training import optimizer as opt
from ..training.step import make_train_step
from . import cells as C


# ---------------------------------------------------------------------------
# LM variants
# ---------------------------------------------------------------------------
def lm_variant(arch: str, shape: str, *, attn_chunk: int = 0,
               microbatches: int = 1, remat: bool | None = None,
               capacity_factor: float = 0.0):
    def _cfg(spec, extra):
        cfg = dataclasses.replace(
            spec.config, attn_chunk=attn_chunk,
            **({"remat": remat} if remat is not None else {}), **extra)
        if capacity_factor and cfg.moe:
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=capacity_factor))
        return cfg

    def build(arch_, shape_, mesh):
        spec = get_spec(arch_)
        cfg = _cfg(spec, {})
        spec2 = dataclasses.replace(spec, config=cfg)
        cell = C.build_lm_cell(spec2, spec.cell(shape_), mesh)
        if microbatches > 1 and spec.cell(shape_).kind == "train":
            ocfg = opt.OptConfig(total_steps=10000)

            def loss_fn(p, batch):
                return lm_loss(p, cfg, batch["tokens"], batch["labels"], mesh)

            cell.step_fn = make_train_step(loss_fn, ocfg,
                                           microbatches=microbatches)
            cell.note = (cell.note or "") + f" mb={microbatches}"
        return cell

    def probe_build(arch_, shape_, mesh, depth):
        spec = get_spec(arch_)
        cfg = _cfg(spec, {"n_layers": depth, "unroll_layers": True})
        spec2 = dataclasses.replace(spec, config=cfg)
        return C.build_lm_cell(spec2, spec.cell(shape_), mesh)

    return build, probe_build


# ---------------------------------------------------------------------------
# GNN variants (gcn ogb_products: the collective-bound cell)
# ---------------------------------------------------------------------------
def gnn_variant(arch: str, shape: str, *, bf16_msgs: bool = False,
                label_prune: float = 0.0, bf16_end2end: bool = False):
    """bf16_msgs: cast hidden features to bf16 around segment_sum so the
    edge-sharded psum all-reduces carry half the bytes.
    label_prune: fraction of labeled nodes; the FINAL conv layer aggregates
    only edges into labeled nodes (receptive-field pruning), shrinking the
    last (and widest) all-reduce by ~1/fraction."""
    def build(arch_, shape_, mesh):
        spec = get_spec(arch_)
        cell0 = C.build_gnn_cell(spec, spec.cell(shape_), mesh)
        meta = spec.cell(shape_).meta
        n_classes = C._GNN_CLASSES[shape_]
        cfg = dataclasses.replace(spec.config, d_feat=meta["d_feat"],
                                  n_classes=n_classes)
        params_sds, opt_sds, batch_sds = cell0.args
        param_sh, opt_sh, bsh = cell0.in_shardings
        all_ax = tuple(mesh.axis_names)
        n_dev = len(mesh.devices.reshape(-1))

        n_labeled = 0
        if label_prune > 0:
            n = batch_sds["x"].shape[0]
            e = batch_sds["edges"].shape[1]
            n_labeled = max(1, int(n * label_prune))
            e_last = -(-max(1, int(e * label_prune)) // n_dev) * n_dev
            batch_sds = dict(batch_sds)
            batch_sds["final_edges"] = jax.ShapeDtypeStruct((2, e_last), jnp.int32)
            batch_sds["label_idx"] = jax.ShapeDtypeStruct((n_labeled,), jnp.int32)
            bsh = dict(bsh)
            bsh["final_edges"] = NamedSharding(mesh, P(None, all_ax))
            bsh["label_idx"] = NamedSharding(mesh, P())

        ocfg = opt.OptConfig(total_steps=1000)

        def loss_fn(p, batch):
            return gnn_loss_opt(p, cfg, batch, bf16_msgs=bf16_msgs,
                                n_labeled=n_labeled, bf16_end2end=bf16_end2end)

        cell0.step_fn = make_train_step(loss_fn, ocfg)
        cell0.args = (params_sds, opt_sds, batch_sds)
        cell0.in_shardings = (param_sh, opt_sh, bsh)
        cell0.note = f"bf16_msgs={bf16_msgs} label_prune={label_prune}"
        return cell0

    return build, None


def gnn_loss_opt(params, cfg, batch, *, bf16_msgs: bool, n_labeled: int,
                 bf16_end2end: bool = False):
    """GCN loss with optional bf16 message casting and final-layer pruning.
    bf16_end2end keeps hidden features bf16 through relu/matmul so the
    collective itself must carry bf16 (no convert between scatter and psum
    for XLA to hoist)."""
    x, edges, deg = batch["x"], batch["edges"], batch["deg"]
    labels, mask = batch["labels"], batch["mask"]
    n = x.shape[0]
    cast = (lambda t: t.astype(jnp.bfloat16)) if bf16_msgs else (lambda t: t)
    uncast = ((lambda t: t) if bf16_end2end else
              ((lambda t: t.astype(jnp.float32)) if bf16_msgs else (lambda t: t)))
    if bf16_end2end:
        x = x.astype(jnp.bfloat16)

    coeff, s, d = gnn._sym_coeff(edges, deg)
    h = x
    dims = cfg.dims()
    for i, _ in enumerate(dims[:-1]):
        h = h @ params[f"conv{i}"]["w"]
        msg = cast(h[s] * coeff[:, None].astype(h.dtype))
        h = uncast(jax.ops.segment_sum(msg, d, num_segments=n))
        h = jax.nn.relu(h + params[f"conv{i}"]["b"])

    i_last = len(dims) - 1
    h = h @ params[f"conv{i_last}"]["w"]
    if n_labeled:
        fe = batch["final_edges"]
        li = batch["label_idx"]
        coeff_f, s_f, d_f = gnn._sym_coeff(fe, deg)
        msg = cast(h[s_f] * coeff_f[:, None].astype(h.dtype))
        # d_f indexes into the compact labeled-row space [0, n_labeled)
        logits = uncast(jax.ops.segment_sum(msg, d_f, num_segments=n_labeled))
        logits = logits + params[f"conv{i_last}"]["b"]
        lbl = labels[li]
        msk = mask[li]
    else:
        msg = cast(h[s] * coeff[:, None].astype(h.dtype))
        logits = uncast(jax.ops.segment_sum(msg, d, num_segments=n))
        logits = logits + params[f"conv{i_last}"]["b"]
        lbl, msk = labels, mask

    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, jnp.maximum(lbl, 0)[:, None], axis=-1)[:, 0]
    w = msk.astype(jnp.float32)
    loss = jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
    return loss, {"ce_loss": loss}


# ---------------------------------------------------------------------------
# FAVOR variants
# ---------------------------------------------------------------------------
def favor_variant(arch: str, shape: str, *, sample_rate: float = 0.01,
                  cand_cap: int = 0, batch: int = 0, n: int = 0):
    def build(arch_, shape_, mesh):
        from ..configs import favor_anns
        spec = get_spec("favor-anns")
        cfg = spec.config
        if batch:
            cfg = dataclasses.replace(cfg, batch=batch)
        if n:
            cfg = dataclasses.replace(cfg, n=n)
        from ..core import distributed as dist
        from ..core.search import SearchConfig
        model = C._mesh_axis_size(mesh, "model")
        qax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        specs = dist.input_specs(cfg.n, cfg.dim, cfg.m_i, cfg.m_f, model,
                                 m0=cfg.m0, m=cfg.m, n_upper=cfg.n_upper,
                                 width=cfg.width, batch=cfg.batch,
                                 sample_rate=sample_rate)
        scfg = SearchConfig(k=cfg.k, ef=cfg.ef, cand_cap=cand_cap)
        fns = dist.make_serve_fns(mesh, scfg, query_axes=qax)
        route = spec.cell(shape_).meta["route"]
        fn = fns["serve_graph"] if route == "graph" else fns["serve_brute"]
        mf = (cfg.batch * 4.0 * cfg.ef * cfg.m0 * 2.0 * cfg.dim
              if route == "graph" else cfg.batch * cfg.n * 2.0 * cfg.dim)
        return C.Cell("favor-anns", shape_, fn,
                      (specs["db"], specs["queries"], specs["programs"],
                       specs["valid"]),
                      None, mf,
                      note=f"sample_rate={sample_rate} ccap={cand_cap} b={batch}")

    return build, None
