"""Quickstart: build a FAVOR index and run hybrid vector+attribute queries.

Uses the typed API: construction is configured by a frozen ``BuildSpec``,
each search batch by a frozen ``SearchOptions`` (the legacy
``fi.search(k=, ef=, ...)`` kwargs still work but are deprecated).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (BuildSpec, FavorIndex, HnswParams, SearchOptions,
                        paper_filters)
from repro.core import filters as F
from repro.core import refimpl
from repro.data import synthetic


def main():
    n, dim, nq = 8000, 32, 64
    print(f"building FAVOR index: {n} vectors x {dim} dims ...")
    vecs, attrs, schema = synthetic.make_paper_dataset(n, dim, seed=0)
    fi = FavorIndex.build(vecs, attrs,
                          spec=BuildSpec(hnsw=HnswParams(M=12, efc=60, seed=0)))
    print(f"  built in {fi.build_seconds:.1f}s  Delta_d={fi.delta_d:.4f} "
          f"(Eq. 5, recorded offline)")

    queries = synthetic.make_queries(nq, dim)
    opts = SearchOptions(k=10, ef=96)
    for name, flt in paper_filters(schema).items():
        res = fi.query(queries, flt, opts)
        mask = F.eval_program(F.compile_filter(flt, schema), attrs.ints,
                              attrs.floats)
        truth = [refimpl.bruteforce_filtered(vecs, mask, q, 10)[0]
                 for q in queries]
        rec = np.mean([refimpl.recall_at_k(res.ids[i], truth[i], 10)
                       for i in range(nq)])
        route = "brute" if res.routed_brute.all() else (
            "graph" if not res.routed_brute.any() else "mixed")
        print(f"  {name:15s} p_hat={res.p_hat.mean():6.3f} route={route:6s} "
              f"recall@10={rec:.3f} qps={res.qps:8.1f}")

    # custom composite filter (Logic: AND of int equality and float range)
    custom = F.And(F.Equality("i0", 3), F.Range("f0", 20.0, 70.0))
    res = fi.query(queries[:8], custom, SearchOptions(k=5, ef=96))
    print("\ncustom filter results (ids):")
    print(res.ids)


if __name__ == "__main__":
    main()
