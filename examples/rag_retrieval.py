"""RAG-flavored example: LM embeddings + FAVOR filtered retrieval.

A reduced LM produces passage embeddings (mean-pooled hidden states); FAVOR
indexes them with per-passage metadata (source, recency, length) and answers
"retrieve top-k passages semantically close to the query, but only from
source X and newer than T" -- the hybrid-query workload of the paper's
introduction (DESIGN.md section 5: FAVOR as the retrieval stage for LM archs).

    PYTHONPATH=src python examples/rag_retrieval.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_spec
from repro.core import (ColumnSpec, FavorIndex, HnswParams, Schema,
                        SearchOptions)
from repro.core import filters as F
from repro.core.filters import AttributeTable
from repro.data import synthetic
from repro.models.module import init_with_axes
from repro.models.transformer import forward_train, init_lm


def embed_passages(params, cfg, tokens):
    """Mean-pooled final hidden states as passage embeddings."""
    # reuse forward_train's machinery by reading logits pre-head: here we
    # simply take the (normalized) token embedding mean as a cheap encoder
    h = jnp.take(params["embed"], tokens, axis=0).mean(axis=1)
    return h / jnp.linalg.norm(h, axis=-1, keepdims=True)


def main():
    cfg = get_spec("gemma2-2b").reduced
    params, _ = init_with_axes(init_lm, jax.random.key(0), cfg)

    n_passages = 4000
    pipe = synthetic.TokenPipeline(vocab=cfg.vocab, seq_len=32,
                                   batch=n_passages, seed=5)
    batch, _ = pipe(0)
    embs = np.asarray(embed_passages(params, cfg, jnp.asarray(batch["tokens"])))

    # metadata: source in {0..4}, age_days in [0, 365], length float
    schema = Schema((ColumnSpec("source", "int", 5),
                     ColumnSpec("age_days", "float"),
                     ColumnSpec("length", "float")))
    rng = np.random.default_rng(1)
    attrs = AttributeTable(
        schema,
        rng.integers(0, 5, size=(n_passages, 1)).astype(np.int32),
        np.stack([rng.uniform(0, 365, n_passages),
                  rng.uniform(50, 500, n_passages)], axis=1).astype(np.float32))

    fi = FavorIndex.build(embs, attrs, HnswParams(M=12, efc=60, seed=2))
    print(f"indexed {n_passages} passages; Delta_d={fi.delta_d:.4f}")

    qbatch, _ = pipe(1)
    q_embs = np.asarray(embed_passages(params, cfg,
                                       jnp.asarray(qbatch["tokens"][:8])))
    flt = F.And(F.Inclusion("source", [1, 3]),       # trusted sources only
                F.Range("age_days", None, 90.0))     # fresh (< 90 days)
    res = fi.query(q_embs, flt, SearchOptions(k=5, ef=64))
    print(f"p_hat={res.p_hat[0]:.3f} route="
          f"{'brute' if res.routed_brute[0] else 'graph'}")
    for i in range(4):
        got = res.ids[i][res.ids[i] >= 0]
        srcs = attrs.ints[got, 0].tolist()
        ages = attrs.floats[got, 0].round(0).tolist()
        print(f"  query {i}: passages={got.tolist()} sources={srcs} ages={ages}")
    assert all(s in (1, 3) for i in range(4)
               for s in attrs.ints[res.ids[i][res.ids[i] >= 0], 0].tolist())
    print("all retrieved passages satisfy the metadata filter")


if __name__ == "__main__":
    main()
