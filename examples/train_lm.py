"""Train a reduced LM (any --arch) for a few hundred steps with the full
fault-tolerant loop: checkpointing, resume, straggler watchdog.

    PYTHONPATH=src python examples/train_lm.py --arch gemma2-2b --steps 200
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_spec
from repro.data import synthetic
from repro.models.module import init_with_axes, param_count
from repro.models.transformer import init_lm, lm_loss
from repro.training import fault_tolerance as ft
from repro.training import optimizer as opt
from repro.training.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = get_spec(args.arch).reduced
    params, _ = init_with_axes(init_lm, jax.random.key(0), cfg)
    print(f"{args.arch} (reduced): {param_count(params):,} params")

    pipe = synthetic.TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                                   batch=args.batch, seed=3)
    ocfg = opt.OptConfig(lr=1e-2, total_steps=args.steps, warmup_steps=10)

    def loss_fn(p, b):
        return lm_loss(p, cfg, jnp.asarray(b["tokens"]),
                       jnp.asarray(b["labels"]))

    raw_step = jax.jit(make_train_step(loss_fn, ocfg))

    def step_fn(state, batch):
        p, s, metrics = raw_step(state["params"], state["opt"], batch)
        state["params"], state["opt"] = p, s
        return state, metrics

    state = {"params": params, "opt": opt.init_opt_state(params, ocfg),
             "data_state": pipe.init_state(), "step": 0}
    state, metrics, wd = ft.run_loop(
        step_fn, state, pipe, n_steps=args.steps, ckpt_dir=args.ckpt,
        save_every=50, log_every=20)
    print(f"final loss: {float(metrics['loss']):.4f}  "
          f"(straggler steps: {wd.slow_steps}, median step {wd.median*1e3:.0f}ms)")


if __name__ == "__main__":
    main()
