"""End-to-end serving driver: batched FAVOR engine under a mixed workload.

Simulates the paper's production scenario: a stream of hybrid queries with
heterogeneous filters (and thus heterogeneous selectivity) hits the batched
engine; the selectivity-driven selector routes each to PreFBF or the
exclusion-distance graph search.  Reports routing statistics, recall and
latency percentiles.

    PYTHONPATH=src python examples/serve_anns.py
"""
import numpy as np

from repro.core import FavorIndex, HnswParams, paper_filters
from repro.core import filters as F
from repro.core import refimpl
from repro.data import synthetic
from repro.serving import ServeEngine


def main():
    n, dim = 10000, 32
    print(f"building index ({n} x {dim}) ...")
    vecs, attrs, schema = synthetic.make_paper_dataset(n, dim, seed=1)
    fi = FavorIndex.build(vecs, attrs, HnswParams(M=12, efc=60, seed=1))
    eng = ServeEngine(fi, k=10, ef=96, max_batch=64)

    rng = np.random.default_rng(0)
    base = paper_filters(schema)
    workload = list(base.values()) + [
        F.And(F.Equality("i0", int(v)), F.Range("f0", lo, lo + 8.0))  # ~0.8%
        for v, lo in zip(rng.integers(0, 10, 4), rng.uniform(0, 90, 4))
    ]
    n_requests = 512
    print(f"submitting {n_requests} requests with {len(workload)} filter kinds ...")
    reqs = {}
    for i in range(n_requests):
        q = synthetic.make_queries(1, dim, seed=200 + i)[0]
        flt = workload[int(rng.integers(0, len(workload)))]
        rid = eng.submit(q, flt)
        reqs[rid] = (q, flt)

    responses = eng.run()
    print(f"done: {len(responses)} responses in {eng.stats['batches']} batches")
    print(f"routing: graph={eng.stats['graph']} brute={eng.stats['brute']}")
    pct = eng.latency_percentiles()
    print("latency ms: " + "  ".join(f"{k}={v:.1f}" for k, v in pct.items()))

    # verify a sample against ground truth
    sample = rng.choice(len(responses), 32, replace=False)
    recs = []
    for si in sample:
        r = responses[si]
        q, flt = reqs[r.rid]
        mask = F.eval_program(F.compile_filter(flt, schema), attrs.ints,
                              attrs.floats)
        truth, _ = refimpl.bruteforce_filtered(vecs, mask, q, 10)
        recs.append(refimpl.recall_at_k(r.ids[r.ids >= 0], truth, 10))
    print(f"sampled recall@10 = {np.mean(recs):.3f}")


if __name__ == "__main__":
    main()
